"""Benchmark harness — one function per paper table.

  table1_latency_split   Tab. I   frontend vs backend time, 3 modes
  table_fe_fm_ratio      Fig. 4   FE vs FM stage latency (multiplexing
                                  rationale: steady period = max(2FE, FM))
  table2_module_cost     Tab. II  per-module cost split (FE ~ 2/3 claim)
  table3_accuracy        Tab. III hardware path (Pallas) vs software
                                  (jnp oracle) + word-length ablation
  table4_throughput      Tab. IV  fps at 640x480 / 1280x720 on this CPU
                                  + modeled TPU-v5e roofline fps
  table_fused_vs_seed    PR 1     fused batched frontend (one dense
                                  launch per level for all 4 cameras) vs
                                  the seed per-camera-per-op dispatch:
                                  wall clock + traced launch counts
  table_describe_fused_vs_gather
                         PR 2     fused sparse descriptor stage (one
                                  orientation+rBRIEF launch per level,
                                  LUT-binned steering) vs the seed
                                  host-graph per-keypoint gathers
  table_whole_frame_vs_per_level
                         PR 3     whole-frame schedule (ONE dense + ONE
                                  sparse launch per frame for all
                                  cameras x levels, ragged levels padded
                                  to a common tile grid) vs the
                                  per-level schedule (2 launches per
                                  level): wall clock + traced launch
                                  counts; also emits the launch_gate
                                  rows the CI regression gate
                                  (check_launches.py) enforces
  table_fm_fused_vs_unfused
                         PR 4     fused FM megakernel (ONE launch per
                                  frame: Hamming match + in-kernel SAD
                                  patch reads, pair axis in the grid)
                                  vs the unfused two-kernel +
                                  host-graph-gather schedule: wall
                                  clock + traced launch counts
  table_fleet            PR 5     `VisualSystem.process_fleet`: an
                                  N-rig fleet frame folded into the
                                  batched kernels (3 launches total,
                                  same as one rig) vs the per-rig
                                  python loop; emits the
                                  launch_gate/fleet_frame_* rows CI
                                  enforces
  table_service          PR 6     streaming fleet service under fault
                                  injection; emits the degraded-fleet
                                  launch_gate rows
  table_precision        PR 7     uint8 integer datapath vs f32: wall
                                  clock + computed resident FM slab
                                  bytes/pair (4x cut), and the
                                  launch_gate/u8_* rows CI enforces
                                  (uint8 frame/fleet frame == 3
                                  launches)
  table_localization     PR 8     depth + ego-motion backend closed
                                  against scene ground truth: ATE/RPE
                                  accuracy_gate rows CI enforces for
                                  f32 AND uint8, plus the
                                  launch_gate/loc_* rows (localized
                                  frame <= 3 frontend + 1 backend
                                  launches)
  table_failover         PR 9     multi-host failover: host_down
                                  redistribution + guarded-dispatch
                                  episode (frames dropped, rigs moved,
                                  retries) and a kill-and-recover
                                  episode through a crash-consistent
                                  snapshot (recovery wall clock,
                                  snapshot bytes); emits the
                                  launch_gate/restored_fleet_frame_*
                                  rows CI enforces

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--out PATH]
Prints CSV rows ``table,name,value,unit,note`` and writes them to a
JSON artifact (default BENCH_frontend.json) for perf-trajectory
tracking in CI.

Timing discipline: every benchmark output is ``jax.block_until_ready``'d
— including outputs produced OUTSIDE ``_bench`` that later feed a timed
function — so no reported ms silently includes an async dependency.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CameraIntrinsics, ORBConfig, PipelineConfig,
                        RigConfig, VisualSystem, backend,
                        extract_features, pipeline_schedule)
from repro.core import pyramid
from repro.data import scenes
from repro.kernels import ops, ref


def _stereo_vs(ocfg, intr=None, impl=None):
    intr = intr if intr is not None else CameraIntrinsics()
    return VisualSystem(RigConfig.stereo(intr),
                        PipelineConfig(orb=ocfg, impl=impl))


def _stereo_frame(vs, img_l, img_r):
    out = vs.process_frame(jnp.stack([img_l, img_r]))
    return jax.tree.map(lambda x: x[0], out)

ROWS = []


def emit(table, name, value, unit="", note=""):
    ROWS.append((table, name, value, unit, note))
    print(f"{table},{name},{value},{unit},{note}", flush=True)


def _bench(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def _bench_median(fn, *args, iters=5, reps=3):
    """Median of ``reps`` independent ``_bench`` means.  For contenders
    whose wall clocks are within scheduler noise of each other (the
    fused-vs-unfused FM table), a single mean can flip the reported
    speedup by 2x on a loaded host; the median of repeats keeps the
    perf-trajectory artifact rows trustworthy."""
    return sorted(_bench(fn, *args, iters=iters)[0]
                  for _ in range(reps))[reps // 2]


def _scene(h, w, n=300, seed=11):
    cfg = scenes.SceneConfig(height=h, width=w, n_points=n, seed=seed,
                             baseline=0.3)
    frames, poses, intr = scenes.render_sequence(cfg, 3)
    return frames, poses, intr, cfg


# ---------------------------------------------------------------------------

def table1_latency_split(quick=False):
    """Tab. I analog: share of localization time spent in the visual
    frontend for three backend modes.  Paper: 54.8% (SLAM), 86.7% (VIO),
    84.6% (Registration)."""
    h, w = (120, 160) if quick else (240, 320)
    frames, poses, intr, _ = _scene(h, w)
    ocfg = ORBConfig(height=h, width=w, max_features=256, n_levels=2,
                     max_disparity=64)

    vs = _stereo_vs(ocfg, intr)
    fe_fm = lambda l, r: _stereo_frame(vs, l, r)   # session-jitted
    t_front, out0 = _bench(fe_fm, frames[0, 0], frames[0, 1])
    out1 = jax.block_until_ready(fe_fm(frames[1, 0], frames[1, 1]))

    def make_backend(refine, iters):
        def run(prev_feats, prev_depth, curr_feats, curr_depth):
            tm = vs.temporal_match(prev_feats, curr_feats)
            pts_p = backend.triangulate(prev_feats, prev_depth, intr)
            pts_c = backend.triangulate(curr_feats, curr_depth, intr)
            idx = tm.right_index
            wgt = (tm.valid & prev_depth.valid
                   & curr_depth.valid[idx]).astype(jnp.float32)
            return backend.estimate_relative_pose(
                pts_p, pts_c[idx], wgt, curr_feats.xy[idx], intr,
                refine=refine, robust_iters=iters)
        return jax.jit(run)

    modes = {"slam": make_backend(True, 3),
             "vio": make_backend(False, 1),
             "registration": make_backend(True, 6)}
    for mode, fn in modes.items():
        t_back, _ = _bench(fn, out0.features_l, out0.depth,
                           out1.features_l, out1.depth)
        share = t_front / (t_front + t_back)
        emit("table1", f"frontend_share_{mode}", round(100 * share, 1),
             "%", f"front {t_front*1e3:.1f}ms back {t_back*1e3:.1f}ms")
    emit("table1", "paper_frontend_share",
         "54.8/86.7/84.6", "%", "slam/vio/registration (paper Tab. I)")


def table_fe_fm_ratio(quick=False):
    """Fig. 4 rationale: FM latency ~ 2x FE at 640x480 in the paper
    (7.28 vs 14.59 ms) -> two channels share one FE."""
    h, w = (240, 320) if quick else (480, 640)
    frames, poses, intr, _ = _scene(h, w)
    ocfg = ORBConfig(height=h, width=w, max_features=512, n_levels=2,
                     max_disparity=96)
    fe = jax.jit(lambda im: extract_features(im, ocfg))
    t_fe, featl = _bench(fe, frames[0, 0])
    featr = jax.block_until_ready(fe(frames[0, 1]))
    vs = _stereo_vs(ocfg, intr)
    fm = lambda l, r, fl, fr: vs.match_pair(l, r, fl, fr)
    t_fm, _ = _bench(fm, frames[0, 0], frames[0, 1], featl, featr)
    emit("fig4", "t_fe_ms", round(t_fe * 1e3, 2), "ms", "one image")
    emit("fig4", "t_fm_ms", round(t_fm * 1e3, 2), "ms", "stereo pair")
    emit("fig4", "fm_over_fe", round(t_fm / t_fe, 2), "x",
         "paper: 2.0 (7.28 vs 14.59 ms)")
    sched = pipeline_schedule(100, t_fe * 1e3, t_fm * 1e3)
    emit("fig4", "steady_period_ms", round(sched["steady_period_ms"], 2),
         "ms", "frame-multiplexed pipeline")
    emit("fig4", "serial_period_ms", round(sched["serial_period_ms"], 2),
         "ms", "no pipelining")
    emit("fig4", "pipeline_speedup",
         round(sched["serial_period_ms"] / sched["steady_period_ms"], 2),
         "x", "Fig. 4 schedule vs serial")


def table2_module_cost(quick=False):
    """Tab. II analog: per-module share of frontend cost.  The FPGA
    spends ~2/3 of its resources on FE; we report the wall-time split of
    the same module boundary plus per-module times."""
    h, w = (240, 320) if quick else (480, 640)
    frames, poses, intr, _ = _scene(h, w)
    ocfg = ORBConfig(height=h, width=w, max_features=512, n_levels=2,
                     max_disparity=96)
    from repro.core import brief, fast
    img = frames[0, 0]

    mods = {}
    t, levels = _bench(jax.jit(lambda i: pyramid.build_pyramid(i, ocfg)),
                       img)
    mods["resize"] = t
    t, _ = _bench(jax.jit(lambda i: ops.fast_score_map(
        i, float(ocfg.fast_threshold))), levels[0])
    mods["fast_detect"] = t
    xy = jnp.asarray(np.stack([np.random.RandomState(0).randint(
        16, w - 16, 512), np.random.RandomState(1).randint(
        16, h - 16, 512)], 1).astype(np.int32))
    t, _ = _bench(jax.jit(lambda i, p: fast.orientations(i, p)),
                  levels[0], xy)
    mods["orientation"] = t
    t, sm = _bench(jax.jit(lambda i: ops.gaussian_blur7(i)), levels[0])
    mods["smoothing"] = t
    th = jnp.zeros((512,))
    t, _ = _bench(jax.jit(lambda s, p, a: brief.describe(s, p, a)),
                  sm, xy, th)
    mods["descriptor"] = t
    vs = _stereo_vs(ocfg, intr)
    fe = jax.jit(lambda i: extract_features(i, ocfg))
    featl = jax.block_until_ready(fe(frames[0, 0]))
    featr = jax.block_until_ready(fe(frames[0, 1]))
    t, m = _bench(vs.stereo_match, featl, featr)
    mods["stereo_match"] = t
    t, _ = _bench(vs.sad_rectify, frames[0, 0], frames[0, 1],
                  featl, featr, m)
    mods["sad_rectify"] = t

    total = sum(mods.values())
    fe_mods = ("resize", "fast_detect", "orientation", "smoothing",
               "descriptor")
    fe_share = sum(mods[k] for k in fe_mods) / total
    for k, v in mods.items():
        emit("table2", f"{k}_ms", round(v * 1e3, 3), "ms",
             "FE" if k in fe_mods else "FM")
    emit("table2", "fe_share", round(100 * fe_share, 1), "%",
         "paper: FE ~ 2/3 of frontend resources")


def table3_accuracy(quick=False):
    """Tab. III: hardware path vs software reference over frames.
    Paper error: < 0.3% on counts; ours is bit-exact (0.0%).  Plus the
    word-length (quantized vs float) ablation."""
    h, w = (120, 160) if quick else (240, 320)
    n_frames = 2 if quick else 6
    cfg = scenes.SceneConfig(height=h, width=w, n_points=200, seed=5,
                             baseline=0.3)
    frames, _, intr = scenes.render_sequence(cfg, n_frames)
    ocfg = ORBConfig(height=h, width=w, max_features=256, n_levels=2,
                     max_disparity=64)
    tot = {"feat": [0, 0], "match": [0, 0], "depth": [0, 0]}
    coord_eq = [0, 0]
    vs_hw = _stereo_vs(ocfg, intr, impl="pallas")
    vs_sw = _stereo_vs(ocfg, intr, impl="ref")
    for t in range(n_frames):
        hw = _stereo_frame(vs_hw, frames[t, 0], frames[t, 1])
        sw = _stereo_frame(vs_sw, frames[t, 0], frames[t, 1])
        tot["feat"][0] += int(hw.features_l.count())
        tot["feat"][1] += int(sw.features_l.count())
        tot["match"][0] += int(hw.matches.count())
        tot["match"][1] += int(sw.matches.count())
        tot["depth"][0] += int(hw.depth.count())
        tot["depth"][1] += int(sw.depth.count())
        eq = np.asarray(hw.features_l.xy) == np.asarray(sw.features_l.xy)
        coord_eq[0] += int(eq.all(-1).sum())
        coord_eq[1] += int(eq.shape[0])
    for k, (a, b) in tot.items():
        err = 100.0 * abs(a - b) / max(b, 1)
        emit("table3", f"{k}_hw_vs_sw", f"{a}/{b}", "count",
             f"err {err:.2f}% (paper <0.3%)")
    emit("table3", "coord_agreement",
         round(100 * coord_eq[0] / coord_eq[1], 2), "%",
         "paper: 99.7/98.2/96.8%")

    q = ocfg
    f = ORBConfig(**{**q.__dict__, "quantized": False})
    hwq = _stereo_frame(_stereo_vs(q, intr), frames[0, 0], frames[0, 1])
    hwf = _stereo_frame(_stereo_vs(f, intr), frames[0, 0], frames[0, 1])
    emit("table3", "wordlen_feat_counts",
         f"{int(hwq.features_l.count())}/{int(hwf.features_l.count())}",
         "count", "8-bit vs float datapath (ablation)")


def table4_throughput(quick=False):
    """Tab. IV: frontend fps at the paper's two resolutions, on this
    CPU (measured) and on TPU v5e (roofline model from kernel
    flops/bytes).  Paper: 69 fps @640x480, 50.7 fps @1280x720 (FPGA);
    9 fps (TX1), 15 fps (i7) @720p."""
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        frames, poses, intr, _ = _scene(h, w, n=400)
        ocfg = ORBConfig(height=h, width=w, max_features=1000,
                         n_levels=2, max_disparity=96)
        vs = _stereo_vs(ocfg, intr)
        step = lambda l, r: _stereo_frame(vs, l, r)
        t, _ = _bench(step, frames[0, 0], frames[0, 1], iters=3)
        emit("table4", f"cpu_fps_{w}x{h}", round(1.0 / t, 1), "fps",
             "this host, one stereo pair")
        # v5e roofline model: frontend is stencil/popcount bound ->
        # bytes-dominated; count pyramid+blur+fast traffic + matcher
        px = h * w * (1 + 1 / (ocfg.scale_factor ** 2))
        bytes_img = px * 4 * 6          # score map, blur, pyramid r/w
        flops_img = px * (16 * 3 * 9 + 49 * 2)  # fast arcs + blur taps
        k = ocfg.max_features
        bytes_match = k * k * 4 + k * 32 * 2
        t_mem = (2 * bytes_img + bytes_match) / HBM_BW
        t_cmp = (2 * flops_img + k * k * 256 * 2) / PEAK_FLOPS_BF16
        fps = 1.0 / max(t_mem, t_cmp)
        emit("table4", f"v5e_model_fps_{w}x{h}", round(fps, 0), "fps",
             "roofline bound, one chip")
    emit("table4", "paper_fpga_fps", "69/50.7", "fps",
         "640x480 / 1280x720")
    emit("table4", "paper_baselines_720p", "TX1 9, i7 15", "fps",
         "paper Tab. IV")


def table_fused_vs_seed(quick=False):
    """Tentpole regression number: the fused batched frontend (ONE
    launch per pyramid level for all 4 cameras, blur + FAST + NMS in one
    VMEM pass) vs the seed dispatch (per camera: separate blur and FAST
    passes over the same pixels plus eight host-graph NMS slices).

    Wall clock is measured on the jnp fallback (interpret-free CPU
    path); kernel-launch counts are traced under the Pallas impl and are
    the deterministic, machine-independent half of the comparison.
    """
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        rng = np.random.RandomState(7)
        imgs = jnp.asarray(rng.randint(0, 256, (4, h, w)).astype(np.float32))
        ocfg = ORBConfig(height=h, width=w, n_levels=2)
        thr = float(ocfg.fast_threshold)

        def seed_frontend(images, impl="ref"):
            """Seed schedule: python-loop over cameras and levels,
            separate blur / FAST launches, jnp-slice NMS."""
            outs = []
            for c in range(images.shape[0]):
                for lv in pyramid.build_pyramid(images[c], ocfg):
                    score = ops.fast_score_map(lv, thr, impl=impl)
                    score = ref.nms3(score)
                    blur = ops.gaussian_blur7(lv, quantized=True, impl=impl)
                    outs.append((blur, score))
            return outs

        def fused_frontend(images, impl="ref"):
            """Fused schedule: one batched launch per level."""
            outs = []
            for lv in pyramid.build_pyramid_batched(images, ocfg):
                outs.append(ops.fast_blur_nms_batched(
                    lv, thr, nms=True, quantized=True, impl=impl))
            return outs

        iters = 3 if (h, w) == (720, 1280) else 5
        t_seed, _ = _bench(jax.jit(seed_frontend), imgs, iters=iters)
        t_fused, _ = _bench(jax.jit(fused_frontend), imgs, iters=iters)
        res = f"{w}x{h}"
        emit("fused", f"seed_ms_{res}", round(t_seed * 1e3, 2), "ms",
             "4 cams x 2 levels, per-image dispatch (jnp)")
        emit("fused", f"fused_ms_{res}", round(t_fused * 1e3, 2), "ms",
             "4 cams x 2 levels, batched fused (jnp)")
        emit("fused", f"speedup_{res}", round(t_seed / t_fused, 2), "x",
             "seed / fused wall clock")

        # Launch counts: trace-only (no kernel execution) under Pallas.
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda im: seed_frontend(im, impl="pallas"), imgs)
        n_seed = audit.count
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda im: fused_frontend(im, impl="pallas"), imgs)
        n_fused = audit.count
        emit("fused", f"launches_seed_{res}", n_seed, "kernels",
             "4 cams x 2 levels x (blur + fast)")
        emit("fused", f"launches_fused_{res}", n_fused, "kernels",
             "1 fused launch per level")


def table_describe_fused_vs_gather(quick=False):
    """Tentpole regression number for the sparse stage: the fused
    orientation + rBRIEF dispatch (ONE launch per level for all 4
    cameras, LUT-binned steering, gather-free taps) vs the seed schedule
    (vmapped per-keypoint 31x31 dynamic_slice gathers + per-keypoint
    cos/sin exact steering on the host graph).

    Wall clock is measured on the jnp paths (interpret-free CPU);
    launch counts are traced under the Pallas impl — the deterministic
    half.
    """
    from repro.core import fast
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        rng = np.random.RandomState(7)
        imgs = jnp.asarray(rng.randint(0, 256, (4, h, w)).astype(np.float32))
        ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=1000)
        res = f"{w}x{h}"

        # Dense stage + top-K once, outside the timed region: both
        # contenders consume identical (raw, smoothed, xy) level inputs.
        levels = pyramid.build_pyramid_batched(imgs, ocfg)
        ks = ocfg.features_per_level()
        staged = []
        for imgs_l, k_l in zip(levels, ks):
            smoothed, score = ops.fast_blur_nms_batched(
                imgs_l, float(ocfg.fast_threshold), impl="ref")
            xy, _, _ = jax.vmap(
                lambda s, k=k_l: fast.select_topk(s, k, ocfg.border))(score)
            staged.append((jax.block_until_ready(imgs_l),
                           jax.block_until_ready(smoothed),
                           jax.block_until_ready(xy)))

        def gather_stage(staged_levels):
            """Seed schedule: host-graph patch gathers, exact steering."""
            outs = []
            for raw_l, sm_l, xy_l in staged_levels:
                theta = jax.vmap(lambda im, p: ref.patch_theta(
                    ref.extract_patches(im, p))[0])(raw_l, xy_l)
                desc = jax.vmap(ref.describe_steered)(sm_l, xy_l, theta)
                outs.append((theta, desc))
            return outs

        def fused_stage(staged_levels, impl="ref"):
            """Fused schedule: one sparse dispatch per level."""
            return [ops.orient_describe_batched(raw_l, sm_l, xy_l, impl=impl)
                    for raw_l, sm_l, xy_l in staged_levels]

        iters = 3 if (h, w) == (720, 1280) else 5
        t_gather, _ = _bench(jax.jit(gather_stage), staged, iters=iters)
        t_fused, _ = _bench(jax.jit(fused_stage), staged, iters=iters)
        emit("describe", f"gather_ms_{res}", round(t_gather * 1e3, 2), "ms",
             "4 cams x 2 levels, vmapped 31x31 gathers + exact steering")
        emit("describe", f"fused_ms_{res}", round(t_fused * 1e3, 2), "ms",
             "4 cams x 2 levels, batched LUT dispatch (jnp)")
        emit("describe", f"speedup_{res}", round(t_gather / t_fused, 2), "x",
             "gather / fused wall clock")

        # Launch counts: trace-only (no kernel execution) under Pallas.
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda s: fused_stage(s, impl="pallas"), staged)
        emit("describe", f"launches_fused_{res}", audit.count,
             "kernels", "1 sparse launch per level (gather path: 0 "
             "kernels, all host graph)")


def table_whole_frame_vs_per_level(quick=False):
    """Tentpole regression number for the whole-frame schedule: ONE
    dense + ONE sparse launch per quad FRAME for all cameras x all
    pyramid levels (ragged level slabs padded to a common tile grid,
    masked by true shape) vs the per-level schedule (2 launches per
    level — ``orb.extract_features_per_level``, the PR-2 pipeline).

    Wall clock is measured on the jnp paths (interpret-free CPU), where
    both schedules run the same per-level arithmetic — the whole-frame
    ref fallback deliberately loops per level because the stacked
    common-canvas pass wastes ~20% CPU compute on ragged-level padding
    (the stacked row below quantifies that, pinning the decision).  The
    whole-frame win is the traced launch count — the deterministic half,
    enforced in CI by ``benchmarks.check_launches`` via the launch_gate
    rows emitted here.
    """
    from repro.core import extract_features_per_level
    from repro.core import orb
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        rng = np.random.RandomState(7)
        imgs = jnp.asarray(rng.randint(0, 256, (4, h, w)).astype(np.float32))
        ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=1000)
        res = f"{w}x{h}"

        iters = 3 if (h, w) == (720, 1280) else 5
        t_per, _ = _bench(
            jax.jit(lambda im: extract_features_per_level(im, ocfg,
                                                          impl="ref")),
            imgs, iters=iters)
        t_whole, _ = _bench(
            jax.jit(lambda im: orb.extract_features_batched(im, ocfg,
                                                            impl="ref")),
            imgs, iters=iters)
        emit("whole_frame", f"per_level_ms_{res}", round(t_per * 1e3, 2),
             "ms", "4 cams x 2 levels, 2 dispatches per level (jnp)")
        emit("whole_frame", f"whole_frame_ms_{res}",
             round(t_whole * 1e3, 2), "ms",
             "4 cams x 2 levels, 1 dense + 1 sparse dispatch (jnp)")
        emit("whole_frame", f"speedup_{res}", round(t_per / t_whole, 2),
             "x", "per-level / whole-frame wall clock")

        # The stacked common-canvas dense pass (the kernel's jnp mirror):
        # quantifies the ragged-padding waste that keeps it out of the
        # production CPU fallback.
        levels = [jax.block_until_ready(lv)
                  for lv in pyramid.build_pyramid_batched(imgs, ocfg)]
        thr = float(ocfg.fast_threshold)
        t_loop, _ = _bench(
            jax.jit(lambda ls: [ops.fast_blur_nms_batched(
                lv, thr, impl="ref") for lv in ls]), levels, iters=iters)
        t_stack, _ = _bench(
            jax.jit(lambda ls: ops.fast_blur_nms_pyramid_stacked_jnp(
                ls, thr)), levels, iters=iters)
        emit("whole_frame", f"dense_stacked_overhead_{res}",
             round(t_stack / t_loop, 2), "x",
             "stacked common-canvas pass / per-level loop (jnp dense "
             "stage; padding waste)")

        # Launch counts: trace-only (no kernel execution) under Pallas.
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda im: extract_features_per_level(
                im, ocfg, impl="pallas"), imgs)
        n_per = audit.count
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda im: orb.extract_features_batched(
                im, ocfg, impl="pallas"), imgs)
        n_whole = audit.count
        emit("whole_frame", f"launches_per_level_{res}", n_per, "kernels",
             "2 per pyramid level")
        emit("whole_frame", f"launches_whole_frame_{res}", n_whole,
             "kernels", "2 per frame")

    # Launch-count regression gate rows: the CI step
    # (benchmarks.check_launches) fails when actual > budget.
    h, w = (240, 320) if quick else (480, 640)
    gcfg = ORBConfig(height=h, width=w, n_levels=2, max_features=512,
                     max_disparity=64)
    intr = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0)
    gimgs = jnp.zeros((4, h, w), jnp.float32)
    gvs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=gcfg))
    actual = gvs.traced_launches("process_frame", gimgs)
    budget = 3
    emit("launch_gate", "quad_frame_launches", actual, "kernels",
         f"traced, 4 cams {w}x{h} x {gcfg.n_levels} levels")
    emit("launch_gate", "quad_frame_budget", budget, "kernels",
         "whole-frame FE (1 dense + 1 sparse) + 1 fused FM")
    emit("launch_gate", "quad_frame_input_bytes", 4 * h * w * 4, "bytes",
         f"4 f32 camera slabs {w}x{h}; /4 under precision='uint8'")


def table_fm_fused_vs_unfused(quick=False):
    """Tentpole regression number for the FM stage: the fused megakernel
    (ONE launch per frame — masked Hamming running-argmin + in-kernel
    11x11/strip patch reads + SAD sweep, stereo pairs folded into the
    grid) vs the unfused schedule (``hamming_match`` kernel + host-graph
    full-image pad + 2*K ``dynamic_slice`` gathers per pair, twice, +
    ``sad_search`` kernel, vmapped over pairs).

    Wall clock is measured on the jnp paths (interpret-free CPU);
    launch counts are traced under the Pallas impl — the deterministic,
    machine-independent half, gated in CI via the launch_gate rows.
    """
    from repro.core import extract_features_batched, match_pair_fused
    from repro.core import match_pair_unfused
    from repro.core.frontend import _split_cameras
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        rng = np.random.RandomState(7)
        imgs = jnp.asarray(rng.randint(0, 256, (4, h, w))
                           .astype(np.float32))
        ocfg = ORBConfig(height=h, width=w, n_levels=2,
                         max_features=1000, max_disparity=96)
        intr = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0)
        res = f"{w}x{h}"
        # FE once, outside the timed region: both contenders consume
        # identical (images, features) inputs.
        feats = jax.block_until_ready(
            extract_features_batched(imgs, ocfg, impl="ref"))
        feat_l, feat_r = _split_cameras(feats, n_pairs=2)
        pairs = imgs.reshape(2, 2, h, w)

        def fm_fused(p, fl, fr, impl="ref"):
            return match_pair_fused(p[:, 0], p[:, 1], fl, fr, ocfg,
                                    intr, impl=impl)

        def fm_unfused(p, fl, fr, impl="ref"):
            return jax.vmap(
                lambda pp, l_, r_: match_pair_unfused(
                    pp[0], pp[1], l_, r_, ocfg, intr, impl=impl)
            )(p, fl, fr)

        iters = 4 if (h, w) == (720, 1280) else 10
        t_unf = _bench_median(jax.jit(fm_unfused), pairs, feat_l, feat_r,
                              iters=iters)
        t_fus = _bench_median(jax.jit(fm_fused), pairs, feat_l, feat_r,
                              iters=iters)
        emit("fm_fused", f"unfused_ms_{res}", round(t_unf * 1e3, 2),
             "ms", "2 pairs, hamming + gather chain + sad (jnp)")
        emit("fm_fused", f"fused_ms_{res}", round(t_fus * 1e3, 2),
             "ms", "2 pairs, one fused FM dispatch (jnp)")
        emit("fm_fused", f"speedup_{res}", round(t_unf / t_fus, 2), "x",
             "unfused / fused wall clock")

        # Launch counts: trace-only (no kernel execution) under Pallas.
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda p, fl, fr: fm_unfused(p, fl, fr, "pallas"),
                           pairs, feat_l, feat_r)
        n_unf = audit.count
        with ops.launch_audit() as audit:
            jax.eval_shape(lambda p, fl, fr: fm_fused(p, fl, fr, "pallas"),
                           pairs, feat_l, feat_r)
        n_fus = audit.count
        emit("fm_fused", f"launches_unfused_{res}", n_unf, "kernels",
             "hamming + sad per traced pair vmap (+ host-graph gathers)")
        emit("fm_fused", f"launches_fused_{res}", n_fus, "kernels",
             "1 megakernel launch, pair axis in the grid")
    # FM launch gate: one fused launch per frame for both pairs.
    emit("launch_gate", "fm_frame_launches", n_fus, "kernels",
         "traced fused FM, 2 stereo pairs")
    emit("launch_gate", "fm_frame_budget", 1, "kernels",
         "single FM megakernel launch per frame")


def table_fleet(quick=False):
    """Fleet batching (PR 5, the `VisualSystem` session API): an N-rig
    fleet frame folds the leading rig axis into the camera/pair batch
    axes of the already-batched kernels, so the WHOLE fleet frame costs
    the same 3 traced launches as one rig (1 dense FE + 1 sparse FE +
    1 fused FM) — the deterministic half, gated in CI via the
    ``launch_gate/fleet_frame_*`` rows.  Wall clock compares the fleet
    dispatch against the per-rig python loop on the jnp path.
    """
    h, w = (240, 320) if quick else (480, 640)
    n_rigs = 4
    ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=512,
                     max_disparity=64)
    intr = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0)
    rng = np.random.RandomState(7)
    fleet = jnp.asarray(
        rng.randint(0, 256, (n_rigs, 4, h, w)).astype(np.float32))
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))
    res = f"{w}x{h}"

    iters = 3 if (h, w) == (480, 640) else 5
    t_loop, _ = _bench(
        lambda f: [vs.process_frame(f[r]) for r in range(n_rigs)],
        fleet, iters=iters)
    t_fleet, _ = _bench(vs.process_fleet, fleet, iters=iters)
    emit("fleet", f"per_rig_loop_ms_{res}", round(t_loop * 1e3, 2), "ms",
         f"{n_rigs} rigs x 3 dispatches each (jnp)")
    emit("fleet", f"fleet_ms_{res}", round(t_fleet * 1e3, 2), "ms",
         f"{n_rigs} rigs, one 3-dispatch fleet frame (jnp)")
    emit("fleet", f"speedup_{res}", round(t_loop / t_fleet, 2), "x",
         "per-rig loop / fleet wall clock")

    # Launch gate: trace-only (no kernel execution) under Pallas.
    actual = vs.traced_launches("process_fleet", fleet)
    emit("launch_gate", "fleet_frame_launches", actual, "kernels",
         f"traced, {n_rigs} rigs x 4 cams {res} x {ocfg.n_levels} levels")
    emit("launch_gate", "fleet_frame_budget", 3, "kernels",
         "rig axis folded into the batched kernels: fleet == single-rig "
         "budget")
    emit("launch_gate", "fleet_frame_input_bytes", n_rigs * 4 * h * w * 4,
         "bytes", f"{n_rigs} rigs x 4 f32 camera slabs {res}; /4 under "
         "precision='uint8'")


def table_service(quick=False):
    """Streaming fleet service (PR 6, `repro.serving`): sustained
    frames/sec at N rigs through the full submit -> bucketed batch ->
    masked `process_fleet` -> supervise loop, under synthetic arrival
    jitter and a ~10% injected fault rate (dead camera / corrupt frame
    / trigger desync) — the robustness tax measured, not assumed.  Also
    emits the `launch_gate/degraded_fleet_frame_*` rows CI enforces: a
    fleet frame with dead cameras masked out still traces EXACTLY 3
    launches (masking is elementwise jnp, not a kernel)."""
    from repro.serving import (FaultInjector, FaultSpec, FleetService,
                               QueueConfig, SupervisorConfig, run_episode)
    h, w = (48, 64) if quick else (120, 160)
    n_rigs, t_total = 4, 6
    dt = 1.0 / 30.0
    scfg = scenes.SceneConfig(height=h, width=w, n_points=60, seed=11,
                              baseline=0.3)
    fleet, intr, _ = scenes.render_fleet_sequence(scfg, t_total, n_rigs)
    fleet = jax.block_until_ready(fleet)
    ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=64,
                     max_disparity=32)
    rig = RigConfig.quad(intr, desync_policy="degrade", max_desync=1e-3)

    def specs():
        # ~10% of the n_rigs * t_total frame slots carry a fault,
        # deterministic slots, kinds round-robin; every rig jitters.
        slots = [(r, t) for r in range(n_rigs) for t in range(t_total)]
        n_faults = max(1, round(0.1 * len(slots)))
        idx = np.random.RandomState(0).choice(len(slots), n_faults,
                                              replace=False)
        kinds = ("dead_camera", "corrupt_frame", "desync")
        out = [FaultSpec(kinds[i % 3], rig=slots[j][0], start=slots[j][1],
                         stop=slots[j][1] + 1, camera=slots[j][0] % 4,
                         magnitude=1.0)
               for i, j in enumerate(sorted(idx))]
        out += [FaultSpec("arrival_jitter", rig=r, magnitude=0.3 * dt)
                for r in range(n_rigs)]
        return out

    def episode(vs):
        svc = FleetService(
            vs, QueueConfig(bucket_sizes=(1, 2, 4), deadline_s=dt),
            SupervisorConfig(heartbeat_timeout_s=3 * dt,
                             backoff_base_s=dt, backoff_max_s=4 * dt))
        return run_episode(svc, fleet, dt=dt,
                           injector=FaultInjector(specs(), seed=0))

    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))
    episode(vs)                       # warmup: trace the bucket shapes
    t0 = time.perf_counter()
    result = episode(vs)
    wall = time.perf_counter() - t0
    served = result.status["counters"]["frames_out"]
    degraded = sum(r.status == "degraded" for r in result.reports)
    res = f"{w}x{h}"
    emit("service", f"sustained_fps_{n_rigs}rigs_{res}",
         round(served / wall, 1), "fps",
         f"{served} frames served in {wall*1e3:.0f}ms, ~10% fault rate "
         "+ arrival jitter")
    emit("service", "frames_degraded", degraded, "frames",
         "dead camera / corrupt slab / desync -> surviving pairs")
    emit("service", "frames_dropped",
         result.status["counters"]["frames_in"] - served, "frames",
         "all-dead or desync-dropped intake")
    emit("service", "batches", result.status["counters"]["batches"],
         "dispatches", "bucketed fleet batches (3 launches each)")

    # Degraded-fleet launch gate: dead cameras must not add launches.
    mask = np.ones((n_rigs, 4), dtype=bool)
    mask[0, 3] = False
    mask[2, 0] = False
    actual = vs.traced_launches("process_fleet", fleet[0],
                                jnp.asarray(mask))
    emit("launch_gate", "degraded_fleet_frame_launches", actual, "kernels",
         f"traced, {n_rigs} rigs with 2 dead cameras masked, {res}")
    emit("launch_gate", "degraded_fleet_frame_budget", 3, "kernels",
         "degradation is elementwise masking — same 3-launch schedule")


def table_precision(quick=False):
    """Low-precision integer datapath (this PR): the whole image path —
    pyramid slabs, fused blur accumulation, FAST scores, patch moments,
    descriptor selection, FM slab reads — runs in integers when the
    session is built with ``PipelineConfig(precision='uint8')``.

    Measures f32 vs uint8 ``process_frame`` wall clock on the jnp path,
    and COMPUTES the resident-slab bytes/pair of the fused FM launch
    from the actual padded slab shapes (``ops._pad_fm_slab`` via
    ``jax.eval_shape`` — no allocation): the uint8 path holds the SAME
    padded geometry in 1-byte elements, a 4x VMEM cut (the acceptance
    floor is 3.5x), in the same 3-launch budget — gated in CI via the
    ``launch_gate/u8_*`` rows emitted here.
    """
    rng = np.random.RandomState(13)
    resolutions = [(480, 640)] + ([] if quick else [(720, 1280)])
    for h, w in resolutions:
        res = f"{w}x{h}"
        ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=512,
                         max_disparity=64)
        intr = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0)
        rig = RigConfig.quad(intr)
        imgs_u8 = rng.randint(0, 256, (4, h, w)).astype(np.uint8)
        vs_f = VisualSystem(rig, PipelineConfig(orb=ocfg, impl="ref"))
        vs_u = VisualSystem(rig, PipelineConfig(orb=ocfg, impl="ref",
                                                precision="uint8"))
        iters = 3 if (h, w) == (720, 1280) else 5
        t_f = _bench_median(vs_f.process_frame,
                            jnp.asarray(imgs_u8.astype(np.float32)),
                            iters=iters)
        t_u = _bench_median(vs_u.process_frame, jnp.asarray(imgs_u8),
                            iters=iters)
        emit("precision", f"f32_frame_ms_{res}", round(t_f * 1e3, 2),
             "ms", "quad frame, f32 slabs (jnp path)")
        emit("precision", f"u8_frame_ms_{res}", round(t_u * 1e3, 2),
             "ms", "quad frame, uint8 slabs / int32 accumulators (jnp "
             "path)")
        emit("precision", f"u8_speedup_{res}", round(t_f / t_u, 2), "x",
             "f32 / uint8 wall clock (host jnp; the VMEM/bandwidth win "
             "is the computed rows below)")

        # Resident-slab bytes of the fused FM launch, computed from the
        # ACTUAL padded shapes the dispatch builds (padding geometry is
        # dtype-independent, so the ratio is exactly itemsize).
        ry = ocfg.sad_window // 2
        def _slab_bytes(dtype):
            one = jax.ShapeDtypeStruct((1, h, w), dtype)
            sl = jax.eval_shape(lambda x: ops._pad_fm_slab(x, ry, ry),
                                one)
            sr = jax.eval_shape(
                lambda x: ops._pad_fm_slab(x, ry, ry + ocfg.sad_range),
                one)
            return int((np.prod(sl.shape) + np.prod(sr.shape))
                       * np.dtype(dtype).itemsize)
        b_f, b_u = _slab_bytes(jnp.float32), _slab_bytes(jnp.uint8)
        emit("precision", f"f32_fm_slab_bytes_per_pair_{res}", b_f,
             "bytes", "padded level-0 L+R slabs resident in the FM "
             "megakernel")
        emit("precision", f"u8_fm_slab_bytes_per_pair_{res}", b_u,
             "bytes", "same padded geometry, 1-byte elements")
        emit("precision", f"u8_slab_reduction_{res}",
             round(b_f / b_u, 2), "x",
             "resident FM slab bytes f32 / uint8 (acceptance floor "
             "3.5x)")

    # Launch-count regression gates: the uint8 schedule is the SAME
    # 3 launches (1 dense FE + 1 sparse FE + 1 fused FM) per frame and
    # per N-rig fleet frame — dtype switches the kernels' element type,
    # not the launch graph.
    h, w = (240, 320) if quick else (480, 640)
    gcfg = ORBConfig(height=h, width=w, n_levels=2, max_features=512,
                     max_disparity=64)
    gvs = VisualSystem(RigConfig.quad(CameraIntrinsics(cx=w / 2.0,
                                                       cy=h / 2.0)),
                       PipelineConfig(orb=gcfg, precision="uint8"))
    gimgs = jnp.zeros((4, h, w), jnp.uint8)
    actual = gvs.traced_launches("process_frame", gimgs)
    emit("launch_gate", "u8_frame_launches", actual, "kernels",
         f"traced, uint8 datapath, 4 cams {w}x{h} x {gcfg.n_levels} "
         "levels")
    emit("launch_gate", "u8_frame_budget", 3, "kernels",
         "uint8 quad frame: same 3-launch schedule as f32")
    n_rigs = 4
    fleet = jnp.zeros((n_rigs, 4, h, w), jnp.uint8)
    actual = gvs.traced_launches("process_fleet", fleet)
    emit("launch_gate", "u8_fleet_frame_launches", actual, "kernels",
         f"traced, uint8 datapath, {n_rigs} rigs x 4 cams {w}x{h}")
    emit("launch_gate", "u8_fleet_frame_budget", 3, "kernels",
         "uint8 fleet frame: same 3-launch schedule as f32")


def table_localization(quick=False):
    """Localization backend (this PR): disparity -> depth -> rig-frame
    points, the one-launch temporal matcher, and the batched robust
    Procrustes solve, closed against ``data.scenes`` ground truth.

    Emits the ``accuracy_gate/*`` rows CI enforces: ATE / RPE of a
    localized ``run`` over a constant-twist scene must stay under
    pinned limits (~2x the measured baseline) for BOTH the f32 and the
    uint8 integer datapath — so neither a solver regression nor a
    quantization change can silently walk the trajectory error up.
    Also emits the ``launch_gate/loc_*`` rows: a localized frame (and
    fleet frame) costs at most 3 frontend + 1 backend launches."""
    from repro import localization as loc
    h, w = (96, 128) if quick else (160, 240)
    kmax = 96 if quick else 128
    t_total = 4 if quick else 6
    scfg = scenes.SceneConfig(height=h, width=w, baseline=0.5, seed=1)
    seq = scenes.render_sequence(scfg, t_total, step_t=(0.25, 0.0, 0.1),
                                 yaw_per_frame=0.0)
    frames = jax.block_until_ready(jnp.asarray(seq.frames))
    ocfg = ORBConfig(height=h, width=w, max_features=kmax,
                     fast_threshold=15)
    res = f"{w}x{h}"
    # Pinned at ~2x the worst measured baseline across quick/full AND
    # f32/u8 (measured 2026-08: ATE 0.19-0.29 m, RPE-t 0.10-0.10 m,
    # RPE-r 0.10-0.14 deg) — tight enough to catch a solver or matcher
    # regression, loose enough to absorb accelerator reduction-order
    # jitter.
    limits = {"ate": 0.60, "rpe_trans": 0.25, "rpe_rot": 0.30}

    def gate(tag, vs, fr):
        t_wall, out = _bench(vs.run, fr, iters=3, warmup=1)
        m = loc.trajectory_metrics(out.pose.rotation,
                                   out.pose.translation, seq.poses)
        inl = np.asarray(out.pose.inliers)
        emit("localization", f"run_ms_{tag}_{res}", round(t_wall * 1e3, 1),
             "ms", f"{t_total}-frame localized run "
             "(3 launches/step + 1 temporal)")
        emit("localization", f"mean_inliers_{tag}",
             round(float(inl[1:].mean()), 1), "points",
             "per-transition robust-solve support")
        emit("localization", f"travel_{tag}", round(m["travel_m"], 3),
             "m", "ground-truth path length")
        for key, metric, unit in (("ate", "ate_rmse_m", "m"),
                                  ("rpe_trans", "rpe_trans_rmse_m", "m"),
                                  ("rpe_rot", "rpe_rot_mean_deg", "deg")):
            emit("accuracy_gate", f"{key}_{tag}", round(m[metric], 4),
                 unit, f"{t_total}-frame constant-twist scene {res} "
                 "vs ground truth")
            emit("accuracy_gate", f"{key}_{tag}_limit", limits[key],
                 unit, "pinned ~2x the measured baseline")
        return out

    rig = RigConfig.quad(seq.intrinsics)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg, localize=True))
    gate("f32", vs, frames)
    u8 = jnp.asarray(np.round(np.clip(np.asarray(frames), 0.0, 255.0))
                     .astype(np.uint8))
    vs_u8 = VisualSystem(rig, PipelineConfig(orb=ocfg, localize=True,
                                             precision="uint8"))
    gate("u8", vs_u8, u8)

    im = frames[0]
    actual = vs.traced_launches("process_frame", im)
    emit("launch_gate", "loc_frame_launches", actual, "kernels",
         f"traced localized quad frame {res}: 3 frontend + 1 temporal")
    emit("launch_gate", "loc_frame_budget", 4, "kernels",
         "frame budget with the localization backend folded in")
    actual = vs.traced_launches("process_fleet", jnp.stack([im, im]))
    emit("launch_gate", "loc_fleet_frame_launches", actual, "kernels",
         "traced localized 2-rig fleet frame: the rig axis folds into "
         "the one temporal launch")
    emit("launch_gate", "loc_fleet_frame_budget", 4, "kernels",
         "fleet == single-rig localized budget")


def table_failover(quick=False):
    """Multi-host failover (PR 9, `repro.serving.failover` +
    `repro.serving.snapshot`): two measured episodes on the SAME
    `run_episode` driver the fault-injection tests use.

    Episode A — host_down + faulted dispatch: one of two host fault
    domains dies mid-stream and its rigs are redistributed over the
    survivor while a `dispatch_error` window exercises the guard's
    seeded retry.  Reports frames dropped (0 is the claim: elastic
    redistribution keeps every queued frame servable), rigs moved, and
    dispatch retries.

    Episode B — kill-and-recover: the service object is destroyed after
    its crash frame and rebuilt cold from the newest crash-consistent
    snapshot; reports the restore wall clock and the on-disk snapshot
    footprint.

    Also emits the `launch_gate/restored_fleet_frame_*` rows CI
    enforces: a fleet frame dispatched by a RESTORED service traces the
    same 3 launches — recovery repopulates state, it never widens the
    launch graph."""
    import shutil
    import tempfile

    from repro.serving import (DispatchGuard, DispatchGuardConfig,
                               FaultInjector, FaultSpec, FleetService,
                               HostMap, QueueConfig, SupervisorConfig,
                               run_episode, snapshot)
    h, w = (48, 64) if quick else (96, 128)
    n_rigs, t_total = 4, 6
    dt = 1.0 / 30.0
    scfg = scenes.SceneConfig(height=h, width=w, n_points=60, seed=11,
                              baseline=0.3)
    fleet, intr, _ = scenes.render_fleet_sequence(scfg, t_total, n_rigs)
    fleet = jax.block_until_ready(fleet)
    ocfg = ORBConfig(height=h, width=w, n_levels=2, max_features=64,
                     max_disparity=32)
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))
    res = f"{w}x{h}"

    def service():
        return FleetService(
            vs, QueueConfig(bucket_sizes=(1, 2, 4), deadline_s=dt),
            SupervisorConfig(heartbeat_timeout_s=3 * dt,
                             backoff_base_s=dt, backoff_max_s=4 * dt),
            guard=DispatchGuard(DispatchGuardConfig(
                backoff_base_s=dt, backoff_max_s=4 * dt)),
            host_map=HostMap(["host0", "host1"]))

    # Episode A: host0 dies at frame 2; one dispatch window faults.
    inj = FaultInjector([
        FaultSpec("host_down", rig="host0", start=2),
        FaultSpec("dispatch_error", start=1, stop=2, magnitude=1),
    ], seed=0)
    resa = run_episode(service(), fleet, dt=dt, injector=inj)
    c = resa.status["counters"]
    emit("failover", "frames_dropped_host_down",
         c["frames_in"] - c["frames_out"], "frames",
         f"{n_rigs} rigs {res}, host0 of 2 lost at frame 2 — elastic "
         "redistribution keeps queued frames servable")
    emit("failover", "rigs_redistributed", c["rigs_redistributed"],
         "rigs", "moved to the surviving domain (pose chains gapped)")
    emit("failover", "dispatch_retries", c.get("dispatch_retries", 0),
         "retries", "guarded dispatch recovered the injected error "
         f"({c.get('dropped_dispatch', 0)} batches dropped)")

    # Episode B: crash after frame 2, rebuild cold, restore newest
    # verifiable snapshot.
    ckpt = tempfile.mkdtemp(prefix="repro-failover-bench-")
    try:
        resb = run_episode(service(), fleet, dt=dt, snapshot_dir=ckpt,
                           crash_at=2, restore=service)
        rec = resb.recovery
        emit("failover", "recovery_ms",
             round(rec["recovery_wall_s"] * 1e3, 2), "ms",
             "cold FleetService rebuild + snapshot verify/restore "
             f"(restored step {rec['restored_step']})")
        import os
        newest = sorted(d for d in os.listdir(ckpt)
                        if d.startswith("step_")
                        and not d.endswith(".tmp"))[-1]
        sdir = os.path.join(ckpt, newest)
        nbytes = sum(os.path.getsize(os.path.join(sdir, f))
                     for f in os.listdir(sdir))
        emit("failover", "snapshot_bytes", nbytes, "bytes",
             f"one crash-consistent step dir: supervisor ledger + "
             f"pose states + pending frames, {n_rigs} rigs {res}")

        # Launch gate: restore into a fresh service, then trace a fleet
        # frame — recovery must not widen the 3-launch schedule.
        svc2 = service()
        snapshot.restore(svc2, ckpt)
        actual = svc2.vs.traced_launches("process_fleet",
                                         jnp.asarray(fleet[0]))
        emit("launch_gate", "restored_fleet_frame_launches", actual,
             "kernels",
             f"traced fleet frame on a snapshot-restored service, "
             f"{n_rigs} rigs {res}")
        emit("launch_gate", "restored_fleet_frame_budget", 3, "kernels",
             "restore repopulates state, never the launch graph")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_frontend.json",
                    help="JSON artifact path ('' to disable)")
    args = ap.parse_args()
    print("table,name,value,unit,note")
    t0 = time.time()
    table1_latency_split(args.quick)
    table_fe_fm_ratio(args.quick)
    table2_module_cost(args.quick)
    table3_accuracy(args.quick)
    table4_throughput(args.quick)
    table_fused_vs_seed(args.quick)
    table_describe_fused_vs_gather(args.quick)
    table_whole_frame_vs_per_level(args.quick)
    table_fm_fused_vs_unfused(args.quick)
    table_fleet(args.quick)
    table_service(args.quick)
    table_precision(args.quick)
    table_localization(args.quick)
    table_failover(args.quick)
    print(f"# done in {time.time() - t0:.1f}s ({len(ROWS)} rows)")
    if args.out:
        rows = [{"table": t, "name": n, "value": v, "unit": u, "note": note}
                for t, n, v, u, note in ROWS]
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "quick": bool(args.quick)}, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
