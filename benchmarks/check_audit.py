"""Static-audit CI gate: AUDIT.json green AND reconciled with runtime.

``python -m repro.analysis`` proves the launch/VMEM/dtype/bounds
invariants from the TRACE; ``benchmarks.run`` counts launches at
RUNTIME into the ``launch_gate/*`` rows of ``BENCH_frontend.json``.
This gate requires both views and their AGREEMENT:

  1. every check in ``AUDIT.json`` is green (launch budgets, VMEM
     residency under the core budget, zero dtype / bounds violations,
     clean serving hostlint);
  2. every required ``launch_gate/*launches`` row is covered by a
     matrix entry claiming that gate, and the entry's STATIC count
     EQUALS the row's runtime value — a drift in either direction
     (analyzer under-modeling the program, or the runtime schedule
     widening past what was proven) fails CI with a reconciliation
     table.

Usage: python -m benchmarks.check_audit [AUDIT.json [BENCH.json]]
Exit status: 0 all green + reconciled, 1 otherwise.
"""

from __future__ import annotations

import json
import math
import sys

from benchmarks.check_launches import REQUIRED_GATES


def _load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot load {path}: {e}")
        return None


def _static_by_gate(audit: dict) -> dict:
    out = {}
    for entry in audit.get("entries", ()):
        for gate in entry.get("gates", ()):
            out[gate] = entry
    return out


def reconcile(audit: dict, bench: dict) -> int:
    """Print the static-vs-runtime table; return exit status."""
    rows = {(r["table"], r["name"]): r for r in bench["rows"]}
    by_gate = _static_by_gate(audit)
    status = 0
    print("gate                              static  runtime  verdict")
    for name in REQUIRED_GATES:
        row = rows.get(("launch_gate", name))
        entry = by_gate.get(name)
        if entry is None:
            print(f"{name:<33} -       -        FAIL: no audit matrix "
                  "entry claims this gate")
            status = 1
            continue
        static = entry["launches"]["static"]
        if row is None:
            print(f"{name:<33} {static:<7} -        FAIL: row missing "
                  "from benchmark artifact")
            status = 1
            continue
        try:
            runtime = float(row["value"])
        except (TypeError, ValueError):
            runtime = math.nan
        if math.isnan(runtime):
            print(f"{name:<33} {static:<7} {row['value']!r:<8} FAIL: "
                  "runtime value is not a number")
            status = 1
            continue
        runtime = int(runtime)
        ok = static == runtime
        verdict = "ok" if ok else (
            f"MISMATCH ({entry['name']}: proven {static}, observed "
            f"{runtime})")
        print(f"{name:<33} {static:<7} {runtime:<8} {verdict}")
        if not ok:
            status = 1
    return status


def check(audit_path: str, bench_path: str) -> int:
    audit = _load(audit_path)
    bench = _load(bench_path)
    if audit is None or bench is None:
        return 1
    status = 0
    for name, ok in audit.get("checks", {}).items():
        print(f"{'ok' if ok else 'FAIL'}: audit check {name}")
        if not ok:
            status = 1
    if not audit.get("checks"):
        print(f"FAIL: {audit_path} has no checks section — wrong file?")
        status = 1
    status |= reconcile(audit, bench)
    if status == 0:
        print("static audit reconciled with runtime launch gates")
    return status


def main() -> None:
    audit_path = sys.argv[1] if len(sys.argv) > 1 else "AUDIT.json"
    bench_path = (sys.argv[2] if len(sys.argv) > 2
                  else "BENCH_frontend.json")
    sys.exit(check(audit_path, bench_path))


if __name__ == "__main__":
    main()
