"""Launch-count regression gate for CI (ROADMAP open item).

Wall clock on shared CI runners is noisy; traced Pallas launch counts
are deterministic.  ``benchmarks.run`` records, in the
``BENCH_frontend.json`` artifact, the number of kernel launches a traced
quad frame issues (``launch_gate/quad_frame_launches``) next to the
fused-schedule budget (``launch_gate/quad_frame_budget`` — 3: ONE dense
+ ONE sparse FE launch for all cameras x all pyramid levels, plus ONE
fused FM megakernel launch for both stereo pairs; the FM stage is also
gated on its own via ``launch_gate/fm_frame_*``).  This script fails the
job when any actual count exceeds its budget, i.e. when a change
silently un-fuses the frontend or matcher back toward per-level,
per-pair or per-op dispatch.

Usage: python -m benchmarks.check_launches [BENCH_frontend.json]
Exit status: 0 when every gate holds, 1 on regression or missing rows.
"""

from __future__ import annotations

import json
import sys

# Gates that MUST be present in the artifact: a refactor that silently
# drops a gate row (renames a table, deletes a benchmark) would
# otherwise pass CI with nothing checked.  quad = one rig frame (3),
# fm = the fused matcher alone (1), fleet = an N-rig fleet frame (3 —
# the `VisualSystem.process_fleet` budget), degraded_fleet = the same
# fleet frame with dead cameras masked out (still 3: degradation is
# elementwise masking, never extra kernels), u8_* = the
# precision='uint8' integer datapath (still 3 for frame AND fleet
# frame: dtype switches the kernels' element type, never the launch
# graph).
REQUIRED_GATES = ("quad_frame_launches", "fm_frame_launches",
                  "fleet_frame_launches",
                  "degraded_fleet_frame_launches",
                  "u8_frame_launches", "u8_fleet_frame_launches")


def check(path: str) -> int:
    with open(path) as f:
        artifact = json.load(f)
    rows = {(r["table"], r["name"]): r for r in artifact["rows"]}

    gates = [name for (table, name) in rows
             if table == "launch_gate" and "launches" in name]
    if not gates:
        print(f"FAIL: no launch_gate/*launches* rows in {path} — "
              "did benchmarks.run change row names?")
        return 1

    status = 0
    for name in REQUIRED_GATES:
        if name not in gates:
            print(f"FAIL: required gate launch_gate/{name} is missing "
                  f"from {path} — did benchmarks.run drop it?")
            status = 1
    for name in sorted(gates):
        budget_name = name.replace("launches", "budget")
        actual_row = rows[("launch_gate", name)]
        budget_row = rows.get(("launch_gate", budget_name))
        if budget_row is None:
            print(f"FAIL: {name} has no matching {budget_name} row")
            status = 1
            continue
        actual, budget = int(actual_row["value"]), int(budget_row["value"])
        verdict = "ok" if actual <= budget else "REGRESSION"
        print(f"{verdict}: launch_gate/{name} = {actual} "
              f"(budget {budget}; {actual_row['note']})")
        if actual > budget:
            status = 1
    return status


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_frontend.json"
    sys.exit(check(path))


if __name__ == "__main__":
    main()
