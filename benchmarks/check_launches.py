"""Launch-count + accuracy regression gate for CI (ROADMAP open item).

Wall clock on shared CI runners is noisy; traced Pallas launch counts
are deterministic.  ``benchmarks.run`` records, in the
``BENCH_frontend.json`` artifact, the number of kernel launches a traced
quad frame issues (``launch_gate/quad_frame_launches``) next to the
fused-schedule budget (``launch_gate/quad_frame_budget`` — 3: ONE dense
+ ONE sparse FE launch for all cameras x all pyramid levels, plus ONE
fused FM megakernel launch for both stereo pairs; the FM stage is also
gated on its own via ``launch_gate/fm_frame_*``).  This script fails the
job when any actual count exceeds its budget, i.e. when a change
silently un-fuses the frontend or matcher back toward per-level,
per-pair or per-op dispatch.

It also enforces the ``accuracy_gate/*`` rows: the localization
backend's trajectory error (ATE / RPE vs scene ground truth, f32 AND
uint8 datapaths) must stay under its pinned ``*_limit`` row — scene,
seeds and the solver are all deterministic, so these are exact
regression pins, not flaky perf numbers.

Usage: python -m benchmarks.check_launches [BENCH_frontend.json]
Exit status: 0 when every gate holds, 1 on regression or missing rows.
"""

from __future__ import annotations

import json
import math
import os
import sys

# Gates that MUST be present in the artifact: a refactor that silently
# drops a gate row (renames a table, deletes a benchmark) would
# otherwise pass CI with nothing checked.  quad = one rig frame (3),
# fm = the fused matcher alone (1), fleet = an N-rig fleet frame (3 —
# the `VisualSystem.process_fleet` budget), degraded_fleet = the same
# fleet frame with dead cameras masked out (still 3: degradation is
# elementwise masking, never extra kernels), u8_* = the
# precision='uint8' integer datapath (still 3 for frame AND fleet
# frame: dtype switches the kernels' element type, never the launch
# graph), loc_* = a localized frame / fleet frame (<= 4: the 3-launch
# frontend plus ONE fused temporal-match backend launch),
# restored_fleet_frame = a fleet frame dispatched by a service rebuilt
# from a crash-consistent snapshot (still 3: restore repopulates state,
# never the launch graph).
REQUIRED_GATES = ("quad_frame_launches", "fm_frame_launches",
                  "fleet_frame_launches",
                  "degraded_fleet_frame_launches",
                  "u8_frame_launches", "u8_fleet_frame_launches",
                  "loc_frame_launches", "loc_fleet_frame_launches",
                  "restored_fleet_frame_launches")

# Failover rows that MUST be present (presence, not thresholds —
# recovery wall clock is host-dependent): the kill-and-recover and
# host_down episodes in benchmarks.run must keep reporting.
REQUIRED_FAILOVER = ("recovery_ms", "frames_dropped_host_down",
                     "rigs_redistributed")

# Accuracy gates that MUST be present: trajectory error of the
# localization backend vs ground truth, for BOTH precisions.  Each name
# pairs with an ``accuracy_gate/<name>_limit`` row pinned in
# benchmarks.run at ~2x the measured baseline.
REQUIRED_ACCURACY = ("ate_f32", "ate_u8",
                     "rpe_trans_f32", "rpe_trans_u8",
                     "rpe_rot_f32", "rpe_rot_u8")


def _numeric(row: dict, table: str, name: str) -> float | None:
    """The row's value as a finite float, else None with a clear FAIL
    diagnosis — a gate row holding "n/a"/None/NaN would otherwise crash
    this script (or, worse for NaN, slide through a <= comparison as a
    silent pass/fail)."""
    value = row.get("value")
    try:
        out = float(value)
    except (TypeError, ValueError):
        print(f"FAIL: {table}/{name} value {value!r} is not numeric — "
              "did benchmarks.run emit a placeholder?")
        return None
    if not math.isfinite(out):
        print(f"FAIL: {table}/{name} value is {out} (not finite) — a "
              "NaN gate would compare as neither pass nor fail")
        return None
    return out


def _print_reconciliation(bench_path: str, artifact: dict) -> None:
    """On a launch-gate failure, show the static-vs-runtime table from
    the sibling AUDIT.json (when present) — whichever side drifted, the
    mismatch is then visible in one place."""
    audit_path = os.path.join(os.path.dirname(os.path.abspath(bench_path)),
                              "AUDIT.json")
    if not os.path.exists(audit_path):
        print(f"(no {audit_path} for static-vs-runtime reconciliation — "
              "run `python -m repro.analysis` to produce it)")
        return
    from benchmarks import check_audit
    try:
        with open(audit_path) as f:
            audit = json.load(f)
    except (OSError, ValueError) as e:
        print(f"(cannot load {audit_path} for reconciliation: {e})")
        return
    print("static-vs-runtime launch reconciliation "
          f"({audit_path}):")
    check_audit.reconcile(audit, artifact)


def check(path: str) -> int:
    with open(path) as f:
        artifact = json.load(f)
    rows = {(r["table"], r["name"]): r for r in artifact["rows"]}

    gates = [name for (table, name) in rows
             if table == "launch_gate" and "launches" in name]
    if not gates:
        print(f"FAIL: no launch_gate/*launches* rows in {path} — "
              "did benchmarks.run change row names?")
        return 1

    status = 0
    launch_failed = False
    for name in REQUIRED_GATES:
        if name not in gates:
            print(f"FAIL: required gate launch_gate/{name} is missing "
                  f"from {path} — did benchmarks.run drop it?")
            status = 1
    for name in sorted(gates):
        budget_name = name.replace("launches", "budget")
        actual_row = rows[("launch_gate", name)]
        budget_row = rows.get(("launch_gate", budget_name))
        if budget_row is None:
            print(f"FAIL: {name} has no matching {budget_name} row")
            status = 1
            launch_failed = True
            continue
        actual = _numeric(actual_row, "launch_gate", name)
        budget = _numeric(budget_row, "launch_gate", budget_name)
        if actual is None or budget is None:
            status = 1
            launch_failed = True
            continue
        actual, budget = int(actual), int(budget)
        verdict = "ok" if actual <= budget else "REGRESSION"
        print(f"{verdict}: launch_gate/{name} = {actual} "
              f"(budget {budget}; {actual_row['note']})")
        if actual > budget:
            status = 1
            launch_failed = True
    if launch_failed:
        _print_reconciliation(path, artifact)

    acc = [name for (table, name) in rows
           if table == "accuracy_gate" and not name.endswith("_limit")]
    if not acc:
        print(f"FAIL: no accuracy_gate/* rows in {path} — "
              "did benchmarks.run drop table_localization?")
        return 1
    for name in REQUIRED_ACCURACY:
        if name not in acc:
            print(f"FAIL: required gate accuracy_gate/{name} is missing "
                  f"from {path} — did benchmarks.run drop it?")
            status = 1
    for name in sorted(acc):
        actual_row = rows[("accuracy_gate", name)]
        limit_row = rows.get(("accuracy_gate", name + "_limit"))
        if limit_row is None:
            print(f"FAIL: {name} has no matching {name}_limit row")
            status = 1
            continue
        actual = _numeric(actual_row, "accuracy_gate", name)
        limit = _numeric(limit_row, "accuracy_gate", name + "_limit")
        if actual is None or limit is None:
            status = 1
            continue
        ok = actual <= limit
        verdict = "ok" if ok else "REGRESSION"
        print(f"{verdict}: accuracy_gate/{name} = {actual} "
              f"{actual_row['unit']} (limit {limit}; "
              f"{actual_row['note']})")
        if not ok:
            status = 1

    for name in REQUIRED_FAILOVER:
        row = rows.get(("failover", name))
        if row is None:
            print(f"FAIL: required row failover/{name} is missing from "
                  f"{path} — did benchmarks.run drop table_failover?")
            status = 1
        else:
            print(f"ok: failover/{name} = {row['value']} {row['unit']} "
                  f"({row['note']})")
    return status


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_frontend.json"
    sys.exit(check(path))


if __name__ == "__main__":
    main()
