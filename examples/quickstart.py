"""Quickstart: run the quad-camera ORB visual frontend on a synthetic
scene and print what it found.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import ORBConfig, process_quad_frame, sync
from repro.data import scenes


def main() -> None:
    # 1. simulate the quad-camera rig (two stereo pairs, front + back)
    scene = scenes.SceneConfig(height=240, width=320, n_points=300,
                               baseline=0.3)
    frames, poses, intr = scenes.render_sequence(scene, n_frames=2)
    print(f"rendered {frames.shape} (frames, cameras, H, W)")

    # 2. hardware-synchronized capture (paper Sec. III-A): one trigger
    #    clock stamps all four cameras + IMU
    trig = sync.TriggerConfig()
    cam_tags, imu_tags = sync.hardware_trigger(trig, 2)
    print(f"max inter-camera desync: {float(sync.max_desync(cam_tags))} s"
          " (hardware sync is exact by construction)")

    # 3. the frame-multiplexed visual frontend (paper Sec. III-B..D):
    #    ORB extraction -> stereo Hamming match -> SAD rectify -> depth
    ocfg = ORBConfig(height=240, width=320, max_features=512,
                     n_levels=2, max_disparity=64)
    out = jax.jit(lambda f: process_quad_frame(f, ocfg, intr))(frames[0])
    for pair in (0, 1):
        nf = int(np.asarray(out.features_l.valid[pair]).sum())
        nm = int(np.asarray(out.matches.valid[pair]).sum())
        nd = int(np.asarray(out.depth.valid[pair]).sum())
        z = np.asarray(out.depth.depth[pair])[
            np.asarray(out.depth.valid[pair])]
        print(f"pair {pair}: {nf} features, {nm} matches, {nd} depths, "
              f"median depth {np.median(z):.2f} m")


if __name__ == "__main__":
    main()
