"""Quickstart: configure a `VisualSystem` session for the quad-camera
rig, run the ORB visual frontend on a synthetic scene and print what it
found.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (ORBConfig, PipelineConfig, RigConfig, VisualSystem,
                        sync)
from repro.data import scenes


def main() -> None:
    # 1. simulate the quad-camera rig (two stereo pairs, front + back)
    scene = scenes.SceneConfig(height=240, width=320, n_points=300,
                               baseline=0.3)
    frames, poses, intr = scenes.render_sequence(scene, n_frames=2)
    print(f"rendered {frames.shape} (frames, cameras, H, W)")

    # 2. one session owns the rig layout, sync spec, ORB parameters and
    #    the jit caches — configure once, stream frames (paper Sec. III)
    ocfg = ORBConfig(height=240, width=320, max_features=512,
                     n_levels=2, max_disparity=64)
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))

    # 3. hardware-synchronized capture (paper Sec. III-A): one trigger
    #    clock stamps all four cameras; the session checks each frame's
    #    tags against the rig's sync policy (hardware => 0 desync)
    cam_tags, imu_tags = sync.hardware_trigger(vs.rig.sync, 2)

    # 4. the frame-multiplexed visual frontend (paper Sec. III-B..D):
    #    ORB extraction -> stereo Hamming match -> SAD rectify -> depth,
    #    3 kernel launches per frame
    out = vs.process_frame(frames[0], timestamps=cam_tags[0])
    print(f"max inter-camera desync: {vs.desync_log[-1]} s"
          " (hardware sync is exact by construction)")
    for pair in (0, 1):
        nf = int(np.asarray(out.features_l.valid[pair]).sum())
        nm = int(np.asarray(out.matches.valid[pair]).sum())
        nd = int(np.asarray(out.depth.valid[pair]).sum())
        z = np.asarray(out.depth.depth[pair])[
            np.asarray(out.depth.valid[pair])]
        print(f"pair {pair}: {nf} features, {nm} matches, {nd} depths, "
              f"median depth {np.median(z):.2f} m")


if __name__ == "__main__":
    main()
