"""Stream a rendered quad-camera fleet through the fault-tolerant
serving layer (`repro.serving`) with injected faults, then print the
supervisor's status report.

    PYTHONPATH=src python examples/serve_fleet.py --rigs 4 --frames 8

What you should see: rig 1 loses camera 3 mid-episode (its reports turn
"degraded", the (2,3) stereo pair goes invalid, pair (0,1) keeps
serving); rig 2 stalls, the watchdog times out, backs off and restarts
it (the restart hook clears the fault, so it recovers); every other
rig serves every frame bit-exact to a fault-free run.  The whole
episode runs on a virtual clock with seeded injection — re-running the
command replays it bit-identically.

(The end-of-episode health snapshot reads "restarting" for every rig:
once arrivals stop, the watchdog correctly flags them all as overdue.
That is the supervisor doing its job on a finite episode, not a fault.)
"""

import argparse

import numpy as np

from repro.core import ORBConfig, PipelineConfig, RigConfig, VisualSystem
from repro.data import scenes
from repro.serving import (FaultInjector, FaultSpec, FleetService,
                           QueueConfig, SupervisorConfig, run_episode)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rigs", type=int, default=4)
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--height", type=int, default=96)
    ap.add_argument("--width", type=int, default=128)
    args = ap.parse_args()
    dt = 1.0 / 30.0

    scfg = scenes.SceneConfig(height=args.height, width=args.width,
                              n_points=80, seed=7, baseline=0.3)
    frames, intr, _ = scenes.render_fleet_sequence(scfg, args.frames,
                                                   args.rigs)

    ocfg = ORBConfig(height=args.height, width=args.width, n_levels=2,
                     max_features=64, max_disparity=32)
    rig = RigConfig.quad(intr, desync_policy="degrade", max_desync=1e-3)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))

    injector = FaultInjector([
        FaultSpec("dead_camera", rig=1, camera=3, start=2),
        FaultSpec("stalled_rig", rig=2, start=3, stop=5),
        FaultSpec("arrival_jitter", rig=0, magnitude=0.3 * dt),
    ], seed=0)

    service = FleetService(
        vs,
        QueueConfig(bucket_sizes=(1, 2, 4, 8), deadline_s=dt),
        SupervisorConfig(heartbeat_timeout_s=2.5 * dt, backoff_base_s=dt,
                         backoff_max_s=4 * dt, seed=0),
        restart_cb=injector.clear_rig)

    result = run_episode(service, np.asarray(frames), dt=dt,
                         injector=injector)

    print(f"served {len(result.reports)} frames from "
          f"{args.rigs} rigs x {args.frames} ticks")
    for r in result.reports:
        n_valid = int(np.asarray(r.output.matches.valid).sum())
        print(f"  t={r.t:6.3f}s rig={r.rig_id} {r.status:8s} "
              f"cameras={''.join('x' if m else '.' for m in r.camera_mask)} "
              f"valid_matches={n_valid}{'  (late)' if r.late else ''}")
    for e in result.events:
        print(f"  event t={e.now:6.3f}s rig={e.rig_id} {e.kind}"
              + (f" attempt={e.attempt}" if e.attempt else ""))
    print("status:")
    for rig_id, rep in sorted(result.status["supervisor"]["rigs"].items()):
        print(f"  rig {rig_id}: {rep['health']} "
              f"frames={rep['frames']} degraded={rep['degraded_frames']} "
              f"restarts={rep['restarts_total']}")
    print(f"counters: {dict(result.status['counters'])}")


if __name__ == "__main__":
    main()
