"""Serve a small LM: batched prefill -> batched greedy decode, the same
prefill/decode_step pair the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b

NOTE (quarantined legacy example): this predates the quad-camera visual
system this repo now reproduces and exercises the seed's LM stack
(`repro.models`/`repro.configs`), which the visual pipeline does not
touch.  Kept runnable but frozen — for the maintained serving story see
`examples/serve_fleet.py` and `repro.serving`.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.models.params import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(lm.model_schema(cfg), jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab,
                                      (args.batch, args.prompt_len)))
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (args.batch, cfg.vlm_prefix, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(size=(
            args.batch, args.prompt_len, cfg.d_model)) * 0.1,
            jnp.float32)

    max_len = args.prompt_len + args.new_tokens + (
        cfg.vlm_prefix if cfg.family == "vlm" else 0)

    prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b))
    decode = jax.jit(lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i),
                     donate_argnums=(2,))

    t0 = time.time()
    cache, last_logits, pos = prefill(params, batch)
    cache = lm.expand_cache(cfg, cache, max_len, args.prompt_len)
    tok = jnp.argmax(last_logits[:, :cfg.vocab], -1)[:, None]
    outs = [tok]
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(int(pos) + i, jnp.int32))
        tok = jnp.argmax(logits[:, 0, :cfg.vocab], -1)[:, None]
        outs.append(tok)
    gen = np.concatenate([np.asarray(t) for t in outs], 1)
    dt = time.time() - t0
    print(f"arch={args.arch} ({cfg.family}) batch={args.batch}")
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. "
          "compile)")
    print("first sequence:", gen[0][:16], "...")


if __name__ == "__main__":
    main()
