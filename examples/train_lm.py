"""Train a ~100M-parameter LM for a few hundred steps on the synthetic
induction corpus (second half of each sequence repeats the first half,
so the loss on the copyable half drops fast once the model learns to
attend backwards).

    PYTHONPATH=src python examples/train_lm.py --steps 300

Checkpoints + crash-restart: add --ckpt-dir /tmp/lm_ckpt and re-run the
same command after killing it — training resumes bit-identically.

NOTE (quarantined legacy example): this predates the quad-camera visual
system this repo now reproduces and trains the seed's LM stack, which
the visual pipeline does not touch.  Kept runnable but frozen — the
maintained examples are `quickstart.py`, `localize.py` and
`serve_fleet.py`.
"""

import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop
from repro.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # a ~100M-param gemma-family config (full machinery, reduced dims)
    cfg = get_smoke_config("gemma_7b").replace(
        n_layers=6, d_model=512, n_heads=8, n_kv=8, head_dim=64,
        d_ff=2048, vocab=8192, remat="nothing")
    data = TokenDataConfig(vocab=cfg.vocab, seq_len=256, global_batch=8)
    opt = AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps)
    mesh = make_host_mesh()

    _, hist = train_loop(cfg, data, opt, mesh, args.steps,
                         ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         log_every=20)
    losses = [l for _, l in hist]
    print(f"\nloss: {np.mean(losses[:10]):.3f} (start) -> "
          f"{np.mean(losses[-10:]):.3f} (end); uniform floor would be "
          f"{np.log(cfg.vocab):.3f}")


if __name__ == "__main__":
    main()
