"""End-to-end localization driver (the paper's full system) on the
`VisualSystem` session API: synthetic quad-camera sequence ->
frame-multiplexed ORB frontend -> stereo depth -> temporal matching ->
robust pose backend -> trajectory, compared to ground truth.

The session is configured ONCE from a ``RigConfig`` (camera layout +
intrinsics + sync) and a ``PipelineConfig`` (ORB parameters, impl,
schedule); every frame then goes through ``vs.process_frame`` — per
FRAME, one dense blur+FAST+NMS launch and one sparse orientation+rBRIEF
launch covering every camera at every pyramid level, plus ONE fused
Feature Matcher launch (Hamming match + in-kernel SAD rectification)
covering both stereo pairs: 3 launches total.  The same session also
serves a FLEET of rigs: ``vs.process_fleet`` folds a leading
``(n_rigs,)`` axis into the batched kernels, so N rigs still cost 3
launches per fleet frame.  Both traced launch audits are printed at
startup.

    PYTHONPATH=src python examples/localize.py [--frames 6]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ORBConfig, PipelineConfig, RigConfig, VisualSystem,
                        backend)
from repro.data import scenes

FLIP = jnp.asarray([[-1.0, 0, 0], [0, 1.0, 0], [0, 0, -1.0]])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--fleet", type=int, default=3,
                    help="rigs in the fleet launch audit")
    args = ap.parse_args()

    scene = scenes.SceneConfig(height=160, width=240, n_points=250,
                               baseline=0.5, seed=13)
    frames, rig_poses, intr = scenes.render_sequence(
        scene, args.frames, step_t=(0.2, 0.0, 0.1), yaw_per_frame=0.02)
    ocfg = ORBConfig(height=160, width=240, max_features=256,
                     n_levels=1, max_disparity=96)

    # One session = one configured rig + pipeline: jitted entry points
    # are cached on it, so the python loop below never retraces.
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))

    # Launch audit: the fused frontend schedule, traced (single rig and
    # an N-rig fleet — the fleet folds into the same 3 launches).
    n_frame = vs.traced_launches("process_frame", frames[0])
    fleet0 = jnp.broadcast_to(frames[0], (args.fleet,) + frames[0].shape)
    n_fleet = vs.traced_launches("process_fleet", fleet0)
    print(f"traced kernel launches per quad frame: {n_frame} "
          f"(1 dense + 1 sparse FE for all 4 cams x all levels, + 1 fused "
          f"FM — Hamming + in-kernel SAD for both pairs in one grid)")
    print(f"traced kernel launches per {args.fleet}-rig fleet frame: "
          f"{n_fleet} (rig axis folded into the same batched kernels)")

    outs = [vs.process_frame(f) for f in frames]  # leading (2,) pair axis
    outs_f = [jax.tree.map(lambda x: x[0], o) for o in outs]
    outs_b = [jax.tree.map(lambda x: x[1], o) for o in outs]

    poses = []
    for t in range(args.frames - 1):
        pts, pts_n, w = [], [], []
        for seq, rot in ((outs_f, jnp.eye(3)), (outs_b, FLIP)):
            prev, curr = seq[t], seq[t + 1]
            tm = vs.temporal_match(prev.features_l, curr.features_l)
            idx = tm.right_index
            wk = (tm.valid & prev.depth.valid
                  & curr.depth.valid[idx]).astype(jnp.float32)
            pts.append(backend.triangulate(
                prev.features_l, prev.depth, intr) @ rot.T)
            pts_n.append(backend.triangulate(
                curr.features_l, curr.depth, intr)[idx] @ rot.T)
            w.append(wk)
        pose = backend.estimate_relative_pose(
            jnp.concatenate(pts), jnp.concatenate(pts_n),
            jnp.concatenate(w), None, intr, refine=False)
        poses.append(pose)
        print(f"frame {t}->{t+1}: {int(pose.inliers)} inliers, "
              f"t = {np.asarray(pose.translation).round(3)}")

    traj = np.asarray(backend.integrate_trajectory(poses))
    true = np.asarray(rig_poses[-1][1])
    err = np.linalg.norm(traj[-1] - true)
    travel = np.linalg.norm(true)
    print(f"\nestimated final position: {traj[-1].round(3)}")
    print(f"ground-truth position:    {true.round(3)}")
    print(f"drift: {err:.3f} m over {travel:.2f} m "
          f"({100 * err / travel:.1f}%)")


if __name__ == "__main__":
    main()
