"""End-to-end localization driver (the paper's full system) on the
`VisualSystem` session API: synthetic quad-camera sequence ->
frame-multiplexed ORB frontend -> stereo depth -> temporal matching ->
robust pose backend -> trajectory, compared to ground truth.

The session is configured ONCE from a ``RigConfig`` (camera layout +
intrinsics + sync) and a ``PipelineConfig`` with ``localize=True``;
every frame then goes through ``vs.process_frame`` — one dense
blur+FAST+NMS launch and one sparse orientation+rBRIEF launch covering
every camera at every pyramid level, ONE fused Feature Matcher launch
(Hamming match + in-kernel SAD rectification) for both stereo pairs,
plus ONE fused temporal-match launch feeding the batched Procrustes
pose solve: 4 launches total.  The same session serves a FLEET of rigs
at the same budget (``vs.process_fleet`` folds a leading ``(n_rigs,)``
axis into the batched kernels), and ``vs.run`` scans a whole sequence,
threading the cross-frame ``LocalizationState`` automatically.

    PYTHONPATH=src python examples/localize.py [--frames 6]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import ORBConfig, PipelineConfig, RigConfig, VisualSystem
from repro.data import scenes
from repro.localization import metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--fleet", type=int, default=3,
                    help="rigs in the fleet launch audit")
    args = ap.parse_args()

    scene = scenes.SceneConfig(height=160, width=240, n_points=250,
                               baseline=0.5, seed=13)
    seq = scenes.render_sequence(scene, args.frames,
                                 step_t=(0.2, 0.0, 0.1),
                                 yaw_per_frame=0.02)
    frames = jnp.asarray(seq.frames)
    ocfg = ORBConfig(height=160, width=240, max_features=256,
                     n_levels=1, max_disparity=96)

    # One session = one configured rig + pipeline: jitted entry points
    # are cached on it, so nothing below ever retraces.  localize=True
    # folds the depth + ego-motion backend into every entry point.
    vs = VisualSystem(RigConfig.quad(seq.intrinsics),
                      PipelineConfig(orb=ocfg, localize=True))

    # Launch audit: the fused schedule, traced (single rig and an
    # N-rig fleet — the fleet folds into the SAME 4 launches).
    n_frame = vs.traced_launches("process_frame", frames[0])
    fleet0 = jnp.broadcast_to(frames[0], (args.fleet,) + frames[0].shape)
    n_fleet = vs.traced_launches("process_fleet", fleet0)
    print(f"traced kernel launches per localized quad frame: {n_frame} "
          f"(1 dense + 1 sparse FE for all 4 cams x all levels, + 1 "
          f"fused stereo FM, + 1 fused temporal FM for the pose solve)")
    print(f"traced kernel launches per {args.fleet}-rig fleet frame: "
          f"{n_fleet} (rig axis folded into the same batched kernels)")

    # The whole sequence in one call: out.pose rows are the t-1 -> t
    # relative poses (row 0 has no predecessor -> identity + invalid).
    out = vs.run(frames)
    for t in range(1, args.frames):
        print(f"frame {t - 1}->{t}: "
              f"{int(out.pose.inliers[t])} inliers, valid="
              f"{bool(out.pose.valid[t])}, t = "
              f"{np.asarray(out.pose.translation[t]).round(3)}")

    m = metrics.trajectory_metrics(out.pose.rotation,
                                   out.pose.translation, seq.poses)
    est_pos, _ = metrics.integrate_relative(out.pose.rotation,
                                            out.pose.translation)
    ref_pos = metrics.gt_positions(seq.poses)
    print(f"\nestimated final position: {est_pos[-1].round(3)}")
    print(f"ground-truth position:    {ref_pos[-1].round(3)}")
    print(f"ATE {m['ate_rmse_m']:.3f} m | RPE {m['rpe_trans_rmse_m']:.3f} m"
          f" / {m['rpe_rot_mean_deg']:.3f} deg over {m['travel_m']:.2f} m"
          f" of travel")


if __name__ == "__main__":
    main()
