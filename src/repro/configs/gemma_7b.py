"""gemma-7b: 28L dense, GeGLU, head_dim 256.  [arXiv:2403.08295]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma_7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv=16,
        head_dim=256, d_ff=24576, vocab=256000,
        mlp_act="gelu", tie_embeddings=True, embed_scale=True,
        notes="gemma-7b; GeGLU; tied embeddings; x *= sqrt(d_model)",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=32,
        d_ff=128, vocab=512, attn_chunk=64, dtype="float32")
