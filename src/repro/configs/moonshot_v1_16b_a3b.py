"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 48L MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B]  DeepSeek-V3-style: 2 shared experts,
first_k_dense_replace=1 (layer 0 keeps attention, dense MLP).  The
listed d_ff=1408 is the per-expert (moe_intermediate) width; the dense
layer-0 MLP uses ~active-width (top_k x 1408 != public 11264 — offline
approximation, documented)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="moonshot_v1_16b_a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv=16,
        d_ff=1408, vocab=163840,
        n_experts=64, top_k=6, n_shared_experts=2,
        first_dense=1, first_dense_ff=8448,
        rope_theta=50000.0, mlp_act="silu",
        notes="Moonlight 16B-A3B; 64e top-6 + 2 shared; first layer dense",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=48,
        vocab=512, n_experts=8, top_k=2, n_shared_experts=1,
        first_dense=1, first_dense_ff=96, attn_chunk=64, capacity_factor=8.0,
        dtype="float32")
