"""zamba2-7b: 81 Mamba2 layers + one SHARED full-attention transformer
block applied after every 6 SSM layers (13 applications + 3-layer tail).
[arXiv:2411.15242]  Simplifications (documented in DESIGN.md): the
shared block runs at d_model (the public model concatenates the
original embedding, 2 x d_model) and per-application LoRA deltas are
omitted — the shared-parameter structure (the paper's memory-saving
idea) is preserved."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2_7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv=32,
        d_ff=14336, vocab=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2,
        hybrid_period=6,
        notes="zamba2-7b; shared attn block every 6 mamba layers",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=5, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=32, hybrid_period=2,
        vocab=512, attn_chunk=32, dtype="float32", ssm_intra_bf16=False)
