"""Architecture configs (assigned pool) + the paper's frontend config."""

from repro.configs.base import (ARCH_IDS, ModelConfig, ShapeCell,
                                get_config, get_smoke_config, shape_cells)

__all__ = ["ARCH_IDS", "ModelConfig", "ShapeCell", "get_config",
           "get_smoke_config", "shape_cells"]
