"""h2o-danube-1.8b: 24L dense, llama+mistral mix, sliding-window
attention.  [arXiv:2401.16818]  All layers windowed -> rolling KV cache
-> long_500k runs (window-bounded, sub-quadratic)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="h2o_danube_18b", family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv=8,
        d_ff=6912, vocab=32000,
        sliding_window=4096,
        notes="h2o-danube 1.8b; SWA 4096 everywhere -> rolling cache",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, sliding_window=32, attn_chunk=32, dtype="float32")
