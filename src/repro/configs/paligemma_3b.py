"""paligemma-3b: SigLIP + gemma decoder, MQA kv=1, prefix-LM.
[arXiv:2407.07726]  The SigLIP vision tower is a STUB per spec:
``input_specs()`` provides 256 precomputed patch embeddings; the
backbone applies a prefix-LM mask (bidirectional over the patches)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="paligemma_3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv=1,
        head_dim=256, d_ff=16384, vocab=257216,
        mlp_act="gelu", tie_embeddings=True, embed_scale=True,
        vlm_prefix=256,
        notes="paligemma-3b backbone; SigLIP stub; prefix-LM over patches",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=32,
        d_ff=128, vocab=512, vlm_prefix=8, attn_chunk=32,
        dtype="float32")
