"""Model-config schema + registry + shape cells.

Every assigned architecture is a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``), constructed from the exact public
hyper-parameters, plus a reduced ``smoke()`` variant of the same family
for CPU tests.  ``shape_cells`` enumerates the assigned (arch x shape)
dry-run cells with applicability flags.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mlp_act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    embed_scale: bool = False            # gemma: x *= sqrt(d_model)
    rms_unit_offset: bool = True
    rms_eps: float = 1e-6
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # default head_dim ** -0.5
    sliding_window: Optional[int] = None
    local_global_period: int = 0         # gemma2: 2 -> alternate local/global
    post_norms: bool = False             # gemma2: post-attn/post-mlp norms
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_dense: int = 0                 # deepseek first_k_dense_replace
    first_dense_ff: int = 0              # dense-MLP width of that layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_cap_data: bool = False           # shard expert capacity over data
    moe_impl: str = "a2a"                # a2a (shard_map EP) | gather
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_intra_bf16: bool = True
    # --- hybrid (zamba2) ---
    hybrid_period: int = 0               # shared attn block every N ssm layers
    # --- enc-dec ---
    n_enc_layers: int = 0
    # --- vlm ---
    vlm_prefix: int = 0                  # image patch tokens (stub frontend)
    # --- substrate knobs ---
    vocab_pad_mult: int = 256
    remat: str = "full"                  # nothing | dots | full
    loss_chunk: int = 512                # fused-head xent seq chunk
    attn_chunk: int = 1024               # flash-attention kv chunk
    dtype: str = "bfloat16"              # compute dtype
    notes: str = ""

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return _pad_to(self.vocab, self.vocab_pad_mult)

    @property
    def kv_eff(self) -> int:
        """KV heads after TP repetition (mathematically identical; lets
        the kv dim shard when n_kv doesn't divide the model axis but a
        small integer multiple does).  16 == production model-axis size."""
        tp = 16
        if self.n_kv == 0 or self.n_heads % tp != 0:
            return self.n_kv
        if self.n_kv % tp == 0:
            return self.n_kv
        # smallest multiple of n_kv that divides n_heads and is % tp == 0
        m = self.n_kv
        while m <= self.n_heads:
            if m % tp == 0 and self.n_heads % m == 0:
                return m
            m += self.n_kv
        return self.n_kv

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def query_scale_(self) -> float:
        return (self.query_scale if self.query_scale is not None
                else self.head_dim_ ** -0.5)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int
    applicable: bool = True
    skip_reason: str = ""


ARCH_IDS = [
    "moonshot_v1_16b_a3b", "phi35_moe_42b_a66b", "gemma_7b", "qwen25_32b",
    "h2o_danube_18b", "gemma2_2b", "paligemma_3b", "mamba2_780m",
    "zamba2_7b", "seamless_m4t_medium",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.smoke()


def shape_cells(cfg: ModelConfig) -> list[ShapeCell]:
    """The 4 assigned shapes with per-family applicability.

    long_500k needs sub-quadratic attention: run for SSM / hybrid /
    sliding-window archs, skip (documented) for pure full-attention ones.
    Enc-dec/decoder rules: all assigned archs have a decoder, so decode
    shapes always lower ``serve_step``.
    """
    # all-layers sliding window counts; gemma2's alternating stack still
    # has full-attention global layers, so it does NOT qualify.
    swa_everywhere = (cfg.sliding_window is not None
                      and cfg.local_global_period == 0)
    sub_quadratic = cfg.family in ("ssm", "hybrid") or swa_everywhere
    cells = [
        ShapeCell("train_4k", "train", 4096, 256),
        ShapeCell("prefill_32k", "prefill", 32768, 32),
        ShapeCell("decode_32k", "decode", 32768, 128),
    ]
    if sub_quadratic:
        cells.append(ShapeCell("long_500k", "decode", 524288, 1))
    else:
        cells.append(ShapeCell(
            "long_500k", "decode", 524288, 1, applicable=False,
            skip_reason="pure full-attention arch: 500k decode is "
                        "quadratic; skipped per spec (see DESIGN.md)"))
    return cells
