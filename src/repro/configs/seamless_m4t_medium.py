"""seamless-m4t-medium: 12L enc + 12L dec, multimodal.  [arXiv:2308.11596]
The speech frontend is a STUB per spec: ``input_specs()`` provides
precomputed frame embeddings (B, S_src, d_model).  Self-attention uses
RoPE on both sides (the public model uses relative position bias —
documented simplification); cross-attention is position-free."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="seamless_m4t_medium", family="encdec",
        n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv=16,
        d_ff=4096, vocab=256206,
        mlp_act="gelu", tie_embeddings=True,
        notes="seamless-m4t-medium; enc-dec; audio frontend stubbed",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=512, attn_chunk=32, dtype="float32")
