"""mamba2-780m: 48L attention-free SSD.  [arXiv:2405.21060]
d_inner = 2 x 1536 = 3072, headdim 64 -> 48 ssm heads, state 128."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2_780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv=0, d_ff=0,
        vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        tie_embeddings=True,
        notes="mamba2-780m; SSD chunked scan; O(1)/token decode",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=32, vocab=512, dtype="float32", ssm_intra_bf16=False)
