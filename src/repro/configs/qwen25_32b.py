"""qwen2.5-32b: 64L dense, GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-32B]

40 heads % 16-way model axis != 0 and no kv_eff repetition divides
(40 % 16), so attention falls back to unsharded heads on the baseline;
see EXPERIMENTS.md §Perf for the sequence-TP hillclimb."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen25_32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv=8,
        d_ff=27648, vocab=152064,
        qkv_bias=True, rope_theta=1e6,
        notes="Qwen2.5-32B; GQA kv8; QKV bias; rope 1e6",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=80, n_heads=5, n_kv=1, d_ff=128,
        vocab=512, attn_chunk=64, dtype="float32")
