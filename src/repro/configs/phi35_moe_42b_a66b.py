"""phi3.5-moe-42b-a6.6b: 32L MoE, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct]  Mixtral-style token-choice MoE
(the public model routes with SparseMixer; we use softmax top-2 —
documented simplification)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi35_moe_42b_a66b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8,
        d_ff=6400, vocab=32064,
        n_experts=16, top_k=2,
        rope_theta=10000.0, mlp_act="silu",
        notes="Phi-3.5-MoE; 16e top-2; softmax router (not SparseMixer)",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=96,
        vocab=512, n_experts=4, top_k=2, attn_chunk=64, capacity_factor=8.0,
        dtype="float32")
