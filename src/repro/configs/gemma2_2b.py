"""gemma2-2b: 26L dense, local/global alternating, logit softcaps,
post-norms.  [arXiv:2408.00118]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma2_2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv=4,
        head_dim=256, d_ff=9216, vocab=256000,
        mlp_act="gelu", tie_embeddings=True, embed_scale=True,
        sliding_window=4096, local_global_period=2,
        attn_softcap=50.0, final_softcap=30.0,
        query_scale=256.0 ** -0.5, post_norms=True,
        notes="gemma2-2b; alternating SWA/global; softcaps; post-norms",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=32,
        d_ff=128, vocab=512, sliding_window=32, attn_chunk=32,
        query_scale=32.0 ** -0.5, dtype="float32")
