"""CLI: ``python -m repro.analysis`` — run the static audit, print a
summary, write ``AUDIT.json``, exit non-zero on any violation.

    PYTHONPATH=src python -m repro.analysis [--out AUDIT.json]
        [--quick] [--height H --width W] [--vmem-budget-mib 16]

``--quick`` audits a small 240x320 / K=512 matrix (seconds instead of
tens of seconds); launch counts, dtype contracts and bounds proofs are
resolution-independent, only the absolute VMEM numbers shrink.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis import report as report_mod
from repro.analysis import vmem as vmem_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static trace-time audit of every VisualSystem "
                    "entry point (launches / VMEM / dtypes / bounds / "
                    "serving lint).")
    ap.add_argument("--out", default="AUDIT.json",
                    help="report path (default AUDIT.json)")
    ap.add_argument("--height", type=int, default=720)
    ap.add_argument("--width", type=int, default=1280)
    ap.add_argument("--max-features", type=int, default=1000)
    ap.add_argument("--vmem-budget-mib", type=float, default=None,
                    help="per-launch resident budget in MiB "
                         "(default 16 — one TPU core)")
    ap.add_argument("--quick", action="store_true",
                    help="small 240x320 / K=512 matrix")
    args = ap.parse_args(argv)

    height, width, kmax = args.height, args.width, args.max_features
    if args.quick:
        height, width, kmax = 240, 320, 512
    budget = (vmem_mod.DEFAULT_VMEM_BUDGET
              if args.vmem_budget_mib is None
              else int(args.vmem_budget_mib * 2 ** 20))

    rep = report_mod.run_audit(vmem_budget=budget, height=height,
                               width=width, max_features=kmax)
    for e in rep["entries"]:
        la = e["launches"]
        worst = max((v["resident_mib"] for v in e["vmem"]),
                    default=0.0)
        flags = []
        if not la["budget_ok"]:
            flags.append(f"launches {la['static']}>"
                         f"{e['launch_budget']}")
        if not la["consistent"]:
            flags.append(f"static {la['static']} != trace_audit "
                         f"{la['trace_audit']}")
        if any(not v["ok"] for v in e["vmem"]):
            flags.append("VMEM over budget")
        if e["dtype_violations"]:
            flags.append(f"{len(e['dtype_violations'])} dtype")
        if e["bounds_violations"]:
            flags.append(f"{len(e['bounds_violations'])} bounds")
        verdict = "ok" if e["ok"] else "FAIL(" + ", ".join(flags) + ")"
        print(f"{verdict:>8}  {e['name']:<18} launches="
              f"{la['static']}/{e['launch_budget']} "
              f"kernels={len(e['vmem'])} "
              f"peak_vmem={worst:.2f}MiB")
    lint = rep["hostlint"]
    print(f"{'ok' if lint['ok'] else 'FAIL':>8}  serving hostlint: "
          f"{len(lint['findings'])} finding(s)")
    for f in lint["findings"]:
        print(f"          {f['file']}:{f['line']} [{f['rule']}] "
              f"{f['symbol']}: {f['message']}")
    bad = [k for k, ok in rep["checks"].items() if not ok]
    print(("AUDIT ok — all checks green" if rep["ok"]
           else f"AUDIT FAILED: {', '.join(bad)}"))
    report_mod.write_report(rep, args.out)
    print(f"wrote {args.out}")
    return 0 if rep["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
