"""Closed-jaxpr walking: find every ``pallas_call`` with its trip count.

This is the trace-time analog of ``launch/hlo_stats.py``'s HLO call
graph: instead of parsing compiled HLO text, we walk the CLOSED jaxpr of
an entry point (``jax.make_jaxpr`` over abstract shapes — no data, no
execution) and enumerate every ``pallas_call`` equation together with a
static execution multiplier:

  * ``pjit`` / ``custom_jvp`` / ``custom_vjp`` / other call-like
    primitives are transparent (multiplier unchanged),
  * ``scan`` multiplies by its static ``length`` (nested scans multiply,
    exactly like nested while bodies in ``hlo_stats.analyze``),
  * ``while`` has no static trip count: launches inside its body are
    UNBOUNDED — recorded as such so a budget check can refuse to prove
    anything rather than silently under-count,
  * ``cond`` branches are alternatives, not a sequence: the launch count
    of a cond is the MAX over its branches (the budget must hold on the
    worst-case path), while ``sites`` still reports every branch's
    kernels so resource checks cover all of them.

The result is the number the runtime ``ops.launch_audit`` counter
observes while tracing — proven from the program structure instead of
observed from a counter, so CI can require the two to agree exactly
(``benchmarks/check_audit.py``).
"""

from __future__ import annotations

import dataclasses

import jax.core as jcore

__all__ = ["PallasSite", "LaunchCount", "pallas_sites", "count_launches"]


@dataclasses.dataclass(frozen=True)
class PallasSite:
    """One ``pallas_call`` equation found in a traced program.

    ``mult`` is the static number of times the launch executes per call
    of the traced entry (scan trip counts multiplied along the path);
    ``None`` means the site sits inside a ``while`` body and has no
    static bound.  ``path`` is the chain of enclosing control-flow
    primitives, for error messages."""

    eqn: jcore.JaxprEqn
    mult: int | None
    path: tuple[str, ...]

    @property
    def name(self) -> str:
        info = self.eqn.params.get("name_and_src_info")
        return getattr(info, "name", None) or "<pallas_call>"

    @property
    def src(self) -> str:
        return str(self.eqn.params.get("name_and_src_info", ""))

    @property
    def grid_mapping(self):
        return self.eqn.params["grid_mapping"]

    @property
    def kernel_jaxpr(self) -> jcore.Jaxpr:
        body = self.eqn.params["jaxpr"]
        return body.jaxpr if isinstance(body, jcore.ClosedJaxpr) else body


@dataclasses.dataclass(frozen=True)
class LaunchCount:
    """Static launch count of a traced program: ``total`` bounded
    launches plus the sites that could not be bounded (inside ``while``
    bodies).  ``bounded`` is False when any unbounded site exists — a
    budget can then not be proven."""

    total: int
    unbounded_sites: tuple[PallasSite, ...] = ()

    @property
    def bounded(self) -> bool:
        return not self.unbounded_sites


def _sub_jaxprs(val):
    """Yield every (Closed)Jaxpr living in one eqn param value."""
    vals = val if isinstance(val, (tuple, list)) else (val,)
    for v in vals:
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v


def _mul(mult: int | None, k: int) -> int | None:
    return None if mult is None else mult * k


def pallas_sites(closed: jcore.ClosedJaxpr) -> list[PallasSite]:
    """Every ``pallas_call`` in ``closed`` (recursively), with trip
    multipliers.  Sites on all ``cond`` branches are reported (resource
    checks must hold on every path)."""
    out: list[PallasSite] = []

    def walk(jaxpr: jcore.Jaxpr, mult: int | None,
             path: tuple[str, ...]) -> None:
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "pallas_call":
                out.append(PallasSite(eqn, mult, path))
                continue
            if prim == "scan":
                k = int(eqn.params.get("length", 1))
                for sub in _sub_jaxprs(eqn.params.get("jaxpr")):
                    walk(sub, _mul(mult, k), path + (f"scan[{k}]",))
                continue
            if prim == "while":
                for key in ("body_jaxpr", "cond_jaxpr"):
                    for sub in _sub_jaxprs(eqn.params.get(key)):
                        walk(sub, None, path + ("while",))
                continue
            if prim == "cond":
                branches = eqn.params.get("branches", ())
                for b, branch in enumerate(branches):
                    for sub in _sub_jaxprs(branch):
                        walk(sub, mult, path + (f"cond.{b}",))
                continue
            # Generic call-like primitive (pjit, custom_jvp_call, ...):
            # descend into every jaxpr-valued param, multiplier unchanged.
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    walk(sub, mult, path + (prim,))

    walk(closed.jaxpr, 1, ())
    return out


def count_launches(closed: jcore.ClosedJaxpr) -> LaunchCount:
    """Static launch count of ``closed``: scan bodies multiply by their
    trip count, cond takes the worst-case branch, while bodies are
    unbounded.  Matches what ``ops.launch_audit`` observes at trace time
    for bounded programs."""

    def walk(jaxpr: jcore.Jaxpr, mult: int | None,
             path: tuple[str, ...]):
        total = 0
        unbounded: list[PallasSite] = []
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "pallas_call":
                if mult is None:
                    unbounded.append(PallasSite(eqn, None, path))
                else:
                    total += mult
                continue
            if prim == "scan":
                k = int(eqn.params.get("length", 1))
                for sub in _sub_jaxprs(eqn.params.get("jaxpr")):
                    t, u = walk(sub, _mul(mult, k), path + (f"scan[{k}]",))
                    total += t
                    unbounded.extend(u)
                continue
            if prim == "while":
                for key in ("body_jaxpr", "cond_jaxpr"):
                    for sub in _sub_jaxprs(eqn.params.get(key)):
                        _, u = walk(sub, None, path + ("while",))
                        unbounded.extend(u)
                continue
            if prim == "cond":
                worst = 0
                for b, branch in enumerate(eqn.params.get("branches", ())):
                    bt = 0
                    for sub in _sub_jaxprs(branch):
                        t, u = walk(sub, mult, path + (f"cond.{b}",))
                        bt += t
                        unbounded.extend(u)
                    worst = max(worst, bt)
                total += worst
                continue
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    t, u = walk(sub, mult, path + (prim,))
                    total += t
                    unbounded.extend(u)
        return total, unbounded

    total, unbounded = walk(closed.jaxpr, 1, ())
    return LaunchCount(total, tuple(unbounded))
