"""AST lint over the serving tier: host-boundary + concurrency hygiene.

The kernels are audited from their traces; the serving loop's failure
modes are PYTHON-side and invisible to a jaxpr: a stray
``block_until_ready`` serializing the dispatch pipeline, an
``np.asarray`` forcing a device sync in the middle of ``step``, a
``jax.jit`` constructed per call (fresh cache -> retrace every tick),
or the ``DispatchGuard`` watchdog thread mutating service state the
main loop reads concurrently.  These are linted at the SOURCE level:

  ``blocking-call``      ``.block_until_ready()`` / ``.item()`` /
                         ``time.sleep()`` inside a hot-path function —
                         each is a host sync or a stall in the serve
                         loop.
  ``host-transfer``      ``np.asarray`` / ``np.array`` / ``jnp.asarray``
                         inside a hot-path function: on a jitted
                         output this is a blocking device->host copy.
                         Intake (``submit``) is NOT a hot path — frames
                         arrive as host arrays there by design.
  ``retrace-risk``       ``jax.jit(...)`` called inside a hot-path
                         function: a jit wrapper built per call has an
                         empty cache, i.e. unbounded retracing.  Jitted
                         entry points must be built once and cached
                         (``VisualSystem._jit``).
  ``watchdog-unlocked``  assignment / mutation of ``self.*`` state from
                         a function defined inside a thread-spawning
                         function (the ``DispatchGuard`` watchdog
                         worker) without an enclosing ``with *lock*:``
                         block.  The worker's contract is to hand its
                         result through a joined-before-read local; any
                         ``self`` touch races the main loop.

A finding on a line carrying the pragma comment ``audit: host-ok`` is
suppressed — the escape hatch for a call that is deliberate and
documented at the site.
"""

from __future__ import annotations

import ast
import dataclasses
import os

__all__ = ["HostLintFinding", "HOT_PATHS", "lint_source", "lint_serving"]

# Hot-path functions per serving module: the per-tick serve loop and
# the guarded-dispatch machinery.  Nested functions (e.g. ``step``'s
# ``_compute`` closure) inherit hotness from their enclosing function.
HOT_PATHS = {
    "service.py": ("step", "_guarded", "_assemble_prev"),
    "failover.py": ("run", "_attempt", "backoff"),
    "queue.py": ("next_batch",),
    "supervisor.py": ("poll", "heartbeat"),
}

_BLOCKING_ATTRS = ("block_until_ready", "item")
_TRANSFER_CALLS = ("np.asarray", "np.array", "jnp.asarray", "np.copy")
_MUTATING_METHODS = ("append", "extend", "add", "update", "pop",
                     "popleft", "remove", "clear", "insert",
                     "setdefault", "appendleft")
_PRAGMA = "audit: host-ok"


@dataclasses.dataclass(frozen=True)
class HostLintFinding:
    file: str
    line: int
    rule: str
    symbol: str
    message: str


def _dotted(node: ast.AST) -> str:
    """'np.asarray' for Attribute chains, 'name' for Names, '' else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_lock_ctx(item: ast.withitem) -> bool:
    return "lock" in _dotted(item.context_expr).lower()


def _touches_self(node: ast.AST) -> bool:
    """Does this store/mutation target reach through ``self``?"""
    while True:
        if isinstance(node, ast.Attribute) or isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            return node.id == "self"
        else:
            return False


class _Linter(ast.NodeVisitor):
    def __init__(self, filename: str, source: str,
                 hot_names: tuple[str, ...]):
        self.filename = filename
        self.lines = source.splitlines()
        self.hot_names = hot_names
        self.findings: list[HostLintFinding] = []
        self._hot_depth = 0
        self._thread_body_depth = 0
        self._lock_depth = 0

    # -- helpers -----------------------------------------------------------

    def _suppressed(self, node: ast.AST) -> bool:
        line = getattr(node, "lineno", 0)
        if 1 <= line <= len(self.lines):
            return _PRAGMA in self.lines[line - 1]
        return False

    def _emit(self, node: ast.AST, rule: str, symbol: str,
              message: str) -> None:
        if not self._suppressed(node):
            self.findings.append(HostLintFinding(
                self.filename, getattr(node, "lineno", 0), rule, symbol,
                message))

    @staticmethod
    def _spawns_thread(fn: ast.AST) -> bool:
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) and \
                    _dotted(sub.func).endswith("Thread"):
                return True
        return False

    # -- scope tracking ----------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        hot = self._hot_depth > 0 or node.name in self.hot_names
        self._generic_function(node, hot)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _generic_function(self, node, hot: bool) -> None:
        spawns = self._spawns_thread(node)
        self._hot_depth += int(hot)
        for child in ast.iter_child_nodes(node):
            self._dispatch_child(child, nested_is_thread_body=spawns)
        self._hot_depth -= int(hot)

    def _dispatch_child(self, child: ast.AST,
                        nested_is_thread_body: bool = False) -> None:
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if nested_is_thread_body:
                self._thread_body_depth += 1
                self.visit(child)
                self._thread_body_depth -= 1
            else:
                self.visit(child)
        else:
            self.visit(child)

    def visit_With(self, node: ast.With) -> None:
        locked = any(_is_lock_ctx(i) for i in node.items)
        self._lock_depth += int(locked)
        self.generic_visit(node)
        self._lock_depth -= int(locked)

    # -- rules -------------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        # Mutating-method calls on self state count as shared stores
        # when made from a thread body.
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATING_METHODS and \
                _touches_self(node.func.value):
            self._check_shared_store(node, node.func)
        if self._hot_depth > 0:
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _BLOCKING_ATTRS:
                self._emit(node, "blocking-call", node.func.attr,
                           f".{node.func.attr}() in a hot-path function "
                           "blocks the serve loop on the device")
            elif name == "time.sleep":
                self._emit(node, "blocking-call", name,
                           "time.sleep() in a hot-path function stalls "
                           "the serve loop (the guard REPORTS backoff, "
                           "it never sleeps)")
            elif name in _TRANSFER_CALLS:
                self._emit(node, "host-transfer", name,
                           f"{name}() in a hot-path function forces a "
                           "device->host sync on jitted outputs")
            elif name == "jax.jit":
                self._emit(node, "retrace-risk", name,
                           "jax.jit() constructed inside a hot-path "
                           "function: per-call wrapper -> empty cache "
                           "-> unbounded retracing")
        self.generic_visit(node)

    def _check_shared_store(self, node: ast.AST, target: ast.AST) -> None:
        if self._thread_body_depth > 0 and self._lock_depth == 0 and \
                _touches_self(target):
            sym = target
            while isinstance(sym, ast.Subscript):
                sym = sym.value
            self._emit(node, "watchdog-unlocked", _dotted(sym) or "self",
                       "shared `self` state mutated from the watchdog "
                       "thread body without holding a lock — races the "
                       "main serve loop")

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            self._check_shared_store(node, target)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_shared_store(node, node.target)
        self.generic_visit(node)


def lint_source(source: str, filename: str,
                hot_names: tuple[str, ...] | None = None
                ) -> list[HostLintFinding]:
    """Lint one serving module's source text."""
    base = os.path.basename(filename)
    if hot_names is None:
        hot_names = HOT_PATHS.get(base, ())
    tree = ast.parse(source, filename=filename)
    linter = _Linter(base, source, tuple(hot_names))
    linter.visit(tree)
    return linter.findings


def lint_serving(root: str | None = None) -> list[HostLintFinding]:
    """Lint every module of ``repro.serving`` (or of ``root``)."""
    if root is None:
        from repro import serving
        root = os.path.dirname(serving.__file__)
    findings: list[HostLintFinding] = []
    for name in sorted(os.listdir(root)):
        if not name.endswith(".py"):
            continue
        path = os.path.join(root, name)
        with open(path) as f:
            findings.extend(lint_source(f.read(), path))
    return findings
