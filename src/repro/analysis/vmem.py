"""Static VMEM residency accounting per ``pallas_call`` launch.

The paper's FPGA flow proves BRAM fit at synthesis; the TPU analog is
the per-core VMEM a launch keeps resident: one block per operand per
grid step (input AND output BlockSpecs), with ``pl.Unblocked`` windows
counted at their full block shape — halos included, exactly the bytes
the kernel touches.  ``launch_vmem`` reads the traced
``grid_mapping.block_mappings`` of a :class:`~.jaxpr_walk.PallasSite`
and reports:

  * ``resident_bytes`` — Σ blocks × itemsize with ONE buffer per
    operand: the floor any schedule must hold resident (this is the
    accounting behind the repro's 7.91 MiB/pair @720p f32 / 1.98 MiB
    uint8 numbers), and the number the budget gates;
  * ``pipelined_bytes`` — the same with double buffering (×2), the
    steady-state working set of the default pipelined schedule,
    reported for context but NOT gated (the compiler may or may not
    double-buffer each operand).

The default budget is 16 MiB — one TPU core's VMEM.  A 1080p float32
FM slab pair (≈17.1 MiB) correctly fails it; the 720p matrix passes.
"""

from __future__ import annotations

import dataclasses
import math

from repro.analysis.jaxpr_walk import PallasSite

__all__ = ["DEFAULT_VMEM_BUDGET", "BlockUsage", "LaunchVmem",
           "launch_vmem"]

# One TPU core's vector memory.  Configurable per call — the CLI
# exposes --vmem-budget-mib.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockUsage:
    """One operand's per-grid-step resident block."""

    origin: str               # 'args[i]' / 'outputs[i]' per the trace
    block_shape: tuple        # as written in the BlockSpec (halos incl.)
    dtype: str
    mode: str                 # 'Blocked' | 'Unblocked'
    nbytes: int


@dataclasses.dataclass(frozen=True)
class LaunchVmem:
    """Residency verdict for one launch site."""

    kernel: str
    grid: tuple
    blocks: tuple[BlockUsage, ...]
    resident_bytes: int       # 1 buffer per operand (gated)
    pipelined_bytes: int      # 2 buffers per operand (reported)
    budget: int

    @property
    def ok(self) -> bool:
        return self.resident_bytes <= self.budget


def _block_elems(block_shape) -> int:
    # Squeezed dims show up as pallas' `mapped` sentinel / None — they
    # contribute one element row, not zero.
    return math.prod(
        int(d) if isinstance(d, int) else 1 for d in block_shape)


def _usage(bm) -> BlockUsage:
    dtype = bm.array_shape_dtype.dtype
    mode = type(bm.indexing_mode).__name__
    shape = tuple(bm.block_shape)
    return BlockUsage(
        origin=str(getattr(bm, "origin", "?")),
        block_shape=shape,
        dtype=str(dtype),
        mode=mode,
        nbytes=_block_elems(shape) * dtype.itemsize)


def launch_vmem(site: PallasSite,
                budget: int = DEFAULT_VMEM_BUDGET) -> LaunchVmem:
    """Resident-bytes accounting for one ``pallas_call``: every input
    and output BlockSpec contributes one block per grid step."""
    gm = site.grid_mapping
    blocks = tuple(_usage(bm) for bm in gm.block_mappings)
    resident = sum(b.nbytes for b in blocks)
    return LaunchVmem(
        kernel=site.name,
        grid=tuple(int(g) for g in gm.grid),
        blocks=blocks,
        resident_bytes=resident,
        pipelined_bytes=2 * resident,
        budget=int(budget))
