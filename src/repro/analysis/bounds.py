"""Grid / index-map bounds proof: every block stays inside its slab.

Each BlockSpec's ``index_map`` is a tiny traced function from grid
indices to a block position; pallas trusts it.  A map that walks a
block past the padded slab edge (an off-by-one in the halo arithmetic,
a slab index that ignores the shape table) reads garbage — silently on
interpret-mode CPU.  This checker closes that gap abstractly: it
evaluates every ``index_map_jaxpr`` over its ENTIRE grid with
``jax.core.eval_jaxpr`` (pure python, no compilation — grids here are a
few hundred points) and proves, per dimension:

  * ``Blocked`` mode — the returned BLOCK index ``b`` satisfies
    ``0 <= b`` and ``b * block < dim`` (the block's first element is
    inside the array; pallas pads the tail block);
  * ``Unblocked`` mode — the returned ELEMENT start ``s`` satisfies
    ``-lo <= s`` and ``s + block <= dim + hi`` where ``(lo, hi)`` is
    the mode's declared padding (none by default) — halo windows must
    sit entirely inside the pre-padded slab.
"""

from __future__ import annotations

import dataclasses
import itertools

import jax.core as jcore
import numpy as np

from repro.analysis.jaxpr_walk import PallasSite

__all__ = ["BoundsViolation", "check_bounds"]

# Violations are truncated per block-mapping: one broken index map can
# fail at thousands of grid points and they all say the same thing.
_MAX_VIOLATIONS = 5


@dataclasses.dataclass(frozen=True)
class BoundsViolation:
    kernel: str
    origin: str
    grid_point: tuple
    dim: int
    message: str


def _pad(indexing_mode, rank: int) -> list[tuple[int, int]]:
    pad = getattr(indexing_mode, "padding", None)
    if pad is None:
        return [(0, 0)] * rank
    return [(int(lo), int(hi)) for lo, hi in pad]


def _check_mapping(site: PallasSite, bm, grid) -> list[BoundsViolation]:
    closed = bm.index_map_jaxpr
    block = tuple(bm.block_shape)
    dims = tuple(int(d) for d in bm.array_shape_dtype.shape)
    mode = type(bm.indexing_mode).__name__
    origin = str(getattr(bm, "origin", "?"))
    if len(closed.jaxpr.invars) != len(grid):
        return [BoundsViolation(
            site.name, origin, (), -1,
            f"index_map takes {len(closed.jaxpr.invars)} args but the "
            f"grid has rank {len(grid)} — cannot evaluate")]
    pad = _pad(bm.indexing_mode, len(block))
    out: list[BoundsViolation] = []
    for point in itertools.product(*(range(g) for g in grid)):
        idx = jcore.eval_jaxpr(closed.jaxpr, closed.consts,
                               *(np.int32(p) for p in point))
        for d, raw in enumerate(idx):
            v = int(raw)
            bs = block[d] if isinstance(block[d], int) else 1
            dim = dims[d] if d < len(dims) else 1
            if mode == "Unblocked":
                lo, hi = pad[d]
                if v < -lo or v + bs > dim + hi:
                    out.append(BoundsViolation(
                        site.name, origin, point, d,
                        f"element window [{v}, {v + bs}) escapes "
                        f"dim {d} of extent {dim} "
                        f"(padding ({lo}, {hi}))"))
            else:
                if v < 0 or v * bs >= dim:
                    out.append(BoundsViolation(
                        site.name, origin, point, d,
                        f"block index {v} (block {bs}) escapes dim "
                        f"{d} of extent {dim}"))
            if len(out) >= _MAX_VIOLATIONS:
                return out
    return out


def check_bounds(site: PallasSite) -> list[BoundsViolation]:
    """Prove every BlockSpec of one launch in-bounds over its full
    grid; returns the (truncated) list of violations, empty = proven."""
    grid = tuple(int(g) for g in site.grid_mapping.grid)
    out: list[BoundsViolation] = []
    for bm in site.grid_mapping.block_mappings:
        out.extend(_check_mapping(site, bm, grid))
        if len(out) >= _MAX_VIOLATIONS:
            break
    return out[:_MAX_VIOLATIONS]
