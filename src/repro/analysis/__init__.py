"""Trace-time static analysis of the visual system (PR 10).

The paper proves its resource claims at synthesis time — BRAM/DSP
budgets hold before the bitstream ever runs.  This package is that
discipline for the jax_pallas repro: every ``VisualSystem`` entry point
is traced ABSTRACTLY (``jax.make_jaxpr`` over shape/dtype structs — no
data, no kernel execution, no TPU) and the traced program is audited:

  ``jaxpr_walk``   find every ``pallas_call`` with its static trip
                   multiplier (scan × length, cond worst-case branch,
                   while = unbounded) — the launch-budget proof
  ``vmem``         per-launch resident bytes from the BlockSpecs/grid
                   (Unblocked halos included) vs a per-core budget
  ``dtype_flow``   silent-widening lint over kernel-body jaxprs
                   (float in an all-integer kernel, float64 anywhere,
                   weak-type promotions)
  ``bounds``       every BlockSpec index_map evaluated over its FULL
                   grid — blocks proven inside the padded slab
  ``hostlint``     AST lint over ``repro.serving`` hot paths (blocking
                   calls, per-call jax.jit retrace risk, watchdog
                   thread touching shared state without a lock)
  ``matrix``       the audited entry × precision × masked × localize ×
                   fleet matrix, reconciled 1:1 with the runtime
                   ``launch_gate/*`` benchmark rows
  ``report``       assembles ``AUDIT.json`` for the CI gate
                   (``benchmarks/check_audit.py``)

Run: ``PYTHONPATH=src python -m repro.analysis [--quick]``.
"""

from repro.analysis.bounds import BoundsViolation, check_bounds
from repro.analysis.dtype_flow import DtypeViolation, check_kernel_dtypes
from repro.analysis.hostlint import (HostLintFinding, lint_serving,
                                     lint_source)
from repro.analysis.jaxpr_walk import (LaunchCount, PallasSite,
                                       count_launches, pallas_sites)
from repro.analysis.matrix import (MATRIX, EntrySpec, TracedEntry,
                                   trace_entry, trace_matrix)
from repro.analysis.report import audit_entry, run_audit, write_report
from repro.analysis.vmem import (DEFAULT_VMEM_BUDGET, LaunchVmem,
                                 launch_vmem)

__all__ = [
    "BoundsViolation", "check_bounds",
    "DtypeViolation", "check_kernel_dtypes",
    "HostLintFinding", "lint_serving", "lint_source",
    "LaunchCount", "PallasSite", "count_launches", "pallas_sites",
    "MATRIX", "EntrySpec", "TracedEntry", "trace_entry", "trace_matrix",
    "audit_entry", "run_audit", "write_report",
    "DEFAULT_VMEM_BUDGET", "LaunchVmem", "launch_vmem",
]
