"""Assemble the audit: run every checker over the traced matrix and
emit the structured ``AUDIT.json`` the CI gate
(``benchmarks/check_audit.py``) consumes.

Top-level shape::

    {
      "version": 1,
      "params":  {height, width, max_features, n_rigs, seq_len,
                  vmem_budget},
      "entries": [ {name, entry, precision, masked, localize, gates,
                    launch_budget,
                    launches: {static, trace_audit, bounded,
                               budget_ok, consistent},
                    vmem:   [ per-launch residency verdicts ],
                    dtype_violations:  [...],
                    bounds_violations: [...],
                    ok} ],
      "hostlint": {findings: [...], ok},
      "checks":  {launch_budget, launch_consistency, vmem, dtype,
                  bounds, hostlint},
      "ok": bool
    }

``launches.static`` is the jaxpr-walk count; ``launches.trace_audit``
is what the runtime ``ops.launch_audit`` counter saw during the same
abstract trace.  ``consistent`` (they agree) is checked HERE; equality
against the benchmark artifact's ``launch_gate/*`` rows is checked in
``benchmarks/check_audit.py`` where the artifact is available.
"""

from __future__ import annotations

import dataclasses
import json

from repro.analysis import bounds as bounds_mod
from repro.analysis import dtype_flow, hostlint
from repro.analysis import matrix as matrix_mod
from repro.analysis import vmem as vmem_mod

__all__ = ["audit_entry", "run_audit", "write_report"]


def _vmem_dict(v: vmem_mod.LaunchVmem, mult) -> dict:
    return {
        "kernel": v.kernel,
        "grid": list(v.grid),
        "mult": mult,
        "resident_bytes": v.resident_bytes,
        "resident_mib": round(v.resident_bytes / 2 ** 20, 3),
        "pipelined_bytes": v.pipelined_bytes,
        "budget": v.budget,
        "ok": v.ok,
        "blocks": [dataclasses.asdict(b) for b in v.blocks],
    }


def audit_entry(te: matrix_mod.TracedEntry,
                vmem_budget: int = vmem_mod.DEFAULT_VMEM_BUDGET) -> dict:
    """Run the launch / VMEM / dtype / bounds checkers over one traced
    entry."""
    spec = te.spec
    vmem = [_vmem_dict(vmem_mod.launch_vmem(s, vmem_budget), s.mult)
            for s in te.sites]
    dtype_v = [dataclasses.asdict(v) for s in te.sites
               for v in dtype_flow.check_kernel_dtypes(s)]
    bounds_v = [dataclasses.asdict(v) for s in te.sites
                for v in bounds_mod.check_bounds(s)]
    for v in bounds_v:
        v["grid_point"] = list(v["grid_point"])
    # The runtime audit counter fires once per pallas dispatch DURING
    # TRACING — a scan body traces once however many trips it runs — so
    # it must equal the number of discovered SITES; the static count
    # (trip multipliers applied) is what the budget bounds.
    launches = {
        "static": te.count.total,
        "sites": len(te.sites),
        "trace_audit": te.audit_count,
        "bounded": te.count.bounded,
        "budget_ok": (te.count.bounded
                      and te.count.total <= spec.launch_budget),
        "consistent": len(te.sites) == te.audit_count,
    }
    entry = {
        "name": spec.name,
        "entry": spec.entry,
        "precision": spec.precision,
        "masked": spec.masked,
        "localize": spec.localize,
        "gates": list(spec.gates),
        "launch_budget": spec.launch_budget,
        "note": spec.note,
        "launches": launches,
        "vmem": vmem,
        "dtype_violations": dtype_v,
        "bounds_violations": bounds_v,
    }
    entry["ok"] = (launches["budget_ok"] and launches["consistent"]
                   and all(v["ok"] for v in vmem)
                   and not dtype_v and not bounds_v)
    return entry


def run_audit(specs: tuple = matrix_mod.MATRIX,
              vmem_budget: int = vmem_mod.DEFAULT_VMEM_BUDGET,
              serving_root: str | None = None,
              **trace_kwargs) -> dict:
    """The full audit: trace the matrix, run every checker, lint the
    serving tier, and assemble the report dict."""
    entries = [audit_entry(te, vmem_budget) for te in
               matrix_mod.trace_matrix(specs, **trace_kwargs)]
    findings = hostlint.lint_serving(serving_root)
    lint = {"findings": [dataclasses.asdict(f) for f in findings],
            "ok": not findings}
    checks = {
        "launch_budget": all(e["launches"]["budget_ok"]
                             for e in entries),
        "launch_consistency": all(e["launches"]["consistent"]
                                  for e in entries),
        "vmem": all(v["ok"] for e in entries for v in e["vmem"]),
        "dtype": not any(e["dtype_violations"] for e in entries),
        "bounds": not any(e["bounds_violations"] for e in entries),
        "hostlint": lint["ok"],
    }
    params = {"vmem_budget": int(vmem_budget),
              "height": trace_kwargs.get("height", 720),
              "width": trace_kwargs.get("width", 1280),
              "max_features": trace_kwargs.get("max_features", 1000),
              "n_rigs": trace_kwargs.get("n_rigs", 2),
              "seq_len": trace_kwargs.get("seq_len", 2)}
    return {"version": 1, "params": params, "entries": entries,
            "hostlint": lint, "checks": checks,
            "ok": all(checks.values())}


def write_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=False)
        f.write("\n")
