"""The audited entry matrix: every ``VisualSystem`` entry point, traced
abstractly over entry × precision × masked × localize × fleet.

Each :class:`EntrySpec` names one program CI cares about, its launch
budget, and the ``launch_gate/*`` row names in ``BENCH_frontend.json``
whose runtime counts the static count must EQUAL (``restored_fleet``
reconciles against the plain fleet entry: a snapshot restore
repopulates state, never the launch graph — it dispatches the same
traced core).  ``trace_entry`` builds the session, makes the closed
jaxpr with ``jax.make_jaxpr`` over ``jax.ShapeDtypeStruct`` avals — no
data, no execution — and simultaneously runs the runtime
``ops.launch_audit`` counter so the report can prove the two agree.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_walk
from repro.core.pipeline import PipelineConfig, VisualSystem
from repro.core.rig import RigConfig
from repro.core.types import CameraIntrinsics, ORBConfig
from repro.kernels import ops

__all__ = ["EntrySpec", "TracedEntry", "MATRIX", "trace_entry",
           "trace_matrix"]


@dataclasses.dataclass(frozen=True)
class EntrySpec:
    """One audited program: which entry core, under which session
    configuration, with which launch budget, reconciled against which
    runtime gate rows."""

    name: str
    entry: str                   # VisualSystem.entry_core key
    precision: str = "f32"
    masked: bool = False
    localize: bool = False
    launch_budget: int = 3
    gates: tuple[str, ...] = ()
    note: str = ""


# Budgets: 3 per frame / fleet frame (1 dense FE + 1 sparse FE + 1
# fused FM), +1 with the localization backend, 1 for the FM stage
# alone, 2 for FE alone, 3 per scan step for sequences (seq_len=2
# below).  Gate names match benchmarks.run's launch_gate rows.
MATRIX: tuple[EntrySpec, ...] = (
    EntrySpec("frame_f32", "process_frame",
              gates=("quad_frame_launches",),
              note="one quad rig frame, f32 datapath"),
    EntrySpec("frame_f32_masked", "process_frame", masked=True,
              note="degraded rig frame: dead-camera mask is "
                   "elementwise jnp, same schedule"),
    EntrySpec("fleet_f32", "process_fleet",
              gates=("fleet_frame_launches",
                     "restored_fleet_frame_launches"),
              note="fleet frame; also reconciles the restored-service "
                   "gate — restore repopulates state, never the "
                   "launch graph"),
    EntrySpec("fleet_f32_masked", "process_fleet", masked=True,
              gates=("degraded_fleet_frame_launches",),
              note="fleet frame with dead cameras masked out"),
    EntrySpec("match_f32", "match", launch_budget=1,
              gates=("fm_frame_launches",),
              note="fused FM megakernel alone, both stereo pairs in "
                   "the grid"),
    EntrySpec("extract_f32", "extract", launch_budget=2,
              note="FE alone: 1 dense + 1 sparse launch"),
    EntrySpec("frame_u8", "process_frame", precision="uint8",
              gates=("u8_frame_launches",),
              note="uint8 integer datapath, same 3-launch schedule"),
    EntrySpec("fleet_u8", "process_fleet", precision="uint8",
              gates=("u8_fleet_frame_launches",),
              note="uint8 fleet frame"),
    EntrySpec("fleet_u8_masked", "process_fleet", precision="uint8",
              masked=True,
              note="uint8 degraded fleet frame"),
    EntrySpec("frame_loc", "process_frame", localize=True,
              launch_budget=4, gates=("loc_frame_launches",),
              note="localized frame: 3 frontend + 1 temporal-match "
                   "backend launch"),
    EntrySpec("fleet_loc", "process_fleet", localize=True,
              launch_budget=4, gates=("loc_fleet_frame_launches",),
              note="localized fleet frame: rigs fold into the one "
                   "temporal launch"),
    EntrySpec("run_f32", "run", launch_budget=6,
              note="T=2 sequence, sequential schedule: the scan body "
                   "multiplies the 3-launch frame"),
    EntrySpec("run_fleet_f32", "run_fleet", launch_budget=6,
              note="T=2 fleet sequence"),
)


@dataclasses.dataclass
class TracedEntry:
    """One matrix entry's abstract trace plus both launch counts: the
    static jaxpr-walk count and the runtime ``launch_audit`` counter
    observed during the same trace (internal cross-check — they must
    agree before either is compared to the benchmark artifact)."""

    spec: EntrySpec
    closed: jax.core.ClosedJaxpr
    sites: list[jaxpr_walk.PallasSite]
    count: jaxpr_walk.LaunchCount
    audit_count: int


def _session(spec: EntrySpec, height: int, width: int,
             max_features: int) -> VisualSystem:
    cfg = ORBConfig(height=height, width=width,
                    max_features=max_features)
    intr = CameraIntrinsics(cx=width / 2.0, cy=height / 2.0)
    return VisualSystem(
        RigConfig.quad(intr),
        PipelineConfig(orb=cfg, precision=spec.precision,
                       localize=spec.localize))


def _entry_avals(vs: VisualSystem, spec: EntrySpec, n_rigs: int,
                 seq_len: int) -> tuple:
    h, w = vs.pipe.orb.height, vs.pipe.orb.width
    c = vs.rig.n_cameras
    dt = jnp.uint8 if spec.precision == "uint8" else jnp.float32
    sds = jax.ShapeDtypeStruct
    if spec.entry == "process_frame":
        avals = (sds((c, h, w), dt),)
        if spec.masked:
            avals += (sds((c,), jnp.bool_),)
        return avals
    if spec.entry == "process_fleet":
        avals = (sds((n_rigs, c, h, w), dt),)
        if spec.masked:
            avals += (sds((n_rigs, c), jnp.bool_),)
        return avals
    if spec.entry == "extract":
        return (sds((c, h, w), dt),)
    if spec.entry == "match":
        # Feature avals come from the FE core's own abstract output —
        # the matrix never hand-writes FeatureSet shapes.
        feats = jax.eval_shape(vs.entry_core("extract"), sds((c, h, w), dt))
        p = vs.rig.n_pairs
        pair = jax.tree.map(
            lambda s: sds((p,) + s.shape[1:], s.dtype), feats)
        img = sds((p, h, w), dt)
        return (img, img, pair, pair)
    if spec.entry == "run":
        return (sds((seq_len, c, h, w), dt),)
    if spec.entry == "run_fleet":
        return (sds((seq_len, n_rigs, c, h, w), dt),)
    raise ValueError(f"unknown entry {spec.entry!r}")


def trace_entry(spec: EntrySpec, height: int = 720, width: int = 1280,
                max_features: int = 1000, n_rigs: int = 2,
                seq_len: int = 2) -> TracedEntry:
    """Abstractly trace one matrix entry under impl='pallas'."""
    vs = _session(spec, height, width, max_features)
    core = vs.entry_core(spec.entry, impl="pallas")
    avals = _entry_avals(vs, spec, n_rigs, seq_len)
    with ops.launch_audit() as audit:
        closed = jax.make_jaxpr(core)(*avals)
    return TracedEntry(
        spec=spec,
        closed=closed,
        sites=jaxpr_walk.pallas_sites(closed),
        count=jaxpr_walk.count_launches(closed),
        audit_count=audit.count)


def trace_matrix(specs: tuple[EntrySpec, ...] = MATRIX,
                 **kwargs) -> list[TracedEntry]:
    return [trace_entry(spec, **kwargs) for spec in specs]
