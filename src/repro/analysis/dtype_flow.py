"""Dtype-flow lint over kernel-body jaxprs: silent widenings.

The ``precision="uint8"`` datapath's whole claim is that slabs stay in
integer fixed-point end-to-end (uint8 pyramid, int32 blur accumulation,
int16 FAST scores, int8 descriptor selection) — a float32 intermediate
silently re-widening the working set would void the 4x VMEM cut while
every launch-count gate still passes.  This lint walks each traced
kernel BODY (the ``jaxpr`` param of the ``pallas_call`` eqn, including
nested ``pjit`` sub-jaxprs) and flags:

  * ``float64-leak`` — any float64 value anywhere, every precision:
    nothing in the pipeline is specified in double, so an f64 aval is
    always an accidental promotion (x64 mode would silently double
    every buffer);
  * ``float-in-integer-kernel`` — a floating-point intermediate inside
    a kernel whose operands (all input AND output blocks) are integer.
    Integer-in/integer-out is exactly where the fixed-point contract
    holds: any float aval between them is a silent widening (the class
    of bug where a literal ``0.5 * x`` sneaks into the int32 blur).
    Kernels with a legitimate float operand (descriptor theta/meta,
    depth) are exempt by construction — the contract is derived from
    the traced operand dtypes, not from a name list;
  * ``weak-float-promotion`` — the float intermediate is weakly typed
    (a bare python float literal promoted the lattice), reported as its
    own class because the fix is different: annotate the constant, not
    the op.
"""

from __future__ import annotations

import dataclasses

import jax.core as jcore
import jax.numpy as jnp

from repro.analysis.jaxpr_walk import PallasSite

__all__ = ["DtypeViolation", "check_kernel_dtypes"]


@dataclasses.dataclass(frozen=True)
class DtypeViolation:
    kernel: str
    rule: str                 # 'float64-leak' | 'float-in-integer-kernel'
    #                         | 'weak-float-promotion'
    dtype: str
    primitive: str            # eqn that produced the value ('invar' for
    #                         kernel inputs)
    detail: str


def _avals(jaxpr: jcore.Jaxpr):
    """Yield (aval, primitive_name) for every value produced in the
    kernel body, recursing into sub-jaxprs (pjit etc.)."""
    for var in jaxpr.invars + jaxpr.constvars:
        yield var.aval, "invar"
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            for var in eqn.outvars:
                yield var.aval, eqn.primitive.name
            for val in eqn.params.values():
                vals = val if isinstance(val, (tuple, list)) else (val,)
                for v in vals:
                    if isinstance(v, jcore.ClosedJaxpr):
                        stack.append(v.jaxpr)
                    elif isinstance(v, jcore.Jaxpr):
                        stack.append(v)


def _dtype_of(aval):
    # Works for ShapedArray and pallas MemRef avals alike; anything
    # without a dtype (tokens) is skipped.
    return getattr(aval, "dtype", None)


def _integer_contract(site: PallasSite) -> bool:
    """True when EVERY traced operand block (inputs and outputs) of the
    launch is integer/bool — the fixed-point contract then holds for
    the whole kernel body."""
    dtypes = [bm.array_shape_dtype.dtype
              for bm in site.grid_mapping.block_mappings]
    return bool(dtypes) and not any(
        jnp.issubdtype(d, jnp.floating) for d in dtypes)


def check_kernel_dtypes(site: PallasSite) -> list[DtypeViolation]:
    """All dtype-flow violations in one launch's kernel body."""
    out: list[DtypeViolation] = []
    int_only = _integer_contract(site)
    for aval, prim in _avals(site.kernel_jaxpr):
        dt = _dtype_of(aval)
        if dt is None:
            continue
        if dt == jnp.float64:
            out.append(DtypeViolation(
                site.name, "float64-leak", str(dt), prim,
                "float64 value traced inside a kernel — nothing in the "
                "pipeline is specified in double precision"))
            continue
        if int_only and jnp.issubdtype(dt, jnp.floating):
            weak = bool(getattr(aval, "weak_type", False))
            rule = ("weak-float-promotion" if weak
                    else "float-in-integer-kernel")
            detail = (
                "weakly-typed float (bare python literal) promoted "
                "inside an all-integer kernel — annotate the constant"
                if weak else
                "float intermediate in a kernel whose operands are all "
                "integer: the fixed-point contract is silently widened")
            out.append(DtypeViolation(site.name, rule, str(dt), prim,
                                      detail))
    return out
