"""ShapeDtypeStruct input stand-ins + per-cell step builders.

``input_specs`` produces weak-type-correct, shardable stand-ins for
every model input of a (config x shape) cell — no device allocation, so
the FULL production configs lower AOT on one CPU.  ``build_cell``
returns (step_fn, arg_specs, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as Ps

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import Rules, resolve, use_sharding
from repro.models import lm, transformer
from repro.models.params import abstract_params, param_specs
from repro.optim import AdamWConfig
from repro.training.steps import TrainState, make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def cell_rules(cfg: ModelConfig, cell: ShapeCell,
               sharding_mode: str = "fsdp_tp",
               seq_parallel: bool | None = None) -> Rules:
    """Per-cell logical->mesh rules (the sharding *policy*).

    - train/prefill: batch over (pod, data); TP over model; optional
      context parallelism (q-seq over model) when heads cannot shard
      (resolver ordering makes seq win only when it is enabled).
    - decode: KV-cache seq over model (flash-decode style: XLA
      partitions the softmax reductions); batch over (pod, data).
    - long_500k (batch 1): cache seq over (data, model) — the whole
      mesh splits one sequence's cache.
    """
    tp = 16
    heads_shardable = cfg.n_heads > 0 and cfg.n_heads % tp == 0
    if seq_parallel is None:
        seq_parallel = not heads_shardable     # CP fallback
    extra = {"capacity": ("data",)} if cfg.moe_cap_data else {}
    if cell.kind in ("train", "prefill"):
        return Rules.make(
            sharding_mode=sharding_mode,
            seq_axes=("model",) if seq_parallel else (),
            cache_seq_axes=(), extra_acts=extra)
    # decode
    cache_axes = ("data", "model") if cell.global_batch == 1 \
        else ("model",)
    return Rules.make(sharding_mode=sharding_mode, seq_axes=(),
                      cache_seq_axes=cache_axes, extra_acts=extra)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Model-input stand-ins for one cell (the spec's ``input_specs``)."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            text = s - cfg.vlm_prefix
            return {"tokens": sds((b, text), jnp.int32),
                    "patches": sds((b, cfg.vlm_prefix, cfg.d_model),
                                   jnp.float32)}
        if cfg.family == "encdec":
            half = s // 2
            return {"tokens": sds((b, half), jnp.int32),
                    "frames": sds((b, half, cfg.d_model), jnp.float32)}
        return {"tokens": sds((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache
    return {"token": sds((b, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract decode cache for the cell (ShapeDtypeStructs)."""
    b, s = cell.global_batch, cell.seq_len
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, dtype=dtype))
    if cfg.family == "encdec":
        # cross K/V covers the source half
        cache = dict(cache)
        for k in ("cross_k", "cross_v"):
            old = cache[k]
            cache[k] = sds((*old.shape[:-2], s // 2, old.shape[-1]),
                           old.dtype)
    return cache


def batch_axes(cfg: ModelConfig, specs: dict) -> dict:
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "token"):
            out[k] = ("batch", "seq")
        elif k == "patches":
            out[k] = ("batch", "seq", "act_embed")
        elif k == "frames":
            out[k] = ("batch", "seq", "act_embed")
        else:
            raise KeyError(k)
    return out


def cache_axes(cfg: ModelConfig, cache: dict) -> dict:
    """Logical axes per cache entry (trees under ssm keys handled)."""
    def kv_ax(ndim):
        # (layers?, B, kv, S, D)
        base = ("batch", "kv_heads", "cache_seq", "head_dim")
        return ("layers",) * (ndim - 4) + base

    out = {}
    for k, v in cache.items():
        if k in ("k", "v", "shared_k", "shared_v", "first_k", "first_v",
                 "cross_k", "cross_v"):
            out[k] = kv_ax(v.ndim)
        elif k in ("ssm", "groups", "tail"):
            # state dicts: h (L.., B, H, N, P); conv_* (L.., B, K-1, C)
            bases = {"h": ("batch", "ssm_heads", "ssm_state", "head_dim"),
                     "conv_x": ("batch", None, "conv_dim"),
                     "conv_B": ("batch", None, "ssm_state"),
                     "conv_C": ("batch", None, "ssm_state")}
            out[k] = {
                name: ("layers",) * (leaf.ndim - len(bases[name]))
                + bases[name]
                for name, leaf in v.items()}
        else:
            raise KeyError(k)
    return out


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    cell: ShapeCell
    step_fn: Any
    arg_specs: tuple
    in_shardings: tuple
    out_shardings: Any
    rules: Rules
    meta: dict


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh,
               sharding_mode: str = "fsdp_tp",
               seq_parallel: bool | None = None,
               opt_cfg: AdamWConfig | None = None) -> Cell:
    """Assemble the jit-able step + shardings for one dry-run cell."""
    rules = cell_rules(cfg, cell, sharding_mode, seq_parallel)
    schema = lm.model_schema(cfg)
    with use_sharding(mesh, rules):
        p_specs = param_specs(schema)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    # serving runs bf16 weights (production norm — halves FSDP-gather
    # wire bytes and avoids f32<->bf16 convert round-trips); training
    # keeps f32 master params
    p_dtype = jnp.float32 if cell.kind == "train" else jnp.bfloat16
    params_abs = abstract_params(schema, p_dtype)
    ins = input_specs(cfg, cell)
    in_ax = batch_axes(cfg, ins)
    with use_sharding(mesh, rules):
        in_shard = jax.tree.map(
            lambda l, a: NamedSharding(
                mesh, resolve(rules.acts, a[:l.ndim], l.shape, mesh)),
            ins, in_ax)

    meta = {"arch": cfg.arch_id, "cell": cell.name, "kind": cell.kind,
            "seq": cell.seq_len, "batch": cell.global_batch,
            "mode": sharding_mode}

    if cell.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        step = make_train_step(cfg, opt_cfg)
        opt_abs = {
            "mu": params_abs, "nu": params_abs,
            "step": sds((), jnp.int32)}
        state_abs = TrainState(params_abs, opt_abs)
        opt_shard = {"mu": p_shard, "nu": p_shard,
                     "step": NamedSharding(mesh, Ps())}
        state_shard = TrainState(p_shard, opt_shard)

        def fn(state, batch):
            with use_sharding(mesh, rules):
                return step(state, batch)

        return Cell(cfg, cell, fn, (state_abs, ins),
                    (state_shard, in_shard),
                    (state_shard, None), rules, meta)

    if cell.kind == "prefill":
        def fn(params, batch):
            with use_sharding(mesh, rules):
                return lm.prefill(params, cfg, batch)

        return Cell(cfg, cell, fn, (params_abs, ins),
                    (p_shard, in_shard), None, rules, meta)

    # decode
    cache_abs = cache_specs(cfg, cell)
    c_ax = cache_axes(cfg, cache_abs)
    with use_sharding(mesh, rules):
        cache_shard = jax.tree.map(
            lambda l, a: NamedSharding(
                mesh, resolve(rules.acts, a[:l.ndim], l.shape, mesh)),
            cache_abs, c_ax, is_leaf=lambda x: isinstance(
                x, jax.ShapeDtypeStruct))
    pos_abs = sds((), jnp.int32)

    def fn(params, token, cache, pos):
        with use_sharding(mesh, rules):
            return lm.decode_step(params, cfg, token, cache, pos)

    return Cell(cfg, cell, fn,
                (params_abs, ins["token"], cache_abs, pos_abs),
                (p_shard, in_shard["token"], cache_shard,
                 NamedSharding(mesh, Ps())),
                (None, cache_shard), rules, meta)
