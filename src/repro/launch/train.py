"""Fault-tolerant training driver.

Run:  PYTHONPATH=src python -m repro.launch.train --arch gemma_7b \
          --smoke --steps 200 --ckpt-dir /tmp/ckpt

Fault-tolerance contract (tested in tests/test_substrate.py):
  * checkpoint every --ckpt-every steps, atomic rename (a crash mid-save
    can't corrupt the latest complete step);
  * the data pipeline is a pure function of (seed, step) — nothing
    stateful to restore;
  * on start, resume from the newest complete checkpoint (crash/restart
    or preemption = re-exec the same command);
  * restart is bitwise identical to an uninterrupted run;
  * --mesh-shape may differ across restarts (elastic re-mesh): restore
    reshards onto the current mesh via the divisibility-aware resolver.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenDataConfig, get_batch
from repro.distributed.sharding import Rules, use_sharding
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.params import init_params, param_specs
from repro.optim import AdamWConfig
from repro.training.steps import (TrainState, make_train_step,
                                  train_state_init)
from jax.sharding import NamedSharding


def train_loop(cfg, data_cfg: TokenDataConfig, opt_cfg: AdamWConfig,
               mesh, steps: int, ckpt_dir: str | None = None,
               ckpt_every: int = 50, log_every: int = 10,
               fail_at: int | None = None):
    """Returns (state, history).  ``fail_at`` raises mid-run to exercise
    the crash/restart path in tests."""
    rules = Rules.make("fsdp_tp" if "model" in mesh.axis_names else "tp")
    schema = lm.model_schema(cfg)
    with use_sharding(mesh, rules):
        p_specs = param_specs(schema)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

    start = checkpoint.latest_step(ckpt_dir) if ckpt_dir else None
    if start is not None:
        from repro.models.params import abstract_params
        import jax.numpy as jnp
        p_abs = abstract_params(schema)
        like = TrainState(p_abs, {
            "mu": p_abs, "nu": p_abs,
            "step": jax.ShapeDtypeStruct((), jnp.int32)})
        host = checkpoint.restore_array_tree(ckpt_dir, start, like)
        state = jax.tree.map(jax.numpy.asarray, host)
        state = jax.device_put(state, TrainState(
            p_shard, {"mu": p_shard, "nu": p_shard,
                      "step": NamedSharding(
                          mesh, jax.sharding.PartitionSpec())}))
    else:
        start = 0
        params = init_params(schema, jax.random.key(0))
        params = jax.device_put(params, p_shard)
        state = train_state_init(params)

    raw_step = make_train_step(cfg, opt_cfg)

    def stepped(state, batch):
        with use_sharding(mesh, rules):
            return raw_step(state, batch)

    step_jit = jax.jit(stepped, donate_argnums=(0,))

    history = []
    for s in range(start, steps):
        if fail_at is not None and s == fail_at:
            raise RuntimeError(f"injected failure at step {s}")
        batch = get_batch(data_cfg, s)
        t0 = time.time()
        state, metrics = step_jit(state, batch)
        loss = float(metrics["loss"])
        history.append((s, loss))
        if s % log_every == 0:
            print(f"step {s:5d} loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
        if ckpt_dir and (s + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_dir, s + 1, state)
    if ckpt_dir:
        checkpoint.save(ckpt_dir, steps, state)
    return state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-parallel", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    data_cfg = TokenDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    _, hist = train_loop(cfg, data_cfg, opt_cfg, mesh, args.steps,
                         args.ckpt_dir, args.ckpt_every)
    losses = [l for _, l in hist]
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
