"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device;
only launch/dryrun.py forces the 512-device placeholder platform).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over however many (host) devices exist — used by the
    integration tests and the examples, never by the dry-run."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def host_fault_domains(mesh, axis: str = "data") -> tuple[str, ...]:
    """Name one host fault domain per index along ``axis``: the unit of
    failure for multi-host failover is a serving HOST (every chip behind
    one index of the sharded rig axis dies together), not a chip.
    ``serving.failover.HostMap`` assigns rigs to these domain ids and
    redistributes them when one goes down.

    Works for both concrete ``Mesh`` and ``AbstractMesh`` (tests run on
    one CPU device; the domain NAMES are what the failover layer keys
    on, not the devices behind them).
    """
    sizes = dict(mesh.shape)
    if axis not in sizes:
        raise ValueError(
            f"host_fault_domains: mesh has no axis {axis!r} "
            f"(axes: {tuple(sizes)})")
    return tuple(f"host{i}" for i in range(int(sizes[axis])))


def domain_devices(mesh, axis: str = "data") -> dict[str, tuple]:
    """Map each fault domain id from ``host_fault_domains`` to the
    devices it owns (requires a concrete mesh)."""
    import numpy as np
    names = host_fault_domains(mesh, axis)
    ax = tuple(mesh.axis_names).index(axis)
    dev = np.moveaxis(np.asarray(mesh.devices), ax, 0)
    return {name: tuple(dev[i].ravel().tolist())
            for i, name in enumerate(names)}


# TPU v5e hardware constants for the roofline terms (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~per-device collective bw)
HBM_BYTES = 16 * 1024**3        # 16 GiB HBM per chip
