"""HLO-text statistics for the roofline terms.

``compiled.cost_analysis()`` visits a while body ONCE, so scanned-layer
programs undercount FLOPs by ~n_layers (verified empirically).  We
therefore parse the compiled HLO text:

  * per-computation symbol tables (instruction -> result shape),
  * dot/convolution FLOPs from result shape x contracted dims,
  * collective result bytes per op kind,
  * the call graph (fusion calls / to_apply / while bodies),
  * while trip counts from XLA's ``known_trip_count`` backend_config
    (fallback: the constant in the loop-condition compare),
  * execution multipliers: an op inside a 48-deep layer scan counts 48x
    (nested loops multiply).

All sizes are PER-DEVICE (the module is the per-partition program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^\s*(?:\([^)]*\)|[\w\[\],{}]+)*\s*([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_of(text: str):
    """First shape literal: (dtype, dims tuple) or (None, ())."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None, ()
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return dt, shape


def _nbytes(dt, shape) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = _DTYPE_BYTES[dt]
    for d in shape:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    rest: str               # text after "name ="
    shape: tuple            # (dtype, dims)
    operands: list          # operand instruction names
    is_root: bool = False
    calls_cast: bool = False  # fusion classified as a cast artifact


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list
    table: dict             # name -> (dtype, dims)
    whiles: list            # (body, cond, trip or None)
    calls: list
    by_name: dict = dataclasses.field(default_factory=dict)


def parse_hlo(text: str) -> dict:
    comps: dict = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line.strip())
        if h:
            cur = Computation(h.group(2), bool(h.group(1)), [], {}, [], [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, rest = im.groups()
        om = _OP_RE.match(rest)
        # fallback: first "token(" occurrence
        op = om.group(1) if om else ""
        if not op:
            toks = re.findall(r"([a-z][a-z0-9\-]*)\(", rest)
            op = toks[0] if toks else ""
        shape = _shape_of(rest)
        # operand names: inside the first (...) after the op
        operands = []
        pm = re.search(re.escape(op) + r"\(([^)]*)\)", rest) if op else None
        if pm:
            operands = re.findall(r"%([\w\.\-]+)", pm.group(1))
        ins = Instr(name, op, rest, shape, operands,
                    is_root=line.lstrip().startswith("ROOT"))
        cur.instrs.append(ins)
        cur.table[name] = shape
        cur.by_name[name] = ins
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            cm = re.search(r"condition=%?([\w\.\-]+)", rest)
            tm = _TRIP_RE.search(rest)
            if bm and cm:
                cur.whiles.append((bm.group(1), cm.group(1),
                                   int(tm.group(1)) if tm else None))
        else:
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                  rest):
                cur.calls.append(cm.group(1))
            ccm = re.search(r"called_computations=\{([^}]*)\}", rest)
            if ccm:
                cur.calls.extend(re.findall(r"%?([\w\.\-]+)",
                                            ccm.group(1)))
    # second pass: mark cast-artifact fusions (operand deref needs it)
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                kind, _ = _classify_fusion(ins, comps)
                ins.calls_cast = kind == "cast"
    return comps


def _cond_trip(comps: dict, cond_name: str) -> int:
    """Fallback trip count: the constant compared against in the cond
    (searches the cond and its called fusions)."""
    seen = set()

    def consts_and_compare(name):
        comp = comps.get(name)
        if comp is None or name in seen:
            return None
        seen.add(name)
        consts = {}
        for ins in comp.instrs:
            cm = re.search(r"constant\((\d+)\)", ins.rest)
            if cm:
                consts[ins.name] = int(cm.group(1))
        for ins in comp.instrs:
            if ins.op == "compare":
                for a in ins.operands:
                    if a in consts:
                        return consts[a]
        for c in comp.calls:
            r = consts_and_compare(c)
            if r:
                return r
        return None

    return consts_and_compare(cond_name) or 1


def dot_flops(ins: Instr, table: dict) -> int:
    out_dt, out_shape = ins.shape
    out_n = 1
    for d in out_shape:
        out_n *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    k = 1
    if cm and ins.operands:
        lhs = table.get(ins.operands[0], (None, ()))[1]
        for i in (int(x) for x in cm.group(1).split(",") if x):
            if i < len(lhs):
                k *= lhs[i]
    return 2 * out_n * k


def conv_flops(ins: Instr, table: dict) -> int:
    out_n = 1
    for d in ins.shape[1]:
        out_n *= d
    k_n = 1
    if len(ins.operands) >= 2:
        rhs = table.get(ins.operands[1], (None, ()))[1]
        for d in rhs:
            k_n *= d
    return 2 * out_n * k_n


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: int = 0
    while_trips: dict = dataclasses.field(default_factory=dict)
    dot_bytes: float = 0.0          # result bytes of dots
    hbm_bytes: float = 0.0          # materialized-buffer write proxy


# view-like / zero-traffic ops excluded from the HBM traffic proxy.
# "copy" is also excluded: on this CPU backend the while-loop carries
# are copy-double-buffered, which a TPU executable aliases in place —
# counting them would charge phantom traffic to every scanned layer.
# "convert" is excluded: the CPU backend legalizes every bf16 dot to
# convert->f32-dot; a TPU MXU reads bf16 natively.  Consumers of a
# convert dereference to the source tensor's bytes instead.
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "after-all",
               "partition-id", "replica-id", "iota", "copy", "convert"}

# ops a cast/view fusion may contain and still count as "no real compute"
_VIEWLIKE = {"parameter", "constant", "convert", "bitcast", "copy",
             "reshape", "transpose", "broadcast", "slice",
             "dynamic-slice", "dynamic-update-slice", "select",
             "select-n", "compare", "add", "subtract", "multiply",
             "divide", "iota", "concatenate", "pad", "and", "or", "not",
             "clamp", "maximum", "minimum", "lt", "gte"}


def _bf16_equiv(shape) -> int:
    dt, dims = shape
    n = 2
    for d in dims:
        n *= d
    return n if dt else 0


def _deref_bytes(name: str, comp: "Computation", comps: dict) -> int:
    """Bytes an operand costs to READ, dereferencing convert artifacts
    (use the pre-convert source size — TPU reads bf16 natively)."""
    ins = comp.by_name.get(name) if hasattr(comp, "by_name") else None
    shape = comp.table.get(name, (None, ()))
    if ins is None:
        return _nbytes(*shape)
    if ins.op == "convert" and ins.operands:
        return _nbytes(*comp.table.get(ins.operands[0], (None, ())))
    if ins.op == "fusion" and ins.calls_cast:
        return _bf16_equiv(shape)
    return _nbytes(*shape)


def _classify_fusion(ins: Instr, comps: dict):
    """(kind, payload): 'dus' -> update bytes; 'cast' -> bf16-equiv
    result; 'compute' -> None."""
    called = None
    import re as _re
    m = _re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    if m:
        called = comps.get(m.group(1))
    if called is None:
        return "compute", None
    ops = {i.op for i in called.instrs}
    if ops <= {"parameter", "convert", "bitcast", "copy"} \
            and "convert" in ops:
        # pure dtype-conversion fusion: CPU bf16-dot legalization; a
        # TPU MXU reads bf16 natively — consumers charge their reads
        return "pure_cast", 0
    if ops <= _VIEWLIKE:
        # a viewlike-only fusion containing a DUS is an in-place cache
        # write (possibly wrapped in carry-dtype converts): charge the
        # update window(s), not the buffer
        dus = [i for i in called.instrs
               if i.op == "dynamic-update-slice" and len(i.operands) > 1]
        if dus:
            upd = max(_nbytes(*called.table.get(i.operands[1],
                                                (None, ())))
                      for i in dus)
            return "dus", upd
        if "convert" in ops:
            return "cast", None
    return "compute", None


def _traffic_bytes(ins: Instr, comp: "Computation", comps: dict) -> int:
    """HBM traffic proxy for one scheduled instruction (reads+writes),
    corrected for CPU-backend legalization artifacts."""
    if ins.op in _NO_TRAFFIC:
        return 0
    if ins.op == "dynamic-slice":
        return 2 * _nbytes(*ins.shape)
    if ins.op == "dynamic-update-slice":
        upd = comp.table.get(ins.operands[1], (None, ())) \
            if len(ins.operands) > 1 else (None, ())
        return 2 * _nbytes(*upd)
    if ins.op == "fusion":
        kind, payload = _classify_fusion(ins, comps)
        if kind == "dus":
            return 2 * payload
        if kind == "pure_cast":
            return 0
        if kind == "cast":
            # one slice-read + write at native (bf16) width
            return 2 * _bf16_equiv(ins.shape)
    total = _nbytes(*ins.shape)
    for o in ins.operands:
        total += _deref_bytes(o, comp, comps)
    return total


def analyze(text: str) -> ModuleStats:
    comps = parse_hlo(text)
    stats = ModuleStats()
    entries = [c.name for c in comps.values() if c.is_entry]
    if not entries:
        called = set()
        for c in comps.values():
            called.update(c.calls)
            for b, cn, _ in c.whiles:
                called.update((b, cn))
        entries = [n for n in comps if n not in called]

    def walk(name: str, mult: float, depth: int, scheduled: bool):
        """scheduled=True for entry/while-body computations, whose
        instruction results are materialized buffers; fusion-called
        computations contribute FLOPs but not HBM writes."""
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                stats.flops += mult * dot_flops(ins, comp.table)
                stats.dot_bytes += mult * _nbytes(*ins.shape)
            elif op == "convolution":
                stats.flops += mult * conv_flops(ins, comp.table)
            else:
                base = op[:-6] if op.endswith("-start") else op
                if base in COLLECTIVES:
                    nb = mult * _nbytes(*ins.shape)
                    stats.collective_bytes += nb
                    stats.per_collective[base] += nb
                    stats.collective_count += 1
            if scheduled and not op.endswith("-done"):
                stats.hbm_bytes += mult * _traffic_bytes(ins, comp,
                                                         comps)
        for b, cn, trip in comp.whiles:
            t = trip if trip is not None else _cond_trip(comps, cn)
            stats.while_trips[b] = t
            walk(b, mult * t, depth + 1, True)
            walk(cn, mult, depth + 1, False)
        for cname in comp.calls:
            walk(cname, mult, depth + 1, False)

    for e in entries:
        walk(e, 1.0, 0, True)
    return stats
