import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init); smoke tests and benches never import this
# module, so they keep seeing the single real CPU device.

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import ARCH_IDS, get_config, shape_cells  # noqa: E402
from repro.launch import hlo_stats, specs as specs_mod       # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models import lm  # noqa: E402
from repro.models.params import P  # noqa: E402

"""Multi-pod dry-run: AOT lower + compile every (architecture x shape)
cell on the production meshes, and extract the roofline terms from the
compiled artifact.  No arrays are allocated — inputs are
ShapeDtypeStructs and parameters are abstract.

  single-pod: (16, 16) over ("data", "model")        = 256 chips
  multi-pod:  (2, 16, 16) over ("pod","data","model") = 512 chips

Per cell we record: memory_analysis (proves it fits), per-device HLO
FLOPs / HBM-write proxy / collective bytes (loop-aware, see
hlo_stats.py), the three roofline terms, the dominant term, and
MODEL_FLOPS / HLO_FLOPs (useful-compute ratio).
"""


def count_params_split(cfg) -> dict:
    """(embed, expert, other) parameter counts from the schema axes."""
    schema = lm.model_schema(cfg)
    counts = {"embed": 0, "expert": 0, "other": 0}

    def walk(node, path):
        for k, v in node.items():
            if isinstance(v, P):
                n = 1
                for s in v.shape:
                    n *= s
                if "vocab" in v.axes:
                    counts["embed"] += n
                elif "experts" in v.axes:
                    counts["expert"] += n
                else:
                    counts["other"] += n
            else:
                walk(v, path + "/" + k)

    walk(schema, "")
    return counts


def model_flops(cfg, cell) -> float:
    """6·N_active·D train / 2·N_active·D serve (N excludes embeddings,
    MoE experts scaled by top_k/E + shared)."""
    c = count_params_split(cfg)
    n_active = c["other"]
    if cfg.n_experts:
        n_active += c["expert"] * cfg.top_k / cfg.n_experts
    # embedding lookup is gather (no matmul flops); lm head IS a matmul
    n_active += cfg.d_model * cfg.vocab_padded
    tokens = cell.global_batch * cell.seq_len
    if cfg.family == "encdec":
        # each token passes one of the two stacks: src half through the
        # encoder, tgt half through the decoder (N counts both stacks)
        tokens = tokens // 2
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch          # decode: 1 token


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             sharding_mode: str = "fsdp_tp",
             seq_parallel: bool | None = None,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = next(c for c in shape_cells(cfg) if c.name == cell_name)
    rec = {"arch": arch, "cell": cell_name, "kind": cell.kind,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "mode": sharding_mode, "ok": False}
    if not cell.applicable:
        rec.update(skipped=True, skip_reason=cell.skip_reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        t0 = time.time()
        built = specs_mod.build_cell(cfg, cell, mesh, sharding_mode,
                                     seq_parallel)
        jit_kwargs = dict(in_shardings=built.in_shardings)
        if built.out_shardings is not None:
            jit_kwargs["out_shardings"] = built.out_shardings
        # donation (production norm): train donates the state, decode
        # donates the cache — removes the functional-update copy
        if cell.kind == "train":
            jit_kwargs["donate_argnums"] = (0,)
        elif cell.kind == "decode":
            jit_kwargs["donate_argnums"] = (2,)
        lowered = jax.jit(built.step_fn, **jit_kwargs).lower(
            *built.arg_specs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        txt = compiled.as_text()
        st = hlo_stats.analyze(txt)
        ca = compiled.cost_analysis() or {}

        # wire-volume weights: a ring all-reduce moves ~2x its payload
        # per device; AG/RS/A2A/permute move ~1x
        wire = sum(v * (2.0 if k == "all-reduce" else 1.0)
                   for k, v in st.per_collective.items())
        per_dev = {
            "flops": st.flops,
            "hbm_bytes": st.hbm_bytes,
            "collective_bytes": st.collective_bytes,
            "collective_wire_bytes": wire,
        }
        terms = {
            "compute_s": st.flops / PEAK_FLOPS_BF16,
            "memory_s": st.hbm_bytes / HBM_BW,
            "collective_s": wire / ICI_BW,
        }
        dominant = max(terms, key=terms.get)
        mf = model_flops(cfg, cell)
        hlo_global = st.flops * n_chips
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            n_chips=n_chips,
            memory_analysis={
                "arg_bytes": mem.argument_size_in_bytes,
                "out_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                # donated buffers alias in place on TPU; the CPU backend
                # additionally copy-double-buffers while carries, which
                # `alias` corrects for
                "fits_16g": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes) < 16 * 1024**3,
            },
            per_device=per_dev,
            per_collective={k: v for k, v in st.per_collective.items()},
            cost_analysis_flops=float(ca.get("flops", 0.0)),
            terms_s=terms,
            dominant=dominant,
            model_flops=mf,
            useful_ratio=(mf / hlo_global) if hlo_global else 0.0,
            step_time_bound_s=max(terms.values()),
        )
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec.update(error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--cell", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="fsdp_tp",
                    choices=["tp", "fsdp_tp"])
    ap.add_argument("--seq-parallel", default=None,
                    choices=[None, "on", "off"])
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb knob)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    cells = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
             if args.cell == "all" else [args.cell])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    sp = {None: None, "on": True, "off": False}[args.seq_parallel]
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                rec = run_cell(arch, cell, mp, args.mode, sp,
                               overrides or None)
                tag = f".{args.tag}" if args.tag else ""
                fname = (f"{arch}.{cell}."
                         f"{'multi' if mp else 'single'}{tag}.json")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec["ok"] else "FAIL")
                print(f"{status:4s} {arch:24s} {cell:12s} "
                      f"{rec['mesh']:8s} "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"dominant={rec.get('dominant', rec.get('error'))}",
                      flush=True)


if __name__ == "__main__":
    main()
