"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]

Prints the §Dry-run summary and the §Roofline table (single-pod) as
markdown; EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d: str, tag: str | None = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        parts = os.path.basename(f)[:-5].split(".")
        if tag is None and len(parts) != 3:
            continue
        if tag is not None and (len(parts) != 4 or parts[3] != tag):
            continue
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs) -> str:
    out = ["| arch | cell | mesh | status | compile s | per-dev args+temp-alias GiB | fits 16G |",
           "|---|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], CELL_ORDER.index(r["cell"]),  # noqa: E731
                     r["mesh"])
    for r in sorted(recs, key=key):
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                       f"SKIP ({r['skip_reason'][:40]}…) | | | |")
            continue
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                       f"FAIL {r.get('error', '')[:60]} | | | |")
            continue
        m = r["memory_analysis"]
        eff = (m["arg_bytes"] + m["temp_bytes"]
               - m.get("alias_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | OK | "
            f"{r['compile_s']} | {fmt_bytes(eff)} | "
            f"{'yes' if m['fits_16g'] else 'NO'} |")
    return "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| arch | cell | compute s | memory s | collective s | "
           "dominant | model TFLOPs | useful ratio | bound s |",
           "|---|---|---|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], CELL_ORDER.index(r["cell"]))  # noqa: E731
    for r in sorted([r for r in recs if r["mesh"] == "16x16"], key=key):
        if r.get("skipped") or not r.get("ok"):
            continue
        t = r["terms_s"]
        out.append(
            f"| {r['arch']} | {r['cell']} | {t['compute_s']:.4g} | "
            f"{t['memory_s']:.4g} | {t['collective_s']:.4g} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['model_flops'] / 1e12:.3g} | "
            f"{r['useful_ratio']:.3f} | {r['step_time_bound_s']:.4g} |")
    return "\n".join(out)


def summary(recs) -> str:
    live = [r for r in recs if not r.get("skipped")]
    ok = [r for r in live if r.get("ok")]
    skip = [r for r in recs if r.get("skipped")]
    lines = [f"cells: {len(recs)} total = {len(ok)} OK + "
             f"{len(live) - len(ok)} FAIL + {len(skip)} documented skips"]
    for r in live:
        if not r.get("ok"):
            lines.append(f"  FAIL {r['arch']} {r['cell']} {r['mesh']}: "
                         f"{r.get('error', '')[:100]}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    print("## Summary\n")
    print(summary(recs))
    print("\n## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16x16, TPU v5e terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
