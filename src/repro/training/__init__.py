"""Training substrate: jit-able train steps (plain + compressed-DP)."""

from repro.training.steps import (TrainState, make_compressed_train_step,
                                  make_train_step, train_state_init)

__all__ = ["TrainState", "make_compressed_train_step", "make_train_step",
           "train_state_init"]
