"""Train steps.

``make_train_step``: the production step — loss + grad + AdamW; under
pjit the DP gradient reduction is emitted by SPMD autodiff and overlaps
with the backward per-layer (scanned layers + latency-hiding scheduler).

``make_compressed_train_step``: the int8-wire variant — shard_map over
the DP axis computes UNREDUCED per-shard gradients, syncs them with the
compressed ring all-reduce (distributed/compression.py), then applies
the optimizer identically on every shard.  Supported for replicated-
parameter (pure-DP) meshes; the word-length idea of the paper applied
to gradient traffic."""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as Ps

from repro.configs.base import ModelConfig
from repro.distributed import compression
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: dict


def train_state_init(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """Returns step(state, batch) -> (state, metrics)."""

    def step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(
            state.params)
        params, opt, opt_m = adamw_update(opt_cfg, grads, state.opt,
                                          state.params)
        metrics = dict(metrics, loss=loss, **opt_m)
        return TrainState(params, opt), metrics

    return step


def make_compressed_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                               mesh: Mesh, axis: str = "data"):
    """Pure-DP step with int8-ring gradient sync (params replicated)."""
    n = mesh.shape[axis]

    def step(state: TrainState, batch: dict):
        p_spec = jax.tree.map(lambda _: Ps(), state.params)
        b_spec = jax.tree.map(lambda _: Ps(axis), batch)

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(p_spec, b_spec),
                           out_specs=(p_spec, Ps()),
                           check_rep=False)
        def local_grads(params, local_batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, local_batch),
                has_aux=True)(params)
            # per-shard gradients, NOT psum'd — sync happens compressed
            return grads, jax.lax.pmean(loss, axis)

        grads, loss = local_grads(state.params, batch)
        grads = compression.compressed_psum(grads, mesh, axis)
        grads = jax.tree.map(lambda g: g / n, grads)
        params, opt, opt_m = adamw_update(opt_cfg, grads, state.opt,
                                          state.params)
        return TrainState(params, opt), dict(loss=loss, **opt_m)

    return step
