"""Trajectory-error metrics against scene ground truth (host float64).

The accuracy gates compare an estimated ``PoseSet`` sequence (from
``VisualSystem.run(localize=...)`` or a ``process_frame`` loop) against
the ground-truth rig poses ``data.scenes.render_sequence`` returns.
All arithmetic here is NUMPY FLOAT64 ON HOST: the metric is the judge
of the f32/uint8 datapaths, so it must not share their rounding.

Conventions: an estimated ``PoseSet`` row t maps frame t-1 rig coords
into frame t (``p_t = R @ p_{t-1} + t_rel``); row 0 is the
identity/invalid first frame.  Ground-truth poses are ``(R, t)`` with R
rig->world and t the world position.  ATE is the RMSE of integrated
positions expressed in the start frame (both trajectories start at the
origin with identity heading, so no Umeyama alignment is needed); RPE
is the per-step RMSE of relative translation and rotation-angle error.
"""

from __future__ import annotations

import numpy as np


def _as_np(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def integrate_relative(rotations, translations) -> tuple[np.ndarray,
                                                         np.ndarray]:
    """Chain relative poses into start-frame world poses.

    ``rotations`` (T, 3, 3) / ``translations`` (T, 3): row t is the
    t-1 -> t relative pose (row 0 is ignored — it has no predecessor).
    Returns (positions (T, 3), headings (T, 3, 3)): standard VO
    composition ``R_w <- R_w @ R_rel^T``, ``p <- p - R_w @ t_rel``.
    An invalid (identity) step simply freezes the trajectory — the
    honest failure mode the gates measure, never a crash."""
    rot = _as_np(rotations)
    tr = _as_np(translations)
    t_total = rot.shape[0]
    pos = np.zeros((t_total, 3))
    head = np.zeros((t_total, 3, 3))
    r_w = np.eye(3)
    head[0] = r_w
    for t in range(1, t_total):
        r_w = r_w @ rot[t].T
        pos[t] = pos[t - 1] - r_w @ tr[t]
        head[t] = r_w
    return pos, head


def gt_positions(poses) -> np.ndarray:
    """Ground-truth rig positions in the START frame: (T, 3) from the
    scenes [(R, t)] list — ``R_0^T (t_t - t_0)``."""
    r0 = _as_np(poses[0][0])
    t0 = _as_np(poses[0][1])
    return np.stack([r0.T @ (_as_np(t) - t0) for _, t in poses])


def gt_relative(poses) -> tuple[np.ndarray, np.ndarray]:
    """Ground-truth relative poses aligned with a ``PoseSet`` sequence:
    (T, 3, 3) rotations / (T, 3) translations with row 0 = identity."""
    t_total = len(poses)
    rot = np.zeros((t_total, 3, 3))
    tr = np.zeros((t_total, 3))
    rot[0] = np.eye(3)
    for t in range(1, t_total):
        r_prev, t_prev = poses[t - 1]
        r_curr, t_curr = poses[t]
        r_prev, r_curr = _as_np(r_prev), _as_np(r_curr)
        rot[t] = r_curr.T @ r_prev
        tr[t] = r_curr.T @ (_as_np(t_prev) - _as_np(t_curr))
    return rot, tr


def _rot_angle_deg(r: np.ndarray) -> float:
    c = np.clip((np.trace(r) - 1.0) / 2.0, -1.0, 1.0)
    return float(np.degrees(np.arccos(c)))


def trajectory_metrics(rotations, translations, gt_poses) -> dict:
    """ATE/RPE of one estimated relative-pose sequence vs ground truth.

    ``rotations``/``translations``: (T, 3, 3)/(T, 3) estimated relative
    poses (``PoseSet`` fields; device arrays accepted — converted to
    float64 here); ``gt_poses``: the scenes [(R, t)] list, same T.
    Returns a dict of host floats:

      ate_rmse_m        RMSE of integrated-position error (metres)
      rpe_trans_rmse_m  per-step relative-translation RMSE (metres)
      rpe_rot_mean_deg  per-step relative-rotation error mean (degrees)
      travel_m          ground-truth path length (for error-per-metre)
    """
    rot = _as_np(rotations)
    tr = _as_np(translations)
    if rot.shape[0] != len(gt_poses):
        raise ValueError(
            f"trajectory_metrics: {rot.shape[0]} estimated poses vs "
            f"{len(gt_poses)} ground-truth poses")
    est_pos, _ = integrate_relative(rot, tr)
    ref_pos = gt_positions(gt_poses)
    ate = float(np.sqrt(np.mean(np.sum((est_pos - ref_pos) ** 2,
                                       axis=-1))))
    gt_rot, gt_tr = gt_relative(gt_poses)
    t_total = rot.shape[0]
    if t_total > 1:
        dt = tr[1:] - gt_tr[1:]
        rpe_t = float(np.sqrt(np.mean(np.sum(dt * dt, axis=-1))))
        rpe_r = float(np.mean([_rot_angle_deg(rot[t] @ gt_rot[t].T)
                               for t in range(1, t_total)]))
        travel = float(np.sum(np.linalg.norm(gt_tr[1:], axis=-1)))
    else:
        rpe_t, rpe_r, travel = 0.0, 0.0, 0.0
    return dict(ate_rmse_m=ate, rpe_trans_rmse_m=rpe_t,
                rpe_rot_mean_deg=rpe_r, travel_m=travel)
