"""Temporal-match ego-motion: batched robust Procrustes pose solve.

One rig's solve consumes the temporal correspondences between the
previous frame's rig-frame points and the current frame's (both from
``geometry.rig_points``) and returns the relative SE(3) motion as a
``PoseSet``.  The solver is a masked top-K reweighting loop around the
weighted Kabsch alignment (``core.backend.kabsch``): each round keeps
the ``keep_frac`` fraction of correspondences with the smallest 3-D
residual (static-shape sort with +inf fill, the ``_masked_median``
idiom) and re-solves, so metre-scale outliers from descriptor aliasing
or stereo quantization cannot poison the least squares.

Degeneracy is data, not control flow: fewer than
``MIN_CORRESPONDENCES`` usable matches, a collapsed point cloud (e.g. a
zero-baseline rig whose depths are all 0), or any non-finite input
yields EXACTLY identity + ``valid=False`` — never NaN — so the first
frame of a session, an all-dead rig, and a textureless scene all flow
through the same jitted graph.  ``solve_pose_batched`` vmaps the solve
over a leading rig axis; the temporal matching itself
(``temporal_correspondences``) is ONE fused match-only kernel launch
for every pair of every rig.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.types import LocalizationState, ORBConfig, PoseSet
from repro.kernels import ops

#: A rigid transform has 6 DoF; 3 point correspondences are the minimum
#: that determines it.  Below this the solve is identity + invalid.
MIN_CORRESPONDENCES = 3


def temporal_correspondences(prev: LocalizationState,
                             curr: LocalizationState, cfg: ORBConfig,
                             search_radius: float,
                             search_radius_y: float,
                             impl: str | None = None):
    """Match prev -> curr left features and gather paired 3-D points.

    ``prev``/``curr`` carry FLAT (B, K, ...) axes where B folds every
    pair of every rig — the whole fleet's temporal matching is ONE
    fused match-only launch (the [0, max_disparity] window is reused as
    [-r, +r] by shifting the previous x coords, exactly like
    ``VisualSystem.temporal_match``).  Returns ``(pts_prev, pts_curr,
    weights)``, each (B, K, ...): weights are 1.0 where the match
    passed the Hamming gate AND both endpoints carry valid
    feature+depth, else 0.0."""
    meta_a = prev.meta.at[..., 0].add(search_radius)
    dist, idx = ops.match_rectify_fused(
        prev.desc, meta_a, curr.desc, curr.meta,
        row_band=float(search_radius_y),
        max_disparity=2.0 * float(search_radius), impl=impl)
    ok = (idx >= 0) & (dist <= cfg.max_hamming) & (prev.meta[..., 3] > 0.5)
    eff = jnp.where(ok, idx, 0)
    pts_curr = jnp.take_along_axis(curr.points, eff[..., None], axis=-2)
    ok_curr = jnp.take_along_axis(curr.valid, eff, axis=-1)
    w = (ok & prev.valid & ok_curr).astype(jnp.float32)
    return prev.points, pts_curr, w


def solve_pose(pts_prev: jnp.ndarray, pts_curr: jnp.ndarray,
               weights: jnp.ndarray, *, iters: int = 3,
               keep_frac: float = 0.7,
               min_corr: int = MIN_CORRESPONDENCES) -> PoseSet:
    """Robust weighted Procrustes for ONE rig: (N, 3) paired points +
    (N,) 0/1 weights -> ``PoseSet`` with ``p_curr = R @ p_prev + t``."""
    w0 = weights.astype(jnp.float32)
    # Insurance against upstream garbage (a corrupt slab that slipped
    # every mask): a non-finite correspondence never enters the solve.
    finite = (jnp.isfinite(pts_prev).all(axis=-1)
              & jnp.isfinite(pts_curr).all(axis=-1))
    w0 = jnp.where(finite, w0, 0.0)
    n0 = jnp.sum((w0 > 0).astype(jnp.int32))
    n_total = w0.shape[0]

    def round_(w_c, _):
        r_c, t_c = backend.kabsch(pts_prev, pts_curr, w_c)
        res = jnp.linalg.norm(pts_prev @ r_c.T + t_c - pts_curr, axis=-1)
        n = jnp.sum((w_c > 0).astype(jnp.int32))
        keep = jnp.maximum(jnp.int32(min_corr),
                           jnp.ceil(keep_frac * n).astype(jnp.int32))
        # masked top-K: threshold at the keep-th smallest residual of
        # the current support (static shape: sort with +inf fill), then
        # re-gate the FULL weight set so a point wrongly dropped in an
        # early round can re-enter once the pose estimate improves.
        filled = jnp.where(w_c > 0, res, jnp.inf)
        thr = jnp.sort(filled)[jnp.clip(keep - 1, 0, n_total - 1)]
        return jnp.where((res <= thr) & (w0 > 0), w0, 0.0), None

    w, _ = jax.lax.scan(round_, w0, None, length=iters)
    r, t = backend.kabsch(pts_prev, pts_curr, w)
    inliers = jnp.sum((w > 0).astype(jnp.int32))

    # Degeneracy gate: a collapsed support cloud (zero/near-zero
    # baseline puts every point at the origin) has no orientation
    # information — the SVD returns SOME orthogonal matrix, so the
    # spread check is what turns "finite but meaningless" into invalid.
    wn = w / jnp.maximum(jnp.sum(w), 1e-6)
    centered = pts_prev - jnp.sum(wn[:, None] * pts_prev, axis=0)
    spread = jnp.sum(wn * jnp.sum(centered * centered, axis=-1))
    ok = ((inliers >= min_corr) & (n0 >= min_corr) & (spread > 1e-8)
          & jnp.isfinite(r).all() & jnp.isfinite(t).all())
    r = jnp.where(ok, r, jnp.eye(3, dtype=jnp.float32))
    t = jnp.where(ok, t, jnp.zeros(3, dtype=jnp.float32))
    return PoseSet(rotation=r.astype(jnp.float32),
                   translation=t.astype(jnp.float32),
                   inliers=inliers, valid=ok)


def solve_pose_batched(pts_prev: jnp.ndarray, pts_curr: jnp.ndarray,
                       weights: jnp.ndarray, *, iters: int = 3,
                       keep_frac: float = 0.7,
                       min_corr: int = MIN_CORRESPONDENCES) -> PoseSet:
    """vmap of ``solve_pose`` over a leading batch axis: (B, N, 3) x 2
    + (B, N) -> ``PoseSet`` with (B,) leading axes.  B is rigs for a
    fleet frame, frame transitions for a sequence, or both folded."""
    solve = functools.partial(solve_pose, iters=iters,
                              keep_frac=keep_frac, min_corr=min_corr)
    return jax.vmap(solve)(pts_prev, pts_curr, weights)
