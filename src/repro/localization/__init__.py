"""Localization backend: depth + ego-motion on top of the frontend.

The paper's frontend exists to feed a localization backend; this
package closes that loop on the session API:

  ``geometry``  disparity -> depth -> RIG-FRAME 3-D points (all stereo
                pairs fused through ``RigConfig.pair_rotations``; pure
                jnp, zero extra launches);
  ``pose``      temporal-match ego-motion — ONE fused match-only
                launch for every pair of every rig, then a batched
                robust (masked top-K reweighted) Procrustes solve,
                vmapped over rigs; degenerate inputs yield identity +
                ``valid=False``, never NaN;
  ``metrics``   ATE / RPE trajectory error vs ``data.scenes`` ground
                truth, host float64 — the accuracy gates CI enforces
                for both f32 and uint8 precision.

``VisualSystem`` (with ``PipelineConfig(localize=True)``) wires these
into ``process_frame`` / ``process_fleet`` / ``run`` so a localized
frame costs at most 3 frontend + 1 backend launches; the helpers below
convert between outputs and the cross-frame ``LocalizationState``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import matching
from repro.core.types import (LocalizationOutput, LocalizationState,
                              PoseSet)
from repro.localization import geometry, metrics, pose
from repro.localization.geometry import rig_points
from repro.localization.metrics import trajectory_metrics
from repro.localization.pose import (MIN_CORRESPONDENCES, solve_pose,
                                     solve_pose_batched)

__all__ = [
    "geometry", "metrics", "pose",
    "rig_points", "trajectory_metrics",
    "MIN_CORRESPONDENCES", "solve_pose", "solve_pose_batched",
    "PoseSet", "LocalizationOutput", "LocalizationState",
    "state_from", "zero_state",
]


def state_from(out: LocalizationOutput) -> LocalizationState:
    """The cross-frame memory a ``LocalizationOutput`` leaves behind:
    its left descriptors + matcher meta, rig-frame points, and the
    combined feature-and-depth usability mask.  Works on any slice
    (a fleet output, or one rig's ``jax.tree.map(lambda x: x[b], ...)``
    row) — this is how ``serving.FleetService`` carries per-rig state
    across re-bucketed batches."""
    feat_l = out.stereo.features_l
    return LocalizationState(
        desc=feat_l.desc, meta=matching._meta(feat_l),
        points=out.points,
        valid=feat_l.valid & out.stereo.depth.valid)


def zero_state(n_pairs: int, k: int, n_rigs: int | None = None
               ) -> LocalizationState:
    """An all-invalid previous-frame state (session start, or a rig the
    service has never served): zero arrays with ``valid=False``
    everywhere, so the first temporal solve degenerates to identity +
    ``valid=False`` through the SAME jitted graph as a normal frame."""
    lead = (n_pairs,) if n_rigs is None else (n_rigs, n_pairs)
    return LocalizationState(
        desc=jnp.zeros(lead + (k, 8), jnp.uint32),
        meta=jnp.zeros(lead + (k, 4), jnp.float32),
        points=jnp.zeros(lead + (k, 3), jnp.float32),
        valid=jnp.zeros(lead + (k,), bool))
