"""Disparity -> depth -> rig-frame 3-D points.

The frontend's ``DepthSet`` is per stereo pair in the pair's LEFT
camera frame; the pose solve wants ONE point cloud per rig.  This
module lifts every pair's matched features through the pair's
intrinsics and folds them into the shared rig frame via
``RigConfig.pair_rotations`` (the quad rig's back pair looks along -z,
so its points rotate 180 degrees about y before fusing with the front
pair's).  Everything is elementwise / small-matmul jnp — the stage adds
ZERO kernel launches and batches over arbitrary leading axes
(fleet rigs, time, both).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.rig import RigConfig


def backproject(xy: jnp.ndarray, depth: jnp.ndarray,
                fx, fy, cx, cy) -> jnp.ndarray:
    """Pinhole back-projection: (..., K, 2) pixel coords + (..., K)
    depth -> (..., K, 3) camera-frame points.  An invalid lane's depth
    is exactly 0 (``matching._depth_set``), so its point is exactly the
    origin — never a division, never NaN."""
    x = (xy[..., 0] - cx) / fx * depth
    y = (xy[..., 1] - cy) / fy * depth
    return jnp.stack([x, y, depth], axis=-1)


def rig_points(xy: jnp.ndarray, depth: jnp.ndarray,
               rig: RigConfig) -> jnp.ndarray:
    """Per-pair left-feature coords + depths -> rig-frame points.

    ``xy``: (..., n_pairs, K, 2) level-0 pixel coords of the left
    features; ``depth``: (..., n_pairs, K) from the pair's ``DepthSet``.
    Returns (..., n_pairs, K, 3) points in the RIG frame: back-projected
    through each pair's left-camera intrinsics, then rotated by the
    pair's camera->rig rotation.  (The scene rig's left cameras sit at
    the rig origin, so rotation alone closes the frame change.)"""
    if xy.shape[-3] != rig.n_pairs:
        raise ValueError(
            f"rig_points: xy pair axis is {xy.shape[-3]} but the rig "
            f"has {rig.n_pairs} pairs")
    intr = rig.pair_intrinsics
    fx = jnp.asarray([ic.fx for ic in intr], jnp.float32)[:, None]
    fy = jnp.asarray([ic.fy for ic in intr], jnp.float32)[:, None]
    cx = jnp.asarray([ic.cx for ic in intr], jnp.float32)[:, None]
    cy = jnp.asarray([ic.cy for ic in intr], jnp.float32)[:, None]
    cam = backproject(xy, depth, fx, fy, cx, cy)
    rot = jnp.asarray(rig.pair_rotation_array())
    return jnp.einsum("pji,...pki->...pkj", rot, cam)


def gt_relative_pose(r_prev: np.ndarray, t_prev: np.ndarray,
                     r_curr: np.ndarray, t_curr: np.ndarray):
    """Ground-truth relative pose between two rig poses (R: rig->world,
    t: world position), in the convention the solver estimates:
    ``p_curr = R_rel @ p_prev + t_rel`` over rig-frame points."""
    r_prev = np.asarray(r_prev, np.float64)
    r_curr = np.asarray(r_curr, np.float64)
    r_rel = r_curr.T @ np.asarray(r_prev, np.float64)
    t_rel = r_curr.T @ (np.asarray(t_prev, np.float64)
                        - np.asarray(t_curr, np.float64))
    return r_rel, t_rel
