"""Deterministic sharded synthetic token pipeline.

Every batch is a pure function of (seed, step): restart at step k
reproduces byte-identical batches with no iterator state to checkpoint
(the data-side half of fault tolerance).  Sequences carry an induction
structure (second half repeats the first half with a fixed stride-shift)
so that a small model measurably learns — loss drops well below the
uniform-entropy floor on the copyable half.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_frac: float = 0.5        # tail fraction that repeats the head


def get_batch(c: TokenDataConfig, step: int | jnp.ndarray) -> dict:
    """(global_batch, seq_len) int32 tokens for `step` (jit-safe)."""
    key = jax.random.fold_in(jax.random.key(c.seed), step)
    head_len = int(c.seq_len * (1.0 - c.copy_frac))
    head = jax.random.randint(
        key, (c.global_batch, head_len), 0, c.vocab, dtype=jnp.int32)
    reps = c.seq_len - head_len
    idx = jnp.arange(reps) % head_len
    tail = head[:, idx]
    return {"tokens": jnp.concatenate([head, tail], axis=1)}


def host_shard(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Per-host slice of the global batch (multi-host data loading:
    each host materializes only its rows)."""
    def f(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(f, batch)
