"""Synthetic quad-camera scene simulator.

Stands in for the paper's camera hardware: a deterministic 3-D landmark
field rendered into four pinhole views (two stereo pairs, front/back)
with known ego-motion, so every frontend/backend quantity has ground
truth.  Landmarks render as small high-contrast squares (strong FAST
corners); the background is a smooth gradient plus mild noise.
"""

from __future__ import annotations

import dataclasses
import typing

import jax.numpy as jnp
import numpy as np

from repro.core.types import CameraIntrinsics


class SequenceOutput(typing.NamedTuple):
    """One rendered trajectory with its ground truth.  ``poses`` is the
    per-frame rig pose [(R, t)] — R maps rig->world, t is the rig's
    world position — the ego-motion the localization backend's accuracy
    gates compare against.  Field order matches the historical
    ``(frames, poses, intrinsics)`` tuple, so positional unpacking of
    ``render_sequence`` keeps working."""

    frames: jnp.ndarray                 # (T, 4, H, W)
    poses: list                         # T x (R (3,3), t (3,)) rig poses
    intrinsics: CameraIntrinsics


class FleetSequenceOutput(typing.NamedTuple):
    """Fleet traffic with per-rig ground truth.  ``poses[r]`` is rig
    ``r``'s per-frame [(R, t)] trajectory (rigs are phase-offset views
    of one master trajectory; the offset is applied here so callers
    never re-derive it).  The historical return was ``(frames,
    intrinsics)`` — the first two fields — so 2-tuple unpacking must be
    updated to name the fields or unpack all three."""

    frames: jnp.ndarray                 # (T, n_rigs, 4, H, W)
    intrinsics: CameraIntrinsics
    poses: tuple                        # n_rigs x [T x (R, t)]


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    n_points: int = 400
    height: int = 480
    width: int = 640
    stamp: int = 5               # landmark square size (px)
    depth_range: tuple[float, float] = (2.0, 12.0)
    spread: float = 8.0          # lateral landmark spread (m)
    noise_std: float = 2.0
    baseline: float = 0.12       # stereo baseline (m); larger -> finer depth
    seed: int = 0


def default_intrinsics(cfg: SceneConfig) -> CameraIntrinsics:
    f = 0.72 * cfg.width
    return CameraIntrinsics(fx=f, fy=f, cx=cfg.width / 2.0,
                            cy=cfg.height / 2.0, baseline=cfg.baseline)


def make_landmarks(cfg: SceneConfig) -> tuple[np.ndarray, np.ndarray]:
    """(N, 3) world points (both hemispheres) + (N, S, S) texture stamps.

    Each landmark gets a unique high-contrast texture patch so BRIEF
    descriptors are discriminative (uniform squares would alias and
    poison temporal matching — real scenes are textured)."""
    rng = np.random.RandomState(cfg.seed)
    n = cfg.n_points
    x = rng.uniform(-cfg.spread, cfg.spread, n)
    y = rng.uniform(-cfg.spread / 2, cfg.spread / 2, n)
    z = rng.uniform(*cfg.depth_range, n)
    z[n // 2:] *= -1.0            # back hemisphere for the rear pair
    pts = np.stack([x, y, z], axis=1)
    s = cfg.stamp
    base = rng.uniform(90.0, 250.0, n)
    texture = rng.uniform(0.4, 1.0, (n, s, s)) * base[:, None, None]
    texture[:, s // 2, s // 2] = 255.0      # bright center -> strong corner
    return pts, texture


def _background(cfg: SceneConfig, seed: int) -> jnp.ndarray:
    rng = np.random.RandomState(seed + 77)
    yy, xx = np.mgrid[0:cfg.height, 0:cfg.width]
    grad = 40.0 + 30.0 * (xx / cfg.width) + 20.0 * (yy / cfg.height)
    noise = rng.normal(0.0, cfg.noise_std, (cfg.height, cfg.width))
    return jnp.asarray(np.clip(grad + noise, 0, 255).astype(np.float32))


def render_view(pts_cam: jnp.ndarray, texture: jnp.ndarray,
                intr: CameraIntrinsics, cfg: SceneConfig,
                bg: jnp.ndarray) -> jnp.ndarray:
    """Project camera-frame points and stamp textured patches.

    pts_cam: (N, 3); texture: (N, S, S)."""
    z = pts_cam[:, 2]
    vis = z > 0.5
    zs = jnp.where(vis, z, 1.0)
    u = jnp.round(intr.fx * pts_cam[:, 0] / zs + intr.cx).astype(jnp.int32)
    v = jnp.round(intr.fy * pts_cam[:, 1] / zs + intr.cy).astype(jnp.int32)
    r = cfg.stamp // 2
    inb = (vis & (u >= r) & (u < cfg.width - r)
           & (v >= r) & (v < cfg.height - r))
    u = jnp.where(inb, u, 0)
    v = jnp.where(inb, v, 0)
    img = bg
    # Stamp texture patches by max-composite: static loop over offsets.
    for dy in range(-r, r + 1):
        for dx in range(-r, r + 1):
            val = jnp.where(inb, texture[:, dy + r, dx + r], 0.0)
            img = img.at[v + dy, u + dx].max(val)
    return jnp.clip(img, 0.0, 255.0)


def camera_poses(rig_r: jnp.ndarray, rig_t: jnp.ndarray,
                 intr: CameraIntrinsics):
    """World->camera transforms for the 4 cameras of the rig.

    Rig frame: +z forward.  Cameras: [front_L, front_R, back_L, back_R];
    right cameras offset +baseline along the rig x axis; the back pair
    looks along -z (180-degree yaw).
    """
    flip = jnp.asarray([[-1.0, 0.0, 0.0], [0.0, 1.0, 0.0],
                        [0.0, 0.0, -1.0]])
    poses = []
    for pair, r_pair in ((0, jnp.eye(3)), (1, flip)):
        for side in (0, 1):
            off = jnp.asarray([side * intr.baseline, 0.0, 0.0])
            # camera rotation in world: rig_r @ r_pair; position:
            # rig_t + rig_r @ r_pair @ off
            r_wc = rig_r @ r_pair
            t_w = rig_t + r_wc @ off
            poses.append((r_wc, t_w))
    return poses


def render_quad(pts_world: jnp.ndarray, texture: jnp.ndarray,
                rig_r: jnp.ndarray, rig_t: jnp.ndarray,
                intr: CameraIntrinsics, cfg: SceneConfig) -> jnp.ndarray:
    """(4, H, W) images for the rig at pose (rig_r, rig_t)."""
    views = []
    for i, (r_wc, t_w) in enumerate(camera_poses(rig_r, rig_t, intr)):
        pts_cam = (pts_world - t_w) @ r_wc          # == r_wc^T applied rowwise
        bg = _background(cfg, seed=cfg.seed + i)
        views.append(render_view(pts_cam, jnp.asarray(texture), intr,
                                 cfg, bg))
    return jnp.stack(views)


def render_fleet_sequence(cfg: SceneConfig, n_frames: int, n_rigs: int,
                          step_t: tuple[float, float, float] =
                          (0.05, 0.0, 0.10),
                          yaw_per_frame: float = 0.01):
    """Deterministic FLEET traffic: (T, n_rigs, 4, H, W) quad frames.

    Every rig drives the same landmark field on the same twist, phase-
    offset by ``r`` frames (rig r starts where rig 0 was r frames ago),
    so rigs see DISTINCT images while the whole fleet renders only
    ``n_frames + n_rigs - 1`` quad frames once.  This is the traffic
    source for the serving layer's fault-injection episodes and the
    ``table_service`` benchmark.  Returns a ``FleetSequenceOutput``
    (frames, intrinsics, per-rig ground-truth pose trajectories)."""
    if n_rigs < 1:
        raise ValueError(f"n_rigs must be >= 1, got {n_rigs}")
    frames, poses, intr = render_sequence(cfg, n_frames + n_rigs - 1,
                                          step_t=step_t,
                                          yaw_per_frame=yaw_per_frame)
    fleet = jnp.stack([frames[r:r + n_frames] for r in range(n_rigs)],
                      axis=1)
    rig_poses = tuple(poses[r:r + n_frames] for r in range(n_rigs))
    return FleetSequenceOutput(fleet, intr, rig_poses)


def render_sequence(cfg: SceneConfig, n_frames: int,
                    step_t: tuple[float, float, float] = (0.05, 0.0, 0.10),
                    yaw_per_frame: float = 0.01):
    """Deterministic trajectory: constant twist. Returns a
    ``SequenceOutput`` of (frames (T, 4, H, W), rig poses [(R, t)],
    intrinsics) — the per-frame ground-truth ego-motion is part of the
    public return, not internal state."""
    pts, tex = make_landmarks(cfg)
    pts = jnp.asarray(pts)
    intr = default_intrinsics(cfg)
    frames, poses = [], []
    r = jnp.eye(3)
    t = jnp.zeros((3,))
    dt = jnp.asarray(step_t)
    c, s = np.cos(yaw_per_frame), np.sin(yaw_per_frame)
    dr = jnp.asarray([[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]])
    for _ in range(n_frames):
        frames.append(render_quad(pts, tex, r, t, intr, cfg))
        poses.append((r, t))
        t = t + r @ dt
        r = r @ dr
    return SequenceOutput(jnp.stack(frames), poses, intr)
