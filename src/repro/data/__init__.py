from repro.data import scenes  # noqa: F401
