"""Atomic, resharding-aware checkpointing."""

from repro.checkpoint.store import (latest_step, list_steps, load_flat,
                                    restore, restore_array_tree, save,
                                    save_async)

__all__ = ["latest_step", "list_steps", "load_flat", "restore",
           "restore_array_tree", "save", "save_async"]
