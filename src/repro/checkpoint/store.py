"""Atomic sharded checkpoint store.

Layout: <dir>/step_<N>/  one .npy per flattened tree path + index.json.
Writes go to a tmp dir and are renamed into place (atomic on POSIX), so
a crash mid-save never corrupts the latest checkpoint — the restart
driver (launch/train.py) just resumes from the newest complete step.
Every data file and the index are fsync'd BEFORE the rename, and the
parent directory is fsync'd after it: without the former, a power loss
can leave a fully-renamed step whose file contents never hit the disk
(rename-before-data), which no amount of tmp-dir discipline catches.

Restore reshards: arrays are device_put against the CURRENT mesh/specs,
so a checkpoint taken on one mesh restores onto a smaller/larger one
(elastic scaling).  ``save_async`` overlaps the host write with the next
step (the device->host copy is synchronous, the file IO is not).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

_SEP = "§"

# numpy can't natively save bf16 & friends — persist as a same-width
# integer view with the logical dtype recorded in the index
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def _write_fsync(path: str, writer) -> None:
    """Write through ``writer(f)`` and fsync before close, so the bytes
    are durable BEFORE the enclosing tmp dir is renamed into place."""
    with open(path, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final directory."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    index = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{abs(hash(key)) & 0xFFFFFFFF:08x}.npy"
        logical = str(arr.dtype)
        if logical in _VIEW_AS:
            arr = arr.view(_VIEW_AS[logical])
        _write_fsync(os.path.join(tmp, fname),
                     lambda f, a=arr: np.save(f, a))
        index[key] = {"file": fname, "shape": list(arr.shape),
                      "dtype": logical}
    _write_fsync(os.path.join(tmp, "index.json"),
                 lambda f: f.write(json.dumps(
                     {"step": step, "leaves": index}).encode()))
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(ckpt_dir)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree,
               keep: int = 3) -> threading.Thread:
    """Device->host copy now; file IO in a background thread."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in
            _flatten(tree).items()}

    def _write():
        save(ckpt_dir, step, flat, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def list_steps(ckpt_dir: str) -> list[int]:
    """Completed (renamed, indexed) step numbers, ascending.  In-flight
    ``.tmp`` dirs from a crashed save are never listed — a torn write
    is invisible here, not a corrupt restore candidate."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "index.json")))


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_flat(ckpt_dir: str, step: int) -> dict:
    """Load every leaf of one step keyed by its flattened tree path —
    no ``like`` tree required (the snapshot layer reconstructs its own
    structure from an embedded manifest).  Raises on missing/truncated
    files; callers that need graceful fallback (serving.snapshot) catch
    and step back to an older snapshot."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)["leaves"]
    out = {}
    for key, meta in index.items():
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        out[key] = arr
    return out


def restore_array_tree(ckpt_dir: str, step: int, like) -> object:
    """Restore as host numpy arrays with the structure of ``like``."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)["leaves"]
    flat_like = _flatten(like)
    out = {}
    for key in flat_like:
        meta = index[key]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        out[key] = arr
    leaves = [out[k] for k in flat_like]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore + device_put with per-leaf shardings (elastic re-mesh:
    the target mesh need not match the one that saved)."""
    host = restore_array_tree(ckpt_dir, step, like)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, host)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host,
                        shardings)
