"""Hardware synchronization (paper Sec. III-A), simulated.

The FPGA has a trigger generator that fires all four cameras + the IMU
from one clock and stamps every sample with a unified time tag; software
sync on a CPU adds a variable per-camera delay that breaks localization.

There is no camera hardware here, so we implement the *algorithm*
(trigger clock, unified tags, interface alignment) and additionally
model the software-sync jitter it removes, so the benefit is measurable
(tests + benchmarks assert hardware desync == 0 < software desync).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TriggerConfig:
    n_cameras: int = 4
    camera_fps: float = 30.0
    imu_rate_hz: float = 200.0
    # Software-sync model: per-camera exposure/readout/OS jitter (seconds).
    sw_jitter_std: float = 4e-3
    t0: float = 0.0

    @property
    def frame_dt(self) -> float:
        return 1.0 / self.camera_fps

    @property
    def imu_per_frame(self) -> int:
        # static upper bound of IMU samples in one frame interval
        return int(jnp.ceil(self.imu_rate_hz / self.camera_fps)) + 2


def hardware_trigger(cfg: TriggerConfig, n_frames: int):
    """Unified time tags from the trigger generator.

    Returns (camera_tags (T, n_cameras) — identical across cameras by
    construction — and imu_tags (T * imu_per_frame_nominal,))."""
    t = cfg.t0 + jnp.arange(n_frames, dtype=jnp.float64) * cfg.frame_dt
    camera_tags = jnp.broadcast_to(t[:, None], (n_frames, cfg.n_cameras))
    n_imu = int(n_frames * cfg.frame_dt * cfg.imu_rate_hz) + 1
    imu_tags = cfg.t0 + jnp.arange(n_imu, dtype=jnp.float64) / cfg.imu_rate_hz
    return camera_tags, imu_tags


def software_sync(cfg: TriggerConfig, n_frames: int, key: jax.Array):
    """Software-sync model: each camera timestamps on CPU arrival with
    independent jitter — the failure mode Sec. III-A eliminates."""
    base, imu_tags = hardware_trigger(cfg, n_frames)
    jitter = cfg.sw_jitter_std * jax.random.normal(
        key, (n_frames, cfg.n_cameras), dtype=jnp.float64)
    return base + jnp.abs(jitter), imu_tags


def max_desync(camera_tags: jnp.ndarray) -> jnp.ndarray:
    """Worst inter-camera time-tag spread over the sequence (seconds)."""
    return jnp.max(jnp.max(camera_tags, axis=1) - jnp.min(camera_tags, axis=1))


def frame_desync(timestamps) -> float:
    """One frame's inter-camera tag spread, evaluated eagerly in float64.

    Epoch-scale stamps (~1.75e9 s) have 128 s float32 spacing, so this
    deliberately stays on the host in float64 — routing through jnp
    without x64 would zero out any real-world desync.
    """
    ts = np.asarray(timestamps, dtype=np.float64).reshape(-1)
    return float(np.max(ts) - np.min(ts))


def desync_camera_mask(timestamps, max_desync_s: float) -> np.ndarray:
    """Which cameras of a desynced frame are still usable (bool mask).

    The degrade policy keeps every camera whose tag lies within
    ``max_desync_s`` of the frame's MEDIAN tag — the largest coherent
    cluster under the paper's one-trigger-clock model, where a desync
    means some camera(s) drifted off the shared clock rather than the
    clock itself moving.  A frame where no camera agrees with the median
    (e.g. a 2-camera rig with one drifted tag) masks out entirely —
    degradation, never a guess.
    """
    ts = np.asarray(timestamps, dtype=np.float64).reshape(-1)
    return np.abs(ts - np.median(ts)) <= float(max_desync_s)


def align_imu(camera_tags: jnp.ndarray, imu_tags: jnp.ndarray,
              cfg: TriggerConfig):
    """Interface alignment: for every frame, the IMU samples with
    prev_tag < t <= tag (static width + mask).

    Returns (indices (T, imu_per_frame) int32, mask (T, imu_per_frame)).
    """
    frame_t = camera_tags[:, 0]
    prev_t = jnp.concatenate([jnp.asarray([-jnp.inf]), frame_t[:-1]])
    width = cfg.imu_per_frame

    # first imu index strictly greater than prev frame tag
    start = jnp.searchsorted(imu_tags, prev_t, side="right")
    idx = start[:, None] + jnp.arange(width)[None, :]
    idx_c = jnp.clip(idx, 0, imu_tags.shape[0] - 1)
    tags = imu_tags[idx_c]
    mask = ((tags <= frame_t[:, None]) & (idx < imu_tags.shape[0])
            & (tags > prev_t[:, None]))
    return idx_c.astype(jnp.int32), mask
