"""Legacy free-function frontend API — now thin deprecation shims over
the ``VisualSystem`` session (``repro.core.pipeline``).

The paper's system is configured once and then streams frames through a
fixed hardware schedule (Sec. III, Fig. 4).  The session API mirrors
that: build ONE ``VisualSystem`` from a ``RigConfig`` (camera count,
pair layout, intrinsics, trigger/sync spec) + ``PipelineConfig`` (ORB
parameters, kernel impl, schedule), then call its jitted cached entry
points — ``process_frame`` (3 kernel launches: 1 dense FE + 1 sparse FE
+ 1 fused FM), ``run`` (sequential or Fig.-4-pipelined schedule), and
``process_fleet`` / ``run_fleet`` (an N-rig fleet frame folds the rig
axis into the batched kernels and still costs 3 launches).

MIGRATION MAP — every function below delegates, bit-exact, to the
session method on the right (sessions are cached per config, so shim
calls reuse jit caches), and warns ``DeprecationWarning``:

    process_quad_frame(im, cfg, intr)     -> VisualSystem.process_frame(im)
    process_stereo_frame(l, r, cfg, intr) -> .process_frame(stack([l, r]))
    run_sequence(frames, cfg, intr)       -> .run(frames)   # "sequential"
    run_sequence_pipelined(frames, ...)   -> .run(frames)   # "pipelined"
    extract_pair(l, r, cfg)               -> .extract(stack([l, r]))
    match_pair(l, r, fl, fr, cfg, intr)   -> .match_pair(l, r, fl, fr)

``pipeline_schedule`` (the analytic Fig. 4 timeline) and
``StereoOutput`` (re-exported from ``core.types``) are NOT deprecated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# The shim plumbing (warning format + cached-session lookup) is shared
# with the matching-side shims — one definition, one message format.
from repro.core.matching import _deprecated, _shim_session as _session
from repro.core.types import (CameraIntrinsics, ORBConfig,  # noqa: F401
                              StereoOutput)


def _split_cameras(feats, n_pairs: int):
    """(B, ...) FeatureSet, B = 2 * n_pairs cameras in [L, R, L, R, ...]
    order -> (feat_l, feat_r), each with leading (n_pairs,) axes."""
    paired = jax.tree.map(
        lambda x: x.reshape(n_pairs, 2, *x.shape[1:]), feats)
    feat_l = jax.tree.map(lambda x: x[:, 0], paired)
    feat_r = jax.tree.map(lambda x: x[:, 1], paired)
    return feat_l, feat_r


def extract_pair(img_l: jnp.ndarray, img_r: jnp.ndarray, cfg: ORBConfig,
                 impl: str | None = None):
    """DEPRECATED shim for ``VisualSystem.extract`` over the stacked
    L/R camera batch (two fused launches for the whole frame)."""
    _deprecated("core.frontend.extract_pair", "extract")
    feats = _session(cfg, None, impl, 2).extract(
        jnp.stack([img_l, img_r]))
    feat_l = jax.tree.map(lambda x: x[0], feats)
    feat_r = jax.tree.map(lambda x: x[1], feats)
    return feat_l, feat_r


def match_pair(img_l, img_r, feat_l, feat_r, cfg: ORBConfig,
               intr: CameraIntrinsics, impl: str | None = None):
    """DEPRECATED shim for ``VisualSystem.match_pair`` (a pair-batch-
    of-one view of the fused FM megakernel — one launch)."""
    _deprecated("core.frontend.match_pair", "match_pair")
    return _session(cfg, intr, impl, 2).match_pair(img_l, img_r, feat_l,
                                                   feat_r)


def process_stereo_frame(img_l, img_r, cfg: ORBConfig,
                         intr: CameraIntrinsics,
                         impl: str | None = None) -> StereoOutput:
    """DEPRECATED shim for ``VisualSystem.process_frame`` on a 2-camera
    rig (outputs drop the pair-batch axis, as before)."""
    _deprecated("core.frontend.process_stereo_frame", "process_frame")
    out = _session(cfg, intr, impl, 2).process_frame(
        jnp.stack([img_l, img_r]))
    return jax.tree.map(lambda x: x[0], out)


def process_quad_frame(images: jnp.ndarray, cfg: ORBConfig,
                       intr: CameraIntrinsics,
                       impl: str | None = None) -> StereoOutput:
    """DEPRECATED shim for ``VisualSystem.process_frame`` on the quad
    rig: images (4, H, W) = [pair0_L, pair0_R, pair1_L, pair1_R] ->
    StereoOutput with a leading (2,) pair axis, 3 kernel launches."""
    _deprecated("core.frontend.process_quad_frame", "process_frame")
    return _session(cfg, intr, impl, 4).process_frame(images)


def run_sequence(frames: jnp.ndarray, cfg: ORBConfig,
                 intr: CameraIntrinsics,
                 impl: str | None = None) -> StereoOutput:
    """DEPRECATED shim for ``VisualSystem.run`` under the "sequential"
    schedule: frames (T, 4, H, W) -> StereoOutput with (T, 2) axes."""
    _deprecated("core.frontend.run_sequence", "run")
    return _session(cfg, intr, impl, 4).run(frames)


def run_sequence_pipelined(frames: jnp.ndarray, cfg: ORBConfig,
                           intr: CameraIntrinsics,
                           impl: str | None = None) -> StereoOutput:
    """DEPRECATED shim for ``VisualSystem.run`` under the "pipelined"
    schedule (Fig. 4: FE(t) overlaps FM(t-1); outputs aligned to
    ``frames`` after the drain step; T == 0 raises a clear error)."""
    _deprecated("core.frontend.run_sequence_pipelined", "run")
    return _session(cfg, intr, impl, 4, schedule="pipelined").run(frames)


def pipeline_schedule(n_frames: int, t_fe_ms: float, t_fm_ms: float):
    """Analytic Fig. 4 timeline for the frame-multiplexed discipline.

    One FE module serves both channels (2 x t_fe per frame, serialized
    L then R); FM(t) runs concurrently with FE(t+1).  Returns a dict of
    per-frame (fe_start, fe_end, fm_start, fm_end) lists plus makespan
    and steady-state frame period max(2 * t_fe, t_fm).
    """
    fe_start, fe_end, fm_start, fm_end = [], [], [], []
    fe_free = 0.0
    fm_free = 0.0
    for n in range(n_frames):
        s = fe_free
        e = s + 2.0 * t_fe_ms               # L then R through the shared FE
        fe_start.append(s)
        fe_end.append(e)
        ms = max(e, fm_free)
        me = ms + t_fm_ms
        fm_start.append(ms)
        fm_end.append(me)
        fe_free = e                          # FE(n+1) may start right away
        fm_free = me
    period = max(2.0 * t_fe_ms, t_fm_ms)
    return {
        "fe_start": fe_start, "fe_end": fe_end,
        "fm_start": fm_start, "fm_end": fm_end,
        "makespan_ms": fm_end[-1],
        "steady_period_ms": period,
        "serial_period_ms": 2.0 * t_fe_ms + t_fm_ms,
    }
