"""Quad-camera frame-multiplexed visual frontend (paper Sec. III-B).

Mapping of the FPGA schedule (Fig. 4) onto TPU/XLA, after the fused
batched frontend refactor:

* Frame-multiplexing (all camera channels share one FE): ALL cameras of
  a frame — 4 for the quad rig, 2 for one stereo pair — enter
  ``orb.extract_features_batched`` as one leading batch axis, and the
  WHOLE frame (every camera at every pyramid level) costs exactly TWO
  fused Pallas launches: the DENSE stage (``ops.fast_blur_nms_pyramid``
  — blur + FAST + NMS in one VMEM pass per pixel, grid over camera x
  level slabs padded to a common tile grid) and the SPARSE stage
  (``ops.orient_describe_pyramid`` — orientation + moments + LUT-steered
  rBRIEF in one VMEM pass per keypoint patch, level-sorted K-blocks).
  The VPU is time-multiplexed across cameras and scales exactly as the
  FPGA FE streams all channels and levels of a frame through one shared
  datapath; the seed issued separate blur and FAST passes per camera per
  level, host-graph NMS slices, and vmapped per-keypoint 31x31 gathers
  for the sparse half, and earlier revisions still re-launched both
  fused stages once per level (2 x L launches per frame).
* One shared FM datapath for the two stereo pairs: the FM stage is ONE
  fused Pallas launch per frame (``matching.match_pair_fused`` →
  ``ops.match_rectify_fused``) whose kernel grid walks (pair, K-block)
  with an inner sequential M sweep — Search Region Decision + Hamming
  Compare + SAD Correction and Disparity Computing stream through one
  kernel exactly as they stream through the paper's single FM block
  (Sec. III-D), with the 11x11 windows read in-kernel from the VMEM-
  resident level-0 slabs.  The pair axis is folded into the grid, not
  ``vmap``'d, and the SAD inputs no longer go through a host-graph
  gather chain.  The Fig. 4 mapping is therefore 2 FE + 1 FM: a traced
  quad frame costs exactly THREE kernel launches.
* FE(N+1) overlapping FM(N): software-pipelined `lax.scan` — the scan
  body computes FE(frame t) and FM(features of frame t-1), which have no
  data dependence, so XLA is free to interleave them; results stream out
  with one frame of latency, exactly the Fig. 4 timeline.  With FM now a
  single schedulable launch (instead of a gather-laden host graph), the
  FE(t) ∥ FM(t-1) overlap is one dense kernel against one matcher
  kernel.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import matching, orb
from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              MatchSet, ORBConfig)


class StereoOutput(NamedTuple):
    features_l: FeatureSet
    features_r: FeatureSet
    matches: MatchSet
    depth: DepthSet


def _split_cameras(feats, n_pairs: int):
    """(B, ...) FeatureSet, B = 2 * n_pairs cameras in [L, R, L, R, ...]
    order -> (feat_l, feat_r), each with leading (n_pairs,) axes (or
    scalar pair axis dropped when n_pairs == 1 handled by callers)."""
    paired = jax.tree.map(
        lambda x: x.reshape(n_pairs, 2, *x.shape[1:]), feats)
    feat_l = jax.tree.map(lambda x: x[:, 0], paired)
    feat_r = jax.tree.map(lambda x: x[:, 1], paired)
    return feat_l, feat_r


def extract_pair(img_l: jnp.ndarray, img_r: jnp.ndarray, cfg: ORBConfig,
                 impl: str | None = None):
    """Frame-multiplexed FE: ONE batched extractor call over the L/R
    camera batch — two fused launches (dense + sparse) for the whole
    frame, all levels included."""
    stacked = jnp.stack([img_l, img_r])          # (2, H, W)
    feats = orb.extract_features_batched(stacked, cfg, impl=impl)
    feat_l = jax.tree.map(lambda x: x[0], feats)
    feat_r = jax.tree.map(lambda x: x[1], feats)
    return feat_l, feat_r


def match_pair(img_l, img_r, feat_l: FeatureSet, feat_r: FeatureSet,
               cfg: ORBConfig, intr: CameraIntrinsics,
               impl: str | None = None):
    """FM stage for ONE stereo pair: a pair-batch-of-one view of the
    fused FM megakernel (``matching.match_pair_fused``) — one launch."""
    matches, depth = matching.match_pair_fused(
        img_l[None], img_r[None],
        jax.tree.map(lambda x: x[None], feat_l),
        jax.tree.map(lambda x: x[None], feat_r), cfg, intr, impl=impl)
    return jax.tree.map(lambda x: x[0], (matches, depth))


def process_stereo_frame(img_l, img_r, cfg: ORBConfig,
                         intr: CameraIntrinsics,
                         impl: str | None = None) -> StereoOutput:
    feat_l, feat_r = extract_pair(img_l, img_r, cfg, impl=impl)
    matches, depth = match_pair(img_l, img_r, feat_l, feat_r, cfg, intr,
                                impl=impl)
    return StereoOutput(feat_l, feat_r, matches, depth)


def process_quad_frame(images: jnp.ndarray, cfg: ORBConfig,
                       intr: CameraIntrinsics,
                       impl: str | None = None) -> StereoOutput:
    """images: (4, H, W) — [pair0_L, pair0_R, pair1_L, pair1_R].

    FE runs ONCE over the whole 4-camera batch (TWO fused launches —
    one dense + one sparse — for all cameras x all pyramid levels) and
    the FM stage runs ONCE over both stereo pairs (ONE fused matcher
    launch whose grid folds the pair axis), so a traced quad frame
    costs exactly 3 kernel launches (2 FE + 1 FM, the budget
    ``benchmarks.check_launches`` gates).  Outputs have a leading (2,)
    pair axis.
    """
    pairs = images.reshape(2, 2, *images.shape[1:])
    feats = orb.extract_features_batched(images, cfg, impl=impl)  # (4, ...)
    feat_l, feat_r = _split_cameras(feats, n_pairs=2)
    matches, depth = matching.match_pair_fused(
        pairs[:, 0], pairs[:, 1], feat_l, feat_r, cfg, intr, impl=impl)
    return StereoOutput(feat_l, feat_r, matches, depth)


def run_sequence(frames: jnp.ndarray, cfg: ORBConfig,
                 intr: CameraIntrinsics,
                 impl: str | None = None) -> StereoOutput:
    """Reference (non-pipelined) schedule: FE+FM of each frame in order.

    frames: (T, 4, H, W) -> StereoOutput with leading (T, 2) axes.
    """
    def body(_, frame):
        out = process_quad_frame(frame, cfg, intr, impl=impl)
        return None, out

    _, outs = jax.lax.scan(body, None, frames)
    return outs


def run_sequence_pipelined(frames: jnp.ndarray, cfg: ORBConfig,
                           intr: CameraIntrinsics,
                           impl: str | None = None) -> StereoOutput:
    """Fig. 4 schedule: FE(t) overlaps FM(t-1) inside one scan step.

    Output step t holds the *completed* result of frame t-1 (one-frame
    pipeline latency); step 0 is a zero-filled bubble.  The final frame's
    FM runs in a drain step, so outputs cover all T frames shifted by 1:
    returns StereoOutput with leading (T, 2) axes, aligned to frames
    (i.e. after the shift/drain, out[t] corresponds to frames[t]).
    """
    t_total = frames.shape[0]

    def fe(frame):
        pairs = frame.reshape(2, 2, *frame.shape[1:])
        # One batched FE over all 4 cameras (2 fused launches per frame).
        feats = orb.extract_features_batched(frame, cfg, impl=impl)
        return pairs, _split_cameras(feats, n_pairs=2)

    def fm(pairs, feats):
        feat_l, feat_r = feats
        # ONE fused matcher launch for both pairs — schedulable against
        # the dense FE launch of the next frame inside the scan body.
        return matching.match_pair_fused(pairs[:, 0], pairs[:, 1],
                                         feat_l, feat_r, cfg, intr,
                                         impl=impl)

    # Pipeline prologue: FE of frame 0.
    pairs0, feats0 = fe(frames[0])

    def body(carry, frame):
        pairs_prev, feats_prev = carry
        # FM(t-1) and FE(t): no data dependence -> XLA may overlap.
        matches, depth = fm(pairs_prev, feats_prev)
        pairs_t, feats_t = fe(frame)
        out = StereoOutput(feats_prev[0], feats_prev[1], matches, depth)
        return (pairs_t, feats_t), out

    (pairs_last, feats_last), outs = jax.lax.scan(
        body, (pairs0, feats0), frames[1:])
    # Drain: FM of the final frame.
    matches, depth = fm(pairs_last, feats_last)
    last = StereoOutput(feats_last[0], feats_last[1], matches, depth)
    outs = jax.tree.map(
        lambda xs, x: jnp.concatenate([xs, x[None]], axis=0), outs, last)
    assert outs.matches.valid.shape[0] == t_total
    return outs


def pipeline_schedule(n_frames: int, t_fe_ms: float, t_fm_ms: float):
    """Analytic Fig. 4 timeline for the frame-multiplexed discipline.

    One FE module serves both channels (2 x t_fe per frame, serialized
    L then R); FM(t) runs concurrently with FE(t+1).  Returns a dict of
    per-frame (fe_start, fe_end, fm_start, fm_end) lists plus makespan
    and steady-state frame period max(2 * t_fe, t_fm).
    """
    fe_start, fe_end, fm_start, fm_end = [], [], [], []
    fe_free = 0.0
    fm_free = 0.0
    for n in range(n_frames):
        s = fe_free
        e = s + 2.0 * t_fe_ms               # L then R through the shared FE
        fe_start.append(s)
        fe_end.append(e)
        ms = max(e, fm_free)
        me = ms + t_fm_ms
        fm_start.append(ms)
        fm_end.append(me)
        fe_free = e                          # FE(n+1) may start right away
        fm_free = me
    period = max(2.0 * t_fe_ms, t_fm_ms)
    return {
        "fe_start": fe_start, "fe_end": fe_end,
        "fm_start": fm_start, "fm_end": fm_end,
        "makespan_ms": fm_end[-1],
        "steady_period_ms": period,
        "serial_period_ms": 2.0 * t_fe_ms + t_fm_ms,
    }
