"""ORB feature extraction — the paper's Feature Extractor block (Fig. 3d)
as an explicit two-stage dense/sparse pipeline.

The hot path is ``extract_features_batched``: all cameras enter as one
leading batch axis and each pyramid level costs exactly TWO fused kernel
launches —

  1. DENSE stage (``ops.fast_blur_nms_batched``): one VMEM pass over
     every pixel emits both the smoothed image (rBRIEF input) and the
     NMS'd FAST score map (top-K input) for the whole camera batch.
  2. SPARSE stage (``ops.orient_describe_batched``): after the static
     top-K, one launch over the (B, K) keypoint block loads each 31x31
     patch into VMEM once and emits orientation theta, the circular-
     patch moments, and the packed 8 x uint32 rBRIEF descriptor, with
     steering resolved through the 30-degree-binned LUT ROM.

This is the TPU analog of the paper's frame-multiplexed FE (Sec. III-B/
III-C): the FPGA streams each frame once through shared FAST + smoothing
hardware, then feeds rotation and description from a shared patch
buffer.  The seed instead ran the sparse half as vmapped 31x31
``dynamic_slice`` gathers on the host graph — the last serialized
per-frame cost this refactor removes.  The single-image
``extract_features`` is a batch-of-one view of the same pipeline.

Per level: batched resize -> dense launch -> top-K -> sparse launch,
then merge levels into one static-shape FeatureSet with level-0 coords.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fast, pyramid
from repro.core.types import FeatureSet, ORBConfig
from repro.kernels import ops


def extract_features_batched(images: jnp.ndarray, cfg: ORBConfig,
                             impl: str | None = None) -> FeatureSet:
    """images: (B, H, W) uint8/float in [0, 255] — B cameras — to a
    FeatureSet of K features with a leading (B,) axis on every field.

    Exactly 2 kernel launches per pyramid level (1 dense + 1 sparse)
    for ALL cameras — asserted by the traced launch counter in tests.
    """
    levels = pyramid.build_pyramid_batched(images, cfg)
    ks = cfg.features_per_level()
    parts = []
    for lvl, (imgs_l, k_l) in enumerate(zip(levels, ks)):
        b = imgs_l.shape[0]
        smoothed, score = ops.fast_blur_nms_batched(
            imgs_l, float(cfg.fast_threshold), nms=cfg.nms,
            quantized=cfg.quantized, impl=impl)
        xy, vals, valid = jax.vmap(
            lambda s: fast.select_topk(s, k_l, cfg.border))(score)
        theta, _moments, desc = ops.orient_describe_batched(
            imgs_l, smoothed, xy, impl=impl)
        scale = cfg.scale_factor ** lvl
        parts.append(FeatureSet(
            xy=xy.astype(jnp.float32) * scale,
            level=jnp.full((b, k_l), lvl, dtype=jnp.int32),
            score=vals,
            theta=theta,
            desc=desc,
            valid=valid,
        ))
    return FeatureSet(*[jnp.concatenate([getattr(p, f) for p in parts],
                                        axis=1)
                        for f in FeatureSet._fields])


def extract_features(image: jnp.ndarray, cfg: ORBConfig,
                     impl: str | None = None) -> FeatureSet:
    """image: (H, W) uint8/float in [0, 255] -> FeatureSet of K features.

    Batch-of-one view of ``extract_features_batched`` so single-image
    callers share the fused kernel path bit-for-bit.
    """
    feats = extract_features_batched(image[None], cfg, impl=impl)
    return jax.tree.map(lambda x: x[0], feats)
