"""ORB feature extraction — the paper's Feature Extractor block (Fig. 3d).

The hot path is ``extract_features_batched``: all cameras enter as one
leading batch axis and each pyramid level costs exactly ONE fused kernel
launch (``ops.fast_blur_nms_batched``) that emits both the smoothed
image (for rBRIEF) and the NMS'd FAST score map (for top-K) from a
single VMEM pass — the TPU analog of the paper's frame-multiplexed FE
streaming each frame once through shared FAST + smoothing hardware.
The single-image ``extract_features`` is a batch-of-one view of it.

Per level: batched resize -> fused blur+FAST+NMS -> top-K ->
orientation -> rBRIEF, then merge levels into one static-shape
FeatureSet with level-0 coords.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import brief, fast, pyramid
from repro.core.types import FeatureSet, ORBConfig
from repro.kernels import ops


def extract_features_batched(images: jnp.ndarray, cfg: ORBConfig,
                             impl: str | None = None) -> FeatureSet:
    """images: (B, H, W) uint8/float in [0, 255] — B cameras — to a
    FeatureSet of K features with a leading (B,) axis on every field."""
    levels = pyramid.build_pyramid_batched(images, cfg)
    ks = cfg.features_per_level()
    parts = []
    for lvl, (imgs_l, k_l) in enumerate(zip(levels, ks)):
        b = imgs_l.shape[0]
        smoothed, score = ops.fast_blur_nms_batched(
            imgs_l, float(cfg.fast_threshold), nms=cfg.nms,
            quantized=cfg.quantized, impl=impl)
        xy, vals, valid = jax.vmap(
            lambda s: fast.select_topk(s, k_l, cfg.border))(score)
        theta = jax.vmap(fast.orientations)(imgs_l, xy)
        desc = jax.vmap(brief.describe)(smoothed, xy, theta)
        scale = cfg.scale_factor ** lvl
        parts.append(FeatureSet(
            xy=xy.astype(jnp.float32) * scale,
            level=jnp.full((b, k_l), lvl, dtype=jnp.int32),
            score=vals,
            theta=theta,
            desc=desc,
            valid=valid,
        ))
    return FeatureSet(*[jnp.concatenate([getattr(p, f) for p in parts],
                                        axis=1)
                        for f in FeatureSet._fields])


def extract_features(image: jnp.ndarray, cfg: ORBConfig,
                     impl: str | None = None) -> FeatureSet:
    """image: (H, W) uint8/float in [0, 255] -> FeatureSet of K features.

    Batch-of-one view of ``extract_features_batched`` so single-image
    callers share the fused kernel path bit-for-bit.
    """
    feats = extract_features_batched(image[None], cfg, impl=impl)
    return jax.tree.map(lambda x: x[0], feats)
