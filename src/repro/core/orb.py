"""ORB feature extraction — the paper's Feature Extractor block (Fig. 3d).

Per level: resize -> FAST detect -> orientation -> smoothing -> rBRIEF,
then merge levels into one static-shape FeatureSet with level-0 coords.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import brief, fast, pyramid
from repro.core.types import FeatureSet, ORBConfig


def extract_features(image: jnp.ndarray, cfg: ORBConfig,
                     impl: str | None = None) -> FeatureSet:
    """image: (H, W) uint8/float in [0, 255] -> FeatureSet of K features."""
    levels = pyramid.build_pyramid(image, cfg)
    ks = cfg.features_per_level()
    parts = []
    for lvl, (img_l, k_l) in enumerate(zip(levels, ks)):
        xy, score, theta, valid = fast.detect(img_l, cfg, k_l, impl=impl)
        smoothed = brief.smooth(img_l, cfg, impl=impl)
        desc = brief.describe(smoothed, xy, theta)
        scale = cfg.scale_factor ** lvl
        parts.append(FeatureSet(
            xy=xy.astype(jnp.float32) * scale,
            level=jnp.full((k_l,), lvl, dtype=jnp.int32),
            score=score,
            theta=theta,
            desc=desc,
            valid=valid,
        ))
    return FeatureSet(*[jnp.concatenate([getattr(p, f) for p in parts],
                                        axis=0)
                        for f in FeatureSet._fields])
