"""ORB feature extraction — the paper's Feature Extractor block (Fig. 3d)
as a whole-frame dense/sparse pipeline: TWO kernel launches per FRAME.

This is the FE engine under the ``VisualSystem`` session
(``repro.core.pipeline``): the session's ``process_frame`` /
``process_fleet`` / ``extract`` entry points all flatten their camera
(and fleet-rig) axes into the single leading batch axis of
``extract_features_batched`` — all cameras of all rigs enter as one
batch, the pyramid is built, and the entire frame — every camera at
every pyramid level — then costs exactly TWO fused kernel launches:

  1. DENSE stage (``ops.fast_blur_nms_pyramid``): ONE launch whose grid
     walks (camera x level slab, tile).  Ragged level slabs are padded
     to a common tile grid and masked by a per-slab (true_h, true_w)
     table; each VMEM pass emits both the smoothed image (rBRIEF input)
     and the NMS'd FAST score map (top-K input).
  2. SPARSE stage (``ops.orient_describe_pyramid``): after the per-level
     static top-K, ONE launch over the level-sorted (B, K_total)
     keypoint block.  Each (camera, K-block) grid step resolves its
     raw/smoothed slab pair through the static block->level offsets in
     the kernel's index maps and emits orientation theta, the circular-
     patch moments, and the packed 8 x uint32 rBRIEF descriptor, with
     steering resolved through the 30-degree-binned LUT ROM.

This is the TPU analog of the paper's whole-frame streaming FE (Sec.
III-B/III-C): the FPGA streams each frame — all channels, all scales —
once through one shared FAST + smoothing datapath and then feeds
rotation and description from a shared patch buffer.  Earlier revisions
re-launched both stages once per pyramid level (2 x L launches per
frame); that schedule survives as ``extract_features_per_level``, the
oracle the whole-frame path is property-tested against bit-for-bit and
the baseline of the ``table_whole_frame_vs_per_level`` benchmark.  The
single-image ``extract_features`` is a batch-of-one view of the same
whole-frame pipeline.

Per frame: batched pyramid -> one dense launch -> per-level top-K ->
one sparse launch, then merge levels into one static-shape FeatureSet
with level-0 coords.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fast, pyramid
from repro.core.types import FeatureSet, ORBConfig
from repro.kernels import ops


def _merge_levels(parts: list[FeatureSet]) -> FeatureSet:
    return FeatureSet(*[jnp.concatenate([getattr(p, f) for p in parts],
                                        axis=1)
                        for f in FeatureSet._fields])


def _level_features(lvl: int, cfg: ORBConfig, xy, vals, valid,
                    theta, desc) -> FeatureSet:
    b, k_l = xy.shape[0], xy.shape[1]
    scale = cfg.scale_factor ** lvl
    return FeatureSet(
        xy=xy.astype(jnp.float32) * scale,
        level=jnp.full((b, k_l), lvl, dtype=jnp.int32),
        # int16 scores (the uint8 datapath) cast losslessly: FAST
        # scores live in [0, 255].  FeatureSet dtypes never change.
        score=vals.astype(jnp.float32),
        theta=theta,
        desc=desc,
        valid=valid,
    )


def extract_features_batched(images: jnp.ndarray, cfg: ORBConfig,
                             impl: str | None = None, *,
                             precision: str = "f32") -> FeatureSet:
    """images: (B, H, W) uint8/float in [0, 255] — B cameras — to a
    FeatureSet of K features with a leading (B,) axis on every field.

    Exactly 2 kernel launches per FRAME (1 dense + 1 sparse) for ALL
    cameras x ALL pyramid levels — asserted by the traced launch counter
    in tests and gated in CI by ``benchmarks.check_launches``.

    precision="uint8" keeps the pyramid slabs uint8 end-to-end (4x less
    resident VMEM, int32 accumulators in the kernels — paper Sec. III
    word-length optimization); the FeatureSet dtypes are unchanged, and
    on quantized images the keypoints/descriptors are bit-equal to the
    f32 path (pinned in tests/test_precision.py).
    """
    levels = pyramid.build_pyramid_batched(images, cfg,
                                           precision=precision)
    ks = cfg.features_per_level()
    dense = ops.fast_blur_nms_pyramid(
        levels, float(cfg.fast_threshold), nms=cfg.nms,
        quantized=cfg.quantized, impl=impl)
    topk = []
    for (_smoothed, score), k_l in zip(dense, ks):
        topk.append(jax.vmap(
            lambda s, k=k_l: fast.select_topk(s, k, cfg.border))(score))
    sparse = ops.orient_describe_pyramid(
        levels, [sm for sm, _ in dense], [xy for xy, _, _ in topk],
        impl=impl)
    parts = []
    for lvl, ((xy, vals, valid), (theta, _mom, desc)) in enumerate(
            zip(topk, sparse)):
        parts.append(_level_features(lvl, cfg, xy, vals, valid, theta, desc))
    return _merge_levels(parts)


def extract_features_per_level(images: jnp.ndarray, cfg: ORBConfig,
                               impl: str | None = None, *,
                               precision: str = "f32") -> FeatureSet:
    """Reference per-level schedule: 2 launches per pyramid LEVEL (the
    PR-2 pipeline).  Kept as the oracle the whole-frame path is pinned
    against bit-for-bit (``tests/test_whole_frame_fused.py``) and as the
    baseline of the ``table_whole_frame_vs_per_level`` benchmark; the
    hot path is ``extract_features_batched``.
    """
    levels = pyramid.build_pyramid_batched(images, cfg,
                                           precision=precision)
    ks = cfg.features_per_level()
    parts = []
    for lvl, (imgs_l, k_l) in enumerate(zip(levels, ks)):
        smoothed, score = ops.fast_blur_nms_batched(
            imgs_l, float(cfg.fast_threshold), nms=cfg.nms,
            quantized=cfg.quantized, impl=impl)
        xy, vals, valid = jax.vmap(
            lambda s: fast.select_topk(s, k_l, cfg.border))(score)
        theta, _moments, desc = ops.orient_describe_batched(
            imgs_l, smoothed, xy, impl=impl)
        parts.append(_level_features(lvl, cfg, xy, vals, valid, theta, desc))
    return _merge_levels(parts)


def extract_features(image: jnp.ndarray, cfg: ORBConfig,
                     impl: str | None = None, *,
                     precision: str = "f32") -> FeatureSet:
    """image: (H, W) uint8/float in [0, 255] -> FeatureSet of K features.

    Batch-of-one view of ``extract_features_batched`` so single-image
    callers share the whole-frame fused kernel path bit-for-bit.
    """
    feats = extract_features_batched(image[None], cfg, impl=impl,
                                     precision=precision)
    return jax.tree.map(lambda x: x[0], feats)
