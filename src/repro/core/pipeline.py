"""`VisualSystem` — the session API of the quad-camera visual frontend.

The paper's system is configured ONCE (rig layout, sync, FE/FM
parameters) and then streams frames through a fixed hardware schedule
(Sec. III, Fig. 4).  This module is that discipline on TPU/XLA: a
``VisualSystem`` session is built from one ``RigConfig`` (camera count,
stereo-pair layout, per-camera intrinsics, trigger/sync spec) plus one
``PipelineConfig`` (ORB parameters, kernel impl, frame schedule, match
radii) and owns everything the old free functions threaded through
every call — cfg, intrinsics, impl resolution, and the jit caches.

Entry points (each jitted once per (entry, shape) and cached on the
session — repeated same-shape calls retrace ZERO times, asserted in
tests):

    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))
    out  = vs.process_frame(images)        # (n_cameras, H, W) -> (P,) axes
    outs = vs.run(frames)                  # (T, C, H, W); schedule from cfg
    fout = vs.process_fleet(fleet_images)  # (n_rigs, C, H, W) -> (N, P)
    fseq = vs.run_fleet(fleet_frames)      # (T, n_rigs, C, H, W)

FLEET BATCHING is the scaling move of this API (the "share one datapath
across channels" discipline of the runtime-reconfigurable accelerator
in PAPERS.md §2, applied across RIGS): ``process_fleet`` folds the
leading ``(n_rigs,)`` axis into the camera/pair batch axes the kernels
already grid over — FE sees one ``(n_rigs * n_cameras,)`` camera batch,
FM one ``(n_rigs * n_pairs,)`` pair batch — so an N-rig fleet frame
still costs exactly THREE kernel launches (1 dense FE + 1 sparse FE +
1 fused FM), the same budget as a single rig (CI-gated via
``launch_gate/fleet_frame_*``), and is bit-exact against the per-rig
loop.  With ``PipelineConfig.rig_shard_axis`` set and a
``distributed.sharding.use_sharding`` mesh installed, the fleet axis is
additionally ``shard_map``'d over that mesh axis (3 launches per
device).

GRACEFUL DEGRADATION (the robustness half of the paper's sync/mux
machinery): ``process_frame`` / ``process_fleet`` accept a per-camera
liveness ``camera_mask`` — dead camera slabs are sanitized to zero
before the kernels and every validity field they touch is gated off, so
a rig with a dead camera degrades to its surviving stereo pairs in the
SAME 3 launches (CI-gated).  Per-frame ``timestamps`` run the rig's
desync policy (``RigConfig.desync_policy``: raise | drop_frame |
degrade); the streaming fleet service (``repro.serving``) layers
watchdog supervision, fault detection and bucketed batching on top of
these hooks.

MIGRATION MAP (the old free functions survive as thin deprecation
shims, bit-exact against these paths):

    process_quad_frame(im, cfg, intr)    -> VisualSystem.process_frame(im)
    process_stereo_frame(l, r, cfg, intr)-> .process_frame(stack([l, r]))
                                            (2-camera rig; drop pair axis)
    run_sequence(frames, cfg, intr)      -> .run(frames)  (schedule=
                                            "sequential")
    run_sequence_pipelined(...)          -> .run(frames)  (schedule=
                                            "pipelined")
    extract_pair(l, r, cfg)              -> .extract(stack([l, r]))
    match_pair(l, r, fl, fr, cfg, intr)  -> .match_pair(l, r, fl, fr)
    stereo_match(fl, fr, cfg)            -> .stereo_match(fl, fr)
    temporal_match(fa, fb, cfg, radius)  -> .temporal_match(fa, fb, ...)
    sad_rectify(l, r, fl, fr, m, cfg, i) -> .sad_rectify(l, r, fl, fr, m)
    ops.set_default_impl(impl)           -> PipelineConfig(impl=...) or
                                            ops.use_impl(impl) (scoped)
    ops.reset_launch_count/launch_count  -> ops.launch_audit() or
                                            VisualSystem.traced_launches
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matching, orb
from repro.core import sync as sync_mod
from repro.core.rig import DesyncError, RigConfig
from repro.core.types import (CameraIntrinsics, FeatureSet,
                              LocalizationOutput, LocalizationState,
                              MatchSet, ORBConfig, PoseSet, StereoOutput)
from repro.distributed import sharding
from repro.kernels import ops
from repro import localization
from repro.localization import pose as pose_solver

_SCHEDULES = ("sequential", "pipelined")
_PRECISIONS = ("f32", "uint8")


class DesyncDecision(typing.NamedTuple):
    """Outcome of the rig's desync policy for one frame's time tags.

    ``action`` is one of ``"ok"`` (process normally — includes the
    legacy software-sync log-only path), ``"raise"``, ``"drop_frame"``
    or ``"degrade"``; ``camera_mask`` is the (n_cameras,) bool keep-mask
    for the degrade action, else None."""

    desync: float
    action: str
    camera_mask: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything about HOW frames are processed (the rig says WHAT).

    ``impl`` resolves the kernel implementation once for the whole
    session ("ref" | "pallas" | None = backend default) instead of the
    old per-call / global ``ops.set_default_impl`` threading.
    ``schedule`` picks the ``run`` discipline: "sequential" (FE+FM per
    frame in order) or "pipelined" (Fig. 4: FE(t) overlaps FM(t-1), one
    frame of latency hidden by the drain step).  ``rig_shard_axis``
    names the mesh axis ``process_fleet`` / ``run_fleet`` shard the
    rig dimension over when a ``use_sharding`` mesh is installed.
    ``precision`` selects the image datapath: "f32" (default) keeps
    float32 slabs; "uint8" is the paper's 8-bit datapath end-to-end —
    uint8 pyramid slabs, int32 fixed-point blur accumulation, int16
    FAST scores, int32 patch moments and int8 descriptor selection —
    cutting resident slab VMEM 4x in the same 3-launch budget.  The
    uint8 path requires ``ORBConfig.quantized`` and uint8 input frames
    (validated eagerly); FAST keypoints and descriptors are bit-exact
    against the quantized f32 path.

    ``localize`` turns on the localization backend
    (``repro.localization``): ``process_frame`` / ``process_fleet`` /
    ``run`` / ``run_fleet`` then return a ``LocalizationOutput``
    (frontend fields + rig-frame 3-D points + relative ego-motion
    ``PoseSet``) instead of a bare ``StereoOutput``.  The backend adds
    exactly ONE kernel launch per frame (the batched temporal matcher;
    triangulation and the robust Procrustes solve are jnp) — a
    localized frame is <= 4 launches, CI-gated.  It defaults OFF so
    frontend-only sessions keep their output type, launch budget, and
    bit-exactness pins unchanged.
    """

    orb: ORBConfig = ORBConfig()
    impl: str | None = None
    schedule: str = "sequential"
    temporal_radius: float = 48.0
    temporal_radius_y: float | None = None
    rig_shard_axis: str | None = None
    precision: str = "f32"
    localize: bool = False

    def __post_init__(self):
        if self.schedule not in _SCHEDULES:
            raise ValueError(
                f"schedule must be one of {_SCHEDULES}, "
                f"got {self.schedule!r}")
        if self.impl not in (None, "ref", "pallas"):
            raise ValueError(
                f"impl must be None, 'ref' or 'pallas', got {self.impl!r}")
        if self.precision not in _PRECISIONS:
            raise ValueError(
                f"precision must be one of {_PRECISIONS}, "
                f"got {self.precision!r}")
        if self.precision == "uint8" and not self.orb.quantized:
            raise ValueError(
                "precision='uint8' requires ORBConfig.quantized=True: "
                "the integer datapath IS the quantized fixed-point "
                "pipeline held in uint8 slabs (a float Gaussian is not "
                "representable in a uint8 level)")


class VisualSystem:
    """One configured rig + pipeline, with jitted cached entry points.

    The session resolves impl once (``PipelineConfig.impl``), owns the
    jit cache for every entry point (``trace_count`` observes retraces),
    validates frame shapes eagerly with clear errors, and applies the
    rig's sync policy to per-frame time tags (``desync_log`` /
    ``DesyncError``).
    """

    def __init__(self, rig: RigConfig,
                 pipe: PipelineConfig | None = None) -> None:
        if not isinstance(rig, RigConfig):
            raise TypeError(f"rig must be a RigConfig, got {type(rig)!r}")
        self.rig = rig
        self.pipe = pipe if pipe is not None else PipelineConfig()
        if not isinstance(self.pipe, PipelineConfig):
            raise TypeError(
                f"pipe must be a PipelineConfig, got {type(self.pipe)!r}")
        # Impl is resolved ONCE, at construction (None -> the ambient
        # use_impl context / process default / backend default), so a
        # session's kernel path is pinned for its lifetime — later
        # context or global flips cannot silently miss the jit cache.
        self.impl: str = ops.resolve_impl(self.pipe.impl)
        self._jitted: dict = {}
        self._trace_counts: dict = {}
        # Bounded health log: one spread per checked frame; a streaming
        # session at 30 fps would otherwise grow this without limit.
        self.desync_log: "collections.deque[float]" = collections.deque(
            maxlen=4096)
        # Localization memory: the previous processed frame's state per
        # entry key ("frame" / ("fleet", n_rigs)) — only written when
        # PipelineConfig.localize is on.  Callers that manage their own
        # cross-batch state (the serving tier) pass ``prev=`` instead.
        self._loc_state: dict = {}

    # -- jit cache ---------------------------------------------------------

    def _jit(self, key, fn):
        """Jit ``fn`` once per entry-point key; jax.jit's own cache then
        keys on argument shapes.  The wrapper counts traces (a python
        side effect that only fires while tracing) so tests can assert
        repeated same-shape calls retrace zero times."""
        if key not in self._jitted:
            def counted(*args):
                self._trace_counts[key] = self._trace_counts.get(key, 0) + 1
                return fn(*args)
            self._jitted[key] = jax.jit(counted)
        return self._jitted[key]

    def trace_count(self, key) -> int:
        """How many times entry point ``key`` has been traced (i.e. how
        many distinct input shapes it has compiled for)."""
        return self._trace_counts.get(key, 0)

    # -- shape / sync validation (eager, outside jit) ----------------------

    def _check_images(self, images, *, fleet: bool, sequence: bool,
                      what: str | None = None) -> None:
        want_nd = 3 + int(fleet) + int(sequence)
        shape = tuple(images.shape)
        if what is None:
            what = (("run_fleet" if sequence else "process_fleet") if fleet
                    else ("run" if sequence else "process_frame"))
        if len(shape) != want_nd:
            raise ValueError(
                f"{what} expects a rank-{want_nd} array "
                f"{'(T, ' if sequence else '('}"
                f"{'n_rigs, ' if fleet else ''}n_cameras, H, W); got "
                f"shape {shape}")
        c, h, w = shape[-3], shape[-2], shape[-1]
        if c != self.rig.n_cameras:
            raise ValueError(
                f"{what}: camera axis is {c} but the rig has "
                f"{self.rig.n_cameras} cameras")
        cfg = self.pipe.orb
        if (h, w) != (cfg.height, cfg.width):
            raise ValueError(
                f"{what}: image shape ({h}, {w}) does not match "
                f"PipelineConfig.orb ({cfg.height}, {cfg.width})")
        if sequence and shape[0] == 0:
            raise ValueError(
                f"{what}: empty sequence (T == 0); the "
                f"{self.pipe.schedule!r} schedule needs at least one "
                "frame (the pipelined prologue/drain is defined for "
                "T >= 1)")
        self._check_dtype(images, what)

    def _check_dtype(self, images, what: str) -> None:
        """Eager dtype validation against the session's configured
        precision — a float frame silently entering a uint8 session (or
        an integer frame a float one) would otherwise produce garbage
        scores deep inside the kernels instead of an error here."""
        dtype = np.dtype(getattr(images, "dtype", np.asarray(images).dtype))
        precision = self.pipe.precision
        if precision == "uint8":
            if dtype != np.uint8:
                raise TypeError(
                    f"{what}: images have dtype {dtype.name} but this "
                    "session is configured with "
                    "PipelineConfig(precision='uint8') — the integer "
                    "datapath needs uint8 frames.  Quantize with "
                    "np.round(np.clip(images, 0, 255)).astype(np.uint8) "
                    "or build the session with precision='f32'.")
        elif not np.issubdtype(dtype, np.floating):
            raise TypeError(
                f"{what}: images have dtype {dtype.name} but this "
                "session is configured with "
                "PipelineConfig(precision='f32') — pass float frames "
                "(e.g. images.astype(np.float32)) or build the session "
                "with precision='uint8' to run the integer datapath.")

    def desync_decision(self, timestamps) -> DesyncDecision:
        """Apply the rig's sync + desync policies to one frame's camera
        time tags WITHOUT raising — the inspectable form ``check_desync``
        and the serving supervisor build on.

        The tag spread is the float64 single-frame evaluation of
        ``sync.max_desync`` over the (n_cameras,) stamp vector
        (``sync.frame_desync`` — epoch-scale stamps have 128 s float32
        spacing, so this must not round-trip through float32); it is
        appended to ``desync_log``.  A spread within ``rig.max_desync``
        is ``"ok"``.  Beyond it, ``rig.desync_policy`` decides: the
        default (None) keeps the legacy split — hardware rigs get
        ``"raise"`` (the paper's Sec. III-A 0-cycle guarantee), software
        rigs log and stay ``"ok"`` — while an explicit policy applies to
        both sync disciplines uniformly (``"degrade"`` also computes the
        median-cluster camera keep-mask)."""
        ts = np.asarray(timestamps, dtype=np.float64).reshape(-1)
        if ts.shape[0] != self.rig.n_cameras:
            raise ValueError(
                f"expected {self.rig.n_cameras} per-camera timestamps, "
                f"got {ts.shape[0]}")
        desync = sync_mod.frame_desync(ts)
        self.desync_log.append(desync)
        if desync <= self.rig.max_desync:
            return DesyncDecision(desync, "ok")
        policy = self.rig.desync_policy
        if policy is None:
            policy = ("raise" if self.rig.sync_policy == "hardware"
                      else "ok")
        if policy == "degrade":
            return DesyncDecision(
                desync, "degrade",
                sync_mod.desync_camera_mask(ts, self.rig.max_desync))
        return DesyncDecision(desync, policy)

    def _desync_error(self, desync: float, what: str = "") -> DesyncError:
        return DesyncError(
            f"{what}{self.rig.sync_policy}-sync rig saw {desync:.3e}s "
            f"inter-camera desync (tolerance {self.rig.max_desync:.3e}s)"
            ": time tags must come from the unified trigger clock "
            "(paper Sec. III-A)")

    def check_desync(self, timestamps) -> float:
        """Legacy strict form of ``desync_decision``: returns the tag
        spread (seconds, logged to ``desync_log``) and raises
        ``DesyncError`` when the rig's policy resolves to ``"raise"``."""
        decision = self.desync_decision(timestamps)
        if decision.action == "raise":
            raise self._desync_error(decision.desync)
        return decision.desync

    # -- engine (pure, jit-able; impl threaded explicitly) -----------------

    def _flat_pair_indices(self, n_rigs: int):
        """Left/right camera indices of every pair of every rig in the
        flattened ``(n_rigs * n_cameras,)`` camera batch."""
        c = self.rig.n_cameras
        left = np.asarray(self.rig.left_cams, np.int32)
        right = np.asarray(self.rig.right_cams, np.int32)
        offs = np.arange(n_rigs, dtype=np.int32)[:, None] * c
        return (jnp.asarray((offs + left[None, :]).reshape(-1)),
                jnp.asarray((offs + right[None, :]).reshape(-1)))

    def _fm_intr(self, n_rigs: int):
        """Shared ``CameraIntrinsics`` when the rig is homogeneous (the
        scalar fast path, bit-identical to the legacy functions), else a
        per-pair ``fx * baseline`` column tiled across the fleet."""
        if self.rig.homogeneous_intrinsics:
            return self.rig.intrinsics[0]
        fxb = np.asarray([float(ic.fx) * float(ic.baseline)
                          for ic in self.rig.pair_intrinsics], np.float32)
        return jnp.asarray(np.tile(fxb, n_rigs)[:, None])

    def _fe_flat(self, images, n_rigs: int, impl):
        """FE stage over the flat camera batch: ONE dense + ONE sparse
        launch for every camera of every rig at every pyramid level."""
        feats = orb.extract_features_batched(images, self.pipe.orb,
                                             impl=impl,
                                             precision=self.pipe.precision)
        li, ri = self._flat_pair_indices(n_rigs)
        feat_l = jax.tree.map(lambda x: x[li], feats)
        feat_r = jax.tree.map(lambda x: x[ri], feats)
        return images[li], images[ri], feat_l, feat_r

    def _fm_flat(self, carry, n_rigs: int, impl) -> StereoOutput:
        """FM stage over the flat pair batch: ONE fused matcher launch
        whose grid folds every pair of every rig."""
        imgs_l, imgs_r, feat_l, feat_r = carry
        matches, depth = matching.match_pair_fused(
            imgs_l, imgs_r, feat_l, feat_r, self.pipe.orb,
            self._fm_intr(n_rigs), impl=impl)
        return StereoOutput(feat_l, feat_r, matches, depth)

    def _core_flat(self, flat, n_rigs: int, impl,
                   mask_flat=None) -> StereoOutput:
        """The 3-launch datapath over the flat (n_rigs * n_cameras,)
        camera batch, with optional graceful degradation: a per-camera
        liveness mask sanitizes dead slabs to zero BEFORE the kernels
        (NaN/garbage from a dead sensor never enters the fused launches)
        and gates every validity field AFTER them — a rig with a dead
        camera degrades to its surviving stereo pairs, in the SAME 3
        launches (masking is elementwise jnp, not a kernel), and
        all-true masks are bit-exact identity."""
        if mask_flat is not None:
            flat = jnp.where(mask_flat[:, None, None], flat,
                             jnp.zeros_like(flat))
        out = self._fm_flat(self._fe_flat(flat, n_rigs, impl), n_rigs,
                            impl)
        if mask_flat is not None:
            li, ri = self._flat_pair_indices(n_rigs)
            ml, mr = mask_flat[li], mask_flat[ri]
            out = matching.mask_stereo_output(out, ml, mr, ml & mr)
        return out

    def _frame_core(self, images, impl, camera_mask=None) -> StereoOutput:
        """(n_cameras, H, W) -> StereoOutput with (n_pairs,) axes; a
        fleet-of-one view of the same 3-launch datapath.  ``camera_mask``
        ((n_cameras,) bool, optional) masks dead cameras through the
        batch axes — see ``_core_flat``."""
        mask = (None if camera_mask is None
                else jnp.asarray(camera_mask).reshape(-1).astype(bool))
        return self._core_flat(images, 1, impl, mask)

    def _fleet_core(self, images, impl, camera_mask=None) -> StereoOutput:
        """(n_rigs, n_cameras, H, W) -> StereoOutput with
        (n_rigs, n_pairs) axes; the rig axis is folded into the kernels'
        camera/pair batch axes, so the whole fleet frame still costs 3
        launches — degraded or not (``camera_mask``: (n_rigs, n_cameras)
        bool, optional)."""
        n = images.shape[0]
        flat = images.reshape((n * self.rig.n_cameras,) + images.shape[2:])
        mask = (None if camera_mask is None
                else jnp.asarray(camera_mask).astype(bool).reshape(-1))
        out = self._core_flat(flat, n, impl, mask)
        return jax.tree.map(
            lambda x: x.reshape((n, self.rig.n_pairs) + x.shape[1:]), out)

    def _run_core(self, frames, impl, fleet: bool) -> StereoOutput:
        if self.pipe.schedule == "pipelined":
            return self._run_pipelined(frames, impl, fleet)
        per_frame = self._fleet_core if fleet else self._frame_core
        def body(_, frame):
            return None, per_frame(frame, impl)
        _, outs = jax.lax.scan(body, None, frames)
        return outs

    def _run_pipelined(self, frames, impl, fleet: bool) -> StereoOutput:
        """Fig. 4 schedule: FE(t) overlaps FM(t-1) inside one scan step;
        the final frame's FM runs in a drain step, so outputs cover all
        T frames aligned to ``frames``.  T == 1 degenerates to prologue
        + drain (an empty scan) and equals the sequential schedule;
        T == 0 is rejected eagerly in ``run``/``run_fleet`` with a
        clear error instead of the old bare in-trace ``assert``."""
        t_total = int(frames.shape[0])
        n_pairs = self.rig.n_pairs

        def fe(frame):
            if fleet:
                n = frame.shape[0]
                flat = frame.reshape((n * self.rig.n_cameras,)
                                     + frame.shape[2:])
                return self._fe_flat(flat, n, impl)
            return self._fe_flat(frame, 1, impl)

        def fm(carry):
            n = carry[0].shape[0] // n_pairs
            out = self._fm_flat(carry, n, impl)
            if fleet:
                out = jax.tree.map(
                    lambda x: x.reshape((n, n_pairs) + x.shape[1:]), out)
            return out

        carry0 = fe(frames[0])

        def body(carry, frame):
            # FM(t-1) and FE(t): no data dependence -> XLA may overlap.
            out = fm(carry)
            return fe(frame), out

        carry_last, outs = jax.lax.scan(body, carry0, frames[1:])
        last = fm(carry_last)
        outs = jax.tree.map(
            lambda xs, x: jnp.concatenate([xs, x[None]], axis=0),
            outs, last)
        if outs.matches.valid.shape[0] != t_total:  # static shape check
            raise RuntimeError(
                f"pipelined schedule produced "
                f"{outs.matches.valid.shape[0]} outputs for {t_total} "
                "frames — drain/prologue accounting is broken")
        return outs

    # -- localization engine (pure, jit-able) ------------------------------

    def _temporal_radii(self) -> tuple[float, float]:
        rx = float(self.pipe.temporal_radius)
        ry = (rx if self.pipe.temporal_radius_y is None
              else float(self.pipe.temporal_radius_y))
        return rx, ry

    def _loc_flat(self, out: StereoOutput, prev: LocalizationState,
                  n_rigs: int, impl):
        """Backend stage over the FLAT (n_rigs * n_pairs,) pair batch:
        rig-frame triangulation (jnp, 0 launches), ONE fused temporal
        match launch folding every pair of every rig, and the vmapped
        robust Procrustes solve (jnp).  Returns (points (B*P, K, 3),
        PoseSet with (n_rigs,) axes)."""
        p = self.rig.n_pairs
        k = out.features_l.valid.shape[-1]
        xy = out.features_l.xy.reshape((n_rigs, p, k, 2))
        z = out.depth.depth.reshape((n_rigs, p, k))
        pts = localization.rig_points(xy, z, self.rig)
        pts_flat = pts.reshape((n_rigs * p, k, 3))
        curr = LocalizationState(
            desc=out.features_l.desc,
            meta=matching._meta(out.features_l),
            points=pts_flat,
            valid=out.features_l.valid & out.depth.valid)
        rx, ry = self._temporal_radii()
        pp, cp, w = pose_solver.temporal_correspondences(
            prev, curr, self.pipe.orb, rx, ry, impl)
        pose = pose_solver.solve_pose_batched(
            pp.reshape((n_rigs, p * k, 3)),
            cp.reshape((n_rigs, p * k, 3)),
            w.reshape((n_rigs, p * k)))
        return pts_flat, pose

    def _localize_frame(self, out: StereoOutput, prev: LocalizationState,
                        impl):
        """Frame view of ``_loc_flat``: (P,) axes in, scalar pose out."""
        pts, pose = self._loc_flat(out, prev, 1, impl)
        return pts, jax.tree.map(lambda x: x[0], pose)

    def _localize_fleet(self, out: StereoOutput, prev: LocalizationState,
                        impl):
        """Fleet view: (n, P, ...) axes in, (n,) pose out — the rig
        axis folds into the temporal matcher's pair grid and the solve's
        vmap, so localizing a whole fleet is still ONE extra launch."""
        n = out.features_l.valid.shape[0]
        p, k = self.rig.n_pairs, out.features_l.valid.shape[-1]
        flat = jax.tree.map(
            lambda x: x.reshape((n * p,) + x.shape[2:]), out)
        prev_flat = jax.tree.map(
            lambda x: x.reshape((n * p,) + x.shape[2:]), prev)
        pts, pose = self._loc_flat(flat, prev_flat, n, impl)
        return pts.reshape((n, p, k, 3)), pose

    def _run_loc(self, frames, impl, fleet: bool) -> LocalizationOutput:
        """Localized sequence: the frontend scan (3 launches per step)
        plus ONE temporal-match launch for ALL T-1 frame transitions of
        all rigs (time folds into the matcher's pair grid exactly like
        the fleet axis), then the (T-1)*n_rigs-way batched solve.
        ``pose`` row 0 is identity + invalid (no predecessor)."""
        outs = self._run_core(frames, impl, fleet)
        shaped = outs if fleet else jax.tree.map(lambda x: x[:, None],
                                                 outs)
        feat_l = shaped.features_l
        t_total, n = feat_l.valid.shape[0], feat_l.valid.shape[1]
        p, k = self.rig.n_pairs, feat_l.valid.shape[-1]
        pts = localization.rig_points(feat_l.xy, shaped.depth.depth,
                                      self.rig)      # (T, n, P, K, 3)
        meta = matching._meta(feat_l)
        valid = feat_l.valid & shaped.depth.valid

        def invalid_pose(lead):
            return PoseSet(
                rotation=jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32),
                                          lead + (3, 3)),
                translation=jnp.zeros(lead + (3,), jnp.float32),
                inliers=jnp.zeros(lead, jnp.int32),
                valid=jnp.zeros(lead, bool))

        if t_total == 1:
            pose = invalid_pose((1, n))
        else:
            b = (t_total - 1) * n

            def flat(x, sl):
                return x[sl].reshape((b * p,) + x.shape[3:])

            def state(sl):
                return LocalizationState(
                    desc=flat(feat_l.desc, sl), meta=flat(meta, sl),
                    points=flat(pts, sl), valid=flat(valid, sl))

            rx, ry = self._temporal_radii()
            pp, cp, w = pose_solver.temporal_correspondences(
                state(slice(None, -1)), state(slice(1, None)),
                self.pipe.orb, rx, ry, impl)
            pose = pose_solver.solve_pose_batched(
                pp.reshape((b, p * k, 3)), cp.reshape((b, p * k, 3)),
                w.reshape((b, p * k)))
            pose = jax.tree.map(
                lambda x: x.reshape((t_total - 1, n) + x.shape[1:]),
                pose)
            pose = jax.tree.map(
                lambda first, rest: jnp.concatenate([first, rest]),
                invalid_pose((1, n)), pose)
        if not fleet:
            pts = pts[:, 0]
            pose = jax.tree.map(lambda x: x[:, 0], pose)
        return LocalizationOutput(outs, pts, pose)

    def _resolve_prev(self, prev, key, out: StereoOutput, what: str
                      ) -> LocalizationState:
        """Previous-frame state for a localized entry: the caller's
        explicit ``prev`` (shape-validated eagerly), else the session's
        stored state for this entry key, else the all-invalid zero state
        (session start — the solve degenerates to identity+invalid)."""
        k = out.features_l.valid.shape[-1]
        n_rigs = None if key == "frame" else key[1]
        if prev is None:
            prev = self._loc_state.get(key)
        if prev is None:
            return localization.zero_state(self.rig.n_pairs, k, n_rigs)
        if not isinstance(prev, LocalizationState):
            raise TypeError(
                f"{what}: prev must be a LocalizationState "
                f"(see repro.localization.state_from), got "
                f"{type(prev)!r}")
        lead = ((self.rig.n_pairs,) if n_rigs is None
                else (n_rigs, self.rig.n_pairs))
        want = lead + (k, 3)
        got = tuple(prev.points.shape)
        if got != want:
            raise ValueError(
                f"{what}: prev.points shape {got} does not match {want} "
                "— the state must come from the same rig layout and "
                "feature budget (and, for fleets, the same n_rigs)")
        return prev

    def reset_localization(self) -> None:
        """Forget all cross-frame localization state: the next
        ``process_frame`` / ``process_fleet`` behaves like a session
        start (identity + invalid pose).  Call between unrelated
        sequences so a stale previous frame cannot leak into a pose."""
        self._loc_state.clear()

    # -- frame / sequence entry points -------------------------------------

    def _coerce_camera_mask(self, camera_mask, n_rigs: int | None,
                            what: str) -> np.ndarray | None:
        """Validate a caller camera mask eagerly: (n_cameras,) bool for
        a frame, (n_rigs, n_cameras) for a fleet; returns np.bool_."""
        if camera_mask is None:
            return None
        mask = np.asarray(camera_mask, dtype=bool)
        want = ((self.rig.n_cameras,) if n_rigs is None
                else (n_rigs, self.rig.n_cameras))
        if mask.shape != want:
            raise ValueError(
                f"{what}: camera_mask shape {mask.shape} does not match "
                f"{want} (per-camera liveness"
                f"{'' if n_rigs is None else ' per rig'})")
        return mask

    def _frame_desync_mask(self, timestamps,
                           camera_mask: np.ndarray | None):
        """Resolve one frame's desync policy into (dropped, camera_mask):
        raise raises, drop_frame -> (True, _), degrade ANDs the median-
        cluster keep-mask into the caller's liveness mask."""
        decision = self.desync_decision(timestamps)
        if decision.action == "raise":
            raise self._desync_error(decision.desync)
        if decision.action == "drop_frame":
            return True, camera_mask
        if decision.action == "degrade":
            keep = decision.camera_mask
            camera_mask = (keep if camera_mask is None
                           else camera_mask & keep)
        return False, camera_mask

    def process_frame(self, images, timestamps=None, camera_mask=None,
                      prev: LocalizationState | None = None
                      ) -> StereoOutput | LocalizationOutput | None:
        """One rig frame: (n_cameras, H, W) -> StereoOutput with leading
        (n_pairs,) axes, in exactly 3 kernel launches (2 FE + 1 FM).
        With ``PipelineConfig.localize`` the return is a
        ``LocalizationOutput`` (adds rig-frame 3-D points and the
        relative pose vs the previous processed frame) in <= 4 launches;
        ``prev`` overrides the session-held previous-frame state
        (``repro.localization.state_from``), e.g. for callers that
        interleave several streams through one session.

        ``timestamps`` (optional, (n_cameras,) seconds) runs the rig's
        per-frame desync policy (``desync_decision``) before dispatch:
        ``raise`` raises ``DesyncError``, ``drop_frame`` returns None
        (the frame is NOT processed), ``degrade`` masks the offending
        cameras.  ``camera_mask`` (optional, (n_cameras,) bool) marks
        dead cameras: their slabs are sanitized to zero before the
        kernels and every validity field they touch is gated off, so
        the rig degrades to its surviving stereo pairs — still 3
        launches, bit-exact on the surviving cameras.
        """
        self._check_images(images, fleet=False, sequence=False)
        camera_mask = self._coerce_camera_mask(camera_mask, None,
                                               "process_frame")
        if timestamps is not None:
            dropped, camera_mask = self._frame_desync_mask(timestamps,
                                                           camera_mask)
            if dropped:
                return None
        if camera_mask is None:
            out = self._jit(
                "process_frame",
                lambda im: self._frame_core(im, self.impl))(images)
        else:
            out = self._jit(
                "process_frame_masked",
                lambda im, cm: self._frame_core(im, self.impl, cm))(
                    images, jnp.asarray(camera_mask))
        if not self.pipe.localize:
            return out
        prev_state = self._resolve_prev(prev, "frame", out,
                                        "process_frame")
        pts, pose = self._jit(
            "localize_frame",
            lambda o, pv: self._localize_frame(o, pv, self.impl))(
                out, prev_state)
        lout = LocalizationOutput(out, pts, pose)
        self._loc_state["frame"] = localization.state_from(lout)
        return lout

    def process_fleet(self, images, timestamps=None, camera_mask=None,
                      prev: LocalizationState | None = None
                      ) -> StereoOutput | LocalizationOutput:
        """One frame from EVERY rig of a fleet: (n_rigs, n_cameras, H, W)
        -> StereoOutput with leading (n_rigs, n_pairs) axes — still 3
        kernel launches total, bit-exact against the per-rig loop.

        ``images`` may also be a SEQUENCE of per-rig (n_cameras, H, W)
        frames; mismatched per-rig shapes (e.g. rigs with different
        camera counts) raise an eager, descriptive ``ValueError`` here
        instead of an opaque jit trace failure deep in the kernels.

        ``timestamps`` ((n_rigs, n_cameras), optional) applies the desync
        policy PER RIG: ``raise`` raises naming the rig, ``drop_frame``
        masks the whole offending rig out of the batch (fleet shapes are
        static — a dropped rig cannot leave the array), ``degrade``
        masks its offending cameras.  ``camera_mask``
        ((n_rigs, n_cameras) bool, optional) marks dead cameras; masked
        rigs/cameras degrade to their surviving pairs in the same 3
        launches.

        With ``PipelineConfig.rig_shard_axis`` set and a
        ``use_sharding`` mesh installed, the rig axis is sharded over
        that mesh axis via ``shard_map`` (n_rigs must divide evenly;
        degraded — masked — fleets currently take the unsharded path).

        With ``PipelineConfig.localize`` the return is a
        ``LocalizationOutput`` with (n_rigs,) pose axes — the temporal
        matcher folds rigs into its pair grid and the solve vmaps, so
        the WHOLE fleet localizes in one extra launch (<= 4 total).
        ``prev`` ((n_rigs, ...) ``LocalizationState``) overrides the
        session-held state — the serving tier re-buckets rigs between
        batches, so it assembles per-rig state explicitly (localized
        fleets take the unsharded path).
        """
        images = self._coerce_fleet_images(images, "process_fleet")
        self._check_images(images, fleet=True, sequence=False)
        n_rigs = int(images.shape[0])
        camera_mask = self._coerce_camera_mask(camera_mask, n_rigs,
                                               "process_fleet")
        if timestamps is not None:
            ts = np.asarray(timestamps, dtype=np.float64)
            if ts.shape != (n_rigs, self.rig.n_cameras):
                raise ValueError(
                    f"process_fleet: timestamps shape {ts.shape} does "
                    f"not match ({n_rigs}, {self.rig.n_cameras})")
            rows = (np.ones((n_rigs, self.rig.n_cameras), dtype=bool)
                    if camera_mask is None else camera_mask.copy())
            for r in range(n_rigs):
                try:
                    dropped, row = self._frame_desync_mask(
                        ts[r], rows[r])
                except DesyncError:
                    raise self._desync_error(
                        sync_mod.frame_desync(ts[r]),
                        what=f"fleet rig {r}: ") from None
                rows[r] = False if dropped else row
            camera_mask = rows
        if camera_mask is None:
            sharded = (None if self.pipe.localize
                       else self._fleet_sharded("process_fleet",
                                                self._fleet_core))
            if sharded is not None:
                return sharded(images)
            out = self._jit(
                "process_fleet",
                lambda im: self._fleet_core(im, self.impl))(images)
        else:
            out = self._jit(
                "process_fleet_masked",
                lambda im, cm: self._fleet_core(im, self.impl, cm))(
                    images, jnp.asarray(camera_mask))
        if not self.pipe.localize:
            return out
        key = ("fleet", n_rigs)
        prev_state = self._resolve_prev(prev, key, out, "process_fleet")
        pts, pose = self._jit(
            "localize_fleet",
            lambda o, pv: self._localize_fleet(o, pv, self.impl))(
                out, prev_state)
        lout = LocalizationOutput(out, pts, pose)
        self._loc_state[key] = localization.state_from(lout)
        return lout

    def _coerce_fleet_images(self, images, what: str):
        """Fleet inputs arrive either as one stacked array or as a
        sequence of per-rig frames.  Stacking is only defined when every
        rig shares one (n_cameras, H, W) shape — mismatched rigs (the
        classic mixed quad/stereo fleet footgun) fail HERE with the
        per-rig shapes spelled out, not as an XLA trace error."""
        if isinstance(images, (list, tuple)) or (
                hasattr(images, "dtype") and images.dtype == object):
            shapes = [tuple(np.shape(x)) for x in images]
            if len(set(shapes)) > 1:
                raise ValueError(
                    f"{what}: rigs have mismatched frame shapes "
                    f"{shapes}; every rig in one fleet batch must share "
                    f"the same (n_cameras, H, W) = "
                    f"({self.rig.n_cameras}, {self.pipe.orb.height}, "
                    f"{self.pipe.orb.width}).  Rigs with different "
                    "camera counts need their own session (one "
                    "RigConfig per layout) — the serving queue buckets "
                    "per layout for exactly this reason.")
            images = jnp.stack([jnp.asarray(x) for x in images])
        return images

    def run(self, frames) -> StereoOutput | LocalizationOutput:
        """A frame sequence (T, n_cameras, H, W) -> StereoOutput with
        leading (T, n_pairs) axes, under ``PipelineConfig.schedule``.
        With ``localize`` on: a ``LocalizationOutput`` whose pose rows
        are the per-step relative motion (row 0 identity+invalid);
        sequences are self-contained — they neither read nor write the
        ``process_frame`` cross-call state."""
        self._check_images(frames, fleet=False, sequence=True)
        if self.pipe.localize:
            return self._jit(
                "run_loc",
                lambda f: self._run_loc(f, self.impl, False))(frames)
        return self._jit(
            "run",
            lambda f: self._run_core(f, self.impl, False))(frames)

    def run_fleet(self, frames) -> StereoOutput | LocalizationOutput:
        """A fleet sequence (T, n_rigs, n_cameras, H, W) -> StereoOutput
        with leading (T, n_rigs, n_pairs) axes; both schedules fold the
        rig axis into the batched kernels (3 launches per scan step).
        With ``localize`` on: a ``LocalizationOutput`` with
        (T, n_rigs) pose axes (row 0 identity+invalid; unsharded)."""
        self._check_images(frames, fleet=True, sequence=True)
        if self.pipe.localize:
            return self._jit(
                "run_fleet_loc",
                lambda f: self._run_loc(f, self.impl, True))(frames)
        sharded = self._fleet_sharded(
            "run_fleet", lambda f, impl: self._run_core(f, impl, True))
        if sharded is not None:
            return sharded(frames)
        return self._jit(
            "run_fleet",
            lambda f: self._run_core(f, self.impl, True))(frames)

    def _fleet_sharded(self, entry: str, core):
        """shard_map'd jitted fleet entry when a mesh context carrying
        ``rig_shard_axis`` is installed, else None.  ``core`` takes
        (array, impl) with the rig axis leading (axis 0 for
        process_fleet; run_fleet shards axis 1 of (T, n_rigs, ...))."""
        axis = self.pipe.rig_shard_axis
        ctx = sharding.current_ctx()
        if axis is None or ctx is None or axis not in dict(ctx.mesh.shape):
            return None
        key = (entry, "sharded", axis, ctx.mesh)
        if key not in self._jitted:
            rig_dim = 1 if entry == "run_fleet" else 0
            fn = sharding.shard_over(
                lambda x: core(x, self.impl), ctx.mesh, axis,
                arg_axis=rig_dim)
            def counted(x):
                # count under the plain entry name so trace_count(entry)
                # observes sharded retraces too
                self._trace_counts[entry] = \
                    self._trace_counts.get(entry, 0) + 1
                return fn(x)
            self._jitted[key] = jax.jit(counted)
        return self._jitted[key]

    # -- feature / matcher entry points ------------------------------------

    def extract(self, images) -> FeatureSet:
        """FE only: (n_cameras, H, W) -> FeatureSet with a leading
        (n_cameras,) axis, in 2 launches (1 dense + 1 sparse)."""
        self._check_images(images, fleet=False, sequence=False,
                           what="extract")
        return self._jit(
            "extract",
            lambda im: orb.extract_features_batched(
                im, self.pipe.orb, impl=self.impl))(images)

    def match_pair(self, img_l, img_r, feat_l: FeatureSet,
                   feat_r: FeatureSet):
        """FM stage for ONE explicit stereo pair (a pair-batch-of-one
        view of the fused megakernel): returns (MatchSet, DepthSet).
        Depth uses the first pair's left-camera intrinsics."""
        intr = self.rig.pair_intrinsics[0]
        def core(il, ir, fl, fr):
            matches, depth = matching.match_pair_fused(
                il[None], ir[None],
                jax.tree.map(lambda x: x[None], fl),
                jax.tree.map(lambda x: x[None], fr),
                self.pipe.orb, intr, impl=self.impl)
            return jax.tree.map(lambda x: x[0], (matches, depth))
        return self._jit("match_pair", core)(img_l, img_r, feat_l, feat_r)

    def stereo_match(self, feat_l: FeatureSet,
                     feat_r: FeatureSet) -> MatchSet:
        """Best Hamming match in the strip-like search region
        (Sec. II-C1) via the fused dispatch's match-only mode — one
        launch."""
        cfg = self.pipe.orb
        def core(fl, fr):
            dist, idx = ops.match_rectify_fused(
                fl.desc[None], matching._meta(fl)[None],
                fr.desc[None], matching._meta(fr)[None],
                row_band=float(cfg.row_band),
                max_disparity=float(cfg.max_disparity),
                impl=self.impl)
            return matching._match_set(dist[0], idx[0], fl, cfg)
        return self._jit("stereo_match", core)(feat_l, feat_r)

    def temporal_match(self, feat_a: FeatureSet, feat_b: FeatureSet,
                       search_radius: float | None = None,
                       search_radius_y: float | None = None) -> MatchSet:
        """Frame-to-frame matching for the VO backend (match-only fused
        mode, one launch) over a rectangular +-radius window; radii
        default to ``PipelineConfig.temporal_radius`` /
        ``temporal_radius_y`` (y falls back to the x radius)."""
        cfg = self.pipe.orb
        rx = (self.pipe.temporal_radius if search_radius is None
              else float(search_radius))
        ry = search_radius_y
        if ry is None:
            ry = (self.pipe.temporal_radius_y
                  if self.pipe.temporal_radius_y is not None else rx)
        ry = float(ry)
        def core(fa, fb):
            meta_a = matching._meta(fa)
            # Reuse the [0, max_disparity] window as [-rx, +rx] by
            # shifting the left x coordinate.
            meta_a = meta_a.at[:, 0].add(rx)
            dist, idx = ops.match_rectify_fused(
                fa.desc[None], meta_a[None],
                fb.desc[None], matching._meta(fb)[None],
                row_band=ry, max_disparity=2.0 * rx, impl=self.impl)
            return matching._match_set(dist[0], idx[0], fa, cfg)
        return self._jit(("temporal_match", rx, ry), core)(feat_a, feat_b)

    def sad_rectify(self, img_l, img_r, feat_l: FeatureSet,
                    feat_r: FeatureSet, matches: MatchSet):
        """SAD rectification + disparity/depth (Sec. II-C2, III-D) for
        one explicit pair, with IN-KERNEL patch reads
        (``ops.sad_patch_search`` — one launch).  Depth uses the first
        pair's left-camera intrinsics."""
        cfg = self.pipe.orb
        intr = self.rig.pair_intrinsics[0]
        def core(il, ir, fl, fr, m):
            xy_l = fl.xy
            xy_r = fr.xy[m.right_index]
            table = ops.sad_patch_search(
                il[None], ir[None], xy_l[None], xy_r[None],
                sad_window=cfg.sad_window, sad_range=cfg.sad_range,
                impl=self.impl)[0]
            best = (jnp.argmin(table, axis=1).astype(jnp.float32)
                    - float(cfg.sad_range))
            return matching._depth_set(xy_l[:, 0], xy_r, best, m, cfg,
                                       intr)
        return self._jit("sad_rectify", core)(img_l, img_r, feat_l,
                                              feat_r, matches)

    # -- audit --------------------------------------------------------------

    ENTRY_POINTS = ("process_frame", "process_fleet", "extract",
                    "match", "run", "run_fleet")

    def entry_core(self, entry: str, impl: str = "pallas"):
        """The PURE traceable core of one entry point — the exact
        function graph the jitted public entry dispatches, with impl
        pinned and all eager validation / state plumbing stripped, so
        audit tooling can ``jax.make_jaxpr`` / ``jax.eval_shape`` it
        over abstract shapes (no data, no execution).

        ``process_frame`` / ``process_fleet`` cores accept an optional
        trailing camera-mask argument (the DEGRADED graph — same 3
        launches, masking is elementwise jnp).  On a ``localize``
        session the frame / fleet / run cores trace the FULL localized
        graph (frontend + temporal matcher + solve) against the zero
        previous state, which shares the launch graph of every steady
        state.  ``match`` is the FM stage alone over a flat
        (n_pairs,)-leading pair batch (``launch_gate/fm_frame_*``).

        Both ``traced_launches`` (the runtime CI gate numbers) and
        ``repro.analysis`` (the static auditor) trace THESE cores, so
        static counts reconcile with the benchmark rows by
        construction."""
        k = self.pipe.orb.max_features

        def frame_core(im, cm=None):
            out = self._frame_core(im, impl, cm)
            if not self.pipe.localize:
                return out
            prev = localization.zero_state(self.rig.n_pairs, k)
            return self._localize_frame(out, prev, impl)

        def fleet_core(im, cm=None):
            out = self._fleet_core(im, impl, cm)
            if not self.pipe.localize:
                return out
            prev = localization.zero_state(self.rig.n_pairs, k,
                                           int(im.shape[0]))
            return self._localize_fleet(out, prev, impl)

        def run_core(f, fleet):
            if self.pipe.localize:
                return self._run_loc(f, impl, fleet)
            return self._run_core(f, impl, fleet)

        def match_core(il, ir, fl, fr):
            n_rigs = max(1, il.shape[0] // self.rig.n_pairs)
            return self._fm_flat((il, ir, fl, fr), n_rigs, impl)

        cores = {
            "process_frame": frame_core,
            "process_fleet": fleet_core,
            "extract": lambda im: orb.extract_features_batched(
                im, self.pipe.orb, impl=impl,
                precision=self.pipe.precision),
            "match": match_core,
            "run": lambda f: run_core(f, False),
            "run_fleet": lambda f: run_core(f, True),
        }
        try:
            return cores[entry]
        except KeyError:
            raise ValueError(
                f"entry_core supports {sorted(cores)}, "
                f"got {entry!r}") from None

    def traced_launches(self, entry: str, *args) -> int:
        """Trace ``entry``'s core (``entry_core``) shape-only under
        impl='pallas' and return the number of kernel launches in the
        traced graph — the deterministic schedule number the CI launch
        gates enforce (3 per frame / fleet frame), independent of the
        session's impl.  ``process_frame`` / ``process_fleet`` accept an
        optional second camera-mask argument so the DEGRADED budget
        (also 3 — masking is elementwise jnp, not a launch) is gateable
        too.  On a ``localize`` session the frame/fleet/run entries
        trace the FULL localized graph (frontend + temporal matcher +
        solve), so the <= 4 localized budget is gateable the same
        way."""
        core = self.entry_core(entry, impl="pallas")
        with ops.launch_audit() as audit:
            jax.eval_shape(core, *args)
        return audit.count


def session_for(cfg: ORBConfig, intr: CameraIntrinsics | None,
                impl: str | None, n_cameras: int = 2,
                schedule: str = "sequential") -> VisualSystem:
    """Session cache backing the legacy free-function shims: one
    ``VisualSystem`` per (ORBConfig, intrinsics, impl, layout), so
    repeated shim calls reuse jit caches exactly like a held session.
    Cameras pair up in the legacy [L, R, L, R, ...] order.  ``impl`` is
    resolved BEFORE the cache lookup, preserving the legacy functions'
    per-call resolution: an ``ops.use_impl`` scope or a
    ``set_default_impl`` flip selects a different cached session rather
    than silently reusing one pinned to the old impl."""
    return _session_for(cfg, intr, ops.resolve_impl(impl), n_cameras,
                        schedule)


@functools.lru_cache(maxsize=128)
def _session_for(cfg, intr, impl, n_cameras, schedule) -> VisualSystem:
    pairs = tuple((2 * i, 2 * i + 1) for i in range(n_cameras // 2))
    rig = RigConfig(n_cameras=n_cameras, pairs=pairs,
                    intrinsics=intr if intr is not None
                    else CameraIntrinsics())
    return VisualSystem(rig, PipelineConfig(orb=cfg, impl=impl,
                                            schedule=schedule))
