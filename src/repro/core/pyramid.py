"""Image pyramid (paper Sec. III-C, "Image Resizing").

Two-layer pyramid with bilinear interpolation; 1280x720 -> 1067x600 at
the paper's 1.2 scale factor.  Works on float32 images in [0, 255]; the
quantized path rounds back to uint8 levels, matching the FPGA's 8-bit
datapath.

The batched pyramid feeds the whole-frame fused frontend: every level
of every camera goes into ONE dense kernel launch
(``ops.fast_blur_nms_pyramid``), which pads the ragged level shapes
returned by ``level_shapes`` to a common tile grid and masks by true
shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ORBConfig


def bilinear_resize(image: jnp.ndarray, out_hw: tuple[int, int]) -> jnp.ndarray:
    """Bilinear resize of a single-channel image (H, W) -> out_hw."""
    img = image.astype(jnp.float32)
    return jax.image.resize(img, out_hw, method="bilinear")


def build_pyramid(image: jnp.ndarray, cfg: ORBConfig, *,
                  precision: str = "f32") -> list[jnp.ndarray]:
    """Return ``cfg.n_levels`` level images; level 0 is the input.

    precision="f32" (default) emits float32 levels as always.
    precision="uint8" emits uint8 levels — the paper's 8-bit datapath:
    level 0 is the uint8 input unchanged, and each resize runs bilinear
    in f32 then rounds/clips back to uint8.  Because the f32 path with
    ``cfg.quantized`` already rounds+clips every resized level to
    integer values in [0, 255], the uint8 levels are the SAME values in
    a 4x smaller slab."""
    if precision == "uint8":
        levels = [image.astype(jnp.uint8)]
        for lvl in range(1, cfg.n_levels):
            out = bilinear_resize(levels[-1], cfg.level_shape(lvl))
            levels.append(jnp.round(jnp.clip(out, 0.0, 255.0))
                          .astype(jnp.uint8))
        return levels
    img = image.astype(jnp.float32)
    levels = [img]
    for lvl in range(1, cfg.n_levels):
        out = bilinear_resize(levels[-1], cfg.level_shape(lvl))
        if cfg.quantized:
            out = jnp.round(jnp.clip(out, 0.0, 255.0))
        levels.append(out)
    return levels


def level_shapes(cfg: ORBConfig) -> list[tuple[int, int]]:
    """Static (h, w) of every pyramid level — the ragged shapes the
    whole-frame launch pads to a common tile grid."""
    return [cfg.level_shape(lvl) for lvl in range(cfg.n_levels)]


def build_pyramid_batched(images: jnp.ndarray, cfg: ORBConfig, *,
                          precision: str = "f32") -> list[jnp.ndarray]:
    """Batched pyramid: (B, H, W) -> list of (B, h_l, w_l) level images
    (float32, or uint8 under precision="uint8").

    B is the flattened camera batch of the fused frontend; each level is
    one resize over the whole batch.  All levels together feed ONE
    whole-frame dense launch (``ops.fast_blur_nms_pyramid``).
    """
    return jax.vmap(
        lambda im: build_pyramid(im, cfg, precision=precision))(images)
