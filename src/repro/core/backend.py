"""Optimization backend — pose estimation from frontend output.

The paper offloads the frontend and leaves the backend (SLAM / VIO /
Registration) on CPU; to make the localization system end-to-end (and to
reproduce the Tab. I frontend/backend latency split) we implement a
compact stereo visual-odometry backend in JAX:

  stereo depth -> 3-D landmarks -> temporal descriptor matching ->
  weighted Kabsch (closed-form SE(3)) -> optional Gauss-Newton
  reprojection refinement -> trajectory integration.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import CameraIntrinsics, DepthSet, FeatureSet


def triangulate(feat_l: FeatureSet, depth: DepthSet,
                intr: CameraIntrinsics) -> jnp.ndarray:
    """Back-project left features with stereo depth -> (K, 3) points."""
    z = depth.depth
    x = (feat_l.xy[:, 0] - intr.cx) / intr.fx * z
    y = (feat_l.xy[:, 1] - intr.cy) / intr.fy * z
    return jnp.stack([x, y, z], axis=-1)


def kabsch(pts_a: jnp.ndarray, pts_b: jnp.ndarray,
           weights: jnp.ndarray):
    """Weighted closed-form rigid alignment: find (R, t) minimizing
    sum_i w_i || R a_i + t - b_i ||^2.  pts: (K, 3); weights: (K,)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-6)
    ca = jnp.sum(w[:, None] * pts_a, axis=0)
    cb = jnp.sum(w[:, None] * pts_b, axis=0)
    a0 = pts_a - ca
    b0 = pts_b - cb
    h = (w[:, None] * a0).T @ b0                      # (3, 3)
    u, _, vt = jnp.linalg.svd(h)
    d = jnp.sign(jnp.linalg.det(vt.T @ u.T))
    s = jnp.diag(jnp.asarray([1.0, 1.0, 1.0])).at[2, 2].set(d)
    r = vt.T @ s @ u.T
    t = cb - r @ ca
    return r, t


def reprojection_residuals(r, t, pts_a, xy_b, intr: CameraIntrinsics):
    p = pts_a @ r.T + t
    z = jnp.maximum(p[:, 2], 1e-3)
    u = intr.fx * p[:, 0] / z + intr.cx
    v = intr.fy * p[:, 1] / z + intr.cy
    return jnp.stack([u - xy_b[:, 0], v - xy_b[:, 1]], axis=-1)


def _so3_exp(w: jnp.ndarray) -> jnp.ndarray:
    # sinc-form exponential map: differentiable at w = 0 (GN linearizes
    # around zero delta, so the naive norm form would emit NaN grads).
    theta2 = jnp.dot(w, w)
    theta = jnp.sqrt(theta2 + 1e-16)
    k = jnp.asarray([[0.0, -w[2], w[1]],
                     [w[2], 0.0, -w[0]],
                     [-w[1], w[0], 0.0]])
    a = jnp.sin(theta) / theta
    b = (1.0 - jnp.cos(theta)) / (theta2 + 1e-16)
    return jnp.eye(3) + a * k + b * (k @ k)


def gauss_newton_refine(r, t, pts_a, xy_b, weights,
                        intr: CameraIntrinsics, iters: int = 8,
                        huber_px: float = 5.0, damping: float = 1e-2):
    """Damped (Levenberg) GN on reprojection error over se(3), with a
    Huber robust loss: per-point weight is scaled by min(1, c/|res|), so
    gross mismatches cannot explode the normal equations."""

    def step(carry, _):
        r_c, t_c = carry
        res_c = reprojection_residuals(r_c, t_c, pts_a, xy_b, intr)
        norm = jnp.linalg.norm(res_c, axis=-1)
        w_rob = weights * jnp.minimum(1.0, huber_px
                                      / jnp.maximum(norm, 1e-6))

        def flat_res(delta):
            r_d = _so3_exp(delta[:3]) @ r_c
            t_d = t_c + delta[3:]
            res = reprojection_residuals(r_d, t_d, pts_a, xy_b, intr)
            return (res * w_rob[:, None]).reshape(-1)

        zero = jnp.zeros((6,))
        res0 = flat_res(zero)
        jac = jax.jacfwd(flat_res)(zero)              # (2K, 6)
        jtj = jac.T @ jac
        lm = jtj + damping * jnp.diag(jnp.diag(jtj)) + 1e-6 * jnp.eye(6)
        delta = -jnp.linalg.solve(lm, jac.T @ res0)
        return (_so3_exp(delta[:3]) @ r_c, t_c + delta[3:]), None

    (r_f, t_f), _ = jax.lax.scan(step, (r, t), None, length=iters)
    return r_f, t_f


class PoseEstimate(NamedTuple):
    rotation: jnp.ndarray       # (3, 3)
    translation: jnp.ndarray    # (3,)
    inliers: jnp.ndarray        # scalar int32


def _masked_median(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Median of x over mask (static shape: sort with +inf fill)."""
    n = jnp.sum(mask.astype(jnp.int32))
    filled = jnp.where(mask, x, jnp.inf)
    s = jnp.sort(filled)
    mid = jnp.maximum(n - 1, 0) // 2
    return s[mid]


def estimate_relative_pose(pts_prev: jnp.ndarray, pts_curr: jnp.ndarray,
                           weights: jnp.ndarray, xy_curr: jnp.ndarray,
                           intr: CameraIntrinsics,
                           refine: bool = True,
                           robust_iters: int = 3,
                           gate_scale: float = 4.0) -> PoseEstimate:
    """(R, t) mapping previous-frame points into the current frame.

    Robust cascade (descriptor mismatches and stereo depth quantization
    produce metre-scale 3-D outliers, so plain least squares would be
    poisoned):
      1. translation-first init — at VO frame rates R ~ I, so the
         per-axis masked median of the displacement field is a robust t;
      2. gate 3-D residuals at ``gate_scale`` x median, iterate Kabsch;
      3. gate reprojection residuals, damped Huber Gauss-Newton refine.
    """
    mask0 = weights > 0

    # 1. robust translation-only init (R = I)
    disp = pts_curr - pts_prev                        # (K, 3)
    t0 = jnp.stack([_masked_median(disp[:, i], mask0) for i in range(3)])
    res0 = jnp.linalg.norm(disp - t0, axis=-1)
    med0 = _masked_median(res0, mask0)
    w = jnp.where(res0 <= gate_scale * jnp.maximum(med0, 1e-2),
                  weights, 0.0)

    # 2. gated Kabsch rounds
    def round_(w_c, _):
        r_n, t_n = kabsch(pts_prev, pts_curr, w_c)
        res = jnp.linalg.norm(pts_prev @ r_n.T + t_n - pts_curr, axis=-1)
        med = _masked_median(res, w_c > 0)
        gate = res <= gate_scale * jnp.maximum(med, 1e-3)
        return jnp.where(gate, weights, 0.0), None

    w, _ = jax.lax.scan(round_, w, None, length=robust_iters)
    r, t = kabsch(pts_prev, pts_curr, w)
    if refine:
        # 3. gate reprojection residuals, then damped-Huber Gauss-Newton
        res = jnp.linalg.norm(
            reprojection_residuals(r, t, pts_prev, xy_curr, intr), axis=-1)
        med = _masked_median(res, w > 0)
        w = jnp.where(res <= gate_scale * jnp.maximum(med, 1.0), w, 0.0)
        r, t = gauss_newton_refine(r, t, pts_prev, xy_curr, w, intr)
    return PoseEstimate(r, t, jnp.sum((w > 0).astype(jnp.int32)))


def integrate_trajectory(poses: list[PoseEstimate]) -> jnp.ndarray:
    """Chain relative poses into world positions (T+1, 3), origin start.

    Relative pose maps prev-frame coords to curr-frame coords; the camera
    position therefore updates as p_w <- p_w - R_w t_rel with
    R_w <- R_w R_rel^-1 (standard VO composition).
    """
    pos = [jnp.zeros((3,))]
    r_w = jnp.eye(3)
    for p in poses:
        r_w = r_w @ p.rotation.T
        pos.append(pos[-1] - r_w @ p.translation)
    return jnp.stack(pos)
