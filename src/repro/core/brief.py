"""Rotated BRIEF descriptors (paper Sec. II-B2, III-C) — thin wrappers
over the two-stage kernel pipeline.

Descriptors are computed on the Gaussian-smoothed level image.  The
steering follows the paper's FPGA: only the n sampling pairs are rotated
(S_theta = R_theta S), never the patch, and the rotation is ANGLE-BINNED
— theta is quantized to 12 bins of 30 degrees and the rotated pattern
comes from the precomputed ``pattern.STEER_LUT`` ROM (Sec. III-C),
not from per-keypoint cos/sin + round.  256 binary tests are packed
into 8 x uint32 (the paper's 32 x 8-bit descriptor RAM layout).

The frontend hot path computes descriptors inside the fused sparse
kernel (``ops.orient_describe_batched`` — one launch per level for all
cameras); ``describe`` below is the software view of that stage for
callers that already hold theta: it quantizes theta with the same
``ref.theta_to_bin`` and reads the same LUT, so given the same theta it
reproduces the kernel output bit-for-bit.  The exact (unbinned) steering
survives as ``kernels.ref.describe_steered`` for quantization-error
measurement.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import ORBConfig
from repro.kernels import ops
from repro.kernels import ref as _ref


def describe(smoothed: jnp.ndarray, xy: jnp.ndarray,
             theta: jnp.ndarray) -> jnp.ndarray:
    """Compute LUT-steered rBRIEF descriptors for one image.

    smoothed: (H, W) float32 smoothed level image; xy: (K, 2) int32 level
    coords; theta: (K,) float32.
    Returns (K, 8) uint32.
    """
    return _ref.lut_descriptor(_ref.extract_patches(smoothed, xy),
                               _ref.theta_to_bin(theta))


def smooth(level_img: jnp.ndarray, cfg: ORBConfig,
           impl: str | None = None) -> jnp.ndarray:
    """Paper's Image Smoothing module: 7x7 Gaussian (Pallas kernel)."""
    return ops.gaussian_blur7(level_img, quantized=cfg.quantized, impl=impl)
