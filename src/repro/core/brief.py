"""Rotated BRIEF descriptors (paper Sec. II-B2, III-C).

Descriptors are computed on the Gaussian-smoothed level image.  The
steering follows the paper: only the n sampling pairs are rotated
(S_theta = R_theta S), never the patch.  256 binary tests packed into
8 x uint32 (the paper's 32 x 8-bit descriptor RAM layout).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pattern
from repro.core.fast import PATCH, RADIUS
from repro.core.types import ORBConfig
from repro.kernels import ops

_N = pattern.N_PAIRS
_WORDS = _N // 32
# Bit weights per pair within its word: bit i of word i // 32.
_BIT_WEIGHT = (jnp.uint32(1) << jnp.arange(_N, dtype=jnp.uint32) % 32)
_WORD_ID = jnp.arange(_N) // 32


def steered_offsets(theta: jnp.ndarray):
    """Rotate the pattern by theta (paper Eq. 3).  theta: scalar.
    Returns int32 (N, 2) offsets for A and B points."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    pa = jnp.asarray(pattern.PATTERN_A, dtype=jnp.float32)
    pb = jnp.asarray(pattern.PATTERN_B, dtype=jnp.float32)

    def rot(p):
        x = c * p[:, 0] - s * p[:, 1]
        y = s * p[:, 0] + c * p[:, 1]
        return jnp.stack([jnp.round(x), jnp.round(y)], axis=-1).astype(
            jnp.int32)

    return rot(pa), rot(pb)


def _pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(N,) bool -> (8,) uint32."""
    weighted = bits.astype(jnp.uint32) * _BIT_WEIGHT
    return jax.ops.segment_sum(weighted, _WORD_ID, num_segments=_WORDS)


def describe(smoothed: jnp.ndarray, xy: jnp.ndarray,
             theta: jnp.ndarray) -> jnp.ndarray:
    """Compute rBRIEF descriptors.

    smoothed: (H, W) float32 smoothed level image; xy: (K, 2) int32 level
    coords (>= border from edges); theta: (K,) float32.
    Returns (K, 8) uint32.
    """
    padded = jnp.pad(smoothed.astype(jnp.float32), RADIUS, mode="edge")

    def one(pt, th):
        patch = jax.lax.dynamic_slice(padded, (pt[1], pt[0]), (PATCH, PATCH))
        a, b = steered_offsets(th)
        pa = patch[a[:, 1] + RADIUS, a[:, 0] + RADIUS]
        pb = patch[b[:, 1] + RADIUS, b[:, 0] + RADIUS]
        # paper Eq. 2: tau = 1 iff p(A) < p(B)
        return _pack_bits(pa < pb)

    return jax.vmap(one)(xy, theta)


def smooth(level_img: jnp.ndarray, cfg: ORBConfig,
           impl: str | None = None) -> jnp.ndarray:
    """Paper's Image Smoothing module: 7x7 Gaussian (Pallas kernel)."""
    return ops.gaussian_blur7(level_img, quantized=cfg.quantized, impl=impl)
