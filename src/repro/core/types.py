"""Shared dataclasses / pytrees for the visual frontend."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


class FeatureSet(NamedTuple):
    """Static-shape feature list (top-K with validity mask).

    The FPGA emits a variable-length feature stream into RAM; XLA needs
    static shapes, so we keep the K strongest corners and a mask.  All
    arrays share the leading K axis.
    """

    xy: jnp.ndarray       # (K, 2) float32 — (x, y) in *level-0* pixel coords
    level: jnp.ndarray    # (K,)  int32   — pyramid level the point came from
    score: jnp.ndarray    # (K,)  float32 — FAST corner score
    theta: jnp.ndarray    # (K,)  float32 — patch orientation (radians)
    desc: jnp.ndarray     # (K, 8) uint32 — 256-bit rBRIEF descriptor
    valid: jnp.ndarray    # (K,)  bool

    @property
    def k(self) -> int:
        return self.xy.shape[0]

    def count(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))


class StereoOutput(NamedTuple):
    """One processed frame: per-pair features, matches, and depth.

    Produced by the ``VisualSystem`` session (and the legacy frame
    shims).  Field leading axes depend on the entry point: a processed
    frame carries ``(n_pairs,)``, a fleet frame ``(n_rigs, n_pairs)``,
    and a sequence prepends ``(T,)``.
    """

    features_l: "FeatureSet"
    features_r: "FeatureSet"
    matches: "MatchSet"
    depth: "DepthSet"


class PoseSet(NamedTuple):
    """Relative rig pose(s) from the localization backend.

    ``rotation``/``translation`` map previous-frame rig coordinates into
    the current frame: ``p_curr = R @ p_prev + t``.  ``valid`` is False
    (and the pose exactly identity) whenever the solve was degenerate —
    first frame, < 3 usable correspondences, collapsed geometry — so a
    consumer integrating a trajectory can skip the step instead of
    ingesting garbage; the fields are NEVER NaN.  Leading axes follow
    the entry point: none for ``process_frame``, ``(n_rigs,)`` for
    ``process_fleet``, ``(T,)`` / ``(T, n_rigs)`` for sequences.
    """

    rotation: jnp.ndarray      # (..., 3, 3) float32
    translation: jnp.ndarray   # (..., 3)    float32
    inliers: jnp.ndarray       # (...,)      int32 — final solve support
    valid: jnp.ndarray         # (...,)      bool


class LocalizationOutput(NamedTuple):
    """One localized frame: the stereo frontend output plus the backend
    quantities derived from it.

    ``points`` are rig-frame 3-D back-projections of the left features
    ((..., n_pairs, K, 3) — a point is meaningful iff the matching
    ``stereo.features_l.valid & stereo.depth.valid`` lane is, otherwise
    it is exactly zero); ``pose`` is the relative ego-motion since the
    previous processed frame (see ``PoseSet``).  The frontend fields
    are also exposed as delegating properties so existing
    ``StereoOutput`` consumers read either type.
    """

    stereo: "StereoOutput"
    points: jnp.ndarray        # (..., n_pairs, K, 3) float32, rig frame
    pose: "PoseSet"

    @property
    def features_l(self) -> "FeatureSet":
        return self.stereo.features_l

    @property
    def features_r(self) -> "FeatureSet":
        return self.stereo.features_r

    @property
    def matches(self) -> "MatchSet":
        return self.stereo.matches

    @property
    def depth(self) -> "DepthSet":
        return self.stereo.depth


class LocalizationState(NamedTuple):
    """Previous-frame memory the temporal pose solve consumes: the last
    frame's left descriptors + matcher meta (to temporal-match against),
    its rig-frame points, and the combined feature-and-depth usability
    mask.  Derivable from any ``LocalizationOutput`` slice
    (``repro.localization.state_from``), which is how the serving tier
    keeps per-rig state across re-bucketed fleet batches.  Leading axes:
    ``(n_pairs, K)`` per rig, ``(n_rigs, n_pairs, K)`` for a fleet."""

    desc: jnp.ndarray          # (..., n_pairs, K, 8) uint32
    meta: jnp.ndarray          # (..., n_pairs, K, 4) float32 (x,y,lvl,valid)
    points: jnp.ndarray        # (..., n_pairs, K, 3) float32, rig frame
    valid: jnp.ndarray         # (..., n_pairs, K) bool — feature & depth


class MatchSet(NamedTuple):
    """Stereo matches: one candidate per left feature."""

    right_index: jnp.ndarray   # (K,) int32 — index into right FeatureSet
    distance: jnp.ndarray      # (K,) int32 — Hamming distance of best match
    valid: jnp.ndarray         # (K,) bool  — passed band/disparity/dist gates

    def count(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))


class DepthSet(NamedTuple):
    """Per-left-feature disparity/depth after SAD rectification."""

    disparity: jnp.ndarray     # (K,) float32 — rectified disparity (px)
    depth: jnp.ndarray         # (K,) float32 — fx * baseline / disparity (m)
    xy_right: jnp.ndarray      # (K, 2) float32 — rectified right coordinates
    valid: jnp.ndarray         # (K,) bool

    def count(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))


@dataclasses.dataclass(frozen=True)
class CameraIntrinsics:
    fx: float = 460.0
    fy: float = 460.0
    cx: float = 640.0
    cy: float = 360.0
    baseline: float = 0.12    # stereo baseline in metres

    def scaled(self, s: float) -> "CameraIntrinsics":
        return CameraIntrinsics(self.fx * s, self.fy * s,
                                self.cx * s, self.cy * s, self.baseline)


@dataclasses.dataclass(frozen=True)
class ORBConfig:
    """Visual-frontend configuration (paper defaults)."""

    height: int = 720
    width: int = 1280
    n_levels: int = 2               # two-layer pyramid (Sec. III-C)
    scale_factor: float = 1.2       # 1280x720 -> 1067x600, as in the paper
    max_features: int = 1000        # static top-K (paper measures ~961)
    fast_threshold: int = 20        # FAST intensity threshold
    nms: bool = True                # 3x3 non-max suppression on score map
    border: int = 16                # keep 31x31 patches inside the image
    # --- matching ---
    row_band: int = 2               # strip-like epipolar search half-height
    max_disparity: int = 96         # search range x_L - x_R in [0, max_disp]
    max_hamming: int = 64           # match acceptance threshold (of 256)
    # --- SAD rectification ---
    sad_window: int = 11            # 11x11 patch (Sec. III-D)
    sad_range: int = 5              # slide +-range pixels
    # --- arithmetic (paper Sec. III-C word-length optimization) ---
    quantized: bool = True          # uint8 image path with int32 accumulators

    def level_shape(self, level: int) -> tuple[int, int]:
        """(H, W) of a pyramid level, matching the paper's rounding."""
        h, w = self.height, self.width
        for _ in range(level):
            h = int(round(h / self.scale_factor))
            w = int(round(w / self.scale_factor))
        return h, w

    def features_per_level(self) -> list[int]:
        """Split the top-K budget across levels proportional to area."""
        areas = [self.level_shape(l)[0] * self.level_shape(l)[1]
                 for l in range(self.n_levels)]
        total = sum(areas)
        ks = [max(1, int(self.max_features * a / total)) for a in areas]
        ks[0] += self.max_features - sum(ks)
        return ks
