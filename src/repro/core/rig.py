"""Rig description for the `VisualSystem` session API.

A ``RigConfig`` captures everything the paper configures ONCE about the
camera hardware (Sec. III, Fig. 4): how many cameras there are, how they
group into stereo pairs, each camera's intrinsics, and the trigger/sync
spec (Sec. III-A).  The session (``repro.core.pipeline.VisualSystem``)
is built from one ``RigConfig`` plus one ``PipelineConfig`` and then
streams frames through a fixed schedule — no per-call cfg/intr/impl
threading.

The pair layout is explicit instead of the old hard-coded "4 cameras =
2 pairs in [L, R, L, R] order": ``pairs`` is a tuple of (left, right)
camera indices, so asymmetric rigs (one stereo pair plus a mono camera,
6-camera rings, ...) describe themselves and the fleet batcher can fold
any rig shape into the kernels' flat camera/pair batch axes.
"""

from __future__ import annotations

import dataclasses

from repro.core.sync import TriggerConfig
from repro.core.types import CameraIntrinsics

_SYNC_POLICIES = ("hardware", "software")


class DesyncError(RuntimeError):
    """A frame's camera time tags spread beyond the rig's tolerance.

    Raised by ``VisualSystem.process_frame`` for hardware-trigger rigs,
    whose trigger generator stamps every camera from one clock (paper
    Sec. III-A) — any nonzero spread means the sync hardware is broken
    or the tags do not come from it.  Software-sync rigs log the jitter
    instead of raising.
    """


@dataclasses.dataclass(frozen=True)
class RigConfig:
    """Static description of one camera rig.

    ``intrinsics`` may be a single ``CameraIntrinsics`` (shared by all
    cameras — the paper's quad rig) or one per camera; it is normalized
    to a per-camera tuple.  ``sync`` defaults to a ``TriggerConfig``
    with a matching camera count.  ``sync_policy`` selects the desync
    discipline ``VisualSystem.process_frame`` applies to per-frame time
    tags: ``"hardware"`` asserts the trigger-generator guarantee (spread
    <= ``max_desync``, 0.0 by default — the paper's 0-cycle desync),
    ``"software"`` only records the observed jitter.
    """

    n_cameras: int = 4
    pairs: tuple[tuple[int, int], ...] = ((0, 1), (2, 3))
    intrinsics: tuple[CameraIntrinsics, ...] | CameraIntrinsics = \
        CameraIntrinsics()
    sync: TriggerConfig | None = None
    sync_policy: str = "hardware"
    max_desync: float = 0.0      # tolerated per-frame tag spread (s)

    def __post_init__(self):
        if self.n_cameras < 1:
            raise ValueError(f"n_cameras must be >= 1, got {self.n_cameras}")
        if isinstance(self.intrinsics, CameraIntrinsics):
            object.__setattr__(self, "intrinsics",
                               (self.intrinsics,) * self.n_cameras)
        else:
            object.__setattr__(self, "intrinsics", tuple(self.intrinsics))
        if len(self.intrinsics) != self.n_cameras:
            raise ValueError(
                f"got {len(self.intrinsics)} intrinsics for "
                f"{self.n_cameras} cameras")
        pairs = tuple((int(l), int(r)) for l, r in self.pairs)
        object.__setattr__(self, "pairs", pairs)
        if not pairs:
            raise ValueError("a rig needs at least one stereo pair")
        for l, r in pairs:
            if not (0 <= l < self.n_cameras and 0 <= r < self.n_cameras):
                raise ValueError(
                    f"pair ({l}, {r}) references a camera outside "
                    f"[0, {self.n_cameras})")
            if l == r:
                raise ValueError(f"pair ({l}, {r}) uses one camera twice")
        if self.sync is None:
            object.__setattr__(self, "sync",
                               TriggerConfig(n_cameras=self.n_cameras))
        if self.sync.n_cameras != self.n_cameras:
            raise ValueError(
                f"sync.n_cameras={self.sync.n_cameras} does not match "
                f"rig n_cameras={self.n_cameras}")
        if self.sync_policy not in _SYNC_POLICIES:
            raise ValueError(
                f"sync_policy must be one of {_SYNC_POLICIES}, "
                f"got {self.sync_policy!r}")
        if self.max_desync < 0.0:
            raise ValueError(f"max_desync must be >= 0, got {self.max_desync}")

    # -- layout views ------------------------------------------------------

    @property
    def n_pairs(self) -> int:
        return len(self.pairs)

    @property
    def left_cams(self) -> tuple[int, ...]:
        return tuple(l for l, _ in self.pairs)

    @property
    def right_cams(self) -> tuple[int, ...]:
        return tuple(r for _, r in self.pairs)

    @property
    def pair_intrinsics(self) -> tuple[CameraIntrinsics, ...]:
        """Per-pair intrinsics (the pair's LEFT camera drives the
        disparity -> depth conversion)."""
        return tuple(self.intrinsics[l] for l in self.left_cams)

    @property
    def homogeneous_intrinsics(self) -> bool:
        return all(ic == self.intrinsics[0] for ic in self.intrinsics[1:])

    # -- constructors ------------------------------------------------------

    @classmethod
    def quad(cls, intrinsics: CameraIntrinsics = CameraIntrinsics(),
             **kwargs) -> "RigConfig":
        """The paper's rig: 4 cameras, front pair (0, 1) + back pair
        (2, 3), one shared set of intrinsics."""
        return cls(n_cameras=4, pairs=((0, 1), (2, 3)),
                   intrinsics=intrinsics, **kwargs)

    @classmethod
    def stereo(cls, intrinsics: CameraIntrinsics = CameraIntrinsics(),
               **kwargs) -> "RigConfig":
        """A single stereo pair (cameras 0 = left, 1 = right)."""
        return cls(n_cameras=2, pairs=((0, 1),), intrinsics=intrinsics,
                   **kwargs)
