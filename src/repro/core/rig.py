"""Rig description for the `VisualSystem` session API.

A ``RigConfig`` captures everything the paper configures ONCE about the
camera hardware (Sec. III, Fig. 4): how many cameras there are, how they
group into stereo pairs, each camera's intrinsics, and the trigger/sync
spec (Sec. III-A).  The session (``repro.core.pipeline.VisualSystem``)
is built from one ``RigConfig`` plus one ``PipelineConfig`` and then
streams frames through a fixed schedule — no per-call cfg/intr/impl
threading.

The pair layout is explicit instead of the old hard-coded "4 cameras =
2 pairs in [L, R, L, R] order": ``pairs`` is a tuple of (left, right)
camera indices, so asymmetric rigs (one stereo pair plus a mono camera,
6-camera rings, ...) describe themselves and the fleet batcher can fold
any rig shape into the kernels' flat camera/pair batch axes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sync import TriggerConfig
from repro.core.types import CameraIntrinsics

_SYNC_POLICIES = ("hardware", "software")
_DESYNC_POLICIES = ("raise", "drop_frame", "degrade")


class DesyncError(RuntimeError):
    """A frame's camera time tags spread beyond the rig's tolerance.

    Raised by ``VisualSystem.process_frame`` for hardware-trigger rigs,
    whose trigger generator stamps every camera from one clock (paper
    Sec. III-A) — any nonzero spread means the sync hardware is broken
    or the tags do not come from it.  Software-sync rigs log the jitter
    instead of raising.
    """


@dataclasses.dataclass(frozen=True)
class RigConfig:
    """Static description of one camera rig.

    ``intrinsics`` may be a single ``CameraIntrinsics`` (shared by all
    cameras — the paper's quad rig) or one per camera; it is normalized
    to a per-camera tuple.  ``sync`` defaults to a ``TriggerConfig``
    with a matching camera count.  ``sync_policy`` selects the desync
    discipline ``VisualSystem.process_frame`` applies to per-frame time
    tags: ``"hardware"`` asserts the trigger-generator guarantee (spread
    <= ``max_desync``, 0.0 by default — the paper's 0-cycle desync),
    ``"software"`` only records the observed jitter.

    ``desync_policy`` selects what a spread beyond ``max_desync`` DOES:

      - ``None`` (default) — the legacy split: hardware rigs raise
        ``DesyncError``, software rigs only log the jitter.
      - ``"raise"`` — raise ``DesyncError`` (both sync policies).
      - ``"drop_frame"`` — the frame is not processed:
        ``process_frame`` returns ``None``; a fleet entry masks the
        whole offending rig out of the batch instead (shapes are
        static — a dropped rig cannot leave the fleet array).
      - ``"degrade"`` — process the frame with the offending cameras
        masked out (``sync.desync_camera_mask``: keep the cameras whose
        tags agree with the frame's median tag), so the rig degrades to
        its surviving stereo pairs instead of failing.
    """

    n_cameras: int = 4
    pairs: tuple[tuple[int, int], ...] = ((0, 1), (2, 3))
    intrinsics: tuple[CameraIntrinsics, ...] | CameraIntrinsics = \
        CameraIntrinsics()
    sync: TriggerConfig | None = None
    sync_policy: str = "hardware"
    max_desync: float = 0.0      # tolerated per-frame tag spread (s)
    desync_policy: str | None = None   # None = legacy raise/log split
    # Per-pair camera->rig rotation (the pair's LEFT camera frame into
    # the shared rig frame), as nested float tuples so the config stays
    # hashable.  None = identity for every pair (a forward-looking rig);
    # ``quad()`` sets the back pair's 180-degree yaw so the localization
    # backend fuses both pairs' 3-D points into ONE rig-frame solve.
    pair_rotations: tuple | None = None

    def __post_init__(self):
        if self.n_cameras < 1:
            raise ValueError(f"n_cameras must be >= 1, got {self.n_cameras}")
        if isinstance(self.intrinsics, CameraIntrinsics):
            object.__setattr__(self, "intrinsics",
                               (self.intrinsics,) * self.n_cameras)
        else:
            object.__setattr__(self, "intrinsics", tuple(self.intrinsics))
        if len(self.intrinsics) != self.n_cameras:
            raise ValueError(
                f"got {len(self.intrinsics)} intrinsics for "
                f"{self.n_cameras} cameras")
        pairs = tuple((int(l), int(r)) for l, r in self.pairs)
        object.__setattr__(self, "pairs", pairs)
        if not pairs:
            raise ValueError("a rig needs at least one stereo pair")
        for l, r in pairs:
            if not (0 <= l < self.n_cameras and 0 <= r < self.n_cameras):
                raise ValueError(
                    f"pair ({l}, {r}) references a camera outside "
                    f"[0, {self.n_cameras})")
            if l == r:
                raise ValueError(f"pair ({l}, {r}) uses one camera twice")
        if self.sync is None:
            object.__setattr__(self, "sync",
                               TriggerConfig(n_cameras=self.n_cameras))
        if self.sync.n_cameras != self.n_cameras:
            raise ValueError(
                f"sync.n_cameras={self.sync.n_cameras} does not match "
                f"rig n_cameras={self.n_cameras}")
        if self.sync_policy not in _SYNC_POLICIES:
            raise ValueError(
                f"sync_policy must be one of {_SYNC_POLICIES}, "
                f"got {self.sync_policy!r}")
        if self.desync_policy not in (None,) + _DESYNC_POLICIES:
            raise ValueError(
                f"desync_policy must be None or one of "
                f"{_DESYNC_POLICIES}, got {self.desync_policy!r}")
        if self.max_desync < 0.0:
            raise ValueError(f"max_desync must be >= 0, got {self.max_desync}")
        if self.pair_rotations is not None:
            rots = np.asarray(self.pair_rotations, dtype=np.float64)
            if rots.shape != (len(pairs), 3, 3):
                raise ValueError(
                    f"pair_rotations shape {rots.shape} does not match "
                    f"({len(pairs)}, 3, 3) — one camera->rig rotation "
                    "per stereo pair")
            for i, r in enumerate(rots):
                if not np.allclose(r @ r.T, np.eye(3), atol=1e-6):
                    raise ValueError(
                        f"pair_rotations[{i}] is not a rotation matrix "
                        "(R @ R.T != I)")
            object.__setattr__(
                self, "pair_rotations",
                tuple(tuple(tuple(float(v) for v in row) for row in r)
                      for r in rots))

    # -- layout views ------------------------------------------------------

    @property
    def n_pairs(self) -> int:
        return len(self.pairs)

    @property
    def left_cams(self) -> tuple[int, ...]:
        return tuple(l for l, _ in self.pairs)

    @property
    def right_cams(self) -> tuple[int, ...]:
        return tuple(r for _, r in self.pairs)

    @property
    def pair_intrinsics(self) -> tuple[CameraIntrinsics, ...]:
        """Per-pair intrinsics (the pair's LEFT camera drives the
        disparity -> depth conversion)."""
        return tuple(self.intrinsics[l] for l in self.left_cams)

    @property
    def homogeneous_intrinsics(self) -> bool:
        return all(ic == self.intrinsics[0] for ic in self.intrinsics[1:])

    def pair_rotation_array(self) -> np.ndarray:
        """(n_pairs, 3, 3) float32 camera->rig rotations (identity rows
        when ``pair_rotations`` is None) — the layout the localization
        backend folds every pair's 3-D points through."""
        if self.pair_rotations is None:
            return np.broadcast_to(np.eye(3, dtype=np.float32),
                                   (self.n_pairs, 3, 3)).copy()
        return np.asarray(self.pair_rotations, dtype=np.float32)

    def pair_mask(self, camera_mask):
        """Per-pair validity from a per-camera validity mask: a stereo
        pair survives iff BOTH of its cameras are alive.  ``camera_mask``
        is (..., n_cameras) bool; returns (..., n_pairs) bool — the
        degraded-rig rule ``process_frame(camera_mask=...)`` applies."""
        m = np.asarray(camera_mask, dtype=bool)
        if m.shape[-1] != self.n_cameras:
            raise ValueError(
                f"camera_mask last axis is {m.shape[-1]} but the rig "
                f"has {self.n_cameras} cameras")
        return (m[..., list(self.left_cams)]
                & m[..., list(self.right_cams)])

    # -- constructors ------------------------------------------------------

    @classmethod
    def quad(cls, intrinsics: CameraIntrinsics = CameraIntrinsics(),
             **kwargs) -> "RigConfig":
        """The paper's rig: 4 cameras, front pair (0, 1) + back pair
        (2, 3), one shared set of intrinsics.  The back pair looks along
        -z (180-degree yaw — ``data.scenes.camera_poses``), so its
        camera->rig rotation is the xz flip; callers may override
        ``pair_rotations`` for a different physical layout."""
        kwargs.setdefault(
            "pair_rotations",
            (((1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0)),
             ((-1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, -1.0))))
        return cls(n_cameras=4, pairs=((0, 1), (2, 3)),
                   intrinsics=intrinsics, **kwargs)

    @classmethod
    def stereo(cls, intrinsics: CameraIntrinsics = CameraIntrinsics(),
               **kwargs) -> "RigConfig":
        """A single stereo pair (cameras 0 = left, 1 = right)."""
        return cls(n_cameras=2, pairs=((0, 1),), intrinsics=intrinsics,
                   **kwargs)
