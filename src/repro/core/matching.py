"""Feature matching — the paper's Feature Matcher block (Fig. 3e).

The FM stage mirrors the paper's ONE hardware block (Sec. III-D): the
hot path is ``match_pair_fused`` — Search Region Decision + Hamming
Compare + SAD Correction and Disparity Computing in a SINGLE fused
Pallas launch per frame, batched over stereo pairs
(``ops.match_rectify_fused``).  The standalone entry points route
through the same dispatch: ``stereo_match`` / ``temporal_match`` use its
match-only mode (one launch, no SAD) and ``sad_rectify`` uses the
in-kernel SAD sweep (``ops.sad_patch_search``), so none of them runs the
old host-graph patch-gather chain.

The pre-fusion schedule — separate ``hamming_match`` kernel, host-graph
``_gather_patches`` (full-image pad + 2*K vmapped ``dynamic_slice`` per
pair, twice) and ``sad_search`` kernel — survives as
``match_pair_unfused`` (+ ``stereo_match_unfused`` /
``sad_rectify_unfused``): the oracle the fused path is pinned against
bit-for-bit in ``tests/test_matcher_fused.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              MatchSet, ORBConfig)
from repro.kernels import ops
from repro.kernels import ref as _ref


def _meta(feat: FeatureSet) -> jnp.ndarray:
    """(..., K) FeatureSet -> (..., K, 4) float32 matcher meta rows of
    (x, y, level, valid); works for unbatched and pair-batched sets."""
    return jnp.stack([feat.xy[..., 0], feat.xy[..., 1],
                      feat.level.astype(jnp.float32),
                      feat.valid.astype(jnp.float32)], axis=-1)


def _match_set(dist, idx, feat_l: FeatureSet, cfg: ORBConfig) -> MatchSet:
    """Acceptance gates + the index-resolution rule shared by every
    matcher entry point: a match is valid iff a candidate exists, it
    passes ``max_hamming`` and the left feature is real; invalid rows
    resolve to right index 0 (the fused kernel bakes the same rule into
    its SAD stage)."""
    valid = (idx >= 0) & (dist <= cfg.max_hamming) & feat_l.valid
    return MatchSet(right_index=jnp.where(valid, idx, 0),
                    distance=dist, valid=valid)


def _depth_set(x_l, rxy, best, matches: MatchSet, cfg: ORBConfig,
               intr: CameraIntrinsics) -> DepthSet:
    """Disparity/depth computation shared by the fused and unfused
    paths: ``best`` is the SAD-argmin offset (already minus sad_range),
    ``rxy`` the effective right feature coords."""
    x_r_rect = rxy[..., 0] + best
    disparity = x_l - x_r_rect
    valid = matches.valid & (disparity > 0.5)
    depth = jnp.where(valid, intr.fx * intr.baseline
                      / jnp.maximum(disparity, 0.5), 0.0)
    xy_right = jnp.stack([x_r_rect, rxy[..., 1]], axis=-1)
    return DepthSet(disparity=jnp.where(valid, disparity, 0.0),
                    depth=depth, xy_right=xy_right, valid=valid)


def match_pair_fused(imgs_l: jnp.ndarray, imgs_r: jnp.ndarray,
                     feat_l: FeatureSet, feat_r: FeatureSet,
                     cfg: ORBConfig, intr: CameraIntrinsics,
                     impl: str | None = None):
    """The whole FM stage of a frame in ONE fused launch.

    All arguments carry a leading (P,) stereo-pair axis (images
    (P, H, W), FeatureSet fields (P, K, ...)); the pair axis is folded
    into the kernel grid instead of ``vmap``.  Returns (MatchSet,
    DepthSet) with leading (P,) axes — bit-exact against
    ``match_pair_unfused`` per pair (tests pin it)."""
    dist, idx, rxy, sad = ops.match_rectify_fused(
        feat_l.desc, _meta(feat_l), feat_r.desc, _meta(feat_r),
        imgs_l, imgs_r,
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity),
        max_hamming=int(cfg.max_hamming),
        sad_window=cfg.sad_window, sad_range=cfg.sad_range, impl=impl)
    matches = _match_set(dist, idx, feat_l, cfg)
    best = sad.astype(jnp.float32) - float(cfg.sad_range)
    depth = _depth_set(feat_l.xy[..., 0], rxy, best, matches, cfg, intr)
    return matches, depth


def match_pair_unfused(img_l: jnp.ndarray, img_r: jnp.ndarray,
                       feat_l: FeatureSet, feat_r: FeatureSet,
                       cfg: ORBConfig, intr: CameraIntrinsics,
                       impl: str | None = None):
    """Pre-fusion FM schedule for ONE stereo pair: the two-kernel +
    host-graph-gather path (``hamming_match`` kernel, pad/dynamic_slice
    patch gathers, ``sad_search`` kernel).  Kept as the oracle
    ``match_pair_fused`` is pinned against bit-for-bit."""
    matches = stereo_match_unfused(feat_l, feat_r, cfg, impl=impl)
    depth = sad_rectify_unfused(img_l, img_r, feat_l, feat_r, matches,
                                cfg, intr, impl=impl)
    return matches, depth


def stereo_match(feat_l: FeatureSet, feat_r: FeatureSet,
                 cfg: ORBConfig, impl: str | None = None) -> MatchSet:
    """Best Hamming match in the strip-like search region (Sec. II-C1),
    via the fused dispatch's match-only mode (one launch)."""
    dist, idx = ops.match_rectify_fused(
        feat_l.desc[None], _meta(feat_l)[None],
        feat_r.desc[None], _meta(feat_r)[None],
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity), impl=impl)
    return _match_set(dist[0], idx[0], feat_l, cfg)


def stereo_match_unfused(feat_l: FeatureSet, feat_r: FeatureSet,
                         cfg: ORBConfig,
                         impl: str | None = None) -> MatchSet:
    """Pre-fusion stereo matcher: the standalone ``hamming_match``
    kernel — the oracle half of ``match_pair_unfused``."""
    dist, idx = ops.hamming_match(
        feat_l.desc, _meta(feat_l), feat_r.desc, _meta(feat_r),
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity), impl=impl)
    return _match_set(dist, idx, feat_l, cfg)


def _gather_patches(img: jnp.ndarray, xy: jnp.ndarray, ph: int, pw: int):
    """Gather (ph, pw) patches centered at integer xy from an image.

    Patches are clamped inside via edge padding; xy: (K, 2) float32.
    Thin alias of ``ref.gather_patches`` — the single definition of the
    FM patch-read clamp, audited against
    ``ref.gather_patches_bruteforce`` and reproduced in-kernel by
    ``matcher_fused``."""
    return _ref.gather_patches(img, xy, ph, pw)


def sad_rectify(img_l: jnp.ndarray, img_r: jnp.ndarray,
                feat_l: FeatureSet, feat_r: FeatureSet, matches: MatchSet,
                cfg: ORBConfig, intr: CameraIntrinsics,
                impl: str | None = None) -> DepthSet:
    """SAD rectification + disparity/depth (Sec. II-C2, III-D).

    Operates on level-0 images with level-0 coordinates (the pyramid-
    multiplexed FM block of the paper processes both levels; our static
    top-K already merged levels into level-0 coords).  Patch windows are
    read IN-KERNEL from the level-0 slabs (``ops.sad_patch_search``) —
    one launch, no host-graph gather chain."""
    xy_l = feat_l.xy
    xy_r = feat_r.xy[matches.right_index]
    table = ops.sad_patch_search(
        img_l[None], img_r[None], xy_l[None], xy_r[None],
        sad_window=cfg.sad_window, sad_range=cfg.sad_range, impl=impl)[0]
    best = (jnp.argmin(table, axis=1).astype(jnp.float32)
            - float(cfg.sad_range))
    return _depth_set(xy_l[:, 0], xy_r, best, matches, cfg, intr)


def sad_rectify_unfused(img_l: jnp.ndarray, img_r: jnp.ndarray,
                        feat_l: FeatureSet, feat_r: FeatureSet,
                        matches: MatchSet, cfg: ORBConfig,
                        intr: CameraIntrinsics,
                        impl: str | None = None) -> DepthSet:
    """Pre-fusion SAD rectification: host-graph ``_gather_patches``
    (full-image pad + 2*K ``dynamic_slice`` per pair, twice) feeding the
    standalone ``sad_search`` kernel — the oracle half of
    ``match_pair_unfused``."""
    p = cfg.sad_window
    r = cfg.sad_range
    xy_l = feat_l.xy
    xy_r = feat_r.xy[matches.right_index]

    left_patches = _gather_patches(img_l, xy_l, p, p)
    right_strips = _gather_patches(img_r, xy_r, p, p + 2 * r)
    table = ops.sad_search(left_patches, right_strips, impl=impl)
    best = jnp.argmin(table, axis=1).astype(jnp.float32) - float(r)
    return _depth_set(xy_l[:, 0], xy_r, best, matches, cfg, intr)


def temporal_match(feat_a: FeatureSet, feat_b: FeatureSet,
                   cfg: ORBConfig, search_radius: float = 48.0,
                   search_radius_y: float | None = None,
                   impl: str | None = None) -> MatchSet:
    """Frame-to-frame matching for the VO backend: the fused dispatch's
    match-only mode (one launch) with a rectangular search region —
    +-``search_radius`` in x (via shifted meta, reusing the
    [0, max_disparity] window) and +-``search_radius_y`` in y (defaults
    to the x radius, i.e. the square window)."""
    radius_y = search_radius if search_radius_y is None else search_radius_y
    meta_a = _meta(feat_a)
    meta_b = _meta(feat_b)
    # Reuse the [0, max_disparity] window as [-radius, +radius] by
    # shifting the left x coordinate.
    meta_a = meta_a.at[:, 0].add(search_radius)
    dist, idx = ops.match_rectify_fused(
        feat_a.desc[None], meta_a[None], feat_b.desc[None], meta_b[None],
        row_band=float(radius_y), max_disparity=2.0 * search_radius,
        impl=impl)
    return _match_set(dist[0], idx[0], feat_a, cfg)
