"""Feature matching — the paper's Feature Matcher block (Fig. 3e).

The FM stage mirrors the paper's ONE hardware block (Sec. III-D): the
hot path is ``match_pair_fused`` — Search Region Decision + Hamming
Compare + SAD Correction and Disparity Computing in a SINGLE fused
Pallas launch per frame, batched over stereo pairs
(``ops.match_rectify_fused``).  This module is the ENGINE layer the
``VisualSystem`` session (``repro.core.pipeline``) is built on; the old
standalone entry points — ``stereo_match`` / ``temporal_match`` /
``sad_rectify``, which threaded cfg/intr/impl through every call — are
kept as thin deprecation shims over the session methods of the same
name (bit-exact by construction: the session owns the only
implementation).

The pre-fusion schedule — separate ``hamming_match`` kernel, host-graph
``_gather_patches`` (full-image pad + 2*K vmapped ``dynamic_slice`` per
pair, twice) and ``sad_search`` kernel — survives as
``match_pair_unfused`` (+ ``stereo_match_unfused`` /
``sad_rectify_unfused``): the oracle the fused path is pinned against
bit-for-bit in ``tests/test_matcher_fused.py``.
"""

from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              MatchSet, ORBConfig, StereoOutput)
from repro.kernels import ops
from repro.kernels import ref as _ref


#: Smallest disparity (px) accepted as a real stereo observation.  One
#: constant drives BOTH the validity gate and the divisor guard in
#: ``_depth_set``: a match at exactly MIN_DISPARITY is INVALID (the gate
#: is strict), and the depth divisor is only ever the raw disparity of a
#: match that passed the gate — the ``maximum(.., MIN_DISPARITY)`` clamp
#: exists purely to keep the masked-out lanes' division finite, never to
#: manufacture a depth for a ruled-out match (its depth is exactly 0).
#: Before unification the gate used 0.5 and the clamp used a separate
#: literal 0.5 — consistent only by coincidence.
MIN_DISPARITY = 0.5


def _meta(feat: FeatureSet) -> jnp.ndarray:
    """(..., K) FeatureSet -> (..., K, 4) float32 matcher meta rows of
    (x, y, level, valid); works for unbatched and pair-batched sets."""
    return jnp.stack([feat.xy[..., 0], feat.xy[..., 1],
                      feat.level.astype(jnp.float32),
                      feat.valid.astype(jnp.float32)], axis=-1)


def _match_set(dist, idx, feat_l: FeatureSet, cfg: ORBConfig) -> MatchSet:
    """Acceptance gates + the index-resolution rule shared by every
    matcher entry point: a match is valid iff a candidate exists, it
    passes ``max_hamming`` and the left feature is real; invalid rows
    resolve to right index 0 (the fused kernel bakes the same rule into
    its SAD stage)."""
    valid = (idx >= 0) & (dist <= cfg.max_hamming) & feat_l.valid
    return MatchSet(right_index=jnp.where(valid, idx, 0),
                    distance=dist, valid=valid)


def _fx_baseline(intr):
    """Disparity -> depth scale: ``CameraIntrinsics`` (shared scalar
    path, python-float product as before) or a precomputed broadcastable
    ``fx * baseline`` array for heterogeneous per-pair intrinsics."""
    if isinstance(intr, CameraIntrinsics):
        return float(intr.fx) * float(intr.baseline)
    return intr


def _depth_set(x_l, rxy, best, matches: MatchSet, cfg: ORBConfig,
               intr) -> DepthSet:
    """Disparity/depth computation shared by the fused and unfused
    paths: ``best`` is the SAD-argmin offset (already minus sad_range),
    ``rxy`` the effective right feature coords.  ``intr`` is a
    ``CameraIntrinsics`` or a broadcastable ``fx * baseline`` array
    (see ``_fx_baseline``)."""
    x_r_rect = rxy[..., 0] + best
    disparity = x_l - x_r_rect
    valid = matches.valid & (disparity > MIN_DISPARITY)
    # The clamp only sanitizes lanes ``where`` discards (static shapes:
    # every lane divides); any lane with disparity <= MIN_DISPARITY is
    # already invalid above, so a clamped divisor NEVER reaches a depth
    # a consumer may read as real.
    depth = jnp.where(valid, _fx_baseline(intr)
                      / jnp.maximum(disparity, MIN_DISPARITY), 0.0)
    xy_right = jnp.stack([x_r_rect, rxy[..., 1]], axis=-1)
    return DepthSet(disparity=jnp.where(valid, disparity, 0.0),
                    depth=depth, xy_right=xy_right, valid=valid)


def mask_stereo_output(out: StereoOutput, mask_l, mask_r,
                       pair_mask) -> StereoOutput:
    """Graceful-degradation gate on a flat pair-batched ``StereoOutput``:
    AND every validity field with the per-camera / per-pair liveness of
    a degraded rig.  ``mask_l``/``mask_r`` are (P,) bool — liveness of
    each pair's left/right CAMERA; ``pair_mask`` is (P,) bool (normally
    ``mask_l & mask_r``).  Numeric fields are left untouched (they may
    hold values computed from a sanitized dead-camera slab) — consumers
    must consult ``valid``, exactly as they already must for top-K
    padding rows.  With all-true masks this is bit-exact identity, so
    healthy rigs in a degraded fleet batch are unaffected.
    """
    ml = mask_l[..., None]
    mr = mask_r[..., None]
    mp = pair_mask[..., None]
    return StereoOutput(
        features_l=out.features_l._replace(valid=out.features_l.valid & ml),
        features_r=out.features_r._replace(valid=out.features_r.valid & mr),
        matches=out.matches._replace(valid=out.matches.valid & mp),
        depth=out.depth._replace(valid=out.depth.valid & mp),
    )


def match_pair_fused(imgs_l: jnp.ndarray, imgs_r: jnp.ndarray,
                     feat_l: FeatureSet, feat_r: FeatureSet,
                     cfg: ORBConfig, intr, impl: str | None = None):
    """The whole FM stage of a frame in ONE fused launch.

    All arguments carry a leading (P,) stereo-pair axis (images
    (P, H, W), FeatureSet fields (P, K, ...)); the pair axis is folded
    into the kernel grid instead of ``vmap``.  ``intr`` is a shared
    ``CameraIntrinsics`` or a broadcastable per-pair ``fx * baseline``
    array (heterogeneous rigs).  Returns (MatchSet, DepthSet) with
    leading (P,) axes — bit-exact against ``match_pair_unfused`` per
    pair (tests pin it)."""
    dist, idx, rxy, sad = ops.match_rectify_fused(
        feat_l.desc, _meta(feat_l), feat_r.desc, _meta(feat_r),
        imgs_l, imgs_r,
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity),
        max_hamming=int(cfg.max_hamming),
        sad_window=cfg.sad_window, sad_range=cfg.sad_range, impl=impl)
    matches = _match_set(dist, idx, feat_l, cfg)
    best = sad.astype(jnp.float32) - float(cfg.sad_range)
    depth = _depth_set(feat_l.xy[..., 0], rxy, best, matches, cfg, intr)
    return matches, depth


def match_pair_unfused(img_l: jnp.ndarray, img_r: jnp.ndarray,
                       feat_l: FeatureSet, feat_r: FeatureSet,
                       cfg: ORBConfig, intr: CameraIntrinsics,
                       impl: str | None = None):
    """Pre-fusion FM schedule for ONE stereo pair: the two-kernel +
    host-graph-gather path (``hamming_match`` kernel, pad/dynamic_slice
    patch gathers, ``sad_search`` kernel).  Kept as the oracle
    ``match_pair_fused`` is pinned against bit-for-bit."""
    matches = stereo_match_unfused(feat_l, feat_r, cfg, impl=impl)
    depth = sad_rectify_unfused(img_l, img_r, feat_l, feat_r, matches,
                                cfg, intr, impl=impl)
    return matches, depth


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.{old} is deprecated; use {new} on a "
        "repro.core.VisualSystem session (see repro.core.pipeline for "
        "the migration map)", DeprecationWarning, stacklevel=3)


def _shim_session(cfg: ORBConfig, intr: CameraIntrinsics | None,
                  impl: str | None, n_cameras: int = 2,
                  schedule: str = "sequential"):
    from repro.core import pipeline  # deferred: pipeline imports matching
    return pipeline.session_for(cfg, intr, impl, n_cameras=n_cameras,
                                schedule=schedule)


def stereo_match(feat_l: FeatureSet, feat_r: FeatureSet,
                 cfg: ORBConfig, impl: str | None = None) -> MatchSet:
    """DEPRECATED shim for ``VisualSystem.stereo_match``: best Hamming
    match in the strip-like search region (Sec. II-C1), via the fused
    dispatch's match-only mode (one launch)."""
    _deprecated("core.matching.stereo_match", "stereo_match")
    return _shim_session(cfg, None, impl).stereo_match(feat_l, feat_r)


def stereo_match_unfused(feat_l: FeatureSet, feat_r: FeatureSet,
                         cfg: ORBConfig,
                         impl: str | None = None) -> MatchSet:
    """Pre-fusion stereo matcher: the standalone ``hamming_match``
    kernel — the oracle half of ``match_pair_unfused``."""
    dist, idx = ops.hamming_match(
        feat_l.desc, _meta(feat_l), feat_r.desc, _meta(feat_r),
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity), impl=impl)
    return _match_set(dist, idx, feat_l, cfg)


def _gather_patches(img: jnp.ndarray, xy: jnp.ndarray, ph: int, pw: int):
    """Gather (ph, pw) patches centered at integer xy from an image.

    Patches are clamped inside via edge padding; xy: (K, 2) float32.
    Thin alias of ``ref.gather_patches`` — the single definition of the
    FM patch-read clamp, audited against
    ``ref.gather_patches_bruteforce`` and reproduced in-kernel by
    ``matcher_fused``."""
    return _ref.gather_patches(img, xy, ph, pw)


def sad_rectify(img_l: jnp.ndarray, img_r: jnp.ndarray,
                feat_l: FeatureSet, feat_r: FeatureSet, matches: MatchSet,
                cfg: ORBConfig, intr: CameraIntrinsics,
                impl: str | None = None) -> DepthSet:
    """DEPRECATED shim for ``VisualSystem.sad_rectify``: SAD
    rectification + disparity/depth (Sec. II-C2, III-D) with in-kernel
    patch reads (``ops.sad_patch_search`` — one launch, no host-graph
    gather chain)."""
    _deprecated("core.matching.sad_rectify", "sad_rectify")
    return _shim_session(cfg, intr, impl).sad_rectify(
        img_l, img_r, feat_l, feat_r, matches)


def sad_rectify_unfused(img_l: jnp.ndarray, img_r: jnp.ndarray,
                        feat_l: FeatureSet, feat_r: FeatureSet,
                        matches: MatchSet, cfg: ORBConfig,
                        intr: CameraIntrinsics,
                        impl: str | None = None) -> DepthSet:
    """Pre-fusion SAD rectification: host-graph ``_gather_patches``
    (full-image pad + 2*K ``dynamic_slice`` per pair, twice) feeding the
    standalone ``sad_search`` kernel — the oracle half of
    ``match_pair_unfused``."""
    p = cfg.sad_window
    r = cfg.sad_range
    xy_l = feat_l.xy
    xy_r = feat_r.xy[matches.right_index]

    left_patches = _gather_patches(img_l, xy_l, p, p)
    right_strips = _gather_patches(img_r, xy_r, p, p + 2 * r)
    table = ops.sad_search(left_patches, right_strips, impl=impl)
    best = jnp.argmin(table, axis=1).astype(jnp.float32) - float(r)
    return _depth_set(xy_l[:, 0], xy_r, best, matches, cfg, intr)


def temporal_match(feat_a: FeatureSet, feat_b: FeatureSet,
                   cfg: ORBConfig, search_radius: float = 48.0,
                   search_radius_y: float | None = None,
                   impl: str | None = None) -> MatchSet:
    """DEPRECATED shim for ``VisualSystem.temporal_match``:
    frame-to-frame matching for the VO backend via the fused dispatch's
    match-only mode (one launch) with a rectangular search region."""
    _deprecated("core.matching.temporal_match", "temporal_match")
    return _shim_session(cfg, None, impl).temporal_match(
        feat_a, feat_b, search_radius=search_radius,
        search_radius_y=search_radius_y)
