"""Feature matching — the paper's Feature Matcher block (Fig. 3e).

Stereo matcher (fused search-region decision + Hamming argmin, Pallas
kernel) followed by SAD rectification (11x11 window, +-range sweep,
Pallas kernel) and disparity/depth computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              MatchSet, ORBConfig)
from repro.kernels import ops


def _meta(feat: FeatureSet) -> jnp.ndarray:
    return jnp.stack([feat.xy[:, 0], feat.xy[:, 1],
                      feat.level.astype(jnp.float32),
                      feat.valid.astype(jnp.float32)], axis=-1)


def stereo_match(feat_l: FeatureSet, feat_r: FeatureSet,
                 cfg: ORBConfig, impl: str | None = None) -> MatchSet:
    """Best Hamming match in the strip-like search region (Sec. II-C1)."""
    dist, idx = ops.hamming_match(
        feat_l.desc, _meta(feat_l), feat_r.desc, _meta(feat_r),
        row_band=float(cfg.row_band),
        max_disparity=float(cfg.max_disparity), impl=impl)
    valid = (idx >= 0) & (dist <= cfg.max_hamming) & feat_l.valid
    return MatchSet(right_index=jnp.where(valid, idx, 0),
                    distance=dist, valid=valid)


def _gather_patches(img: jnp.ndarray, xy: jnp.ndarray, ph: int, pw: int):
    """Gather (ph, pw) patches centered at integer xy from an image.

    Patches are clamped inside via edge padding; xy: (K, 2) float32."""
    ry, rx = ph // 2, pw // 2
    padded = jnp.pad(img.astype(jnp.float32), ((ry, ry), (rx, rx)),
                     mode="edge")
    xs = jnp.clip(jnp.round(xy[:, 0]).astype(jnp.int32), 0,
                  img.shape[1] - 1)
    ys = jnp.clip(jnp.round(xy[:, 1]).astype(jnp.int32), 0,
                  img.shape[0] - 1)

    def one(x, y):
        return jax.lax.dynamic_slice(padded, (y, x), (ph, pw))

    return jax.vmap(one)(xs, ys)


def sad_rectify(img_l: jnp.ndarray, img_r: jnp.ndarray,
                feat_l: FeatureSet, feat_r: FeatureSet, matches: MatchSet,
                cfg: ORBConfig, intr: CameraIntrinsics,
                impl: str | None = None) -> DepthSet:
    """SAD rectification + disparity/depth (Sec. II-C2, III-D).

    Operates on level-0 images with level-0 coordinates (the pyramid-
    multiplexed FM block of the paper processes both levels; our static
    top-K already merged levels into level-0 coords).
    """
    p = cfg.sad_window
    r = cfg.sad_range
    xy_l = feat_l.xy
    xy_r = feat_r.xy[matches.right_index]

    left_patches = _gather_patches(img_l, xy_l, p, p)
    right_strips = _gather_patches(img_r, xy_r, p, p + 2 * r)
    table = ops.sad_search(left_patches, right_strips, impl=impl)
    best = jnp.argmin(table, axis=1).astype(jnp.float32) - float(r)

    x_r_rect = xy_r[:, 0] + best
    disparity = xy_l[:, 0] - x_r_rect
    valid = matches.valid & (disparity > 0.5)
    depth = jnp.where(valid, intr.fx * intr.baseline
                      / jnp.maximum(disparity, 0.5), 0.0)
    xy_right = jnp.stack([x_r_rect, xy_r[:, 1]], axis=-1)
    return DepthSet(disparity=jnp.where(valid, disparity, 0.0),
                    depth=depth, xy_right=xy_right, valid=valid)


def temporal_match(feat_a: FeatureSet, feat_b: FeatureSet,
                   cfg: ORBConfig, search_radius: float = 48.0,
                   impl: str | None = None) -> MatchSet:
    """Frame-to-frame matching for the VO backend: same kernel, wider
    square search region (band in y, +-radius in x via shifted meta)."""
    meta_a = _meta(feat_a)
    meta_b = _meta(feat_b)
    # Reuse the [0, max_disparity] window as [-radius, +radius] by
    # shifting the left x coordinate.
    meta_a = meta_a.at[:, 0].add(search_radius)
    dist, idx = ops.hamming_match(
        feat_a.desc, meta_a, feat_b.desc, meta_b,
        row_band=search_radius, max_disparity=2.0 * search_radius,
        impl=impl)
    valid = (idx >= 0) & (dist <= cfg.max_hamming) & feat_a.valid
    return MatchSet(right_index=jnp.where(valid, idx, 0),
                    distance=dist, valid=valid)
