"""Back-compat re-export of the BRIEF sampling pattern.

The pattern and its angle-binned steering LUT moved to
``repro.kernels.pattern`` (numpy-only) so the kernel layer — which may
not import ``repro.core`` — owns the single definition shared by the
Pallas descriptor kernel, the jnp fallback and the ref oracle.
"""

from __future__ import annotations

from repro.kernels.pattern import (ANGLE_BIN_STEP, N_ANGLE_BINS,  # noqa: F401
                                   N_PAIRS, PATCH_RADIUS, PATTERN,
                                   PATTERN_A, PATTERN_B, PATTERN_RADIUS,
                                   PATTERN_SIGMA, STEER_LUT, rotated_pattern)
