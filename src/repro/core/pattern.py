"""BRIEF sampling pattern (paper Sec. II-B2).

The paper selects ``n`` point pairs from the circular patch "based on
Gaussian distribution" (ORB's original construction).  We generate a
deterministic pattern once at import time with a fixed seed so that the
descriptor is reproducible across the pure-jnp oracle, the Pallas kernel
and checkpoints.

The pattern radius is capped at ``PATTERN_RADIUS`` so that after an
arbitrary rotation (norm-preserving) and rounding, every sampled point
stays strictly inside the 31x31 patch (radius 15) used by the hardware.
"""

from __future__ import annotations

import numpy as np

N_PAIRS = 256          # descriptor length in bits (32 x 8 bits, Sec. III-C)
PATCH_RADIUS = 15      # 31 x 31 patch, matching the FPGA register bank
PATTERN_RADIUS = 13    # max |offset| so rotate+round stays within radius 15
PATTERN_SIGMA = PATCH_RADIUS / 2.0
_SEED = 20210606       # AICAS'21 conference date; fixed for reproducibility


def _generate(seed: int = _SEED) -> np.ndarray:
    """Return int32 array (N_PAIRS, 4) of (ax, ay, bx, by) offsets."""
    rng = np.random.RandomState(seed)
    pts = []
    while len(pts) < N_PAIRS:
        cand = rng.normal(0.0, PATTERN_SIGMA, size=(4 * N_PAIRS, 4))
        cand = np.round(cand).astype(np.int32)
        ok = (
            (np.abs(cand[:, 0::2]).max(axis=1) ** 2
             + np.abs(cand[:, 1::2]).max(axis=1) ** 2)
            <= PATTERN_RADIUS ** 2
        )
        # Also require A != B so every binary test is informative.
        ok &= np.any(cand[:, :2] != cand[:, 2:], axis=1)
        pts.extend(cand[ok].tolist())
    return np.asarray(pts[:N_PAIRS], dtype=np.int32)


# (N_PAIRS, 4): columns are (ax, ay, bx, by), y down / x right image coords.
PATTERN: np.ndarray = _generate()

# Split views used by descriptor code: (N_PAIRS, 2) each.
PATTERN_A: np.ndarray = PATTERN[:, 0:2]
PATTERN_B: np.ndarray = PATTERN[:, 2:4]


def rotated_pattern(theta: float) -> np.ndarray:
    """Reference (numpy) steered pattern for a single angle — test helper."""
    c, s = np.cos(theta), np.sin(theta)
    rot = np.array([[c, -s], [s, c]])
    a = np.round(PATTERN_A @ rot.T).astype(np.int32)
    b = np.round(PATTERN_B @ rot.T).astype(np.int32)
    return np.concatenate([a, b], axis=1)
