"""Oriented FAST detection (paper Sec. II-B1, III-C).

Pipeline per pyramid level:
  fused score map + 3x3 NMS (Pallas megakernel) -> border mask ->
  static top-K -> intensity-centroid orientation from 31x31
  circular-patch moments.

The hot path (``orb.extract_features_batched``) gets the NMS'd score map
straight from the fused kernel; ``detect`` below is the single-image
convenience path and shares the same fused dispatch.  The standalone
3x3 NMS lives in ``kernels.ref.nms3`` (the oracle) and is re-exported
here for back-compat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ORBConfig
from repro.kernels import ops
from repro.kernels.ref import nms3  # noqa: F401  (oracle; back-compat export)

PATCH = 31
RADIUS = PATCH // 2

# Circular patch mask and coordinate grids (paper Eq. 1: r = patch radius).
_yy, _xx = np.mgrid[-RADIUS:RADIUS + 1, -RADIUS:RADIUS + 1]
CIRCLE_MASK = (_xx ** 2 + _yy ** 2 <= RADIUS ** 2).astype(np.float32)
X_GRID = (_xx * CIRCLE_MASK).astype(np.float32)
Y_GRID = (_yy * CIRCLE_MASK).astype(np.float32)


def select_topk(score: jnp.ndarray, k: int, border: int):
    """Top-K corners of a score map. Returns (xy (K,2) int32, score (K,),
    valid (K,) bool)."""
    h, w = score.shape
    row = jnp.arange(h)[:, None]
    col = jnp.arange(w)[None, :]
    inside = ((row >= border) & (row < h - border)
              & (col >= border) & (col < w - border))
    masked = jnp.where(inside, score, 0.0)
    vals, idx = jax.lax.top_k(masked.reshape(-1), k)
    ys = (idx // w).astype(jnp.int32)
    xs = (idx % w).astype(jnp.int32)
    valid = vals > 0.0
    return jnp.stack([xs, ys], axis=-1), vals, valid


def _patch31(padded_img: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """31x31 patch centered at (x, y); padded_img is edge-padded by RADIUS."""
    return jax.lax.dynamic_slice(padded_img, (y, x), (PATCH, PATCH))


def orientations(img: jnp.ndarray, xy: jnp.ndarray) -> jnp.ndarray:
    """Intensity-centroid orientation theta = atan2(m01, m10) (paper Eq. 1).

    img: (H, W) float32 level image; xy: (K, 2) int32.  Assumes xy at
    least ``border`` >= RADIUS from the edge (guaranteed by select_topk),
    so no padding is needed beyond edge replication.
    """
    padded = jnp.pad(img.astype(jnp.float32), RADIUS, mode="edge")
    xg = jnp.asarray(X_GRID)
    yg = jnp.asarray(Y_GRID)
    mask = jnp.asarray(CIRCLE_MASK)

    def one(pt):
        patch = _patch31(padded, pt[0], pt[1]) * mask
        m10 = jnp.sum(xg * patch)
        m01 = jnp.sum(yg * patch)
        return jnp.arctan2(m01, m10)

    return jax.vmap(one)(xy)


def detect(level_img: jnp.ndarray, cfg: ORBConfig, k: int,
           impl: str | None = None):
    """Run oriented FAST on one pyramid level (single-image path).

    Score-only dispatch: the standalone FAST kernel plus the ``nms3``
    oracle — bit-identical to the fused megakernel's score output (the
    kernels differ only in min/max association, which is exact) without
    computing the blur this path would discard (a pallas_call output
    cannot be dead-code-eliminated).  The frontend hot path uses
    ``orb.extract_features_batched`` / the fused kernel instead.

    Returns (xy (K,2) int32 level coords, score (K,), theta (K,),
    valid (K,))."""
    score = ops.fast_score_map(level_img, float(cfg.fast_threshold),
                               impl=impl)
    if cfg.nms:
        score = nms3(score)
    xy, vals, valid = select_topk(score, k, cfg.border)
    theta = orientations(level_img, xy)
    return xy, vals, theta, valid
