"""Oriented FAST detection (paper Sec. II-B1, III-C) — thin wrappers
over the two-stage kernel pipeline.

The frontend splits per pyramid level into a DENSE stage (fused
blur + FAST + NMS megakernel over every pixel) and a SPARSE stage (one
``ops.orient_describe_batched`` launch over the top-K keypoints).  This
module owns the pieces between them: static top-K selection, plus
single-image convenience wrappers that route through the SAME sparse
dispatch as the batched hot path, so single-image and batched results
are bit-identical.

The 31x31 patch geometry, circular-patch moment grids and the
orientation oracle live in ``kernels.ref`` (shared with the Pallas
kernel); the standalone 3x3 NMS oracle is re-exported here for
back-compat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ORBConfig
from repro.kernels import ops
from repro.kernels.ref import nms3  # noqa: F401  (oracle; back-compat export)
from repro.kernels.ref import PATCH, RADIUS  # noqa: F401


def select_topk(score: jnp.ndarray, k: int, border: int):
    """Top-K corners of a score map. Returns (xy (K,2) int32, score (K,),
    valid (K,) bool)."""
    h, w = score.shape
    row = jnp.arange(h)[:, None]
    col = jnp.arange(w)[None, :]
    inside = ((row >= border) & (row < h - border)
              & (col >= border) & (col < w - border))
    masked = jnp.where(inside, score, jnp.zeros_like(score))
    vals, idx = jax.lax.top_k(masked.reshape(-1), k)
    ys = (idx // w).astype(jnp.int32)
    xs = (idx % w).astype(jnp.int32)
    valid = vals > 0
    return jnp.stack([xs, ys], axis=-1), vals, valid


def orientations(img: jnp.ndarray, xy: jnp.ndarray,
                 impl: str | None = None) -> jnp.ndarray:
    """Intensity-centroid orientation theta = atan2(m01, m10) (paper
    Eq. 1) for a single image — batch-of-one view of the fused sparse
    dispatch (orientation-only kernel), so it shares every bit with
    ``orb.extract_features_batched``.

    img: (H, W) float32 level image; xy: (K, 2) int32.  Coordinates are
    clamped into the image by the dispatch.
    """
    theta, _, _ = ops.orient_describe_batched(img[None], None, xy[None],
                                              impl=impl)
    return theta[0]


def detect(level_img: jnp.ndarray, cfg: ORBConfig, k: int,
           impl: str | None = None):
    """Run oriented FAST on one pyramid level (single-image path).

    Score-only dispatch: the standalone FAST kernel plus the ``nms3``
    oracle — bit-identical to the fused megakernel's score output (the
    kernels differ only in min/max association, which is exact) without
    computing the blur this path would discard (a pallas_call output
    cannot be dead-code-eliminated).  Orientation then routes through
    the SAME ``ops.orient_describe_batched`` dispatch as the batched hot
    path (orientation-only kernel: no smoothed image, no descriptor), so
    ``detect`` and ``orb.extract_features_batched`` can never diverge on
    theta.  The frontend hot path uses ``orb.extract_features_batched``
    instead.

    Returns (xy (K,2) int32 level coords, score (K,), theta (K,),
    valid (K,))."""
    score = ops.fast_score_map(level_img, float(cfg.fast_threshold),
                               impl=impl)
    if cfg.nms:
        score = nms3(score)
    xy, vals, valid = select_topk(score, k, cfg.border)
    theta = orientations(level_img, xy, impl=impl)
    return xy, vals, theta, valid
