"""Core library: the paper's ORB-based quad-camera visual frontend."""

from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              MatchSet, ORBConfig)
from repro.core.orb import (extract_features, extract_features_batched,
                            extract_features_per_level)
from repro.core.matching import (match_pair_fused, match_pair_unfused,
                                 sad_rectify, sad_rectify_unfused,
                                 stereo_match, stereo_match_unfused,
                                 temporal_match)
from repro.core.frontend import (StereoOutput, extract_pair, match_pair,
                                 pipeline_schedule, process_quad_frame,
                                 process_stereo_frame, run_sequence,
                                 run_sequence_pipelined)
from repro.core import backend, sync  # noqa: F401

__all__ = [
    "CameraIntrinsics", "DepthSet", "FeatureSet", "MatchSet", "ORBConfig",
    "StereoOutput", "extract_features", "extract_features_batched",
    "extract_features_per_level", "stereo_match", "stereo_match_unfused",
    "sad_rectify", "sad_rectify_unfused", "match_pair_fused",
    "match_pair_unfused",
    "temporal_match", "extract_pair", "match_pair", "process_stereo_frame",
    "process_quad_frame", "run_sequence", "run_sequence_pipelined",
    "pipeline_schedule", "backend", "sync",
]
