"""Core library: the paper's ORB-based quad-camera visual frontend.

The public API is the SESSION layer: build one ``VisualSystem`` from a
``RigConfig`` + ``PipelineConfig`` and stream frames through its jitted
cached entry points (``process_frame`` / ``run`` / ``process_fleet`` /
``run_fleet``).  The legacy free functions (``process_quad_frame``,
``run_sequence*``, ``stereo_match``, ...) survive as thin deprecation
shims over the session — see ``repro.core.pipeline`` for the migration
map.  Below the session sit the engine layers: ``orb`` (whole-frame
fused extraction), ``matching`` (fused FM megakernel + unfused oracle),
``pyramid``/``fast``/``brief``, and ``kernels.ops`` dispatch.
"""

from repro.core.types import (CameraIntrinsics, DepthSet, FeatureSet,
                              LocalizationOutput, LocalizationState,
                              MatchSet, ORBConfig, PoseSet, StereoOutput)
from repro.core.rig import DesyncError, RigConfig
from repro.core.pipeline import PipelineConfig, VisualSystem
from repro.core.orb import (extract_features, extract_features_batched,
                            extract_features_per_level)
from repro.core.matching import (match_pair_fused, match_pair_unfused,
                                 sad_rectify, sad_rectify_unfused,
                                 stereo_match, stereo_match_unfused,
                                 temporal_match)
from repro.core.frontend import (extract_pair, match_pair,
                                 pipeline_schedule, process_quad_frame,
                                 process_stereo_frame, run_sequence,
                                 run_sequence_pipelined)
from repro.core import backend, sync  # noqa: F401

__all__ = [
    "CameraIntrinsics", "DepthSet", "FeatureSet", "MatchSet", "ORBConfig",
    "StereoOutput", "LocalizationOutput", "LocalizationState", "PoseSet",
    "RigConfig", "PipelineConfig", "VisualSystem", "DesyncError",
    "extract_features", "extract_features_batched",
    "extract_features_per_level", "stereo_match", "stereo_match_unfused",
    "sad_rectify", "sad_rectify_unfused", "match_pair_fused",
    "match_pair_unfused",
    "temporal_match", "extract_pair", "match_pair", "process_stereo_frame",
    "process_quad_frame", "run_sequence", "run_sequence_pipelined",
    "pipeline_schedule", "backend", "sync",
]
