"""Jit'd dispatch wrappers around the Pallas kernels and jnp oracles.

Every op takes ``impl``:
  - "ref"     — pure-jnp oracle (ref.py), any backend.
  - "pallas"  — Pallas kernel; on CPU it automatically runs in
                interpret mode (the kernel body executed in Python),
                on TPU it compiles to Mosaic.
  - None      — module default (``set_default_impl`` / REPRO_KERNEL_IMPL
                env var; "ref" on CPU, "pallas" on TPU).

The wrappers own all padding/unpadding so kernels see tile-aligned
shapes and callers see exact shapes.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fast_detect import (HALO, TILE_H, TILE_W,
                                       fast_score_map_pallas)
from repro.kernels.gaussian_blur import gaussian_blur7_pallas
from repro.kernels.hamming_match import BIG, BK, hamming_match_pallas
from repro.kernels.sad_rectify import sad_search_pallas

_DEFAULT_IMPL: str | None = os.environ.get("REPRO_KERNEL_IMPL") or None


def set_default_impl(impl: str | None) -> None:
    global _DEFAULT_IMPL
    assert impl in (None, "ref", "pallas")
    _DEFAULT_IMPL = impl


def resolve_impl(impl: str | None) -> str:
    if impl is not None:
        return impl
    if _DEFAULT_IMPL is not None:
        return _DEFAULT_IMPL
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_tiles(img: jnp.ndarray, halo: int, th: int, tw: int):
    """Edge-pad by halo and zero-pad H/W up to tile multiples.

    Returns (padded, (H, W)) where padded is ((H'+2h), (W'+2h))."""
    h, w = img.shape
    hp = (-h) % th
    wp = (-w) % tw
    padded = jnp.pad(img.astype(jnp.float32),
                     ((halo, halo + hp), (halo, halo + wp)), mode="edge")
    return padded, (h, w)


def fast_score_map(img: jnp.ndarray, threshold: float,
                   impl: str | None = None) -> jnp.ndarray:
    """(H, W) image -> (H, W) float32 FAST-9/16 corner score map."""
    if resolve_impl(impl) == "ref":
        return _ref.fast_score_map(img, threshold)
    padded, (h, w) = _pad_tiles(img, HALO, TILE_H, TILE_W)
    out = fast_score_map_pallas(padded, threshold=float(threshold),
                                interpret=_interpret())
    return out[:h, :w]


def gaussian_blur7(img: jnp.ndarray, quantized: bool = True,
                   impl: str | None = None) -> jnp.ndarray:
    """(H, W) image -> (H, W) float32 7x7-Gaussian-smoothed image."""
    if resolve_impl(impl) == "ref":
        return _ref.gaussian_blur7(img, quantized=quantized)
    padded, (h, w) = _pad_tiles(img, HALO, TILE_H, TILE_W)
    out = gaussian_blur7_pallas(padded, quantized=quantized,
                                interpret=_interpret())
    return out[:h, :w]


def _pad_rows(x: jnp.ndarray, mult: int, fill=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    pad_width = [(0, p)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width, constant_values=fill)


def hamming_match(desc_l: jnp.ndarray, meta_l: jnp.ndarray,
                  desc_r: jnp.ndarray, meta_r: jnp.ndarray, *,
                  row_band: float, max_disparity: float,
                  impl: str | None = None):
    """Fused search-region + Hamming argmin (paper's FM front half).

    desc_*: (K, 8) uint32; meta_*: (K, 4) float32 (x, y, level, valid).
    Returns (best_dist (K,) int32 [BIG when no candidate], best_idx (K,)
    int32 [-1 when no candidate])."""
    k = desc_l.shape[0]
    if resolve_impl(impl) == "ref":
        dist = _ref.hamming_distance_matrix(desc_l, desc_r)
        dx = meta_l[:, 0][:, None] - meta_r[:, 0][None, :]
        dy = jnp.abs(meta_l[:, 1][:, None] - meta_r[:, 1][None, :])
        mask = ((dy <= row_band) & (dx >= 0.0) & (dx <= max_disparity)
                & (meta_l[:, 2][:, None] == meta_r[:, 2][None, :])
                & (meta_l[:, 3][:, None] > 0.5)
                & (meta_r[:, 3][None, :] > 0.5))
        dist = jnp.where(mask, dist, BIG)
        best = jnp.min(dist, axis=1)
        idx = jnp.where(best >= BIG, -1,
                        jnp.argmin(dist, axis=1).astype(jnp.int32))
        return best.astype(jnp.int32), idx
    # Pad to BK multiples with invalid rows (valid=0 masks them out).
    dl = _pad_rows(desc_l, BK)
    dr = _pad_rows(desc_r, BK)
    ml = _pad_rows(meta_l, BK)
    mr = _pad_rows(meta_r, BK)
    dist, idx = hamming_match_pallas(dl, ml, dr, mr, row_band=float(row_band),
                                     max_disparity=float(max_disparity),
                                     interpret=_interpret())
    dist, idx = dist[:k], idx[:k]
    return dist, jnp.where(dist >= BIG, -1, idx)


def sad_search(left_patches: jnp.ndarray, right_strips: jnp.ndarray,
               impl: str | None = None) -> jnp.ndarray:
    """(K, P, P) x (K, P, P+2R) patches -> (K, 2R+1) int32 SAD table."""
    if resolve_impl(impl) == "ref":
        return _ref.sad_search(left_patches, right_strips)
    k = left_patches.shape[0]
    lp = _pad_rows(left_patches, 128)
    rs = _pad_rows(right_strips, 128)
    return sad_search_pallas(lp, rs, interpret=_interpret())[:k]


NO_MATCH_DIST = BIG
