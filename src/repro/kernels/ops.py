"""Jit'd dispatch wrappers around the Pallas kernels and jnp oracles.

Every op takes ``impl``:
  - "ref"     — pure-jnp oracle (ref.py), any backend.
  - "pallas"  — Pallas kernel; on CPU it automatically runs in
                interpret mode (the kernel body executed in Python),
                on TPU it compiles to Mosaic.
  - None      — the innermost ``use_impl`` context, else the process
                default (``set_default_impl`` / REPRO_KERNEL_IMPL env
                var), else "ref" on CPU and "pallas" on TPU.  Sessions
                (``core.pipeline.VisualSystem``) resolve their impl
                once from ``PipelineConfig`` and thread it explicitly.

Impl scoping and the launch audit are both context-var based so
parallel sessions (threads, concurrent test workers) never cross-talk:
``use_impl`` scopes the default impl, and ``launch_audit()`` yields a
counter that observes every Pallas launch traced inside its scope.
``set_default_impl`` / ``reset_launch_count`` / ``launch_count`` are
kept as legacy shims over the same machinery.

The wrappers own all padding/unpadding so kernels see tile-aligned
shapes and callers see exact shapes.
"""

from __future__ import annotations

import contextlib
import contextvars
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pattern as _pattern
from repro.kernels import ref as _ref
from repro.kernels.describe_fused import (KP_BLOCK, _cast_slab,
                                          describe_fused_pallas,
                                          describe_fused_pyramid_pallas,
                                          orient_fused_pallas)
from repro.kernels.fast_detect import (HALO, TILE_H, TILE_W,
                                       fast_score_map_pallas)
from repro.kernels.frontend_fused import (FUSED_HALO, fast_score_from_taps,
                                          frontend_fused_pallas,
                                          frontend_fused_pyramid_pallas)
from repro.kernels.gaussian_blur import gaussian_blur7_pallas
from repro.kernels.hamming_match import BIG, BK, hamming_match_pallas
from repro.kernels.matcher_fused import (FM_BK, FM_BM, MO_BK,
                                         match_fused_pallas,
                                         match_rectify_fused_pallas,
                                         sad_fused_pallas)
from repro.kernels.sad_rectify import sad_search_pallas

_DEFAULT_IMPL: str | None = os.environ.get("REPRO_KERNEL_IMPL") or None

# Context-scoped impl override: ``use_impl`` installs a value here; the
# context var is per-thread (new threads start from defaults), so scoped
# overrides in one session/thread never leak into another.
_IMPL_VAR: contextvars.ContextVar[str | None] = contextvars.ContextVar(
    "repro_kernel_impl", default=None)


def _check_impl(impl: str | None) -> None:
    if impl not in (None, "ref", "pallas"):
        raise ValueError(
            f"unknown kernel impl {impl!r} (expected 'ref' or 'pallas'; "
            "check REPRO_KERNEL_IMPL)")


@contextlib.contextmanager
def use_impl(impl: str | None):
    """Scope the default kernel impl for the dynamic extent of the
    ``with`` block (context-var based: thread-safe, re-entrant)."""
    _check_impl(impl)
    token = _IMPL_VAR.set(impl)
    try:
        yield
    finally:
        _IMPL_VAR.reset(token)


def set_default_impl(impl: str | None) -> None:
    """Legacy shim: set the PROCESS-WIDE default impl.  Prefer scoping
    with ``use_impl`` or resolving once in a ``VisualSystem`` session —
    this global is shared across threads."""
    global _DEFAULT_IMPL
    _check_impl(impl)
    _DEFAULT_IMPL = impl


def resolve_impl(impl: str | None) -> str:
    if impl is None:
        impl = _IMPL_VAR.get()
    if impl is None:
        impl = _DEFAULT_IMPL
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    _check_impl(impl)
    return impl


# Trace-time Pallas launch audit: each pallas-path dispatch below bumps
# every active audit once per kernel launch appearing in the traced
# graph.  Benchmarks and tests open a ``launch_audit()`` scope around a
# trace (jax.eval_shape / jit tracing) to report how many kernel
# launches a schedule issues — the regression-trackable "fused vs seed"
# number when wall-clock is noisy.  Audits are context-var based so
# parallel sessions (threads) count independently; the legacy
# ``reset_launch_count`` / ``launch_count`` pair is a shim over a
# per-context counter.
class LaunchAudit:
    """Counter bound to one ``launch_audit()`` scope."""

    __slots__ = ("count",)

    def __init__(self) -> None:
        self.count = 0


_AUDIT_STACK: contextvars.ContextVar[tuple[LaunchAudit, ...]] = \
    contextvars.ContextVar("repro_launch_audits", default=())
_LEGACY_AUDIT: contextvars.ContextVar[LaunchAudit | None] = \
    contextvars.ContextVar("repro_launch_legacy", default=None)


@contextlib.contextmanager
def launch_audit():
    """Yield a ``LaunchAudit`` whose ``.count`` observes every Pallas
    launch traced inside the ``with`` block.  Scopes nest (an inner
    audit also feeds enclosing ones) and are thread-isolated."""
    audit = LaunchAudit()
    token = _AUDIT_STACK.set(_AUDIT_STACK.get() + (audit,))
    try:
        yield audit
    finally:
        _AUDIT_STACK.reset(token)


def _legacy_audit() -> LaunchAudit:
    audit = _LEGACY_AUDIT.get()
    if audit is None:
        audit = LaunchAudit()
        _LEGACY_AUDIT.set(audit)
    return audit


def reset_launch_count() -> None:
    """Legacy shim over the per-context counter; prefer
    ``launch_audit()``."""
    _legacy_audit().count = 0


def launch_count() -> int:
    """Legacy shim over the per-context counter; prefer
    ``launch_audit()``."""
    return _legacy_audit().count


def _count_launches(n: int = 1) -> None:
    _legacy_audit().count += n
    for audit in _AUDIT_STACK.get():
        audit.count += n


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_tiles(img: jnp.ndarray, halo: int, th: int, tw: int):
    """Edge-pad by halo and zero-pad H/W up to tile multiples.

    Returns (padded, (H, W)) where padded is ((H'+2h), (W'+2h))."""
    h, w = img.shape
    hp = (-h) % th
    wp = (-w) % tw
    padded = jnp.pad(img.astype(jnp.float32),
                     ((halo, halo + hp), (halo, halo + wp)), mode="edge")
    return padded, (h, w)


def fast_score_map(img: jnp.ndarray, threshold: float,
                   impl: str | None = None) -> jnp.ndarray:
    """(H, W) image -> (H, W) float32 FAST-9/16 corner score map."""
    if resolve_impl(impl) == "ref":
        return _ref.fast_score_map(img, threshold)
    padded, (h, w) = _pad_tiles(img, HALO, TILE_H, TILE_W)
    _count_launches()
    out = fast_score_map_pallas(padded, threshold=float(threshold),
                                interpret=_interpret())
    return out[:h, :w]


def gaussian_blur7(img: jnp.ndarray, quantized: bool = True,
                   impl: str | None = None) -> jnp.ndarray:
    """(H, W) image -> (H, W) float32 7x7-Gaussian-smoothed image."""
    if resolve_impl(impl) == "ref":
        return _ref.gaussian_blur7(img, quantized=quantized)
    padded, (h, w) = _pad_tiles(img, HALO, TILE_H, TILE_W)
    _count_launches()
    out = gaussian_blur7_pallas(padded, quantized=quantized,
                                interpret=_interpret())
    return out[:h, :w]


def _blur_rawscore_jnp(x: jnp.ndarray, threshold: float, quantized: bool):
    """Shared jnp stencil body of the fused fallbacks: (B, H, W) float32
    OR uint8 -> (blur, raw score), each (B, H, W).  ONE shared edge-pad
    feeds both stencils, the FAST arc extrema use the van Herk block
    prefix/suffix scheme instead of materializing (16, H, W) stacks
    (min/max reassociation is exact, so results are unchanged), and the
    blur keeps the oracle's tap-summation order (float-exact).  uint8
    input runs the integer datapath (int32 accumulators, uint8 blur +
    int16 score out) — equal in value on quantized images (see
    ``ref.gaussian_blur7_u8`` / ``ref.fast_score_map_int``)."""
    _, h, w = x.shape
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    if integer:
        x = x.astype(jnp.int32)
    pad = jnp.pad(x, ((0, 0), (3, 3), (3, 3)), mode="edge")

    wts = ([int(v) for v in _ref.GAUSS7_WEIGHTS_INT] if integer
           else [float(v) for v in _ref.GAUSS7_WEIGHTS_INT])
    horiz = None
    for k in range(7):
        term = wts[k] * pad[:, :, k:k + w]              # (B, H+6, W)
        horiz = term if horiz is None else horiz + term
    vert = None
    for k in range(7):
        term = wts[k] * horiz[:, k:k + h, :]            # (B, H, W)
        vert = term if vert is None else vert + term
    norm2 = _ref.GAUSS7_NORM * _ref.GAUSS7_NORM
    if integer:
        blur = ((vert + norm2 // 2) // norm2).astype(jnp.uint8)
    elif quantized:
        blur = jnp.floor((vert + norm2 / 2.0) / float(norm2))
    else:
        blur = vert / float(norm2)

    taps = [pad[:, 3 + dy:3 + dy + h, 3 + dx:3 + dx + w] - x
            for dx, dy in _ref.CIRCLE16]
    score = fast_score_from_taps(taps, float(threshold))
    if integer:
        score = score.astype(jnp.int16)
    return blur, score


def _nms_jnp(score: jnp.ndarray) -> jnp.ndarray:
    """Separable included-center 3x3 max over (B, H, W); cs >= max(cs,
    nbrs) iff cs >= max(nbrs), so the decision matches ref.nms3 exactly
    (the -1 constant pad is the oracle's outside-image sentinel)."""
    spad = jnp.pad(score, ((0, 0), (1, 1), (1, 1)),
                   constant_values=jnp.asarray(-1, score.dtype))
    rmax = jnp.maximum(jnp.maximum(spad[:, :-2, :], spad[:, 1:-1, :]),
                       spad[:, 2:, :])
    nmax = jnp.maximum(jnp.maximum(rmax[:, :, :-2], rmax[:, :, 1:-1]),
                       rmax[:, :, 2:])
    return (jnp.where(score >= nmax, score, jnp.zeros_like(score))
            * (score > 0).astype(score.dtype))


def _fast_blur_nms_fused_jnp(imgs: jnp.ndarray, threshold: float,
                             nms: bool, quantized: bool):
    """Interpret-free jnp fallback of the fused megakernel.

    Bit-exact against the ``ref.py`` oracle chain (tests assert it), but
    structured like the kernel rather than like the oracle — see
    ``_blur_rawscore_jnp``/``_nms_jnp``.  ~1.7x faster than the
    per-image oracle chain on CPU — the "fused" contender of the
    fused-vs-seed benchmark.
    """
    blur, score = _blur_rawscore_jnp(_cast_slab(imgs), threshold,
                                     quantized)
    if nms:
        score = _nms_jnp(score)
    return blur, score


def fast_blur_nms_batched(imgs: jnp.ndarray, threshold: float, *,
                          nms: bool = True, quantized: bool = True,
                          impl: str | None = None):
    """Fused batched frontend: (B, H, W) images -> (blur, score), each
    (B, H, W) float32, in ONE kernel launch.

    B is a flattened camera batch (the frontend stacks all cameras of a
    pyramid level); ``blur`` is the 7x7-Gaussian-smoothed image and
    ``score`` the (optionally 3x3-NMS'd) FAST-9/16 corner score map.
    This wrapper owns all padding: edge halo for the stencils plus
    zero-cost tile alignment for ragged level shapes — kernels see
    aligned tiles, callers see exact shapes.
    """
    _, h, w = imgs.shape
    if resolve_impl(impl) == "ref":
        return _fast_blur_nms_fused_jnp(imgs, threshold, nms, quantized)
    hp = (-h) % TILE_H
    wp = (-w) % TILE_W
    padded = jnp.pad(
        _cast_slab(imgs),
        ((0, 0), (FUSED_HALO, FUSED_HALO + hp), (FUSED_HALO, FUSED_HALO + wp)),
        mode="edge")
    _count_launches()
    blur, score = frontend_fused_pallas(
        padded, threshold=float(threshold), nms=bool(nms),
        quantized=bool(quantized), true_h=h, true_w=w,
        interpret=_interpret())
    return blur[:, :h, :w], score[:, :h, :w]


def fast_blur_nms_pyramid_stacked_jnp(levels, threshold: float, *,
                                      nms: bool = True,
                                      quantized: bool = True):
    """jnp mirror of the whole-pyramid kernel's ragged-padding
    semantics: every ragged level slab is edge-padded to the COMMON
    (max) canvas, the shared stencil body runs ONCE over the
    (L*B, Hc, Wc) stack, and the per-slab true shape masks outside
    pixels to the -1 NMS sentinel.

    Bit-exact against running ``_fast_blur_nms_fused_jnp`` per level
    (tests assert it): blur taps only reach 3 px past the true image —
    edge-replicated rows/cols in both schedules — and the NMS mask gives
    true-border pixels the same -1 neighbours the per-level constant pad
    does.  Kept as an INDEPENDENT oracle of the kernel's padding logic,
    not as the production fallback: on CPU the common-canvas padding
    wastes compute at 1.2x scale (measured ~1.1-1.25x the per-level
    loop's wall clock at 640x480 — the ``dense_stacked_overhead``
    benchmark row), so ``fast_blur_nms_pyramid``'s ref path loops per
    level instead — the whole-frame win is launch overhead on the
    accelerator, not CPU arithmetic.
    """
    shapes = [(int(lv.shape[1]), int(lv.shape[2])) for lv in levels]
    b = levels[0].shape[0]
    hc = max(h for h, _ in shapes)
    wc = max(w for _, w in shapes)
    x = jnp.concatenate([
        jnp.pad(_cast_slab(lv), ((0, 0), (0, hc - h), (0, wc - w)),
                mode="edge")
        for lv, (h, w) in zip(levels, shapes)], axis=0)
    blur, score = _blur_rawscore_jnp(x, threshold, quantized)
    th = jnp.asarray(np.repeat([h for h, _ in shapes], b))[:, None, None]
    tw = jnp.asarray(np.repeat([w for _, w in shapes], b))[:, None, None]
    inside = ((jnp.arange(hc)[None, :, None] < th)
              & (jnp.arange(wc)[None, None, :] < tw))
    score = jnp.where(inside, score, jnp.asarray(-1, score.dtype))
    score = (_nms_jnp(score) if nms
             else jnp.maximum(score, jnp.zeros_like(score)))
    return [(blur[l * b:(l + 1) * b, :h, :w],
             score[l * b:(l + 1) * b, :h, :w])
            for l, (h, w) in enumerate(shapes)]


def fast_blur_nms_pyramid(levels, threshold: float, *, nms: bool = True,
                          quantized: bool = True, impl: str | None = None):
    """Whole-pyramid dense stage: L ragged (B, h_l, w_l) level batches
    -> [(blur_l, score_l)] per level, ALL cameras x ALL levels in ONE
    kernel launch.

    This is the whole-frame analog of ``fast_blur_nms_batched`` (which
    launches once per level): ragged level slabs are edge-padded to a
    common tile grid, the kernel grid walks (slab, tile_i, tile_j), and
    a per-slab (true_h, true_w) table masks the padding region so small
    levels never emit spurious corners.  Together with
    ``orient_describe_pyramid`` this makes the frontend exactly TWO
    launches per quad FRAME.  The wrapper owns all padding; callers see
    exact per-level shapes.

    The ref fallback loops ``_fast_blur_nms_fused_jnp`` per level —
    bit-identical to the per-level schedule by construction and free of
    the common-canvas padding waste on CPU; the stacked jnp mirror of
    the kernel's padding logic is ``fast_blur_nms_pyramid_stacked_jnp``
    (tests pin all three against each other).
    """
    if resolve_impl(impl) == "ref":
        return [_fast_blur_nms_fused_jnp(lv, threshold, nms, quantized)
                for lv in levels]
    shapes = [(int(lv.shape[1]), int(lv.shape[2])) for lv in levels]
    b = levels[0].shape[0]
    hc = max(h + (-h) % TILE_H for h, _ in shapes)
    wc = max(w + (-w) % TILE_W for _, w in shapes)
    flat = jnp.concatenate([
        jnp.pad(_cast_slab(lv),
                ((0, 0), (FUSED_HALO, FUSED_HALO + hc - h),
                 (FUSED_HALO, FUSED_HALO + wc - w)), mode="edge")
        for lv, (h, w) in zip(levels, shapes)], axis=0)
    hw = jnp.asarray(np.repeat(np.asarray(shapes, np.int32), b, axis=0))
    _count_launches()
    blur, score = frontend_fused_pyramid_pallas(
        flat, hw, threshold=float(threshold), nms=bool(nms),
        quantized=bool(quantized), interpret=_interpret())
    return [(blur[l * b:(l + 1) * b, :h, :w],
             score[l * b:(l + 1) * b, :h, :w])
            for l, (h, w) in enumerate(shapes)]


def _orient_describe_jnp(raw, smoothed, xy):
    """jnp fallback of the fused sparse descriptor kernel: the per-image
    gather oracle vmapped over the camera batch.

    Bit-exact against the Pallas kernel (tests assert it): the moment /
    theta / bin math is the SAME ``ref.py`` helpers the kernel body
    calls, and the tap gather equals the kernel's selection-matmul sign
    exactly (see ``ref.lut_descriptor``).
    """
    integer = jnp.issubdtype(raw.dtype, jnp.integer)
    if smoothed is None:
        if integer:
            theta, mom = jax.vmap(lambda im, p: _ref.patch_theta_int(
                _ref.extract_patches(im, p, preserve_dtype=True)))(raw, xy)
            return theta, mom.astype(jnp.float32), None
        return jax.vmap(
            lambda im, p: _ref.patch_theta(_ref.extract_patches(im, p))
        )(raw, xy) + (None,)
    if integer:
        theta, mom, desc = jax.vmap(_ref.orient_describe_int)(
            raw, smoothed, xy)
        return theta, mom.astype(jnp.float32), desc
    return jax.vmap(_ref.orient_describe)(raw, smoothed, xy)


def _pad_patch_slab(imgs: jnp.ndarray) -> jnp.ndarray:
    """Edge-pad a (B, H, W) batch by the 31x31 patch RADIUS, plus
    edge-replicated tile alignment (Hp % 8 == Wp % 128 == 0).  Clamped
    patch starts never reach the alignment region."""
    _, h, w = imgs.shape
    r = _ref.RADIUS
    hp = (-(h + 2 * r)) % 8
    wp = (-(w + 2 * r)) % 128
    return jnp.pad(_cast_slab(imgs),
                   ((0, 0), (r, r + hp), (r, r + wp)), mode="edge")


def orient_describe_batched(raw: jnp.ndarray, smoothed: jnp.ndarray | None,
                            xy: jnp.ndarray, *, impl: str | None = None):
    """Fused batched sparse stage: orientation + moments + rBRIEF for a
    (B, K) block of keypoints in ONE kernel launch.

    raw/smoothed: (B, H, W) level images (smoothed = 7x7 Gaussian blur;
    None selects the orientation-only kernel — ``fast.detect``'s path);
    xy: (B, K, 2) int32 level coords (clamped into the image, so top-K
    padding rows with ``valid=False`` are safe).  Returns (theta (B, K)
    float32, moments (B, K, 2) float32, desc (B, K, 8) uint32 or None).

    B is the flattened camera batch of a pyramid level: together with
    ``fast_blur_nms_batched`` this makes the frontend exactly TWO
    launches per level (dense + sparse) for all cameras.  The wrapper
    owns K-padding to KP_BLOCK multiples and the patch-halo image pad.
    """
    _, h, w = raw.shape
    k = xy.shape[1]
    if resolve_impl(impl) == "ref":
        return _orient_describe_jnp(raw, smoothed, xy)
    kp = (-k) % KP_BLOCK
    xy_p = jnp.pad(xy.astype(jnp.int32), ((0, 0), (0, kp), (0, 0)))
    raw_p = _pad_patch_slab(raw)
    _count_launches()
    if smoothed is None:
        theta, mom = orient_fused_pallas(raw_p, xy_p, true_h=h, true_w=w,
                                         interpret=_interpret())
        return theta[:, :k], mom[:, :k], None
    theta, mom, desc = describe_fused_pallas(
        jnp.asarray(_pattern.STEER_LUT), raw_p, _pad_patch_slab(smoothed),
        xy_p, true_h=h, true_w=w, interpret=_interpret())
    return theta[:, :k], mom[:, :k], desc[:, :k]


def orient_describe_pyramid(raws, smootheds, xys, *,
                            impl: str | None = None):
    """Whole-frame sparse stage: per-level raw/smoothed (B, h_l, w_l)
    slab pairs plus per-level (B, K_l, 2) keypoint blocks -> per-level
    (theta, moments, desc) tuples, ALL cameras x ALL levels in ONE
    kernel launch.

    This is the whole-frame analog of ``orient_describe_batched`` (one
    launch per level): each level's keypoints are padded to a KP_BLOCK
    multiple and concatenated level-major, so every K-block is
    level-homogeneous and the kernel's index maps resolve its slab pair
    from the static block->level offsets; a per-block (true_h, true_w)
    table drives the coordinate clamp.  The wrapper owns the common-
    canvas slab padding and the K padding; callers see exact per-level
    shapes.  The jnp fallback is the per-level gather oracle — the
    per-level and whole-frame ref paths are bit-identical by
    construction.
    """
    if resolve_impl(impl) == "ref":
        return [_orient_describe_jnp(r, s, xy)
                for r, s, xy in zip(raws, smootheds, xys)]
    shapes = [(int(r_.shape[1]), int(r_.shape[2])) for r_ in raws]
    b = raws[0].shape[0]
    rad = _ref.RADIUS
    hc = max(h for h, _ in shapes) + 2 * rad
    hc += (-hc) % 8
    wc = max(w for _, w in shapes) + 2 * rad
    wc += (-wc) % 128

    def slab(imgs, h, w):
        # Per-level edge pad by the patch RADIUS, then edge-replicated
        # out to the common canvas; clamped patch starts stay within the
        # (h + 2*rad, w + 2*rad) region, so the canvas pad is never read
        # with values differing from the per-level slab.
        return jnp.pad(_cast_slab(imgs),
                       ((0, 0), (rad, hc - h - rad), (rad, wc - w - rad)),
                       mode="edge")

    raw_all = jnp.concatenate(
        [slab(im, h, w) for im, (h, w) in zip(raws, shapes)], axis=0)
    sm_all = jnp.concatenate(
        [slab(im, h, w) for im, (h, w) in zip(smootheds, shapes)], axis=0)
    ks = [int(xy.shape[1]) for xy in xys]
    kps = [(-k) % KP_BLOCK for k in ks]
    xy_all = jnp.concatenate(
        [jnp.pad(xy.astype(jnp.int32), ((0, 0), (0, kp), (0, 0)))
         for xy, kp in zip(xys, kps)], axis=1)
    nbs = [(k + kp) // KP_BLOCK for k, kp in zip(ks, kps)]
    offsets = tuple(int(o) for o in np.cumsum([0] + nbs[:-1]))
    hw = jnp.asarray(np.repeat(np.asarray(shapes, np.int32), nbs, axis=0))
    _count_launches()
    theta, mom, desc = describe_fused_pyramid_pallas(
        jnp.asarray(_pattern.STEER_LUT), raw_all, sm_all, xy_all, hw,
        level_offsets=offsets, interpret=_interpret())
    outs, off = [], 0
    for k, kp in zip(ks, kps):
        outs.append((theta[:, off:off + k], mom[:, off:off + k],
                     desc[:, off:off + k]))
        off += k + kp
    return outs


def _pad_rows(x: jnp.ndarray, mult: int, fill=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    pad_width = [(0, p)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width, constant_values=fill)


def _hamming_argmin_jnp(desc_l, meta_l, desc_r, meta_r,
                        row_band: float, max_disparity: float):
    """jnp oracle of the fused search-region + Hamming argmin: ONE
    definition shared by ``hamming_match`` and the fused-matcher ref
    fallbacks, so all ref paths are bit-identical by construction."""
    dist = _ref.hamming_distance_matrix(desc_l, desc_r)
    dx = meta_l[:, 0][:, None] - meta_r[:, 0][None, :]
    dy = jnp.abs(meta_l[:, 1][:, None] - meta_r[:, 1][None, :])
    mask = ((dy <= row_band) & (dx >= 0.0) & (dx <= max_disparity)
            & (meta_l[:, 2][:, None] == meta_r[:, 2][None, :])
            & (meta_l[:, 3][:, None] > 0.5)
            & (meta_r[:, 3][None, :] > 0.5))
    dist = jnp.where(mask, dist, BIG)
    best = jnp.min(dist, axis=1)
    idx = jnp.where(best >= BIG, -1,
                    jnp.argmin(dist, axis=1).astype(jnp.int32))
    return best.astype(jnp.int32), idx


def hamming_match(desc_l: jnp.ndarray, meta_l: jnp.ndarray,
                  desc_r: jnp.ndarray, meta_r: jnp.ndarray, *,
                  row_band: float, max_disparity: float,
                  impl: str | None = None):
    """Fused search-region + Hamming argmin (paper's FM front half).

    desc_*: (K, 8) uint32; meta_*: (K, 4) float32 (x, y, level, valid).
    Returns (best_dist (K,) int32 [BIG when no candidate], best_idx (K,)
    int32 [-1 when no candidate])."""
    k = desc_l.shape[0]
    if resolve_impl(impl) == "ref":
        return _hamming_argmin_jnp(desc_l, meta_l, desc_r, meta_r,
                                   row_band, max_disparity)
    # Pad to BK multiples with invalid rows (valid=0 masks them out).
    dl = _pad_rows(desc_l, BK)
    dr = _pad_rows(desc_r, BK)
    ml = _pad_rows(meta_l, BK)
    mr = _pad_rows(meta_r, BK)
    _count_launches()
    dist, idx = hamming_match_pallas(dl, ml, dr, mr, row_band=float(row_band),
                                     max_disparity=float(max_disparity),
                                     interpret=_interpret())
    dist, idx = dist[:k], idx[:k]
    return dist, jnp.where(dist >= BIG, -1, idx)


def sad_search(left_patches: jnp.ndarray, right_strips: jnp.ndarray,
               impl: str | None = None) -> jnp.ndarray:
    """(K, P, P) x (K, P, P+2R) patches -> (K, 2R+1) int32 SAD table."""
    if resolve_impl(impl) == "ref":
        return _ref.sad_search(left_patches, right_strips)
    k = left_patches.shape[0]
    lp = _pad_rows(left_patches, 128)
    rs = _pad_rows(right_strips, 128)
    _count_launches()
    return sad_search_pallas(lp, rs, interpret=_interpret())[:k]


def _pad_axis1(x: jnp.ndarray, mult: int):
    """Zero-pad axis 1 (the K/M feature axis of pair-batched arrays) up
    to a multiple of ``mult``; padded meta rows carry valid=0."""
    p = (-x.shape[1]) % mult
    if p == 0:
        return x
    pad_width = [(0, 0), (0, p)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad_width)


def _pad_fm_slab(imgs: jnp.ndarray, ry: int, rx: int) -> jnp.ndarray:
    """Edge-pad a (P, H, W) pair batch by the FM patch radii, plus
    edge-replicated tile alignment (Hp % 8 == Wp % 128 == 0).  Clamped
    patch starts never reach the alignment region."""
    _, h, w = imgs.shape
    hp = (-(h + 2 * ry)) % 8
    wp = (-(w + 2 * rx)) % 128
    return jnp.pad(_cast_slab(imgs),
                   ((0, 0), (ry, ry + hp), (rx, rx + wp)), mode="edge")


def _match_rectify_jnp(dl, ml, dr, mr, il, ir, row_band, max_disparity,
                       max_hamming, patch, sad_range):
    """Single-pair jnp fallback of the FM megakernel: the hamming
    oracle, the MatchSet index-resolution rule (``where(valid, idx,
    0)``), the edge-clamped patch gathers and the int32 SAD sweep —
    each the SAME helper the unfused path uses, so fused-ref equals
    unfused-ref by construction (and the Pallas kernel is pinned
    bit-exact against both in tests)."""
    dist, idx = _hamming_argmin_jnp(dl, ml, dr, mr, row_band,
                                    max_disparity)
    ok = (idx >= 0) & (dist <= max_hamming) & (ml[:, 3] > 0.5)
    eff = jnp.where(ok, idx, 0)
    rxy = mr[eff, :2]
    lp = _ref.gather_patches(il, ml[:, :2], patch, patch)
    rs = _ref.gather_patches(ir, rxy, patch, patch + 2 * sad_range)
    table = _ref.sad_search(lp, rs)
    return dist, idx, rxy, jnp.argmin(table, axis=1).astype(jnp.int32)


def match_rectify_fused(desc_l: jnp.ndarray, meta_l: jnp.ndarray,
                        desc_r: jnp.ndarray, meta_r: jnp.ndarray,
                        img_l: jnp.ndarray | None = None,
                        img_r: jnp.ndarray | None = None, *,
                        row_band: float, max_disparity: float,
                        max_hamming: int = 0, sad_window: int = 11,
                        sad_range: int = 5, impl: str | None = None):
    """Fused Feature Matcher dispatch: the ENTIRE FM stage of a frame —
    search-region decision + Hamming argmin + SAD rectification sweep —
    in ONE kernel launch, batched over stereo pairs (the pair axis is
    folded into the kernel grid, not vmapped).

    desc_*: (P, K, 8)/(P, M, 8) uint32; meta_*: (P, K, 4)/(P, M, 4)
    float32 rows of (x, y, level, valid); img_*: (P, H, W) level-0
    images.  Returns (dist (P, K) int32 [BIG when no candidate], idx
    (P, K) int32 [-1], rxy (P, K, 2) float32 — the effective right
    feature's coords after the ``where(valid, idx, 0)`` resolution rule,
    sad (P, K) int32 — SAD argmin in [0, 2*sad_range]; the rectified
    offset is ``sad - sad_range``).

    MATCH-ONLY mode: with ``img_l``/``img_r`` omitted the SAD half is
    skipped and only (dist, idx) return — still one launch with the
    pair-folded grid; ``stereo_match`` / ``temporal_match`` route here
    so the VO backend's matching also costs a single launch.  The
    wrapper owns all padding (K/M block alignment with valid=0 rows,
    edge-replicated image slabs); callers see exact shapes.
    """
    match_only = img_l is None
    k = desc_l.shape[1]
    if resolve_impl(impl) == "ref":
        if match_only:
            dist, idx = jax.vmap(
                lambda a, b, c, d: _hamming_argmin_jnp(
                    a, b, c, d, row_band, max_disparity)
            )(desc_l, meta_l, desc_r, meta_r)
            return dist, idx
        return jax.vmap(
            lambda a, b, c, d, e, f: _match_rectify_jnp(
                a, b, c, d, e, f, row_band, max_disparity, max_hamming,
                sad_window, sad_range)
        )(desc_l, meta_l, desc_r, meta_r, img_l, img_r)
    bk = MO_BK if match_only else FM_BK
    dl = _pad_axis1(desc_l, bk)
    ml = _pad_axis1(meta_l, bk)
    dr = _pad_axis1(desc_r, FM_BM)
    mr = _pad_axis1(meta_r, FM_BM)
    _count_launches()
    if match_only:
        dist, idx = match_fused_pallas(
            dl, ml, dr, mr, row_band=float(row_band),
            max_disparity=float(max_disparity), interpret=_interpret())
        dist, idx = dist[:, :k], idx[:, :k]
        return dist, jnp.where(dist >= BIG, -1, idx)
    _, h, w = img_l.shape
    ry = sad_window // 2
    dist, idx, rxy, sad = match_rectify_fused_pallas(
        dl, ml, dr, mr, meta_r[:, 0, :2],
        _pad_fm_slab(img_l, ry, ry),
        _pad_fm_slab(img_r, ry, ry + sad_range),
        row_band=float(row_band), max_disparity=float(max_disparity),
        max_hamming=int(max_hamming), patch=int(sad_window),
        sad_range=int(sad_range), true_h=h, true_w=w,
        interpret=_interpret())
    dist, idx = dist[:, :k], idx[:, :k]
    return (dist, jnp.where(dist >= BIG, -1, idx), rxy[:, :k],
            sad[:, :k])


def sad_patch_search(img_l: jnp.ndarray, img_r: jnp.ndarray,
                     xy_l: jnp.ndarray, xy_r: jnp.ndarray, *,
                     sad_window: int = 11, sad_range: int = 5,
                     impl: str | None = None) -> jnp.ndarray:
    """SAD sweep with IN-KERNEL patch reads for caller-provided match
    targets (``sad_rectify``'s path): one launch replaces the host-graph
    full-image pad + 2*K ``dynamic_slice`` gather chain per pair.

    img_*: (P, H, W) level-0 images; xy_*: (P, K, 2) float32 window
    centers (left features / matched right features).  Returns the
    (P, K, 2*sad_range + 1) int32 SAD table — same contract as
    ``sad_search``, argmin taken by the caller."""
    if resolve_impl(impl) == "ref":
        return jax.vmap(
            lambda il, ir, xl, xr: _ref.sad_search(
                _ref.gather_patches(il, xl, sad_window, sad_window),
                _ref.gather_patches(ir, xr, sad_window,
                                    sad_window + 2 * sad_range))
        )(img_l, img_r, xy_l, xy_r)
    k = xy_l.shape[1]
    _, h, w = img_l.shape
    ry = sad_window // 2
    _count_launches()
    table = sad_fused_pallas(
        _pad_axis1(xy_l.astype(jnp.float32), FM_BK),
        _pad_axis1(xy_r.astype(jnp.float32), FM_BK),
        _pad_fm_slab(img_l, ry, ry),
        _pad_fm_slab(img_r, ry, ry + sad_range),
        patch=int(sad_window), sad_range=int(sad_range), true_h=h,
        true_w=w, interpret=_interpret())
    return table[:, :k]


NO_MATCH_DIST = BIG
