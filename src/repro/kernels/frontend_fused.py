"""Pallas TPU megakernel: fused batched quad-camera ORB frontend.

One VMEM pass per tile emits BOTH per-pixel products the ORB frontend
needs from a level image:

  * the 7x7-Gaussian-smoothed image (input to rBRIEF), and
  * the 3x3-NMS'd FAST-9/16 corner score map (input to top-K).

This is the TPU analog of the paper's frame-multiplexed FE (Sec.
III-B/III-C): the FPGA streams each frame once through a shared FAST +
smoothing datapath, multiplexing all four cameras through one module.
Here the leading grid dimension is a flattened batch of camera images,
so the VPU is time-multiplexed across cameras exactly as the FPGA FE is
time-multiplexed across channels — and each pixel is read from VMEM
once instead of once per op.  Two entry points share one tile body:

  * ``frontend_fused_pallas`` — one launch per pyramid level, batch =
    cameras, true (h, w) static (the PR-1 schedule, kept as the
    per-level oracle path), and
  * ``frontend_fused_pyramid_pallas`` — ONE launch per whole frame,
    batch = cameras x levels with ragged level slabs padded to a common
    tile grid and masked by a per-slab (true_h, true_w) shape table
    (the paper's whole-frame streaming FE, Sec. III-B).

Halo arithmetic: blur and FAST both need a 3-pixel stencil halo; fusing
the 3x3 NMS needs the *raw score* one pixel beyond the tile, and that
score row/column needs its own 3-pixel image halo — hence FUSED_HALO=4
(vs. HALO=3 for the unfused kernels).  Block = (1, TILE+8, TILE+8) f32
in VMEM via ``pl.Unblocked`` overlapping indexing; two (1, TILE, TILE)
outputs.  MXU-free, pure VPU stencil.

Boundary semantics match the ``ref.py`` oracle chain exactly:
  * image taps outside the true image replicate the edge pixel
    (``ops.py`` edge-pads before tiling), and
  * NMS neighbours outside the true (H, W) image are -1.0 (the constant
    pad of ``ref.nms3``) — the kernel masks by global pixel coordinate,
    which also keeps tile-alignment padding from suppressing real
    corners.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (ARC_LEN, CIRCLE16, GAUSS7_NORM,
                               GAUSS7_WEIGHTS_INT, int_threshold)

TILE_H = 128
TILE_W = 128
FUSED_HALO = 4          # 3 (7x7 blur / FAST circle) + 1 (in-kernel 3x3 NMS)


def arc_extrema(taps):
    """Per-start (min, max) over the 9 contiguous circular taps of each
    FAST-9/16 arc, via block prefix/suffix extrema (van Herk/Gil-Werman
    sliding-window trick on the circular 16-sequence).

    ~half the min/max ops of naively unrolling 16 windows x 8
    comparisons, and BIT-exact — min/max are associative and
    commutative, so reassociation cannot change any result.  Shared by
    the Pallas kernel body and the interpret-free jnp fallback; shape-
    agnostic (works on any list of same-shape arrays).

    taps: list of 16 arrays.  Returns (arc_min, arc_max): lists of 16
    arrays where arc_min[s] = min(taps[s..s+8 mod 16]) etc.
    """
    wlen = ARC_LEN
    ext = list(taps) + list(taps[:wlen - 1])       # unroll the wrap
    m = len(ext)
    pmin = [None] * m
    pmax = [None] * m
    for i in range(m):
        if i % wlen == 0:
            pmin[i], pmax[i] = ext[i], ext[i]
        else:
            pmin[i] = jnp.minimum(pmin[i - 1], ext[i])
            pmax[i] = jnp.maximum(pmax[i - 1], ext[i])
    smin = [None] * m
    smax = [None] * m
    for i in reversed(range(m)):
        if i % wlen == wlen - 1 or i == m - 1:
            smin[i], smax[i] = ext[i], ext[i]
        else:
            smin[i] = jnp.minimum(smin[i + 1], ext[i])
            smax[i] = jnp.maximum(smax[i + 1], ext[i])
    arc_min, arc_max = [], []
    for i in range(len(taps)):
        j = i + wlen - 1
        if i % wlen == 0:                           # window == one block
            arc_min.append(pmin[j])
            arc_max.append(pmax[j])
        else:
            arc_min.append(jnp.minimum(smin[i], pmin[j]))
            arc_max.append(jnp.maximum(smax[i], pmax[j]))
    return arc_min, arc_max


def fast_score_from_taps(taps, threshold: float):
    """FAST-9/16 score from the 16 circle-tap difference arrays:
    max over arc starts of (min over bright arc, -max over dark arc),
    thresholded to 0.  Exact; shared by kernel and jnp fallback."""
    arc_min, arc_max = arc_extrema(taps)
    bright = arc_min[0]
    dark = arc_max[0]
    for s in range(1, len(taps)):
        bright = jnp.maximum(bright, arc_min[s])
        dark = jnp.minimum(dark, arc_max[s])
    score = jnp.maximum(bright, -dark)
    # Integer taps (the uint8 datapath) compare against floor(threshold)
    # — exactly ``score > threshold`` for integer scores (ref.int_threshold).
    if jnp.issubdtype(score.dtype, jnp.integer):
        thr = jnp.asarray(int_threshold(threshold), score.dtype)
    else:
        thr = jnp.asarray(threshold, score.dtype)
    return jnp.where(score > thr, score, jnp.zeros_like(score))


def _tile_outputs(x, true_h, true_w, *, threshold: float, nms: bool,
                  quantized: bool, tile_h: int, tile_w: int):
    """Shared per-tile body: (tile_h + 8, tile_w + 8) input window ->
    (blur, score), each (tile_h, tile_w).  ``true_h``/``true_w`` may be
    static Python ints (per-level launch) or traced scalars read from the
    whole-pyramid shape table — the NMS boundary mask broadcasts either
    way, so both launch schedules run the exact same math.

    Dtype is static at trace time, so the integer datapath (paper Sec.
    III word length: uint8 slab in, int32 accumulators, uint8 blur +
    int16 score out) and the f32 datapath share this one body — the
    branch below selects accumulator/literal dtypes, nothing else."""
    fh = FUSED_HALO
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    if integer:
        x = x.astype(jnp.int32)        # int32 accumulate, uint8 values

    # ---- 7x7 separable Gaussian (needs halo 3: rows/cols 1..tile+7) ----
    w = ([int(v) for v in GAUSS7_WEIGHTS_INT] if integer
         else [float(v) for v in GAUSS7_WEIGHTS_INT])
    horiz = None
    for k in range(7):
        term = w[k] * x[1:tile_h + 7, 1 + k:1 + k + tile_w]
        horiz = term if horiz is None else horiz + term    # (tile_h+6, tile_w)
    vert = None
    for k in range(7):
        term = w[k] * horiz[k:k + tile_h, :]
        vert = term if vert is None else vert + term       # (tile_h, tile_w)
    norm2 = GAUSS7_NORM * GAUSS7_NORM
    if integer:
        # Exact round-half-up division; vert + 648 < 2^24, the same
        # quotient the f32 floor computes (ref.gaussian_blur7_u8).
        blur = ((vert + norm2 // 2) // norm2).astype(jnp.uint8)
    elif quantized:
        blur = jnp.floor((vert + norm2 / 2.0) / float(norm2))
    else:
        blur = vert / float(norm2)

    # ---- FAST-9/16 raw score on the (tile+2)^2 window (1-px NMS rim) ----
    eh, ew = tile_h + 2, tile_w + 2
    center = x[fh - 1:fh - 1 + eh, fh - 1:fh - 1 + ew]
    taps = [
        x[fh - 1 + dy:fh - 1 + dy + eh, fh - 1 + dx:fh - 1 + dx + ew] - center
        for dx, dy in CIRCLE16
    ]
    score = fast_score_from_taps(taps, threshold)

    # Mask pixels outside the true image to -1.0 — the ref.nms3 constant
    # pad — so image borders and tile-alignment padding never win NMS.
    i = pl.program_id(1)
    j = pl.program_id(2)
    rows = i * tile_h - 1 + jax.lax.broadcasted_iota(jnp.int32, (eh, ew), 0)
    cols = j * tile_w - 1 + jax.lax.broadcasted_iota(jnp.int32, (eh, ew), 1)
    inside = ((rows >= 0) & (rows < true_h) & (cols >= 0) & (cols < true_w))
    score = jnp.where(inside, score, jnp.asarray(-1, score.dtype))

    cs = score[1:1 + tile_h, 1:1 + tile_w]
    if nms:
        # Separable 3x3 max INCLUDING the center: cs >= max(cs, nbrs)
        # iff cs >= max(nbrs), so the NMS decision is unchanged while
        # the 8-neighbour max folds into 2 + 2 row/column maxes.
        rmax = jnp.maximum(jnp.maximum(score[:eh - 2, :], score[1:eh - 1, :]),
                           score[2:, :])
        nmax = jnp.maximum(jnp.maximum(rmax[:, :ew - 2], rmax[:, 1:ew - 1]),
                           rmax[:, 2:])
        out = (jnp.where(cs >= nmax, cs, jnp.zeros_like(cs))
               * (cs > 0).astype(cs.dtype))
    else:
        out = jnp.maximum(cs, jnp.zeros_like(cs))  # strip the -1 sentinel
    if integer:
        out = out.astype(jnp.int16)        # FAST scores live in [0, 255]
    return blur, out


def _slab_dtypes(padded, quantized: bool):
    """Resolve the (input slab, (blur, score) output dtypes) pair from
    the slab dtype: integer slabs run the uint8 datapath (requires the
    quantized blur — the float blur is not representable in uint8),
    float slabs the f32 one."""
    if jnp.issubdtype(padded.dtype, jnp.integer):
        if not quantized:
            raise ValueError(
                "uint8 datapath requires quantized=True (the float "
                "Gaussian is not representable in a uint8 slab)")
        return padded.astype(jnp.uint8), (jnp.uint8, jnp.int16)
    return padded.astype(jnp.float32), (jnp.float32, jnp.float32)


def _kernel(x_ref, blur_ref, score_ref, *, threshold: float, nms: bool,
            quantized: bool, true_h: int, true_w: int,
            tile_h: int, tile_w: int):
    blur, out = _tile_outputs(x_ref[0], true_h, true_w, threshold=threshold,
                              nms=nms, quantized=quantized,
                              tile_h=tile_h, tile_w=tile_w)
    blur_ref[...] = blur[None]
    score_ref[...] = out[None]


def _kernel_pyramid(x_ref, hw_ref, blur_ref, score_ref, *, threshold: float,
                    nms: bool, quantized: bool, tile_h: int, tile_w: int):
    """Whole-pyramid variant: the slab's true (h, w) comes from the
    per-slab shape table instead of static kwargs — every other
    instruction is shared with the per-level kernel."""
    blur, out = _tile_outputs(x_ref[0], hw_ref[0, 0], hw_ref[0, 1],
                              threshold=threshold, nms=nms,
                              quantized=quantized,
                              tile_h=tile_h, tile_w=tile_w)
    blur_ref[...] = blur[None]
    score_ref[...] = out[None]


@functools.partial(jax.jit, static_argnames=(
    "threshold", "nms", "quantized", "true_h", "true_w", "interpret"))
def frontend_fused_pallas(padded: jnp.ndarray, *, threshold: float,
                          nms: bool = True, quantized: bool = True,
                          true_h: int, true_w: int,
                          interpret: bool = False):
    """padded: (B, H + 8, W + 8) float32 OR uint8, edge-padded by
    FUSED_HALO and tile-aligned (H % TILE_H == 0, W % TILE_W == 0 —
    ``ops.py`` guarantees this).  (true_h, true_w) is the un-tile-padded
    image size used for the NMS boundary mask.  Returns (blur, score):
    (B, H, W) float32 pair for float input, (uint8 blur, int16 score)
    for uint8 input (the integer datapath — 4x less VMEM per resident
    tile, same values on quantized images)."""
    padded, out_dtypes = _slab_dtypes(padded, quantized)
    b = padded.shape[0]
    h = padded.shape[1] - 2 * FUSED_HALO
    w = padded.shape[2] - 2 * FUSED_HALO
    grid = (b, h // TILE_H, w // TILE_W)
    kern = functools.partial(
        _kernel, threshold=float(threshold), nms=bool(nms),
        quantized=bool(quantized), true_h=int(true_h), true_w=int(true_w),
        tile_h=TILE_H, tile_w=TILE_W)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(
            (1, TILE_H + 2 * FUSED_HALO, TILE_W + 2 * FUSED_HALO),
            lambda bb, i, j: (bb, i * TILE_H, j * TILE_W),
            indexing_mode=pl.Unblocked())],
        out_specs=[
            pl.BlockSpec((1, TILE_H, TILE_W), lambda bb, i, j: (bb, i, j)),
            pl.BlockSpec((1, TILE_H, TILE_W), lambda bb, i, j: (bb, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, w), out_dtypes[0]),
            jax.ShapeDtypeStruct((b, h, w), out_dtypes[1]),
        ],
        interpret=interpret,
    )(padded)


@functools.partial(jax.jit, static_argnames=(
    "threshold", "nms", "quantized", "interpret"))
def frontend_fused_pyramid_pallas(padded: jnp.ndarray, hw: jnp.ndarray, *,
                                  threshold: float, nms: bool = True,
                                  quantized: bool = True,
                                  interpret: bool = False):
    """Whole-pyramid dense launch: ALL cameras x ALL levels in ONE
    ``pallas_call`` whose grid walks (slab, tile_i, tile_j).

    padded: (N, Hc + 8, Wc + 8) float32 — N = levels x cameras flattened
    level-major; every ragged level slab is edge-padded by FUSED_HALO and
    out to the COMMON tile-aligned (Hc, Wc) canvas (``ops.py`` owns that
    padding).  hw: (N, 2) int32 per-slab (true_h, true_w) — the shape
    table the kernel masks by, so tiles that fall in a small level's
    padding region emit only the -1/0 sentinels and never win NMS.
    Returns (blur, score), each (N, Hc, Wc): float32 pair for float
    input, (uint8, int16) for uint8 slabs (integer datapath); callers
    slice each slab back to its true shape.

    TPU-validation note: the (1, 2) int32 shape-table block rides in the
    default memory space; on a real Mosaic build it belongs in SMEM
    (scalar prefetch), like the keypoint blocks of ``describe_fused``.
    """
    padded, out_dtypes = _slab_dtypes(padded, quantized)
    n = padded.shape[0]
    h = padded.shape[1] - 2 * FUSED_HALO
    w = padded.shape[2] - 2 * FUSED_HALO
    grid = (n, h // TILE_H, w // TILE_W)
    kern = functools.partial(
        _kernel_pyramid, threshold=float(threshold), nms=bool(nms),
        quantized=bool(quantized), tile_h=TILE_H, tile_w=TILE_W)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, TILE_H + 2 * FUSED_HALO, TILE_W + 2 * FUSED_HALO),
                lambda bb, i, j: (bb, i * TILE_H, j * TILE_W),
                indexing_mode=pl.Unblocked()),
            pl.BlockSpec((1, 2), lambda bb, i, j: (bb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_H, TILE_W), lambda bb, i, j: (bb, i, j)),
            pl.BlockSpec((1, TILE_H, TILE_W), lambda bb, i, j: (bb, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, h, w), out_dtypes[0]),
            jax.ShapeDtypeStruct((n, h, w), out_dtypes[1]),
        ],
        interpret=interpret,
    )(padded, hw.astype(jnp.int32))
