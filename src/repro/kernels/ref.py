"""Pure-jnp oracles for every Pallas kernel.

These are the ground-truth implementations: numerically straightforward,
shape-polymorphic, no tiling.  ``ops.py`` dispatches between these and
the Pallas kernels; tests assert exact/allclose agreement on shape and
dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Bresenham circle of radius 3 — the 16 FAST taps, in order around the
# circle, as (dx, dy) with y down.  (paper Sec. II-B1)
CIRCLE16: tuple[tuple[int, int], ...] = (
    (0, -3), (1, -3), (2, -2), (3, -1), (3, 0), (3, 1), (2, 2), (1, 3),
    (0, 3), (-1, 3), (-2, 2), (-3, 1), (-3, 0), (-3, -1), (-2, -2), (-1, -3),
)
ARC_LEN = 9  # FAST-9/16: a corner needs >= 9 contiguous bright/dark taps


def fast_score_map(img: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """FAST-9/16 corner score map.

    score(p) = max(max_s min_{j<9} d[s+j], -min_s max_{j<9} d[s+j]) where
    d[i] = I(circle_i) - I(p); a pixel is a corner iff score > threshold.
    Returns float32 (H, W); 0 where not a corner.  Border pixels (3 px)
    use edge padding and are masked downstream by the feature border.
    """
    img = img.astype(jnp.float32)
    h, w = img.shape
    pad = jnp.pad(img, 3, mode="edge")
    taps = [
        jax.lax.dynamic_slice(pad, (3 + dy, 3 + dx), (h, w)) - img
        for dx, dy in CIRCLE16
    ]
    d = jnp.stack(taps)                        # (16, H, W)
    dd = jnp.concatenate([d, d[: ARC_LEN - 1]], axis=0)   # wrap for arcs
    bright = jnp.stack(
        [jnp.min(dd[s : s + ARC_LEN], axis=0) for s in range(16)]
    )                                           # (16, H, W) min over each arc
    dark = jnp.stack(
        [jnp.max(dd[s : s + ARC_LEN], axis=0) for s in range(16)]
    )
    score = jnp.maximum(jnp.max(bright, axis=0), -jnp.min(dark, axis=0))
    return jnp.where(score > threshold, score, 0.0).astype(jnp.float32)


def nms3(score: jnp.ndarray) -> jnp.ndarray:
    """3x3 non-max suppression: keep pixels that are the strict max of
    their neighbourhood (score >= all 8 neighbours, and positive).

    Neighbours outside the image are -1.0 (constant pad), so border
    pixels compete only against real pixels.  This is the oracle for the
    NMS stage fused into ``frontend_fused.py``; the frontend hot path no
    longer runs these eight host-graph dynamic slices.
    """
    h, w = score.shape
    pad = jnp.pad(score, 1, mode="constant", constant_values=-1.0)
    neigh = []
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            neigh.append(jax.lax.dynamic_slice(pad, (1 + dy, 1 + dx), (h, w)))
    nmax = functools.reduce(jnp.maximum, neigh)
    return jnp.where(score >= nmax, score, 0.0) * (score > 0.0)


def fast_blur_nms(img: jnp.ndarray, threshold: float, *, nms: bool = True,
                  quantized: bool = True):
    """Single-image oracle for the fused frontend megakernel.

    Returns (blur, score): the 7x7-Gaussian-smoothed image and the
    (optionally NMS'd) FAST-9/16 score map, exactly the two outputs
    ``frontend_fused_pallas`` emits per batch slice.
    """
    blur = gaussian_blur7(img, quantized=quantized)
    score = fast_score_map(img, threshold)
    if nms:
        score = nms3(score)
    return blur, score


# 7x7 Gaussian (sigma=2) with integer weights — the word-length-optimized
# filter of paper Sec. III-C.  Integer taps keep the quantized path exact.
GAUSS7_WEIGHTS_INT = np.array([1, 4, 8, 10, 8, 4, 1], dtype=np.int32)
GAUSS7_NORM = int(GAUSS7_WEIGHTS_INT.sum())  # 36


def gaussian_blur7(img: jnp.ndarray, quantized: bool = True) -> jnp.ndarray:
    """Separable 7x7 Gaussian smoothing (paper's Image Smoothing module).

    quantized=True reproduces the 8-bit datapath: integer taps, integer
    accumulate, single rounding division at the end (exactly computable
    in int32, so the Pallas kernel can match bit-for-bit).
    """
    w = jnp.asarray(GAUSS7_WEIGHTS_INT, dtype=jnp.float32)
    img_f = img.astype(jnp.float32)
    pad = jnp.pad(img_f, 3, mode="edge")
    h, wid = img.shape
    # Horizontal then vertical pass, as two explicit tap sums (streaming
    # line-buffer analog; avoids conv_general_dilated for interpret parity).
    horiz = sum(
        w[k] * jax.lax.dynamic_slice(pad, (3, k), (h + 6, wid))
        for k in range(7)
    )                                             # (H+6, W), weight-summed x
    vert = sum(
        w[k] * jax.lax.dynamic_slice(horiz, (k, 0), (h, wid))
        for k in range(7)
    )                                             # (H, W)
    if quantized:
        # round-half-up of vert / norm^2, all-integer equivalent
        return jnp.floor((vert + (GAUSS7_NORM * GAUSS7_NORM) / 2.0)
                         / (GAUSS7_NORM * GAUSS7_NORM)).astype(jnp.float32)
    return vert / float(GAUSS7_NORM * GAUSS7_NORM)


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint32 array -> int32 (no native popcount on VPU)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def hamming_distance_matrix(desc_l: jnp.ndarray,
                            desc_r: jnp.ndarray) -> jnp.ndarray:
    """(K, 8) x (M, 8) uint32 descriptors -> (K, M) int32 Hamming distances."""
    x = jnp.bitwise_xor(desc_l[:, None, :], desc_r[None, :, :])
    return jnp.sum(_popcount32(x), axis=-1)


def sad_search(left_patches: jnp.ndarray,
               right_strips: jnp.ndarray) -> jnp.ndarray:
    """SAD rectification sweep (paper Sec. II-C2 / III-D).

    left_patches: (K, P, P) — window around each left feature.
    right_strips: (K, P, P + 2R) — horizontal strip around the matched
      right feature.
    Returns (K, 2R + 1) int32 SAD values; caller argmins to re-locate F'.
    """
    k, p, _ = left_patches.shape
    sweep = right_strips.shape[-1] - p + 1
    lp = left_patches.astype(jnp.int32)
    rs = right_strips.astype(jnp.int32)
    sads = [
        jnp.sum(jnp.abs(lp - jax.lax.dynamic_slice_in_dim(rs, s, p, axis=2)),
                axis=(1, 2))
        for s in range(sweep)
    ]
    return jnp.stack(sads, axis=1)
