"""Pure-jnp oracles for every Pallas kernel.

These are the ground-truth implementations: numerically straightforward,
shape-polymorphic, no tiling.  ``ops.py`` dispatches between these and
the Pallas kernels; tests assert exact/allclose agreement on shape and
dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pattern

# Bresenham circle of radius 3 — the 16 FAST taps, in order around the
# circle, as (dx, dy) with y down.  (paper Sec. II-B1)
CIRCLE16: tuple[tuple[int, int], ...] = (
    (0, -3), (1, -3), (2, -2), (3, -1), (3, 0), (3, 1), (2, 2), (1, 3),
    (0, 3), (-1, 3), (-2, 2), (-3, 1), (-3, 0), (-3, -1), (-2, -2), (-1, -3),
)
ARC_LEN = 9  # FAST-9/16: a corner needs >= 9 contiguous bright/dark taps


def fast_score_map(img: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """FAST-9/16 corner score map.

    score(p) = max(max_s min_{j<9} d[s+j], -min_s max_{j<9} d[s+j]) where
    d[i] = I(circle_i) - I(p); a pixel is a corner iff score > threshold.
    Returns float32 (H, W); 0 where not a corner.  Border pixels (3 px)
    use edge padding and are masked downstream by the feature border.
    """
    img = img.astype(jnp.float32)
    h, w = img.shape
    pad = jnp.pad(img, 3, mode="edge")
    taps = [
        jax.lax.dynamic_slice(pad, (3 + dy, 3 + dx), (h, w)) - img
        for dx, dy in CIRCLE16
    ]
    d = jnp.stack(taps)                        # (16, H, W)
    dd = jnp.concatenate([d, d[: ARC_LEN - 1]], axis=0)   # wrap for arcs
    bright = jnp.stack(
        [jnp.min(dd[s : s + ARC_LEN], axis=0) for s in range(16)]
    )                                           # (16, H, W) min over each arc
    dark = jnp.stack(
        [jnp.max(dd[s : s + ARC_LEN], axis=0) for s in range(16)]
    )
    score = jnp.maximum(jnp.max(bright, axis=0), -jnp.min(dark, axis=0))
    return jnp.where(score > threshold, score, 0.0).astype(jnp.float32)


def nms3(score: jnp.ndarray) -> jnp.ndarray:
    """3x3 non-max suppression: keep pixels that are the strict max of
    their neighbourhood (score >= all 8 neighbours, and positive).

    Neighbours outside the image are -1.0 (constant pad), so border
    pixels compete only against real pixels.  This is the oracle for the
    NMS stage fused into ``frontend_fused.py``; the frontend hot path no
    longer runs these eight host-graph dynamic slices.
    """
    h, w = score.shape
    pad = jnp.pad(score, 1, mode="constant",
                  constant_values=jnp.asarray(-1, score.dtype))
    neigh = []
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            neigh.append(jax.lax.dynamic_slice(pad, (1 + dy, 1 + dx), (h, w)))
    nmax = functools.reduce(jnp.maximum, neigh)
    keep = jnp.where(score >= nmax, score, jnp.zeros_like(score))
    return keep * (score > 0).astype(score.dtype)


def fast_blur_nms(img: jnp.ndarray, threshold: float, *, nms: bool = True,
                  quantized: bool = True):
    """Single-image oracle for the fused frontend megakernel.

    Returns (blur, score): the 7x7-Gaussian-smoothed image and the
    (optionally NMS'd) FAST-9/16 score map, exactly the two outputs
    ``frontend_fused_pallas`` emits per batch slice.
    """
    blur = gaussian_blur7(img, quantized=quantized)
    score = fast_score_map(img, threshold)
    if nms:
        score = nms3(score)
    return blur, score


# 7x7 Gaussian (sigma=2) with integer weights — the word-length-optimized
# filter of paper Sec. III-C.  Integer taps keep the quantized path exact.
GAUSS7_WEIGHTS_INT = np.array([1, 4, 8, 10, 8, 4, 1], dtype=np.int32)
GAUSS7_NORM = int(GAUSS7_WEIGHTS_INT.sum())  # 36


def gaussian_blur7(img: jnp.ndarray, quantized: bool = True) -> jnp.ndarray:
    """Separable 7x7 Gaussian smoothing (paper's Image Smoothing module).

    quantized=True reproduces the 8-bit datapath: integer taps, integer
    accumulate, single rounding division at the end (exactly computable
    in int32, so the Pallas kernel can match bit-for-bit).
    """
    w = jnp.asarray(GAUSS7_WEIGHTS_INT, dtype=jnp.float32)
    img_f = img.astype(jnp.float32)
    pad = jnp.pad(img_f, 3, mode="edge")
    h, wid = img.shape
    # Horizontal then vertical pass, as two explicit tap sums (streaming
    # line-buffer analog; avoids conv_general_dilated for interpret parity).
    horiz = sum(
        w[k] * jax.lax.dynamic_slice(pad, (3, k), (h + 6, wid))
        for k in range(7)
    )                                             # (H+6, W), weight-summed x
    vert = sum(
        w[k] * jax.lax.dynamic_slice(horiz, (k, 0), (h, wid))
        for k in range(7)
    )                                             # (H, W)
    if quantized:
        # round-half-up of vert / norm^2, all-integer equivalent
        return jnp.floor((vert + (GAUSS7_NORM * GAUSS7_NORM) / 2.0)
                         / (GAUSS7_NORM * GAUSS7_NORM)).astype(jnp.float32)
    return vert / float(GAUSS7_NORM * GAUSS7_NORM)


# ---------------------------------------------------------------------------
# Integer-datapath oracles (paper Sec. III word-length optimization).
#
# The uint8 pipeline holds pyramid slabs as uint8 and runs blur / FAST /
# NMS / moments on integer accumulators.  Each oracle below states why
# its output is BIT-EQUAL to the f32 oracle on quantized (integer-
# valued) images; tests pin that equivalence on ref and
# pallas-interpret.

def int_threshold(threshold: float) -> int:
    """FAST threshold for the integer datapath.  For integer scores,
    ``score > threshold`` == ``score > floor(threshold)`` exactly, so
    the int16 compare reproduces the f32 compare bit-for-bit."""
    return int(np.floor(threshold))


def fast_score_map_int(img: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """Integer FAST-9/16 oracle: uint8 image -> int16 score map.

    Taps d = I(circle) - I(p) live in [-255, 255]; arc min/max and the
    final max stay in that range, so int16 is exact and equals the f32
    oracle's values on integer images.
    """
    img_i = img.astype(jnp.int32)
    h, w = img.shape
    pad = jnp.pad(img_i, 3, mode="edge")
    taps = [
        jax.lax.dynamic_slice(pad, (3 + dy, 3 + dx), (h, w)) - img_i
        for dx, dy in CIRCLE16
    ]
    d = jnp.stack(taps)
    dd = jnp.concatenate([d, d[: ARC_LEN - 1]], axis=0)
    bright = jnp.stack(
        [jnp.min(dd[s: s + ARC_LEN], axis=0) for s in range(16)]
    )
    dark = jnp.stack(
        [jnp.max(dd[s: s + ARC_LEN], axis=0) for s in range(16)]
    )
    score = jnp.maximum(jnp.max(bright, axis=0), -jnp.min(dark, axis=0))
    thr = jnp.int32(int_threshold(threshold))
    return jnp.where(score > thr, score, 0).astype(jnp.int16)


def gaussian_blur7_u8(img: jnp.ndarray) -> jnp.ndarray:
    """Integer-datapath 7x7 Gaussian: uint8 -> uint8.

    int32 accumulate + round-half-up integer division.  vert + 648 <=
    255*36*36 + 648 = 331128 < 2^24, so the f32 oracle's
    ``floor((vert + 648.0) / 1296.0)`` computes the same quotient: the
    int32 path is bit-equal to ``gaussian_blur7(img, quantized=True)``.
    """
    w = jnp.asarray(GAUSS7_WEIGHTS_INT, dtype=jnp.int32)
    pad = jnp.pad(img.astype(jnp.int32), 3, mode="edge")
    h, wid = img.shape
    horiz = sum(
        w[k] * jax.lax.dynamic_slice(pad, (3, k), (h + 6, wid))
        for k in range(7)
    )
    vert = sum(
        w[k] * jax.lax.dynamic_slice(horiz, (k, 0), (h, wid))
        for k in range(7)
    )
    norm2 = GAUSS7_NORM * GAUSS7_NORM
    return ((vert + norm2 // 2) // norm2).astype(jnp.uint8)


def fast_blur_nms_int(img: jnp.ndarray, threshold: float, *,
                      nms: bool = True):
    """uint8 single-image oracle for the fused frontend: returns
    (blur uint8, score int16) — the integer twins of ``fast_blur_nms``'s
    outputs, equal in value on quantized images."""
    blur = gaussian_blur7_u8(img)
    score = fast_score_map_int(img, threshold)
    if nms:
        score = nms3(score)
    return blur, score


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint32 array -> int32 (no native popcount on VPU)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def hamming_distance_matrix(desc_l: jnp.ndarray,
                            desc_r: jnp.ndarray) -> jnp.ndarray:
    """(K, 8) x (M, 8) uint32 descriptors -> (K, M) int32 Hamming distances."""
    x = jnp.bitwise_xor(desc_l[:, None, :], desc_r[None, :, :])
    return jnp.sum(_popcount32(x), axis=-1)


# ---------------------------------------------------------------------------
# 31x31 patch oracles — the sparse descriptor stage (orientation + rBRIEF).
#
# These are the single definition of the edge-pad + patch-slice geometry
# that used to be copy-pasted between ``fast.orientations`` and
# ``brief.describe``; both core wrappers and the fused Pallas kernel
# (``describe_fused.py``) build on them.

PATCH = 2 * pattern.PATCH_RADIUS + 1      # 31
RADIUS = pattern.PATCH_RADIUS             # 15


def pad_patch(img: jnp.ndarray) -> jnp.ndarray:
    """Edge-pad by RADIUS so a 31x31 slice starting at (y, x) of the
    padded image is the patch *centered* on pixel (x, y)."""
    return jnp.pad(img.astype(jnp.float32), RADIUS, mode="edge")


def extract_patches(img: jnp.ndarray, xy: jnp.ndarray, *,
                    preserve_dtype: bool = False) -> jnp.ndarray:
    """(H, W) image + (K, 2) int32 centers -> (K, 31, 31) patches.

    Centers are clamped into the image (top-K padding rows may carry
    arbitrary coordinates) — identical clamping to the Pallas kernel.
    This is the host-graph gather the fused kernel replaces; kept as the
    oracle and the single-image fallback.  ``preserve_dtype=True`` keeps
    the input dtype (the uint8 datapath); default casts to f32 as the
    f32 oracle always did.
    """
    padded = (jnp.pad(img, RADIUS, mode="edge") if preserve_dtype
              else pad_patch(img))
    h, w = img.shape

    def one(pt):
        x = jnp.clip(pt[0], 0, w - 1)
        y = jnp.clip(pt[1], 0, h - 1)
        return jax.lax.dynamic_slice(padded, (y, x), (PATCH, PATCH))

    return jax.vmap(one)(xy)


def moment_grids():
    """The circular-mask moment grids (X_GRID, Y_GRID) built from 2D
    iota instead of baked numpy constants — bit-identical values (small
    integers are exact in f32), but legal inside a Pallas kernel body,
    where captured array constants are rejected."""
    yy = (jax.lax.broadcasted_iota(jnp.float32, (PATCH, PATCH), 0)
          - float(RADIUS))
    xx = (jax.lax.broadcasted_iota(jnp.float32, (PATCH, PATCH), 1)
          - float(RADIUS))
    mask = (xx * xx + yy * yy <= float(RADIUS * RADIUS)).astype(jnp.float32)
    return xx * mask, yy * mask


def patch_theta(patches: jnp.ndarray):
    """(..., 31, 31) raw patches -> (theta (...,), moments (..., 2)).

    Intensity-centroid moments over the circular patch (paper Eq. 1):
    m10 = sum(x * I), m01 = sum(y * I), theta = atan2(m01, m10).  Shared
    verbatim by the ref oracle, the jnp fallback and the Pallas kernel
    body so all three are bit-identical.
    """
    xg, yg = moment_grids()
    m10 = jnp.sum(patches * xg, axis=(-2, -1))
    m01 = jnp.sum(patches * yg, axis=(-2, -1))
    return jnp.arctan2(m01, m10), jnp.stack([m10, m01], axis=-1)


def moment_grids_int():
    """Integer twins of ``moment_grids``: int32 circular-mask coordinate
    grids for the uint8 datapath's int32 moment accumulators."""
    yy = (jax.lax.broadcasted_iota(jnp.int32, (PATCH, PATCH), 0)
          - RADIUS)
    xx = (jax.lax.broadcasted_iota(jnp.int32, (PATCH, PATCH), 1)
          - RADIUS)
    mask = (xx * xx + yy * yy <= RADIUS * RADIUS).astype(jnp.int32)
    return xx * mask, yy * mask


def patch_theta_int(patches: jnp.ndarray):
    """uint8 (..., 31, 31) patches -> (theta (...,) f32, moments
    (..., 2) int32), int32 accumulators.

    |m10|, |m01| <= 255 * sum|x| over the circular mask ~ 1.4e6 < 2^24,
    so the f32 oracle's moment sums are exact and the int32 moments
    equal them; theta = atan2 of the same two f32 values is bit-equal.
    """
    xg, yg = moment_grids_int()
    p = patches.astype(jnp.int32)
    m10 = jnp.sum(p * xg, axis=(-2, -1))
    m01 = jnp.sum(p * yg, axis=(-2, -1))
    theta = jnp.arctan2(m01.astype(jnp.float32), m10.astype(jnp.float32))
    return theta, jnp.stack([m10, m01], axis=-1)


def orient_describe_int(raw: jnp.ndarray, smoothed: jnp.ndarray,
                        xy: jnp.ndarray):
    """uint8 single-image oracle for the fused sparse stage.

    raw/smoothed: (H, W) uint8 level image + its uint8 blur; xy: (K, 2)
    int32.  Returns (theta f32, moments int32 (K, 2), desc uint32
    (K, 8)).  Theta is bit-equal to the f32 oracle (see
    ``patch_theta_int``); descriptors compare the same integer tap
    values, so they are bit-equal too.
    """
    theta, mom = patch_theta_int(
        extract_patches(raw, xy, preserve_dtype=True))
    desc = lut_descriptor(
        extract_patches(smoothed, xy, preserve_dtype=True),
        theta_to_bin(theta))
    return theta, mom, desc


# theta -> steering bin: nearest bin center, bins at b * ANGLE_BIN_STEP.
_INV_ANGLE_STEP = float(pattern.N_ANGLE_BINS / (2.0 * np.pi))


def theta_to_bin(theta: jnp.ndarray) -> jnp.ndarray:
    """(...,) float32 theta in (-pi, pi] -> (...,) int32 bin in [0, 12)."""
    return jnp.mod(jnp.round(theta * _INV_ANGLE_STEP).astype(jnp.int32),
                   pattern.N_ANGLE_BINS)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., 256) bool -> (..., 8) uint32, bit i of word i // 32.

    The paper's 32 x 8-bit descriptor RAM layout.  Bitwise-disjoint
    uint32 adds, so any summation order is exact.
    """
    w = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], 8, 32)
    weights = (jnp.uint32(1)
               << jax.lax.broadcasted_iota(jnp.uint32, (8, 32), 1))
    return jnp.sum(w * weights, axis=-1)


def lut_descriptor(sm_patches: jnp.ndarray,
                   bins: jnp.ndarray) -> jnp.ndarray:
    """(K, 31, 31) smoothed patches + (K,) int32 steering bins ->
    (K, 8) uint32 rBRIEF descriptors (gather oracle).

    Taps are resolved through ``pattern.STEER_LUT`` — the same ROM the
    Pallas kernel reads; the kernel differs only in resolving taps with
    a one-hot matmul instead of this gather, which cannot change any bit
    (tau = p(A) < p(B) iff fl(p(B) - p(A)) > 0 exactly in f32).
    """
    lut = jnp.asarray(pattern.STEER_LUT)                 # (12, 256, 2)
    idx = lut[bins]                                      # (K, 256, 2)
    flat = sm_patches.reshape(-1, PATCH * PATCH)
    pa = jnp.take_along_axis(flat, idx[..., 0], axis=1)
    pb = jnp.take_along_axis(flat, idx[..., 1], axis=1)
    return pack_bits(pa < pb)                            # paper Eq. 2


def orient_describe(raw: jnp.ndarray, smoothed: jnp.ndarray,
                    xy: jnp.ndarray):
    """Single-image oracle for the fused sparse stage.

    raw/smoothed: (H, W) float32 level image and its 7x7-Gaussian blur;
    xy: (K, 2) int32 level coords.  Returns (theta (K,), moments (K, 2),
    desc (K, 8) uint32) — exactly the three outputs
    ``describe_fused_pallas`` emits per (camera, K-block) grid step.
    """
    theta, mom = patch_theta(extract_patches(raw, xy))
    desc = lut_descriptor(extract_patches(smoothed, xy),
                          theta_to_bin(theta))
    return theta, mom, desc


def steered_offsets(theta: jnp.ndarray):
    """EXACT pattern steering for one angle (paper Eq. 3): per-angle
    cos/sin + round.  Returns int32 (N, 2) offsets for A and B points.

    Superseded in the pipeline by the binned ``pattern.STEER_LUT``; kept
    as the reference the bin quantization is measured against (and the
    pre-refactor descriptor definition).
    """
    c, s = jnp.cos(theta), jnp.sin(theta)
    pa = jnp.asarray(pattern.PATTERN_A, dtype=jnp.float32)
    pb = jnp.asarray(pattern.PATTERN_B, dtype=jnp.float32)

    def rot(p):
        x = c * p[:, 0] - s * p[:, 1]
        y = s * p[:, 0] + c * p[:, 1]
        return jnp.stack([jnp.round(x), jnp.round(y)], axis=-1).astype(
            jnp.int32)

    return rot(pa), rot(pb)


def describe_steered(smoothed: jnp.ndarray, xy: jnp.ndarray,
                     theta: jnp.ndarray) -> jnp.ndarray:
    """Pre-refactor EXACT-steering rBRIEF oracle: (K, 8) uint32.

    Rotates all 256 pairs by each keypoint's exact theta.  The pipeline
    now uses the binned LUT instead; descriptor differences between the
    two are bounded by the 30-degree bin quantization (pinned in tests).
    """
    patches = extract_patches(smoothed, xy)

    def one(patch, th):
        a, b = steered_offsets(th)
        pa = patch[a[:, 1] + RADIUS, a[:, 0] + RADIUS]
        pb = patch[b[:, 1] + RADIUS, b[:, 0] + RADIUS]
        return pack_bits(pa < pb)

    return jax.vmap(one)(patches, theta)


def gather_patches(img: jnp.ndarray, xy: jnp.ndarray, ph: int, pw: int):
    """Gather (ph, pw) patches centered at rounded xy from one image.

    The FM stage's patch-read semantics, in ONE place: centers are
    rounded (round-half-even) and clamped into the image, and window
    pixels overhanging the border replicate the edge (``jnp.pad
    mode="edge")``.  This host-graph gather is the oracle the fused
    matcher kernels' in-kernel slab reads are pinned against
    (``matcher_fused.py`` clamps identically), and the jnp fallback of
    ``ops.sad_patch_search``; ``matching._gather_patches`` is a thin
    alias.  img: (H, W); xy: (K, 2) float32."""
    ry, rx = ph // 2, pw // 2
    padded = jnp.pad(img.astype(jnp.float32), ((ry, ry), (rx, rx)),
                     mode="edge")
    xs = jnp.clip(jnp.round(xy[:, 0]).astype(jnp.int32), 0,
                  img.shape[1] - 1)
    ys = jnp.clip(jnp.round(xy[:, 1]).astype(jnp.int32), 0,
                  img.shape[0] - 1)

    def one(x, y):
        return jax.lax.dynamic_slice(padded, (y, x), (ph, pw))

    return jax.vmap(one)(xs, ys)


# ---------------------------------------------------------------------------
# Brute-force NUMPY oracles for the matcher ops — python loops, no jnp,
# no vectorization tricks.  These are deliberately the dumbest possible
# implementations: the jnp oracles above and the Pallas kernels are both
# pinned against them in tests, so a vectorization bug cannot hide in a
# shared formulation.

MATCH_BIG = 1 << 20       # no-candidate sentinel; == hamming_match.BIG


def gather_patches_bruteforce(img, xy, ph: int, pw: int):
    """Python-loop reference of ``gather_patches``: per-PIXEL coordinate
    clamping instead of pad-then-slice, so a border off-by-one in the
    pad/slice formulation cannot hide.  For a center clamped to (xc, yc)
    the window pixel (dy, dx) is img[clip(yc + dy - ph//2, 0, H - 1),
    clip(xc + dx - pw//2, 0, W - 1)] — edge replication IS per-axis
    clamping.  img: (H, W); xy: (K, 2) float; returns (K, ph, pw) f32."""
    img = np.asarray(img, dtype=np.float32)
    xy = np.asarray(xy, dtype=np.float32)
    h, w = img.shape
    ry, rx = ph // 2, pw // 2
    out = np.zeros((xy.shape[0], ph, pw), np.float32)
    for i, (x, y) in enumerate(xy):
        xc = int(np.clip(np.round(x), 0, w - 1))
        yc = int(np.clip(np.round(y), 0, h - 1))
        for dy in range(ph):
            for dx in range(pw):
                out[i, dy, dx] = img[min(max(yc + dy - ry, 0), h - 1),
                                     min(max(xc + dx - rx, 0), w - 1)]
    return out


def hamming_match_bruteforce(desc_l, meta_l, desc_r, meta_r,
                             row_band: float, max_disparity: float):
    """O(K*M) python-loop reference of the fused search-region + Hamming
    argmin (``ops.hamming_match``).

    desc_*: (K, 8) uint32; meta_*: (K, 4) float32 (x, y, level, valid).
    Returns numpy (dist (K,) int32 [MATCH_BIG when no candidate], idx
    (K,) int32 [-1]).  Ties resolve to the LOWEST right index, matching
    jnp argmin.
    """
    desc_l = np.asarray(desc_l, dtype=np.uint32)
    desc_r = np.asarray(desc_r, dtype=np.uint32)
    meta_l = np.asarray(meta_l, dtype=np.float32)
    meta_r = np.asarray(meta_r, dtype=np.float32)
    kl, kr = desc_l.shape[0], desc_r.shape[0]
    dist = np.full(kl, MATCH_BIG, np.int32)
    idx = np.full(kl, -1, np.int32)
    for i in range(kl):
        if meta_l[i, 3] <= 0.5:
            continue
        best, best_j = MATCH_BIG, -1
        for j in range(kr):
            if meta_r[j, 3] <= 0.5:
                continue
            dx = meta_l[i, 0] - meta_r[j, 0]
            dy = abs(meta_l[i, 1] - meta_r[j, 1])
            if not (dy <= row_band and 0.0 <= dx <= max_disparity
                    and meta_l[i, 2] == meta_r[j, 2]):
                continue
            d = sum(bin(int(a) ^ int(b)).count("1")
                    for a, b in zip(desc_l[i], desc_r[j]))
            if d < best:
                best, best_j = d, j
        dist[i], idx[i] = best, best_j
    return dist, idx


def sad_search_bruteforce(left_patches, right_strips):
    """Python-loop reference of the SAD sweep (``ops.sad_search``):
    (K, P, P) x (K, P, P+2R) -> (K, 2R+1) int32."""
    lp = np.asarray(left_patches).astype(np.int64)
    rs = np.asarray(right_strips).astype(np.int64)
    k, p, _ = lp.shape
    sweep = rs.shape[-1] - p + 1
    table = np.zeros((k, sweep), np.int64)
    for i in range(k):
        for s in range(sweep):
            table[i, s] = np.abs(lp[i] - rs[i, :, s:s + p]).sum()
    return table.astype(np.int32)


# ---------------------------------------------------------------------------
# Bounded-error comparators — the uint8-vs-f32 correctness contract.
# Where the integer math is exact (blur, FAST, moments, descriptors on
# quantized images) tests pin bit-equality; everywhere else (float
# inputs snapped to uint8, wire quantization) they pin a measured bound
# through these helpers.

def max_abs_err(a, b) -> float:
    """max |a - b| in f32 — the bound the wire/quantization pins use."""
    a = jnp.asarray(a).astype(jnp.float32)
    b = jnp.asarray(b).astype(jnp.float32)
    return float(jnp.max(jnp.abs(a - b))) if a.size else 0.0


def keypoint_set_diff(xy_a, valid_a, xy_b, valid_b) -> int:
    """Symmetric-difference size of two keypoint sets (valid (x, y)
    rows as python sets — top-K ordering and tie permutations between
    equal-score corners don't count as disagreement)."""
    def to_set(xy, valid):
        xy = np.asarray(xy).reshape(-1, np.asarray(xy).shape[-1])
        valid = np.asarray(valid).reshape(-1)
        return {tuple(map(float, r)) for r, v in zip(xy, valid) if v}
    return len(to_set(xy_a, valid_a) ^ to_set(xy_b, valid_b))


def descriptor_hamming_stats(desc, ref_desc, valid=None):
    """Per-descriptor Hamming distance between two (..., 8) uint32
    descriptor sets -> (mean, max) over valid rows; (0.0, 0) when
    nothing is valid.  The uint8-path pin: 0 bits where descriptors are
    exact-in-integers, a measured bound elsewhere."""
    d = np.asarray(jnp.sum(_popcount32(
        jnp.bitwise_xor(jnp.asarray(desc), jnp.asarray(ref_desc))), -1))
    if valid is not None:
        d = d[np.asarray(valid)]
    if d.size == 0:
        return 0.0, 0
    return float(d.mean()), int(d.max())


def sad_search(left_patches: jnp.ndarray,
               right_strips: jnp.ndarray) -> jnp.ndarray:
    """SAD rectification sweep (paper Sec. II-C2 / III-D).

    left_patches: (K, P, P) — window around each left feature.
    right_strips: (K, P, P + 2R) — horizontal strip around the matched
      right feature.
    Returns (K, 2R + 1) int32 SAD values; caller argmins to re-locate F'.
    """
    k, p, _ = left_patches.shape
    sweep = right_strips.shape[-1] - p + 1
    lp = left_patches.astype(jnp.int32)
    rs = right_strips.astype(jnp.int32)
    sads = [
        jnp.sum(jnp.abs(lp - jax.lax.dynamic_slice_in_dim(rs, s, p, axis=2)),
                axis=(1, 2))
        for s in range(sweep)
    ]
    return jnp.stack(sads, axis=1)
