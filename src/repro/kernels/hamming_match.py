"""Pallas TPU kernel: fused stereo feature matcher.

Implements the paper's Feature Matcher front half (Sec. III-D) as ONE
kernel: Search Region Decision (epipolar row band + disparity range +
same pyramid level + validity) fused with Distance Computing and Compare
(256-bit Hamming via SWAR popcount, running argmin) — exactly the fusion
the FPGA performs in hardware, which avoids materializing the K x M
distance matrix in HBM.

Grid: (K / BK, M / BM); the M axis is the inner sequential dimension and
accumulates a running (best_dist, best_idx) into the output block
(revisited across the inner grid steps — the Pallas accumulation
pattern).  Ties resolve to the lowest right-feature index, matching the
jnp oracle's first-occurrence argmin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 128          # left-feature tile
BM = 128          # right-feature tile
BIG = 1 << 20     # sentinel distance for masked-out pairs


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def masked_hamming(dl, ml, dr, mr, *, row_band: float,
                   max_disparity: float):
    """(BK, 8) x (BM, 8) uint32 descriptors + (x, y, level, valid) meta
    -> (BK, BM) int32 Hamming distances with the Search Region Decision
    (paper Sec. III-D) fused as a BIG-sentinel mask.  The shared front
    half of every matcher kernel body — this per-pair kernel and the
    pair-folded grids of ``matcher_fused.py``."""
    # Hamming distance, accumulated word-by-word to keep VMEM small.
    dist = jnp.zeros((dl.shape[0], dr.shape[0]), jnp.int32)
    for word in range(dl.shape[1]):
        x = jnp.bitwise_xor(dl[:, word][:, None], dr[:, word][None, :])
        dist = dist + _popcount32(x)

    dx = ml[:, 0][:, None] - mr[:, 0][None, :]            # x_L - x_R
    dy = jnp.abs(ml[:, 1][:, None] - mr[:, 1][None, :])
    same_level = ml[:, 2][:, None] == mr[:, 2][None, :]
    valid = (ml[:, 3][:, None] > 0.5) & (mr[:, 3][None, :] > 0.5)
    mask = (dy <= row_band) & (dx >= 0.0) & (dx <= max_disparity) \
        & same_level & valid
    return jnp.where(mask, dist, BIG)


def _kernel(dl_ref, ml_ref, dr_ref, mr_ref, dist_ref, idx_ref, *,
            row_band: float, max_disparity: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, BIG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    dist = masked_hamming(dl_ref[...], ml_ref[...], dr_ref[...],
                          mr_ref[...], row_band=row_band,
                          max_disparity=max_disparity)

    # Compare: running argmin against the accumulated best.
    tile_best = jnp.min(dist, axis=1)                      # (BK,)
    tile_arg = jnp.argmin(dist, axis=1).astype(jnp.int32) + j * BM
    improved = tile_best < dist_ref[...]
    idx_ref[...] = jnp.where(improved, tile_arg, idx_ref[...])
    dist_ref[...] = jnp.where(improved, tile_best, dist_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("row_band", "max_disparity", "interpret"))
def hamming_match_pallas(desc_l: jnp.ndarray, meta_l: jnp.ndarray,
                         desc_r: jnp.ndarray, meta_r: jnp.ndarray, *,
                         row_band: float, max_disparity: float,
                         interpret: bool = False):
    """desc_*: (K, 8)/(M, 8) uint32 (K, M multiples of 128 — ops.py pads).
    meta_*: (K, 4)/(M, 4) float32 rows of (x, y, level, valid).
    Returns (best_dist (K,) int32, best_idx (K,) int32); masked-out rows
    keep dist=BIG, idx=-1."""
    k, m = desc_l.shape[0], desc_r.shape[0]
    grid = (k // BK, m // BM)
    kern = functools.partial(_kernel, row_band=float(row_band),
                             max_disparity=float(max_disparity))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BK, 8), lambda i, j: (i, 0)),
            pl.BlockSpec((BK, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((BM, 8), lambda i, j: (j, 0)),
            pl.BlockSpec((BM, 4), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BK,), lambda i, j: (i,)),
            pl.BlockSpec((BK,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(desc_l, meta_l, desc_r, meta_r)
