"""Pallas TPU kernel: FAST-9/16 corner score map.

TPU adaptation of the paper's FAST Detection module (Sec. III-C).  The
FPGA streams the image through line buffers and register banks; here the
image is tiled into halo'd VMEM blocks (``pl.Unblocked`` indexing gives
the overlapping 3-pixel halo the Bresenham-16 circle needs) and the 16
taps become static VREG shifts of the tile — the register-bank analog.

Block shape: (TILE_H + 6, TILE_W + 6) float32 in VMEM; default 128x128
output tiles (~70 KB in + 64 KB out), MXU-free, pure VPU stencil.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import ARC_LEN, CIRCLE16

TILE_H = 128
TILE_W = 128
HALO = 3


def _kernel(x_ref, o_ref, *, threshold: float, tile_h: int, tile_w: int):
    x = x_ref[...]                                   # (tile_h+6, tile_w+6)
    center = x[HALO:HALO + tile_h, HALO:HALO + tile_w]
    # 16 circle taps as static shifted views of the halo'd tile.
    taps = [
        x[HALO + dy:HALO + dy + tile_h, HALO + dx:HALO + dx + tile_w] - center
        for dx, dy in CIRCLE16
    ]
    # Arc mins/maxes over 9 contiguous taps (16 wrap-around windows),
    # unrolled with running min/max to bound live registers.
    score_bright = None
    score_dark = None
    for s in range(16):
        arc_min = taps[s % 16]
        arc_max = taps[s % 16]
        for j in range(1, ARC_LEN):
            t = taps[(s + j) % 16]
            arc_min = jnp.minimum(arc_min, t)
            arc_max = jnp.maximum(arc_max, t)
        score_bright = arc_min if score_bright is None else jnp.maximum(
            score_bright, arc_min)
        score_dark = arc_max if score_dark is None else jnp.minimum(
            score_dark, arc_max)
    score = jnp.maximum(score_bright, -score_dark)
    o_ref[...] = jnp.where(score > threshold, score, 0.0)


@functools.partial(jax.jit, static_argnames=("threshold", "interpret"))
def fast_score_map_pallas(padded: jnp.ndarray, *, threshold: float,
                          interpret: bool = False) -> jnp.ndarray:
    """padded: (H + 6, W + 6) float32, edge-padded by 3 and tile-aligned
    (H % TILE_H == 0, W % TILE_W == 0 — ``ops.py`` guarantees this).
    Returns (H, W) float32 score map."""
    h = padded.shape[0] - 2 * HALO
    w = padded.shape[1] - 2 * HALO
    grid = (h // TILE_H, w // TILE_W)
    kern = functools.partial(_kernel, threshold=float(threshold),
                             tile_h=TILE_H, tile_w=TILE_W)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(
            (TILE_H + 2 * HALO, TILE_W + 2 * HALO),
            lambda i, j: (i * TILE_H, j * TILE_W),
            indexing_mode=pl.Unblocked())],
        out_specs=pl.BlockSpec((TILE_H, TILE_W), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=interpret,
    )(padded.astype(jnp.float32))
