"""Pallas TPU kernels: single-launch Feature Matcher megakernel.

The paper's Feature Matcher is ONE hardware block (Sec. III-D): Search
Region Decision, Hamming Compare and SAD Correction / Disparity
Computing stream through a shared datapath.  Before this kernel our FM
stage was three pieces per stereo pair — the ``hamming_match`` kernel, a
host-graph gather chain (full-image pad + 2*K vmapped ``dynamic_slice``
per pair, twice) and the ``sad_search`` kernel.  Here the WHOLE stage is
one ``pallas_call`` batched over stereo pairs:

  * Grid = (pair, K-block, M-block); the M axis is the inner sequential
    dimension and accumulates the masked Hamming running-argmin into
    revisited output blocks exactly as ``hamming_match._kernel`` does
    (ties resolve to the LOWEST right index — first-occurrence argmin).
    Alongside (dist, idx) the sweep accumulates the winning right
    feature's float (x, y), extracted per tile by an exact one-hot
    masked sum — so no cross-block gather is ever needed.
  * Once the sweep completes (last M step), the SAME kernel step
    resolves the effective right feature (index 0 when the match fails
    the ``max_hamming``/validity gates, mirroring
    ``MatchSet.right_index``'s ``where(valid, idx, 0)``), reads the
    P x P left patch and the (P, P + 2R) right strip directly from the
    level-0 image slabs resident in VMEM (dynamic in-kernel slicing a la
    ``describe_fused`` — gather-free), runs the SAD sweep in int32 and
    emits the argmin.  Per traced frame the FM stage is ONE launch.

``match_fused_pallas`` is the match-only variant (no images, no SAD) —
the same pair-folded grid serving ``stereo_match`` / ``temporal_match``
in one launch; ``sad_fused_pallas`` is the SAD-only variant serving
``sad_rectify`` with caller-provided match indices, replacing its
host-graph patch-gather chain with the same in-kernel reads.

Boundary semantics are pinned to the gather oracle
(``ref.gather_patches`` / ``ref.gather_patches_bruteforce``): patch
centers are rounded (round-half-even) and clamped into the true image,
and the slabs are edge-padded by the patch radii, so window pixels
replicate the border exactly like the oracle's ``jnp.pad(mode="edge")``.
All SAD arithmetic is int32 (associative), so any summation order is
bit-exact against the oracle.

TPU-validation note (see ROADMAP): in-kernel scalar extraction of the
clamped starts, the VMEM-resident level-0 slabs (~3.8 MB each at
1280x720 f32 — left + right ~7.6 MB per grid step) and the per-row
``jnp.argmin`` over the (2R+1,) SAD table are exercised in interpret
mode; a Mosaic build may want the meta block in SMEM / scalar prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.describe_fused import _cast_slab
from repro.kernels.hamming_match import BIG, masked_hamming

FM_BK = 8         # left-feature tile of the fused/SAD kernels (unrolled)
FM_BM = 128       # right-feature tile (inner sequential sweep)
MO_BK = 128       # left-feature tile of the match-only kernel


def _clamped_start(coord, limit: int):
    """Float center coordinate -> int32 patch start in the edge-padded
    slab: round-half-even then clamp into the true image, exactly
    ``ref.gather_patches``'s center clamp."""
    return jnp.clip(jnp.round(coord).astype(jnp.int32), 0, limit - 1)


def _sad_row(il_ref, ir_ref, xl, yl, xr, yr, *, patch: int, sweep: int):
    """One feature's SAD table row: read the (patch, patch) left window
    and the (patch, patch + sweep - 1) right strip from the VMEM slabs
    at the given clamped starts and sweep the window.  int32 throughout
    — bit-exact against ``ref.sad_search`` for any summation order."""
    lp = il_ref[0, pl.ds(yl, patch), pl.ds(xl, patch)].astype(jnp.int32)
    rs = ir_ref[0, pl.ds(yr, patch),
                pl.ds(xr, patch + sweep - 1)].astype(jnp.int32)
    return jnp.stack([jnp.sum(jnp.abs(lp - rs[:, s:s + patch]))
                      for s in range(sweep)])              # (sweep,) int32


def _match_rectify_kernel(dl_ref, ml_ref, dr_ref, mr_ref, xy0_ref,
                          il_ref, ir_ref,
                          dist_ref, idx_ref, rxy_ref, sad_ref, *,
                          row_band: float, max_disparity: float,
                          max_hamming: int, patch: int, sweep: int,
                          n_m: int, true_h: int, true_w: int, bk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, BIG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)
        rxy_ref[...] = jnp.zeros_like(rxy_ref)
        sad_ref[...] = jnp.zeros_like(sad_ref)

    dl = dl_ref[0]                         # (bk, 8) uint32
    dr = dr_ref[0]                         # (BM, 8) uint32
    ml = ml_ref[0]                         # (bk, 4) f32: x, y, level, valid
    mr = mr_ref[0]                         # (BM, 4) f32
    dist = masked_hamming(dl, ml, dr, mr, row_band=row_band,
                          max_disparity=max_disparity)

    # Compare: running argmin, plus the winner's float (x, y) extracted
    # by an exact one-hot masked sum (one nonzero term -> a bit-exact
    # f32 copy of the winning meta row, no cross-block gather).
    tile_best = jnp.min(dist, axis=1)                      # (bk,)
    am = jnp.argmin(dist, axis=1).astype(jnp.int32)        # (bk,) in-tile
    onehot = (jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
              == am[:, None])
    xw = jnp.sum(jnp.where(onehot, mr[:, 0][None, :], 0.0), axis=1)
    yw = jnp.sum(jnp.where(onehot, mr[:, 1][None, :], 0.0), axis=1)
    improved = tile_best < dist_ref[0]
    idx_ref[0] = jnp.where(improved, am + j * dr.shape[0], idx_ref[0])
    rxy_ref[0, :, 0] = jnp.where(improved, xw, rxy_ref[0, :, 0])
    rxy_ref[0, :, 1] = jnp.where(improved, yw, rxy_ref[0, :, 1])
    dist_ref[0] = jnp.where(improved, tile_best, dist_ref[0])

    @pl.when(j == n_m - 1)
    def _sad():
        # Resolve the effective right feature: the accumulated winner
        # when the match passes the acceptance gates, else right
        # feature 0 — mirroring MatchSet.right_index's where(valid,
        # idx, 0) so downstream reads are bit-identical to the oracle.
        d = dist_ref[0]
        ix = idx_ref[0]
        ok = (ix >= 0) & (d <= max_hamming) & (ml[:, 3] > 0.5)
        rxy_ref[0, :, 0] = jnp.where(ok, rxy_ref[0, :, 0], xy0_ref[0, 0])
        rxy_ref[0, :, 1] = jnp.where(ok, rxy_ref[0, :, 1], xy0_ref[0, 1])
        for kk in range(bk):
            xl = _clamped_start(ml_ref[0, kk, 0], true_w)
            yl = _clamped_start(ml_ref[0, kk, 1], true_h)
            xr = _clamped_start(rxy_ref[0, kk, 0], true_w)
            yr = _clamped_start(rxy_ref[0, kk, 1], true_h)
            table = _sad_row(il_ref, ir_ref, xl, yl, xr, yr,
                             patch=patch, sweep=sweep)
            sad_ref[0, kk] = jnp.argmin(table).astype(jnp.int32)


def _match_only_kernel(dl_ref, ml_ref, dr_ref, mr_ref,
                       dist_ref, idx_ref, *,
                       row_band: float, max_disparity: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, BIG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    dist = masked_hamming(dl_ref[0], ml_ref[0], dr_ref[0], mr_ref[0],
                          row_band=row_band,
                          max_disparity=max_disparity)
    tile_best = jnp.min(dist, axis=1)
    tile_arg = (jnp.argmin(dist, axis=1).astype(jnp.int32)
                + j * dr_ref.shape[1])
    improved = tile_best < dist_ref[0]
    idx_ref[0] = jnp.where(improved, tile_arg, idx_ref[0])
    dist_ref[0] = jnp.where(improved, tile_best, dist_ref[0])


def _sad_only_kernel(xyl_ref, xyr_ref, il_ref, ir_ref, tab_ref, *,
                     patch: int, sweep: int, true_h: int, true_w: int,
                     bk: int):
    for kk in range(bk):
        xl = _clamped_start(xyl_ref[0, kk, 0], true_w)
        yl = _clamped_start(xyl_ref[0, kk, 1], true_h)
        xr = _clamped_start(xyr_ref[0, kk, 0], true_w)
        yr = _clamped_start(xyr_ref[0, kk, 1], true_h)
        tab_ref[0, kk] = _sad_row(il_ref, ir_ref, xl, yl, xr, yr,
                                  patch=patch, sweep=sweep)


@functools.partial(jax.jit, static_argnames=(
    "row_band", "max_disparity", "max_hamming", "patch", "sad_range",
    "true_h", "true_w", "interpret"))
def match_rectify_fused_pallas(desc_l, meta_l, desc_r, meta_r, xy0,
                               img_l_padded, img_r_padded, *,
                               row_band: float, max_disparity: float,
                               max_hamming: int, patch: int,
                               sad_range: int, true_h: int, true_w: int,
                               interpret: bool = False):
    """The FM megakernel: ONE launch for Hamming match + SAD sweep of a
    whole frame, batched over stereo pairs.

    desc_*: (P, K, 8)/(P, M, 8) uint32 (K % FM_BK == M % FM_BM == 0 —
    ``ops.py`` pads); meta_*: (P, K, 4)/(P, M, 4) float32 rows of
    (x, y, level, valid); xy0: (P, 2) float32 — right feature 0's (x, y)
    per pair, the oracle's fallback read when a match fails the gates;
    img_*_padded: (P, Hp, Wp) float32 level-0 slabs edge-padded by the
    patch radii (left: P//2 each side; right: P//2 + sad_range in x) and
    tile-aligned (alignment region never read).  Returns (dist (P, K)
    int32 [BIG when no candidate], idx (P, K) int32 [-1], rxy (P, K, 2)
    float32 — the effective right feature's float coords, sad (P, K)
    int32 — SAD-sweep argmin in [0, 2*sad_range]).
    """
    n_pairs, k = desc_l.shape[0], desc_l.shape[1]
    m = desc_r.shape[1]
    _, hlp, wlp = img_l_padded.shape
    _, hrp, wrp = img_r_padded.shape
    sweep = 2 * sad_range + 1
    grid = (n_pairs, k // FM_BK, m // FM_BM)
    kern = functools.partial(
        _match_rectify_kernel, row_band=float(row_band),
        max_disparity=float(max_disparity), max_hamming=int(max_hamming),
        patch=int(patch), sweep=int(sweep), n_m=m // FM_BM,
        true_h=int(true_h), true_w=int(true_w), bk=FM_BK)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, FM_BK, 8), lambda p, i, j: (p, i, 0)),
            pl.BlockSpec((1, FM_BK, 4), lambda p, i, j: (p, i, 0)),
            pl.BlockSpec((1, FM_BM, 8), lambda p, i, j: (p, j, 0)),
            pl.BlockSpec((1, FM_BM, 4), lambda p, i, j: (p, j, 0)),
            pl.BlockSpec((1, 2), lambda p, i, j: (p, 0)),
            pl.BlockSpec((1, hlp, wlp), lambda p, i, j: (p, 0, 0)),
            pl.BlockSpec((1, hrp, wrp), lambda p, i, j: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, FM_BK), lambda p, i, j: (p, i)),
            pl.BlockSpec((1, FM_BK), lambda p, i, j: (p, i)),
            pl.BlockSpec((1, FM_BK, 2), lambda p, i, j: (p, i, 0)),
            pl.BlockSpec((1, FM_BK), lambda p, i, j: (p, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, k), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs, k), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs, k, 2), jnp.float32),
            jax.ShapeDtypeStruct((n_pairs, k), jnp.int32),
        ],
        interpret=interpret,
    )(desc_l, meta_l, desc_r, meta_r, xy0.astype(jnp.float32),
      _cast_slab(img_l_padded), _cast_slab(img_r_padded))


@functools.partial(jax.jit, static_argnames=(
    "row_band", "max_disparity", "interpret"))
def match_fused_pallas(desc_l, meta_l, desc_r, meta_r, *,
                       row_band: float, max_disparity: float,
                       interpret: bool = False):
    """Match-only variant: the same pair-folded (pair, K-block, M-block)
    grid without images or SAD — ``stereo_match`` / ``temporal_match``
    in ONE launch for all pairs.  desc_*: (P, K, 8)/(P, M, 8) uint32
    (K % MO_BK == M % FM_BM == 0); returns (dist (P, K) int32, idx
    (P, K) int32 [-1 when no candidate])."""
    n_pairs, k = desc_l.shape[0], desc_l.shape[1]
    m = desc_r.shape[1]
    grid = (n_pairs, k // MO_BK, m // FM_BM)
    kern = functools.partial(_match_only_kernel, row_band=float(row_band),
                             max_disparity=float(max_disparity))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, MO_BK, 8), lambda p, i, j: (p, i, 0)),
            pl.BlockSpec((1, MO_BK, 4), lambda p, i, j: (p, i, 0)),
            pl.BlockSpec((1, FM_BM, 8), lambda p, i, j: (p, j, 0)),
            pl.BlockSpec((1, FM_BM, 4), lambda p, i, j: (p, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, MO_BK), lambda p, i, j: (p, i)),
            pl.BlockSpec((1, MO_BK), lambda p, i, j: (p, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, k), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs, k), jnp.int32),
        ],
        interpret=interpret,
    )(desc_l, meta_l, desc_r, meta_r)


@functools.partial(jax.jit, static_argnames=(
    "patch", "sad_range", "true_h", "true_w", "interpret"))
def sad_fused_pallas(xy_l, xy_r, img_l_padded, img_r_padded, *,
                     patch: int, sad_range: int, true_h: int,
                     true_w: int, interpret: bool = False):
    """SAD-only variant for caller-provided match targets
    (``sad_rectify``'s path): in-kernel patch reads replace the
    host-graph pad + 2*K ``dynamic_slice`` gather chain.  xy_*:
    (P, K, 2) float32 centers (K % FM_BK == 0); returns the full
    (P, K, 2*sad_range + 1) int32 SAD table (argmin taken by the
    caller, exactly like ``ops.sad_search``)."""
    n_pairs, k = xy_l.shape[0], xy_l.shape[1]
    _, hlp, wlp = img_l_padded.shape
    _, hrp, wrp = img_r_padded.shape
    sweep = 2 * sad_range + 1
    grid = (n_pairs, k // FM_BK)
    kern = functools.partial(_sad_only_kernel, patch=int(patch),
                             sweep=int(sweep), true_h=int(true_h),
                             true_w=int(true_w), bk=FM_BK)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, FM_BK, 2), lambda p, i: (p, i, 0)),
            pl.BlockSpec((1, FM_BK, 2), lambda p, i: (p, i, 0)),
            pl.BlockSpec((1, hlp, wlp), lambda p, i: (p, 0, 0)),
            pl.BlockSpec((1, hrp, wrp), lambda p, i: (p, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, FM_BK, sweep), lambda p, i: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pairs, k, sweep), jnp.int32),
        interpret=interpret,
    )(xy_l.astype(jnp.float32), xy_r.astype(jnp.float32),
      _cast_slab(img_l_padded), _cast_slab(img_r_padded))
