"""Pallas TPU kernel: separable 7x7 Gaussian smoothing.

The paper's Image Smoothing module (Sec. III-C) streams 7x7 patches
through two-stage shifting line buffers fused with the descriptor
pipeline.  The TPU analog: one halo'd VMEM tile per grid cell, the two
1-D passes fused in a single kernel so the horizontal intermediate never
leaves VMEM (the line-buffer role).

Integer-weight taps ([1,4,8,10,8,4,1], norm 36) implement the paper's
8-bit word-length optimization; the quantized path rounds once at the
end and is bit-exact against the ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import GAUSS7_NORM, GAUSS7_WEIGHTS_INT

TILE_H = 128
TILE_W = 128
HALO = 3


def _kernel(x_ref, o_ref, *, quantized: bool, tile_h: int, tile_w: int):
    x = x_ref[...]                                # (tile_h+6, tile_w+6) f32
    w = [float(v) for v in GAUSS7_WEIGHTS_INT]
    # Horizontal pass on the full halo'd tile (keeps vertical halo rows).
    horiz = None
    for k in range(7):
        term = w[k] * x[:, k:k + tile_w]
        horiz = term if horiz is None else horiz + term    # (tile_h+6, tile_w)
    # Vertical pass.
    vert = None
    for k in range(7):
        term = w[k] * horiz[k:k + tile_h, :]
        vert = term if vert is None else vert + term       # (tile_h, tile_w)
    if quantized:
        norm2 = float(GAUSS7_NORM * GAUSS7_NORM)
        o_ref[...] = jnp.floor((vert + norm2 / 2.0) / norm2)
    else:
        o_ref[...] = vert / float(GAUSS7_NORM * GAUSS7_NORM)


@functools.partial(jax.jit, static_argnames=("quantized", "interpret"))
def gaussian_blur7_pallas(padded: jnp.ndarray, *, quantized: bool = True,
                          interpret: bool = False) -> jnp.ndarray:
    """padded: (H + 6, W + 6) float32, edge-padded by 3, tile-aligned.
    Returns (H, W) float32 smoothed image."""
    h = padded.shape[0] - 2 * HALO
    w = padded.shape[1] - 2 * HALO
    grid = (h // TILE_H, w // TILE_W)
    kern = functools.partial(_kernel, quantized=quantized,
                             tile_h=TILE_H, tile_w=TILE_W)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(
            (TILE_H + 2 * HALO, TILE_W + 2 * HALO),
            lambda i, j: (i * TILE_H, j * TILE_W),
            indexing_mode=pl.Unblocked())],
        out_specs=pl.BlockSpec((TILE_H, TILE_W), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=interpret,
    )(padded.astype(jnp.float32))
