"""Pallas TPU kernels for the paper's compute hot-spots (FE + FM).

frontend_fused — batched blur + FAST + NMS megakernel (one VMEM pass
                 per tile for all cameras x levels — the DENSE stage,
                 paper's frame-multiplexed FE analog)
describe_fused — batched orientation + moments + LUT-steered rBRIEF per
                 keypoint block (the SPARSE stage; gather-free taps via
                 selection matmul, 30-degree-binned steering ROM)
pattern        — BRIEF sampling pattern + STEER_LUT ROM (numpy-only)
fast_detect    — FAST-9/16 corner score map (standalone, halo'd tiles)
gaussian_blur  — fused separable 7x7 Gaussian (line-buffer analog)
hamming_match  — fused search-region + Hamming argmin (FM front half)
sad_rectify    — 11x11 SAD sweep (FM rectifier)

ops.py dispatches kernels vs. the pure-jnp oracles in ref.py and owns
all padding; the batch-first entry points are ``ops.fast_blur_nms_batched``
(dense) and ``ops.orient_describe_batched`` (sparse) — together exactly
two launches per pyramid level for the whole camera batch.
"""

from repro.kernels import ops, ref  # noqa: F401
