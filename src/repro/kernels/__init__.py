"""Pallas TPU kernels for the paper's compute hot-spots (FE + FM).

fast_detect    — FAST-9/16 corner score map (stencil, halo'd VMEM tiles)
gaussian_blur  — fused separable 7x7 Gaussian (line-buffer analog)
hamming_match  — fused search-region + Hamming argmin (FM front half)
sad_rectify    — 11x11 SAD sweep (FM rectifier)

ops.py dispatches kernels vs. the pure-jnp oracles in ref.py.
"""

from repro.kernels import ops, ref  # noqa: F401
