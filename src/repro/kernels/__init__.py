"""Pallas TPU kernels for the paper's compute hot-spots (FE + FM).

frontend_fused — batched blur + FAST + NMS megakernel (one VMEM pass
                 per tile for all cameras x levels — the frontend hot
                 path, paper's frame-multiplexed FE analog)
fast_detect    — FAST-9/16 corner score map (standalone, halo'd tiles)
gaussian_blur  — fused separable 7x7 Gaussian (line-buffer analog)
hamming_match  — fused search-region + Hamming argmin (FM front half)
sad_rectify    — 11x11 SAD sweep (FM rectifier)

ops.py dispatches kernels vs. the pure-jnp oracles in ref.py and owns
all padding; the batch-first entry point is ``ops.fast_blur_nms_batched``.
"""

from repro.kernels import ops, ref  # noqa: F401
