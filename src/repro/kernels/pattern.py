"""BRIEF sampling pattern and angle-binned steering LUT (paper Sec.
II-B2, III-C).

The paper selects ``n`` point pairs from the circular patch "based on
Gaussian distribution" (ORB's original construction).  We generate a
deterministic pattern once at import time with a fixed seed so that the
descriptor is reproducible across the pure-jnp oracle, the Pallas kernel
and checkpoints.

The pattern radius is capped at ``PATTERN_RADIUS`` so that after an
arbitrary rotation (norm-preserving) and rounding, every sampled point
stays strictly inside the 31x31 patch (radius 15) used by the hardware.

Steering is angle-BINNED, as in the paper's FPGA (Sec. III-C): instead
of rotating all 256 pairs by each keypoint's exact theta (per-keypoint
cos/sin + round), theta is quantized to ``N_ANGLE_BINS`` bins of 30
degrees and the rotated pattern for every bin is precomputed once at
import time into ``STEER_LUT`` — the descriptor RAM's address ROM.
``STEER_LUT[b, i]`` holds the two *flattened* 31x31-patch indices
(row-major, ``(y + 15) * 31 + (x + 15)``) of pair ``i`` rotated by the
bin-``b`` center angle ``b * 2*pi / N_ANGLE_BINS``.  The LUT is the
single definition of steering shared by the Pallas kernel, the jnp
fallback and the ref oracle.

This module is numpy-only (no jax) so the kernel layer can import it
without touching ``repro.core``; ``repro.core.pattern`` re-exports it
for back-compat.
"""

from __future__ import annotations

import numpy as np

N_PAIRS = 256          # descriptor length in bits (32 x 8 bits, Sec. III-C)
PATCH_RADIUS = 15      # 31 x 31 patch, matching the FPGA register bank
PATTERN_RADIUS = 13    # max |offset| so rotate+round stays within radius 15
PATTERN_SIGMA = PATCH_RADIUS / 2.0
_SEED = 20210606       # AICAS'21 conference date; fixed for reproducibility

N_ANGLE_BINS = 12                          # 30-degree steering bins
ANGLE_BIN_STEP = 2.0 * np.pi / N_ANGLE_BINS


def _generate(seed: int = _SEED) -> np.ndarray:
    """Return int32 array (N_PAIRS, 4) of (ax, ay, bx, by) offsets."""
    rng = np.random.RandomState(seed)
    pts = []
    while len(pts) < N_PAIRS:
        cand = rng.normal(0.0, PATTERN_SIGMA, size=(4 * N_PAIRS, 4))
        cand = np.round(cand).astype(np.int32)
        ok = (
            (np.abs(cand[:, 0::2]).max(axis=1) ** 2
             + np.abs(cand[:, 1::2]).max(axis=1) ** 2)
            <= PATTERN_RADIUS ** 2
        )
        # Also require A != B so every binary test is informative.
        ok &= np.any(cand[:, :2] != cand[:, 2:], axis=1)
        pts.extend(cand[ok].tolist())
    return np.asarray(pts[:N_PAIRS], dtype=np.int32)


# (N_PAIRS, 4): columns are (ax, ay, bx, by), y down / x right image coords.
PATTERN: np.ndarray = _generate()

# Split views used by descriptor code: (N_PAIRS, 2) each.
PATTERN_A: np.ndarray = PATTERN[:, 0:2]
PATTERN_B: np.ndarray = PATTERN[:, 2:4]


def rotated_pattern(theta: float) -> np.ndarray:
    """Reference (numpy) EXACT steered pattern for a single angle.

    This is the pre-LUT steering (per-angle cos/sin + round-half-even);
    the binned ``STEER_LUT`` rows equal ``rotated_pattern(b *
    ANGLE_BIN_STEP)``.  Kept as the test reference that the angle-bin
    quantization is measured against.
    """
    c, s = np.cos(theta), np.sin(theta)
    rot = np.array([[c, -s], [s, c]])
    a = np.round(PATTERN_A @ rot.T).astype(np.int32)
    b = np.round(PATTERN_B @ rot.T).astype(np.int32)
    return np.concatenate([a, b], axis=1)


def _flatten_offsets(pts: np.ndarray) -> np.ndarray:
    """(N, 2) int32 (x, y) offsets -> (N,) row-major 31x31 patch indices."""
    assert np.abs(pts).max() <= PATCH_RADIUS
    return ((pts[:, 1] + PATCH_RADIUS) * (2 * PATCH_RADIUS + 1)
            + (pts[:, 0] + PATCH_RADIUS)).astype(np.int32)


def _steer_lut() -> np.ndarray:
    """(N_ANGLE_BINS, N_PAIRS, 2) int32 flattened-patch-index LUT."""
    rows = []
    for b in range(N_ANGLE_BINS):
        rot = rotated_pattern(b * ANGLE_BIN_STEP)
        rows.append(np.stack([_flatten_offsets(rot[:, 0:2]),
                              _flatten_offsets(rot[:, 2:4])], axis=-1))
    return np.stack(rows).astype(np.int32)


# The descriptor steering ROM: STEER_LUT[bin, pair] = (a_lin, b_lin).
STEER_LUT: np.ndarray = _steer_lut()
