"""Pallas TPU kernel: SAD rectification sweep.

The paper's Correction and Disparity Computing module (Sec. III-D): for
each matched pair, an 11x11 window around the left feature is compared
(sum of absolute differences) against the right window slid over
+-sad_range pixels; the argmin re-locates the right feature.

Layout note (TPU): patch tensors are (BK, P, P) / (BK, P, P + 2R) with
tiny trailing dims — lanes are padded to 128 on real hardware, which is
acceptable because this module is minuscule (the FPGA version used 0
DSPs / 0 BRAMs, Tab. II); correctness and fusion matter, not MXU
utilization.  BK = 128 features per grid step keeps the sublane axis
full.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 128


def _kernel(lp_ref, rs_ref, o_ref, *, patch: int, sweep: int):
    lp = lp_ref[...].astype(jnp.int32)       # (BK, P, P)
    rs = rs_ref[...].astype(jnp.int32)       # (BK, P, P + 2R)
    for s in range(sweep):
        window = rs[:, :, s:s + patch]
        sad = jnp.sum(jnp.abs(lp - window), axis=(1, 2))   # (BK,)
        o_ref[:, s] = sad


@functools.partial(jax.jit, static_argnames=("interpret",))
def sad_search_pallas(left_patches: jnp.ndarray, right_strips: jnp.ndarray,
                      *, interpret: bool = False) -> jnp.ndarray:
    """left_patches: (K, P, P); right_strips: (K, P, P + 2R); K % 128 == 0.
    Returns (K, 2R + 1) int32 SAD table (argmin taken by the caller)."""
    k, p, _ = left_patches.shape
    sweep = right_strips.shape[-1] - p + 1
    kern = functools.partial(_kernel, patch=p, sweep=sweep)
    return pl.pallas_call(
        kern,
        grid=(k // BK,),
        in_specs=[
            pl.BlockSpec((BK, p, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((BK, p, right_strips.shape[-1]),
                         lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BK, sweep), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, sweep), jnp.int32),
        interpret=interpret,
    )(left_patches.astype(jnp.int32), right_strips.astype(jnp.int32))
