"""Pallas TPU kernel: batched sparse ORB descriptor stage (orientation +
rBRIEF) — the gather-free half of the two-stage frontend.

The dense stage (``frontend_fused.py``) emits per-pixel products (blur +
NMS'd FAST score); after top-K the frontend needs three per-KEYPOINT
products, which the seed computed as vmapped 31x31 ``dynamic_slice``
gathers over the host graph — the last serialized host-graph work per
frame.  This kernel computes all three in ONE launch per pyramid level
for the whole camera batch:

  * intensity-centroid orientation theta (paper Eq. 1),
  * the circular-patch moments (m10, m01), and
  * the packed 8 x uint32 rBRIEF descriptor (paper Eqs. 2-3).

Grid = (B, K / KP_BLOCK): each step loads KP_BLOCK 31x31 patches from
the raw and smoothed level images (both resident in VMEM; the block
index map pins them per camera so the pipeline fetches each image once,
not once per K-block) and keeps every per-keypoint product on-chip.
``describe_fused_pyramid_pallas`` extends the same body to the WHOLE
frame: keypoint blocks are level-sorted, each (camera, K-block) grid
step resolves its raw/smoothed slab pair through the static block->level
offsets baked into the index maps, and the clamp bounds come from a
per-block (true_h, true_w) shape table — one sparse launch per frame.
This mirrors the paper's FPGA datapath (Sec. III-C), where a shared
patch register bank feeds the rotation and descriptor pipelines and the
31x31 window is read from BRAM exactly once per feature.

Steering is LUT-binned as in the paper: theta is quantized to 12 bins
of 30 degrees and the rotated pattern comes from the precomputed
``pattern.STEER_LUT`` ROM — no per-keypoint cos/sin + round.  Taps are
resolved GATHER-FREE: the LUT row is expanded to a +-1 selection matrix
with a 2D iota compare and contracted against the flattened patch on
the MXU, so ``tau = p(A) < p(B)`` becomes the sign of a matmul.  The
sign of a correctly-rounded f32 difference equals the sign of the exact
difference, so this is BIT-exact against the gather oracle
(``ref.lut_descriptor``) — tests assert it.

Boundary semantics: keypoint coords are clamped into the true image
(top-K padding rows carry arbitrary coords) and the images are
edge-padded by RADIUS, exactly like ``ref.extract_patches``; the
tile-alignment zero pad that ``ops.py`` adds is never read.

TPU-validation note (see ROADMAP): in-kernel ``arctan2`` and the
VMEM-sourced dynamic patch starts are exercised in interpret mode; on a
real Mosaic build the keypoint block may need to move to SMEM /
scalar prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pattern
from repro.kernels.ref import (PATCH, pack_bits, patch_theta,
                               patch_theta_int, theta_to_bin)

KP_BLOCK = 8            # keypoints per grid step (unrolled in-kernel)

_N_PAIRS = pattern.N_PAIRS
_N_BINS = pattern.N_ANGLE_BINS
_FLAT = PATCH * PATCH


def _load_patches(img_ref, xy_ref, kb: int, true_h: int, true_w: int):
    """Load kb 31x31 patches from a (1, Hp, Wp) VMEM image slab at the
    (clamped) keypoint centers of the current K-block."""
    pats = []
    for kk in range(kb):
        x = jnp.clip(xy_ref[0, kk, 0], 0, true_w - 1)
        y = jnp.clip(xy_ref[0, kk, 1], 0, true_h - 1)
        pats.append(img_ref[0, pl.ds(y, PATCH), pl.ds(x, PATCH)])
    return pats


def _lut_rows(lut_ref, bin_k):
    """Resolve one bin's LUT row without a gather: one-hot over bins,
    contracted against the (12, 256) index planes."""
    binoh = (jax.lax.broadcasted_iota(jnp.int32, (_N_BINS, 1), 0)
             == bin_k).astype(jnp.int32)
    a_idx = jnp.sum(lut_ref[:, :, 0] * binoh, axis=0)       # (256,)
    b_idx = jnp.sum(lut_ref[:, :, 1] * binoh, axis=0)
    return a_idx, b_idx


def _tap_sign_bits(sm_flat_row, a_idx, b_idx):
    """(1, 961) patch row + LUT index rows -> (256,) bool tau bits via
    the +-1 selection matmul (MXU gather)."""
    pos = jax.lax.broadcasted_iota(jnp.int32, (_FLAT, _N_PAIRS), 0)
    if jnp.issubdtype(sm_flat_row.dtype, jnp.integer):
        # Integer datapath: int8 +-1 selection matrix (4x less VMEM
        # than the f32 one), int32 accumulate — p(B) - p(A) is computed
        # exactly, so tau equals the gather oracle's bit-for-bit.
        sel = ((pos == b_idx[None, :]).astype(jnp.int8)
               - (pos == a_idx[None, :]).astype(jnp.int8))
        diff = jnp.dot(sm_flat_row, sel,
                       preferred_element_type=jnp.int32)    # (1, 256)
        return diff[0] > 0
    sel = ((pos == b_idx[None, :]).astype(jnp.float32)
           - (pos == a_idx[None, :]).astype(jnp.float32))
    # HIGHEST precision: the default TPU dot precision multiplies via
    # bf16 passes, which could flip a tau bit when |p(B) - p(A)| is
    # below bf16 resolution — the sign-exactness argument needs true
    # f32 products.
    diff = jnp.dot(sm_flat_row, sel,
                   preferred_element_type=jnp.float32,
                   precision=jax.lax.Precision.HIGHEST)     # (1, 256)
    return diff[0] > 0.0


def _block_theta(raw):
    """Orientation of a stacked patch block, dtype-dispatched: uint8
    patches run the int32 moment accumulators (theta bit-equal — see
    ``ref.patch_theta_int``); moments come back f32 either way (int32
    moments < 2^24 cast losslessly), so output shapes never change."""
    if jnp.issubdtype(raw.dtype, jnp.integer):
        theta, mom = patch_theta_int(raw)
        return theta, mom.astype(jnp.float32)
    return patch_theta(raw)


def _describe_block(lut_ref, raw_ref, sm_ref, xy_ref,
                    theta_ref, mom_ref, desc_ref, kb, true_h, true_w):
    """Shared K-block body.  ``true_h``/``true_w`` may be static ints
    (per-level launch) or traced scalars read from the whole-pyramid
    shape table — the coordinate clamp broadcasts either way, so both
    launch schedules run bit-identical math per block."""
    raw = jnp.stack(_load_patches(raw_ref, xy_ref, kb, true_h, true_w))
    sm = _load_patches(sm_ref, xy_ref, kb, true_h, true_w)
    theta, mom = _block_theta(raw)                          # (kb,), (kb, 2)
    bins = theta_to_bin(theta)
    theta_ref[0] = theta
    mom_ref[0] = mom
    rows = []
    for kk in range(kb):
        a_idx, b_idx = _lut_rows(lut_ref, bins[kk])
        rows.append(_tap_sign_bits(sm[kk].reshape(1, _FLAT), a_idx, b_idx))
    desc_ref[0] = pack_bits(jnp.stack(rows))                # (kb, 8)


def _cast_slab(x):
    """Keep integer image slabs uint8 (the integer datapath); float
    slabs run f32 exactly as before."""
    return x.astype(jnp.uint8 if jnp.issubdtype(x.dtype, jnp.integer)
                    else jnp.float32)


def _describe_kernel(lut_ref, raw_ref, sm_ref, xy_ref,
                     theta_ref, mom_ref, desc_ref, *,
                     true_h: int, true_w: int, kb: int):
    _describe_block(lut_ref, raw_ref, sm_ref, xy_ref,
                    theta_ref, mom_ref, desc_ref, kb, true_h, true_w)


def _describe_kernel_pyramid(lut_ref, raw_ref, sm_ref, xy_ref, hw_ref,
                             theta_ref, mom_ref, desc_ref, *, kb: int):
    """Whole-frame variant: each K-block's slab pair was resolved by the
    level-aware index maps; its true (h, w) comes from the per-block
    shape table instead of static kwargs."""
    _describe_block(lut_ref, raw_ref, sm_ref, xy_ref,
                    theta_ref, mom_ref, desc_ref, kb,
                    hw_ref[0, 0], hw_ref[0, 1])


def _orient_kernel(raw_ref, xy_ref, theta_ref, mom_ref, *,
                   true_h: int, true_w: int, kb: int):
    raw = jnp.stack(_load_patches(raw_ref, xy_ref, kb, true_h, true_w))
    theta, mom = _block_theta(raw)
    theta_ref[0] = theta
    mom_ref[0] = mom


@functools.partial(jax.jit, static_argnames=(
    "true_h", "true_w", "kb", "interpret"))
def describe_fused_pallas(lut: jnp.ndarray, raw_padded: jnp.ndarray,
                          sm_padded: jnp.ndarray, xy: jnp.ndarray, *,
                          true_h: int, true_w: int, kb: int = KP_BLOCK,
                          interpret: bool = False):
    """raw_padded/sm_padded: (B, Hp, Wp) float32, edge-padded by RADIUS
    and tile-aligned (``ops.py`` guarantees Hp % 8 == Wp % 128 == 0);
    lut: (12, 256, 2) int32 ``pattern.STEER_LUT``; xy: (B, K, 2) int32
    with K % kb == 0.  Returns (theta (B, K) f32, moments (B, K, 2) f32,
    desc (B, K, 8) uint32)."""
    b, hp, wp = raw_padded.shape
    k = xy.shape[1]
    grid = (b, k // kb)
    kern = functools.partial(_describe_kernel, true_h=int(true_h),
                             true_w=int(true_w), kb=int(kb))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_N_BINS, _N_PAIRS, 2), lambda bb, kk: (0, 0, 0)),
            pl.BlockSpec((1, hp, wp), lambda bb, kk: (bb, 0, 0)),
            pl.BlockSpec((1, hp, wp), lambda bb, kk: (bb, 0, 0)),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb), lambda bb, kk: (bb, kk)),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
            pl.BlockSpec((1, kb, 8), lambda bb, kk: (bb, kk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k, 2), jnp.float32),
            jax.ShapeDtypeStruct((b, k, 8), jnp.uint32),
        ],
        interpret=interpret,
    )(lut, _cast_slab(raw_padded), _cast_slab(sm_padded),
      xy.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=(
    "true_h", "true_w", "kb", "interpret"))
def orient_fused_pallas(raw_padded: jnp.ndarray, xy: jnp.ndarray, *,
                        true_h: int, true_w: int, kb: int = KP_BLOCK,
                        interpret: bool = False):
    """Orientation-only variant (``fast.detect``'s score-only analog):
    same patch path, no smoothed image / descriptor work.  Returns
    (theta (B, K) f32, moments (B, K, 2) f32)."""
    b, hp, wp = raw_padded.shape
    k = xy.shape[1]
    grid = (b, k // kb)
    kern = functools.partial(_orient_kernel, true_h=int(true_h),
                             true_w=int(true_w), kb=int(kb))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp), lambda bb, kk: (bb, 0, 0)),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb), lambda bb, kk: (bb, kk)),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k, 2), jnp.float32),
        ],
        interpret=interpret,
    )(_cast_slab(raw_padded), xy.astype(jnp.int32))


def _block_level(kk, level_offsets):
    """Pyramid level of K-block ``kk``: keypoint blocks are level-sorted,
    so the level is the number of level start-offsets at or below kk.
    ``level_offsets`` is a STATIC tuple (offsets[l] = first block of
    level l) — the sum unrolls to L-1 compares on the traced block id,
    legal inside a BlockSpec index map."""
    lvl = 0
    for off in level_offsets[1:]:
        lvl = lvl + jnp.where(kk >= off, 1, 0)
    return lvl


@functools.partial(jax.jit, static_argnames=(
    "level_offsets", "kb", "interpret"))
def describe_fused_pyramid_pallas(lut: jnp.ndarray, raw_slabs: jnp.ndarray,
                                  sm_slabs: jnp.ndarray, xy: jnp.ndarray,
                                  hw: jnp.ndarray, *,
                                  level_offsets: tuple[int, ...],
                                  kb: int = KP_BLOCK,
                                  interpret: bool = False):
    """Whole-frame sparse launch: ALL cameras x ALL levels in ONE
    ``pallas_call`` whose grid walks (camera, level-sorted K-block).

    raw_slabs/sm_slabs: (L*B, Hc, Wc) float32 — level-major flattened
    level slab pairs, each edge-padded by RADIUS and out to the COMMON
    aligned (Hc, Wc) canvas (``ops.py`` owns that padding; clamped patch
    starts never reach the common-canvas region).  xy: (B, Ktot, 2)
    int32, keypoints level-sorted with each level's block padded to a kb
    multiple.  hw: (Ktot/kb, 2) int32 per-K-block true (h, w) used for
    the coordinate clamp.  level_offsets: static per-level first-block
    offsets — each grid step resolves its raw/smoothed slab pair through
    ``_block_level`` in the index maps, so the pipeline fetches each
    (camera, level) slab once (blocks of one level are contiguous).
    Returns (theta (B, Ktot) f32, moments (B, Ktot, 2) f32, desc
    (B, Ktot, 8) uint32)."""
    n, hc, wc = raw_slabs.shape
    b, k = xy.shape[0], xy.shape[1]
    grid = (b, k // kb)
    kern = functools.partial(_describe_kernel_pyramid, kb=int(kb))

    def slab_index(bb, kk):
        return (_block_level(kk, level_offsets) * b + bb, 0, 0)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_N_BINS, _N_PAIRS, 2), lambda bb, kk: (0, 0, 0)),
            pl.BlockSpec((1, hc, wc), slab_index),
            pl.BlockSpec((1, hc, wc), slab_index),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
            pl.BlockSpec((1, 2), lambda bb, kk: (kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb), lambda bb, kk: (bb, kk)),
            pl.BlockSpec((1, kb, 2), lambda bb, kk: (bb, kk, 0)),
            pl.BlockSpec((1, kb, 8), lambda bb, kk: (bb, kk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k, 2), jnp.float32),
            jax.ShapeDtypeStruct((b, k, 8), jnp.uint32),
        ],
        interpret=interpret,
    )(lut, _cast_slab(raw_slabs), _cast_slab(sm_slabs),
      xy.astype(jnp.int32), hw.astype(jnp.int32))
