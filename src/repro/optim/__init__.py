"""Optimizer: AdamW + global-norm clip + warmup-cosine schedule.

States mirror the parameter tree, so they inherit the parameter
sharding (FSDP mode => ZeRO: optimizer state sharded over "data")."""

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm, warmup_cosine)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "warmup_cosine"]
