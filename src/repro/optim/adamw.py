"""AdamW with decoupled weight decay, global-norm clipping and a
warmup-cosine LR schedule — pure pytree functions (no optax dep)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def warmup_cosine(c: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(c.warmup_steps, 1)
    prog = ((s - c.warmup_steps)
            / jnp.maximum(c.total_steps - c.warmup_steps, 1))
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.where(s < c.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(c: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    lr = warmup_cosine(c, step)
    b1t = 1.0 - c.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - c.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m2 = c.b1 * m + (1 - c.b1) * g
        v2 = c.b2 * v + (1 - c.b2) * g * g
        mh = m2 / b1t
        vh = v2 / b2t
        delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        p2, m2, v2 = upd(g, m, v, p)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"mu": jax.tree.unflatten(treedef, new_m),
                 "nu": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
