"""Streaming fleet service over the `VisualSystem` session.

The core session (``repro.core.pipeline``) answers "process THIS fleet
frame in 3 launches"; production traffic is rigs arriving
asynchronously, stalling, desyncing and losing cameras.  This package
is the robustness layer between the two:

  ``queue``       host-side frame queue coalescing async rig arrivals
                  into BUCKETED fleet batches (fixed small set of fleet
                  sizes -> bounded retraces; padding rigs masked out)
                  with per-rig deadlines.
  ``supervisor``  watchdog: per-rig health state machine (HEALTHY ->
                  DEGRADED -> RESTARTING -> QUARANTINED), heartbeat
                  timeouts, deterministic exponential backoff + jitter,
                  bounded restart budget, structured status report.
  ``faults``      deterministic fault-injection harness (dead cameras,
                  stalled rigs, corrupted frames, trigger desync,
                  arrival jitter) so every failure mode has a
                  reproducible test.
  ``failover``    host-level failure domain: ``HostMap`` placing rigs
                  on host fault domains with deterministic elastic
                  redistribution on ``host_down``, and ``DispatchGuard``
                  converting stuck/throwing dispatches into counted,
                  deterministically backed-off retries.
  ``snapshot``    crash-consistent service snapshots (versioned +
                  checksummed over ``repro.checkpoint``): a fresh
                  service restored from the newest verifiable snapshot
                  serves healthy rigs bit-exactly; torn snapshots fall
                  back a step instead of crashing.
  ``service``     ``FleetService``: ties them to a ``VisualSystem`` —
                  submit/step API, never-crash discipline (faults
                  become degradation or quarantine, not exceptions),
                  plus the ``run_episode`` driver (with kill-and-recover
                  support) tests and benchmarks share.

All time is explicit (every entry point takes ``now``): tests and the
fault harness drive a virtual clock, so restart/backoff behavior is
bit-reproducible under a fixed seed.  The one wall-clock exception is
the ``DispatchGuard`` timeout — a stuck XLA dispatch does not consult
a virtual clock.
"""

from repro.serving import snapshot
from repro.serving.failover import (DispatchEvent, DispatchGuard,
                                    DispatchGuardConfig, DispatchOutcome,
                                    HostEvent, HostMap,
                                    InjectedDispatchError)
from repro.serving.faults import FaultInjector, FaultSpec, InjectedFrame
from repro.serving.queue import FleetBatch, FrameQueue, QueueConfig
from repro.serving.service import (EpisodeResult, FleetService, RigReport,
                                   run_episode, wire_decode, wire_encode)
from repro.serving.supervisor import (RigHealth, Supervisor, SupervisorConfig,
                                      SupervisorEvent)

__all__ = [
    "DispatchEvent", "DispatchGuard", "DispatchGuardConfig",
    "DispatchOutcome", "HostEvent", "HostMap", "InjectedDispatchError",
    "FaultInjector", "FaultSpec", "InjectedFrame",
    "FleetBatch", "FrameQueue", "QueueConfig",
    "EpisodeResult", "FleetService", "RigReport", "run_episode",
    "wire_decode", "wire_encode",
    "RigHealth", "Supervisor", "SupervisorConfig", "SupervisorEvent",
    "snapshot",
]
