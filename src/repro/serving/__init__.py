"""Streaming fleet service over the `VisualSystem` session.

The core session (``repro.core.pipeline``) answers "process THIS fleet
frame in 3 launches"; production traffic is rigs arriving
asynchronously, stalling, desyncing and losing cameras.  This package
is the robustness layer between the two:

  ``queue``       host-side frame queue coalescing async rig arrivals
                  into BUCKETED fleet batches (fixed small set of fleet
                  sizes -> bounded retraces; padding rigs masked out)
                  with per-rig deadlines.
  ``supervisor``  watchdog: per-rig health state machine (HEALTHY ->
                  DEGRADED -> RESTARTING -> QUARANTINED), heartbeat
                  timeouts, deterministic exponential backoff + jitter,
                  bounded restart budget, structured status report.
  ``faults``      deterministic fault-injection harness (dead cameras,
                  stalled rigs, corrupted frames, trigger desync,
                  arrival jitter) so every failure mode has a
                  reproducible test.
  ``service``     ``FleetService``: ties the three to a ``VisualSystem``
                  — submit/step API, never-crash discipline (faults
                  become degradation or quarantine, not exceptions),
                  plus the ``run_episode`` driver tests and benchmarks
                  share.

All time is explicit (every entry point takes ``now``): tests and the
fault harness drive a virtual clock, so restart/backoff behavior is
bit-reproducible under a fixed seed.
"""

from repro.serving.faults import FaultInjector, FaultSpec, InjectedFrame
from repro.serving.queue import FleetBatch, FrameQueue, QueueConfig
from repro.serving.service import (EpisodeResult, FleetService, RigReport,
                                   run_episode, wire_decode, wire_encode)
from repro.serving.supervisor import (RigHealth, Supervisor, SupervisorConfig,
                                      SupervisorEvent)

__all__ = [
    "FaultInjector", "FaultSpec", "InjectedFrame",
    "FleetBatch", "FrameQueue", "QueueConfig",
    "EpisodeResult", "FleetService", "RigReport", "run_episode",
    "wire_decode", "wire_encode",
    "RigHealth", "Supervisor", "SupervisorConfig", "SupervisorEvent",
]
