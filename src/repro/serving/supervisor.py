"""Watchdog supervision: per-rig health, restarts, quarantine.

A production fleet loses rigs in two ways the batch math cannot see:
a rig stops SENDING (wedged driver, dead link — detected here via
heartbeat timeout) or keeps sending but degraded (dead camera, desync —
reported by the service via ``heartbeat(degraded=True)``).  The
supervisor runs the classic process-watchdog loop over both signals:

    HEALTHY <-> DEGRADED --timeout--> RESTARTING --budget--> QUARANTINED
        ^________________heartbeat________|  ^-- reinstate --'

Restarts back off exponentially with DETERMINISTIC per-(rig, attempt)
jitter (seeded — two supervisors with the same seed schedule identical
restart times, so fault-injection episodes are bit-reproducible), and a
rig that needs more than ``restart_budget`` restarts within
``flap_window_s`` is quarantined instead of flapping forever.

All time is an explicit ``now`` argument — no wall-clock reads — so the
state machine is a pure function of its inputs.
"""

from __future__ import annotations

import dataclasses
import enum
import typing
import zlib

import numpy as np


class RigHealth(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"        # serving, but with masked cameras/frames
    RESTARTING = "restarting"    # not serving; restart scheduled or issued
    QUARANTINED = "quarantined"  # flapped past the budget; manual reinstate


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    heartbeat_timeout_s: float = 0.5
    backoff_base_s: float = 0.1
    backoff_factor: float = 2.0
    backoff_max_s: float = 5.0
    backoff_jitter: float = 0.25   # +- fraction of the deterministic delay
    restart_budget: int = 3        # restarts inside flap_window_s before
    flap_window_s: float = 60.0    # ... the rig is quarantined
    seed: int = 0

    def __post_init__(self):
        if self.heartbeat_timeout_s <= 0:
            raise ValueError("heartbeat_timeout_s must be > 0")
        if self.backoff_base_s <= 0 or self.backoff_factor < 1:
            raise ValueError("backoff_base_s > 0 and backoff_factor >= 1 "
                             "required")
        if not (0 <= self.backoff_jitter < 1):
            raise ValueError("backoff_jitter must be in [0, 1)")
        if self.restart_budget < 1:
            raise ValueError("restart_budget must be >= 1")


class SupervisorEvent(typing.NamedTuple):
    """One observable transition from ``poll``: ``kind`` is
    ``"timeout"`` (heartbeat lapsed; restart scheduled at ``at``),
    ``"restart"`` (restart issued now) or ``"quarantine"``."""

    rig_id: typing.Any
    kind: str
    now: float
    at: float | None = None      # scheduled restart time for "timeout"
    attempt: int | None = None


@dataclasses.dataclass
class _RigState:
    health: RigHealth
    last_heartbeat: float
    restart_at: float | None = None     # scheduled; None while waiting
    restart_times: list = dataclasses.field(default_factory=list)
    restarts_total: int = 0
    degraded_frames: int = 0
    frames: int = 0


class Supervisor:
    """Heartbeat-driven health tracking for a set of rigs.

    ``restart_cb(rig_id)``, when given, is invoked from ``poll`` at the
    moment a scheduled restart fires — the hook a real deployment points
    at its camera-driver relaunch (and fault-injection tests point at
    ``FaultInjector.clear_rig`` so a restart actually heals the rig).
    """

    def __init__(self, cfg: SupervisorConfig | None = None,
                 restart_cb=None) -> None:
        self.cfg = cfg if cfg is not None else SupervisorConfig()
        self.restart_cb = restart_cb
        self._rigs: dict = {}

    # -- intake ------------------------------------------------------------

    def register(self, rig_id, now: float) -> None:
        if rig_id not in self._rigs:
            self._rigs[rig_id] = _RigState(RigHealth.HEALTHY, float(now))

    def heartbeat(self, rig_id, now: float, degraded: bool = False) -> None:
        """A sign of life from a rig (the service calls this on every
        accepted frame).  Revives a RESTARTING rig; never un-quarantines
        (that requires an explicit ``reinstate``)."""
        self.register(rig_id, now)
        st = self._rigs[rig_id]
        st.frames += 1
        st.degraded_frames += int(degraded)
        if st.health is RigHealth.QUARANTINED:
            return
        st.last_heartbeat = float(now)
        st.restart_at = None
        st.health = RigHealth.DEGRADED if degraded else RigHealth.HEALTHY

    def is_serving(self, rig_id) -> bool:
        st = self._rigs.get(rig_id)
        return st is not None and st.health in (RigHealth.HEALTHY,
                                                RigHealth.DEGRADED)

    def health(self, rig_id) -> RigHealth | None:
        st = self._rigs.get(rig_id)
        return None if st is None else st.health

    # -- watchdog ----------------------------------------------------------

    def _backoff(self, rig_id, attempt: int) -> float:
        """Deterministic exponential backoff with seeded jitter: the
        delay before restart ``attempt`` (1-based) of ``rig_id``.  The
        jitter stream is keyed on (seed, rig, attempt) so it decorrelates
        rigs (no restart stampede) yet replays exactly under one seed."""
        cfg = self.cfg
        delay = min(cfg.backoff_base_s * cfg.backoff_factor ** (attempt - 1),
                    cfg.backoff_max_s)
        key = [cfg.seed & 0xFFFFFFFF,
               zlib.crc32(repr(rig_id).encode()) & 0xFFFFFFFF,
               attempt]
        u = np.random.RandomState(key).uniform(-1.0, 1.0)
        return float(delay * (1.0 + cfg.backoff_jitter * u))

    def poll(self, now: float) -> list[SupervisorEvent]:
        """Advance the watchdog to ``now``; returns the transitions that
        fired.  Call at every service step (idempotent between state
        changes)."""
        now = float(now)
        events: list[SupervisorEvent] = []
        for rig_id, st in self._rigs.items():
            if st.health is RigHealth.QUARANTINED:
                continue
            if st.health is RigHealth.RESTARTING and st.restart_at is not None:
                if now >= st.restart_at:
                    st.restart_at = None
                    st.restarts_total += 1
                    # fresh timeout window to come back up in; if no
                    # heartbeat arrives, the lapse below schedules the
                    # next (further backed-off) attempt.
                    st.last_heartbeat = now
                    events.append(SupervisorEvent(
                        rig_id, "restart", now,
                        attempt=len(st.restart_times)))
                    if self.restart_cb is not None:
                        self.restart_cb(rig_id)
                continue
            if now - st.last_heartbeat <= self.cfg.heartbeat_timeout_s:
                continue
            # Heartbeat lapsed (serving rig wedged, or a restarted rig
            # that never came back): schedule the next restart, or
            # quarantine once the budget inside the flap window is spent.
            window = self.cfg.flap_window_s
            st.restart_times = [t for t in st.restart_times
                                if now - t <= window]
            if len(st.restart_times) >= self.cfg.restart_budget:
                st.health = RigHealth.QUARANTINED
                st.restart_at = None
                events.append(SupervisorEvent(rig_id, "quarantine", now))
                continue
            st.restart_times.append(now)
            attempt = len(st.restart_times)
            st.restart_at = now + self._backoff(rig_id, attempt)
            st.health = RigHealth.RESTARTING
            events.append(SupervisorEvent(rig_id, "timeout", now,
                                          at=st.restart_at, attempt=attempt))
        return events

    def reinstate(self, rig_id, now: float) -> None:
        """Manually lift a quarantine: the rig re-enters RESTARTING with
        a cleared flap history and an immediate restart."""
        st = self._rigs[rig_id]
        st.health = RigHealth.RESTARTING
        st.restart_times = []
        st.restart_at = float(now)

    # -- snapshot ----------------------------------------------------------

    def export_state(self) -> list:
        """The full per-rig ledger as plain JSON-able records (rig ids
        left as-is — ``serving.snapshot`` tags them for JSON).  This is
        the state a host crash must NOT launder: restart times, flap
        budgets and quarantine flags all survive a snapshot/restore
        round trip bit-for-bit."""
        return [
            {"rig_id": rig_id,
             "health": st.health.value,
             "last_heartbeat": st.last_heartbeat,
             "restart_at": st.restart_at,
             "restart_times": list(st.restart_times),
             "restarts_total": st.restarts_total,
             "degraded_frames": st.degraded_frames,
             "frames": st.frames}
            for rig_id, st in self._rigs.items()]

    def restore_state(self, records: list) -> None:
        """Inverse of ``export_state``: replace the ledger wholesale.
        A quarantined rig stays quarantined, a rig mid-backoff keeps its
        scheduled ``restart_at`` and its in-window restart history — the
        watchdog resumes exactly where the dead host left off."""
        self._rigs = {}
        for rec in records:
            self._rigs[rec["rig_id"]] = _RigState(
                health=RigHealth(rec["health"]),
                last_heartbeat=float(rec["last_heartbeat"]),
                restart_at=(None if rec["restart_at"] is None
                            else float(rec["restart_at"])),
                restart_times=[float(t) for t in rec["restart_times"]],
                restarts_total=int(rec["restarts_total"]),
                degraded_frames=int(rec["degraded_frames"]),
                frames=int(rec["frames"]))

    # -- reporting ---------------------------------------------------------

    def status_report(self, now: float) -> dict:
        """Structured health snapshot: per-rig state + fleet counts."""
        rigs = {}
        counts = {h.value: 0 for h in RigHealth}
        for rig_id, st in sorted(self._rigs.items(), key=lambda kv: repr(kv[0])):
            counts[st.health.value] += 1
            rigs[rig_id] = {
                "health": st.health.value,
                "since_heartbeat_s": round(float(now) - st.last_heartbeat, 6),
                "restart_at": st.restart_at,
                "restarts_total": st.restarts_total,
                "restarts_in_window": len(st.restart_times),
                "frames": st.frames,
                "degraded_frames": st.degraded_frames,
            }
        return {"now": float(now), "counts": counts, "rigs": rigs}
