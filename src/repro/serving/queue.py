"""Host-side frame queue: async rig arrivals -> bucketed fleet batches.

``VisualSystem.process_fleet`` wants one ``(n_rigs, C, H, W)`` array per
call, and every DISTINCT ``n_rigs`` it sees costs a retrace.  Real rigs
arrive one at a time with jitter, so the queue coalesces: frames
accumulate until either a full bucket's worth is pending or the oldest
frame hits its deadline, then the batch is padded UP to the smallest
configured bucket size — the jit cache holds at most
``len(bucket_sizes)`` fleet shapes forever, regardless of traffic.
Padding rigs carry zero images and an all-False camera mask, so the
masked fleet path gates all their validity off (and the whole batch is
still the 3-launch schedule — masking is elementwise, not a kernel).

The queue is intentionally dumb about WHY a camera mask is partial or a
frame late: fault detection, desync policy and health tracking live in
``service``/``supervisor``; this module only does shape-checked
buffering and bucketing.
"""

from __future__ import annotations

import collections
import dataclasses
import typing

import jax.numpy as jnp
import numpy as np

from repro.core.rig import RigConfig


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """``bucket_sizes`` is the closed set of fleet sizes ever dispatched
    (sorted ascending at validation); ``deadline_s`` is how long a frame
    may wait before the queue declares the batch ready anyway (and flags
    the frame ``late``); ``max_pending_per_rig`` bounds per-rig buffering
    — a streaming consumer wants the freshest frames, so the OLDEST
    frame of an over-buffered rig is dropped (counted, never silent)."""

    bucket_sizes: tuple[int, ...] = (1, 2, 4, 8)
    deadline_s: float = 0.05
    max_pending_per_rig: int = 2

    def __post_init__(self):
        sizes = tuple(sorted(int(b) for b in self.bucket_sizes))
        if not sizes or sizes[0] < 1:
            raise ValueError(
                f"bucket_sizes must be >= 1, got {self.bucket_sizes}")
        if len(set(sizes)) != len(sizes):
            raise ValueError(
                f"bucket_sizes has duplicates: {self.bucket_sizes}")
        object.__setattr__(self, "bucket_sizes", sizes)
        if self.deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {self.deadline_s}")
        if self.max_pending_per_rig < 1:
            raise ValueError(
                f"max_pending_per_rig must be >= 1, "
                f"got {self.max_pending_per_rig}")


class _Pending(typing.NamedTuple):
    rig_id: typing.Any
    images: np.ndarray          # (C, H, W) in the queue's dtype
    t_arrival: float
    camera_mask: np.ndarray     # (C,) bool


class FleetBatch(typing.NamedTuple):
    """One bucketed fleet frame ready for ``process_fleet``.

    ``images`` is ``(B, C, H, W)`` with ``B`` in ``bucket_sizes``;
    ``rig_mask[b]`` says whether row ``b`` is a real rig (padding rows
    are all-False in ``camera_mask`` too); ``rig_ids``/``late`` cover
    only the real rows (length ``rig_mask.sum()``)."""

    images: jnp.ndarray
    camera_mask: np.ndarray     # (B, C) bool
    rig_ids: tuple
    rig_mask: np.ndarray        # (B,) bool
    late: np.ndarray            # (n_real,) bool
    t_arrivals: tuple           # (n_real,) per-frame arrival times
    t_oldest: float

    @property
    def n_real(self) -> int:
        return int(self.rig_mask.sum())


class FrameQueue:
    """FIFO of shape-validated rig frames with bucketed draining."""

    def __init__(self, rig: RigConfig, frame_hw: tuple[int, int],
                 cfg: QueueConfig | None = None,
                 dtype=np.float32) -> None:
        self.rig = rig
        self.frame_hw = (int(frame_hw[0]), int(frame_hw[1]))
        self.cfg = cfg if cfg is not None else QueueConfig()
        # Frame storage dtype — np.uint8 when the session runs the
        # integer datapath (4x smaller queue + batch slabs), else f32.
        self.dtype = np.dtype(dtype)
        self._pending: collections.deque[_Pending] = collections.deque()
        self.dropped_overflow = 0     # oldest-frame drops from over-buffering

    # -- intake ------------------------------------------------------------

    def put(self, rig_id, images, t_arrival: float,
            camera_mask=None) -> None:
        """Validate one rig frame eagerly and buffer it.

        ``images``: (n_cameras, H, W); shape mismatches fail HERE with
        the expected shape spelled out, not as a trace error after the
        batch is padded.  ``camera_mask`` defaults to all-True."""
        im = np.asarray(images, dtype=self.dtype)
        want = (self.rig.n_cameras,) + self.frame_hw
        if im.shape != want:
            raise ValueError(
                f"FrameQueue.put(rig_id={rig_id!r}): frame shape "
                f"{im.shape} does not match the queue's rig layout "
                f"{want} (n_cameras, H, W)")
        if camera_mask is None:
            mask = np.ones(self.rig.n_cameras, dtype=bool)
        else:
            mask = np.asarray(camera_mask, dtype=bool)
            if mask.shape != (self.rig.n_cameras,):
                raise ValueError(
                    f"FrameQueue.put(rig_id={rig_id!r}): camera_mask "
                    f"shape {mask.shape} does not match "
                    f"({self.rig.n_cameras},)")
        mine = [p for p in self._pending if p.rig_id == rig_id]
        if len(mine) >= self.cfg.max_pending_per_rig:
            self._pending.remove(mine[0])     # oldest of THIS rig
            self.dropped_overflow += 1
        self._pending.append(_Pending(rig_id, im, float(t_arrival), mask))

    # -- snapshot ----------------------------------------------------------

    def export_pending(self) -> list[_Pending]:
        """The buffered-but-unserved frames, oldest first — part of the
        crash-consistent service snapshot (a frame accepted by
        ``submit`` must survive a host crash, or recovery silently
        drops it and the restored run diverges from an uninterrupted
        one)."""
        return list(self._pending)

    def restore_pending(self, items, dropped_overflow: int = 0) -> None:
        """Replace the pending buffer (snapshot restore).  Each frame
        re-enters through ``put`` so a corrupt snapshot cannot smuggle
        a bad shape past the eager validation."""
        self._pending.clear()
        for p in items:
            self.put(p.rig_id, p.images, p.t_arrival,
                     camera_mask=p.camera_mask)
        self.dropped_overflow = int(dropped_overflow)

    # -- draining ----------------------------------------------------------

    def pending(self) -> int:
        return len(self._pending)

    def oldest_wait(self, now: float) -> float:
        if not self._pending:
            return 0.0
        return float(now) - min(p.t_arrival for p in self._pending)

    def ready(self, now: float) -> bool:
        """A batch is worth dispatching when a full largest-bucket is
        pending (throughput) or the oldest frame hit its deadline
        (latency)."""
        if not self._pending:
            return False
        return (len(self._pending) >= self.cfg.bucket_sizes[-1]
                or self.oldest_wait(now) >= self.cfg.deadline_s)

    def next_batch(self, now: float, force: bool = False
                   ) -> FleetBatch | None:
        """Drain up to one largest-bucket of frames (oldest first) into
        a padded ``FleetBatch``; None when not ``ready`` (pass
        ``force=True`` to flush regardless, e.g. at episode end)."""
        if not (force or self.ready(now)):
            return None
        if not self._pending:
            return None
        take = min(len(self._pending), self.cfg.bucket_sizes[-1])
        frames = [self._pending.popleft() for _ in range(take)]
        bucket = next(b for b in self.cfg.bucket_sizes if b >= take)

        c, (h, w) = self.rig.n_cameras, self.frame_hw
        images = np.zeros((bucket, c, h, w), dtype=self.dtype)
        camera_mask = np.zeros((bucket, c), dtype=bool)
        rig_mask = np.zeros(bucket, dtype=bool)
        deadline = self.cfg.deadline_s
        late = np.asarray([float(now) - p.t_arrival > deadline  # audit: host-ok
                           for p in frames], dtype=bool)        # host floats in
        for b, p in enumerate(frames):
            images[b] = p.images
            camera_mask[b] = p.camera_mask
            rig_mask[b] = True
        return FleetBatch(
            images=jnp.asarray(images), camera_mask=camera_mask,  # audit: host-ok — upload, not a device sync
            rig_ids=tuple(p.rig_id for p in frames), rig_mask=rig_mask,
            late=late, t_arrivals=tuple(p.t_arrival for p in frames),
            t_oldest=min(p.t_arrival for p in frames))
