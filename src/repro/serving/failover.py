"""Multi-host failover primitives: rig placement and guarded dispatch.

PR 6 made the RIG the failure domain (watchdog, quarantine); one level
up, the serving HOST itself fails — taking every rig it serves, their
pose chains and its dispatch loop with it.  This module holds the two
host-level pieces that are independent of the `FleetService` wiring:

  ``HostMap``        rigs -> host fault domains (deterministic
                     least-loaded placement over the domain ids from
                     ``launch.mesh.host_fault_domains``); ``host_down``
                     redistributes the casualties over the survivors —
                     the serving-tier face of ``distributed.elastic``'s
                     re-mesh idiom (the device-side arrays re-place via
                     ``elastic.surviving_mesh`` + ``remesh_tree``).
  ``DispatchGuard``  wraps the service's per-batch compute in a
                     wall-clock watchdog thread + bounded retries with
                     the Supervisor's deterministic seeded backoff
                     (``RandomState([seed, crc32(key), attempt])``), so
                     a stuck or throwing dispatch becomes a counted,
                     reported event instead of a wedged ``step`` loop.

Crash-consistent snapshots (the third piece) live in
``repro.serving.snapshot``; ``FleetService`` ties all three together.
"""

from __future__ import annotations

import contextvars
import dataclasses
import threading
import typing
import zlib

import numpy as np

__all__ = ["DispatchGuard", "DispatchGuardConfig", "DispatchOutcome",
           "DispatchEvent", "HostEvent", "HostMap",
           "InjectedDispatchError"]


# ---------------------------------------------------------------------------
# Rig placement over host fault domains

class HostEvent(typing.NamedTuple):
    """One observable host-level transition (``FleetService.events``
    carries these next to the per-rig ``SupervisorEvent``s):
    ``kind="host_down"`` with the lost domain and the (rig, new_host)
    moves the redistribution made."""

    kind: str
    now: float
    host: typing.Any
    moved: tuple = ()


class HostMap:
    """Assignment of rigs to host fault domains.

    Placement is deterministic least-loaded (ties broken by the hosts'
    given order), so two coordinators with the same arrival order hold
    identical maps — the same discipline as every other seeded piece of
    the serving layer.  ``host_down`` removes a domain and re-places its
    rigs over the survivors (stable ``repr`` order), returning the moves
    so the service can gap their pose chains and count the event.
    """

    def __init__(self, hosts: typing.Sequence,
                 assignment: dict | None = None) -> None:
        hosts = list(hosts)
        if not hosts:
            raise ValueError("HostMap needs at least one host domain")
        if len(set(hosts)) != len(hosts):
            raise ValueError(f"duplicate host domains: {hosts}")
        self.hosts: list = hosts
        self.down: list = []
        self._assignment: dict = {}
        for rig, host in (assignment or {}).items():
            if host not in hosts:
                raise ValueError(
                    f"rig {rig!r} assigned to unknown host {host!r}")
            self._assignment[rig] = host

    @classmethod
    def from_mesh(cls, mesh, axis: str = "data") -> "HostMap":
        """One fault domain per index of the mesh's ``axis`` — the axis
        the fleet's rig dimension is shard_map'ed over."""
        from repro.launch.mesh import host_fault_domains
        return cls(host_fault_domains(mesh, axis))

    # -- placement ---------------------------------------------------------

    def load(self) -> dict:
        out = {h: 0 for h in self.hosts}
        for host in self._assignment.values():
            if host in out:     # mid-redistribution, casualties still
                out[host] += 1  # point at the dead host — weightless
        return out

    def _least_loaded(self):
        load = self.load()
        return min(self.hosts, key=lambda h: (load[h],
                                              self.hosts.index(h)))

    def assign(self, rig_id):
        """The rig's host, placing it least-loaded on first sight."""
        host = self._assignment.get(rig_id)
        if host is None:
            host = self._least_loaded()
            self._assignment[rig_id] = host
        return host

    def host_of(self, rig_id):
        return self._assignment.get(rig_id)

    def rigs_on(self, host) -> tuple:
        return tuple(sorted((r for r, h in self._assignment.items()
                             if h == host), key=repr))

    # -- failure -----------------------------------------------------------

    def host_down(self, host) -> tuple:
        """Remove ``host`` and redistribute its rigs least-loaded over
        the survivors.  Returns ``((rig, new_host), ...)`` in stable
        order.  Losing the LAST host is a fleet-wide outage, not a
        redistribution — that raises."""
        if host not in self.hosts:
            raise ValueError(f"host {host!r} is not an active domain "
                             f"(active: {self.hosts}, down: {self.down})")
        if len(self.hosts) == 1:
            raise ValueError(
                f"host {host!r} is the last surviving domain — "
                "redistribution target set is empty (fleet-wide outage)")
        casualties = self.rigs_on(host)
        self.hosts.remove(host)
        self.down.append(host)
        moved = []
        for rig in casualties:
            new = self._least_loaded()
            self._assignment[rig] = new
            moved.append((rig, new))
        return tuple(moved)

    # -- snapshot ----------------------------------------------------------

    def export_state(self) -> dict:
        """Plain-python placement state (ids left as-is; the snapshot
        layer tags them for JSON)."""
        return {"hosts": list(self.hosts), "down": list(self.down),
                "assignment": [[r, h] for r, h
                               in self._assignment.items()]}

    def restore_state(self, state: dict) -> None:
        self.hosts = list(state["hosts"])
        self.down = list(state["down"])
        self._assignment = {r: h for r, h in state["assignment"]}

    def status(self) -> dict:
        return {"hosts": list(self.hosts), "down": list(self.down),
                "load": self.load()}


# ---------------------------------------------------------------------------
# Guarded dispatch

class InjectedDispatchError(RuntimeError):
    """What a ``dispatch_error`` fault spec raises inside the guarded
    compute — a stand-in for the real failure zoo (XLA OOM, device
    resets, driver faults)."""


class _Stalled(Exception):
    """Internal: the watchdog thread outlived its timeout."""


@dataclasses.dataclass(frozen=True)
class DispatchGuardConfig:
    """``timeout_s`` is WALL clock (the one place the serving layer
    reads it: a stuck XLA dispatch does not consult our virtual clock);
    generous by default because the first call per bucket shape pays
    jit tracing.  Retry backoff reuses the Supervisor's deterministic
    seeded-jitter idiom and is REPORTED, not slept — the service loop
    owns pacing, the guard owns the schedule."""

    timeout_s: float = 60.0
    max_attempts: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 1.0
    backoff_jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError("timeout_s must be > 0")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_base_s <= 0 or self.backoff_factor < 1:
            raise ValueError("backoff_base_s > 0 and backoff_factor >= 1 "
                             "required")
        if not (0 <= self.backoff_jitter < 1):
            raise ValueError("backoff_jitter must be in [0, 1)")


class DispatchOutcome(typing.NamedTuple):
    """``ok`` with the computed ``value``, or exhausted after
    ``attempts`` tries; ``faults`` records each failed attempt
    (``"stall"`` / ``"error:<Type>"``) and ``backoff_s`` the
    deterministic delays scheduled between attempts."""

    ok: bool
    value: typing.Any
    attempts: int
    faults: tuple[str, ...]
    backoff_s: tuple[float, ...]


class DispatchEvent(typing.NamedTuple):
    """Emitted into ``FleetService.events`` whenever a guarded dispatch
    saw at least one fault: ``kind`` is ``"dispatch_recovered"`` (a
    retry succeeded) or ``"dispatch_drop"`` (budget exhausted, batch
    dropped)."""

    kind: str
    now: float
    dispatch: int
    attempts: int
    faults: tuple[str, ...]
    backoff_s: tuple[float, ...]


class DispatchGuard:
    """Timeout + bounded-retry wrapper for one dispatch callable.

    Each attempt runs in a daemon watchdog thread joined with
    ``timeout_s``; a thread that outlives the join is counted a stall
    and ABANDONED (its eventual result, if any, is discarded — a truly
    stuck dispatch never returns, and a merely-slow one must not race a
    retry).  Exceptions propagate out of the thread and are counted.
    ``inject`` (from ``FaultInjector.dispatch_fault``) lets episodes
    deterministically fault attempts: ``"error"`` raises
    ``InjectedDispatchError`` before the compute, ``"stall"`` simulates
    the timeout without calling the compute or burning wall clock (so
    an injected stall cannot leave a concurrent trace racing the
    retry, and episode tests stay fast under generous real timeouts).
    """

    def __init__(self, cfg: DispatchGuardConfig | None = None) -> None:
        self.cfg = cfg if cfg is not None else DispatchGuardConfig()

    def backoff(self, key, attempt: int) -> float:
        """Deterministic delay before retry ``attempt`` (1-based count
        of FAILED attempts) of dispatch ``key`` — the Supervisor's
        ``RandomState([seed, crc32, attempt])`` idiom, so replays
        schedule identically and concurrent hosts decorrelate."""
        cfg = self.cfg
        delay = min(cfg.backoff_base_s * cfg.backoff_factor ** (attempt - 1),
                    cfg.backoff_max_s)
        u = np.random.RandomState(
            [cfg.seed & 0xFFFFFFFF,
             zlib.crc32(repr(key).encode()) & 0xFFFFFFFF,
             attempt]).uniform(-1.0, 1.0)
        return float(delay * (1.0 + cfg.backoff_jitter * u))

    def _attempt(self, fn, mode: str | None):
        if mode == "error":
            raise InjectedDispatchError("injected dispatch_error")
        if mode == "stall":
            # Simulated timeout: fn is never called and no wall clock
            # is burned — an injected stall must neither slow the test
            # down by timeout_s nor leave an abandoned compute racing
            # the retry.  Genuine stalls take the thread path below.
            raise _Stalled
        box: dict = {}

        def worker():
            try:
                box["value"] = fn()
            except BaseException as e:      # noqa: BLE001 — reported below
                box["error"] = e

        # A fresh Thread starts with an EMPTY contextvars context, so an
        # ambient ``ops.launch_audit()`` scope (and any other contextvar
        # the caller holds) would not see launches dispatched inside the
        # guarded compute.  Run the worker inside a copy of the caller's
        # context: LaunchAudit objects are shared by reference, so counts
        # land in the caller's audit even though the context is a copy.
        ctx = contextvars.copy_context()
        t = threading.Thread(target=lambda: ctx.run(worker), daemon=True,
                             name="repro-dispatch-guard")
        t.start()
        t.join(self.cfg.timeout_s)
        if t.is_alive():
            raise _Stalled
        if "error" in box:
            raise box["error"]
        return box["value"]

    def run(self, key, fn, inject=None) -> DispatchOutcome:
        faults: list[str] = []
        delays: list[float] = []
        for attempt in range(1, self.cfg.max_attempts + 1):
            mode = inject(attempt) if inject is not None else None
            try:
                value = self._attempt(fn, mode)
                return DispatchOutcome(True, value, attempt,
                                       tuple(faults), tuple(delays))
            except _Stalled:
                faults.append("stall")
            except Exception as e:          # noqa: BLE001 — the guard's job
                faults.append(f"error:{type(e).__name__}")
            if attempt < self.cfg.max_attempts:
                delays.append(self.backoff(key, attempt))
        return DispatchOutcome(False, None, self.cfg.max_attempts,
                               tuple(faults), tuple(delays))
