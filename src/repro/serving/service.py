"""``FleetService``: the never-crash streaming loop over a session.

One service owns one ``VisualSystem`` (one rig layout — mixed layouts
get one service each, mirroring the per-layout jit caches) plus a
``FrameQueue`` and a ``Supervisor``.  The contract is the robustness
inversion of the core API: ``process_frame`` RAISES on bad input so
callers can't miss it; the service CONVERTS every fault into
degradation, a drop, or quarantine and keeps serving —

  corrupted frames   eager finite-check per camera slab -> dead-camera
                     mask (the kernels then sanitize the slab);
  desync             the rig's ``desync_decision`` applied eagerly; a
                     policy that would raise becomes a dropped frame
                     (counted + health-reported, never an exception);
  dead cameras       driver mask -> masked fleet batch, surviving
                     stereo pairs still served in the 3-launch budget;
  stalled rigs       no frames -> no heartbeats -> supervisor timeout,
                     backoff restarts, quarantine when flapping.

All time is explicit (``submit``/``step`` take the caller's clock), so
``run_episode`` can drive a virtual clock and replay an injected-fault
episode bit-identically.
"""

from __future__ import annotations

import collections
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro import localization
from repro.core.pipeline import VisualSystem
from repro.core.types import LocalizationOutput, StereoOutput
from repro.distributed import compression
from repro.kernels import ops
from repro.serving.failover import DispatchEvent, DispatchGuard, HostEvent, \
    HostMap
from repro.serving.faults import FaultInjector
from repro.serving.queue import FrameQueue, QueueConfig
from repro.serving import snapshot
from repro.serving.supervisor import Supervisor, SupervisorConfig


class RigReport(typing.NamedTuple):
    """One served (or dropped) rig frame.  ``output`` is the rig's
    ``StereoOutput`` slice (leading (n_pairs,) axes) for served frames
    — a ``LocalizationOutput`` slice (with 3-D points + pose) when the
    session localizes — None for drops; ``status`` is ``"ok"``,
    ``"degraded"``, or one of the ``"dropped_*"`` reasons."""

    rig_id: typing.Any
    t: float                    # service-step time the frame was served
    t_arrival: float            # when the frame arrived (the stable key
    status: str                 # for cross-episode output comparison)
    camera_mask: np.ndarray | None
    output: typing.Any
    late: bool = False


class FleetService:
    def __init__(self, vs: VisualSystem,
                 queue_cfg: QueueConfig | None = None,
                 sup_cfg: SupervisorConfig | None = None,
                 restart_cb=None,
                 guard: DispatchGuard | None = None,
                 host_map: HostMap | None = None) -> None:
        self.vs = vs
        # The queue buffers frames in the session's datapath dtype —
        # a uint8-precision session keeps the whole intake path 8-bit
        # (4x smaller pending buffers and fleet batch slabs).
        self._frame_dtype = (np.uint8 if vs.pipe.precision == "uint8"
                             else np.float32)
        self.queue = FrameQueue(vs.rig,
                                (vs.pipe.orb.height, vs.pipe.orb.width),
                                queue_cfg, dtype=self._frame_dtype)
        self.supervisor = Supervisor(sup_cfg, restart_cb)
        # Optional failover layer: a DispatchGuard turns stuck/throwing
        # dispatches into counted retries/drops; a HostMap places rigs
        # on host fault domains so ``host_down`` can redistribute.
        self.guard = guard
        self.host_map = host_map
        self._dispatch_injector: FaultInjector | None = None
        self.events: list = []
        self.counters = collections.Counter()
        # Per-rig cross-frame localization memory (LocalizationState),
        # keyed by rig_id.  The queue re-buckets rigs freely between
        # batches, so the service — not the session — owns this state
        # and hands each batch an explicitly assembled ``prev``.
        self._loc_state: dict = {}

    # -- intake ------------------------------------------------------------

    def submit(self, rig_id, images, t_arrival: float, timestamps=None,
               camera_mask=None) -> str:
        """Accept one rig frame into the queue, running fault detection
        eagerly.  Returns the intake status (``"queued"`` /
        ``"queued_degraded"`` / ``"dropped_*"``); never raises on frame
        CONTENT (shape/layout errors still raise — those are caller
        bugs, not sensor faults)."""
        now = float(t_arrival)
        self.supervisor.register(rig_id, now)
        if self.host_map is not None:
            self.host_map.assign(rig_id)
        self.counters["frames_in"] += 1
        if self.supervisor.health(rig_id) is not None \
                and self.supervisor.health(rig_id).value == "quarantined":
            self.counters["dropped_quarantined"] += 1
            return "dropped_quarantined"

        arr = np.asarray(images)
        mask = (np.ones(self.vs.rig.n_cameras, dtype=bool)
                if camera_mask is None
                else np.asarray(camera_mask, dtype=bool).reshape(-1))
        if self._frame_dtype == np.uint8 and arr.dtype == np.uint8:
            # Integer fast path: a uint8 slab into a uint8-precision
            # session is already finite and already quantized — the
            # float32 widen + finite scan + round/clip/cast (4x the
            # bytes, three full passes) would be pure overhead, so the
            # 8-bit intake stays actually 8-bit.
            im = arr
        else:
            im = np.asarray(arr, dtype=np.float32)
            # Corruption: a NaN/inf slab with a healthy driver mask —
            # catch it here so garbage never reaches (or retraces) the
            # kernels.
            finite = np.isfinite(im).all(axis=tuple(range(1, im.ndim)))
            if not finite.all():
                self.counters["corrupt_cameras"] += int((~finite & mask).sum())
                mask &= finite
            if self._frame_dtype == np.uint8:
                # Quantize at ingest (round/clip, matching the f32
                # path's quantized pyramid) — NaNs were already masked
                # above, so the cast is well-defined on every surviving
                # camera.
                im = np.round(np.clip(np.nan_to_num(im), 0.0, 255.0)) \
                    .astype(np.uint8)
        if timestamps is not None:
            decision = self.vs.desync_decision(timestamps)
            if decision.action in ("raise", "drop_frame"):
                # Never-crash discipline: a raise-policy desync becomes
                # a counted drop; the rig stays alive but degraded.
                self.counters["dropped_desync"] += 1
                self.supervisor.heartbeat(rig_id, now, degraded=True)
                return "dropped_desync"
            if decision.action == "degrade":
                self.counters["desync_degraded"] += 1
                mask &= decision.camera_mask
        if not mask.any():
            self.counters["dropped_dead"] += 1
            self.supervisor.heartbeat(rig_id, now, degraded=True)
            return "dropped_dead"

        degraded = not mask.all()
        self.supervisor.heartbeat(rig_id, now, degraded=degraded)
        self.queue.put(rig_id, im, now, camera_mask=mask)
        self.counters["queued"] += 1
        return "queued_degraded" if degraded else "queued"

    # -- serving -----------------------------------------------------------

    def _assemble_prev(self, batch):
        """Stack each batch row's previous-frame ``LocalizationState``
        (all-invalid ``zero_state`` for first-seen rigs and padding
        rows, so they localize to identity + ``valid=False`` through
        the same jitted graph).

        A backlogged rig can appear TWICE in one batch (its frames are
        oldest-first).  The batch is one jit call, so the second frame
        cannot chain on the first's not-yet-computed state; giving it
        the same stored state would silently solve a double-length
        step.  Instead only the FIRST occurrence chains; later ones get
        ``zero_state`` and honestly report identity + ``valid=False``
        (the stored state still advances to the newest frame, so the
        next batch chains from there)."""
        zero = localization.zero_state(self.vs.rig.n_pairs,
                                       self.vs.pipe.orb.max_features)
        n_slots = batch.images.shape[0]
        rows, seen = [], set()
        for b in range(n_slots):
            if b >= len(batch.rig_ids):
                rows.append(zero)                      # padding row
                continue
            rid = batch.rig_ids[b]
            rows.append(zero if rid in seen
                        else self._loc_state.get(rid, zero))
            seen.add(rid)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)

    def step(self, now: float, force: bool = False) -> list[RigReport]:
        """One service tick: advance the watchdog, then serve at most
        one bucketed fleet batch (3 kernel launches regardless of how
        many rigs are real, padded, or degraded — plus 1 localization
        launch when the session localizes)."""
        new_events = self.supervisor.poll(now)
        self.events.extend(new_events)
        # A restarted rig's frame stream has a gap: its stashed state
        # is stale, and a pose solved against it would be finite but
        # meaningless.  Drop it — the next served frame then reports
        # the honest identity + valid=False.
        for ev in new_events:
            if ev.kind in ("restart", "quarantine"):
                self._loc_state.pop(ev.rig_id, None)
        batch = self.queue.next_batch(now, force=force)
        if batch is None:
            return []
        localize = self.vs.pipe.localize

        def _compute():
            if localize:
                out = self.vs.process_fleet(batch.images,
                                            camera_mask=batch.camera_mask,
                                            prev=self._assemble_prev(batch))
                return out, localization.state_from(out)
            return self.vs.process_fleet(batch.images,
                                         camera_mask=batch.camera_mask), None

        if self.guard is not None:
            out, state = self._guarded(_compute, now)
            if out is None:
                # Budget exhausted: the batch is dropped (counted per
                # rig frame, health degraded) but the loop keeps
                # serving — same never-crash discipline as intake.
                for rig_id in batch.rig_ids:
                    self.counters["dropped_dispatch"] += 1
                    self.supervisor.heartbeat(rig_id, now, degraded=True)
                return []
        else:
            out, state = _compute()
        self.counters["batches"] += 1
        self.counters["padded_rows"] += len(batch.rig_mask) - batch.n_real
        reports = []
        for b, rig_id in enumerate(batch.rig_ids):
            mask = batch.camera_mask[b]
            if localize:
                self._loc_state[rig_id] = jax.tree.map(
                    lambda x: x[b], state)
            reports.append(RigReport(
                rig_id=rig_id, t=float(now),
                t_arrival=batch.t_arrivals[b],
                status="ok" if mask.all() else "degraded",
                camera_mask=mask,
                output=jax.tree.map(lambda x: x[b], out),
                late=bool(batch.late[b])))
            self.counters["frames_out"] += 1
            self.counters["late_frames"] += int(batch.late[b])
        return reports

    def _guarded(self, compute, now: float):
        """Run one batch compute under the ``DispatchGuard``: stalls and
        exceptions become counted events + deterministic-backoff retries,
        and an exhausted budget returns ``(None, None)`` instead of
        raising.  The dispatch ordinal keys the injector window AND the
        backoff stream, and lives in ``counters`` so it survives a
        snapshot/restore (a restored service does not replay old
        ordinals)."""
        dispatch = int(self.counters["dispatches"])
        self.counters["dispatches"] += 1
        inj = self._dispatch_injector
        inject = (None if inj is None
                  else lambda attempt: inj.dispatch_fault(dispatch, attempt))
        outcome = self.guard.run(dispatch, compute, inject=inject)
        for fault in outcome.faults:
            kind = "dispatch_stalls" if fault == "stall" \
                else "dispatch_errors"
            self.counters[kind] += 1
        if outcome.faults:
            self.counters["dispatch_retries"] += outcome.attempts - 1
            self.events.append(DispatchEvent(
                "dispatch_recovered" if outcome.ok else "dispatch_drop",
                float(now), dispatch, outcome.attempts, outcome.faults,
                outcome.backoff_s))
        if not outcome.ok:
            return None, None
        return outcome.value

    # -- failover ----------------------------------------------------------

    def host_down(self, host, now: float) -> HostEvent:
        """A host fault domain died: redistribute its rigs over the
        survivors (``HostMap.host_down``) and gap their pose chains —
        migration is a stream gap exactly like a restart, so a moved
        rig's next frame reports identity + ``valid=False`` rather than
        chaining across the outage.  Supervisor state is untouched: the
        rigs themselves are healthy, they just live somewhere else now."""
        if self.host_map is None:
            raise ValueError(
                "FleetService.host_down needs a HostMap (pass host_map= "
                "at construction)")
        moved = self.host_map.host_down(host)
        for rig_id, _ in moved:
            self._loc_state.pop(rig_id, None)
        self.counters["host_down_events"] += 1
        self.counters["rigs_redistributed"] += len(moved)
        event = HostEvent("host_down", float(now), host, moved)
        self.events.append(event)
        return event

    def status(self, now: float) -> dict:
        """Structured service snapshot: supervisor report + queue depth
        + intake/serve counters (queue-side drop/lateness tallies are
        mirrored into ``counters`` so one dict answers "what did we
        lose"), plus host placement when a ``HostMap`` is attached."""
        out = {
            "supervisor": self.supervisor.status_report(now),
            "queue": {"pending": self.queue.pending(),
                      "oldest_wait_s": self.queue.oldest_wait(now),
                      "dropped_overflow": self.queue.dropped_overflow},
            "counters": {**dict(self.counters),
                         "dropped_overflow": self.queue.dropped_overflow},
        }
        if self.host_map is not None:
            out["hosts"] = self.host_map.status()
        return out


def wire_encode(output) -> dict:
    """Serialize one served output into the fleet uplink wire format
    (``repro.distributed.compression``): descriptors as lossless uint8
    bytes, match index/distance as uint16 with a no-match sentinel,
    float fields (xy, score, theta, disparity, depth) as int8+scale
    with bounded error, validity as packed bits — ~4x fewer payload
    bytes than shipping the f32 pytree.  A ``LocalizationOutput``
    additionally ships its rig-frame 3-D points and pose LOSSLESSLY
    (see ``compression.encode_pose``/``encode_points`` — the pose is
    the accuracy-gated product, so it rides uncompressed).  Use
    ``compression.wire_bytes`` on the result for the payload size."""
    if isinstance(output, LocalizationOutput):
        wire = wire_encode(output.stereo)
        wire["points"] = compression.encode_points(output.points)
        wire["pose"] = compression.encode_pose(output.pose)
        return wire
    return dict(
        features_l=compression.encode_features(output.features_l),
        features_r=compression.encode_features(output.features_r),
        matches=compression.encode_matches(output.matches),
        depth=compression.encode_depth(output.depth))


def wire_decode(wire: dict):
    """Inverse of ``wire_encode``.  Descriptors, match indices/
    distances (the kernels' BIG sentinel restored), validity masks,
    and — when present — 3-D points and pose round-trip bit-exact;
    quantized float fields come back within the int8+scale error bound
    (pinned in tests/test_precision.py).  Returns a
    ``LocalizationOutput`` when the wire dict carries a pose, else a
    ``StereoOutput``."""
    stereo = StereoOutput(
        features_l=compression.decode_features(wire["features_l"]),
        features_r=compression.decode_features(wire["features_r"]),
        matches=compression.decode_matches(
            wire["matches"], no_match_distance=ops.NO_MATCH_DIST),
        depth=compression.decode_depth(wire["depth"]))
    if "pose" in wire:
        return LocalizationOutput(
            stereo=stereo,
            points=compression.decode_points(wire["points"]),
            pose=compression.decode_pose(wire["pose"]))
    return stereo


class EpisodeResult(typing.NamedTuple):
    reports: list        # every RigReport, in service order
    events: list         # Supervisor/Dispatch/Host events, in order
    status: dict         # final FleetService.status snapshot
    recovery: dict | None = None   # kill-and-recover timing (crash_at)


def run_episode(service: FleetService, frames, dt: float = 1.0 / 30.0,
                t0: float = 0.0, rig_ids: typing.Sequence | None = None,
                injector: FaultInjector | None = None,
                settle_steps: int = 4,
                snapshot_dir: str | None = None, snapshot_keep: int = 3,
                crash_at: int | None = None,
                restore=None) -> EpisodeResult:
    """Drive a deterministic streaming episode on a virtual clock.

    ``frames``: (T, n_rigs, n_cameras, H, W).  Frame t of rig r nominally
    arrives at ``t0 + t * dt`` with trigger tags equal to the arrival
    time; the optional ``injector`` perturbs images/tags/arrival or
    withholds delivery per its specs (and its host-level specs fire
    here: ``host_down`` at its start frame, dispatch faults through the
    service's guard).  After the T arrival ticks, ``settle_steps``
    extra force-flushed ticks let watchdog timeouts, backoff restarts
    and the final partial batch play out.  The SAME driver feeds the
    fault-injection tests and the service/failover benchmarks, so
    "what CI verifies" and "what we measure" is one code path.

    Kill-and-recover: with ``snapshot_dir`` set, every tick ends in a
    crash-consistent ``serving.snapshot`` save; with ``crash_at=t`` the
    service object is DESTROYED after tick ``t`` and replaced by
    ``restore()`` (a zero-arg factory building a fresh, cold
    ``FleetService``) restored from the newest verifiable snapshot —
    through any ``corrupt_snapshot`` tearing the injector dictates.
    The episode then simply continues; ``result.recovery`` reports the
    restored step and the recovery wall clock.
    """
    if crash_at is not None and (restore is None or snapshot_dir is None):
        raise ValueError("crash_at requires restore= and snapshot_dir=")
    frames = np.asarray(frames)
    t_total, n_rigs = frames.shape[0], frames.shape[1]
    n_cameras = frames.shape[2]
    if rig_ids is None:
        rig_ids = tuple(range(n_rigs))
    service._dispatch_injector = injector
    reports: list[RigReport] = []
    pre_crash_events: list = []
    recovery = None
    for t in range(t_total):
        now = t0 + t * dt
        if injector is not None:
            for host in injector.hosts_down_at(t):
                service.host_down(host, now)
        for r in range(n_rigs):
            ts = np.full(n_cameras, now, dtype=np.float64)
            if injector is None:
                service.submit(rig_ids[r], frames[t, r], now, timestamps=ts)
                continue
            inj = injector.apply(rig_ids[r], t, frames[t, r], ts, now)
            if not inj.delivered:
                continue
            service.submit(rig_ids[r], inj.images, inj.t_arrival,
                           timestamps=inj.timestamps,
                           camera_mask=inj.camera_mask)
        reports.extend(service.step(now + 0.5 * dt))
        if snapshot_dir is not None:
            snapshot.save(service, snapshot_dir, step=t, keep=snapshot_keep)
        if crash_at is not None and t == crash_at:
            pre_crash_events = list(service.events)
            torn = (injector.snapshot_corruption(t)
                    if injector is not None else None)
            if torn is not None:
                snapshot.corrupt_newest(snapshot_dir, torn["leaf_index"],
                                        torn["keep_fraction"])
            wall = time.perf_counter()
            service = restore()
            restored_step = snapshot.restore(service, snapshot_dir)
            wall = time.perf_counter() - wall
            service._dispatch_injector = injector
            recovery = {
                "crash_at": int(t),
                "restored_step": restored_step,
                "recovery_wall_s": float(wall),
                "snapshot_fallback": bool(restored_step is not None
                                          and restored_step < t),
            }
    for k in range(settle_steps):
        now = t0 + (t_total + k) * dt
        reports.extend(service.step(now, force=True))
    final = t0 + (t_total + settle_steps) * dt
    return EpisodeResult(reports, pre_crash_events + list(service.events),
                         service.status(final), recovery)
