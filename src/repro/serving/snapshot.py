"""Crash-consistent service snapshots: ``FleetService`` recovery state.

A host crash must not cost more than the pose-chain gap the restart
semantics already define (PR 8): every OTHER piece of serving state —
the supervisor's restart ledger and flap budgets, quarantine flags,
buffered-but-unserved frames, per-rig localization memory, intake/serve
counters, the host placement map — survives byte-for-byte, so a fresh
``FleetService`` restored from the newest snapshot serves healthy rigs
BIT-EXACTLY as the uninterrupted service would have.  Poses are the one
deliberate exception: a crash is a stream gap, and a gap never chains
(the restored state keeps its descriptors/points but drops ``valid``,
so the first post-restore frame honestly reports identity +
``valid=False`` — exactly the restart rule).

Torn snapshots are a first-class input, not an error path: every leaf
is CRC-checksummed into the JSON manifest (itself a leaf), and
``load``/``restore`` walk steps newest -> oldest, skipping anything
truncated, unparseable, version-skewed or checksum-mismatched.  The
worst case of a crash DURING save is "recover from the previous step",
never "crash again on restore".

Storage rides ``repro.checkpoint.store`` (atomic tmp-dir + rename,
fsync-before-rename): the snapshot tree is ``{"meta": <json as uint8>,
"leaves": [arr, ...]}`` with the manifest naming every leaf's owner,
dtype, shape and CRC.
"""

from __future__ import annotations

import collections
import json
import os
import zlib

import numpy as np

from repro.checkpoint import store
from repro.core.types import LocalizationState
from repro.serving.queue import _Pending

SNAPSHOT_VERSION = 1

# LocalizationState field order — the per-rig leaf layout on the wire.
_STATE_FIELDS = LocalizationState._fields          # (desc, meta, points, valid)


# ---------------------------------------------------------------------------
# Rig/host ids: JSON round-trip without type laundering

def _encode_id(x) -> list:
    """Tag an id for JSON so ``1`` and ``"1"`` stay distinct rigs (the
    service accepts any hashable id; the snapshot supports the two that
    survive JSON honestly)."""
    if isinstance(x, bool) or not isinstance(x, (int, str)):
        raise TypeError(
            f"snapshot rig/host ids must be int or str, got {type(x).__name__}"
            f" ({x!r})")
    return ["int", int(x)] if isinstance(x, int) else ["str", x]


def _decode_id(pair):
    kind, v = pair
    return int(v) if kind == "int" else str(v)


# ---------------------------------------------------------------------------
# Leaf checksums

def _crc(arr: np.ndarray) -> int:
    """CRC over dtype + shape + bytes: a leaf whose contents survive but
    whose shape was reinterpreted still fails verification."""
    a = np.ascontiguousarray(arr)
    header = f"{a.dtype.str}|{a.shape}".encode()
    return zlib.crc32(a.tobytes(), zlib.crc32(header)) & 0xFFFFFFFF


# ---------------------------------------------------------------------------
# Capture

def _layout(service) -> dict:
    vs = service.vs
    return {"n_cameras": int(vs.rig.n_cameras),
            "n_pairs": int(vs.rig.n_pairs),
            "h": int(vs.pipe.orb.height), "w": int(vs.pipe.orb.width),
            "max_features": int(vs.pipe.orb.max_features),
            "dtype": np.dtype(service._frame_dtype).name,
            "localize": bool(vs.pipe.localize),
            "bucket_sizes": list(service.queue.cfg.bucket_sizes)}


def _capture(service) -> tuple[dict, list]:
    """The (manifest, leaves) pair for one service instant.  Leaf order:
    per localization rig (sorted by repr) the ``LocalizationState``
    fields, then per pending frame (queue order) images + camera_mask."""
    leaves: list[np.ndarray] = []

    sup_records = []
    for rec in service.supervisor.export_state():
        rec = dict(rec)
        rec["rig_id"] = _encode_id(rec["rig_id"])
        sup_records.append(rec)

    loc_rigs = sorted(service._loc_state, key=repr)
    for rid in loc_rigs:
        st = service._loc_state[rid]
        for field in _STATE_FIELDS:
            leaves.append(np.asarray(getattr(st, field)))

    pending = service.queue.export_pending()
    pending_records = []
    for p in pending:
        pending_records.append({"rig_id": _encode_id(p.rig_id),
                                "t_arrival": float(p.t_arrival)})
        leaves.append(np.asarray(p.images))
        leaves.append(np.asarray(p.camera_mask))

    host_map = getattr(service, "host_map", None)
    hm = None
    if host_map is not None:
        raw = host_map.export_state()
        hm = {"hosts": [_encode_id(h) for h in raw["hosts"]],
              "down": [_encode_id(h) for h in raw["down"]],
              "assignment": [[_encode_id(r), _encode_id(h)]
                             for r, h in raw["assignment"]]}

    meta = {
        "version": SNAPSHOT_VERSION,
        "layout": _layout(service),
        "supervisor": sup_records,
        "counters": dict(service.counters),
        "queue": {"dropped_overflow": int(service.queue.dropped_overflow)},
        "loc_rigs": [_encode_id(r) for r in loc_rigs],
        "pending": pending_records,
        "host_map": hm,
        "n_leaves": len(leaves),
        "leaf_crcs": [_crc(a) for a in leaves],
    }
    return meta, leaves


def save(service, ckpt_dir: str, step: int, keep: int = 3) -> str:
    """Snapshot ``service`` as checkpoint ``step`` (atomic, fsync'd,
    keeping the newest ``keep`` steps as fallback candidates)."""
    meta, leaves = _capture(service)
    meta_arr = np.frombuffer(json.dumps(meta).encode(), np.uint8).copy()
    return store.save(ckpt_dir, step, {"meta": meta_arr, "leaves": leaves},
                      keep=keep)


# ---------------------------------------------------------------------------
# Load (with torn-snapshot fallback)

def _load_step(ckpt_dir: str, step: int) -> tuple[dict, list]:
    """Load + verify one step; raises on ANY inconsistency (missing or
    truncated files, bad JSON, version skew, CRC mismatch) — ``load``
    turns that into fallback."""
    flat = store.load_flat(ckpt_dir, step)
    meta = json.loads(bytes(flat["meta"]).decode())
    if meta.get("version") != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot version {meta.get('version')} != "
                         f"{SNAPSHOT_VERSION}")
    n = int(meta["n_leaves"])
    leaves = [flat[f"leaves{store._SEP}{i}"] for i in range(n)]
    for i, (arr, want) in enumerate(zip(leaves, meta["leaf_crcs"])):
        got = _crc(arr)
        if got != int(want):
            raise ValueError(f"snapshot leaf {i} checksum mismatch "
                             f"({got:#x} != {int(want):#x})")
    return meta, leaves


def load(ckpt_dir: str) -> tuple[int, dict, list] | None:
    """The newest VERIFIABLE snapshot, walking steps newest -> oldest
    past torn/corrupt ones.  None when no step survives scrutiny."""
    for step in reversed(store.list_steps(ckpt_dir)):
        try:
            meta, leaves = _load_step(ckpt_dir, step)
        except Exception:       # noqa: BLE001 — any tear means "older step"
            continue
        return step, meta, leaves
    return None


# ---------------------------------------------------------------------------
# Restore

def restore(service, ckpt_dir: str) -> int | None:
    """Load the newest verifiable snapshot into a (fresh) ``service``.

    Returns the restored step, or None when no snapshot survived
    verification (the service then simply starts cold — never raises
    for corruption).  A LAYOUT mismatch does raise: restoring rig-A
    state into a rig-B service is a caller bug, not a torn write.

    Localization states come back with ``valid`` dropped — the
    pose-chain gap rule: a crash is a stream gap, and the first frame a
    restored rig serves must report identity + ``valid=False`` exactly
    like a post-restart frame, not silently chain across the outage."""
    loaded = load(ckpt_dir)
    if loaded is None:
        return None
    step, meta, leaves = loaded

    want = _layout(service)
    if meta["layout"] != want:
        raise ValueError(
            f"snapshot layout {meta['layout']} does not match the "
            f"service layout {want} — refusing to restore across rig "
            "geometries")

    sup_records = []
    for rec in meta["supervisor"]:
        rec = dict(rec)
        rec["rig_id"] = _decode_id(rec["rig_id"])
        sup_records.append(rec)
    service.supervisor.restore_state(sup_records)

    service.counters = collections.Counter(
        {k: int(v) for k, v in meta["counters"].items()})

    i = 0
    service._loc_state = {}
    for enc in meta["loc_rigs"]:
        fields = dict(zip(_STATE_FIELDS, leaves[i:i + len(_STATE_FIELDS)]))
        i += len(_STATE_FIELDS)
        fields["valid"] = np.zeros_like(fields["valid"])
        service._loc_state[_decode_id(enc)] = LocalizationState(**fields)

    items = []
    for rec in meta["pending"]:
        images, camera_mask = leaves[i], leaves[i + 1]
        i += 2
        items.append(_Pending(_decode_id(rec["rig_id"]), images,
                              float(rec["t_arrival"]), camera_mask))
    service.queue.restore_pending(
        items, dropped_overflow=meta["queue"]["dropped_overflow"])

    hm = meta.get("host_map")
    if hm is not None and getattr(service, "host_map", None) is not None:
        service.host_map.restore_state(
            {"hosts": [_decode_id(h) for h in hm["hosts"]],
             "down": [_decode_id(h) for h in hm["down"]],
             "assignment": [[_decode_id(r), _decode_id(h)]
                            for r, h in hm["assignment"]]})
    return step


# ---------------------------------------------------------------------------
# Deterministic corruption (fault injection / torn-write tests)

def corrupt_newest(ckpt_dir: str, leaf_index: int,
                   keep_fraction: float) -> str | None:
    """Truncate one data file of the NEWEST snapshot step — the
    reproducible stand-in for a torn write (power loss mid-flush).
    ``leaf_index`` picks among the step's ``.npy`` files (mod count, in
    sorted name order); ``keep_fraction`` of the bytes survive.
    Returns the truncated path (None when there is nothing to tear)."""
    steps = store.list_steps(ckpt_dir)
    if not steps:
        return None
    d = os.path.join(ckpt_dir, f"step_{steps[-1]:08d}")
    files = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    if not files:
        return None
    path = os.path.join(d, files[leaf_index % len(files)])
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(int(size * float(keep_fraction)))
    return path
