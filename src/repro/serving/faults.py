"""Deterministic fault injection for the streaming fleet service.

Every failure mode the service claims to survive gets a reproducible
trigger: a ``FaultSpec`` names a rig, a frame window and a fault kind,
and ``FaultInjector.apply`` perturbs that rig's frames accordingly —
pure function of (specs, seed, rig, frame index), no wall clock, no
global RNG — so an episode replays bit-identically and tests can pin
healthy-rig outputs bit-exact against a no-fault run.

Fault kinds (who detects them is part of the contract):

  ``dead_camera``    slab zeroed AND reported dead in the driver-level
                     ``camera_mask`` (a real driver knows its camera
                     died) -> core degrades to surviving pairs.
  ``corrupt_frame``  slab filled with NaN, mask says HEALTHY — the
                     service's finite-check must catch it.
  ``stalled_rig``    the frame is never delivered -> the supervisor's
                     heartbeat timeout must catch it.
  ``desync``         one camera's trigger tag drifts by ``magnitude``
                     seconds -> the rig's desync policy must catch it.
  ``arrival_jitter`` delivery time skews (deterministic per-frame
                     half-normal, scale ``magnitude``) -> exercises
                     queue deadlines/bucketing, not a fault per se.
"""

from __future__ import annotations

import dataclasses
import typing
import zlib

import numpy as np

_KINDS = ("dead_camera", "corrupt_frame", "stalled_rig", "desync",
          "arrival_jitter")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: ``kind`` applied to ``rig`` for frame indices in
    [``start``, ``stop``) (``stop=None`` = forever).  ``camera`` selects
    the slab for dead_camera/corrupt_frame/desync; ``magnitude`` is the
    desync offset / jitter scale in seconds."""

    kind: str
    rig: typing.Any
    start: int = 0
    stop: int | None = None
    camera: int = 0
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.stop is not None and self.stop <= self.start:
            raise ValueError(f"empty fault window [{self.start}, {self.stop})")

    def active(self, frame_index: int) -> bool:
        return (frame_index >= self.start
                and (self.stop is None or frame_index < self.stop))


class InjectedFrame(typing.NamedTuple):
    """``apply``'s output: the (possibly perturbed) frame plus what the
    DRIVER layer would know.  ``camera_mask`` only reflects faults a
    real driver reports (dead_camera) — corruption and desync must be
    caught downstream.  ``delivered=False`` means the frame never
    reaches the service (stall)."""

    images: np.ndarray
    timestamps: np.ndarray
    t_arrival: float
    delivered: bool
    camera_mask: np.ndarray
    faults: tuple[str, ...]


class FaultInjector:
    """Applies the active subset of ``specs`` to each (rig, frame).

    ``clear_rig`` disables every spec targeting a rig — the restart
    hook: point ``Supervisor.restart_cb`` here and a watchdog restart
    actually heals the fault, closing the detect -> restart -> recover
    loop deterministically."""

    def __init__(self, specs: typing.Sequence[FaultSpec],
                 seed: int = 0) -> None:
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._disabled: set[int] = set()

    def clear_rig(self, rig_id) -> int:
        """Disable all specs for ``rig_id``; returns how many."""
        hit = [i for i, s in enumerate(self.specs)
               if s.rig == rig_id and i not in self._disabled]
        self._disabled.update(hit)
        return len(hit)

    def active_faults(self, rig_id, frame_index: int) -> tuple[str, ...]:
        return tuple(s.kind for i, s in enumerate(self.specs)
                     if i not in self._disabled and s.rig == rig_id
                     and s.active(frame_index))

    def _rng(self, rig_id, frame_index: int) -> np.random.RandomState:
        key = [self.seed & 0xFFFFFFFF,
               zlib.crc32(repr(rig_id).encode()) & 0xFFFFFFFF,
               int(frame_index)]
        return np.random.RandomState(key)

    def apply(self, rig_id, frame_index: int, images, timestamps,
              t_arrival: float) -> InjectedFrame:
        im = np.array(images, dtype=np.float32, copy=True)
        ts = np.array(timestamps, dtype=np.float64, copy=True).reshape(-1)
        mask = np.ones(im.shape[0], dtype=bool)
        t = float(t_arrival)
        delivered = True
        applied: list[str] = []
        for i, s in enumerate(self.specs):
            if i in self._disabled or s.rig != rig_id \
                    or not s.active(frame_index):
                continue
            applied.append(s.kind)
            if s.kind == "dead_camera":
                im[s.camera] = 0.0
                mask[s.camera] = False
            elif s.kind == "corrupt_frame":
                im[s.camera] = np.nan
            elif s.kind == "stalled_rig":
                delivered = False
            elif s.kind == "desync":
                ts[s.camera] += s.magnitude
            elif s.kind == "arrival_jitter":
                t += abs(self._rng(rig_id, frame_index)
                         .normal(0.0, s.magnitude))
        return InjectedFrame(im, ts, t, delivered, mask, tuple(applied))
