"""Deterministic fault injection for the streaming fleet service.

Every failure mode the service claims to survive gets a reproducible
trigger: a ``FaultSpec`` names a rig, a frame window and a fault kind,
and ``FaultInjector.apply`` perturbs that rig's frames accordingly —
pure function of (specs, seed, rig, frame index), no wall clock, no
global RNG — so an episode replays bit-identically and tests can pin
healthy-rig outputs bit-exact against a no-fault run.

Fault kinds (who detects them is part of the contract):

  ``dead_camera``    slab zeroed AND reported dead in the driver-level
                     ``camera_mask`` (a real driver knows its camera
                     died) -> core degrades to surviving pairs.
  ``corrupt_frame``  slab filled with NaN, mask says HEALTHY — the
                     service's finite-check must catch it.
  ``stalled_rig``    the frame is never delivered -> the supervisor's
                     heartbeat timeout must catch it.
  ``desync``         one camera's trigger tag drifts by ``magnitude``
                     seconds -> the rig's desync policy must catch it.
  ``arrival_jitter`` delivery time skews (deterministic per-frame
                     half-normal, scale ``magnitude``) -> exercises
                     queue deadlines/bucketing, not a fault per se.

Host-level faults (PR 9 — the failure domain is the serving host, not
a rig; all still pure functions of (spec, seed, frame)):

  ``host_down``        ``rig`` names the HOST fault domain; fires once
                       at ``start`` -> ``FleetService.host_down``
                       redistributes its rigs over the survivors.
  ``stuck_dispatch``   the guarded ``step`` compute stalls past the
                       ``DispatchGuard`` timeout for the first
                       ``int(magnitude)`` attempts of every dispatch
                       in the window -> counted stall + retry, never a
                       wedged loop.
  ``dispatch_error``   same windowing, but the compute raises ->
                       counted error + deterministic backoff retry.
  ``corrupt_snapshot`` the newest service snapshot is torn
                       (deterministically truncated) before a
                       kill-and-recover restore -> the restore must
                       fall back to the previous step, never crash.
"""

from __future__ import annotations

import dataclasses
import typing
import zlib

import numpy as np

_KINDS = ("dead_camera", "corrupt_frame", "stalled_rig", "desync",
          "arrival_jitter",
          "host_down", "stuck_dispatch", "dispatch_error",
          "corrupt_snapshot")

# Kinds that perturb one rig's frames in `apply` (vs the host-level
# kinds queried by the service/episode driver directly).
_FRAME_KINDS = ("dead_camera", "corrupt_frame", "stalled_rig", "desync",
                "arrival_jitter")
_DISPATCH_KINDS = ("stuck_dispatch", "dispatch_error")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: ``kind`` applied to ``rig`` for frame indices in
    [``start``, ``stop``) (``stop=None`` = forever).  ``camera`` selects
    the slab for dead_camera/corrupt_frame/desync; ``magnitude`` is the
    desync offset / jitter scale in seconds — or, for the dispatch
    kinds, the number of consecutive failing attempts per dispatch.

    For ``host_down`` the ``rig`` field names the HOST fault domain
    (``launch.mesh.host_fault_domains`` id); for the dispatch and
    snapshot kinds ``rig`` is unused (the fault hits the whole host)."""

    kind: str
    rig: typing.Any = None
    start: int = 0
    stop: int | None = None
    camera: int = 0
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.stop is not None and self.stop <= self.start:
            raise ValueError(f"empty fault window [{self.start}, {self.stop})")
        if self.kind in _FRAME_KINDS + ("host_down",) and self.rig is None:
            raise ValueError(
                f"{self.kind!r} needs a target: rig id for frame faults, "
                "host domain id for host_down")

    def active(self, frame_index: int) -> bool:
        return (frame_index >= self.start
                and (self.stop is None or frame_index < self.stop))


class InjectedFrame(typing.NamedTuple):
    """``apply``'s output: the (possibly perturbed) frame plus what the
    DRIVER layer would know.  ``camera_mask`` only reflects faults a
    real driver reports (dead_camera) — corruption and desync must be
    caught downstream.  ``delivered=False`` means the frame never
    reaches the service (stall)."""

    images: np.ndarray
    timestamps: np.ndarray
    t_arrival: float
    delivered: bool
    camera_mask: np.ndarray
    faults: tuple[str, ...]


class FaultInjector:
    """Applies the active subset of ``specs`` to each (rig, frame).

    ``clear_rig`` disables every spec targeting a rig — the restart
    hook: point ``Supervisor.restart_cb`` here and a watchdog restart
    actually heals the fault, closing the detect -> restart -> recover
    loop deterministically."""

    def __init__(self, specs: typing.Sequence[FaultSpec],
                 seed: int = 0) -> None:
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._disabled: set[int] = set()

    def clear_rig(self, rig_id) -> int:
        """Disable all specs for ``rig_id``; returns how many."""
        hit = [i for i, s in enumerate(self.specs)
               if s.rig == rig_id and i not in self._disabled]
        self._disabled.update(hit)
        return len(hit)

    def active_faults(self, rig_id, frame_index: int) -> tuple[str, ...]:
        return tuple(s.kind for i, s in enumerate(self.specs)
                     if i not in self._disabled and s.rig == rig_id
                     and s.active(frame_index))

    def _rng(self, rig_id, frame_index: int) -> np.random.RandomState:
        key = [self.seed & 0xFFFFFFFF,
               zlib.crc32(repr(rig_id).encode()) & 0xFFFFFFFF,
               int(frame_index)]
        return np.random.RandomState(key)

    def apply(self, rig_id, frame_index: int, images, timestamps,
              t_arrival: float) -> InjectedFrame:
        im = np.array(images, dtype=np.float32, copy=True)
        ts = np.array(timestamps, dtype=np.float64, copy=True).reshape(-1)
        mask = np.ones(im.shape[0], dtype=bool)
        t = float(t_arrival)
        delivered = True
        applied: list[str] = []
        for i, s in enumerate(self.specs):
            if i in self._disabled or s.kind not in _FRAME_KINDS \
                    or s.rig != rig_id or not s.active(frame_index):
                continue
            applied.append(s.kind)
            if s.kind == "dead_camera":
                im[s.camera] = 0.0
                mask[s.camera] = False
            elif s.kind == "corrupt_frame":
                im[s.camera] = np.nan
            elif s.kind == "stalled_rig":
                delivered = False
            elif s.kind == "desync":
                ts[s.camera] += s.magnitude
            elif s.kind == "arrival_jitter":
                t += abs(self._rng(rig_id, frame_index)
                         .normal(0.0, s.magnitude))
        return InjectedFrame(im, ts, t, delivered, mask, tuple(applied))

    # -- host-level faults (queried, not applied to frames) ----------------

    def hosts_down_at(self, frame_index: int) -> tuple:
        """Host fault domains whose ``host_down`` spec STARTS at this
        frame — a host dies once, so the event fires exactly at
        ``start`` (the window end is irrelevant)."""
        return tuple(s.rig for i, s in enumerate(self.specs)
                     if i not in self._disabled and s.kind == "host_down"
                     and s.start == frame_index)

    def dispatch_fault(self, dispatch_index: int, attempt: int
                       ) -> str | None:
        """What the guarded dispatch sees on ``attempt`` (1-based) of
        dispatch ordinal ``dispatch_index``: ``"stall"``, ``"error"``
        or None.  A spec fails the first ``int(magnitude)`` attempts of
        every dispatch in its window, so retries deterministically
        recover when the guard's budget exceeds the fault's depth —
        pure function of (specs, frame, attempt), no RNG needed."""
        for i, s in enumerate(self.specs):
            if i in self._disabled or s.kind not in _DISPATCH_KINDS \
                    or not s.active(dispatch_index):
                continue
            if attempt <= max(1, int(s.magnitude)):
                return "stall" if s.kind == "stuck_dispatch" else "error"
        return None

    def snapshot_corruption(self, frame_index: int) -> dict | None:
        """Deterministic torn-snapshot parameters for a crash at
        ``frame_index`` (None when no ``corrupt_snapshot`` spec is
        active): which leaf file to tear and how much of it to keep,
        drawn from the same seeded (spec, frame) stream as every other
        fault."""
        for i, s in enumerate(self.specs):
            if i in self._disabled or s.kind != "corrupt_snapshot" \
                    or not s.active(frame_index):
                continue
            rng = self._rng("snapshot", frame_index)
            return {"leaf_index": int(rng.randint(0, 1 << 30)),
                    "keep_fraction": float(0.1 + 0.7 * rng.uniform())}
        return None
