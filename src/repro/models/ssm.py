"""Mamba2 (SSD — state-space duality) block, chunked scan + decode step.

Faithful structure: in_proj -> causal depthwise conv (x, B, C) -> SSD
with scalar-per-head decay -> D skip -> gated RMSNorm -> out_proj.
The chunked algorithm computes intra-chunk contributions as a masked
attention-like quadratic form and carries the (H, N, P) state across
chunks with a ``lax.scan`` — O(S * Q) instead of O(S^2), and the decode
step is the O(1) recurrence  h <- a h + dt B x^T;  y = C . h + D x.

Simplification vs the reference CUDA code (documented in DESIGN.md):
the fused in_proj is split into per-stream weights (z, x, B, C, dt) —
mathematically identical, and it lets each stream carry its own logical
sharding axes (d_inner shards over "model"; the small B/C streams stay
replicated, ngroups = 1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import P


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    d_state: int                  # N
    head_dim: int = 64            # P
    expand: int = 2
    conv: int = 4                 # causal depthwise kernel size
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    # bf16 intra-chunk operands with f32 einsum accumulation — the
    # (B,Q,Q,H) decay/weight tensors dominate SSD memory traffic; this
    # is what a fused TPU kernel does (bf16 in VMEM, f32 in the MXU)
    intra_bf16: bool = True

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def schema(s: SSMSpec) -> dict:
    d, di, n, h, k = s.d_model, s.d_inner, s.d_state, s.n_heads, s.conv
    return {
        "wz": P((d, di), ("embed", "conv_dim")),
        "wx": P((d, di), ("embed", "conv_dim")),
        "wB": P((d, n), ("embed", "ssm_state")),
        "wC": P((d, n), ("embed", "ssm_state")),
        "wdt": P((d, h), ("embed", "ssm_heads")),
        "dt_bias": P((h,), ("ssm_heads",), init="zeros"),
        "A_log": P((h,), ("ssm_heads",), init="zeros"),
        "D": P((h,), ("ssm_heads",), init="ones"),
        "conv_x": P((k, di), (None, "conv_dim"), scale=k ** -0.5),
        "conv_B": P((k, n), (None, "ssm_state"), scale=k ** -0.5),
        "conv_C": P((k, n), (None, "ssm_state"), scale=k ** -0.5),
        "conv_bx": P((di,), ("conv_dim",), init="zeros"),
        "conv_bB": P((n,), ("ssm_state",), init="zeros"),
        "conv_bC": P((n,), ("ssm_state",), init="zeros"),
        "norm": layers.rmsnorm_schema(di),
        "wo": P((di, d), ("conv_dim", "embed")),
    }


def _conv_full(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Causal depthwise conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i].astype(u.dtype)
              for i in range(k))
    return out + b.astype(u.dtype)


def _streams(params, x: jnp.ndarray, s: SSMSpec):
    """Project and activate the five streams for a full sequence.

    Also returns the conv ring buffers (last K-1 *raw* inputs of each
    conv'd stream) so prefill can hand decode a warm state."""
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(x.dtype))
    z = jnp.einsum("bsd,di->bsi", x, params["wz"].astype(x.dtype))
    xs_raw = jnp.einsum("bsd,di->bsi", x, params["wx"].astype(x.dtype))
    bs_raw = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(x.dtype))
    cs_raw = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(x.dtype))
    xs = jax.nn.silu(_conv_full(xs_raw, params["conv_x"],
                                params["conv_bx"]))
    bs = jax.nn.silu(_conv_full(bs_raw, params["conv_B"],
                                params["conv_bB"]))
    cs = jax.nn.silu(_conv_full(cs_raw, params["conv_C"],
                                params["conv_bC"]))
    xs = constrain(xs, "batch", "seq", "conv_dim")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    log_a = (-dt * jnp.exp(params["A_log"].astype(jnp.float32)))
    k = s.conv
    raw_tail = {"conv_x": xs_raw[:, -(k - 1):, :],
                "conv_B": bs_raw[:, -(k - 1):, :],
                "conv_C": cs_raw[:, -(k - 1):, :]}
    return z, xs, bs, cs, dt, log_a, raw_tail


def ssd_scan(xs, bs, cs, dt, log_a, s: SSMSpec, h0=None):
    """Chunked SSD.  xs: (B, S, H, P) f32; bs/cs: (B, S, N) f32;
    dt/log_a: (B, S, H) f32.  Returns (y (B, S, H, P), h_final)."""
    b, seq, h, p = xs.shape
    n = bs.shape[-1]
    q = min(s.chunk, seq)
    assert seq % q == 0, (seq, q)
    nc = seq // q

    def split(t):
        return jnp.moveaxis(t.reshape(b, nc, q, *t.shape[2:]), 1, 0)

    xs_c, bs_c, cs_c, dt_c, la_c = map(split, (xs, bs, cs, dt, log_a))
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    cdt = jnp.bfloat16 if s.intra_bf16 else jnp.float32

    def body(carry, xc):
        hs = carry
        x_, b_, c_, dt_, la_ = xc                     # (B,Q,...)
        acum = jnp.cumsum(la_, axis=1)                # (B, Q, H) inclusive
        xl = x_.astype(cdt)
        # intra-chunk: w[i,j,h] = (C_i . B_j) exp(acum_i - acum_j) dt_j
        cb = jnp.einsum("bin,bjn->bij", c_.astype(cdt), b_.astype(cdt),
                        preferred_element_type=jnp.float32)
        decay = jnp.exp(acum[:, :, None, :]
                        - acum[:, None, :, :]).astype(cdt)
        mask = jnp.tril(jnp.ones((q, q), bool))
        w = cb[..., None].astype(cdt) * decay \
            * dt_[:, None, :, :].astype(cdt)
        w = jnp.where(mask[None, :, :, None], w, jnp.zeros((), cdt))
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xl,
                             preferred_element_type=jnp.float32)
        # inter-chunk: y_i += exp(acum_i) C_i . h_prev
        y_inter = jnp.einsum("bin,bhnp->bihp", c_, hs) \
            * jnp.exp(acum)[..., None]
        # state update: h <- exp(acum_Q) h + sum_j exp(acum_Q - acum_j)
        #                                        dt_j B_j x_j^T
        tot = acum[:, -1, :]                          # (B, H)
        sdecay = jnp.exp(tot[:, None, :] - acum)      # (B, Q, H)
        s_c = jnp.einsum("bjh,bjn,bjhp->bhnp",
                         (sdecay * dt_).astype(cdt), b_.astype(cdt), xl,
                         preferred_element_type=jnp.float32)
        h_new = jnp.exp(tot)[:, :, None, None] * hs + s_c
        return h_new, y_intra + y_inter

    h_fin, ys = jax.lax.scan(body, h0, (xs_c, bs_c, cs_c, dt_c, la_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, seq, h, p)
    return y, h_fin


def _apply(params, x, s: SSMSpec, rms_eps: float, want_state: bool):
    z, xs, bs, cs, dt, log_a, raw_tail = _streams(params, x, s)
    b, seq, _ = x.shape
    xh = xs.astype(jnp.float32).reshape(b, seq, s.n_heads, s.head_dim)
    y, h_fin = ssd_scan(xh, bs.astype(jnp.float32), cs.astype(jnp.float32),
                        dt, log_a, s)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, seq, s.d_inner).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z), eps=rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(x.dtype))
    out = constrain(out, "batch", "res_seq", "act_embed")
    if not want_state:
        return out, None
    state = {"h": h_fin, **raw_tail}
    return out, state


def full_layer(params, x: jnp.ndarray, s: SSMSpec,
               rms_eps: float = 1e-6) -> jnp.ndarray:
    """Full-sequence Mamba2 block (train)."""
    return _apply(params, x, s, rms_eps, want_state=False)[0]


def full_layer_with_state(params, x: jnp.ndarray, s: SSMSpec,
                          rms_eps: float = 1e-6):
    """Prefill: full-sequence block that also returns the decode state
    (final SSD state + conv ring buffers of the last K-1 raw inputs)."""
    return _apply(params, x, s, rms_eps, want_state=True)


def init_state(batch: int, s: SSMSpec, dtype=jnp.float32):
    """Decode state: SSD state + conv ring buffers (last K-1 inputs)."""
    return {
        "h": jnp.zeros((batch, s.n_heads, s.d_state, s.head_dim), dtype),
        "conv_x": jnp.zeros((batch, s.conv - 1, s.d_inner), dtype),
        "conv_B": jnp.zeros((batch, s.conv - 1, s.d_state), dtype),
        "conv_C": jnp.zeros((batch, s.conv - 1, s.d_state), dtype),
    }


def decode_layer(params, x_tok: jnp.ndarray, state: dict, s: SSMSpec,
                 rms_eps: float = 1e-6):
    """One-token decode.  x_tok: (B, 1, d).  Returns (y, new_state)."""
    b = x_tok.shape[0]
    x1 = x_tok[:, 0, :]
    dt_raw = x1 @ params["wdt"].astype(x1.dtype)
    z = x1 @ params["wz"].astype(x1.dtype)
    xs = x1 @ params["wx"].astype(x1.dtype)
    bs = x1 @ params["wB"].astype(x1.dtype)
    cs = x1 @ params["wC"].astype(x1.dtype)

    def conv_step(buf, u, w, bias):
        # buf: (B, K-1, C) past inputs; returns (act, new_buf)
        k = w.shape[0]
        hist = jnp.concatenate([buf, u[:, None, :]], axis=1)  # (B, K, C)
        out = sum(hist[:, i, :] * w[i].astype(u.dtype) for i in range(k))
        return jax.nn.silu(out + bias.astype(u.dtype)), hist[:, 1:, :]

    xs, cx = conv_step(state["conv_x"], xs, params["conv_x"],
                       params["conv_bx"])
    bs, cb = conv_step(state["conv_B"], bs, params["conv_B"],
                       params["conv_bB"])
    cs, cc = conv_step(state["conv_C"], cs, params["conv_C"],
                       params["conv_bC"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt * jnp.exp(params["A_log"].astype(jnp.float32)))
    xh = xs.astype(jnp.float32).reshape(b, s.n_heads, s.head_dim)
    h = state["h"]
    h_new = (a[:, :, None, None] * h
             + jnp.einsum("bh,bn,bhp->bhnp", dt, bs.astype(jnp.float32),
                          xh))
    y = jnp.einsum("bn,bhnp->bhp", cs.astype(jnp.float32), h_new)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, s.d_inner).astype(x_tok.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z), eps=rms_eps)
    out = (y @ params["wo"].astype(y.dtype))[:, None, :]
    new_state = {"h": h_new, "conv_x": cx, "conv_B": cb, "conv_C": cc}
    return constrain(out, "batch", "res_seq", "act_embed"), new_state
