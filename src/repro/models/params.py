"""Declarative parameter schemas.

A model config produces a *schema*: a nested dict whose leaves are ``P``
entries (shape + logical axes + init).  Parameter trees, logical-axis
trees and sharding-spec trees are all derived from the one schema, so
they can never drift apart.  Scan-stacked (per-layer) parameters carry a
leading "layers" axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_ctx, resolve


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]            # logical axis names, len == ndim
    init: str = "normal"             # normal | zeros | ones | embed
    scale: float | None = None       # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: the last axis is the output axis of a weight
    return max(1, int(jnp.prod(jnp.asarray(shape[:-1]))) or 1)


def _init_leaf(key: jax.Array, p: P, dtype) -> jnp.ndarray:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 1.0
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    std = p.scale if p.scale is not None else _fan_in(p.shape) ** -0.5
    return (std * jax.random.normal(key, p.shape)).astype(dtype)


def _walk(schema: Mapping, fn: Callable[[str, P], Any], prefix="") -> dict:
    out = {}
    for k, v in schema.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, P):
            out[k] = fn(path, v)
        else:
            out[k] = _walk(v, fn, path)
    return out


def init_params(schema: Mapping, key: jax.Array, dtype=jnp.float32) -> dict:
    """Deterministic init: each leaf keyed by fold_in(hash(path))."""

    def leaf(path: str, p: P):
        k = jax.random.fold_in(key, hash(path) & 0x7FFFFFFF)
        return _init_leaf(k, p, dtype)

    return _walk(schema, leaf)


def abstract_params(schema: Mapping, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct tree (for AOT lowering without allocation)."""
    return _walk(schema, lambda _, p: jax.ShapeDtypeStruct(p.shape, dtype))


def logical_axes(schema: Mapping) -> dict:
    return _walk(schema, lambda _, p: p.axes)


def param_specs(schema: Mapping) -> dict:
    """PartitionSpec tree under the installed sharding context."""
    ctx = current_ctx()
    assert ctx is not None

    def leaf(_, p: P):
        return resolve(ctx.rules.params, p.axes, p.shape, ctx.mesh)

    return _walk(schema, leaf)


def count_params(schema: Mapping) -> int:
    total = 0

    def leaf(_, p: P):
        nonlocal total
        n = 1
        for s in p.shape:
            n *= s
        total += n
        return None

    _walk(schema, leaf)
    return total


def stack_layers(n: int, sub: Mapping) -> dict:
    """Prefix every leaf of a per-layer schema with a 'layers' axis."""

    def leaf(_, p: P):
        return P(shape=(n, *p.shape), axes=("layers", *p.axes),
                 init=p.init, scale=p.scale)

    return _walk(sub, leaf)
