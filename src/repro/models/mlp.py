"""Feed-forward blocks: gated MLP (SwiGLU / GeGLU) and token-choice MoE.

MoE is the TPU-native static-shape dispatch: top-k routing -> capacity-
bounded slotting (scatter token indices into an (E, C) slot table) ->
per-expert batched matmuls (E-sharded) -> weighted scatter-add combine.
FLOPs scale with ACTIVE experts (top_k), not total experts, unlike the
dense one-hot dispatch einsum.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import P


# ---------------------------------------------------------------------------
# Dense gated MLP

def mlp_schema(d_model: int, d_ff: int) -> dict:
    return {
        "wi_gate": P((d_model, d_ff), ("embed", "ffn")),
        "wi_up": P((d_model, d_ff), ("embed", "ffn")),
        "wo": P((d_ff, d_model), ("ffn", "embed")),
    }


def mlp(params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    f = layers.act_fn(act)
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(x.dtype))
    h = constrain(f(g) * u, "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
    return constrain(y, "batch", "res_seq", "act_embed")


# ---------------------------------------------------------------------------
# Mixture of Experts

@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int                 # per-expert intermediate size
    n_experts: int
    top_k: int
    n_shared: int = 0         # always-active shared experts (fused as one)
    capacity_factor: float = 1.25
    act: str = "silu"
    impl: str = "a2a"         # a2a (shard_map EP) | gather (SPMD einsum)

    def capacity(self, tokens: int) -> int:
        c = int(self.capacity_factor * self.top_k * tokens
                / self.n_experts)
        return max(8, ((c + 7) // 8) * 8)    # pad for lane alignment


def moe_schema(s: MoESpec) -> dict:
    e, d, f = s.n_experts, s.d_model, s.d_ff
    out = {
        "router": P((d, e), ("embed", "experts"), scale=d ** -0.5),
        "wi_gate": P((e, d, f), ("experts", "embed", "ffn")),
        "wi_up": P((e, d, f), ("experts", "embed", "ffn")),
        "wo": P((e, f, d), ("experts", "ffn", "embed")),
    }
    if s.n_shared:
        out["shared"] = mlp_schema(d, s.n_shared * f)
    return out


def router_probs(params, x: jnp.ndarray, s: MoESpec):
    """Top-k routing.  Returns (expert_idx (T, k), gates (T, k), aux_loss)
    where T = B * S and gates renormalize over the selected k."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, s.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx[:, 0], s.n_experts)   # top-1 assignment
    ce = jnp.mean(one_hot, axis=0)
    aux = s.n_experts * jnp.sum(me * ce)
    return idx, gates.astype(x.dtype), aux


def moe_a2a(params, x: jnp.ndarray, s: MoESpec):
    """Expert parallelism via shard_map + all_to_all (the GShard/Switch
    TPU pattern).

    Tokens stay where they live (batch over (pod, data), seq over
    model); each device routes its LOCAL tokens into per-expert slot
    blocks, one all_to_all over the `model` axis moves each block to
    its expert's owner, the expert FFN runs data-parallel, and the
    reverse all_to_all brings outputs home for a local combine.  Wire
    cost per device ~= 2 x (k x T_local x d) instead of the SPMD
    gather's all-gather of the full global slot tensor (~16x less),
    and expert compute is data-parallel instead of replicated.

    Expert weights are FSDP-sharded on their d_model dim; they are
    gathered per layer over `data` in bf16 (half the wire of the f32
    gathers XLA emits for the einsum formulation).
    """
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Ps

    from repro.distributed.sharding import current_ctx, resolve

    ctx = current_ctx()
    if ctx is None:
        return _moe_gather(params, x, s)   # un-meshed (smoke/CPU)
    mesh = ctx.mesh
    sizes = dict(mesh.shape)
    tp = sizes.get("model", 1)
    if s.n_experts % max(tp, 1) != 0 or tp == 1:
        return _moe_gather(params, x, s)

    x_spec = resolve(ctx.rules.acts, ("batch", "res_seq", "act_embed"),
                     x.shape, mesh)
    r_spec = resolve(ctx.rules.params, ("embed", "experts"),
                     params["router"].shape, mesh)
    w_axes = ("experts", "embed", "ffn")
    wi_spec = resolve(ctx.rules.params, w_axes,
                      params["wi_gate"].shape, mesh)
    wo_spec = resolve(ctx.rules.params, ("experts", "ffn", "embed"),
                      params["wo"].shape, mesh)
    seq_sharded = len(x_spec) > 1 and x_spec[1] is not None
    all_axes = tuple(mesh.axis_names)

    def gather_axes(w, spec, skip_dim=0):
        """all_gather a param over every sharded dim except skip_dim
        (the expert dim stays local), in the compute dtype."""
        w = w.astype(x.dtype)
        for dim, ax in enumerate(spec):
            if ax is None or dim == skip_dim:
                continue
            for a in ((ax,) if isinstance(ax, str) else ax):
                w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
        return w

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(x_spec, r_spec, wi_spec, wi_spec, wo_spec),
        out_specs=(x_spec, Ps()), check_rep=False)
    def run(x_l, router_l, wg_l, wu_l, wo_l):
        b_l, s_l, d = x_l.shape
        t_l = b_l * s_l
        xt = x_l.reshape(t_l, d)
        # gather router fully (tiny), expert weights over FSDP dims
        router = router_l.astype(jnp.float32)
        for dim, ax in enumerate(r_spec):
            if ax is None:
                continue
            for a in ((ax,) if isinstance(ax, str) else ax):
                router = jax.lax.all_gather(router, a, axis=dim,
                                            tiled=True)
        wg = gather_axes(wg_l, wi_spec)
        wu = gather_axes(wu_l, wi_spec)
        wo = gather_axes(wo_l, wo_spec)

        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, s.top_k)
        gates = (gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True),
                                     1e-9)).astype(x_l.dtype)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(idx[:, 0], s.n_experts), axis=0)
        aux = jax.lax.pmean(s.n_experts * jnp.sum(me * ce), all_axes)

        # local slotting (static shapes)
        cap = s.capacity(t_l)
        flat_e = idx.reshape(-1)
        one_hot = jax.nn.one_hot(flat_e, s.n_experts, dtype=jnp.int32)
        pos = jnp.sum(jnp.cumsum(one_hot, axis=0) * one_hot, -1) - 1
        keep = pos < cap
        tok_ids = jnp.repeat(jnp.arange(t_l), s.top_k)
        e_ids = jnp.where(keep, flat_e, s.n_experts)
        c_ids = jnp.where(keep, pos, 0)
        slot_tok = jnp.full((s.n_experts, cap), t_l, jnp.int32)
        slot_gate = jnp.zeros((s.n_experts, cap), x_l.dtype)
        slot_tok = slot_tok.at[(e_ids, c_ids)].set(
            jnp.where(keep, tok_ids, t_l), mode="drop")
        slot_gate = slot_gate.at[(e_ids, c_ids)].set(
            jnp.where(keep, gates.reshape(-1), 0.0), mode="drop")
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
        xe = xt_pad[slot_tok]                      # (E, C_l, d) local

        # a2a: expert blocks to their owners (model axis)
        xe = jax.lax.all_to_all(xe, "model", split_axis=0,
                                concat_axis=1, tiled=True)
        f = layers.act_fn(s.act)                   # (E/tp, tp*C_l, d)
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", f(g) * u, wo)
        # reverse a2a: outputs back to token owners
        ye = jax.lax.all_to_all(ye, "model", split_axis=1,
                                concat_axis=0, tiled=True)

        y = jnp.zeros((t_l + 1, d), x_l.dtype)
        y = y.at[slot_tok].add(ye * slot_gate[..., None], mode="drop")
        return y[:t_l].reshape(b_l, s_l, d), aux

    y, aux = run(x, params["router"], params["wi_gate"],
                 params["wi_up"], params["wo"])
    if s.n_shared:
        y = y + mlp(params["shared"], x, act=s.act)
    return constrain(y, "batch", "res_seq", "act_embed"), aux


def moe(params, x: jnp.ndarray, s: MoESpec):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).  Dispatches to
    the shard_map EP implementation unless configured (or forced by a
    missing mesh / non-divisible expert count) onto the SPMD gather."""
    if s.impl == "a2a":
        return moe_a2a(params, x, s)
    return _moe_gather(params, x, s)


def _moe_gather(params, x: jnp.ndarray, s: MoESpec):
    b, sq, d = x.shape
    t = b * sq
    xt = x.reshape(t, d)
    idx, gates, aux = router_probs(params, xt, s)      # (T, k)

    cap = s.capacity(t)
    # position of each (token, choice) within its expert, by arrival order
    flat_e = idx.reshape(-1)                           # (T*k,)
    one_hot = jax.nn.one_hot(flat_e, s.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(one_hot, axis=0) * one_hot   # (T*k, E)
    pos = jnp.sum(pos_in_e, axis=-1) - 1               # (T*k,)
    keep = pos < cap                                   # capacity drop

    # slot tables: which token fills (e, c); -1 = empty
    slot_tok = jnp.full((s.n_experts, cap), t, jnp.int32)  # t = pad row
    slot_gate = jnp.zeros((s.n_experts, cap), x.dtype)
    tok_ids = jnp.repeat(jnp.arange(t), s.top_k)
    e_ids = jnp.where(keep, flat_e, s.n_experts)       # drop -> pad expert
    c_ids = jnp.where(keep, pos, 0)
    slot_tok = slot_tok.at[(e_ids, c_ids)].set(
        jnp.where(keep, tok_ids, t), mode="drop")
    slot_gate = slot_gate.at[(e_ids, c_ids)].set(
        jnp.where(keep, gates.reshape(-1), 0.0), mode="drop")

    # gather tokens into expert slots: (E, C, d)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xt_pad[slot_tok]
    xe = constrain(xe, "experts", "capacity", "act_embed")

    f = layers.act_fn(s.act)
    g = jnp.einsum("ecd,edf->ecf", xe, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, params["wi_up"].astype(x.dtype))
    h = constrain(f(g) * u, "experts", "capacity", "ffn")
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
    ye = constrain(ye, "experts", "capacity", "act_embed")

    # combine: weighted scatter-add back to tokens
    y = jnp.zeros((t + 1, d), x.dtype)
    y = y.at[slot_tok].add(ye * slot_gate[..., None], mode="drop")
    y = y[:t].reshape(b, sq, d)

    if s.n_shared:
        y = y + mlp(params["shared"], x, act=s.act)
    return constrain(y, "batch", "res_seq", "act_embed"), aux
