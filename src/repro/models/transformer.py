"""Layer stacks for every assigned family, with scan-over-layers.

One compiled block body per stack (lax.scan over stacked params) keeps
HLO size and compile time O(1) in depth — an 81-layer hybrid compiles
like one layer, which is what makes the 40-cell x 2-mesh dry-run matrix
tractable.  Remat policy is a config knob applied to the block body.

Families:
  dense / vlm   uniform [attn + gated MLP] blocks (+ alternating
                local/global windows, post-norms, softcaps for gemma2)
  moe           [attn + MoE] blocks; optional leading dense-MLP layer
                (moonshot/deepseek first_k_dense_replace=1)
  ssm           uniform Mamba2 blocks
  hybrid        groups of ``hybrid_period`` Mamba2 blocks, a SHARED
                full-attention transformer block applied between groups
                (zamba2: one parameter set, G applications, per-
                application KV caches)
  encdec        encoder stack (full mask) + decoder stack with fused
                cross-attention (seamless)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention, layers, mlp, ssm
from repro.models.params import stack_layers


# ---------------------------------------------------------------------------
# Specs from config

def attn_spec(cfg: ModelConfig, mask: str = "causal") -> attention.AttnSpec:
    return attention.AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        kv_eff=cfg.kv_eff, head_dim=cfg.head_dim_,
        rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias,
        query_scale=cfg.query_scale_, softcap=cfg.attn_softcap,
        window=cfg.sliding_window, mask=mask,
        prefix_len=cfg.vlm_prefix, chunk=cfg.attn_chunk)


def moe_spec(cfg: ModelConfig) -> mlp.MoESpec:
    return mlp.MoESpec(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor, act=cfg.mlp_act,
        impl=cfg.moe_impl)


def ssm_spec(cfg: ModelConfig) -> ssm.SSMSpec:
    return ssm.SSMSpec(
        d_model=cfg.d_model, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
        conv=cfg.ssm_conv, chunk=cfg.ssm_chunk,
        intra_bf16=cfg.ssm_intra_bf16)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "nothing":
        return fn
    pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
           else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Block schemas

def dense_block_schema(cfg: ModelConfig, use_moe: bool = False,
                       cross: bool = False) -> dict:
    s = attn_spec(cfg)
    out: dict = {"ln_attn": layers.rmsnorm_schema(cfg.d_model),
                 "attn": attention.schema(s)}
    if cross:
        out["ln_cross"] = layers.rmsnorm_schema(cfg.d_model)
        out["cross"] = attention.schema(s, cross=True)
    out["ln_mlp"] = layers.rmsnorm_schema(cfg.d_model)
    if use_moe:
        out["moe"] = mlp.moe_schema(moe_spec(cfg))
    else:
        out["mlp"] = mlp.mlp_schema(cfg.d_model, cfg.d_ff)
    if cfg.post_norms:
        out["ln_attn_post"] = layers.rmsnorm_schema(cfg.d_model)
        out["ln_mlp_post"] = layers.rmsnorm_schema(cfg.d_model)
    return out


def ssm_block_schema(cfg: ModelConfig) -> dict:
    return {"ln": layers.rmsnorm_schema(cfg.d_model),
            "ssm": ssm.schema(ssm_spec(cfg))}


def shared_block_schema(cfg: ModelConfig) -> dict:
    """zamba2 shared transformer block (full attention, own d_ff)."""
    return dense_block_schema(cfg)


# ---------------------------------------------------------------------------
# Block applies (full sequence)

def _norm(cfg, p, x):
    return layers.rmsnorm(p, x, eps=cfg.rms_eps,
                          unit_offset=cfg.rms_unit_offset)


def dense_block(cfg: ModelConfig, p, x, positions, is_local=None,
                use_moe=False, mask="causal", collect_kv=False,
                cross_kv=None):
    """Returns (x, aux, kv)."""
    s = attn_spec(cfg, mask)
    h = _norm(cfg, p["ln_attn"], x)
    if collect_kv:
        a, kv = attention.full_layer(p["attn"], h, s, positions,
                                     is_local=is_local, return_kv=True)
    else:
        a = attention.full_layer(p["attn"], h, s, positions,
                                 is_local=is_local)
        kv = None
    if cfg.post_norms:
        a = _norm(cfg, p["ln_attn_post"], a)
    x = constrain(x + a, "batch", "res_seq", "act_embed")
    if cross_kv is not None:
        c = attention.cross_layer(p["cross"],
                                  _norm(cfg, p["ln_cross"], x),
                                  cross_kv, s)
        x = constrain(x + c, "batch", "res_seq", "act_embed")
    h = _norm(cfg, p["ln_mlp"], x)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        m, aux = mlp.moe(p["moe"], h, moe_spec(cfg))
    else:
        m = mlp.mlp(p["mlp"], h, act=cfg.mlp_act)
    if cfg.post_norms:
        m = _norm(cfg, p["ln_mlp_post"], m)
    x = constrain(x + m, "batch", "res_seq", "act_embed")
    return x, aux, kv


def ssm_block(cfg: ModelConfig, p, x, collect_state=False):
    h = _norm(cfg, p["ln"], x)
    if collect_state:
        y, st = ssm.full_layer_with_state(p["ssm"], h, ssm_spec(cfg),
                                          rms_eps=cfg.rms_eps)
    else:
        y = ssm.full_layer(p["ssm"], h, ssm_spec(cfg), rms_eps=cfg.rms_eps)
        st = None
    return constrain(x + y, "batch", "res_seq", "act_embed"), st


# ---------------------------------------------------------------------------
# Stack schema

def stack_schema(cfg: ModelConfig) -> dict:
    f = cfg.family
    if f in ("dense", "vlm"):
        return {"blocks": stack_layers(cfg.n_layers,
                                       dense_block_schema(cfg))}
    if f == "moe":
        first = cfg.first_dense
        out = {"blocks": stack_layers(cfg.n_layers - first,
                                      dense_block_schema(cfg, use_moe=True))}
        if first:
            # deepseek-style: layer 0 keeps attention but uses a dense MLP
            assert first == 1
            out["first"] = dense_block_schema(
                cfg.replace(d_ff=cfg.first_dense_ff or cfg.d_ff),
                use_moe=False)
        return out
    if f == "ssm":
        return {"blocks": stack_layers(cfg.n_layers, ssm_block_schema(cfg))}
    if f == "hybrid":
        g = cfg.n_layers // cfg.hybrid_period
        tail = cfg.n_layers - g * cfg.hybrid_period
        out = {
            "groups": stack_layers(
                g, stack_layers(cfg.hybrid_period, ssm_block_schema(cfg))),
            "shared": shared_block_schema(cfg),
        }
        if tail:
            out["tail"] = stack_layers(tail, ssm_block_schema(cfg))
        return out
    if f == "encdec":
        return {
            "enc_blocks": stack_layers(cfg.n_enc_layers,
                                       dense_block_schema(cfg)),
            "enc_norm": layers.rmsnorm_schema(cfg.d_model),
            "dec_blocks": stack_layers(
                cfg.n_layers, dense_block_schema(cfg, cross=True)),
        }
    raise ValueError(f"unknown family {f}")


def _is_local_flags(cfg: ModelConfig, n: int) -> jnp.ndarray | None:
    """gemma2 alternating stack: even layers local (SWA), odd global."""
    if cfg.local_global_period:
        return (jnp.arange(n) % cfg.local_global_period) == 0
    return None


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)

def forward(params, cfg: ModelConfig, x, positions, x_src=None,
            collect: bool = False):
    """x: (B, S, d) embedded inputs.  Returns (hidden, aux, cache).

    collect=True additionally returns the serve cache (KV / SSM states),
    turning this forward into the prefill step.
    """
    f = cfg.family
    mask = "prefix" if f == "vlm" else "causal"
    aux_total = jnp.zeros((), jnp.float32)
    cache: dict = {}

    if f in ("dense", "vlm", "moe"):
        use_moe = f == "moe"
        if "first" in params:
            x, _, kv = dense_block(cfg, params["first"], x, positions,
                                   use_moe=False, mask=mask,
                                   collect_kv=collect)
            if collect:
                cache["first_k"], cache["first_v"] = kv
        flags = _is_local_flags(
            cfg, params["blocks"]["ln_attn"]["scale"].shape[0])

        def body(carry, xs):
            xc, aux = carry
            lp = xs[0]
            loc = xs[1] if flags is not None else None
            xc, a, kv = _remat(cfg, functools.partial(
                dense_block, cfg, use_moe=use_moe, mask=mask,
                collect_kv=collect))(lp, xc, positions, is_local=loc)
            return (xc, aux + a), kv

        xs = (params["blocks"],) + ((flags,) if flags is not None else ())
        (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total), xs)
        if collect:
            cache["k"], cache["v"] = kvs

    elif f == "ssm":
        def body(xc, lp):
            xc, st = _remat(cfg, functools.partial(
                ssm_block, cfg, collect_state=collect))(lp, xc)
            return xc, st

        x, states = jax.lax.scan(body, x, params["blocks"])
        if collect:
            cache["ssm"] = states

    elif f == "hybrid":
        def inner(xc, lp):
            xc, st = _remat(cfg, functools.partial(
                ssm_block, cfg, collect_state=collect))(lp, xc)
            return xc, st

        def group(xc, gp):
            xc, states = jax.lax.scan(inner, xc, gp)
            xc, _, kv = dense_block(cfg, params["shared"], xc, positions,
                                    collect_kv=collect)
            return xc, (states, kv)

        x, (g_states, g_kv) = jax.lax.scan(group, x, params["groups"])
        if collect:
            cache["groups"] = g_states
            cache["shared_k"], cache["shared_v"] = g_kv
        if "tail" in params:
            x, t_states = jax.lax.scan(inner, x, params["tail"])
            if collect:
                cache["tail"] = t_states

    elif f == "encdec":
        assert x_src is not None
        enc_pos = jnp.broadcast_to(jnp.arange(x_src.shape[1]),
                                   x_src.shape[:2])

        # encoder (full mask, no cache needed beyond cross K/V)
        def enc_body(xc, lp):
            xc, _, _ = _remat(cfg, functools.partial(
                dense_block, cfg, mask="full"))(lp, xc, enc_pos)
            return xc, None

        src, _ = jax.lax.scan(enc_body, x_src, params["enc_blocks"])
        src = _norm(cfg, params["enc_norm"], src)
        s = attn_spec(cfg)

        def cross_kv_of(lp):
            return attention.encode_kv(lp["cross"], src, s)

        def dec_body(carry, lp):
            xc, aux = carry
            ckv = cross_kv_of(lp)
            xc, a, kv = _remat(cfg, functools.partial(
                dense_block, cfg, collect_kv=collect))(
                    lp, xc, positions, cross_kv=ckv)
            return (xc, aux), (kv, ckv if collect else None)

        (x, aux_total), (kvs, ckvs) = jax.lax.scan(
            dec_body, (x, aux_total), params["dec_blocks"])
        if collect:
            cache["k"], cache["v"] = kvs
            cache["cross_k"] = ckvs[0]
            cache["cross_v"] = ckvs[1]
    else:
        raise ValueError(f)

    return x, aux_total, (cache if collect else None)


# ---------------------------------------------------------------------------
# Decode (one token against a cache)

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Empty serve cache for ``decode`` (shapes only — dry-run safe).

    Sliding-window archs get a ROLLING buffer of min(window, max_len)
    slots; gemma2's alternating stack keeps full-length buffers for all
    layers (global layers need them; the local-layer overallocation is a
    documented hillclimb target)."""
    f = cfg.family
    s = attn_spec(cfg)
    kv_len = max_len
    if cfg.sliding_window is not None and cfg.local_global_period == 0:
        kv_len = min(cfg.sliding_window, max_len)

    def kv(n, length):
        shape = (n, batch, cfg.kv_eff, length, cfg.head_dim_)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    if f in ("dense", "vlm", "moe"):
        n = cfg.n_layers - cfg.first_dense
        c = {}
        c["k"], c["v"] = kv(n, kv_len)
        if cfg.first_dense:
            fk, fv = kv(1, kv_len)
            c["first_k"], c["first_v"] = fk[0], fv[0]
        return c
    if f == "ssm":
        spec = ssm_spec(cfg)
        st = ssm.init_state(batch, spec)
        return {"ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), st)}
    if f == "hybrid":
        spec = ssm_spec(cfg)
        g = cfg.n_layers // cfg.hybrid_period
        tail = cfg.n_layers - g * cfg.hybrid_period
        st = ssm.init_state(batch, spec)
        c = {"groups": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (g, cfg.hybrid_period, *a.shape)), st)}
        c["shared_k"], c["shared_v"] = kv(g, max_len)
        if tail:
            c["tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (tail, *a.shape)), st)
        return c
    if f == "encdec":
        c = {}
        c["k"], c["v"] = kv(cfg.n_layers, kv_len)
        src_len = max_len  # caller overrides by slicing if needed
        c["cross_k"], c["cross_v"] = kv(cfg.n_layers, src_len)
        return c
    raise ValueError(f)


def _dense_decode_block(cfg: ModelConfig, p, x_tok, ck, cv, pos, q=None,
                        is_local=None, rolling=False, use_moe=False,
                        cross_kv=None):
    """One decode block against ALREADY-UPDATED cache slices ck/cv
    (B, kv_eff, Smax, D).  ``q`` may be precomputed by the caller (the
    same projection that produced the cache write).  Returns the new
    hidden state."""
    s = attn_spec(cfg)
    if q is None:
        h = _norm(cfg, p["ln_attn"], x_tok)
        q, _, _ = attention.decode_qkv(p["attn"], h, pos, s)
    a = attention.decode_attend(p["attn"], q, ck, cv, pos, s,
                                is_local=is_local, rolling=rolling)
    if cfg.post_norms:
        a = _norm(cfg, p["ln_attn_post"], a)
    x_tok = x_tok + a
    if cross_kv is not None:
        c = attention.cross_layer(p["cross"],
                                  _norm(cfg, p["ln_cross"], x_tok),
                                  cross_kv, s)
        x_tok = x_tok + c
    h = _norm(cfg, p["ln_mlp"], x_tok)
    if use_moe:
        m, _ = mlp.moe(p["moe"], h, moe_spec(cfg))
    else:
        m = mlp.mlp(p["mlp"], h, act=cfg.mlp_act)
    if cfg.post_norms:
        m = _norm(cfg, p["ln_mlp_post"], m)
    return x_tok + m


def _write_layer_slot(cache, tok, li, slot):
    """cache: (L, B, H, Smax, D); tok: (B, H, 1, D) — in-place single-
    slot write at (layer li, position slot).  The cache is a scan CARRY
    (not xs->ys), so XLA aliases the donated input buffer; when the seq
    dim is sharded, attention.write_slot routes through a shard_map so
    no shard rewrites its whole buffer."""
    return attention.write_slot(cache, tok, slot, li=li)


def decode(params, cfg: ModelConfig, x_tok, cache: dict, pos):
    """One-token decode.  x_tok: (B, 1, d) embedded; pos: scalar int32.
    Returns (hidden (B, 1, d), new_cache).  Caches ride the layer scan
    as carries with single-slot in-place writes (donation-friendly)."""
    f = cfg.family
    new_cache = dict(cache)
    rolling = (cfg.sliding_window is not None
               and cfg.local_global_period == 0)
    s = attn_spec(cfg) if cfg.n_heads else None

    def slot_of(smax):
        return pos % smax if rolling else pos

    def qkv_write(p, xc, ck_all, cv_all, li):
        h = _norm(cfg, p["ln_attn"], xc)
        q, kt, vt = attention.decode_qkv(p["attn"], h, pos, s)
        sl = slot_of(ck_all.shape[-2])
        ck_all = _write_layer_slot(ck_all, kt, li, sl)
        cv_all = _write_layer_slot(cv_all, vt, li, sl)
        return q, ck_all, cv_all

    if f in ("dense", "vlm", "moe"):
        if cfg.first_dense:
            fk, fv = cache["first_k"], cache["first_v"]
            h = _norm(cfg, params["first"]["ln_attn"], x_tok)
            q, kt, vt = attention.decode_qkv(params["first"]["attn"], h,
                                             pos, s)
            sl = slot_of(fk.shape[-2])
            fk = attention.write_slot(fk, kt, sl)
            fv = attention.write_slot(fv, vt, sl)
            x_tok = _dense_decode_block(
                cfg, params["first"], x_tok, fk, fv, pos, q=q,
                rolling=rolling)
            new_cache["first_k"], new_cache["first_v"] = fk, fv
        n_blocks = params["blocks"]["ln_attn"]["scale"].shape[0]
        flags = _is_local_flags(cfg, n_blocks)

        def body(carry, xs):
            xc, ck_all, cv_all = carry
            lp, li = xs[0], xs[1]
            loc = xs[2] if flags is not None else None
            q, ck_all, cv_all = qkv_write(lp, xc, ck_all, cv_all, li)
            ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0,
                                              keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0,
                                              keepdims=False)
            xc = _dense_decode_block(
                cfg, lp, xc, ck, cv, pos, q=q, is_local=loc,
                rolling=rolling, use_moe=(f == "moe"))
            return (xc, ck_all, cv_all), None

        xs = (params["blocks"], jnp.arange(n_blocks))
        if flags is not None:
            xs = xs + (flags,)
        (x_tok, nk, nv), _ = jax.lax.scan(
            body, (x_tok, cache["k"], cache["v"]), xs)
        new_cache["k"], new_cache["v"] = nk, nv

    elif f == "ssm":
        spec = ssm_spec(cfg)

        def body(carry, xs):
            xc, states = carry
            lp, li = xs
            st = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, li, 0, keepdims=False), states)
            h = _norm(cfg, lp["ln"], xc)
            y, st2 = ssm.decode_layer(lp["ssm"], h, st, spec,
                                      rms_eps=cfg.rms_eps)
            states = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), li, 0), states, st2)
            return (xc + y, states), None

        n = cfg.n_layers
        (x_tok, states), _ = jax.lax.scan(
            body, (x_tok, cache["ssm"]),
            (params["blocks"], jnp.arange(n)))
        new_cache["ssm"] = states

    elif f == "hybrid":
        spec = ssm_spec(cfg)

        def ssm_step(xc, lp, st):
            h = _norm(cfg, lp["ln"], xc)
            y, st2 = ssm.decode_layer(lp["ssm"], h, st, spec,
                                      rms_eps=cfg.rms_eps)
            return xc + y, st2

        def group(carry, xs):
            xc, gstates, sk_all, sv_all = carry
            gp, gi = xs

            def inner(c2, xs2):
                x2, gst = c2                  # gst = full carried states
                lp, li = xs2
                st = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(
                            a, gi, 0, keepdims=False),
                        li, 0, keepdims=False), gst)
                x2, st2 = ssm_step(x2, lp, st)
                gst = jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_slice(
                        a, u[None, None].astype(a.dtype),
                        (gi, li) + (0,) * u.ndim), gst, st2)
                return (x2, gst), None

            (xc, gstates), _ = jax.lax.scan(
                inner, (xc, gstates),
                (gp, jnp.arange(cfg.hybrid_period)))
            # shared attention block, per-application cache row gi
            h = _norm(cfg, params["shared"]["ln_attn"], xc)
            q, kt, vt = attention.decode_qkv(params["shared"]["attn"],
                                             h, pos, s)
            sk_all = _write_layer_slot(sk_all, kt, gi, pos)
            sv_all = _write_layer_slot(sv_all, vt, gi, pos)
            ck = jax.lax.dynamic_index_in_dim(sk_all, gi, 0,
                                              keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(sv_all, gi, 0,
                                              keepdims=False)
            xc = _dense_decode_block(cfg, params["shared"], xc, ck, cv,
                                     pos, q=q)
            return (xc, gstates, sk_all, sv_all), None

        g = cfg.n_layers // cfg.hybrid_period
        (x_tok, gst, sk, sv), _ = jax.lax.scan(
            group,
            (x_tok, cache["groups"], cache["shared_k"],
             cache["shared_v"]),
            (params["groups"], jnp.arange(g)))
        new_cache["groups"] = gst
        new_cache["shared_k"], new_cache["shared_v"] = sk, sv
        if "tail" in params:
            def tail_body(carry, xs):
                xc, states = carry
                lp, li = xs
                st = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, li, 0, keepdims=False), states)
                xc, st2 = ssm_step(xc, lp, st)
                states = jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_index_in_dim(
                        a, u.astype(a.dtype), li, 0), states, st2)
                return (xc, states), None

            tail_n = cfg.n_layers - g * cfg.hybrid_period
            (x_tok, tst), _ = jax.lax.scan(
                tail_body, (x_tok, cache["tail"]),
                (params["tail"], jnp.arange(tail_n)))
            new_cache["tail"] = tst

    elif f == "encdec":
        def body(carry, xs):
            xc, ck_all, cv_all = carry
            lp, li, xk, xv = xs
            q, ck_all, cv_all = qkv_write(lp, xc, ck_all, cv_all, li)
            ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0,
                                              keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0,
                                              keepdims=False)
            xc = _dense_decode_block(cfg, lp, xc, ck, cv, pos, q=q,
                                     cross_kv=(xk, xv))
            return (xc, ck_all, cv_all), None

        (x_tok, nk, nv), _ = jax.lax.scan(
            body, (x_tok, cache["k"], cache["v"]),
            (params["dec_blocks"], jnp.arange(cfg.n_layers),
             cache["cross_k"], cache["cross_v"]))
        new_cache["k"], new_cache["v"] = nk, nv
    else:
        raise ValueError(f)

    return x_tok, new_cache
