"""Attention: GQA/MQA with RoPE, flash-chunked softmax, sliding window,
logit soft-capping, prefix-LM masks, and KV-cache prefill/decode.

TPU/memory design: full-sequence attention never materializes the
(S x S) score tensor — a ``lax.scan`` over KV chunks carries the running
(max, sum, acc) online-softmax state, bounding live memory to
(B, H, S, chunk) per layer (the jnp analog of flash attention; the
paper's line-buffer streaming applied to the sequence axis).

GQA-for-TP: when n_kv doesn't divide the model axis but n_heads does,
K/V heads are repeated to ``kv_eff`` (mathematically identical) so the
kv dim shards; see ModelConfig.kv_eff.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import P

NEG = -2.0e9


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv: int                     # logical kv heads (public config)
    kv_eff: int                   # kv heads after TP repetition
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    query_scale: float = 1.0
    softcap: Optional[float] = None
    window: Optional[int] = None          # sliding-window size
    mask: str = "causal"                  # causal | full | prefix
    prefix_len: int = 0
    chunk: int = 1024

    @property
    def group(self) -> int:
        return self.n_heads // self.kv_eff


def schema(s: AttnSpec, cross: bool = False) -> dict:
    d, h, kv, hd = s.d_model, s.n_heads, s.n_kv, s.head_dim
    out = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        # K/V are stored at the LOGICAL kv-head count; repetition to
        # kv_eff happens in apply (keeps parameters faithful).
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if s.qkv_bias:
        out["bq"] = P((h, hd), ("heads", "head_dim"), init="zeros")
        out["bk"] = P((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = P((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return out


def _repeat_kv(x: jnp.ndarray, s: AttnSpec) -> jnp.ndarray:
    """(B, S, n_kv, D) -> (B, S, kv_eff, D) by head repetition."""
    if s.kv_eff == s.n_kv:
        return x
    r = s.kv_eff // s.n_kv
    return jnp.repeat(x, r, axis=2)


def qkv(params, x: jnp.ndarray, s: AttnSpec, positions, rope: bool = True):
    """x: (B, S, d) -> q (B, S, H, D), k/v (B, S, kv_eff, D), rope'd."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if s.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    k = _repeat_kv(k, s)
    v = _repeat_kv(v, s)
    if rope:
        pos = positions
        q = layers.rope(q.swapaxes(1, 2), pos[:, None, :],
                        s.rope_theta).swapaxes(1, 2)
        k = layers.rope(k.swapaxes(1, 2), pos[:, None, :],
                        s.rope_theta).swapaxes(1, 2)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _mask_block(s: AttnSpec, q_pos, k_pos, is_local):
    """(Sq, C) boolean mask for one KV chunk.  is_local: traced bool or
    None — selects the sliding window on alternating-stack local layers."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if s.mask == "full":
        base = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif s.mask == "prefix":
        base = (kp <= qp) | (kp < s.prefix_len)
    else:
        base = kp <= qp
    if s.window is not None:
        win = base & (kp > qp - s.window)
        if is_local is None:
            base = win
        else:
            base = jnp.where(is_local, win, base)
    return base


def flash(q, k, v, s: AttnSpec, is_local=None):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, D); k, v: (B, Skv, kv_eff, D).  Self-attention layout:
    q_pos == k_pos grids (offset 0).  Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    g = s.group
    chunk = min(s.chunk, skv)
    if skv % chunk:                       # pad KV to a chunk multiple;
        pad = chunk - skv % chunk         # padded k_pos are masked below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // chunk

    qh = q.reshape(b, sq, s.kv_eff, g, d).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3).reshape(b, s.kv_eff, nc, chunk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b, s.kv_eff, nc, chunk, d)
    kh = jnp.moveaxis(kh, 2, 0)         # (nc, B, kv, C, D)
    vh = jnp.moveaxis(vh, 2, 0)

    q_pos = jnp.arange(sq)
    scale = jnp.asarray(s.query_scale, jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        k_pos = ci * chunk + jnp.arange(chunk)
        sc = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kc,
                        preferred_element_type=jnp.float32) * scale
        sc = layers.softcap(sc, s.softcap)
        mask = _mask_block(s, q_pos, k_pos, is_local)
        mask = mask & (k_pos < skv)[None, :]          # KV padding
        sc = jnp.where(mask[None, None, None], sc, NEG)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # P in the KV dtype, f32 accumulate (never upcast the KV chunk:
        # XLA would hoist the convert and materialize an f32 cache)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vc.dtype),
                                vc, preferred_element_type=jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s.kv_eff, g, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, s.kv_eff, g, sq), jnp.float32)
    a0 = jnp.zeros((b, s.kv_eff, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kh, vh, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return constrain(out.astype(q.dtype), "batch", "seq", "heads",
                     "head_dim")


def project_out(params, o: jnp.ndarray, dtype) -> jnp.ndarray:
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dtype))
    return constrain(y, "batch", "res_seq", "act_embed")


def full_layer(params, x, s: AttnSpec, positions, is_local=None,
               return_kv: bool = False):
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = qkv(params, x, s, positions)
    o = flash(q, k, v, s, is_local=is_local)
    y = project_out(params, o, x.dtype)
    if return_kv:
        # cache layout: (B, kv_eff, S, D)
        return y, (k.swapaxes(1, 2), v.swapaxes(1, 2))
    return y


def cross_layer(params, x, kv_cache, s: AttnSpec):
    """Cross-attention: q from x, K/V precomputed from the encoder
    (kv_cache = (k, v) each (B, kv_eff, S_src, D)); full mask."""
    b, sq, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if s.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
    k, v = kv_cache
    s_full = dataclasses.replace(s, mask="full", window=None)
    o = flash(q, k.swapaxes(1, 2), v.swapaxes(1, 2), s_full)
    return project_out(params, o, x.dtype)


def encode_kv(params, x_src, s: AttnSpec):
    """Precompute cross-attention K/V from encoder output (no rope)."""
    k = jnp.einsum("bsd,dhk->bshk", x_src, params["wk"].astype(x_src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x_src, params["wv"].astype(x_src.dtype))
    if s.qkv_bias:
        k = k + params["bk"].astype(x_src.dtype)
        v = v + params["bv"].astype(x_src.dtype)
    return (_repeat_kv(k, s).swapaxes(1, 2),
            _repeat_kv(v, s).swapaxes(1, 2))


def decode_qkv(params, x_tok, pos, s: AttnSpec):
    """Project one token.  Returns (q (B,1,H,D), k_tok/v_tok
    (B, kv_eff, 1, D)) — the caller writes k/v into the cache carry
    IN PLACE (single-slot write; the cache buffer is donated)."""
    b = x_tok.shape[0]
    pos_b = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = qkv(params, x_tok, s, pos_b)
    return q, k.swapaxes(1, 2), v.swapaxes(1, 2)


def write_slot(cache, tok, slot, li=None):
    """Write one token into the cache at (layer li, position slot).

    cache: (L, B, H, Smax, D) with li, or (B, H, Smax, D) without.
    When the Smax dim is SHARDED, a plain dynamic-update-slice at a
    traced index makes the SPMD partitioner guard the write with a
    whole-buffer select per layer (full cache rewrite!); instead we
    shard_map the write so each shard updates at most its own slot in
    place — the flash-decode cache-write pattern.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Ps

    from repro.distributed.sharding import current_ctx, resolve

    tok = tok.astype(cache.dtype)
    seq_dim = cache.ndim - 2
    idx_prefix = (li,) if li is not None else ()
    tok_full = tok if li is None else tok[None]     # match cache rank

    def plain(c, t):
        idx = idx_prefix + (0,) * (c.ndim - 2 - len(idx_prefix)) \
            + (slot, 0)
        return jax.lax.dynamic_update_slice(c, t, idx)

    ctx = current_ctx()
    if ctx is None:
        return plain(cache, tok_full)
    axes = ("layers",) * (cache.ndim - 4) + (
        "batch", "kv_heads", "cache_seq", "head_dim")
    spec = resolve(ctx.rules.acts, axes, cache.shape, ctx.mesh)
    seq_sh = spec[seq_dim] if len(spec) > seq_dim else None
    if seq_sh is None:
        return plain(cache, tok_full)
    mesh_axes = (seq_sh,) if isinstance(seq_sh, str) else tuple(seq_sh)
    sizes = dict(ctx.mesh.shape)
    n_shards = 1
    for a in mesh_axes:
        n_shards *= sizes[a]
    shard_len = cache.shape[seq_dim] // n_shards
    tok_axes = ("batch", "kv_heads", None, "head_dim")
    tok_exp = tok if li is None else tok[None]
    tok_spec = resolve(ctx.rules.acts,
                       (("layers",) if li is not None else ())
                       + tok_axes, tok_exp.shape, ctx.mesh)

    # traced scalars (slot, li) enter as explicit replicated args
    li_arr = jnp.asarray(0 if li is None else li, jnp.int32)
    slot_arr = jnp.asarray(slot, jnp.int32)

    @partial(shard_map, mesh=ctx.mesh,
             in_specs=(spec, tok_spec, Ps(), Ps()),
             out_specs=spec, check_rep=False)
    def write(c_loc, t_loc, slot_, li_):
        sid = 0
        for a in mesh_axes:
            sid = sid * sizes[a] + jax.lax.axis_index(a)
        start = sid * shard_len
        loc = slot_ - start
        ok = (loc >= 0) & (loc < shard_len)
        loc_c = jnp.clip(loc, 0, shard_len - 1)
        pre = (li_,) if li is not None else ()
        idx = pre + (0,) * (c_loc.ndim - 2 - len(pre)) + (loc_c, 0)
        cur = jax.lax.dynamic_slice(c_loc, idx, t_loc.shape)
        upd = jnp.where(ok, t_loc, cur)
        return jax.lax.dynamic_update_slice(c_loc, upd, idx)

    return write(cache, tok_exp, slot_arr, li_arr)


def decode_attend(params, q, cache_k, cache_v, pos, s: AttnSpec,
                  is_local=None, rolling: bool = False):
    """Attend one query over the (already updated) cache slice.

    q: (B, 1, H, D); cache_k/v: (B, kv_eff, Smax, D); pos: tokens
    already in cache (the new token sits at slot pos / pos % Smax)."""
    b = q.shape[0]
    smax = cache_k.shape[2]
    qh = q.reshape(b, 1, s.kv_eff, s.group, -1).transpose(0, 2, 3, 1, 4)
    sc = jnp.einsum("bhgqd,bhkd->bhgqk", qh, cache_k,
                    preferred_element_type=jnp.float32) * s.query_scale
    sc = layers.softcap(sc, s.softcap)
    slots = jnp.arange(smax)
    if rolling:
        valid = (slots <= pos) | (pos >= smax)      # filled slots
    else:
        valid = slots <= pos
        if s.window is not None:
            win = valid & (slots > pos - s.window)
            valid = win if is_local is None else jnp.where(
                is_local, win, valid)
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG)
    p = jax.nn.softmax(sc, axis=-1)
    # P in the cache dtype (never upcast the cache), f32 accumulate
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(cache_v.dtype),
                   cache_v, preferred_element_type=jnp.float32)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, s.n_heads, -1)
    return project_out(params, o.astype(q.dtype), q.dtype)
