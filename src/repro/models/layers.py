"""Shared functional layers: norms, embeddings, RoPE, activations.

All applies are pure functions of (params, inputs); activations are
annotated with logical axes via ``constrain`` (no-ops without a mesh).
Compute dtype is the caller's (bf16 in production, f32 in smoke tests);
norms always accumulate in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.params import P


# ---------------------------------------------------------------------------
# RMSNorm

def rmsnorm_schema(dim: int) -> dict:
    return {"scale": P((dim,), ("embed",), init="zeros")}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6,
            unit_offset: bool = True) -> jnp.ndarray:
    """RMSNorm with the (1 + scale) parameterization (gemma convention;
    scale is zero-init so ones-init archs use unit_offset=True too)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    w = (1.0 + scale) if unit_offset else scale
    return (xn * w).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding

def embed_schema(vocab: int, dim: int) -> dict:
    # std = 1/sqrt(d): with gemma's sqrt(d) embed scaling the residual
    # stream starts O(1), and tied logits stay O(1) at init.
    return {"table": P((vocab, dim), ("vocab", "embed"), init="embed",
                       scale=dim ** -0.5)}


def embed(params, tokens: jnp.ndarray, scale_by_dim: bool = False):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(params["table"].shape[1] ** 0.5, x.dtype)
    return constrain(x, "batch", "res_seq", "act_embed")


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """Project to (padded) vocab logits with the embedding table
    (tied head) — callers with untied heads pass their own table."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"])
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Rotary position embeddings (half-split convention)

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq   # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# misc

def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]
