"""LM-family architectures (assigned pool) on a shared functional stack."""

from repro.models import lm, params

__all__ = ["lm", "params"]
