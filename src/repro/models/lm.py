"""Top-level language model: embeddings -> stack -> head, loss, serving.

Batch dicts (all inputs ShapeDtypeStruct-able for the dry-run):
  train/prefill:  {"tokens": (B, S) i32}
                  vlm adds    {"patches": (B, P, d) f32}  (stub frontend)
                  encdec adds {"frames": (B, S_src, d) f32}  (stub audio)
  decode:         {"token": (B, 1) i32} + cache + pos
Loss positions with target id < 0 are masked (and the vlm prefix is
masked automatically).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers, transformer
from repro.models.params import P


def model_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    out = {
        "embed": layers.embed_schema(cfg.vocab_padded, d),
        "stack": transformer.stack_schema(cfg),
        "final_norm": layers.rmsnorm_schema(d),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = {
            "w": P((d, cfg.vocab_padded), ("embed", "vocab"),
                   scale=d ** -0.5)}
    return out


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _head(params, cfg: ModelConfig, x) -> jnp.ndarray:
    """Logits stay in the compute dtype (bf16 in production) — the loss
    upcasts inside its reductions, so the (B, S, V) f32 tensor is never
    materialized."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x,
                            params["lm_head"]["w"].astype(x.dtype))
    if cfg.final_softcap:
        logits = layers.softcap(logits.astype(jnp.float32),
                                cfg.final_softcap).astype(x.dtype)
    # mask vocab padding
    if cfg.vocab_padded != cfg.vocab:
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e9, logits.dtype), logits)
    return constrain(logits, "batch", "logits_seq", "vocab")


def _embed_tokens(params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    x = layers.embed(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    return x.astype(_dtype(cfg))


def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Returns (x, x_src, positions)."""
    f = cfg.family
    x_src = None
    if f == "vlm":
        tok = _embed_tokens(params, cfg, batch["tokens"])
        patches = batch["patches"].astype(tok.dtype)
        x = jnp.concatenate([patches, tok], axis=1)
    elif f == "encdec":
        x = _embed_tokens(params, cfg, batch["tokens"])
        x_src = batch["frames"].astype(x.dtype)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"])
    x = constrain(x, "batch", "res_seq", "act_embed")
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, x_src, positions


def forward_logits(params, cfg: ModelConfig, batch: dict,
                   collect: bool = False):
    x, x_src, positions = embed_inputs(params, cfg, batch)
    h, aux, cache = transformer.forward(params["stack"], cfg, x, positions,
                                        x_src=x_src, collect=collect)
    h = layers.rmsnorm(params["final_norm"], h, eps=cfg.rms_eps,
                       unit_offset=cfg.rms_unit_offset)
    return _head(params, cfg, h), aux, cache


def forward_hidden(params, cfg: ModelConfig, batch: dict):
    """Forward to the final-norm hidden states (no head)."""
    x, x_src, positions = embed_inputs(params, cfg, batch)
    h, aux, _ = transformer.forward(params["stack"], cfg, x, positions,
                                    x_src=x_src)
    h = layers.rmsnorm(params["final_norm"], h, eps=cfg.rms_eps,
                       unit_offset=cfg.rms_unit_offset)
    return h, aux


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Next-token cross entropy (+ z-loss + MoE aux).

    Fused-head formulation: the (B, S, V) logits tensor is never
    materialized — the head matmul + log-softmax run per seq CHUNK
    inside a rematerialized scan, so peak loss memory is
    (B, loss_chunk, V/model) regardless of sequence length (the
    production trick for 256k vocabularies)."""
    h, aux = forward_hidden(params, cfg, batch)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        # prefix patches occupy the first vlm_prefix positions; only
        # text positions produce next-token targets
        pad = jnp.full((tokens.shape[0], cfg.vlm_prefix), -1,
                       tokens.dtype)
        full = jnp.concatenate([pad, tokens], axis=1)
    else:
        full = tokens
    targets = full[:, 1:]
    h_in = h[:, :-1]
    b, sm1, d = h_in.shape
    c = min(cfg.loss_chunk, sm1)
    pad_s = (-sm1) % c
    if pad_s:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad_s), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad_s)),
                          constant_values=-1)
    nc = h_in.shape[1] // c
    h_c = jnp.moveaxis(h_in.reshape(b, nc, c, d), 1, 0)
    t_c = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def chunk_stats(hc, tc):
        logits = _head(params, cfg, hc)          # (B, C, Vp)
        mask = (tc >= 0).astype(jnp.float32)
        tgt = jnp.maximum(tc, 0)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits, tgt[..., None],
                                 axis=-1)[..., 0].astype(jnp.float32)
        xent = jnp.sum((lse - ll) * mask)
        z = jnp.sum((lse * mask) ** 2)
        return xent, z, jnp.sum(mask)

    def body(carry, xs):
        xe, z, n = carry
        hc, tc = xs
        xe2, z2, n2 = chunk_stats(hc, tc)
        return (xe + xe2, z + z2, n + n2), None

    (xe, z, n), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (h_c, t_c))
    denom = jnp.maximum(n, 1.0)
    loss = xe / denom
    z_loss = 1e-4 * z / denom
    total = loss + z_loss + cfg.router_aux_coef * aux
    return total, {"xent": loss, "z_loss": z_loss, "aux": aux,
                   "tokens": denom}


def prefill(params, cfg: ModelConfig, batch: dict):
    """Process the full prompt; returns (cache, last_logits, pos)."""
    logits, _, cache = forward_logits(params, cfg, batch, collect=True)
    pos = jnp.asarray(batch["tokens"].shape[1]
                      + (cfg.vlm_prefix if cfg.family == "vlm" else 0),
                      jnp.int32)
    return cache, logits[:, -1], pos


def expand_cache(cfg: ModelConfig, cache: dict, max_len: int,
                 prompt_len: int) -> dict:
    """Prefill -> decode handoff: re-lay the prefill cache into decode
    buffers of ``max_len`` slots.

    Full-attention caches are zero-padded on the seq axis.  Rolling
    (all-layers-SWA) caches are rebuilt into the circular layout: token
    p lives in slot p % window, keeping the last ``window`` tokens.
    SSM states and cross K/V pass through unchanged.
    """
    rolling = (cfg.sliding_window is not None
               and cfg.local_global_period == 0)
    out = dict(cache)

    def pad_seq(x, target):
        p = target - x.shape[-2]
        if p <= 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[-2] = (0, p)
        return jnp.pad(x, widths)

    def to_rolling(x, window):
        # x: (..., P, D) -> (..., window, D) circular
        p_len = x.shape[-2]
        w = min(window, max_len)
        buf = jnp.zeros((*x.shape[:-2], w, x.shape[-1]), x.dtype)
        start = max(0, p_len - w)
        pos = jnp.arange(start, p_len)
        return buf.at[..., pos % w, :].set(x[..., start:p_len, :])

    for key in ("k", "v", "first_k", "first_v", "shared_k", "shared_v"):
        if key in out:
            if rolling and key in ("k", "v", "first_k", "first_v"):
                out[key] = to_rolling(out[key], cfg.sliding_window)
            else:
                out[key] = pad_seq(out[key], max_len)
    return out


def decode_step(params, cfg: ModelConfig, token, cache: dict, pos):
    """token: (B, 1) i32; pos: scalar i32.  Returns (logits, new_cache).

    NOTE on prefill->decode handoff for full-attention archs: the
    prefill cache holds S entries; decode writes at slot ``pos``.  The
    serve driver allocates the cache at max_len >= prompt + new tokens
    and copies the prefill K/V in (see launch/serve.py); the dry-run
    lowers decode_step directly against a full cache.
    """
    x = _embed_tokens(params, cfg, token)
    h, new_cache = transformer.decode(params["stack"], cfg, x, cache, pos)
    h = layers.rmsnorm(params["final_norm"], h, eps=cfg.rms_eps,
                       unit_offset=cfg.rms_unit_offset)
    return _head(params, cfg, h), new_cache
