"""Distribution layer: logical-axis sharding, meshes, gradient
compression, pipeline parallelism, elastic re-meshing."""

from repro.distributed.sharding import (Rules, constrain, current_ctx,
                                        resolve, spec_for, use_sharding)

__all__ = ["Rules", "constrain", "current_ctx", "resolve", "spec_for",
           "use_sharding"]
