"""Compressed gradient all-reduce (int8 ring), via shard_map.

The TPU analog of the paper's 8-bit word-length optimization, applied to
the DP gradient sync: a ring reduce-scatter whose wire format is int8
with one f32 scale per shard-chunk, followed by an int8 all-gather.
Wire volume: 2 x size/4 bytes vs 2 x size (f32 AR) — ~4x reduction, at
a bounded quantization error (tested).

Accumulation stays exact-ish: each hop dequantizes, adds in f32, and
requantizes, so error grows O(log-ish) with ring length rather than
compounding catastrophically; relative error is bounded by ~1/127 per
hop on the running partial sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as Ps


def _quant(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """All-reduce over mesh axis `axis` (static size ``n`` — the caller
    reads it off the mesh; jax<0.5 has no ``lax.axis_size``) with int8
    wire format.

    x: per-device f32 vector (flat, length % n == 0; caller pads).
    Classic two-phase ring: n-1 reduce-scatter hops + n-1 all-gather
    hops, each hop sending size/n int8 + one f32 scale.
    """
    me = jax.lax.axis_index(axis)
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 hops, device d owns the full sum of
    # chunk (d + 1) % n
    def rs_body(i, carry):
        acc = carry                       # (n, c) running per-chunk sums
        send_idx = (me - i) % n
        q, s = _quant(acc[send_idx])
        q2 = jax.lax.ppermute(q, axis, perm)
        s2 = jax.lax.ppermute(s, axis, perm)
        recv_idx = (me - i - 1) % n
        acc = acc.at[recv_idx].add(_dequant(q2, s2))
        return acc

    acc = jax.lax.fori_loop(0, n - 1, rs_body, chunks)
    own = (me + 1) % n                    # chunk this device fully owns

    # all-gather: circulate the owned chunk in int8
    out = jnp.zeros_like(chunks)
    q, s = _quant(acc[own])
    out = out.at[own].set(_dequant(q, s))

    def ag_body(i, carry):
        out_c, q_c, s_c = carry
        q2 = jax.lax.ppermute(q_c, axis, perm)
        s2 = jax.lax.ppermute(s_c, axis, perm)
        idx = (me - i) % n                # chunk that just arrived
        out_c = out_c.at[idx].set(_dequant(q2, s2))
        return out_c, q2, s2

    out, _, _ = jax.lax.fori_loop(0, n - 1, ag_body, (out, q, s))
    return out.reshape(x.shape)


def compressed_psum(tree, mesh: Mesh, axis: str = "data"):
    """Compressed all-reduce (sum) of a pytree of replicated-along-axis
    f32 arrays.  Returns the summed tree.  Used by the compressed train
    step to sync per-shard gradients over the DP axis."""
    flat, treedef = jax.tree.flatten(tree)
    sizes = [x.size for x in flat]
    n = mesh.shape[axis]
    cat = jnp.concatenate([x.reshape(-1) for x in flat])
    pad = (-cat.size) % n
    cat = jnp.pad(cat, (0, pad))

    spec = Ps(*(None,) * cat.ndim)

    @functools.partial(shard_map, mesh=mesh, in_specs=spec,
                       out_specs=spec, check_rep=False)
    def run(v):
        return _ring_allreduce_int8(v, axis, n)

    summed = run(cat)[:cat.size - pad if pad else None]
    if pad:
        summed = summed[:sum(sizes)]
    out, off = [], 0
    for x, size in zip(flat, sizes):
        out.append(summed[off:off + size].reshape(x.shape))
        off += size
    return jax.tree.unflatten(treedef, out)
