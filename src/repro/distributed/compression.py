"""8-bit wire formats: compressed gradient all-reduce + feature wire.

The TPU analog of the paper's 8-bit word-length optimization, applied
everywhere data crosses a link:

1. GRADIENT SYNC (``compressed_psum``): a ring reduce-scatter whose
   wire format is int8 with one f32 scale per shard-chunk, followed by
   an int8 all-gather.  Wire volume: 2 x size/4 bytes vs 2 x size
   (f32 AR) — ~4x reduction, at a bounded quantization error (tested).

   Accumulation stays exact-ish: each hop dequantizes, adds in f32, and
   requantizes, so error grows O(log-ish) with ring length rather than
   compounding catastrophically; relative error is bounded by ~1/127
   per hop on the running partial sum.

2. FEATURE / MATCH WIRE (``encode_features`` et al.): the serving tier
   ships frontend outputs off-accelerator (VO backend, fleet uplink).
   Descriptors are BIT PATTERNS, not magnitudes — they go over the wire
   as a lossless uint32 <-> 4-byte little-endian view (256 bits stay
   256 bits, Hamming distances unchanged); float fields (disparity,
   depth, coordinates) reuse the SAME int8+scale quantizer as the
   gradient ring (bounded relative error ~1/127 of the field's max);
   validity masks pack to one bit per entry; match distances fit uint16
   with a no-match sentinel.  Round-trip pins live in
   tests/test_precision.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as Ps

from repro.core.types import DepthSet, FeatureSet, MatchSet, PoseSet


def _quant(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """All-reduce over mesh axis `axis` (static size ``n`` — the caller
    reads it off the mesh; jax<0.5 has no ``lax.axis_size``) with int8
    wire format.

    x: per-device f32 vector (flat, length % n == 0; caller pads).
    Classic two-phase ring: n-1 reduce-scatter hops + n-1 all-gather
    hops, each hop sending size/n int8 + one f32 scale.
    """
    me = jax.lax.axis_index(axis)
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 hops, device d owns the full sum of
    # chunk (d + 1) % n
    def rs_body(i, carry):
        acc = carry                       # (n, c) running per-chunk sums
        send_idx = (me - i) % n
        q, s = _quant(acc[send_idx])
        q2 = jax.lax.ppermute(q, axis, perm)
        s2 = jax.lax.ppermute(s, axis, perm)
        recv_idx = (me - i - 1) % n
        acc = acc.at[recv_idx].add(_dequant(q2, s2))
        return acc

    acc = jax.lax.fori_loop(0, n - 1, rs_body, chunks)
    own = (me + 1) % n                    # chunk this device fully owns

    # all-gather: circulate the owned chunk in int8
    out = jnp.zeros_like(chunks)
    q, s = _quant(acc[own])
    out = out.at[own].set(_dequant(q, s))

    def ag_body(i, carry):
        out_c, q_c, s_c = carry
        q2 = jax.lax.ppermute(q_c, axis, perm)
        s2 = jax.lax.ppermute(s_c, axis, perm)
        idx = (me - i) % n                # chunk that just arrived
        out_c = out_c.at[idx].set(_dequant(q2, s2))
        return out_c, q2, s2

    out, _, _ = jax.lax.fori_loop(0, n - 1, ag_body, (out, q, s))
    return out.reshape(x.shape)


def compressed_psum(tree, mesh: Mesh, axis: str = "data"):
    """Compressed all-reduce (sum) of a pytree of replicated-along-axis
    f32 arrays.  Returns the summed tree.  Used by the compressed train
    step to sync per-shard gradients over the DP axis."""
    flat, treedef = jax.tree.flatten(tree)
    sizes = [x.size for x in flat]
    n = mesh.shape[axis]
    cat = jnp.concatenate([x.reshape(-1) for x in flat])
    pad = (-cat.size) % n
    cat = jnp.pad(cat, (0, pad))

    spec = Ps(*(None,) * cat.ndim)

    @functools.partial(shard_map, mesh=mesh, in_specs=spec,
                       out_specs=spec, check_rep=False)
    def run(v):
        return _ring_allreduce_int8(v, axis, n)

    summed = run(cat)[:cat.size - pad if pad else None]
    if pad:
        summed = summed[:sum(sizes)]
    out, off = [], 0
    for x, size in zip(flat, sizes):
        out.append(summed[off:off + size].reshape(x.shape))
        off += size
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Feature / match wire format (int8 + scale, lossless descriptor bytes)
# ---------------------------------------------------------------------------

#: uint16 sentinel for "no match" slots (right_index == -1 or distance
#: >= the kernels' MATCH_BIG).  Real Hamming distances are <= 256 and
#: real indices are < max_features (<= 1000), so the sentinel is
#: unambiguous.
WIRE_NO_MATCH = 0xFFFF

_BYTE_SHIFTS = jnp.arange(4, dtype=jnp.uint32) * jnp.uint32(8)


def encode_descriptors(desc: jnp.ndarray) -> jnp.ndarray:
    """(..., 8) uint32 rBRIEF descriptors -> (..., 32) uint8 wire bytes
    (little-endian per word).  LOSSLESS: descriptors are bit patterns —
    quantizing them like magnitudes would corrupt Hamming distances, so
    the wire format is a pure byte view."""
    d = desc.astype(jnp.uint32)
    b = (d[..., None] >> _BYTE_SHIFTS) & jnp.uint32(0xFF)
    return b.astype(jnp.uint8).reshape(desc.shape[:-1] + (32,))


def decode_descriptors(wire: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``encode_descriptors``: (..., 32) uint8 -> (..., 8)
    uint32, bit-exact."""
    b = wire.astype(jnp.uint32).reshape(wire.shape[:-1] + (8, 4))
    return jnp.sum(b << _BYTE_SHIFTS, axis=-1, dtype=jnp.uint32)


def quantize_f32(x: jnp.ndarray):
    """Public int8+scale quantizer — the gradient ring's wire format
    reused for float feature fields.  Returns (int8 codes, f32 scale);
    absolute error is bounded by scale/2 ~= max|x| / 254."""
    return _quant(x)


def dequantize_f32(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return _dequant(q, scale)


def _pack_mask(valid: jnp.ndarray) -> jnp.ndarray:
    flat = valid.reshape(-1).astype(jnp.uint8)
    pad = (-flat.size) % 8
    flat = jnp.pad(flat, (0, pad))
    bits = flat.reshape(-1, 8) << jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits, axis=-1, dtype=jnp.uint8)


def _unpack_mask(packed: jnp.ndarray, shape) -> jnp.ndarray:
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    n = int(np.prod(shape))
    return bits.reshape(-1)[:n].reshape(shape).astype(bool)


def _encode_u16(x: jnp.ndarray, no_value) -> jnp.ndarray:
    """int32 field -> uint16 with WIRE_NO_MATCH for ``no_value`` slots
    (sentinel comparison is >= so the kernels' MATCH_BIG maps too)."""
    x = x.astype(jnp.int32)
    bad = (x < 0) | (x >= jnp.int32(no_value))
    return jnp.where(bad, jnp.int32(WIRE_NO_MATCH), x).astype(jnp.uint16)


def _decode_u16(w: jnp.ndarray, no_value) -> jnp.ndarray:
    x = w.astype(jnp.int32)
    return jnp.where(x == WIRE_NO_MATCH, jnp.int32(no_value), x)


def encode_features(feat: FeatureSet) -> dict:
    """FeatureSet -> wire dict.  Descriptors lossless (uint8 bytes);
    xy/score/theta int8+scale (bounded error); level uint8; valid
    packed bits.  ~37 bytes/feature vs ~57 f32 — and the descriptor,
    the dominant field, crosses at exactly 32 bytes either way."""
    qxy, sxy = _quant(feat.xy)
    qsc, ssc = _quant(feat.score)
    qth, sth = _quant(feat.theta)
    return dict(
        desc=encode_descriptors(feat.desc),
        xy=qxy, xy_scale=sxy, score=qsc, score_scale=ssc,
        theta=qth, theta_scale=sth,
        level=feat.level.astype(jnp.uint8),
        valid=_pack_mask(feat.valid), k=int(feat.valid.shape[-1]),
        shape=tuple(feat.valid.shape))


def decode_features(wire: dict) -> FeatureSet:
    shape = wire["shape"]
    return FeatureSet(
        xy=_dequant(wire["xy"], wire["xy_scale"]),
        level=wire["level"].astype(jnp.int32),
        score=_dequant(wire["score"], wire["score_scale"]),
        theta=_dequant(wire["theta"], wire["theta_scale"]),
        desc=decode_descriptors(wire["desc"]),
        valid=_unpack_mask(wire["valid"], shape))


def encode_matches(matches: MatchSet) -> dict:
    """MatchSet -> wire dict: uint16 index/distance with a no-match
    sentinel (LOSSLESS — both fields are small ints), packed validity.

    Raises eagerly when the feature budget is too large for the uint16
    sentinel scheme: with K >= WIRE_NO_MATCH a legitimate
    ``right_index`` value can equal (or exceed and silently map to) the
    0xFFFF no-match sentinel, corrupting matches on decode with no
    error anywhere — the failure the eager check converts into a named
    ValueError at encode time."""
    k = int(matches.right_index.shape[-1])
    if k >= WIRE_NO_MATCH:
        raise ValueError(
            f"encode_matches: matches.right_index has K = {k} "
            f">= WIRE_NO_MATCH (0x{WIRE_NO_MATCH:04X}) — a legitimate "
            "match index would collide with the uint16 no-match "
            "sentinel and decode as 'no match'.  Shrink "
            "ORBConfig.max_features or widen the wire index field "
            "before shipping this set.")
    return dict(
        right_index=_encode_u16(matches.right_index, WIRE_NO_MATCH),
        distance=_encode_u16(matches.distance, WIRE_NO_MATCH),
        valid=_pack_mask(matches.valid),
        shape=tuple(matches.valid.shape))


def decode_matches(wire: dict, *, no_match_distance: int) -> MatchSet:
    """``no_match_distance`` restores the kernels' BIG sentinel (pass
    ``ops.NO_MATCH_DIST``) so decoded sets compare equal upstream."""
    return MatchSet(
        right_index=_decode_u16(wire["right_index"], -1),
        distance=_decode_u16(wire["distance"], no_match_distance),
        valid=_unpack_mask(wire["valid"], wire["shape"]))


def encode_depth(depth: DepthSet) -> dict:
    """DepthSet -> wire dict: disparity/depth/xy_right int8+scale
    (bounded relative error ~1/127), packed validity."""
    qd, sd = _quant(depth.disparity)
    qz, sz = _quant(depth.depth)
    qxy, sxy = _quant(depth.xy_right)
    return dict(disparity=qd, disparity_scale=sd,
                depth=qz, depth_scale=sz,
                xy_right=qxy, xy_right_scale=sxy,
                valid=_pack_mask(depth.valid),
                shape=tuple(depth.valid.shape))


def decode_depth(wire: dict) -> DepthSet:
    return DepthSet(
        disparity=_dequant(wire["disparity"], wire["disparity_scale"]),
        depth=_dequant(wire["depth"], wire["depth_scale"]),
        xy_right=_dequant(wire["xy_right"], wire["xy_right_scale"]),
        valid=_unpack_mask(wire["valid"], wire["shape"]))


def encode_pose(pose: PoseSet) -> dict:
    """PoseSet -> wire dict, LOSSLESS (raw f32/i32 + packed validity).

    The pose is the backend's *product* — the thing the accuracy gates
    certify — so unlike the bulky int8 feature/depth payloads it ships
    verbatim: 9 + 3 floats and one int per rig is noise next to the
    descriptor slabs, and quantizing it would corrupt exactly the
    quantity the fleet operator consumes."""
    valid = jnp.atleast_1d(jnp.asarray(pose.valid, bool))
    return dict(rotation=jnp.asarray(pose.rotation, jnp.float32),
                translation=jnp.asarray(pose.translation, jnp.float32),
                inliers=jnp.asarray(pose.inliers, jnp.int32),
                valid=_pack_mask(valid),
                shape=tuple(np.shape(pose.valid)))


def decode_pose(wire: dict) -> PoseSet:
    return PoseSet(
        rotation=wire["rotation"], translation=wire["translation"],
        inliers=wire["inliers"],
        valid=_unpack_mask(wire["valid"], wire["shape"]))


def encode_points(points: jnp.ndarray) -> dict:
    """Rig-frame 3-D points -> wire dict, LOSSLESS raw f32.  Validity
    is NOT duplicated here: a point is usable iff the feature and depth
    masks already on the wire say so (``features_l.valid & depth.valid``
    — what ``localization.state_from`` reconstructs on the far side)."""
    return dict(points=jnp.asarray(points, jnp.float32),
                shape=tuple(np.shape(points)))


def decode_points(wire: dict) -> jnp.ndarray:
    return wire["points"]


def wire_bytes(wire) -> int:
    """Total payload bytes of a wire dict (or nest of them) — array
    itemsizes only; keys/shape metadata ride the header."""
    total = 0
    for v in jax.tree.leaves(wire):
        if hasattr(v, "size") and hasattr(v, "dtype"):
            total += int(v.size) * int(np.dtype(v.dtype).itemsize)
    return total
