"""GPipe-style pipeline parallelism over a mesh axis, via shard_map.

The stack is split into S stages (params stacked on a leading stage
axis, sharded over the chosen mesh axis); a microbatched forward runs
the classic (M + S - 1)-tick schedule where activations hop stage ->
stage+1 through ``ppermute`` each tick.  Stage s sits idle for s ticks
(the pipeline bubble): utilization = M / (M + S - 1).

This is the optional PP wrapper (production cells default to DP over
the pod axis); it is demonstrated + compiled on a reduced config in the
dry-run and equivalence-tested against the serial stack in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as Ps


def pipeline_forward(stage_fn, mesh: Mesh, axis: str, stage_params,
                     x_micro: jnp.ndarray) -> jnp.ndarray:
    """Run ``stage_fn(params_s, x)`` over S stages for M microbatches.

    stage_params: pytree with leading stage axis (sharded over `axis`).
    x_micro: (M, micro_batch, ...) microbatched input (replicated).
    Returns (M, micro_batch, ...) outputs, as if applied serially.
    """
    n_stages = mesh.shape[axis]
    m = x_micro.shape[0]
    ticks = m + n_stages - 1

    p_spec = jax.tree.map(lambda _: Ps(axis), stage_params)
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(p_spec, Ps()), out_specs=Ps(),
        check_rep=False)
    def run(params, xm):
        params = jax.tree.map(lambda a: a[0], params)   # local stage slice
        sid = jax.lax.axis_index(axis)
        act = jnp.zeros_like(xm[0])
        out = jnp.zeros_like(xm)

        def tick(t, carry):
            act_c, out_c = carry
            # stage 0 ingests microbatch t; others take the hop input
            x_in = jnp.where(sid == 0,
                             xm[jnp.clip(t, 0, m - 1)], act_c)
            y = stage_fn(params, x_in)
            # completed microbatch index at the last stage
            done = t - (n_stages - 1)
            out_c = jax.lax.cond(
                (sid == n_stages - 1) & (done >= 0) & (done < m),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(done, 0), 0),
                lambda o: o, out_c)
            # hop activations to the next stage
            act_next = jax.lax.ppermute(y, axis, perm)
            return act_next, out_c

        act, out = jax.lax.fori_loop(0, ticks, tick, (act, out))
        # only the last stage holds real outputs; broadcast them
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)),
            axis)
        return out

    return run(stage_params, x_micro)
