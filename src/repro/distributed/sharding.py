"""Logical-axis sharding (MaxText-style), divisibility-aware.

Every parameter and activation is annotated with *logical* axis names
("batch", "heads", "ffn", ...).  A rule table maps each logical name to
an ordered tuple of mesh axes to try; the resolver takes the maximal
prefix of candidates whose cumulative product divides the dimension and
whose mesh axes are not already used in the same spec.  A mesh axis is
*skipped, never force-fit*: a 40-head dim on a 16-way "model" axis
resolves to unsharded rather than erroring, and the roofline table shows
the cost (that is a feature: baselines stay honest, hillclimbs fix them).

``use_sharding(mesh, rules)`` installs a context; ``constrain(x, *axes)``
is a no-op outside it, so model code is runnable un-meshed (CPU smoke
tests) and sharded (dry-run / production) without change.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# ---------------------------------------------------------------------------
# Rule tables


def _merge(*dicts) -> dict:
    out: dict = {}
    for d in dicts:
        out.update(d)
    return out


# Parameters.  "embed" marks the d_model-ish dim of weight matrices; in
# fsdp_tp mode it shards over "data" (ZeRO-3: XLA all-gathers per layer).
PARAM_RULES_TP: dict = {
    "layers": (),            # scan-stacked leading axis
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "qkv": (),
    "ffn": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "conv_dim": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (),
    None: (),
}

PARAM_RULES_FSDP_TP = _merge(PARAM_RULES_TP, {"embed": ("data",)})

# Activations.
ACT_RULES_BASE: dict = {
    "batch": ("pod", "data"),
    # Fleet batching (core.pipeline.VisualSystem.process_fleet): the
    # leading rig axis of a multi-rig frame batch is data-parallel.
    "rig": ("pod", "data"),
    "seq": (),               # context-parallel knob rewires to ("model",)
    # Megatron-style sequence parallelism: the RESIDUAL STREAM (and the
    # saved per-layer activations) shard their seq dim over "model";
    # XLA turns each block's TP all-reduce into all-gather + reduce-
    # scatter (same wire volume, 16x less activation memory).
    "res_seq": ("model",),
    # logits ALWAYS prefer vocab-sharding over seq-sharding: the loss
    # reduces over vocab, and full-vocab gather/one-hot buffers at 256k
    # vocab would dominate memory if seq grabbed the model axis first
    "logits_seq": (),
    "act_embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ffn": ("model",),
    "experts": ("model",),
    "capacity": (),
    "vocab": ("model",),
    "cache_seq": (),         # decode policy rewires to ("model",) etc.
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv_dim": ("model",),
    "layers": (),
    None: (),
}


@dataclasses.dataclass(frozen=True)
class Rules:
    """A resolved pair of rule tables for one (config x shape) cell."""

    params: Mapping[str, tuple]
    acts: Mapping[str, tuple]

    @staticmethod
    def make(sharding_mode: str = "fsdp_tp",
             seq_axes: tuple = (),
             cache_seq_axes: tuple = (),
             extra_acts: Mapping[str, tuple] | None = None,
             extra_params: Mapping[str, tuple] | None = None) -> "Rules":
        params = (PARAM_RULES_FSDP_TP if sharding_mode == "fsdp_tp"
                  else PARAM_RULES_TP)
        acts = _merge(ACT_RULES_BASE,
                      {"seq": tuple(seq_axes),
                       "cache_seq": tuple(cache_seq_axes)},
                      dict(extra_acts or {}))
        return Rules(params=_merge(params, dict(extra_params or {})),
                     acts=dict(acts))


# ---------------------------------------------------------------------------
# Resolver


def resolve(rules: Mapping[str, tuple], axes: Sequence[str | None],
            shape: Sequence[int], mesh: Mesh) -> PartitionSpec:
    """Logical axes -> PartitionSpec under divisibility + no-reuse."""
    assert len(axes) == len(shape), (axes, shape)
    sizes = dict(mesh.shape)        # works for Mesh and AbstractMesh
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        cand = rules.get(name, ())
        picked: list = []
        prod = 1
        for ax in cand:
            if ax in used or ax not in sizes:
                continue
            if dim % (prod * sizes[ax]) != 0:
                break                      # maximal divisible prefix
            picked.append(ax)
            prod *= sizes[ax]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    # strip trailing Nones for a tidy spec
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


# ---------------------------------------------------------------------------
# Context


class _Ctx(threading.local):
    def __init__(self):
        self.stack: list = []


_CTX = _Ctx()


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    rules: Rules


def current_ctx() -> ShardingCtx | None:
    return _CTX.stack[-1] if _CTX.stack else None


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Rules):
    _CTX.stack.append(ShardingCtx(mesh=mesh, rules=rules))
    try:
        yield
    finally:
        _CTX.stack.pop()


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a mesh)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = resolve(ctx.rules.acts, axes, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def shard_over(fn, mesh: Mesh, axis: str, arg_axis: int = 0):
    """``shard_map`` a single-argument function over ONE named mesh
    axis: dimension ``arg_axis`` of the argument is split across
    ``axis`` and every output leaf keeps that axis as its leading
    dimension.  Used by ``core.pipeline.VisualSystem`` to shard the
    fleet rig axis; the per-device program is the unmodified fused
    3-launch datapath."""
    from jax.experimental.shard_map import shard_map

    in_spec = PartitionSpec(*([None] * arg_axis + [axis]))
    return shard_map(fn, mesh=mesh, in_specs=(in_spec,),
                     out_specs=in_spec)


def spec_for(axes: Sequence[str | None], shape: Sequence[int],
             kind: str = "param") -> PartitionSpec:
    """Resolve a spec with the installed context (for in/out_shardings)."""
    ctx = current_ctx()
    assert ctx is not None, "spec_for needs use_sharding()"
    rules = ctx.rules.params if kind == "param" else ctx.rules.acts
    return resolve(rules, axes, shape, ctx.mesh)
