"""Elastic re-meshing: restore a checkpoint onto a different mesh.

The checkpoint stores full (unsharded) host arrays; re-sharding is a
device_put against the new mesh's resolved specs.  Combined with the
divisibility-aware resolver this lets a job restart on half (or double)
the chips after a pod failure — dims that no longer divide simply drop
that mesh axis instead of failing.

``remesh_tree`` is the shared idiom: full host arrays placed against
whatever mesh is CURRENT, not the one that produced them.  Training
restarts use it through ``reshard_restore``; the serving failover layer
(``repro.serving.failover``) uses ``surviving_mesh`` + ``remesh_tree``
to re-place a fleet's rig axis after a host fault domain dies.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import checkpoint
from repro.distributed.sharding import Rules, use_sharding
from repro.models.params import param_specs
from jax.sharding import NamedSharding


def remesh_tree(tree, mesh, specs):
    """device_put a tree of full host arrays against ``mesh`` under
    per-leaf ``specs`` — the elastic re-mesh idiom: the target mesh need
    not match (in size or topology) whatever produced the arrays."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                        shardings)


def surviving_mesh(mesh, domain_index: int, axis: str = "data"):
    """The mesh after losing one fault domain: drop index
    ``domain_index`` along ``axis`` and rebuild over the surviving
    devices.  Accepts ``AbstractMesh`` too (shape-only tests)."""
    sizes = dict(mesh.shape)
    if axis not in sizes:
        raise ValueError(f"surviving_mesh: mesh has no axis {axis!r} "
                         f"(axes: {tuple(sizes)})")
    n = int(sizes[axis])
    if not (0 <= domain_index < n):
        raise ValueError(f"surviving_mesh: domain {domain_index} out of "
                         f"range for axis {axis!r} of size {n}")
    if n < 2:
        raise ValueError(
            f"surviving_mesh: axis {axis!r} has a single fault domain — "
            "losing it is a fleet-wide outage, not a re-mesh")
    if isinstance(mesh, jax.sharding.AbstractMesh):
        return jax.sharding.AbstractMesh(
            tuple((name, n - 1 if name == axis else size)
                  for name, size in mesh.shape.items()))
    ax = tuple(mesh.axis_names).index(axis)
    devices = np.delete(np.asarray(mesh.devices), domain_index, axis=ax)
    return jax.sharding.Mesh(devices, mesh.axis_names)


def reshard_restore(ckpt_dir: str, step: int, like, schema, mesh,
                    rules: Rules):
    """Restore `like`-structured params onto `mesh` under `rules`."""
    with use_sharding(mesh, rules):
        specs = param_specs(schema)
    host = checkpoint.restore_array_tree(ckpt_dir, step, like)
    return remesh_tree(host, mesh, specs)
