"""Elastic re-meshing: restore a checkpoint onto a different mesh.

The checkpoint stores full (unsharded) host arrays; re-sharding is a
device_put against the new mesh's resolved specs.  Combined with the
divisibility-aware resolver this lets a job restart on half (or double)
the chips after a pod failure — dims that no longer divide simply drop
that mesh axis instead of failing.
"""

from __future__ import annotations

import jax

from repro import checkpoint
from repro.distributed.sharding import Rules, use_sharding
from repro.models.params import param_specs
from jax.sharding import NamedSharding


def reshard_restore(ckpt_dir: str, step: int, like, schema, mesh,
                    rules: Rules):
    """Restore `like`-structured params onto `mesh` under `rules`."""
    with use_sharding(mesh, rules):
        specs = param_specs(schema)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return checkpoint.restore(ckpt_dir, step, like, shardings)
