"""Localization backend: triangulation, robust pose solve, degeneracy,
session wiring, wire format, and the MIN_DISPARITY boundary pins.

The contract under test is "degeneracy is data": every pathological
input — too few correspondences, collapsed clouds, zero baselines, dead
cameras, non-finite garbage — must yield EXACTLY identity +
``valid=False``, never NaN, through the same jitted graph as a healthy
frame.  Accuracy itself is gated in benchmarks (``accuracy_gate/*``);
here we pin exactness, equivalence across entry points, and graceful
degradation monotonicity."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import localization
from repro.core import (ORBConfig, PipelineConfig, RigConfig,
                        VisualSystem)
from repro.core import matching
from repro.core.types import (CameraIntrinsics, FeatureSet,
                              LocalizationOutput,
                              MatchSet)
from repro.data import scenes
from repro.distributed import compression
from repro.localization import geometry, metrics, pose
from repro.serving import wire_decode, wire_encode

H, W = 96, 128
T, KMAX = 4, 96


@functools.lru_cache(maxsize=1)
def _scene():
    cfg = scenes.SceneConfig(height=H, width=W, baseline=0.5, seed=1)
    out = scenes.render_sequence(cfg, n_frames=T,
                                 step_t=(0.25, 0.0, 0.1),
                                 yaw_per_frame=0.0)
    return cfg, np.asarray(out.frames), out.poses, out.intrinsics


def _session(intr, localize=True, impl="ref", **pipe_kw):
    ocfg = ORBConfig(height=H, width=W, max_features=KMAX,
                     fast_threshold=15)
    return VisualSystem(
        RigConfig.quad(intr),
        PipelineConfig(orb=ocfg, impl=impl, localize=localize, **pipe_kw))


def _pose_np(p):
    return (np.asarray(p.rotation), np.asarray(p.translation),
            np.asarray(p.inliers), np.asarray(p.valid))


def _assert_finite_pose(p):
    for leaf in _pose_np(p)[:2]:
        assert np.isfinite(leaf).all(), leaf


# -- S1: the MIN_DISPARITY boundary ------------------------------------------

def test_min_disparity_boundary_unit():
    """At exactly MIN_DISPARITY the gate is strict (invalid, depth 0);
    just above, the depth divisor is the RAW disparity (the clamp is
    bit-exact identity for every valid lane)."""
    cfg = ORBConfig(height=H, width=W, max_features=4)
    intr = CameraIntrinsics(fx=100.0, baseline=0.5)
    fxb = 100.0 * 0.5
    eps = 0.25
    d = np.array([matching.MIN_DISPARITY,          # exactly at -> invalid
                  matching.MIN_DISPARITY + eps,    # just above -> valid
                  0.0,                             # no parallax -> invalid
                  -2.0], np.float32)               # crossed     -> invalid
    x_l = jnp.asarray([40.0, 40.0, 40.0, 40.0], jnp.float32)
    rxy = jnp.stack([x_l - jnp.asarray(d), jnp.full(4, 7.0)], axis=-1)
    m = MatchSet(right_index=jnp.zeros(4, jnp.int32),
                 distance=jnp.zeros(4, jnp.int32),
                 valid=jnp.ones(4, bool))
    ds = matching._depth_set(x_l, rxy, jnp.zeros(4, jnp.float32), m,
                             cfg, intr)
    np.testing.assert_array_equal(np.asarray(ds.valid),
                                  [False, True, False, False])
    assert float(ds.depth[0]) == 0.0 and float(ds.disparity[0]) == 0.0
    # raw-divisor pin: bit-exact against the unclamped division
    want = np.float32(fxb) / np.float32(matching.MIN_DISPARITY + eps)
    assert float(ds.depth[1]) == float(want)
    assert np.asarray(ds.depth)[2:].tolist() == [0.0, 0.0]
    assert np.isfinite(np.asarray(ds.depth)).all()


#: Lane disparities the boundary pair bakes into its images/features:
#: 0.0 and 0.5 must come out INVALID (strict gate), the integers VALID.
_BOUNDARY_DISPS = (0.0, 0.5, 1.0, 2.0, 3.0, 4.0)


def _boundary_pair():
    """Deterministic stereo pair whose lanes straddle MIN_DISPARITY.

    One lane per 16-row band (so the 11x11 SAD windows never mix
    bands); each lane's left/right descriptors are identical (Hamming
    0) and its band of the RIGHT image is the left ramp shifted by the
    lane's integer disparity, so the SAD argmin is uniquely offset 0
    and the decoded disparity is EXACTLY ``x_l - x_r``.  The half-pixel
    lane keeps shift 0: whichever integer the SAD snaps to, its
    disparity lands at +-0.5 — at/below the strict gate either way."""
    disp = np.asarray(_BOUNDARY_DISPS, np.float32)
    k = len(disp)
    rng = np.random.RandomState(7)
    desc = jnp.asarray(rng.randint(0, 2**32, (k, 8), dtype=np.uint64)
                       .astype(np.uint32))
    ys = 12.0 + 16.0 * np.arange(k, dtype=np.float32)
    x_r = np.full(k, 40.0, np.float32)
    feat = dict(level=jnp.zeros(k, jnp.int32),
                score=jnp.ones(k, jnp.float32),
                theta=jnp.zeros(k, jnp.float32), desc=desc,
                valid=jnp.ones(k, bool))
    fl = FeatureSet(xy=jnp.asarray(np.stack([x_r + disp, ys], 1)), **feat)
    fr = FeatureSet(xy=jnp.asarray(np.stack([x_r, ys], 1)), **feat)
    col = np.arange(W, dtype=np.float32) * 2.0
    img_l = np.tile(col, (H, 1))
    img_r = np.empty_like(img_l)
    shifts = np.zeros(H, np.float32)
    for i, d in enumerate(disp):
        shifts[int(ys[i]) - 8:int(ys[i]) + 8] = np.floor(d)
    for y in range(H):
        img_r[y] = col + 2.0 * shifts[y]
    return fl, fr, jnp.asarray(img_l)[None], jnp.asarray(img_r)[None], disp


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_min_disparity_boundary_through_fused_matcher(impl):
    """End-to-end through ``match_pair_fused`` on BOTH impls (ref and
    pallas-interpret): the 0 px and 0.5 px lanes are invalid with depth
    exactly 0; every valid lane's depth divides by the RAW disparity
    (bit-exact against the unclamped f32 division)."""
    fl, fr, img_l, img_r, disp = _boundary_pair()
    cfg = ORBConfig(height=H, width=W, max_features=8, max_disparity=16)
    intr = CameraIntrinsics(fx=120.0, baseline=0.4)
    fl = jax.tree.map(lambda x: x[None], fl)
    fr = jax.tree.map(lambda x: x[None], fr)
    matches, depth = matching.match_pair_fused(
        img_l, img_r, fl, fr, cfg, intr, impl=impl)
    assert np.asarray(matches.valid)[0].all()   # every lane matched...
    v = np.asarray(depth.valid)[0]
    got_disp = np.asarray(depth.disparity)[0]
    got_depth = np.asarray(depth.depth)[0]
    # ...but sub-boundary disparity kills the depth observation
    np.testing.assert_array_equal(v, disp > matching.MIN_DISPARITY)
    np.testing.assert_array_equal(got_disp[:2], [0.0, 0.0])
    np.testing.assert_array_equal(got_depth[:2], [0.0, 0.0])
    np.testing.assert_array_equal(got_disp[2:], disp[2:])
    want = np.float32(120.0 * 0.4) / disp[2:].astype(np.float32)
    np.testing.assert_array_equal(got_depth[2:], want)
    assert np.isfinite(got_depth).all()


def test_min_disparity_fused_ref_equals_pallas():
    fl, fr, img_l, img_r, _ = _boundary_pair()
    cfg = ORBConfig(height=H, width=W, max_features=8, max_disparity=16)
    intr = CameraIntrinsics(fx=120.0, baseline=0.4)
    fl = jax.tree.map(lambda x: x[None], fl)
    fr = jax.tree.map(lambda x: x[None], fr)
    a = matching.match_pair_fused(img_l, img_r, fl, fr, cfg, intr,
                                  impl="ref")
    b = matching.match_pair_fused(img_l, img_r, fl, fr, cfg, intr,
                                  impl="pallas")
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- triangulation -----------------------------------------------------------

def test_backproject_exact():
    intr = CameraIntrinsics(fx=100.0, fy=50.0, cx=10.0, cy=20.0)
    xy = jnp.asarray([[110.0, 70.0]])
    pts = geometry.backproject(xy, jnp.asarray([4.0]), intr.fx, intr.fy,
                               intr.cx, intr.cy)
    np.testing.assert_allclose(np.asarray(pts), [[4.0, 4.0, 4.0]],
                               atol=1e-6)
    # invalid lane contract: depth 0 -> exactly the origin
    zero = geometry.backproject(xy, jnp.asarray([0.0]), intr.fx, intr.fy,
                                intr.cx, intr.cy)
    np.testing.assert_array_equal(np.asarray(zero), [[0.0, 0.0, 0.0]])


def test_rig_points_fuses_back_pair():
    """The quad rig's back pair looks along -z: a point at camera-frame
    (x, y, z) lands at rig-frame (-x, y, -z); the front pair is
    identity."""
    rig = RigConfig.quad(CameraIntrinsics(fx=100.0, fy=100.0, cx=0.0,
                                          cy=0.0))
    xy = jnp.asarray([[[100.0, 50.0]], [[100.0, 50.0]]])   # (P=2, K=1, 2)
    z = jnp.asarray([[2.0], [2.0]])
    pts = np.asarray(geometry.rig_points(xy, z, rig))
    np.testing.assert_allclose(pts[0, 0], [2.0, 1.0, 2.0], atol=1e-5)
    np.testing.assert_allclose(pts[1, 0], [-2.0, 1.0, -2.0], atol=1e-5)


def test_rig_points_rejects_wrong_pair_axis():
    rig = RigConfig.quad()
    with pytest.raises(ValueError, match="pair axis"):
        geometry.rig_points(jnp.zeros((3, 4, 2)), jnp.zeros((3, 4)), rig)


# -- the robust solve --------------------------------------------------------

def _cloud(rng, n=64):
    return rng.uniform(-4.0, 4.0, (n, 3)).astype(np.float32)


def _rot_y(a):
    c, s = np.cos(a), np.sin(a)
    return np.asarray([[c, 0, s], [0, 1, 0], [-s, 0, c]], np.float32)


def test_solve_pose_recovers_known_motion_with_outliers():
    rng = np.random.RandomState(0)
    pts = _cloud(rng)
    r = _rot_y(0.05)
    t = np.asarray([0.3, -0.1, 0.2], np.float32)
    curr = pts @ r.T + t
    # 25% metre-scale outliers that the top-K reweighting must shed
    out_idx = rng.choice(len(pts), 16, replace=False)
    curr[out_idx] += rng.uniform(2.0, 5.0, (16, 3)).astype(np.float32)
    est = pose.solve_pose(jnp.asarray(pts), jnp.asarray(curr),
                          jnp.ones(len(pts)))
    rr, tt, inl, valid = _pose_np(est)
    assert bool(valid)
    # the top-K loop trims support toward keep_frac^iters of the pool;
    # what matters is that the kept support excludes the outliers and
    # the pose is right
    assert int(inl) >= pose.MIN_CORRESPONDENCES
    np.testing.assert_allclose(rr, r, atol=1e-3)
    np.testing.assert_allclose(tt, t, atol=1e-2)


def test_solve_pose_degenerate_inputs_never_nan():
    rng = np.random.RandomState(1)
    pts = jnp.asarray(_cloud(rng, 16))
    eye = np.eye(3, dtype=np.float32)
    cases = {
        "all_invalid": jnp.zeros(16),
        "two_points": jnp.asarray([1.0, 1.0] + [0.0] * 14),
    }
    for name, w in cases.items():
        est = pose.solve_pose(pts, pts, w)
        rr, tt, _, valid = _pose_np(est)
        assert not bool(valid), name
        np.testing.assert_array_equal(rr, eye, err_msg=name)
        np.testing.assert_array_equal(tt, np.zeros(3), err_msg=name)
    # collapsed cloud: every point at the origin (zero-baseline depth)
    zero = jnp.zeros((16, 3))
    est = pose.solve_pose(zero, zero, jnp.ones(16))
    rr, tt, _, valid = _pose_np(est)
    assert not bool(valid)
    np.testing.assert_array_equal(rr, eye)
    # non-finite correspondences are scrubbed, not propagated
    bad = pts.at[:8].set(jnp.nan)
    est = pose.solve_pose(bad, bad, jnp.ones(16))
    _assert_finite_pose(est)
    est = pose.solve_pose(jnp.full((16, 3), jnp.nan),
                          jnp.full((16, 3), jnp.nan), jnp.ones(16))
    rr, tt, _, valid = _pose_np(est)
    assert not bool(valid)
    assert np.isfinite(rr).all() and np.isfinite(tt).all()


def test_solve_pose_batched_matches_loop():
    rng = np.random.RandomState(2)
    pts = np.stack([_cloud(rng, 24) for _ in range(3)])
    curr = pts + np.asarray([0.1, 0.0, -0.2], np.float32)
    w = np.ones((3, 24), np.float32)
    batched = pose.solve_pose_batched(jnp.asarray(pts),
                                      jnp.asarray(curr), jnp.asarray(w))
    for b in range(3):
        single = pose.solve_pose(jnp.asarray(pts[b]),
                                 jnp.asarray(curr[b]), jnp.asarray(w[b]))
        for la, lb in zip(jax.tree.leaves(single),
                          jax.tree.leaves(jax.tree.map(lambda x: x[b],
                                                       batched))):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- session wiring ----------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _run_localized():
    cfg, frames, poses, intr = _scene()
    vs = _session(intr)
    return vs.run(jnp.asarray(frames)), poses


def test_run_returns_localization_output():
    out, poses = _run_localized()
    assert isinstance(out, LocalizationOutput)
    rig = RigConfig.quad()
    assert out.points.shape == (T, rig.n_pairs, KMAX, 3)
    assert out.pose.rotation.shape == (T, 3, 3)
    # delegation keeps the stereo API readable on the wrapped output
    assert out.matches.valid.shape == (T, rig.n_pairs, KMAX)
    # frame 0 has no predecessor: identity + invalid, by construction
    rr, tt, inl, valid = _pose_np(out.pose)
    assert not valid[0] and valid[1:].all()
    np.testing.assert_array_equal(rr[0], np.eye(3, dtype=np.float32))
    assert np.isfinite(rr).all() and np.isfinite(tt).all()


def test_run_accuracy_against_ground_truth():
    """The sequence solve tracks the constant-twist ground truth: ATE
    well under the travelled distance and every per-step estimate close
    to the true relative motion (thresholds are ~2x measured)."""
    out, poses = _run_localized()
    m = metrics.trajectory_metrics(out.pose.rotation,
                                   out.pose.translation, poses)
    assert m["travel_m"] > 0.5
    assert m["ate_rmse_m"] <= 0.4, m
    assert m["rpe_trans_rmse_m"] <= 0.25, m
    assert m["rpe_rot_mean_deg"] <= 1.0, m


def test_process_frame_loop_matches_run():
    """The stateful per-frame loop and the one-shot sequence solve are
    the same computation (the T-1 transitions just fold into one
    launch)."""
    cfg, frames, _, intr = _scene()
    run_out, _ = _run_localized()
    vs = _session(intr)
    vs.reset_localization()
    rots, trs, valids = [], [], []
    for t in range(T):
        out = vs.process_frame(jnp.asarray(frames[t]))
        assert isinstance(out, LocalizationOutput)
        rr, tt, _, valid = _pose_np(out.pose)
        rots.append(rr), trs.append(tt), valids.append(bool(valid))
    np.testing.assert_array_equal(valids, np.asarray(run_out.pose.valid))
    np.testing.assert_allclose(np.stack(rots),
                               np.asarray(run_out.pose.rotation),
                               atol=1e-5)
    np.testing.assert_allclose(np.stack(trs),
                               np.asarray(run_out.pose.translation),
                               atol=1e-4)


def test_fleet_matches_per_frame():
    """Two identical rigs in a fleet localize exactly like the single
    frame path (the rig axis folds into the matcher grid + vmap)."""
    cfg, frames, _, intr = _scene()
    vs = _session(intr)
    vs.reset_localization()
    singles = [vs.process_frame(jnp.asarray(frames[t]))
               for t in range(2)]
    vf = _session(intr)
    fleet = jnp.asarray(np.stack([frames[:2], frames[:2]], axis=1))
    prev = None
    for t in range(2):
        fout = vf.process_fleet(fleet[t])
        assert isinstance(fout, LocalizationOutput)
    for b in range(2):
        np.testing.assert_allclose(
            np.asarray(fout.pose.translation)[b],
            np.asarray(singles[1].pose.translation), atol=1e-4)
        assert bool(np.asarray(fout.pose.valid)[b]) \
            == bool(singles[1].pose.valid)


def test_explicit_prev_overrides_session_state():
    cfg, frames, _, intr = _scene()
    vs = _session(intr)
    vs.reset_localization()
    out0 = vs.process_frame(jnp.asarray(frames[0]))
    state0 = localization.state_from(out0)
    out1 = vs.process_frame(jnp.asarray(frames[1]))
    assert bool(out1.pose.valid)
    # replaying frame 1 against an explicit zero state -> invalid
    vs.reset_localization()
    zero = localization.zero_state(vs.rig.n_pairs, KMAX)
    out1z = vs.process_frame(jnp.asarray(frames[1]), prev=zero)
    assert not bool(out1z.pose.valid)
    # and against the explicit frame-0 state -> the same pose again
    out1e = vs.process_frame(jnp.asarray(frames[1]), prev=state0)
    np.testing.assert_allclose(np.asarray(out1e.pose.translation),
                               np.asarray(out1.pose.translation),
                               atol=1e-5)


def test_prev_validation_errors():
    cfg, frames, _, intr = _scene()
    vs = _session(intr)
    with pytest.raises(TypeError, match="LocalizationState"):
        vs.process_frame(jnp.asarray(frames[0]), prev=np.zeros(3))
    bad = localization.zero_state(vs.rig.n_pairs, KMAX + 1)
    with pytest.raises(ValueError, match="prev.points"):
        vs.process_frame(jnp.asarray(frames[0]), prev=bad)


def test_zero_baseline_rig_invalid_not_nan():
    """A zero-baseline rig has no depth: every point collapses to the
    origin and the pose must come out identity + invalid — finite,
    through the same graph."""
    cfg, frames, _, intr = _scene()
    import dataclasses
    zb = dataclasses.replace(intr, baseline=0.0)
    vs = _session(zb)
    for t in range(2):
        out = vs.process_frame(jnp.asarray(frames[t]))
        _assert_finite_pose(out.pose)
        assert not bool(out.pose.valid)
        np.testing.assert_array_equal(np.asarray(out.points), 0.0)


def test_masked_fleet_pose_graceful():
    """Dead cameras degrade accuracy, never NaN: a rig with a dead back
    pair still localizes from the front pair; an all-dead rig is
    identity + invalid; healthy rigs are unaffected."""
    cfg, frames, _, intr = _scene()
    vs = _session(intr)
    fleet = jnp.asarray(np.stack([frames, frames, frames], axis=1))
    mask = np.ones((3, 4), bool)
    mask[1, 2:] = False          # rig 1: back pair dead
    mask[2, :] = False           # rig 2: fully dead
    prev_pose = None
    for t in range(2):
        out = vs.process_fleet(fleet[t], camera_mask=jnp.asarray(mask))
        _assert_finite_pose(out.pose)
    valid = np.asarray(out.pose.valid)
    assert valid[0] and valid[1]
    assert not valid[2]
    np.testing.assert_array_equal(
        np.asarray(out.pose.rotation)[2], np.eye(3, dtype=np.float32))
    # healthy rig matches the unmasked single-frame path
    vs2 = _session(intr)
    vs2.reset_localization()
    for t in range(2):
        single = vs2.process_frame(jnp.asarray(frames[t]))
    np.testing.assert_allclose(np.asarray(out.pose.translation)[0],
                               np.asarray(single.pose.translation),
                               atol=1e-4)


def test_localized_launch_budget():
    """Frame budget with localization: 3 frontend + 1 backend = 4
    launches, frame and fleet, masked or not; a non-localized session
    stays at 3; a localized RUN costs 3 per scan step + 1 total."""
    cfg, frames, _, intr = _scene()
    vs = _session(intr)
    im = jnp.asarray(frames[0])
    fleet = jnp.asarray(np.stack([frames[0]] * 2))
    assert vs.traced_launches("process_frame", im) == 4
    assert vs.traced_launches("process_frame", im,
                              jnp.ones(4, bool)) == 4
    assert vs.traced_launches("process_fleet", fleet) == 4
    off = _session(intr, localize=False)
    assert off.traced_launches("process_frame", im) == 3
    # a localized RUN adds exactly ONE launch to the traced graph for
    # ALL T-1 transitions (the scan body's 3 launches appear once)
    seq = jnp.asarray(frames)
    assert vs.traced_launches("run", seq) \
        == off.traced_launches("run", seq) + 1 == 4


# -- wire format (S3) --------------------------------------------------------

def test_wire_roundtrip_localization_output():
    out, _ = _run_localized()
    one = jax.tree.map(lambda x: x[1], out)
    wire = wire_encode(one)
    back = wire_decode(wire)
    assert isinstance(back, LocalizationOutput)
    np.testing.assert_array_equal(np.asarray(back.points),
                                  np.asarray(one.points))
    for la, lb in zip(jax.tree.leaves(back.pose),
                      jax.tree.leaves(one.pose)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # a stereo-only wire dict still decodes to a StereoOutput
    stereo_back = wire_decode(wire_encode(one.stereo))
    assert not isinstance(stereo_back, LocalizationOutput)
    # and the localized payload accounts for the extra fields
    assert compression.wire_bytes(wire) \
        > compression.wire_bytes(wire_encode(one.stereo))


def test_wire_pose_batched_roundtrip():
    out, _ = _run_localized()
    wire = compression.encode_pose(out.pose)
    back = compression.decode_pose(wire)
    for la, lb in zip(jax.tree.leaves(back), jax.tree.leaves(out.pose)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_wire_encode_matches_rejects_sentinel_collision():
    k = compression.WIRE_NO_MATCH
    m = MatchSet(right_index=jnp.zeros((1, k), jnp.int32),
                 distance=jnp.zeros((1, k), jnp.int32),
                 valid=jnp.zeros((1, k), bool))
    with pytest.raises(ValueError, match="right_index"):
        compression.encode_matches(m)
    # one below the sentinel is the last legal budget
    m_ok = jax.tree.map(lambda x: x[:, :-1], m)
    compression.encode_matches(m_ok)


# -- graceful-degradation sweeps (S4) ----------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=20)
    @given(seed=st.integers(0, 2**16), n_rigs=st.integers(1, 4))
    def test_prop_solver_rig_count_invariant(seed, n_rigs):
        """vmapping the solve over any rig count reproduces the
        single-rig result bit for bit on every row."""
        rng = np.random.RandomState(seed)
        pts = _cloud(rng, 32)
        curr = pts @ _rot_y(0.03).T + np.asarray([0.2, 0.0, -0.1],
                                                 np.float32)
        w = (rng.uniform(size=32) > 0.2).astype(np.float32)
        single = pose.solve_pose(jnp.asarray(pts), jnp.asarray(curr),
                                 jnp.asarray(w))
        tile = lambda x: jnp.asarray(np.stack([x] * n_rigs))
        batched = pose.solve_pose_batched(tile(pts), tile(curr), tile(w))
        for b in range(n_rigs):
            for la, lb in zip(jax.tree.leaves(single),
                              jax.tree.leaves(jax.tree.map(
                                  lambda x: x[b], batched))):
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb))

    @settings(max_examples=15)
    @given(seed=st.integers(0, 2**16))
    def test_prop_noise_monotone_graceful(seed):
        """Scaling the SAME noise draw up never improves the pose: the
        translation error is monotone in the noise level, and even at
        metre-scale noise the solve stays finite (identity + invalid at
        worst) — graceful degradation, not collapse."""
        rng = np.random.RandomState(seed)
        pts = _cloud(rng, 48)
        t_true = np.asarray([0.3, -0.2, 0.1], np.float32)
        curr0 = pts + t_true
        unit = rng.normal(size=(48, 3)).astype(np.float32)
        errs = []
        for sigma in (0.0, 0.05, 0.5):
            est = pose.solve_pose(jnp.asarray(pts),
                                  jnp.asarray(curr0 + sigma * unit),
                                  jnp.ones(48))
            _assert_finite_pose(est)
            errs.append(float(np.linalg.norm(
                np.asarray(est.translation) - t_true)))
        assert errs[0] <= 1e-4
        assert errs[0] <= errs[1] + 1e-6 <= errs[2] + 2e-6, errs

    @settings(max_examples=10)
    @given(n_dead=st.integers(0, 4))
    def test_prop_dead_cameras_monotone_valid(n_dead):
        """Killing cameras only ever shrinks the usable-correspondence
        pool: inlier count is non-increasing in the number of dead
        cameras, validity flips off (never NaN) once both pairs die."""
        cfg, frames, _, intr = _scene()
        vs = _session(intr)
        vs.reset_localization()
        mask = np.ones(4, bool)
        mask[:n_dead] = False
        for t in range(2):
            out = vs.process_frame(jnp.asarray(frames[t]),
                                   camera_mask=jnp.asarray(mask))
            _assert_finite_pose(out.pose)
        if n_dead == 0:
            assert bool(out.pose.valid)
        if n_dead >= 3:        # both pairs broken -> no stereo at all
            assert not bool(out.pose.valid)
