"""Whole-frame fused extraction vs the per-level oracle pipeline.

The whole-frame schedule (ONE dense + ONE sparse launch per frame for
all cameras x all pyramid levels) must be BIT-exact against the
per-level pipeline (``orb.extract_features_per_level`` — 2 launches per
level) on every FeatureSet field, on both the jnp fallback and the
Pallas interpret path, for ragged/odd level shapes, boundary keypoints
and all-invalid levels.  A traced launch-count assertion pins the
2-launch budget (3 for a full quad frame with the fused FM).

Deterministic parametrized pins run everywhere; the Hypothesis property
suite (random camera counts, shapes, level counts, thresholds) runs
where hypothesis is installed (CI) under the fixed-seed profile from
``conftest.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CameraIntrinsics, ORBConfig, PipelineConfig,
                        RigConfig, VisualSystem,
                        extract_features_batched, extract_features_per_level)
from repro.core import pyramid
from repro.kernels import ops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # dev-only dep; property tests skip
    HAVE_HYPOTHESIS = False


def _imgs(seed, b, h, w):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 256, (b, h, w)).astype(np.float32))


def _assert_featureset_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg} field {f}")


def _levels(seed, b, shapes):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randint(0, 256, (b, h, w)).astype(np.float32))
            for h, w in shapes]


# ---------------------------------------------------------------------------
# Dense stage: ops.fast_blur_nms_pyramid vs per-level dispatch.

RAGGED = [(70, 111), (58, 93), (37, 53)]       # non-square, odd, < 1 tile


@pytest.mark.parametrize("nms", [True, False])
@pytest.mark.parametrize("quantized", [True, False])
def test_dense_pyramid_bitexact_vs_per_level(nms, quantized):
    levels = _levels(3, 2, RAGGED)
    for impl in ("ref", "pallas"):
        outs = ops.fast_blur_nms_pyramid(levels, 20.0, nms=nms,
                                         quantized=quantized, impl=impl)
        assert len(outs) == len(levels)
        for lvl, (lv, (blur, score)) in enumerate(zip(levels, outs)):
            want_b, want_s = ops.fast_blur_nms_batched(
                lv, 20.0, nms=nms, quantized=quantized, impl="ref")
            if impl == "pallas" and not quantized:
                # float blur divides inside the kernel: last-ulp drift vs
                # the jnp oracle — same tolerance as the per-level
                # test_fused_flag_combinations; quantized (the pipeline
                # default) is bit-exact.
                np.testing.assert_allclose(
                    np.asarray(blur), np.asarray(want_b), rtol=1e-5,
                    atol=1e-4, err_msg=f"{impl} blur level {lvl}")
            else:
                np.testing.assert_array_equal(
                    np.asarray(blur), np.asarray(want_b),
                    err_msg=f"{impl} blur level {lvl}")
            np.testing.assert_array_equal(
                np.asarray(score), np.asarray(want_s),
                err_msg=f"{impl} score level {lvl}")


@pytest.mark.parametrize("nms", [True, False])
@pytest.mark.parametrize("quantized", [True, False])
def test_dense_pyramid_stacked_jnp_oracle_bitexact(nms, quantized):
    """The stacked jnp mirror of the kernel's ragged-padding semantics
    (ONE pass over the common canvas + true-shape masking) must be
    bit-exact against the per-level fallback — an independent pin of the
    padding logic that doesn't go through Pallas interpret mode."""
    levels = _levels(13, 2, RAGGED)
    outs = ops.fast_blur_nms_pyramid_stacked_jnp(
        levels, 20.0, nms=nms, quantized=quantized)
    for lvl, (lv, (blur, score)) in enumerate(zip(levels, outs)):
        want_b, want_s = ops.fast_blur_nms_batched(
            lv, 20.0, nms=nms, quantized=quantized, impl="ref")
        np.testing.assert_array_equal(np.asarray(blur), np.asarray(want_b),
                                      err_msg=f"blur level {lvl}")
        np.testing.assert_array_equal(np.asarray(score),
                                      np.asarray(want_s),
                                      err_msg=f"score level {lvl}")


def test_dense_pyramid_single_level_degenerates_to_batched():
    levels = _levels(4, 3, [(96, 128)])
    for impl in ("ref", "pallas"):
        (blur, score), = ops.fast_blur_nms_pyramid(levels, 15.0, impl=impl)
        want_b, want_s = ops.fast_blur_nms_batched(levels[0], 15.0,
                                                   impl=impl)
        np.testing.assert_array_equal(np.asarray(blur), np.asarray(want_b))
        np.testing.assert_array_equal(np.asarray(score), np.asarray(want_s))


def test_dense_pyramid_corner_on_small_level_boundary():
    """A corner on the last row/col of the SMALLEST level must survive:
    its NMS neighbours are the -1 mask sentinels of the common-canvas
    padding, never edge-replicated garbage from the bigger canvas."""
    shapes = [(130, 131), (66, 67)]
    levels = []
    for h, w in shapes:
        img = np.full((1, h, w), 10.0, np.float32)
        img[:, h - 6:, w - 6:] = 220.0
        levels.append(jnp.asarray(img))
    out_ref = ops.fast_blur_nms_pyramid(levels, 20.0, impl="ref")
    out_pl = ops.fast_blur_nms_pyramid(levels, 20.0, impl="pallas")
    for (br, sr), (bp, sp) in zip(out_ref, out_pl):
        np.testing.assert_array_equal(np.asarray(sr), np.asarray(sp))
        np.testing.assert_array_equal(np.asarray(br), np.asarray(bp))
        assert float(jnp.sum(sr > 0)) > 0


# ---------------------------------------------------------------------------
# Sparse stage: ops.orient_describe_pyramid vs per-level dispatch.

def test_sparse_pyramid_bitexact_vs_per_level():
    levels = _levels(5, 2, RAGGED)
    sms = [ops.fast_blur_nms_batched(lv, 20.0, impl="ref")[0]
           for lv in levels]
    rng = np.random.RandomState(6)
    # K not a KP_BLOCK multiple, and coords spanning borders AND
    # out-of-range values (top-K padding rows carry arbitrary coords)
    xys = []
    for lv, k in zip(levels, (21, 8, 5)):
        h, w = lv.shape[1], lv.shape[2]
        xy = np.stack([rng.randint(-7, w + 7, (2, k)),
                       rng.randint(-7, h + 7, (2, k))], -1)
        xy[:, 0] = [0, 0]
        xy[:, -1] = [w - 1, h - 1]
        xys.append(jnp.asarray(xy.astype(np.int32)))
    out_ref = ops.orient_describe_pyramid(levels, sms, xys, impl="ref")
    out_pl = ops.orient_describe_pyramid(levels, sms, xys, impl="pallas")
    for lvl, (lv, sm, xy) in enumerate(zip(levels, sms, xys)):
        want = ops.orient_describe_batched(lv, sm, xy, impl="ref")
        for name, a, b, c in zip(("theta", "moments", "desc"),
                                 out_ref[lvl], out_pl[lvl], want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c),
                                          err_msg=f"ref {name} lvl {lvl}")
            np.testing.assert_array_equal(np.asarray(b), np.asarray(c),
                                          err_msg=f"pallas {name} lvl {lvl}")
            assert np.isfinite(np.asarray(a)).all() or name == "desc"


# ---------------------------------------------------------------------------
# Full extractor: whole-frame vs per-level pipeline.

@pytest.mark.parametrize("b,shape,n_levels", [
    (1, (64, 96), 1),
    (2, (70, 111), 3),       # odd ragged shapes
    (4, (96, 128), 2),       # the quad rig
    (3, (37, 53), 5),        # image smaller than one dense tile, deep
])
def test_whole_frame_extractor_equals_per_level_ref(b, shape, n_levels):
    imgs = _imgs(7, b, *shape)
    cfg = ORBConfig(height=shape[0], width=shape[1], max_features=48,
                    n_levels=n_levels)
    whole = extract_features_batched(imgs, cfg, impl="ref")
    per = extract_features_per_level(imgs, cfg, impl="ref")
    _assert_featureset_equal(whole, per, f"ref b={b} {shape} L={n_levels}")


@pytest.mark.parametrize("b,shape,n_levels", [
    (2, (70, 111), 2),
    (4, (64, 96), 2),
])
def test_whole_frame_extractor_equals_per_level_pallas(b, shape, n_levels):
    imgs = _imgs(8, b, *shape)
    cfg = ORBConfig(height=shape[0], width=shape[1], max_features=32,
                    n_levels=n_levels)
    whole = extract_features_batched(imgs, cfg, impl="pallas")
    per = extract_features_per_level(imgs, cfg, impl="pallas")
    _assert_featureset_equal(whole, per, "pallas whole vs per-level")
    _assert_featureset_equal(whole,
                             extract_features_batched(imgs, cfg, impl="ref"),
                             "pallas vs ref")


def test_whole_frame_paper_level1_shape():
    """600x1067 — the paper's 1280x720 level-1 shape, far from tile
    alignment on both axes — through the WHOLE-frame pallas path with a
    second ragged level (500x889)."""
    cfg = ORBConfig(height=600, width=1067, n_levels=2, max_features=64)
    shapes = pyramid.level_shapes(cfg)
    assert shapes == [(600, 1067), (500, 889)]
    levels = _levels(9, 1, shapes)
    out_ref = ops.fast_blur_nms_pyramid(levels, 20.0, impl="ref")
    out_pl = ops.fast_blur_nms_pyramid(levels, 20.0, impl="pallas")
    for lvl, ((br, sr), (bp, sp)) in enumerate(zip(out_ref, out_pl)):
        np.testing.assert_array_equal(np.asarray(br), np.asarray(bp),
                                      err_msg=f"blur level {lvl}")
        np.testing.assert_array_equal(np.asarray(sr), np.asarray(sp),
                                      err_msg=f"score level {lvl}")
    rng = np.random.RandomState(10)
    xys = [jnp.asarray(np.stack([rng.randint(0, w, (1, 9)),
                                 rng.randint(0, h, (1, 9))], -1)
                       .astype(np.int32))
           for h, w in shapes]
    sms = [blur for blur, _ in out_ref]
    sp_ref = ops.orient_describe_pyramid(levels, sms, xys, impl="ref")
    sp_pl = ops.orient_describe_pyramid(levels, sms, xys, impl="pallas")
    for lvl, (a, b) in enumerate(zip(sp_ref, sp_pl)):
        for name, x, y in zip(("theta", "moments", "desc"), a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{name} level {lvl}")


def test_whole_frame_all_invalid_levels():
    """Blank images: no corners anywhere — every level's top-K emits
    valid=False rows with degenerate coords; the whole-frame sparse
    launch must stay finite and agree across impls and schedules."""
    imgs = jnp.zeros((2, 64, 96), jnp.float32)
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=3)
    outs = {}
    for impl in ("ref", "pallas"):
        feats = extract_features_batched(imgs, cfg, impl=impl)
        assert int(feats.count()) == 0
        assert np.isfinite(np.asarray(feats.theta)).all()
        outs[impl] = feats
    _assert_featureset_equal(outs["ref"], outs["pallas"], "all-invalid")
    _assert_featureset_equal(outs["ref"],
                             extract_features_per_level(imgs, cfg,
                                                        impl="ref"),
                             "all-invalid vs per-level")


# ---------------------------------------------------------------------------
# Launch budget: the acceptance number of this refactor.

def test_whole_frame_two_fe_launches():
    """Acceptance: a traced frame costs exactly 2 FE launches (1 dense +
    1 sparse) regardless of camera count and level count, and a traced
    quad frame costs exactly 3 kernel launches total (+ the single
    fused FM launch covering both pairs)."""
    for b, n_levels in ((1, 1), (2, 3), (4, 2)):
        imgs = _imgs(11, b, 64, 96)
        cfg = ORBConfig(height=64, width=96, max_features=16,
                        n_levels=n_levels)
        with ops.launch_audit() as audit:
            jax.eval_shape(
                lambda im: extract_features_batched(im, cfg,
                                                    impl="pallas"),
                imgs)
        assert audit.count == 2, (b, n_levels, audit.count)
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                    max_disparity=32)
    intr = CameraIntrinsics(cx=48.0, cy=32.0)
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=cfg))
    assert vs.traced_launches("process_frame", _imgs(12, 4, 64, 96)) == 3


# ---------------------------------------------------------------------------
# Hypothesis property suite (runs where hypothesis is installed — CI).

if HAVE_HYPOTHESIS:

    @given(b=st.integers(1, 4), h=st.integers(24, 96),
           w=st.integers(24, 96), n_levels=st.integers(1, 8),
           thr=st.floats(5.0, 40.0), seed=st.integers(0, 2**16))
    @settings(max_examples=20, deadline=None)
    def test_prop_whole_frame_equals_per_level_ref(b, h, w, n_levels,
                                                   thr, seed):
        """Full-pipeline property: for random camera counts, odd shapes
        and level counts, the whole-frame jnp path is bit-exact against
        the per-level pipeline on every field."""
        imgs = _imgs(seed, b, h, w)
        cfg = ORBConfig(height=h, width=w, max_features=24,
                        n_levels=n_levels, fast_threshold=int(thr))
        whole = extract_features_batched(imgs, cfg, impl="ref")
        per = extract_features_per_level(imgs, cfg, impl="ref")
        _assert_featureset_equal(whole, per,
                                 f"b={b} {h}x{w} L={n_levels} thr={thr}")

    @given(b=st.integers(1, 2), h=st.integers(16, 72),
           w=st.integers(16, 72), n_levels=st.integers(1, 4),
           seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_prop_dense_pyramid_pallas_bitexact(b, h, w, n_levels, seed):
        """Dense whole-pyramid Pallas launch (interpret mode) vs the
        per-level jnp oracle, random ragged shapes."""
        cfg = ORBConfig(height=h, width=w, n_levels=n_levels)
        levels = _levels(seed, b, pyramid.level_shapes(cfg))
        outs = ops.fast_blur_nms_pyramid(levels, 20.0, impl="pallas")
        for lvl, (lv, (blur, score)) in enumerate(zip(levels, outs)):
            want_b, want_s = ops.fast_blur_nms_batched(lv, 20.0,
                                                       impl="ref")
            np.testing.assert_array_equal(np.asarray(blur),
                                          np.asarray(want_b),
                                          err_msg=f"blur lvl {lvl}")
            np.testing.assert_array_equal(np.asarray(score),
                                          np.asarray(want_s),
                                          err_msg=f"score lvl {lvl}")

    @given(b=st.integers(1, 2), h=st.integers(16, 72),
           w=st.integers(16, 72), n_levels=st.integers(1, 3),
           k=st.integers(1, 20), seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_prop_sparse_pyramid_pallas_bitexact(b, h, w, n_levels, k,
                                                 seed):
        """Sparse whole-frame Pallas launch (interpret mode) vs the
        per-level oracle, with keypoints spanning borders and
        out-of-range coords (boundary clamping)."""
        cfg = ORBConfig(height=h, width=w, n_levels=n_levels)
        shapes = pyramid.level_shapes(cfg)
        levels = _levels(seed, b, shapes)
        sms = [ops.fast_blur_nms_batched(lv, 20.0, impl="ref")[0]
               for lv in levels]
        rng = np.random.RandomState(seed)
        xys = [jnp.asarray(np.stack(
            [rng.randint(-10, w_l + 10, (b, k)),
             rng.randint(-10, h_l + 10, (b, k))], -1).astype(np.int32))
            for h_l, w_l in shapes]
        got = ops.orient_describe_pyramid(levels, sms, xys, impl="pallas")
        for lvl in range(n_levels):
            want = ops.orient_describe_batched(levels[lvl], sms[lvl],
                                               xys[lvl], impl="ref")
            for name, a, c in zip(("theta", "moments", "desc"),
                                  got[lvl], want):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(c),
                    err_msg=f"{name} lvl {lvl}")
