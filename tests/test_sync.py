"""Hardware-synchronization tests (paper Sec. III-A)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sync


def test_hardware_trigger_zero_desync():
    cfg = sync.TriggerConfig()
    cams, imu = sync.hardware_trigger(cfg, 100)
    assert float(sync.max_desync(cams)) == 0.0


def test_software_sync_has_jitter():
    cfg = sync.TriggerConfig(sw_jitter_std=4e-3)
    cams, _ = sync.software_sync(cfg, 100, jax.random.key(0))
    # software sync shows the variable inter-camera delay the paper
    # eliminates; hardware sync is exactly zero.
    assert float(sync.max_desync(cams)) > 1e-4


def test_imu_alignment_masks_correct_window():
    cfg = sync.TriggerConfig(camera_fps=30.0, imu_rate_hz=200.0)
    cams, imu = sync.hardware_trigger(cfg, 50)
    idx, mask = sync.align_imu(cams, imu, cfg)
    assert idx.shape == mask.shape == (50, cfg.imu_per_frame)
    tags = np.asarray(imu)[np.asarray(idx)]
    m = np.asarray(mask)
    frame_t = np.asarray(cams[:, 0])
    prev_t = np.concatenate([[-np.inf], frame_t[:-1]])
    # every selected sample lies in (prev, curr]
    assert np.all(tags[m] <= np.repeat(frame_t, m.sum(1))[None].ravel()
                  [: m.sum()] + 1e-12)
    for t in range(50):
        sel = tags[t][m[t]]
        assert np.all(sel <= frame_t[t] + 1e-12)
        assert np.all(sel > prev_t[t])
    # steady-state frames carry ~ rate/fps samples
    per_frame = m[1:].sum(axis=1)
    assert per_frame.min() >= int(200 / 30) - 1
    assert per_frame.max() <= int(200 / 30) + 2


def test_no_imu_sample_lost_or_duplicated():
    cfg = sync.TriggerConfig(camera_fps=30.0, imu_rate_hz=200.0)
    cams, imu = sync.hardware_trigger(cfg, 40)
    idx, mask = sync.align_imu(cams, imu, cfg)
    flat = np.asarray(idx)[np.asarray(mask)]
    assert len(flat) == len(set(flat.tolist()))  # no duplicates
    # all samples up to the last frame tag are assigned to some frame
    last_t = float(cams[-1, 0])
    expected = np.sum(np.asarray(imu) <= last_t)
    assert len(flat) == expected
