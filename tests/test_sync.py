"""Hardware-synchronization tests (paper Sec. III-A): behaviour pins
plus property tests (hypothesis) of the trigger/sync desync bounds and
the interface-alignment window over random rates and frame counts."""

import jax
import numpy as np

from repro.core import sync

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # dev-only dep; property tests skip
    HAVE_HYPOTHESIS = False


def test_hardware_trigger_zero_desync():
    cfg = sync.TriggerConfig()
    cams, imu = sync.hardware_trigger(cfg, 100)
    assert float(sync.max_desync(cams)) == 0.0


def test_software_sync_has_jitter():
    cfg = sync.TriggerConfig(sw_jitter_std=4e-3)
    cams, _ = sync.software_sync(cfg, 100, jax.random.key(0))
    # software sync shows the variable inter-camera delay the paper
    # eliminates; hardware sync is exactly zero.
    assert float(sync.max_desync(cams)) > 1e-4


def test_imu_alignment_masks_correct_window():
    cfg = sync.TriggerConfig(camera_fps=30.0, imu_rate_hz=200.0)
    cams, imu = sync.hardware_trigger(cfg, 50)
    idx, mask = sync.align_imu(cams, imu, cfg)
    assert idx.shape == mask.shape == (50, cfg.imu_per_frame)
    tags = np.asarray(imu)[np.asarray(idx)]
    m = np.asarray(mask)
    frame_t = np.asarray(cams[:, 0])
    prev_t = np.concatenate([[-np.inf], frame_t[:-1]])
    # every selected sample lies in (prev, curr]
    assert np.all(tags[m] <= np.repeat(frame_t, m.sum(1))[None].ravel()
                  [: m.sum()] + 1e-12)
    for t in range(50):
        sel = tags[t][m[t]]
        assert np.all(sel <= frame_t[t] + 1e-12)
        assert np.all(sel > prev_t[t])
    # steady-state frames carry ~ rate/fps samples
    per_frame = m[1:].sum(axis=1)
    assert per_frame.min() >= int(200 / 30) - 1
    assert per_frame.max() <= int(200 / 30) + 2


def test_no_imu_sample_lost_or_duplicated():
    cfg = sync.TriggerConfig(camera_fps=30.0, imu_rate_hz=200.0)
    cams, imu = sync.hardware_trigger(cfg, 40)
    idx, mask = sync.align_imu(cams, imu, cfg)
    flat = np.asarray(idx)[np.asarray(mask)]
    assert len(flat) == len(set(flat.tolist()))  # no duplicates
    # all samples up to the last frame tag are assigned to some frame
    last_t = float(cams[-1, 0])
    expected = np.sum(np.asarray(imu) <= last_t)
    assert len(flat) == expected


if HAVE_HYPOTHESIS:

    _cfg_st = dict(
        n_cameras=st.integers(1, 8),
        fps=st.floats(5.0, 120.0),
        rate=st.floats(50.0, 1000.0),
        n_frames=st.integers(2, 60),
    )

    @given(**_cfg_st)
    @settings(max_examples=40, deadline=None)
    def test_hardware_trigger_desync_is_exactly_zero(n_cameras, fps, rate,
                                                     n_frames):
        """Paper Sec. III-A: one trigger clock stamps every camera, so
        the inter-camera time-tag spread is 0 by construction — for ANY
        camera count, frame rate, and IMU rate, not just the defaults."""
        cfg = sync.TriggerConfig(n_cameras=n_cameras, camera_fps=fps,
                                 imu_rate_hz=rate)
        cams, imu = sync.hardware_trigger(cfg, n_frames)
        assert float(sync.max_desync(cams)) == 0.0
        # unified tags also cover the whole sequence monotonically
        assert np.all(np.diff(np.asarray(imu)) > 0)
        assert np.all(np.diff(np.asarray(cams[:, 0])) > 0)

    @given(seed=st.integers(0, 2**16), **_cfg_st)
    @settings(max_examples=25, deadline=None)
    def test_software_sync_bounds(n_cameras, fps, rate, n_frames, seed):
        """Software sync adds independent per-camera arrival jitter:
        desync is positive whenever there are >= 2 cameras (the failure
        mode the trigger generator removes) and never negative."""
        cfg = sync.TriggerConfig(n_cameras=n_cameras, camera_fps=fps,
                                 imu_rate_hz=rate, sw_jitter_std=4e-3)
        cams, _ = sync.software_sync(cfg, n_frames, jax.random.key(seed))
        desync = float(sync.max_desync(cams))
        assert desync >= 0.0
        if n_cameras >= 2:
            assert desync > 0.0
        # jitter only delays (abs model): software tags never precede
        # the hardware trigger tags
        hw, _ = sync.hardware_trigger(cfg, n_frames)
        assert np.all(np.asarray(cams) >= np.asarray(hw))

    @given(**_cfg_st)
    @settings(max_examples=40, deadline=None)
    def test_align_imu_window_matches_bruteforce(n_cameras, fps, rate,
                                                 n_frames):
        """align_imu's static-width window must select EXACTLY the IMU
        samples with prev_tag < t <= frame_tag — pinned against a
        python-loop reference over random rate combinations."""
        cfg = sync.TriggerConfig(n_cameras=n_cameras, camera_fps=fps,
                                 imu_rate_hz=rate)
        cams, imu = sync.hardware_trigger(cfg, n_frames)
        idx, mask = sync.align_imu(cams, imu, cfg)
        idx, mask = np.asarray(idx), np.asarray(mask)
        imu_np = np.asarray(imu)
        frame_t = np.asarray(cams[:, 0])
        prev_t = np.concatenate([[-np.inf], frame_t[:-1]])
        assert idx.shape == mask.shape == (n_frames, cfg.imu_per_frame)
        for t in range(n_frames):
            want = set(np.nonzero((imu_np > prev_t[t])
                                  & (imu_np <= frame_t[t]))[0].tolist())
            got = set(idx[t][mask[t]].tolist())
            assert got == want, (t, got, want)
