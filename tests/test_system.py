"""End-to-end system tests on the session API: synthetic quad-camera
scene -> frontend -> backend -> trajectory, plus the paper's accuracy
methodology (Tab. III: quantized/kernel path vs float oracle on the
same frames)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ORBConfig, PipelineConfig,
                        RigConfig, VisualSystem, backend)
from repro.data import scenes


_FLIP = jnp.asarray([[-1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, -1.0]])


def _stereo_system(ocfg, intr, impl=None):
    return VisualSystem(RigConfig.stereo(intr),
                        PipelineConfig(orb=ocfg, impl=impl))


def _stereo_frame(vs, img_l, img_r):
    """2-camera session frame, pair axis dropped (legacy shape)."""
    out = vs.process_frame(jnp.stack([img_l, img_r]))
    return jax.tree.map(lambda x: x[0], out)


def _run_vo(frames, ocfg, intr, z_max=10.0):
    """Quad-camera VO: fuse BOTH stereo pairs into one rig-frame solve.

    A single forward camera cannot separate yaw from lateral translation
    for far landmarks (narrow FOV); the paper's 360-degree rig breaks the
    degeneracy — the back pair sees opposite-sign flow.  Points from the
    back pair are rotated into the rig frame and the relative pose is
    solved on the fused cloud with flat weights (the estimator's median
    gating handles outliers; 1/z^2 weighting would bias the scale toward
    the sparse near field)."""
    vs = _stereo_system(ocfg, intr)
    outs = [_stereo_frame(vs, f[0], f[1]) for f in frames]
    outs_b = [_stereo_frame(vs, f[2], f[3]) for f in frames]
    poses = []
    for t in range(len(frames) - 1):
        pts, pts_n, w = [], [], []
        for seq, rot in ((outs, jnp.eye(3)), (outs_b, _FLIP)):
            prev, curr = seq[t], seq[t + 1]
            tm = vs.temporal_match(prev.features_l, curr.features_l)
            idx = tm.right_index
            wk = (tm.valid & prev.depth.valid
                  & curr.depth.valid[idx]).astype(jnp.float32)
            pts.append(backend.triangulate(prev.features_l, prev.depth,
                                           intr) @ rot.T)
            pts_n.append(backend.triangulate(curr.features_l, curr.depth,
                                             intr)[idx] @ rot.T)
            w.append(wk)
        pose = backend.estimate_relative_pose(
            jnp.concatenate(pts), jnp.concatenate(pts_n),
            jnp.concatenate(w), xy_curr=None, intr=intr, refine=False)
        poses.append(pose)
    return outs, poses


def test_end_to_end_localization_recovers_motion():
    # wide baseline -> usable disparity resolution at 240 px; lateral-
    # dominant motion is the observable regime for integer-pixel stereo
    cfg = scenes.SceneConfig(height=160, width=240, n_points=200, seed=7,
                             baseline=0.5)
    step = (0.25, 0.0, 0.1)
    frames, rig_poses, intr = scenes.render_sequence(cfg, 4, step_t=step,
                                                     yaw_per_frame=0.0)
    ocfg = ORBConfig(height=160, width=240, max_features=256, n_levels=1,
                     max_disparity=96)
    outs, poses = _run_vo(frames, ocfg, intr)
    for p in poses:
        assert int(p.inliers) >= 8
    traj = np.asarray(backend.integrate_trajectory(poses))
    true_final = np.asarray(rig_poses[-1][1])
    travel = np.linalg.norm(true_final)
    err = np.linalg.norm(traj[-1] - true_final)
    assert err < 0.3 * travel, (traj[-1], true_final)  # < 30% drift


def test_visual_odometry_never_fails_claim():
    """Paper: 'visual odometry should never fail ... always enough
    overlapping spatial regions between consecutive frames' — with the
    quad rig, every consecutive-frame pair must keep enough matches on
    at least one stereo pair even under yaw."""
    cfg = scenes.SceneConfig(height=120, width=160, n_points=150, seed=8)
    frames, rig_poses, intr = scenes.render_sequence(
        cfg, 3, step_t=(0.0, 0.0, 0.05), yaw_per_frame=0.06)
    ocfg = ORBConfig(height=120, width=160, max_features=160, n_levels=1,
                     max_disparity=48)
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=ocfg))
    prev = vs.process_frame(frames[0])
    for t in range(1, 3):
        curr = vs.process_frame(frames[t])
        per_pair = []
        for pair in (0, 1):
            fp = jax.tree.map(lambda x: x[pair], prev.features_l)
            fc = jax.tree.map(lambda x: x[pair], curr.features_l)
            tm = vs.temporal_match(fp, fc)
            per_pair.append(int(tm.count()))
        assert max(per_pair) >= 10, per_pair
        prev = curr


def test_tab3_methodology_hardware_vs_software_counts():
    """Tab. III analog: the hardware path (Pallas kernels) against the
    software reference (jnp oracle), same algorithm — the paper's
    FPGA-vs-MATLAB comparison.  Our error is 0 (bit-exact), beating the
    paper's <0.3%."""
    cfg = scenes.SceneConfig(height=120, width=160, n_points=100, seed=9)
    frames, _, intr = scenes.render_sequence(cfg, 2)
    ocfg = ORBConfig(height=120, width=160, max_features=160, n_levels=2,
                     max_disparity=48)
    vs_hw = _stereo_system(ocfg, intr, impl="pallas")
    vs_sw = _stereo_system(ocfg, intr, impl="ref")
    for t in range(2):
        hw = _stereo_frame(vs_hw, frames[t, 0], frames[t, 1])
        sw = _stereo_frame(vs_sw, frames[t, 0], frames[t, 1])
        assert int(hw.features_l.count()) == int(sw.features_l.count())
        assert int(hw.matches.count()) == int(sw.matches.count())
        assert int(hw.depth.count()) == int(sw.depth.count())
        np.testing.assert_array_equal(np.asarray(hw.features_l.desc),
                                      np.asarray(sw.features_l.desc))


def test_word_length_ablation_counts_stay_close():
    """Word-length optimization ablation (paper Sec. III-C): the 8-bit
    quantized datapath changes pyramid/smoothing rounding; feature,
    match and depth counts must stay within ~15% of the float path."""
    cfg = scenes.SceneConfig(height=120, width=160, n_points=100, seed=9)
    frames, _, intr = scenes.render_sequence(cfg, 1)
    base = dict(height=120, width=160, max_features=160, n_levels=2,
                max_disparity=48)
    q = ORBConfig(quantized=True, **base)
    f = ORBConfig(quantized=False, **base)
    out_q = _stereo_frame(_stereo_system(q, intr), frames[0, 0],
                          frames[0, 1])
    out_f = _stereo_frame(_stereo_system(f, intr), frames[0, 0],
                          frames[0, 1])
    # rounding shifts which near-threshold corners fire -> counts move,
    # but matching efficacy (matches / features) must be preserved.
    nf_q, nf_f = int(out_q.features_l.count()), int(out_f.features_l.count())
    nm_q, nm_f = int(out_q.matches.count()), int(out_f.matches.count())
    nd_q, nd_f = int(out_q.depth.count()), int(out_f.depth.count())
    assert abs(nf_q - nf_f) <= max(3, 0.2 * nf_f), (nf_q, nf_f)
    rate_q, rate_f = nm_q / nf_q, nm_f / nf_f
    assert abs(rate_q - rate_f) <= 0.1, (rate_q, rate_f)
    assert abs(nd_q / nm_q - nd_f / nm_f) <= 0.1
