"""Fused Feature Matcher megakernel vs the unfused two-kernel + gather
oracle path.

``match_pair_fused`` (ONE Pallas launch per frame: Hamming match + SAD
rectification with in-kernel patch reads, pair axis folded into the
grid) must be BIT-exact against ``match_pair_unfused`` (the retained
``hamming_match`` kernel + host-graph ``_gather_patches`` +
``sad_search`` kernel schedule) on every MatchSet/DepthSet field, on
both the jnp fallback and the Pallas interpret path — including 640x480
and odd shapes, all-invalid features and argmin ties.  The
``_gather_patches`` border clamp is audited against a python-loop
per-pixel oracle (``ref.gather_patches_bruteforce``), and a traced
``VisualSystem.process_frame`` pins the 3-launch budget (2 FE + 1 FM).

Deterministic parametrized pins run everywhere; the Hypothesis property
suite (random K/M/pair counts) runs where hypothesis is installed (CI)
under the fixed-seed profile from ``conftest.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CameraIntrinsics, FeatureSet, ORBConfig,
                        PipelineConfig, RigConfig, VisualSystem,
                        match_pair_fused, match_pair_unfused,
                        sad_rectify_unfused, stereo_match_unfused)
from repro.core.matching import _gather_patches
from repro.kernels import ops, ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # dev-only dep; property tests skip
    HAVE_HYPOTHESIS = False


def _system(cfg, intr=None, impl=None):
    intr = intr if intr is not None else CameraIntrinsics()
    return VisualSystem(RigConfig.stereo(intr),
                        PipelineConfig(orb=cfg, impl=impl))


def _random_features(rng, k, h, w, n_levels=2, valid_frac=0.8):
    desc = jnp.asarray(rng.randint(0, 2**32, (k, 8), dtype=np.uint64)
                       .astype(np.uint32))
    return FeatureSet(
        xy=jnp.asarray(np.stack([rng.uniform(-6, w + 6, k),
                                 rng.uniform(-6, h + 6, k)], 1)
                       .astype(np.float32)),
        level=jnp.asarray(rng.randint(0, n_levels, k).astype(np.int32)),
        score=jnp.asarray(rng.uniform(1, 50, k).astype(np.float32)),
        theta=jnp.asarray(rng.uniform(-np.pi, np.pi, k)
                          .astype(np.float32)),
        desc=desc,
        valid=jnp.asarray(rng.uniform(size=k) > 1.0 - valid_frac),
    )


def _stack_feats(feats):
    return jax.tree.map(lambda *x: jnp.stack(x), *feats)


def _pair_inputs(seed, n_pairs, k, m, h, w, valid_frac=0.8):
    rng = np.random.RandomState(seed)
    imgs_l = jnp.asarray(rng.randint(0, 256, (n_pairs, h, w))
                         .astype(np.float32))
    imgs_r = jnp.asarray(rng.randint(0, 256, (n_pairs, h, w))
                         .astype(np.float32))
    fls = [_random_features(rng, k, h, w, valid_frac=valid_frac)
           for _ in range(n_pairs)]
    frs = [_random_features(rng, m, h, w, valid_frac=valid_frac)
           for _ in range(n_pairs)]
    return imgs_l, imgs_r, fls, frs


def _assert_pair_equal(got, want_per_pair, msg=""):
    """got: pair-batched NamedTuple; want_per_pair: list of unbatched."""
    for p, want in enumerate(want_per_pair):
        for f in want._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f))[p],
                np.asarray(getattr(want, f)),
                err_msg=f"{msg} pair {p} field {f}")


# ---------------------------------------------------------------------------
# Tentpole: fused megakernel vs unfused oracle, bit-for-bit.

@pytest.mark.parametrize("h,w,k,m,n_pairs", [
    (480, 640, 75, 61, 2),       # the paper benchmark resolution
    (97, 143, 37, 29, 2),        # odd shape, far from tile alignment
    (63, 89, 21, 45, 3),         # M > K, three pairs
    (50, 71, 9, 5, 1),           # tiny: K < FM_BK after padding
])
def test_fused_matches_unfused_bitexact(h, w, k, m, n_pairs):
    imgs_l, imgs_r, fls, frs = _pair_inputs(21, n_pairs, k, m, h, w)
    cfg = ORBConfig(height=h, width=w, row_band=25, max_disparity=250,
                    max_hamming=140)
    intr = CameraIntrinsics(fx=120.0, cx=w / 2.0, cy=h / 2.0,
                            baseline=0.2)
    want = [match_pair_unfused(imgs_l[p], imgs_r[p], fls[p], frs[p],
                               cfg, intr, impl="ref")
            for p in range(n_pairs)]
    for impl in ("ref", "pallas"):
        mf, df = match_pair_fused(imgs_l, imgs_r, _stack_feats(fls),
                                  _stack_feats(frs), cfg, intr,
                                  impl=impl)
        _assert_pair_equal(mf, [wm for wm, _ in want], f"{impl} match")
        _assert_pair_equal(df, [wd for _, wd in want], f"{impl} depth")
    # the scenario must exercise both accepted and rejected matches
    assert any(bool(wm.valid.any()) for wm, _ in want)
    assert any(bool((~wm.valid).any()) for wm, _ in want)


def test_fused_all_invalid_features():
    """Every feature masked out: no candidate anywhere — dist stays at
    the BIG sentinel, indices resolve to 0, the SAD stage reads the
    right-feature-0 fallback window, and fused == unfused still holds
    bit-for-bit on every field."""
    imgs_l, imgs_r, fls, frs = _pair_inputs(22, 2, 17, 13, 64, 96,
                                            valid_frac=0.0)
    cfg = ORBConfig(height=64, width=96, max_disparity=64)
    intr = CameraIntrinsics(cx=48.0, cy=32.0)
    want = [match_pair_unfused(imgs_l[p], imgs_r[p], fls[p], frs[p],
                               cfg, intr, impl="ref") for p in range(2)]
    for impl in ("ref", "pallas"):
        mf, df = match_pair_fused(imgs_l, imgs_r, _stack_feats(fls),
                                  _stack_feats(frs), cfg, intr,
                                  impl=impl)
        assert int(mf.valid.sum()) == 0
        assert (np.asarray(mf.distance) == ref.MATCH_BIG).all()
        assert (np.asarray(mf.right_index) == 0).all()
        _assert_pair_equal(mf, [wm for wm, _ in want], f"{impl} match")
        _assert_pair_equal(df, [wd for _, wd in want], f"{impl} depth")


def test_fused_tie_breaks_to_lowest_right_index():
    """Identical descriptors planted at several right indices inside the
    search region: the running argmin must resolve to the LOWEST right
    index, across M-tile boundaries, on both impls — the oracle's
    first-occurrence argmin."""
    h, w = 64, 400
    rng = np.random.RandomState(23)
    k, m = 8, 300                       # m spans 3 M-tiles of 128
    fl = _random_features(rng, k, h, w, n_levels=1, valid_frac=1.0)
    fr = _random_features(rng, m, h, w, n_levels=1, valid_frac=1.0)
    # all right features inside every left feature's search region
    fl = fl._replace(xy=jnp.asarray(np.tile([350.0, 30.0], (k, 1))
                                    .astype(np.float32)))
    fr = fr._replace(xy=jnp.asarray(np.tile([200.0, 30.0], (m, 1))
                                    .astype(np.float32)))
    # plant the SAME descriptor as left row 0 at ties spanning tiles
    ties = [5, 120, 129, 250]
    desc_r = np.asarray(fr.desc).copy()
    desc_r[ties] = np.asarray(fl.desc)[0]
    fr = fr._replace(desc=jnp.asarray(desc_r))
    cfg = ORBConfig(height=h, width=w, row_band=100, max_disparity=300,
                    max_hamming=256)
    for impl in ("ref", "pallas"):
        got = _system(cfg, impl=impl).stereo_match(fl, fr)
        want = stereo_match_unfused(fl, fr, cfg, impl="ref")
        np.testing.assert_array_equal(np.asarray(got.right_index),
                                      np.asarray(want.right_index),
                                      err_msg=impl)
        assert int(got.right_index[0]) == ties[0], impl
        assert int(got.distance[0]) == 0, impl


def test_stereo_match_fused_equals_unfused():
    rng_shapes = [(37, 29), (128, 128), (5, 200)]
    cfg = ORBConfig(height=96, width=144, row_band=30, max_disparity=200,
                    max_hamming=200)
    for seed, (k, m) in enumerate(rng_shapes):
        rng = np.random.RandomState(31 + seed)
        fl = _random_features(rng, k, 96, 144)
        fr = _random_features(rng, m, 96, 144)
        want = stereo_match_unfused(fl, fr, cfg, impl="ref")
        for impl in ("ref", "pallas"):
            got = _system(cfg, impl=impl).stereo_match(fl, fr)
            for f in want._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, f)),
                    np.asarray(getattr(want, f)),
                    err_msg=f"{impl} K={k} M={m} field {f}")


def test_sad_rectify_in_kernel_equals_unfused():
    """The standalone ``sad_rectify`` (in-kernel patch reads via
    ``ops.sad_patch_search``) vs the retained gather + ``sad_search``
    path, with matches pointing at masked right features (index-0
    fallback) and windows overhanging every border."""
    h, w = 97, 143
    rng = np.random.RandomState(33)
    cfg = ORBConfig(height=h, width=w, max_hamming=256, row_band=40)
    intr = CameraIntrinsics(fx=120.0, cx=w / 2.0, cy=h / 2.0,
                            baseline=0.2)
    img_l = jnp.asarray(rng.randint(0, 256, (h, w)).astype(np.float32))
    img_r = jnp.asarray(rng.randint(0, 256, (h, w)).astype(np.float32))
    fl = _random_features(rng, 27, h, w)
    fr = _random_features(rng, 19, h, w)
    # push some left windows against/over every border
    xy = np.asarray(fl.xy).copy()
    xy[:4] = [[0.0, 0.0], [w - 1.0, h - 1.0], [-5.3, h / 2.0],
              [w / 2.0, h + 4.9]]
    fl = fl._replace(xy=jnp.asarray(xy))
    matches = _system(cfg).stereo_match(fl, fr)
    want = sad_rectify_unfused(img_l, img_r, fl, fr, matches, cfg, intr,
                               impl="ref")
    for impl in ("ref", "pallas"):
        got = _system(cfg, intr, impl=impl).sad_rectify(
            img_l, img_r, fl, fr, matches)
        for f in want._fields:
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(want, f)),
                                          err_msg=f"{impl} {f}")


# ---------------------------------------------------------------------------
# Satellite: _gather_patches border-clamp audit vs the per-pixel oracle.

@pytest.mark.parametrize("ph,pw", [(11, 11), (11, 21), (5, 9)])
def test_gather_patches_pinned_to_bruteforce(ph, pw):
    """``matching._gather_patches`` (pad-then-slice) vs the python-loop
    per-pixel clamp oracle: keypoints within half a window of every
    edge, exactly on corners, fractional (round-half-even) and fully
    out of range."""
    h, w = 48, 37
    rng = np.random.RandomState(41)
    img = rng.randint(0, 256, (h, w)).astype(np.float32)
    xy = np.array([
        [0.0, 0.0], [w - 1.0, h - 1.0],                  # corners
        [ph // 2 - 1.0, pw // 2 - 1.0],                  # inside half-win
        [w - pw // 2 + 0.0, h - ph // 2 + 0.0],
        [0.5, 0.5], [1.5, 2.5],                          # half-even ties
        [w - 1.5, h - 1.5],
        [-7.9, 3.0], [w + 12.2, h + 0.4],                # out of range
        [w / 3.0, -0.5],
    ], np.float32)
    xy = np.concatenate([xy, np.stack([rng.uniform(-3, w + 3, 12),
                                       rng.uniform(-3, h + 3, 12)],
                                      1).astype(np.float32)])
    want = ref.gather_patches_bruteforce(img, xy, ph, pw)
    got = _gather_patches(jnp.asarray(img), jnp.asarray(xy), ph, pw)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_gather_patches_through_masked_right_index():
    """Right strips gathered through ``matches.right_index`` pointing at
    an invalid (masked) feature resolve to right feature 0 — the strip
    the oracle, the gather path and the fused kernel must all read."""
    h, w = 64, 96
    rng = np.random.RandomState(42)
    img = rng.randint(0, 256, (h, w)).astype(np.float32)
    fr = _random_features(rng, 9, h, w, valid_frac=0.0)
    right_index = jnp.zeros(5, jnp.int32)        # the where(valid, idx, 0)
    xy_r = np.asarray(fr.xy)[np.asarray(right_index)]
    want = ref.gather_patches_bruteforce(img, xy_r, 11, 21)
    got = _gather_patches(jnp.asarray(img), jnp.asarray(xy_r), 11, 21)
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        want, np.tile(want[:1], (5, 1, 1)))      # all rows == feature 0's


# ---------------------------------------------------------------------------
# Satellite: temporal_match through the match-only mode, asymmetric radii.

@pytest.mark.parametrize("rx,ry", [(40.0, 8.0), (12.0, 60.0),
                                   (48.0, None)])
def test_temporal_match_asymmetric_radii_vs_bruteforce(rx, ry):
    """The rectangular window (+-rx in x via the meta shift, +-ry in y
    via the row band) equals the python-loop reference for asymmetric
    radii on both impls; ry=None keeps the legacy square window."""
    rng = np.random.RandomState(51)
    cfg = ORBConfig(height=480, width=640, max_hamming=256)
    fa = _random_features(rng, 41, 480, 640)
    fb = _random_features(rng, 33, 480, 640)
    # plant near-duplicates so the gates accept some matches
    desc_b = np.asarray(fb.desc).copy()
    desc_b[:12] = np.asarray(fa.desc)[:12]
    xy_b = np.asarray(fb.xy).copy()
    eff_ry = rx if ry is None else ry
    xy_b[:12] = (np.asarray(fa.xy)[:12]
                 + np.stack([rng.uniform(-rx, rx, 12),
                             rng.uniform(-eff_ry, eff_ry, 12)], 1))
    fb = fb._replace(desc=jnp.asarray(desc_b),
                     xy=jnp.asarray(xy_b.astype(np.float32)),
                     level=fb.level.at[:12].set(fa.level[:12]),
                     valid=fb.valid.at[:12].set(True))
    meta_a = np.stack([np.asarray(fa.xy)[:, 0] + rx,
                       np.asarray(fa.xy)[:, 1],
                       np.asarray(fa.level, np.float32),
                       np.asarray(fa.valid, np.float32)], 1)
    meta_b = np.stack([np.asarray(fb.xy)[:, 0], np.asarray(fb.xy)[:, 1],
                       np.asarray(fb.level, np.float32),
                       np.asarray(fb.valid, np.float32)], 1)
    want_d, want_i = ref.hamming_match_bruteforce(
        fa.desc, meta_a, fb.desc, meta_b, row_band=eff_ry,
        max_disparity=2.0 * rx)
    want_valid = ((want_i >= 0) & (want_d <= cfg.max_hamming)
                  & np.asarray(fa.valid))
    for impl in ("ref", "pallas"):
        tm = _system(cfg, impl=impl).temporal_match(fa, fb,
                                                    search_radius=rx,
                                                    search_radius_y=ry)
        np.testing.assert_array_equal(np.asarray(tm.distance), want_d,
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(tm.valid), want_valid,
                                      err_msg=impl)
        np.testing.assert_array_equal(
            np.asarray(tm.right_index), np.where(want_valid, want_i, 0),
            err_msg=impl)
    assert want_valid.any()


def test_temporal_match_single_launch():
    rng = np.random.RandomState(52)
    cfg = ORBConfig(height=96, width=144)
    fa = _random_features(rng, 30, 96, 144)
    fb = _random_features(rng, 30, 96, 144)
    vs = _system(cfg, impl="pallas")
    with ops.launch_audit() as audit:
        vs.temporal_match(fa, fb)    # first call: traces under the audit
    assert audit.count == 1


# ---------------------------------------------------------------------------
# Launch budget: the acceptance number of this refactor.

def test_quad_frame_three_launches():
    """Acceptance: a traced quad frame costs exactly 3 Pallas launches —
    2 FE (dense + sparse, all cameras x all levels) + 1 fused FM (both
    stereo pairs in one grid)."""
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                    max_disparity=32)
    intr = CameraIntrinsics(cx=48.0, cy=32.0)
    rng = np.random.RandomState(53)
    imgs = jnp.asarray(rng.randint(0, 256, (4, 64, 96))
                       .astype(np.float32))
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=cfg))
    assert vs.traced_launches("process_frame", imgs) == 3
    # and the fused FM itself is exactly ONE of those launches
    assert vs.traced_launches("extract", imgs) == 2


# ---------------------------------------------------------------------------
# Hypothesis property suite (runs where hypothesis is installed — CI).

if HAVE_HYPOTHESIS:

    @given(n_pairs=st.integers(1, 3), k=st.integers(1, 40),
           m=st.integers(1, 40), seed=st.integers(0, 2**16))
    @settings(max_examples=15, deadline=None)
    def test_prop_fused_equals_unfused_ref(n_pairs, k, m, seed):
        """Full-FM property: for random pair counts and K/M (spanning
        non-multiples of every block size), the fused jnp path equals
        the unfused oracle bit-for-bit on every field."""
        h, w = 48, 71
        imgs_l, imgs_r, fls, frs = _pair_inputs(seed, n_pairs, k, m, h, w)
        cfg = ORBConfig(height=h, width=w, row_band=20, max_disparity=80,
                        max_hamming=160)
        intr = CameraIntrinsics(fx=90.0, cx=w / 2.0, cy=h / 2.0,
                                baseline=0.15)
        mf, df = match_pair_fused(imgs_l, imgs_r, _stack_feats(fls),
                                  _stack_feats(frs), cfg, intr,
                                  impl="ref")
        want = [match_pair_unfused(imgs_l[p], imgs_r[p], fls[p], frs[p],
                                   cfg, intr, impl="ref")
                for p in range(n_pairs)]
        _assert_pair_equal(mf, [wm for wm, _ in want],
                           f"P={n_pairs} K={k} M={m}")
        _assert_pair_equal(df, [wd for _, wd in want],
                           f"P={n_pairs} K={k} M={m}")

    @given(n_pairs=st.integers(1, 2), k=st.integers(1, 20),
           m=st.integers(1, 20), seed=st.integers(0, 2**16))
    @settings(max_examples=8, deadline=None)
    def test_prop_fused_pallas_equals_unfused(n_pairs, k, m, seed):
        """Pallas-interpret megakernel vs the unfused oracle for random
        K/M/pair counts (block padding, M-tile sweep boundaries)."""
        h, w = 40, 57
        imgs_l, imgs_r, fls, frs = _pair_inputs(seed, n_pairs, k, m, h, w)
        cfg = ORBConfig(height=h, width=w, row_band=15, max_disparity=60,
                        max_hamming=180)
        intr = CameraIntrinsics(fx=90.0, cx=w / 2.0, cy=h / 2.0,
                                baseline=0.15)
        mf, df = match_pair_fused(imgs_l, imgs_r, _stack_feats(fls),
                                  _stack_feats(frs), cfg, intr,
                                  impl="pallas")
        want = [match_pair_unfused(imgs_l[p], imgs_r[p], fls[p], frs[p],
                                   cfg, intr, impl="ref")
                for p in range(n_pairs)]
        _assert_pair_equal(mf, [wm for wm, _ in want],
                           f"P={n_pairs} K={k} M={m}")
        _assert_pair_equal(df, [wd for _, wd in want],
                           f"P={n_pairs} K={k} M={m}")

    @given(k=st.integers(1, 30), seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_prop_gather_patches_bruteforce(k, seed):
        """Clamp property: pad-then-slice gather == per-pixel clamp
        oracle for random window sizes and out-of-range centers."""
        rng = np.random.RandomState(seed)
        h, w = rng.randint(20, 60), rng.randint(20, 60)
        ph = 2 * rng.randint(1, 7) + 1
        pw = ph + 2 * rng.randint(0, 6)
        img = rng.randint(0, 256, (h, w)).astype(np.float32)
        xy = np.stack([rng.uniform(-8, w + 8, k),
                       rng.uniform(-8, h + 8, k)], 1).astype(np.float32)
        want = ref.gather_patches_bruteforce(img, xy, ph, pw)
        got = _gather_patches(jnp.asarray(img), jnp.asarray(xy), ph, pw)
        np.testing.assert_array_equal(np.asarray(got), want)
