"""Multi-host failover acceptance tests: crash-consistent snapshots
(kill a service mid-episode, restore a FRESH one, continue bit-exactly),
torn-snapshot fallback, quarantine/flap-budget survival across a crash,
elastic host_down redistribution inside the launch budget, and the
guarded dispatch loop — all on the virtual clock with seeded injection,
so every scenario is a bit-reproducible replay."""

import functools

import jax
import numpy as np
import pytest

from repro.core import (ORBConfig, PipelineConfig, RigConfig, VisualSystem)
from repro.data import scenes
from repro.serving import (DispatchGuard, DispatchGuardConfig, FaultInjector,
                           FaultSpec, FleetService, HostMap, QueueConfig,
                           RigHealth, SupervisorConfig, run_episode, snapshot)

H, W = 48, 64
DT = 1.0 / 30.0
N_RIGS, T = 3, 4


@functools.lru_cache(maxsize=1)
def _fleet():
    cfg = scenes.SceneConfig(height=H, width=W, n_points=40, seed=3,
                             baseline=0.3)
    frames, intr, _ = scenes.render_fleet_sequence(cfg, n_frames=T,
                                                   n_rigs=N_RIGS)
    return np.asarray(frames), intr


def _service(impl=None, localize=False, guard=None, host_map=None,
             **sup_kw):
    frames, intr = _fleet()
    ocfg = ORBConfig(height=H, width=W, max_features=16, n_levels=1,
                     max_disparity=24)
    rig = RigConfig.quad(intr, desync_policy="degrade", max_desync=1e-3)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg, impl=impl,
                                          localize=localize))
    sup = dict(heartbeat_timeout_s=2.5 * DT, backoff_base_s=DT,
               backoff_max_s=4 * DT, restart_budget=2, flap_window_s=1.0,
               seed=0)
    sup.update(sup_kw)
    return FleetService(vs, QueueConfig(bucket_sizes=(1, 2, 4),
                                        deadline_s=DT),
                        SupervisorConfig(**sup), guard=guard,
                        host_map=host_map)


def _assert_bit_exact(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _by_key(result, rig_id):
    return {round(r.t_arrival, 9): r for r in result.reports
            if r.rig_id == rig_id and r.output is not None}


# ---------------------------------------------------------------------------
# Kill-and-recover: the tentpole acceptance test

CRASH_AT = 1


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_kill_and_recover_bit_exact(impl, tmp_path):
    """Snapshot every tick, destroy the service after tick CRASH_AT,
    restore a fresh one, continue: every healthy rig's STEREO outputs
    are bit-exact against the uninterrupted run; its pose chain shows
    ``valid=False`` exactly at the first post-restore frame (the crash
    is a stream gap) and is bit-exact again afterwards."""
    frames, _ = _fleet()
    base = run_episode(_service(impl=impl, localize=True), frames, dt=DT,
                       settle_steps=6)
    crashed = run_episode(
        _service(impl=impl, localize=True), frames, dt=DT, settle_steps=6,
        snapshot_dir=str(tmp_path), crash_at=CRASH_AT,
        restore=lambda: _service(impl=impl, localize=True))

    assert crashed.recovery is not None
    assert crashed.recovery["restored_step"] == CRASH_AT
    assert not crashed.recovery["snapshot_fallback"]
    # the crash happens after the tick-CRASH_AT service step
    crash_time = CRASH_AT * DT + 0.5 * DT + 1e-9

    reestablished = False
    for rig in range(N_RIGS):
        want, got = _by_key(base, rig), _by_key(crashed, rig)
        assert set(want) == set(got), f"rig {rig} served different frames"
        # a rig's GAP frame is the first one SERVED by the restored
        # service (it may have arrived pre-crash and ridden the
        # snapshot's pending buffer)
        post = sorted(k for k in got if got[k].t > crash_time)
        assert post, f"rig {rig} never served after the crash"
        gap_key = post[0]
        for key in want:
            _assert_bit_exact(got[key].output.stereo,
                              want[key].output.stereo,
                              f"rig {rig} stereo at t_arrival={key}")
            _assert_bit_exact(got[key].output.points,
                              want[key].output.points,
                              f"rig {rig} points at t_arrival={key}")
            if key == gap_key:
                # The deliberate difference: identity + valid=False at
                # the gap, where the uninterrupted run chained a pose.
                assert not np.asarray(got[key].output.pose.valid).any(), \
                    f"rig {rig} chained a pose across the crash gap"
            else:
                _assert_bit_exact(got[key].output.pose,
                                  want[key].output.pose,
                                  f"rig {rig} pose at t_arrival={key}")
        reestablished |= any(np.asarray(got[k].output.pose.valid).any()
                             for k in post[1:])
    assert reestablished, "no rig re-established its pose chain"


def test_kill_and_recover_preserves_pending_frames(tmp_path):
    """A frame accepted by ``submit`` but not yet served must survive
    the crash: snapshot with a pending frame, restore fresh, serve it."""
    frames, _ = _fleet()
    svc = _service()
    svc.submit(0, frames[0, 0], 0.0)
    svc.submit(1, frames[0, 1], 0.001)
    assert svc.queue.pending() == 2
    snapshot.save(svc, str(tmp_path), step=0)

    fresh = _service()
    assert snapshot.restore(fresh, str(tmp_path)) == 0
    assert fresh.queue.pending() == 2
    want = svc.step(1.0, force=True)
    got = fresh.step(1.0, force=True)
    assert [r.rig_id for r in got] == [r.rig_id for r in want] == [0, 1]
    assert [r.t_arrival for r in got] == [r.t_arrival for r in want]
    for a, b in zip(got, want):
        _assert_bit_exact(a.output, b.output)


def test_quarantine_survives_restore(tmp_path):
    """A quarantined rig cannot launder its flap budget through a host
    crash: quarantine state, restart ledger and counters all ride the
    snapshot."""
    frames, _ = _fleet()
    svc = _service(restart_budget=1)
    svc.submit(1, frames[0, 1], 0.0)
    svc.step(0.0, force=True)
    now, t = 0.0, 1
    while svc.supervisor.health(1) is not RigHealth.QUARANTINED:
        assert t < 200, "rig 1 never quarantined"
        now = t * DT
        svc.step(now, force=True)
        t += 1
    snapshot.save(svc, str(tmp_path), step=42)

    fresh = _service(restart_budget=1)
    assert snapshot.restore(fresh, str(tmp_path)) == 42
    assert fresh.supervisor.health(1) is RigHealth.QUARANTINED
    st_want = svc.supervisor.export_state()
    st_got = fresh.supervisor.export_state()
    assert st_got == st_want                    # full ledger, bit-for-bit
    assert dict(fresh.counters) == dict(svc.counters)
    # and the restored service keeps enforcing the quarantine
    assert fresh.submit(1, frames[1, 1], now + DT) == "dropped_quarantined"


def test_corrupt_snapshot_falls_back_a_step(tmp_path):
    """A torn newest snapshot (injected ``corrupt_snapshot``) must not
    crash the restore — it falls back to the previous verifiable step
    and the episode completes."""
    frames, _ = _fleet()
    inj = FaultInjector([FaultSpec("corrupt_snapshot", start=CRASH_AT)],
                        seed=11)
    result = run_episode(
        _service(), frames, dt=DT, injector=inj, settle_steps=6,
        snapshot_dir=str(tmp_path), crash_at=CRASH_AT,
        restore=_service)
    assert result.recovery["restored_step"] == CRASH_AT - 1
    assert result.recovery["snapshot_fallback"]
    # the episode still finished serving; no frame raised
    assert any(r.t_arrival > CRASH_AT * DT for r in result.reports)


def test_snapshot_layout_mismatch_raises(tmp_path):
    """Restoring across rig geometries is a caller bug, not a torn
    write — it must raise, not silently misread state."""
    frames, _ = _fleet()
    svc = _service()
    svc.submit(0, frames[0, 0], 0.0)
    snapshot.save(svc, str(tmp_path), step=0)
    other = _service(localize=True)             # different layout echo
    with pytest.raises(ValueError, match="layout"):
        snapshot.restore(other, str(tmp_path))


def test_restore_with_no_snapshot_is_cold_start(tmp_path):
    assert snapshot.restore(_service(), str(tmp_path)) is None


# ---------------------------------------------------------------------------
# HostMap: elastic rig redistribution

def test_host_map_places_deterministically():
    hm = HostMap(["h0", "h1", "h2"])
    assert [hm.assign(r) for r in range(6)] == \
        ["h0", "h1", "h2", "h0", "h1", "h2"]
    assert hm.assign(0) == "h0"                 # sticky
    assert hm.load() == {"h0": 2, "h1": 2, "h2": 2}
    # same arrival order -> identical map
    hm2 = HostMap(["h0", "h1", "h2"])
    for r in range(6):
        hm2.assign(r)
    assert hm2.export_state() == hm.export_state()


def test_host_map_down_redistributes_least_loaded():
    hm = HostMap(["h0", "h1", "h2"])
    for r in range(6):
        hm.assign(r)
    moved = hm.host_down("h1")
    assert moved == ((1, "h0"), (4, "h2"))
    assert hm.down == ["h1"]
    assert hm.load() == {"h0": 3, "h2": 3}
    with pytest.raises(ValueError, match="not an active domain"):
        hm.host_down("h1")                      # already down
    hm.host_down("h0")
    with pytest.raises(ValueError, match="last surviving"):
        hm.host_down("h2")                      # fleet-wide outage


def test_host_map_rejects_bad_construction():
    with pytest.raises(ValueError, match="at least one"):
        HostMap([])
    with pytest.raises(ValueError, match="duplicate"):
        HostMap(["h0", "h0"])
    with pytest.raises(ValueError, match="unknown host"):
        HostMap(["h0"], assignment={0: "nope"})


def test_host_down_episode_stays_in_launch_budget(tmp_path):
    """host_down mid-episode: the survivors absorb the moved rigs, the
    moved rigs' pose chains gap, and the whole episode still traces at
    most once per bucket size (redistribution rides the SAME bucketed
    batch path — no new fleet shapes)."""
    frames, _ = _fleet()
    hm = HostMap(["host0", "host1"])
    svc = _service(localize=True, host_map=hm)
    inj = FaultInjector([FaultSpec("host_down", rig="host0", start=2)])
    result = run_episode(svc, frames, dt=DT, injector=inj, settle_steps=6)

    host_evs = [e for e in result.events
                if getattr(e, "kind", None) == "host_down"]
    assert len(host_evs) == 1 and host_evs[0].host == "host0"
    moved_rigs = [r for r, _ in host_evs[0].moved]
    assert moved_rigs                           # host0 had rigs placed
    assert svc.host_map.down == ["host0"]
    assert svc.host_map.hosts == ["host1"]
    assert result.status["counters"]["rigs_redistributed"] == len(moved_rigs)
    # every rig still served every frame — redistribution drops nothing
    for rig in range(N_RIGS):
        assert len(_by_key(result, rig)) == T
    # migration gapped the moved rigs' pose chains: their first frame
    # served AFTER the host_down event must not chain
    for rig in moved_rigs:
        got = _by_key(result, rig)
        post = sorted(k for k in got if got[k].t > host_evs[0].now)
        assert post, f"moved rig {rig} never served after host_down"
        assert not np.asarray(got[post[0]].output.pose.valid).any(), \
            f"rig {rig} chained a pose across its migration"
    # launch budget: no new fleet shapes from the failover
    n_buckets = len(svc.queue.cfg.bucket_sizes)
    assert svc.vs.trace_count("process_fleet_masked") <= n_buckets


def test_host_down_without_host_map_raises():
    with pytest.raises(ValueError, match="HostMap"):
        _service().host_down("host0", 0.0)


# ---------------------------------------------------------------------------
# Guarded dispatch through the service

def _guard(**kw):
    # Generous real timeout: the first dispatch per bucket shape pays
    # jit tracing; injected stalls simulate the timeout without it.
    cfg = dict(timeout_s=60.0, max_attempts=2, backoff_base_s=DT,
               backoff_max_s=4 * DT, seed=0)
    cfg.update(kw)
    return DispatchGuard(DispatchGuardConfig(**cfg))


def test_dispatch_error_retries_and_recovers():
    """magnitude=1 fails the first attempt of every dispatch in the
    window; max_attempts=2 means the retry lands — every frame is still
    served, the faults are counted, recovery events are recorded."""
    frames, _ = _fleet()
    base = run_episode(_service(), frames, dt=DT, settle_steps=6)
    inj = FaultInjector([FaultSpec("dispatch_error", start=1, stop=3,
                                   magnitude=1)])
    svc = _service(guard=_guard())
    result = run_episode(svc, frames, dt=DT, injector=inj, settle_steps=6)
    assert svc.counters["dispatch_errors"] == 2
    assert svc.counters["dispatch_retries"] == 2
    assert svc.counters["dropped_dispatch"] == 0
    recovered = [e for e in result.events
                 if getattr(e, "kind", None) == "dispatch_recovered"]
    assert len(recovered) == 2
    assert all(e.faults == ("error:InjectedDispatchError",)
               for e in recovered)
    # recovered dispatches serve bit-exactly what an unguarded run does
    for rig in range(N_RIGS):
        want, got = _by_key(base, rig), _by_key(result, rig)
        assert set(want) == set(got)
        for key in want:
            _assert_bit_exact(got[key].output, want[key].output)


def test_stuck_dispatch_exhausts_budget_and_drops():
    """magnitude >= max_attempts: every attempt stalls, the batch is
    dropped (counted per rig, health degraded) — and the loop KEEPS
    SERVING the frames outside the fault window."""
    frames, _ = _fleet()
    inj = FaultInjector([FaultSpec("stuck_dispatch", start=1, stop=2,
                                   magnitude=2)])
    svc = _service(guard=_guard())
    result = run_episode(svc, frames, dt=DT, injector=inj, settle_steps=6)
    assert svc.counters["dispatch_stalls"] == 2
    drops = [e for e in result.events
             if getattr(e, "kind", None) == "dispatch_drop"]
    assert len(drops) == 1 and drops[0].faults == ("stall", "stall")
    assert svc.counters["dropped_dispatch"] > 0
    assert result.status["counters"]["dropped_dispatch"] == \
        svc.counters["dropped_dispatch"]
    # later dispatches (past the window) still served frames
    assert any(r.t_arrival > 1 * DT for r in result.reports)


def test_guard_times_out_a_genuinely_stuck_compute():
    """The real wall-clock watchdog (no injection): a compute that
    outlives timeout_s is abandoned and counted a stall."""
    import time as _time
    g = DispatchGuard(DispatchGuardConfig(timeout_s=0.05, max_attempts=2))
    out = g.run("stuck", lambda: _time.sleep(5.0))
    assert not out.ok and out.faults == ("stall", "stall")
    out = g.run("fine", lambda: 7)
    assert out.ok and out.value == 7 and out.faults == ()


def test_guard_backoff_is_deterministic_and_bounded():
    g = _guard()
    for key in (0, 1, "batch-7"):
        for attempt in (1, 2, 3, 9):
            d = g.backoff(key, attempt)
            assert d == _guard().backoff(key, attempt)
            assert 0.0 < d <= g.cfg.backoff_max_s * \
                (1.0 + g.cfg.backoff_jitter)
    assert g.backoff(0, 1) != g.backoff(1, 1)   # keys decorrelate


def test_failover_episode_replays_bit_identically(tmp_path):
    """The new fault kinds (host_down + dispatch_error + crash/restore)
    preserve the replay guarantee: two identical episodes produce
    identical reports, events and outputs."""
    def run(d):
        inj = FaultInjector([
            FaultSpec("host_down", rig="host0", start=2),
            FaultSpec("dispatch_error", start=1, stop=2, magnitude=1),
        ], seed=9)
        svc = _service(guard=_guard(), host_map=HostMap(["host0", "host1"]))
        return run_episode(svc, _fleet()[0], dt=DT, injector=inj,
                           settle_steps=6, snapshot_dir=str(d),
                           crash_at=2,
                           restore=lambda: _service(
                               guard=_guard(),
                               host_map=HostMap(["host0", "host1"])))

    a = run(tmp_path / "a")
    b = run(tmp_path / "b")
    assert [(r.rig_id, r.status, r.t, r.t_arrival) for r in a.reports] == \
           [(r.rig_id, r.status, r.t, r.t_arrival) for r in b.reports]
    assert a.events == b.events
    assert a.recovery["restored_step"] == b.recovery["restored_step"]
    for ra, rb in zip(a.reports, b.reports):
        _assert_bit_exact(ra.output, rb.output)


def test_guarded_compute_counts_into_caller_launch_audit():
    """Regression: the guard's watchdog thread starts with an EMPTY
    contextvars context, so launches dispatched inside the guarded
    compute used to escape an ambient ``ops.launch_audit()`` scope.
    The guard must copy the caller's context into the worker."""
    from repro.core import orb
    from repro.kernels import ops

    ocfg = ORBConfig(height=H, width=W, max_features=16, n_levels=1)
    aval = jax.ShapeDtypeStruct((2, H, W), np.float32)

    def compute():
        # Trace-only FE dispatch: bumps the launch counter twice
        # (1 dense + 1 sparse), no kernel execution.
        return jax.eval_shape(
            lambda im: orb.extract_features_batched(im, ocfg,
                                                    impl="pallas"),
            aval)

    guard = DispatchGuard(DispatchGuardConfig(timeout_s=30.0))
    with ops.launch_audit() as audit:
        outcome = guard.run("audit-ctx", compute)
    assert outcome.ok
    assert audit.count == 2


def test_guarded_compute_audit_counts_match_unguarded():
    """The guard must be launch-transparent: tracing a fleet frame
    through the guarded path observes exactly the same count as calling
    the compute directly (the restored_fleet/degraded gates rely on
    this when the service dispatches through the guard)."""
    from repro.kernels import ops

    svc = _service(guard=_guard())
    frames, _ = _fleet()
    fleet = jax.numpy.asarray(frames[0])

    def compute():
        return svc.vs.traced_launches("process_fleet", fleet)

    direct = compute()
    with ops.launch_audit() as audit:
        outcome = svc.guard.run("parity", compute)
    assert outcome.ok and outcome.value == direct
    assert audit.count == direct == 3
