"""End-to-end fault-injection episodes (the ISSUE's acceptance bar):
for every injected fault kind the service finishes the episode with the
faulty rig degraded, restarted or quarantined — never an exception —
and the HEALTHY rigs' outputs are bit-exact against a no-fault run of
the same episode.  Everything runs on a virtual clock with seeded
injection, so each test is a bit-reproducible replay."""

import functools

import jax
import numpy as np

from repro.core import (ORBConfig, PipelineConfig, RigConfig, VisualSystem)
from repro.data import scenes
from repro.serving import (FaultInjector, FaultSpec, FleetService,
                           QueueConfig, RigHealth, SupervisorConfig,
                           run_episode)

H, W = 48, 64
DT = 1.0 / 30.0
N_RIGS, T = 3, 4


@functools.lru_cache(maxsize=1)
def _fleet():
    cfg = scenes.SceneConfig(height=H, width=W, n_points=40, seed=3,
                             baseline=0.3)
    frames, intr, _ = scenes.render_fleet_sequence(cfg, n_frames=T,
                                                   n_rigs=N_RIGS)
    return np.asarray(frames), intr


def _service(restart_cb=None, **sup_kw):
    frames, intr = _fleet()
    ocfg = ORBConfig(height=H, width=W, max_features=16, n_levels=1,
                     max_disparity=24)
    rig = RigConfig.quad(intr, desync_policy="degrade", max_desync=1e-3)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))
    sup = dict(heartbeat_timeout_s=2.5 * DT, backoff_base_s=DT,
               backoff_max_s=4 * DT, restart_budget=2, flap_window_s=1.0,
               seed=0)
    sup.update(sup_kw)
    return FleetService(vs, QueueConfig(bucket_sizes=(1, 2, 4),
                                        deadline_s=DT),
                        SupervisorConfig(**sup), restart_cb)


def _episode(injector=None, restart_cb=None, settle=6, **sup_kw):
    svc = _service(restart_cb=restart_cb, **sup_kw)
    return run_episode(svc, _fleet()[0], dt=DT, injector=injector,
                       settle_steps=settle), svc


def _outputs_by_key(result, rig_id, full_mask_only=True):
    """(t_arrival -> StereoOutput) for one rig's served frames; arrival
    times are the stable cross-episode key (virtual clock)."""
    return {round(r.t_arrival, 9): r.output for r in result.reports
            if r.rig_id == rig_id and r.output is not None
            and (r.camera_mask.all() or not full_mask_only)}


def _assert_bit_exact(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_healthy_rigs_unaffected(base, faulty, faulty_rig):
    """Every healthy-rig frame served in BOTH runs must match bit for
    bit — fault isolation across the shared fleet batch."""
    checked = 0
    for rig in range(N_RIGS):
        if rig == faulty_rig:
            continue
        want = _outputs_by_key(base, rig)
        got = _outputs_by_key(faulty, rig)
        for key in set(want) & set(got):
            _assert_bit_exact(got[key], want[key])
            checked += 1
    assert checked > 0, "no healthy-rig frames overlapped between runs"


# ---------------------------------------------------------------------------

def test_no_fault_episode_all_ok():
    result, _ = _episode()
    assert len(result.reports) == N_RIGS * T
    assert {r.status for r in result.reports} == {"ok"}
    assert result.status["counters"]["frames_out"] == N_RIGS * T
    assert not result.events or all(e.now >= T * DT for e in result.events)


def test_dead_camera_degrades_surviving_pairs():
    base, _ = _episode()
    inj = FaultInjector([FaultSpec("dead_camera", rig=1, camera=3)])
    result, _ = _episode(injector=inj)
    rig1 = [r for r in result.reports if r.rig_id == 1]
    assert rig1 and all(r.status == "degraded" for r in rig1)
    for r in rig1:
        assert r.camera_mask.tolist() == [True, True, True, False]
        valid = np.asarray(r.output.matches.valid)
        assert not valid[1].any()            # pair (2,3) masked out
    _assert_healthy_rigs_unaffected(base, result, faulty_rig=1)


def test_corrupt_frame_detected_and_masked():
    base, _ = _episode()
    inj = FaultInjector([FaultSpec("corrupt_frame", rig=0, start=1, stop=3,
                                   camera=0)])
    result, svc = _episode(injector=inj)
    assert svc.counters["corrupt_cameras"] == 2
    rig0 = [r for r in result.reports if r.rig_id == 0]
    assert {r.status for r in rig0} == {"ok", "degraded"}
    for r in rig0:
        assert np.isfinite(jax.tree.leaves(r.output)[0]).all() or True
        if r.status == "degraded":
            assert not r.camera_mask[0]
            assert not np.asarray(r.output.matches.valid[0]).any()
    _assert_healthy_rigs_unaffected(base, result, faulty_rig=0)


def test_desync_degrades_offending_camera():
    base, _ = _episode()
    inj = FaultInjector([FaultSpec("desync", rig=2, camera=1,
                                   magnitude=0.5)])
    result, _ = _episode(injector=inj)
    rig2 = [r for r in result.reports if r.rig_id == 2]
    assert rig2 and all(r.status == "degraded" for r in rig2)
    for r in rig2:
        assert r.camera_mask.tolist() == [True, False, True, True]
        assert not np.asarray(r.output.matches.valid[0]).any()
    _assert_healthy_rigs_unaffected(base, result, faulty_rig=2)


def test_stalled_rig_restarts_and_recovers():
    """Rig 1 stalls after its first frame; the watchdog times out,
    backs off, restarts — and because the restart hook clears the
    fault, later frames flow again."""
    inj = FaultInjector([FaultSpec("stalled_rig", rig=1, start=1)])
    base, _ = _episode()
    result, svc = _episode(injector=inj, restart_cb=inj.clear_rig,
                           settle=2)
    kinds = [(e.rig_id, e.kind) for e in result.events]
    assert (1, "timeout") in kinds and (1, "restart") in kinds
    # only rig 1 was ever restarted during the arrival window
    assert all(e.rig_id == 1 for e in result.events
               if e.now < T * DT)
    rig1_served = [r for r in result.reports if r.rig_id == 1]
    assert 1 <= len(rig1_served) < T          # stalled frames never served
    _assert_healthy_rigs_unaffected(base, result, faulty_rig=1)


def test_flapping_rig_is_quarantined():
    """A rig that stalls forever burns its restart budget and lands in
    QUARANTINED — the service stops waiting for it."""
    inj = FaultInjector([FaultSpec("stalled_rig", rig=1, start=1)])
    result, svc = _episode(injector=inj, settle=40, restart_budget=2)
    assert (1, "quarantine") in [(e.rig_id, e.kind) for e in result.events]
    assert svc.supervisor.health(1) is RigHealth.QUARANTINED
    # the healthy rigs still served their whole episode
    for rig in (0, 2):
        assert len(_outputs_by_key(result, rig)) == T


def test_arrival_jitter_still_serves_every_frame():
    inj = FaultInjector([FaultSpec("arrival_jitter", rig=r,
                                   magnitude=0.3 * DT)
                         for r in range(N_RIGS)], seed=5)
    result, _ = _episode(injector=inj)
    for rig in range(N_RIGS):
        assert len(_outputs_by_key(result, rig)) == T
    assert {r.status for r in result.reports} == {"ok"}


def test_episode_replay_is_bit_identical():
    """Same seeds, same virtual clock -> the entire episode (reports,
    events, outputs) replays bit-identically."""
    def run():
        inj = FaultInjector([
            FaultSpec("dead_camera", rig=1, camera=3),
            FaultSpec("stalled_rig", rig=2, start=2),
            FaultSpec("arrival_jitter", rig=0, magnitude=0.2 * DT),
        ], seed=9)
        return _episode(injector=inj, restart_cb=inj.clear_rig)[0]

    a, b = run(), run()
    assert [(r.rig_id, r.status, r.t, r.t_arrival, r.late)
            for r in a.reports] == \
           [(r.rig_id, r.status, r.t, r.t_arrival, r.late)
            for r in b.reports]
    assert a.events == b.events
    for ra, rb in zip(a.reports, b.reports):
        _assert_bit_exact(ra.output, rb.output)


def test_fleet_batches_bound_retraces_to_buckets():
    """Whatever the traffic pattern, the masked fleet entry traces at
    most once per bucket size."""
    inj = FaultInjector([FaultSpec("stalled_rig", rig=2, start=1)])
    result, svc = _episode(injector=inj)
    n_buckets = len(svc.queue.cfg.bucket_sizes)
    assert svc.vs.trace_count("process_fleet_masked") <= n_buckets


# -- localization under faults ----------------------------------------------

def _loc_service(**sup_kw):
    frames, intr = _fleet()
    ocfg = ORBConfig(height=H, width=W, max_features=16, n_levels=1,
                     max_disparity=24)
    rig = RigConfig.quad(intr, desync_policy="degrade", max_desync=1e-3)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg, localize=True))
    sup = dict(heartbeat_timeout_s=2.5 * DT, backoff_base_s=DT,
               backoff_max_s=4 * DT, restart_budget=2, flap_window_s=1.0,
               seed=0)
    sup.update(sup_kw)
    return FleetService(vs, QueueConfig(bucket_sizes=(1, 2, 4),
                                        deadline_s=DT),
                        SupervisorConfig(**sup))


def test_localized_episode_poses_never_nan():
    """A localizing service under injected faults: every served frame
    carries a pose (LocalizationOutput), every pose leaf is finite —
    dead cameras and corrupt slabs degrade accuracy or flip
    ``valid=False``, they NEVER NaN the pose — and the state machinery
    keeps healthy rigs producing valid poses."""
    from repro.core.types import LocalizationOutput
    inj = FaultInjector([
        FaultSpec("dead_camera", rig=1, start=1, camera=2),
        FaultSpec("corrupt_frame", rig=2, start=2, stop=3, camera=0),
    ], seed=4)
    svc = _loc_service()
    result = run_episode(svc, _fleet()[0], dt=DT, injector=inj,
                         settle_steps=6)
    served = [r for r in result.reports if r.output is not None]
    assert served
    saw_degraded = saw_valid = False
    for r in served:
        assert isinstance(r.output, LocalizationOutput)
        pose = r.output.pose
        assert np.isfinite(np.asarray(pose.rotation)).all(), r.rig_id
        assert np.isfinite(np.asarray(pose.translation)).all(), r.rig_id
        assert np.isfinite(np.asarray(r.output.points)).all(), r.rig_id
        saw_degraded |= r.status == "degraded"
        saw_valid |= bool(np.asarray(pose.valid))
    assert saw_degraded, "fault injection never degraded a frame"
    assert saw_valid, "no rig ever produced a valid pose"


def test_localized_quarantine_drops_pose_state():
    """A rig that flaps into quarantine loses its cross-frame
    localization state (a later resurrection must not chain a pose
    across the gap), while rigs that keep heartbeating keep theirs.
    Driven manually (not via ``run_episode``) so the healthy rigs'
    heartbeats stay fresh while rig 1 burns its restart budget."""
    frames, _ = _fleet()
    svc = _loc_service(restart_budget=2)
    now = 0.0
    for rig in range(N_RIGS):
        svc.submit(rig, frames[0, rig], now)
    reports = svc.step(now, force=True)
    assert set(svc._loc_state) == {0, 1, 2}
    # Rig 1 goes silent; 0 and 2 keep streaming until the watchdog
    # drives rig 1 through restart backoff into quarantine.
    t = 1
    while (1, "quarantine") not in [(e.rig_id, e.kind)
                                    for e in svc.events]:
        assert t < 200, "rig 1 never quarantined"
        now = t * DT
        for rig in (0, 2):
            svc.submit(rig, frames[t % T, rig], now)
        reports += svc.step(now, force=True)
        t += 1
    assert svc.supervisor.health(1) is RigHealth.QUARANTINED
    assert 1 not in svc._loc_state      # restart/quarantine popped it
    for rig in (0, 2):
        assert rig in svc._loc_state    # survivors keep chaining
    for r in reports:
        if r.output is not None:
            assert np.isfinite(
                np.asarray(r.output.pose.translation)).all()
