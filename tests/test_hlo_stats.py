"""Pin `launch/hlo_stats.py`'s HLO-text parser against handcrafted
fixtures: the regex surface (`_SHAPE_RE` / `_TRIP_RE` / headers /
instructions), the call graph (fusion calls, nested while bodies with
trip multipliers), and the byte accounting edge cases (scalar `f32[]`
shapes, unknown dtypes -> 0 bytes).  These underpin every roofline
number the benchmarks report and previously had no direct coverage."""

import pytest

from repro.launch import hlo_stats


# ---------------------------------------------------------------------------
# regex / low-level helpers

def test_shape_of_scalar_and_ranked():
    assert hlo_stats._shape_of("f32[] constant(0)") == ("f32", ())
    assert hlo_stats._shape_of("f32[4,8] parameter(0)") == ("f32", (4, 8))
    assert hlo_stats._shape_of("u8[720,1280] copy(%x)") == \
        ("u8", (720, 1280))
    assert hlo_stats._shape_of("no shape here") == (None, ())


def test_nbytes_known_unknown_and_zero_dim():
    assert hlo_stats._nbytes("f32", (4, 8)) == 128
    assert hlo_stats._nbytes("u8", (3,)) == 3
    assert hlo_stats._nbytes("f32", ()) == 4          # scalar
    assert hlo_stats._nbytes("f32", (0, 8)) == 0      # zero-dim extent
    # Unknown dtype tokens must degrade to 0 bytes, not crash or guess.
    assert hlo_stats._nbytes("mystery99", (4, 4)) == 0
    assert hlo_stats._nbytes(None, ()) == 0


def test_trip_re_pins_exact_xla_serialization():
    """XLA serializes backend_config without spaces; the regex pins
    that exact form, so a looser variant must NOT match (the fallback
    `_cond_trip` path handles those)."""
    tight = '"known_trip_count":{"n":"48"}'
    loose = '"known_trip_count": {"n": "48"}'
    m = hlo_stats._TRIP_RE.search(tight)
    assert m and m.group(1) == "48"
    assert hlo_stats._TRIP_RE.search(loose) is None


# ---------------------------------------------------------------------------
# parse_hlo structure

_BASIC = """\
HloModule jit_step

ENTRY %main (p0: f32[4,8], p1: f32[8,16]) -> f32[4,16] {
  %p0 = f32[4,8] parameter(0)
  %p1 = f32[8,16] parameter(1)
  %eps = f32[] constant(1)
  %odd = q99[4,4] custom-call(%p0)
  ROOT %d = f32[4,16] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parse_basic_entry():
    comps = hlo_stats.parse_hlo(_BASIC)
    assert set(comps) == {"main"}
    main = comps["main"]
    assert main.is_entry
    assert main.table["p0"] == ("f32", (4, 8))
    assert main.table["eps"] == ("f32", ())
    dot = main.by_name["d"]
    assert dot.is_root and dot.op == "dot"
    assert dot.operands == ["p0", "p1"]
    # dot FLOPs: 2 * out_elems * contracted = 2 * (4*16) * 8
    assert hlo_stats.dot_flops(dot, main.table) == 1024


def test_analyze_basic_flops_and_unknown_dtype_bytes():
    stats = hlo_stats.analyze(_BASIC)
    assert stats.flops == 1024
    # The q99 custom-call result is an unknown dtype: its traffic
    # contribution must be 0, never a KeyError.
    assert stats.hbm_bytes >= 0


_NESTED_WHILE = """\
ENTRY %main (p0: f32[2,2]) -> f32[2,2] {
  %p0 = f32[2,2] parameter(0)
  %t0 = (f32[2,2]) tuple(%p0)
  %w1 = (f32[2,2]) while((f32[2,2]) %t0), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %out = f32[2,2] get-tuple-element((f32[2,2]) %w1), index=0
}

%outer_body (arg.1: (f32[2,2])) -> (f32[2,2]) {
  %arg.1 = (f32[2,2]) parameter(0)
  %w2 = (f32[2,2]) while((f32[2,2]) %arg.1), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r1 = (f32[2,2]) tuple(%w2)
}

%inner_body (arg.2: (f32[2,2])) -> (f32[2,2]) {
  %arg.2 = (f32[2,2]) parameter(0)
  %g = f32[2,2] get-tuple-element((f32[2,2]) %arg.2), index=0
  %dd = f32[2,2] dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r2 = (f32[2,2]) tuple(%dd)
}

%outer_cond (arg.3: (f32[2,2])) -> pred[] {
  %arg.3 = (f32[2,2]) parameter(0)
  ROOT %c1 = pred[] constant(0)
}

%inner_cond (arg.4: (f32[2,2])) -> pred[] {
  %arg.4 = (f32[2,2]) parameter(0)
  ROOT %c2 = pred[] constant(0)
}
"""


def test_nested_while_trip_multipliers():
    """An op inside a 5-trip while inside a 3-trip while counts 15x —
    the multiplier semantics the scanned-layer roofline relies on."""
    comps = hlo_stats.parse_hlo(_NESTED_WHILE)
    assert comps["main"].whiles == [("outer_body", "outer_cond", 3)]
    assert comps["outer_body"].whiles == \
        [("inner_body", "inner_cond", 5)]
    stats = hlo_stats.analyze(_NESTED_WHILE)
    assert stats.while_trips == {"outer_body": 3, "inner_body": 5}
    # dot: 2 * (2*2) * 2 = 16 flops per trip, 3*5 trips
    assert stats.flops == 16 * 15


_COND_FALLBACK = """\
ENTRY %main (p0: s32[]) -> s32[] {
  %p0 = s32[] parameter(0)
  %t0 = (s32[]) tuple(%p0)
  %w = (s32[]) while((s32[]) %t0), condition=%cond, body=%body
  ROOT %out = s32[] get-tuple-element((s32[]) %w), index=0
}

%body (arg.1: (s32[])) -> (s32[]) {
  %arg.1 = (s32[]) parameter(0)
  %g = s32[] get-tuple-element((s32[]) %arg.1), index=0
  %one = s32[] constant(1)
  %n = s32[] add(%g, %one)
  ROOT %r = (s32[]) tuple(%n)
}

%cond (arg.2: (s32[])) -> pred[] {
  %arg.2 = (s32[]) parameter(0)
  %i = s32[] get-tuple-element((s32[]) %arg.2), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}
"""


def test_cond_compare_constant_fallback_trip_count():
    """Without backend_config the trip count falls back to the
    constant the loop condition compares against."""
    comps = hlo_stats.parse_hlo(_COND_FALLBACK)
    assert hlo_stats._cond_trip(comps, "cond") == 7
    stats = hlo_stats.analyze(_COND_FALLBACK)
    assert stats.while_trips == {"body": 7}


_FUSION = """\
ENTRY %main (p0: bf16[4,8], p1: bf16[8,16]) -> f32[4,16] {
  %p0 = bf16[4,8] parameter(0)
  %p1 = bf16[8,16] parameter(1)
  %cast = f32[4,8] fusion(%p0), kind=kLoop, calls=%cast_comp
  %big = f32[4,16] fusion(%cast, %p1), kind=kOutput, calls=%dot_comp
  ROOT %r = f32[4,16] copy(%big)
}

%cast_comp (cp: bf16[4,8]) -> f32[4,8] {
  %cp = bf16[4,8] parameter(0)
  ROOT %cv = f32[4,8] convert(%cp)
}

%dot_comp (dp0: f32[4,8], dp1: bf16[8,16]) -> f32[4,16] {
  %dp0 = f32[4,8] parameter(0)
  %dp1 = bf16[8,16] parameter(1)
  %dp1c = f32[8,16] convert(%dp1)
  ROOT %dd = f32[4,16] dot(%dp0, %dp1c), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_fusion_call_graph_and_classification():
    comps = hlo_stats.parse_hlo(_FUSION)
    main = comps["main"]
    # Both fusion targets land in the call graph.
    assert "cast_comp" in main.calls and "dot_comp" in main.calls
    cast = main.by_name["cast"]
    kind, payload = hlo_stats._classify_fusion(cast, comps)
    assert (kind, payload) == ("pure_cast", 0)
    # pure-cast fusions are CPU legalization artifacts: zero traffic.
    assert hlo_stats._traffic_bytes(cast, main, comps) == 0
    kind, _ = hlo_stats._classify_fusion(main.by_name["big"], comps)
    assert kind == "compute"


def test_fusion_called_computation_contributes_flops():
    stats = hlo_stats.analyze(_FUSION)
    # dot inside the fusion-called computation: 2 * (4*16) * 8
    assert stats.flops == 1024


_COLLECTIVE = """\
ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128] parameter(0)
  %ar = f32[64,128] all-reduce(%p0), replica_groups={}, to_apply=%sum
  ROOT %r = f32[64,128] copy(%ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""


def test_collective_bytes_per_kind():
    stats = hlo_stats.analyze(_COLLECTIVE)
    assert stats.collective_count == 1
    assert stats.collective_bytes == 64 * 128 * 4
    assert stats.per_collective == {"all-reduce": 64 * 128 * 4}


def test_entry_fallback_without_entry_marker():
    """A module printed without the ENTRY keyword still analyzes: the
    uncalled computation is taken as the root."""
    text = _BASIC.replace("ENTRY %main", "%main")
    stats = hlo_stats.analyze(text)
    assert stats.flops == 1024


@pytest.mark.parametrize("line,expect", [
    ("ENTRY %main (p: f32[2]) -> f32[2] {", ("main", True)),
    ("%scan_body.17 (arg: f32[2]) -> f32[2] {", ("scan_body.17", False)),
    ("not a header", None),
])
def test_header_regex(line, expect):
    m = hlo_stats._HEADER_RE.match(line.strip())
    if expect is None:
        assert m is None
    else:
        name, is_entry = expect
        assert m is not None
        assert m.group(2) == name
        assert bool(m.group(1)) == is_entry
