"""Graceful-degradation core tests (no hypothesis dependency): the
per-camera ``camera_mask`` through ``process_frame``/``process_fleet``,
NaN-slab sanitization, the 3-launch degraded budget, desync-policy
plumbing, and the eager mismatched-fleet ValueError."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CameraIntrinsics, DesyncError, ORBConfig,
                        PipelineConfig, RigConfig, VisualSystem)

H, W = 48, 64


def _imgs(seed, *lead):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 256, lead + (H, W))
                       .astype(np.float32))


def _quad(impl=None, **rig_kw):
    ocfg = ORBConfig(height=H, width=W, max_features=16, n_levels=2,
                     max_disparity=24)
    return VisualSystem(
        RigConfig.quad(CameraIntrinsics(cx=W / 2.0, cy=H / 2.0), **rig_kw),
        PipelineConfig(orb=ocfg, impl=impl))


def _tree_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# camera_mask through the frame/fleet paths

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_all_true_mask_is_bit_exact_identity(impl):
    vs = _quad(impl=impl)
    im = _imgs(0, 4)
    _tree_equal(vs.process_frame(im, camera_mask=np.ones(4, bool)),
                vs.process_frame(im), f"impl {impl}")


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_dead_camera_masks_its_pair_and_preserves_the_other(impl):
    """Camera 3 dead: pair (2,3) fully invalid, pair (0,1) bit-exact to
    the healthy frame — per-slab batch independence is what makes the
    whole degradation story sound."""
    vs = _quad(impl=impl)
    im = _imgs(1, 4)
    mask = np.asarray([True, True, True, False])
    out = vs.process_frame(im, camera_mask=mask)
    healthy = vs.process_frame(im)
    assert not np.asarray(out.matches.valid[1]).any()
    assert not np.asarray(out.depth.valid[1]).any()
    assert not np.asarray(out.features_r.valid[1]).any()
    _tree_equal(jax.tree.map(lambda x: x[0], out),
                jax.tree.map(lambda x: x[0], healthy), f"impl {impl}")


def test_nan_slab_is_sanitized_by_mask():
    """A masked camera's slab may be garbage (NaN): sanitization zeroes
    it BEFORE the kernels, so the output matches a zero-slab input
    bit for bit and no NaN leaks anywhere."""
    vs = _quad()
    im = np.asarray(_imgs(2, 4))
    bad = im.copy()
    bad[3] = np.nan
    zeroed = im.copy()
    zeroed[3] = 0.0
    mask = np.asarray([True, True, True, False])
    out_bad = vs.process_frame(jnp.asarray(bad), camera_mask=mask)
    out_zero = vs.process_frame(jnp.asarray(zeroed), camera_mask=mask)
    _tree_equal(out_bad, out_zero)
    for leaf in jax.tree.leaves(out_bad):
        assert np.isfinite(np.asarray(leaf, dtype=np.float64)).all()


def test_fleet_mask_matches_per_rig_frames():
    vs = _quad()
    fleet = _imgs(3, 3, 4)
    mask = np.asarray([[True] * 4,
                       [True, True, False, True],
                       [False, False, True, True]])
    out = vs.process_fleet(fleet, camera_mask=mask)
    for r in range(3):
        want = vs.process_frame(fleet[r], camera_mask=mask[r])
        _tree_equal(jax.tree.map(lambda x: x[r], out), want, f"rig {r}")


def test_degraded_paths_stay_in_the_3_launch_budget():
    """Masking is elementwise jnp — the degraded frame AND fleet trace
    the same 3 launches as the healthy path (CI gates the fleet one
    via benchmarks)."""
    vs = _quad()
    im = _imgs(4, 4)
    fleet = _imgs(5, 3, 4)
    fmask = jnp.asarray(np.asarray([True, True, True, False]))
    assert vs.traced_launches("process_frame", im) == 3
    assert vs.traced_launches("process_frame", im, fmask) == 3
    flmask_np = np.ones((3, 4), bool)
    flmask_np[1, 2] = False
    flmask = jnp.asarray(flmask_np)
    assert vs.traced_launches("process_fleet", fleet) == 3
    assert vs.traced_launches("process_fleet", fleet, flmask) == 3


def test_masked_entry_uses_its_own_jit_key():
    """Degraded calls must not retrace (or pollute) the healthy
    entry's cache."""
    vs = _quad()
    im = _imgs(6, 4)
    vs.process_frame(im)
    assert vs.trace_count("process_frame") == 1
    vs.process_frame(im, camera_mask=np.asarray([True, True, False, True]))
    vs.process_frame(im, camera_mask=np.asarray([True, True, True, False]))
    assert vs.trace_count("process_frame") == 1
    assert vs.trace_count("process_frame_masked") == 1   # mask is data


def test_camera_mask_shape_validated_eagerly():
    vs = _quad()
    with pytest.raises(ValueError, match="camera_mask"):
        vs.process_frame(_imgs(7, 4), camera_mask=np.ones(3, bool))
    with pytest.raises(ValueError, match="camera_mask"):
        vs.process_fleet(_imgs(8, 2, 4), camera_mask=np.ones((3, 4), bool))


# ---------------------------------------------------------------------------
# desync policy plumbing (the hypothesis matrix lives in
# test_desync_policy.py; these are the always-run pins)

def test_drop_frame_policy_returns_none_and_fleet_masks_rig():
    vs = _quad(desync_policy="drop_frame", max_desync=1e-3)
    im = _imgs(9, 4)
    ts_bad = [0.0, 0.0, 0.0, 1.0]
    assert vs.process_frame(im, timestamps=ts_bad) is None
    fleet = jnp.stack([im, im])
    out = vs.process_fleet(fleet, timestamps=[[0.0] * 4, ts_bad])
    # dropped rig: every validity field all-False; healthy rig intact
    for field in (out.features_l.valid, out.matches.valid,
                  out.depth.valid):
        assert not np.asarray(field[1]).any()
    _tree_equal(jax.tree.map(lambda x: x[0], out), vs.process_frame(im))


def test_fleet_raise_names_the_offending_rig():
    vs = _quad(desync_policy="raise", max_desync=1e-3)
    fleet = _imgs(10, 2, 4)
    with pytest.raises(DesyncError, match="fleet rig 1"):
        vs.process_fleet(fleet, timestamps=[[0.0] * 4, [0.0, 0.0, 0.0, 1.0]])


def test_degrade_composes_with_caller_mask():
    """Desync keep-mask ANDs into the caller's dead-camera mask."""
    vs = _quad(desync_policy="degrade", max_desync=1e-3)
    im = _imgs(11, 4)
    out = vs.process_frame(im, timestamps=[0.0, 0.0, 0.0, 1.0],
                           camera_mask=np.asarray([False, True, True, True]))
    want = vs.process_frame(
        im, camera_mask=np.asarray([False, True, True, False]))
    _tree_equal(out, want)


def test_two_camera_rig_with_split_tags_degrades_to_nothing():
    """Median-cluster rule on a stereo rig with one drifted tag: no
    camera agrees with the median within tolerance -> everything masks
    out (degradation, never a guess) — but no crash."""
    ocfg = ORBConfig(height=H, width=W, max_features=8, n_levels=1,
                     max_disparity=16)
    vs = VisualSystem(
        RigConfig.stereo(CameraIntrinsics(cx=W / 2.0, cy=H / 2.0),
                         desync_policy="degrade", max_desync=1e-3),
        PipelineConfig(orb=ocfg))
    out = vs.process_frame(_imgs(12, 2), timestamps=[0.0, 1.0])
    assert not np.asarray(out.features_l.valid).any()
    assert not np.asarray(out.matches.valid).any()


# ---------------------------------------------------------------------------
# eager fleet-shape footgun (ISSUE 6 satellite)

def test_mismatched_fleet_shapes_raise_eagerly():
    vs = _quad()
    quad = np.zeros((4, H, W), np.float32)
    stereo = np.zeros((2, H, W), np.float32)
    with pytest.raises(ValueError, match="mismatched frame shapes"):
        vs.process_fleet([quad, stereo])
    with pytest.raises(ValueError, match="per layout"):
        vs.process_fleet([quad, np.zeros((4, H, W + 2), np.float32)])


def test_fleet_sequence_input_still_works_when_uniform():
    vs = _quad()
    f0, f1 = np.asarray(_imgs(13, 4)), np.asarray(_imgs(14, 4))
    out = vs.process_fleet([f0, f1])
    _tree_equal(out, vs.process_fleet(jnp.stack([jnp.asarray(f0),
                                                 jnp.asarray(f1)])))
