"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward /
train-grad / prefill+decode-chain step on CPU, asserting output shapes,
no NaNs, and decode-vs-forward consistency (the gold cache test)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, shape_cells
from repro.models import lm
from repro.models.params import count_params, init_params

B, S = 2, 32


def _batch(cfg, seed=0, s=S):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, s)))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            0.1 * rng.normal(size=(B, cfg.vlm_prefix, cfg.d_model))
            .astype(np.float32))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            0.1 * rng.normal(size=(B, s, cfg.d_model)).astype(np.float32))
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for aid in ARCH_IDS:
        cfg = get_smoke_config(aid)
        params = init_params(lm.model_schema(cfg), jax.random.key(7))
        out[aid] = (cfg, params)
    return out


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_forward_shapes_and_finite(smoke_models, aid):
    cfg, params = smoke_models[aid]
    batch = _batch(cfg)
    logits, aux, _ = lm.forward_logits(params, cfg, batch)
    s_total = S + (cfg.vlm_prefix if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab])))


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_train_grad_step_finite(smoke_models, aid):
    cfg, params = smoke_models[aid]
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                               for g in flat)))
    assert gnorm > 0.0


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_decode_chain_matches_forward(smoke_models, aid):
    """Teacher-forced decode from a prefill cache must reproduce the
    full-forward logits token by token (validates KV layout, rolling
    buffers, SSM state carry, shared-block caches, cross-attention)."""
    cfg, params = smoke_models[aid]
    split = S // 2
    batch = _batch(cfg)
    full_logits, _, _ = lm.forward_logits(params, cfg, batch)

    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :split]
    cache, last_logits, pos = lm.prefill(params, cfg, prompt)
    cache = lm.expand_cache(cfg, cache, max_len=S + 8, prompt_len=split)

    prefix = cfg.vlm_prefix if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, prefix + split - 1]),
        rtol=2e-3, atol=2e-3)

    for t in range(split, min(split + 4, S)):
        tok = batch["tokens"][:, t:t + 1]
        logits, cache = lm.decode_step(params, cfg, tok, cache,
                                       jnp.asarray(prefix + t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, prefix + t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{aid} decode diverges at t={t}")


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_full_config_well_formed(aid):
    """The FULL (production) configs are exercised via the dry-run only;
    here we sanity-check their derived quantities."""
    cfg = get_config(aid)
    assert cfg.vocab_padded % 256 == 0 and cfg.vocab_padded >= cfg.vocab
    cells = shape_cells(cfg)
    assert [c.name for c in cells] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    live = [c for c in cells if c.applicable]
    if cfg.family in ("ssm", "hybrid") or (
            cfg.sliding_window and not cfg.local_global_period):
        assert len(live) == 4
    else:
        assert len(live) == 3
    if cfg.n_heads:
        assert cfg.n_heads % cfg.n_kv == 0
        if cfg.kv_eff != cfg.n_kv:
            assert cfg.kv_eff % cfg.n_kv == 0
            assert cfg.n_heads % cfg.kv_eff == 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.d_inner % cfg.ssm_head_dim == 0


def test_param_counts_match_public_scale():
    """Total parameters must land near the public model sizes (the
    arch names carry the count: 16B, 42B, 7B, 32B, 1.8B, 2B, 3B, 780M,
    7B, ~1.2B medium)."""
    expected = {
        # NOTE: the assigned 48L x 64-expert dims give ~28B total
        # (the public "16B" name corresponds to fewer layers); we
        # implement the dims as assigned.
        "moonshot_v1_16b_a3b": (26e9, 30e9),
        "phi35_moe_42b_a66b": (39e9, 45e9),
        "gemma_7b": (7.5e9, 9.5e9),
        "qwen25_32b": (31e9, 34e9),
        "h2o_danube_18b": (1.5e9, 2.1e9),
        "gemma2_2b": (2.2e9, 3.3e9),
        "paligemma_3b": (2.3e9, 3.2e9),     # backbone only (no SigLIP)
        "mamba2_780m": (0.7e9, 0.9e9),
        "zamba2_7b": (6.0e9, 8.5e9),
        "seamless_m4t_medium": (0.6e9, 1.6e9),  # frontend stubbed
    }
    for aid, (lo, hi) in expected.items():
        cfg = get_config(aid)
        n = count_params(lm.model_schema(cfg))
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
