"""`repro.analysis` acceptance: the static auditor proves the real
tree's invariants (launch budgets, VMEM residency, dtype contracts,
index-map bounds, serving hostlint) AND each checker demonstrably FAILS
on a deliberately broken fixture — an auditor that cannot fail proves
nothing."""

import json

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import analysis

H, W, K = 96, 128, 64
SMALL = dict(height=H, width=W, max_features=K)


def _spec(name):
    return next(s for s in analysis.MATRIX if s.name == name)


def _trace(name):
    return analysis.trace_entry(_spec(name), **SMALL)


# ---------------------------------------------------------------------------
# fixture kernels (traced only — interpret mode, never executed)

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _pallas_copy(x, in_spec=None, out_spec=None, grid=(2,)):
    spec = pl.BlockSpec((4,), lambda i: (i,))
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[in_spec if in_spec is not None else spec],
        out_specs=out_spec if out_spec is not None else spec,
        interpret=True)(x)


def _sites_of(fn, *avals):
    closed = jax.make_jaxpr(fn)(*avals)
    return closed, analysis.pallas_sites(closed)


_VEC = jax.ShapeDtypeStruct((8,), jnp.float32)


# ---------------------------------------------------------------------------
# launch auditor — green on the tree, red on extra launches

def test_frame_entry_proves_three_launch_budget():
    te = _trace("frame_f32")
    assert te.count.bounded
    assert te.count.total == 3 == len(te.sites)
    assert te.audit_count == 3
    assert all(s.mult == 1 for s in te.sites)


def test_localized_frame_is_four_launches():
    te = _trace("frame_loc")
    assert te.count.total == 4 <= _spec("frame_loc").launch_budget


def test_scan_applies_trip_multiplier():
    """run (T=2 sequential) is a scan over the 3-launch frame core:
    3 traced sites, each with multiplier 2, static total 6."""
    te = _trace("run_f32")
    assert len(te.sites) == 3 and te.audit_count == 3
    assert all(s.mult == 2 for s in te.sites)
    assert te.count.total == 6


def test_extra_launch_breaks_the_budget():
    closed, sites = _sites_of(lambda x: _pallas_copy(_pallas_copy(x)),
                              _VEC)
    count = analysis.count_launches(closed)
    assert count.total == 2 == len(sites)
    assert count.total > 1  # vs a 1-launch budget: the gate trips


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return _pallas_copy(c2), None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    closed, sites = _sites_of(f, _VEC)
    assert [s.mult for s in sites] == [12]
    assert analysis.count_launches(closed).total == 12


def test_while_body_launch_is_unbounded():
    def f(x):
        def body(carry):
            i, v = carry
            return i + 1, _pallas_copy(v)
        _, out = jax.lax.while_loop(lambda c: c[0] < 3, body, (0, x))
        return out

    closed, _ = _sites_of(f, _VEC)
    count = analysis.count_launches(closed)
    assert not count.bounded
    assert count.unbounded_sites
    assert "while" in count.unbounded_sites[0].path


def test_cond_counts_worst_case_branch():
    def f(p, x):
        return jax.lax.cond(p,
                            lambda v: _pallas_copy(_pallas_copy(v)),
                            lambda v: _pallas_copy(v), x)

    closed, sites = _sites_of(f, jax.ShapeDtypeStruct((), jnp.bool_),
                              _VEC)
    assert len(sites) == 3          # every branch's kernels reported
    assert analysis.count_launches(closed).total == 2  # max, not sum


# ---------------------------------------------------------------------------
# VMEM residency — documented number on the tree, red on a fat block

def test_fm_resident_bytes_match_documented_720p_number():
    """The fused FM launch at 720p f32 must account to the documented
    7.91 MiB/pair residency (the PR 7 class of regression this catches
    before runtime)."""
    te = analysis.trace_entry(_spec("match_f32"), height=720,
                              width=1280, max_features=1000)
    (site,) = te.sites
    v = analysis.launch_vmem(site)
    assert v.ok
    assert round(v.resident_bytes / 2 ** 20, 2) == 7.91


def test_all_matrix_launches_fit_default_budget():
    for name in ("frame_f32", "frame_u8", "frame_loc"):
        for site in _trace(name).sites:
            v = analysis.launch_vmem(site)
            assert v.ok, (name, v.kernel, v.resident_bytes)


def test_uint8_cuts_resident_bytes_3x():
    f32 = {v.kernel: v for v in
           (analysis.launch_vmem(s) for s in _trace("frame_f32").sites)}
    u8 = {v.kernel: v for v in
          (analysis.launch_vmem(s) for s in _trace("frame_u8").sites)}
    total_f32 = sum(v.resident_bytes for v in f32.values())
    total_u8 = sum(v.resident_bytes for v in u8.values())
    # Image slabs shrink 4x; int32 score/descriptor blocks are shared
    # by both datapaths, so the aggregate saving lands a bit above 3x.
    assert total_u8 * 3 <= total_f32


def test_oversized_block_fails_the_budget():
    big = jax.ShapeDtypeStruct((2048, 2048), jnp.float32)
    spec = pl.BlockSpec((2048, 2048), lambda: (0, 0))
    closed, (site,) = _sites_of(
        lambda x: pl.pallas_call(
            _copy_kernel, out_shape=big, in_specs=[spec],
            out_specs=spec, interpret=True)(x), big)
    v = analysis.launch_vmem(site)
    assert not v.ok                   # 2 x 16 MiB blocks vs 16 MiB core
    assert v.resident_bytes == 2 * 2048 * 2048 * 4
    assert analysis.launch_vmem(site, budget=64 * 2 ** 20).ok


def test_unblocked_halo_counted_in_block_bytes():
    """frontend_fused loads (1, T+8, T+8) halo windows via Unblocked —
    residency must charge the halo'd block, not the 128x128 tile."""
    te = _trace("frame_f32")
    halo = [b for s in te.sites
            for b in analysis.launch_vmem(s).blocks
            if b.mode == "Unblocked"]
    assert halo
    assert any(b.block_shape[-1] == 136 for b in halo)


# ---------------------------------------------------------------------------
# dtype flow — clean on the tree, red on a float leak

def test_uint8_matrix_has_zero_dtype_violations():
    for name in ("frame_u8", "fleet_u8"):
        te = _trace(name)
        for site in te.sites:
            assert analysis.check_kernel_dtypes(site) == [], site.name


def test_integer_contract_applies_to_dense_u8_frontend():
    from repro.analysis import dtype_flow
    te = _trace("frame_u8")
    assert any(dtype_flow._integer_contract(s) for s in te.sites)


def test_f32_leak_in_integer_kernel_is_flagged():
    def leaky(x_ref, o_ref):
        o_ref[...] = (x_ref[...].astype(jnp.float32)
                      * jnp.float32(1.5)).astype(jnp.uint8)

    spec = pl.BlockSpec((4,), lambda i: (i,))
    u8 = jax.ShapeDtypeStruct((8,), jnp.uint8)
    closed, (site,) = _sites_of(
        lambda x: pl.pallas_call(
            leaky, out_shape=u8, grid=(2,), in_specs=[spec],
            out_specs=spec, interpret=True)(x), u8)
    violations = analysis.check_kernel_dtypes(site)
    assert violations
    assert {v.rule for v in violations} == {"float-in-integer-kernel"}


def test_weak_float_promotion_is_its_own_rule():
    def promoted(x_ref, o_ref):
        o_ref[...] = (x_ref[...] + 0.5).astype(jnp.uint8)

    spec = pl.BlockSpec((4,), lambda i: (i,))
    u8 = jax.ShapeDtypeStruct((8,), jnp.uint8)
    closed, (site,) = _sites_of(
        lambda x: pl.pallas_call(
            promoted, out_shape=u8, grid=(2,), in_specs=[spec],
            out_specs=spec, interpret=True)(x), u8)
    rules = {v.rule for v in analysis.check_kernel_dtypes(site)}
    assert "weak-float-promotion" in rules


def test_float_kernel_is_exempt_from_integer_contract():
    closed, (site,) = _sites_of(_pallas_copy, _VEC)
    assert analysis.check_kernel_dtypes(site) == []


# ---------------------------------------------------------------------------
# bounds — proven on the tree, red on an off-by-one index map

def test_real_kernels_prove_in_bounds():
    for name in ("frame_f32", "frame_u8", "frame_loc"):
        for site in _trace(name).sites:
            assert analysis.check_bounds(site) == [], site.name


def test_blocked_index_map_off_by_one_is_caught():
    bad = pl.BlockSpec((4,), lambda i: (i + 1,))
    closed, (site,) = _sites_of(
        lambda x: _pallas_copy(x, in_spec=bad), _VEC)
    violations = analysis.check_bounds(site)
    assert violations
    assert violations[0].grid_point == (1,)
    assert "escapes" in violations[0].message


def test_unblocked_window_escaping_slab_is_caught():
    bad = pl.BlockSpec((6,), lambda i: (i * 4,),
                       indexing_mode=pl.Unblocked())
    out = pl.BlockSpec((6,), lambda i: (0,),
                       indexing_mode=pl.Unblocked())
    out_shape = jax.ShapeDtypeStruct((6,), jnp.float32)
    closed, (site,) = _sites_of(
        lambda x: pl.pallas_call(
            _copy_kernel, out_shape=out_shape, grid=(2,),
            in_specs=[bad], out_specs=out, interpret=True)(x), _VEC)
    violations = analysis.check_bounds(site)
    assert violations
    assert "[4, 10)" in violations[0].message


# ---------------------------------------------------------------------------
# hostlint — clean tree, red fixtures

def test_serving_tree_is_hostlint_clean():
    assert analysis.lint_serving() == []


_WATCHDOG_BAD = """
import threading

class Guard:
    def _attempt(self, fn):
        box = {}
        def worker():
            self.stats["calls"] += 1
            box["value"] = fn()
        t = threading.Thread(target=worker, daemon=True)
        t.start()
        return box
"""

_WATCHDOG_LOCKED = _WATCHDOG_BAD.replace(
    '            self.stats["calls"] += 1\n',
    '            with self._lock:\n'
    '                self.stats["calls"] += 1\n')


def test_lock_free_watchdog_mutation_is_flagged():
    findings = analysis.lint_source(_WATCHDOG_BAD, "failover.py")
    assert [f.rule for f in findings] == ["watchdog-unlocked"]
    assert findings[0].symbol == "self.stats"


def test_locked_watchdog_mutation_passes():
    assert analysis.lint_source(_WATCHDOG_LOCKED, "failover.py") == []


_HOT_BLOCKING = """
import time
import numpy as np

class Service:
    def step(self, now):
        out = self.vs.process_fleet(self.batch)
        out.depth.block_until_ready()
        host = np.asarray(out.depth)
        time.sleep(0.01)
        return host

    def submit(self, images):
        return np.asarray(images)
"""


def test_blocking_and_transfer_calls_flagged_only_in_hot_paths():
    findings = analysis.lint_source(_HOT_BLOCKING, "service.py")
    rules = sorted(f.rule for f in findings)
    assert rules == ["blocking-call", "blocking-call", "host-transfer"]
    # submit (intake) is not a hot path: its np.asarray is allowed.
    assert all(f.line < _HOT_BLOCKING.count("\n") for f in findings)


def test_pragma_suppresses_a_deliberate_call():
    src = _HOT_BLOCKING.replace(
        "host = np.asarray(out.depth)",
        "host = np.asarray(out.depth)  # audit: host-ok")
    rules = sorted(f.rule for f in
                   analysis.lint_source(src, "service.py"))
    assert rules == ["blocking-call", "blocking-call"]


def test_per_call_jit_in_hot_path_is_retrace_risk():
    src = """
import jax

class Service:
    def step(self, now):
        fn = jax.jit(lambda x: x + 1)
        return fn(self.batch)
"""
    findings = analysis.lint_source(src, "service.py")
    assert [f.rule for f in findings] == ["retrace-risk"]


# ---------------------------------------------------------------------------
# report + CI gate plumbing

def test_run_audit_green_on_current_tree():
    rep = analysis.run_audit(**SMALL)
    assert rep["ok"], rep["checks"]
    assert all(rep["checks"].values())
    names = {e["name"] for e in rep["entries"]}
    assert {"frame_f32", "frame_u8", "frame_loc", "fleet_loc",
            "match_f32"} <= names


def test_matrix_covers_every_required_runtime_gate():
    from benchmarks.check_launches import REQUIRED_GATES
    claimed = {g for s in analysis.MATRIX for g in s.gates}
    assert set(REQUIRED_GATES) <= claimed


def test_check_audit_reconciles_and_catches_drift(tmp_path):
    from benchmarks import check_audit
    from benchmarks.check_launches import REQUIRED_GATES

    entries = [{"name": f"e{i}", "gates": [g],
                "launches": {"static": 4 if "loc" in g else 3}}
               for i, g in enumerate(REQUIRED_GATES)]
    audit = {"checks": {"launch_budget": True}, "entries": entries}
    rows = [{"table": "launch_gate", "name": g,
             "value": 4 if "loc" in g else 3, "unit": "kernels",
             "note": ""} for g in REQUIRED_GATES]
    bench = {"rows": rows}

    a, b = tmp_path / "AUDIT.json", tmp_path / "BENCH.json"
    a.write_text(json.dumps(audit))
    b.write_text(json.dumps(bench))
    assert check_audit.check(str(a), str(b)) == 0

    # Runtime drifts by one launch -> the gate trips.
    rows[0]["value"] += 1
    b.write_text(json.dumps(bench))
    assert check_audit.check(str(a), str(b)) == 1

    # Non-numeric runtime value -> clear failure, not a crash.
    rows[0]["value"] = "n/a"
    b.write_text(json.dumps(bench))
    assert check_audit.check(str(a), str(b)) == 1


def test_check_launches_rejects_non_numeric_and_nan(capsys):
    from benchmarks.check_launches import _numeric
    assert _numeric({"value": 3}, "t", "n") == 3.0
    assert _numeric({"value": "3.5"}, "t", "n") == 3.5
    assert _numeric({"value": "oops"}, "t", "n") is None
    assert _numeric({"value": float("nan")}, "t", "n") is None
    assert _numeric({"value": None}, "t", "n") is None
    out = capsys.readouterr().out
    assert "not numeric" in out and "not finite" in out
