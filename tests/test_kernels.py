"""Per-kernel Pallas (interpret mode) vs pure-jnp oracle agreement.

Every kernel is swept over shapes and dtypes; integer datapaths must be
bit-exact, float paths allclose.  This is the Tab. III accuracy story at
the kernel level: the word-length-optimized (quantized) path is compared
against the float oracle separately in test_paper_claims.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref


def _img(rng, h, w, dtype):
    img = rng.randint(0, 256, (h, w)).astype(np.float32)
    if dtype == "uint8":
        return jnp.asarray(img.astype(np.uint8))
    return jnp.asarray(img)


SHAPES = [(32, 32), (37, 53), (128, 128), (130, 250), (240, 320)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", ["uint8", "float32"])
def test_fast_score_map_matches_ref(rng, shape, dtype):
    img = _img(rng, *shape, dtype)
    out_ref = ops.fast_score_map(img, 20.0, impl="ref")
    out_pl = ops.fast_score_map(img, 20.0, impl="pallas")
    assert out_pl.shape == shape
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pl))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("quantized", [True, False])
def test_gaussian_blur7_matches_ref(rng, shape, quantized):
    img = _img(rng, *shape, "float32")
    out_ref = ops.gaussian_blur7(img, quantized=quantized, impl="ref")
    out_pl = ops.gaussian_blur7(img, quantized=quantized, impl="pallas")
    if quantized:
        np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pl))
    else:
        np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pl),
                                   rtol=1e-5, atol=1e-4)


def _features(rng, k, h=480, w=640, level_count=2):
    desc = jnp.asarray(
        rng.randint(0, 2 ** 32, (k, 8), dtype=np.uint64).astype(np.uint32))
    x = rng.uniform(0, w, k).astype(np.float32)
    y = rng.uniform(0, h, k).astype(np.float32)
    lvl = rng.randint(0, level_count, k).astype(np.float32)
    valid = (rng.uniform(size=k) > 0.15).astype(np.float32)
    meta = jnp.asarray(np.stack([x, y, lvl, valid], axis=1))
    return desc, meta


@pytest.mark.parametrize("k,m", [(64, 64), (100, 130), (128, 128),
                                 (200, 77), (1, 1), (500, 500)])
def test_hamming_match_matches_ref(rng, k, m):
    dl, ml = _features(rng, k)
    dr, mr = _features(rng, m)
    d_ref, i_ref = ops.hamming_match(dl, ml, dr, mr, row_band=2.0,
                                     max_disparity=96.0, impl="ref")
    d_pl, i_pl = ops.hamming_match(dl, ml, dr, mr, row_band=2.0,
                                   max_disparity=96.0, impl="pallas")
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_pl))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pl))


@pytest.mark.parametrize("k", [1, 32, 128, 300])
@pytest.mark.parametrize("p,r", [(11, 5), (7, 3), (11, 2)])
def test_sad_search_matches_ref(rng, k, p, r):
    lp = jnp.asarray(rng.randint(0, 256, (k, p, p)).astype(np.float32))
    rs = jnp.asarray(rng.randint(0, 256, (k, p, p + 2 * r)).astype(np.float32))
    out_ref = ops.sad_search(lp, rs, impl="ref")
    out_pl = ops.sad_search(lp, rs, impl="pallas")
    assert out_pl.shape == (k, 2 * r + 1)
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pl))


def test_hamming_no_candidate_gives_minus_one(rng):
    dl, ml = _features(rng, 16)
    dr, mr = _features(rng, 16)
    # Push all right features outside any disparity window.
    mr = mr.at[:, 0].set(ml[:, 0].max() + 500.0)
    for impl in ("ref", "pallas"):
        d, i = ops.hamming_match(dl, ml, dr, mr, row_band=2.0,
                                 max_disparity=96.0, impl=impl)
        assert bool(jnp.all(i == -1))
        assert bool(jnp.all(d >= ops.NO_MATCH_DIST))


def test_popcount_against_python(rng):
    x = rng.randint(0, 2 ** 32, 4096, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(ref._popcount32(jnp.asarray(x)))
    want = np.array([bin(int(v)).count("1") for v in x], dtype=np.int32)
    np.testing.assert_array_equal(got, want)


def test_hamming_distance_identity(rng):
    d, _ = _features(rng, 32)
    mat = ref.hamming_distance_matrix(d, d)
    assert bool(jnp.all(jnp.diag(mat) == 0))
    assert bool(jnp.all((mat >= 0) & (mat <= 256)))
    np.testing.assert_array_equal(np.asarray(mat), np.asarray(mat).T)


def test_fast_score_constant_image_is_zero():
    img = jnp.full((64, 64), 128.0)
    for impl in ("ref", "pallas"):
        out = ops.fast_score_map(img, 20.0, impl=impl)
        assert float(jnp.max(out)) == 0.0


def test_gaussian_blur_constant_image_is_identity():
    img = jnp.full((64, 96), 77.0)
    for impl in ("ref", "pallas"):
        out = ops.gaussian_blur7(img, quantized=True, impl=impl)
        np.testing.assert_array_equal(np.asarray(out), 77.0)
