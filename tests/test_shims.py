"""Deprecation shims: every pre-existing public free function must (a)
still be importable, (b) warn ``DeprecationWarning`` with the "repro."
message prefix the pytest filter turns into errors elsewhere, and (c)
stay bit-exact against the ``VisualSystem`` session path it delegates
to.  Also pins the legacy ``ops`` shims (``set_default_impl``,
``reset_launch_count`` / ``launch_count``) over the context-var
machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CameraIntrinsics, ORBConfig, PipelineConfig,
                        RigConfig, VisualSystem, extract_features,
                        extract_pair, match_pair, process_quad_frame,
                        process_stereo_frame, run_sequence,
                        run_sequence_pipelined, sad_rectify, stereo_match,
                        temporal_match)
from repro.kernels import ops


def _imgs(seed, *shape):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 256, shape).astype(np.float32))


_H, _W = 64, 96
_CFG = ORBConfig(height=_H, width=_W, max_features=16, n_levels=2,
                 max_disparity=32)
_INTR = CameraIntrinsics(cx=_W / 2.0, cy=_H / 2.0)


def _quad_session(schedule="sequential"):
    return VisualSystem(RigConfig.quad(_INTR),
                        PipelineConfig(orb=_CFG, schedule=schedule))


def _stereo_session():
    return VisualSystem(RigConfig.stereo(_INTR), PipelineConfig(orb=_CFG))


def _assert_tree_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _call(fn, *args, **kwargs):
    """Every shim call must warn with the filterable 'repro.' prefix."""
    with pytest.warns(DeprecationWarning, match=r"^repro\..*deprecated"):
        return fn(*args, **kwargs)


def test_process_quad_frame_shim():
    imgs = _imgs(1, 4, _H, _W)
    want = _quad_session().process_frame(imgs)
    _assert_tree_equal(_call(process_quad_frame, imgs, _CFG, _INTR), want)


def test_process_stereo_frame_shim():
    imgs = _imgs(2, 2, _H, _W)
    want = jax.tree.map(lambda x: x[0],
                        _stereo_session().process_frame(imgs))
    got = _call(process_stereo_frame, imgs[0], imgs[1], _CFG, _INTR)
    _assert_tree_equal(got, want)


def test_run_sequence_shims():
    frames = _imgs(3, 3, 4, _H, _W)
    want = _quad_session().run(frames)
    _assert_tree_equal(_call(run_sequence, frames, _CFG, _INTR), want)
    want_p = _quad_session(schedule="pipelined").run(frames)
    _assert_tree_equal(
        _call(run_sequence_pipelined, frames, _CFG, _INTR), want_p)


def test_run_sequence_pipelined_shim_degenerate_lengths():
    """The T==0 / T==1 fix reaches the legacy entry point too."""
    frames = _imgs(4, 1, 4, _H, _W)
    one = _call(run_sequence_pipelined, frames, _CFG, _INTR)
    assert one.matches.valid.shape[0] == 1
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="empty sequence"):
            run_sequence_pipelined(frames[:0], _CFG, _INTR)


def test_extract_and_match_pair_shims():
    imgs = _imgs(5, 2, _H, _W)
    vs = _stereo_session()
    feats = vs.extract(imgs)
    want_l = jax.tree.map(lambda x: x[0], feats)
    want_r = jax.tree.map(lambda x: x[1], feats)
    got_l, got_r = _call(extract_pair, imgs[0], imgs[1], _CFG)
    _assert_tree_equal(got_l, want_l)
    _assert_tree_equal(got_r, want_r)
    want_m = vs.match_pair(imgs[0], imgs[1], want_l, want_r)
    got_m = _call(match_pair, imgs[0], imgs[1], got_l, got_r, _CFG, _INTR)
    _assert_tree_equal(got_m, want_m)


def test_matcher_shims():
    imgs = _imgs(6, 2, _H, _W)
    vs = _stereo_session()
    feat_l = extract_features(imgs[0], _CFG)
    feat_r = extract_features(imgs[1], _CFG)
    want = vs.stereo_match(feat_l, feat_r)
    got = _call(stereo_match, feat_l, feat_r, _CFG)
    _assert_tree_equal(got, want)
    want_t = vs.temporal_match(feat_l, feat_r, search_radius=32.0,
                               search_radius_y=8.0)
    got_t = _call(temporal_match, feat_l, feat_r, _CFG,
                  search_radius=32.0, search_radius_y=8.0)
    _assert_tree_equal(got_t, want_t)
    want_d = vs.sad_rectify(imgs[0], imgs[1], feat_l, feat_r, want)
    got_d = _call(sad_rectify, imgs[0], imgs[1], feat_l, feat_r, got,
                  _CFG, _INTR)
    _assert_tree_equal(got_d, want_d)


def test_ops_legacy_impl_shim():
    """set_default_impl still flips the process default; use_impl and
    explicit args override it."""
    try:
        ops.set_default_impl("pallas")
        assert ops.resolve_impl(None) == "pallas"
        with ops.use_impl("ref"):
            assert ops.resolve_impl(None) == "ref"
        assert ops.resolve_impl(None) == "pallas"
        assert ops.resolve_impl("ref") == "ref"
        with pytest.raises(ValueError, match="unknown kernel impl"):
            ops.set_default_impl("fpga")
    finally:
        ops.set_default_impl(None)


def test_shim_sessions_resolve_impl_per_call():
    """The legacy functions resolved impl on every call; the shim cache
    preserves that by resolving BEFORE the session lookup — a use_impl
    scope selects a different cached session."""
    from repro.core import pipeline
    a = pipeline.session_for(_CFG, None, None)
    with ops.use_impl("pallas"):
        b = pipeline.session_for(_CFG, None, None)
    assert a.impl == "ref" and b.impl == "pallas"
    assert a is not b
    assert pipeline.session_for(_CFG, None, None) is a


def test_ops_legacy_launch_count_shim():
    """reset_launch_count/launch_count keep working as a per-context
    counter and observe the same launches as a launch_audit scope."""
    imgs = _imgs(7, 2, _H, _W)
    ops.reset_launch_count()
    assert ops.launch_count() == 0
    with ops.launch_audit() as audit:
        jax.eval_shape(
            lambda im: extract_features(im, _CFG, impl="pallas"), imgs[0])
    assert audit.count == 2
    assert ops.launch_count() == 2
    ops.reset_launch_count()
    assert ops.launch_count() == 0
    assert audit.count == 2        # closed audits keep their tally
