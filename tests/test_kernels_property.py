"""Property-based (hypothesis) tests for kernel invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops

_SETTINGS = dict(max_examples=25, deadline=None)


@given(h=st.integers(8, 96), w=st.integers(8, 96), seed=st.integers(0, 2**16),
       thr=st.floats(1.0, 60.0))
@settings(**_SETTINGS)
def test_fast_pallas_equals_ref_random_shapes(h, w, seed, thr):
    rng = np.random.RandomState(seed)
    img = jnp.asarray(rng.randint(0, 256, (h, w)).astype(np.float32))
    a = ops.fast_score_map(img, thr, impl="ref")
    b = ops.fast_score_map(img, thr, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(h=st.integers(8, 96), w=st.integers(8, 96), seed=st.integers(0, 2**16))
@settings(**_SETTINGS)
def test_blur_bounds_and_agreement(h, w, seed):
    """Blur output stays within the input intensity range (a convex
    combination with one final rounding) and impls agree bit-exact."""
    rng = np.random.RandomState(seed)
    img = jnp.asarray(rng.randint(0, 256, (h, w)).astype(np.float32))
    a = ops.gaussian_blur7(img, quantized=True, impl="ref")
    b = ops.gaussian_blur7(img, quantized=True, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(jnp.min(a)) >= float(jnp.min(img)) - 1.0
    assert float(jnp.max(a)) <= float(jnp.max(img)) + 1.0


@given(k=st.integers(1, 64), m=st.integers(1, 64), seed=st.integers(0, 2**16),
       band=st.floats(0.0, 10.0), disp=st.floats(1.0, 200.0))
@settings(**_SETTINGS)
def test_hamming_match_invariants(k, m, seed, band, disp):
    rng = np.random.RandomState(seed)

    def feats(n):
        desc = jnp.asarray(rng.randint(0, 2**32, (n, 8), dtype=np.uint64)
                           .astype(np.uint32))
        meta = jnp.asarray(np.stack([
            rng.uniform(0, 640, n), rng.uniform(0, 480, n),
            rng.randint(0, 2, n).astype(float),
            (rng.uniform(size=n) > 0.2).astype(float)], axis=1)
            .astype(np.float32))
        return desc, meta

    dl, ml = feats(k)
    dr, mr = feats(m)
    d_ref, i_ref = ops.hamming_match(dl, ml, dr, mr, row_band=band,
                                     max_disparity=disp, impl="ref")
    d_pl, i_pl = ops.hamming_match(dl, ml, dr, mr, row_band=band,
                                   max_disparity=disp, impl="pallas")
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_pl))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pl))
    # invariants: matched distances in [0, 256]; indices in range or -1;
    # matched pairs actually satisfy the search-region constraints.
    d_np, i_np = np.asarray(d_ref), np.asarray(i_ref)
    matched = i_np >= 0
    assert np.all((d_np[matched] >= 0) & (d_np[matched] <= 256))
    assert np.all(i_np[matched] < m)
    ml_np, mr_np = np.asarray(ml), np.asarray(mr)
    for li in np.nonzero(matched)[0]:
        ri = i_np[li]
        dx = ml_np[li, 0] - mr_np[ri, 0]
        dy = abs(ml_np[li, 1] - mr_np[ri, 1])
        assert dy <= band + 1e-4 and -1e-4 <= dx <= disp + 1e-4
        assert ml_np[li, 2] == mr_np[ri, 2]
        assert ml_np[li, 3] > 0.5 and mr_np[ri, 3] > 0.5


@given(k=st.integers(1, 48), p=st.sampled_from([7, 11]),
       r=st.integers(1, 6), seed=st.integers(0, 2**16))
@settings(**_SETTINGS)
def test_sad_identity_strip_argmin_at_center(k, p, r, seed):
    """If the right strip contains the left patch exactly at offset r
    (the center), the SAD table has an exact zero at column r."""
    rng = np.random.RandomState(seed)
    lp = rng.randint(0, 256, (k, p, p)).astype(np.float32)
    rs = rng.randint(0, 256, (k, p, p + 2 * r)).astype(np.float32)
    rs[:, :, r:r + p] = lp
    table = np.asarray(ops.sad_search(jnp.asarray(lp), jnp.asarray(rs),
                                      impl="pallas"))
    assert np.all(table[:, r] == 0)
    assert np.all(table >= 0)
    np.testing.assert_array_equal(
        table, np.asarray(ops.sad_search(jnp.asarray(lp), jnp.asarray(rs),
                                         impl="ref")))
