"""Substrate tests: checkpoint/restart fault tolerance, elastic
re-mesh, gradient compression error bounds, data determinism, pipeline
parallelism equivalence, sharding resolver behaviour."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as Ps

from repro import checkpoint
from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataConfig, get_batch, host_shard
from repro.distributed import compression, pipeline
from repro.distributed.sharding import Rules, resolve
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop
from repro.models import lm
from repro.models.params import init_params
from repro.optim import AdamWConfig


# ---------------------------------------------------------------------------
# sharding resolver

def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolver_divisibility_skips_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = Rules.make()
    # 40 heads on a 1-wide model axis divides trivially; emulate a
    # 16-wide axis with a fake mesh via direct table checks instead:
    spec = resolve(rules.params, ("embed", "heads", "head_dim"),
                   (512, 40, 128), mesh)
    assert spec == Ps("data", "model") or isinstance(spec, Ps)


def test_resolver_no_axis_reuse():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = Rules.make()
    # vocab and ffn both want "model": only the first dim gets it
    spec = resolve(rules.acts, ("vocab", "ffn"), (256, 256), mesh)
    flat = [s for s in spec if s is not None]
    names = [n for s in flat for n in ((s,) if isinstance(s, str) else s)]
    assert len(names) == len(set(names))


def test_resolver_maximal_divisible_prefix():
    # batch wants (pod, data): with batch=2 only pod(2) fits on a
    # (2, 2, 1) mesh; with batch=4 both fit.  AbstractMesh lets the
    # resolver be tested without 4 physical devices.
    # jax 0.4.x AbstractMesh signature: one ((name, size), ...) tuple
    mesh = jax.sharding.AbstractMesh(
        (("pod", 2), ("data", 2), ("model", 1)))
    rules = Rules.make()
    s2 = resolve(rules.acts, ("batch",), (2,), mesh)
    s4 = resolve(rules.acts, ("batch",), (4,), mesh)
    assert s2 == Ps("pod")
    assert s4 == Ps(("pod", "data"))
    s3 = resolve(rules.acts, ("batch",), (3,), mesh)
    assert s3 == Ps()


# ---------------------------------------------------------------------------
# data pipeline

def test_data_pure_function_of_step():
    c = TokenDataConfig(vocab=1000, seq_len=64, global_batch=4, seed=3)
    a = get_batch(c, 7)["tokens"]
    b = get_batch(c, 7)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c2 = get_batch(c, 8)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(c2))


def test_data_induction_structure():
    c = TokenDataConfig(vocab=1000, seq_len=64, global_batch=2,
                        copy_frac=0.5)
    t = np.asarray(get_batch(c, 0)["tokens"])
    np.testing.assert_array_equal(t[:, 32:], t[:, :32])


def test_host_shard_partitions_batch():
    c = TokenDataConfig(vocab=1000, seq_len=16, global_batch=8)
    b = get_batch(c, 0)
    parts = [host_shard(b, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p) for p in parts]),
        np.asarray(b["tokens"]))


# ---------------------------------------------------------------------------
# checkpoint + restart fault tolerance

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": jnp.asarray(3)}
    checkpoint.save(str(tmp_path), 5, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    back = checkpoint.restore(str(tmp_path), 5, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]


def test_restart_is_bitwise_identical(tmp_path):
    """Crash at step 7, restart from the step-5 checkpoint: losses from
    the restarted run must equal the uninterrupted run exactly."""
    cfg = get_smoke_config("h2o_danube_18b").replace(remat="nothing")
    data = TokenDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    mesh = make_host_mesh()

    d1 = str(tmp_path / "uninterrupted")
    _, hist_full = train_loop(cfg, data, opt, mesh, 10, d1, ckpt_every=5,
                              log_every=100)

    d2 = str(tmp_path / "crashy")
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(cfg, data, opt, mesh, 10, d2, ckpt_every=5,
                   log_every=100, fail_at=7)
    # restart resumes from step 5 automatically
    _, hist_resumed = train_loop(cfg, data, opt, mesh, 10, d2,
                                 ckpt_every=5, log_every=100)
    full = dict(hist_full)
    for s, loss in hist_resumed:
        assert loss == full[s], (s, loss, full[s])


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Save params, restore onto a different mesh spec — values equal,
    shardings resolved for the new mesh."""
    cfg = get_smoke_config("gemma2_2b")
    schema = lm.model_schema(cfg)
    params = init_params(schema, jax.random.key(0))
    checkpoint.save(str(tmp_path), 1, params)

    from repro.distributed.elastic import reshard_restore
    mesh = make_host_mesh()          # 1 device — the "shrunk" cluster
    rules = Rules.make("tp")
    back = reshard_restore(str(tmp_path), 1, params, schema, mesh, rules)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# compressed gradient all-reduce

def test_compressed_psum_error_bound():
    """int8 ring all-reduce error stays within the quantization bound;
    on a 1-device axis it must be exact."""
    mesh = make_host_mesh()          # single device: n=1, exact path
    tree = {"w": jnp.asarray(np.random.RandomState(0)
                             .normal(size=(130,)).astype(np.float32))}
    out = compression.compressed_psum(tree, mesh, "data")
    got, want = np.asarray(out["w"]), np.asarray(tree["w"])
    scale = np.abs(want).max() / 127.0
    assert np.all(np.abs(got - want) <= scale * 1.01)


def test_quant_dequant_roundtrip_bound():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 5)
    q, s = compression._quant(x)
    back = compression._dequant(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# pipeline parallelism

def test_pipeline_forward_matches_serial():
    """GPipe over a 1-stage 'mesh' axis (host CPU) degenerates to serial
    — and the schedule math is validated vs direct application."""
    mesh = make_host_mesh()          # (1, 1): one stage
    rng = np.random.RandomState(0)
    n_stages = mesh.shape["data"]
    ws = jnp.asarray(rng.normal(size=(n_stages, 8, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 2, 8)).astype(np.float32))

    def stage(w, h):
        return jnp.tanh(h @ w)

    # run pipeline over the "data" axis
    out = pipeline.pipeline_forward(stage, mesh, "data", ws, x)
    want = x
    for sidx in range(n_stages):
        want = jax.vmap(lambda m: stage(ws[sidx], m))(want)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
