"""Fused batched frontend megakernel vs. the ref.py oracle chain.

The fused kernel must be BIT-exact per camera/level slice against the
unfused oracle pipeline (gaussian_blur7, fast_score_map, nms3) for every
batch slice, including non-tile-aligned shapes, in interpret mode on
CPU.  Also checks that the batched extractor the frontend now defaults
to agrees with per-image extraction.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ORBConfig, extract_features, extract_features_batched
from repro.core import pyramid
from repro.kernels import ops, ref


def _imgs(rng, b, h, w):
    return jnp.asarray(rng.randint(0, 256, (b, h, w)).astype(np.float32))


SHAPES = [(32, 32), (37, 53), (128, 128), (130, 250), (240, 320)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("b", [1, 4])
def test_fused_matches_oracle_chain_per_slice(rng, shape, b):
    imgs = _imgs(rng, b, *shape)
    blur, score = ops.fast_blur_nms_batched(imgs, 20.0, impl="pallas")
    assert blur.shape == imgs.shape and score.shape == imgs.shape
    for c in range(b):
        want_blur = ref.gaussian_blur7(imgs[c], quantized=True)
        want_score = ref.nms3(ref.fast_score_map(imgs[c], 20.0))
        np.testing.assert_array_equal(np.asarray(blur[c]),
                                      np.asarray(want_blur))
        np.testing.assert_array_equal(np.asarray(score[c]),
                                      np.asarray(want_score))


@pytest.mark.parametrize("nms", [True, False])
@pytest.mark.parametrize("quantized", [True, False])
def test_fused_jnp_fallback_bitexact_vs_oracle(rng, nms, quantized):
    """The interpret-free jnp fallback (running-min arcs, shared pad,
    inline NMS) must be BIT-exact against the straightforward oracle
    chain in both word-length modes — min/max reassociation and the
    preserved blur tap order make this exact, not approximate."""
    imgs = _imgs(rng, 3, 70, 111)
    blur, score = ops.fast_blur_nms_batched(imgs, 20.0, nms=nms,
                                            quantized=quantized, impl="ref")
    for c in range(3):
        want_blur, want_score = ref.fast_blur_nms(
            imgs[c], 20.0, nms=nms, quantized=quantized)
        np.testing.assert_array_equal(np.asarray(blur[c]),
                                      np.asarray(want_blur))
        np.testing.assert_array_equal(np.asarray(score[c]),
                                      np.asarray(want_score))


@pytest.mark.parametrize("nms", [True, False])
@pytest.mark.parametrize("quantized", [True, False])
def test_fused_flag_combinations(rng, nms, quantized):
    imgs = _imgs(rng, 2, 96, 130)
    out_ref = ops.fast_blur_nms_batched(imgs, 15.0, nms=nms,
                                        quantized=quantized, impl="ref")
    out_pl = ops.fast_blur_nms_batched(imgs, 15.0, nms=nms,
                                       quantized=quantized, impl="pallas")
    for a, p in zip(out_ref, out_pl):
        if quantized:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(p))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(p),
                                       rtol=1e-5, atol=1e-4)


def test_fused_paper_level1_shape(rng):
    """600x1067 — the paper's 1280x720 level-1 shape (Sec. III-C), far
    from tile alignment on both axes."""
    imgs = _imgs(rng, 1, 600, 1067)
    blur, score = ops.fast_blur_nms_batched(imgs, 20.0, impl="pallas")
    want_blur, want_score = ref.fast_blur_nms(imgs[0], 20.0)
    np.testing.assert_array_equal(np.asarray(blur[0]), np.asarray(want_blur))
    np.testing.assert_array_equal(np.asarray(score[0]),
                                  np.asarray(want_score))


def test_fused_nms_boundary_uses_constant_pad(rng):
    """A corner on the image border must survive NMS exactly as in the
    oracle (outside-image neighbours are -1, never real scores from the
    edge-padded halo)."""
    img = np.full((40, 48), 10.0, np.float32)
    img[0:5, 0:5] = 220.0        # bright square touching the border
    img[35:, 43:] = 220.0        # and one in the bottom-right corner
    imgs = jnp.asarray(img)[None]
    _, score_pl = ops.fast_blur_nms_batched(imgs, 20.0, impl="pallas")
    _, score_ref = ops.fast_blur_nms_batched(imgs, 20.0, impl="ref")
    np.testing.assert_array_equal(np.asarray(score_pl), np.asarray(score_ref))
    assert float(jnp.sum(score_ref > 0)) > 0


def test_tile_alignment_padding_never_suppresses_corners(rng):
    """Corners on the last row/col of a non-aligned image compete against
    -1 sentinels in the alignment pad, not against edge-replicated
    garbage scores."""
    h, w = 130, 131              # 2 px past a tile boundary on each axis
    img = np.full((h, w), 10.0, np.float32)
    img[h - 6:, w - 6:] = 220.0
    imgs = jnp.asarray(img)[None]
    _, score_pl = ops.fast_blur_nms_batched(imgs, 20.0, impl="pallas")
    _, score_ref = ops.fast_blur_nms_batched(imgs, 20.0, impl="ref")
    np.testing.assert_array_equal(np.asarray(score_pl), np.asarray(score_ref))


def test_extract_features_batched_matches_per_image(rng):
    """The batched extractor (frontend default) equals per-image
    extraction camera by camera."""
    imgs = _imgs(rng, 4, 96, 128)
    cfg = ORBConfig(height=96, width=128, max_features=48, n_levels=2)
    batched = extract_features_batched(imgs, cfg, impl="ref")
    for c in range(4):
        single = extract_features(imgs[c], cfg, impl="ref")
        for f in single._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(batched, f)[c]),
                np.asarray(getattr(single, f)), err_msg=f"camera {c} {f}")


def test_quad_frame_two_fused_launches_per_frame(rng):
    """Acceptance: a session frame issues exactly TWO fused FE launches
    per FRAME for all 4 cameras x all pyramid levels (1 dense
    blur+FAST+NMS + 1 sparse orientation+rBRIEF) — not per level, not
    per camera per op, and no host-graph descriptor gathers."""
    from repro.core import (CameraIntrinsics, PipelineConfig, RigConfig,
                            VisualSystem)
    imgs = _imgs(rng, 4, 64, 96)
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                    max_disparity=32)
    intr = CameraIntrinsics(cx=48.0, cy=32.0)
    vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=cfg))
    # 2 fused FE launches per frame; FM adds ONE fused matcher launch
    # covering both stereo pairs (the pair axis lives in the grid).
    assert vs.traced_launches("process_frame", imgs) == 2 + 1


def test_build_pyramid_batched_matches_single(rng):
    imgs = _imgs(rng, 3, 96, 128)
    cfg = ORBConfig(height=96, width=128, n_levels=3)
    batched = pyramid.build_pyramid_batched(imgs, cfg)
    for c in range(3):
        single = pyramid.build_pyramid(imgs[c], cfg)
        for lvl, (bl, sl) in enumerate(zip(batched, single)):
            np.testing.assert_array_equal(np.asarray(bl[c]), np.asarray(sl),
                                          err_msg=f"camera {c} level {lvl}")
