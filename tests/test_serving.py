"""Serving-layer unit tests: queue bucketing/padding/deadlines and the
supervisor's health state machine with deterministic backoff — all on a
virtual clock (no wall-time reads anywhere in the layer)."""

import numpy as np
import pytest

from repro.core import (CameraIntrinsics, ORBConfig, PipelineConfig,
                        RigConfig, VisualSystem)
from repro.serving import (FleetService, FrameQueue, QueueConfig, RigHealth,
                           Supervisor, SupervisorConfig)

H, W = 48, 64


def _rig(**kw):
    return RigConfig.quad(CameraIntrinsics(cx=W / 2.0, cy=H / 2.0), **kw)


def _frame(seed=0):
    return np.random.RandomState(seed).randint(
        0, 256, (4, H, W)).astype(np.float32)


# ---------------------------------------------------------------------------
# FrameQueue

def test_queue_put_validates_shape_eagerly():
    q = FrameQueue(_rig(), (H, W))
    with pytest.raises(ValueError, match=r"\(4, 48, 64\)"):
        q.put("a", np.zeros((4, H, W + 1), np.float32), 0.0)
    with pytest.raises(ValueError, match="camera_mask"):
        q.put("a", _frame(), 0.0, camera_mask=np.ones(3, bool))


def test_queue_buckets_and_pads():
    """3 pending rigs -> smallest covering bucket (4), padding row
    masked out of both rig_mask and camera_mask."""
    q = FrameQueue(_rig(), (H, W), QueueConfig(bucket_sizes=(1, 2, 4, 8),
                                               deadline_s=0.1))
    for r in range(3):
        q.put(r, _frame(r), t_arrival=0.0)
    batch = q.next_batch(now=0.2)          # past deadline -> ready
    assert batch is not None
    assert batch.images.shape == (4, 4, H, W)
    assert batch.rig_ids == (0, 1, 2)
    assert batch.rig_mask.tolist() == [True, True, True, False]
    assert batch.camera_mask[:3].all() and not batch.camera_mask[3].any()
    assert np.asarray(batch.images[3]).sum() == 0.0
    assert q.pending() == 0


def test_queue_not_ready_before_deadline_ready_when_full():
    cfg = QueueConfig(bucket_sizes=(1, 2), deadline_s=1.0)
    q = FrameQueue(_rig(), (H, W), cfg)
    q.put("a", _frame(), t_arrival=0.0)
    assert q.next_batch(now=0.5) is None          # under deadline, not full
    for i in range(1):
        q.put(i, _frame(i), t_arrival=0.5)
    assert q.ready(0.6)                           # largest bucket (2) full
    batch = q.next_batch(now=0.6)
    assert batch.n_real == 2 and not batch.late.any()
    # force flushes regardless of readiness
    q.put("z", _frame(), t_arrival=10.0)
    assert q.next_batch(now=10.0) is None
    assert q.next_batch(now=10.0, force=True).rig_ids == ("z",)


def test_queue_late_flag_and_overflow_drop():
    cfg = QueueConfig(bucket_sizes=(4,), deadline_s=0.1,
                      max_pending_per_rig=2)
    q = FrameQueue(_rig(), (H, W), cfg)
    q.put("a", _frame(1), t_arrival=0.0)
    q.put("a", _frame(2), t_arrival=1.0)
    q.put("a", _frame(3), t_arrival=2.0)   # 3rd pending -> oldest dropped
    assert q.dropped_overflow == 1 and q.pending() == 2
    batch = q.next_batch(now=2.05, force=True)
    assert batch.t_arrivals == (1.0, 2.0)  # t=0.0 frame was the drop
    assert batch.late.tolist() == [True, False]


def test_queue_partial_camera_mask_threads_through():
    q = FrameQueue(_rig(), (H, W))
    mask = np.asarray([True, True, False, True])
    q.put("a", _frame(), 0.0, camera_mask=mask)
    batch = q.next_batch(now=1.0, force=True)
    assert batch.camera_mask[0].tolist() == mask.tolist()


# ---------------------------------------------------------------------------
# Supervisor

def _sup(**kw):
    base = dict(heartbeat_timeout_s=1.0, backoff_base_s=1.0,
                backoff_factor=2.0, backoff_max_s=8.0, backoff_jitter=0.25,
                restart_budget=2, flap_window_s=100.0, seed=7)
    base.update(kw)
    return Supervisor(SupervisorConfig(**base))


def test_supervisor_heartbeats_keep_healthy():
    s = _sup()
    s.register("r", 0.0)
    for t in (0.5, 1.0, 1.5):
        s.heartbeat("r", t)
        assert s.poll(t) == []
    assert s.health("r") is RigHealth.HEALTHY
    s.heartbeat("r", 2.0, degraded=True)
    assert s.health("r") is RigHealth.DEGRADED
    s.heartbeat("r", 2.5)
    assert s.health("r") is RigHealth.HEALTHY


def test_supervisor_timeout_restart_recovery():
    s = _sup()
    s.register("r", 0.0)
    events = s.poll(2.0)                       # heartbeat lapsed
    assert [e.kind for e in events] == ["timeout"]
    assert s.health("r") is RigHealth.RESTARTING
    at = events[0].at
    assert 2.0 + 1.0 * 0.75 <= at <= 2.0 + 1.0 * 1.25   # base +- jitter
    assert s.poll(at - 1e-6) == []             # not due yet
    events = s.poll(at)
    assert [e.kind for e in events] == ["restart"]
    s.heartbeat("r", at + 0.1)                 # rig came back
    assert s.health("r") is RigHealth.HEALTHY
    assert s.poll(at + 0.2) == []


def test_supervisor_backoff_grows_then_quarantines():
    s = _sup(restart_budget=2)
    s.register("r", 0.0)
    t = 2.0
    delays = []
    for _ in range(2):
        ev = s.poll(t)
        assert ev[0].kind == "timeout"
        at = ev[0].at
        delays.append(at - t)
        ev = s.poll(at)
        assert [e.kind for e in ev] == ["restart"]
        t = at + 2.0                           # no heartbeat -> lapse again
    assert delays[1] > delays[0]               # exponential growth
    ev = s.poll(t)
    assert [e.kind for e in ev] == ["quarantine"]
    assert s.health("r") is RigHealth.QUARANTINED
    # quarantined rigs are inert until reinstated
    s.heartbeat("r", t + 1.0)
    assert s.health("r") is RigHealth.QUARANTINED
    assert s.poll(t + 50.0) == []
    s.reinstate("r", t + 60.0)
    ev = s.poll(t + 60.0)
    assert [e.kind for e in ev] == ["restart"]
    s.heartbeat("r", t + 61.0)
    assert s.health("r") is RigHealth.HEALTHY


def test_supervisor_backoff_deterministic_and_decorrelated():
    """Same seed -> identical schedules; different rigs -> different
    jitter (no restart stampede)."""
    def schedule(sup, rig):
        sup.register(rig, 0.0)
        return [e.at for e in sup.poll(5.0) if e.kind == "timeout"]

    a = schedule(_sup(seed=7), "rig-a")
    b = schedule(_sup(seed=7), "rig-a")
    assert a == b
    c = schedule(_sup(seed=7), "rig-b")
    assert a != c


def test_supervisor_flap_window_forgives_old_restarts():
    s = _sup(restart_budget=1, flap_window_s=10.0)
    s.register("r", 0.0)
    ev = s.poll(2.0)
    assert ev[0].kind == "timeout"
    s.poll(ev[0].at)
    s.heartbeat("r", ev[0].at + 0.1)           # recovers
    # next lapse far outside the flap window: budget is reset, so it
    # schedules a restart instead of quarantining
    ev = s.poll(ev[0].at + 50.0)
    assert [e.kind for e in ev] == ["timeout"]


def test_supervisor_status_report_structure():
    s = _sup()
    s.register("a", 0.0)
    s.register("b", 0.0)
    s.heartbeat("a", 0.5, degraded=True)
    rep = s.status_report(1.0)
    assert rep["counts"]["degraded"] == 1 and rep["counts"]["healthy"] == 1
    assert rep["rigs"]["a"]["degraded_frames"] == 1
    assert rep["rigs"]["b"]["since_heartbeat_s"] == 1.0


# ---------------------------------------------------------------------------
# FleetService intake (fault detection at submit; serving is covered
# end-to-end in test_serving_faults.py)

def _service(**rig_kw):
    ocfg = ORBConfig(height=H, width=W, max_features=8, n_levels=1,
                     max_disparity=16)
    vs = VisualSystem(_rig(**rig_kw), PipelineConfig(orb=ocfg))
    return FleetService(vs, QueueConfig(bucket_sizes=(1, 2, 4),
                                        deadline_s=0.01))


def test_service_detects_corrupt_slab():
    svc = _service()
    im = _frame()
    im[2] = np.nan
    assert svc.submit("r", im, 0.0) == "queued_degraded"
    assert svc.counters["corrupt_cameras"] == 1
    batch = svc.queue.next_batch(0.0, force=True)
    assert batch.camera_mask[0].tolist() == [True, True, False, True]


def test_service_never_raises_on_desync():
    """A raise-policy desync becomes a counted drop, not an exception —
    the service's never-crash discipline."""
    svc = _service(sync_policy="hardware")      # legacy policy -> raise
    ts = [0.0, 0.0, 0.0, 5.0]
    assert svc.submit("r", _frame(), 0.0, timestamps=ts) == "dropped_desync"
    assert svc.counters["dropped_desync"] == 1
    assert svc.supervisor.health("r") is RigHealth.DEGRADED
    assert svc.queue.pending() == 0


def test_service_degrade_policy_masks_camera():
    svc = _service(desync_policy="degrade", max_desync=1e-3)
    ts = [0.0, 0.0, 0.0, 5.0]
    assert svc.submit("r", _frame(), 0.0, timestamps=ts) == "queued_degraded"
    batch = svc.queue.next_batch(0.0, force=True)
    assert batch.camera_mask[0].tolist() == [True, True, True, False]


def test_service_drops_all_dead_frame():
    svc = _service()
    im = np.full((4, H, W), np.nan, np.float32)
    assert svc.submit("r", im, 0.0) == "dropped_dead"
    assert svc.queue.pending() == 0


def test_service_drops_quarantined_rig_frames():
    svc = _service()
    svc.supervisor.register("r", 0.0)
    svc.supervisor._rigs["r"].health = RigHealth.QUARANTINED
    assert svc.submit("r", _frame(), 1.0) == "dropped_quarantined"
    assert svc.queue.pending() == 0


def _u8_service():
    ocfg = ORBConfig(height=H, width=W, max_features=8, n_levels=1,
                     max_disparity=16)
    vs = VisualSystem(_rig(), PipelineConfig(orb=ocfg, precision="uint8"))
    return FleetService(vs, QueueConfig(bucket_sizes=(1, 2, 4),
                                        deadline_s=0.01))


def test_service_uint8_submit_is_zero_copy():
    """uint8 frames into a uint8-precision service skip the float32
    widen + finite scan + requantize entirely: the queued slab IS the
    caller's array (integer slabs are always finite), keeping the
    8-bit intake actually 8-bit."""
    svc = _u8_service()
    im = _frame().astype(np.uint8)
    assert svc.submit("r", im, 0.0) == "queued"
    pending = svc.queue.export_pending()
    assert pending[0].images.dtype == np.uint8
    assert np.shares_memory(pending[0].images, im)


def test_service_uint8_and_float_submits_agree():
    """The fast path changes the cost, not the bytes: a uint8 slab and
    its float32 twin queue identical frames (the float path round/clip
    quantizes to the same values)."""
    svc = _u8_service()
    im = _frame(4).astype(np.uint8)
    svc.submit("a", im, 0.0)
    svc.submit("b", im.astype(np.float32), 0.0)
    a, b = svc.queue.export_pending()
    np.testing.assert_array_equal(a.images, b.images)
    assert a.images.dtype == b.images.dtype == np.uint8


def test_service_uint8_still_catches_float_corruption():
    """A float slab with NaN into a uint8 service still takes the
    checked path — the fast path is gated on dtype, not assumed."""
    svc = _u8_service()
    im = _frame()
    im[1] = np.nan
    assert svc.submit("r", im, 0.0) == "queued_degraded"
    assert svc.counters["corrupt_cameras"] == 1
    batch = svc.queue.next_batch(0.0, force=True)
    assert batch.camera_mask[0].tolist() == [True, False, True, True]


def test_service_status_surfaces_queue_drop_counters():
    """``status()['counters']`` answers "what did we lose" in one dict:
    queue overflow drops are mirrored in alongside the intake/serve
    counters (late_frames already lives there)."""
    svc = _service()
    for i in range(4):      # max_pending_per_rig=2 -> 2 overflow drops
        svc.submit("r", _frame(i), float(i))
    status = svc.status(4.0)
    assert svc.queue.dropped_overflow == 2
    assert status["counters"]["dropped_overflow"] == 2
    assert status["queue"]["dropped_overflow"] == 2
    assert status["counters"]["frames_in"] == 4
