"""Low-precision integer datapath property suite (ISSUE 7).

Three contracts, each swept over camera counts, odd shapes and 1-8
pyramid levels (Hypothesis) on the jnp ref path AND pallas-interpret:

  (a) uint8 FAST keypoints == f32 keypoints.  The integer path is
      bit-exact against the QUANTIZED f32 path (same rounded pyramid
      values, same fixed-point blur, integer score comparisons), so the
      keypoint sets match exactly — the only freedom the contract
      allows is threshold-boundary ties, and the order-insensitive
      ``ref.keypoint_set_diff`` comparator would absorb tie
      permutations if they occurred.

  (b) descriptor Hamming distance to the f32 oracle is bounded: ZERO
      against the quantized oracle (bit-exact, pinned), and a measured
      ~14/256 bits mean against the UNQUANTIZED float oracle (the true
      quantization cost — pinned loosely at the fixed seeds below; a
      single steering-bin tie flip can move one descriptor ~150 bits,
      which is why the pin is on the mean, not the max).

  (c) the int8 wire format (``repro.distributed.compression``) round-
      trips descriptors LOSSLESSLY (bit patterns through the uint8 byte
      view) and float disparities within the int8+scale bound
      (max|x|/127 absolute).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is a dev/CI dep; fixed-case tests below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (CameraIntrinsics, ORBConfig,  # noqa: E402
                        PipelineConfig, RigConfig, VisualSystem)
from repro.core.orb import extract_features_batched  # noqa: E402
from repro.core.types import DepthSet, MatchSet  # noqa: E402
from repro.distributed import compression  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.serving import wire_decode, wire_encode  # noqa: E402

_SETTINGS = dict(max_examples=15, deadline=None)


def _imgs_u8(seed, b, h, w):
    rng = np.random.RandomState(seed % (2 ** 31))
    return rng.randint(0, 256, (b, h, w)).astype(np.uint8)


def _cfg(h, w, n_levels, thr=20, quantized=True):
    return ORBConfig(height=h, width=w, max_features=24,
                     n_levels=n_levels, fast_threshold=thr,
                     quantized=quantized)


def _assert_bitexact(fu, ff, msg):
    """uint8-path FeatureSet vs quantized-f32-path FeatureSet: every
    field identical (scores are integer-valued in both)."""
    for name in fu._fields:
        a, b = getattr(fu, name), getattr(ff, name)
        assert a.dtype == b.dtype, f"{msg}: {name} dtype {a.dtype}!={b.dtype}"
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}: field {name}")


# ---------------------------------------------------------------------------
# (a) + (b, quantized oracle): bit-exactness sweeps, ref then interpret


def _check_u8_equals_f32(b, h, w, n_levels, thr, seed, impl):
    imgs = _imgs_u8(seed, b, h, w)
    cfg = _cfg(h, w, n_levels, thr)
    fu = extract_features_batched(jnp.asarray(imgs), cfg, impl=impl,
                                  precision="uint8")
    ff = extract_features_batched(jnp.asarray(imgs.astype(np.float32)),
                                  cfg, impl=impl)
    for i in range(b):
        assert ref.keypoint_set_diff(fu.xy[i], fu.valid[i],
                                     ff.xy[i], ff.valid[i]) == 0
        mean, mx = ref.descriptor_hamming_stats(
            fu.desc[i], ff.desc[i], fu.valid[i] & ff.valid[i])
        assert (mean, mx) == (0.0, 0)
    _assert_bitexact(fu, ff,
                     f"{impl} b={b} {h}x{w} L={n_levels} thr={thr}")


def _check_u8_frame_bitexact(h, w, seed, impl):
    """Whole 3-launch frame (FE + fused FM + SAD + depth): the uint8
    session's StereoOutput equals the f32 session's on every leaf."""
    imgs = _imgs_u8(seed, 4, h, w)
    cfg = ORBConfig(height=h, width=w, max_features=16, n_levels=2,
                    max_disparity=32)
    rig = RigConfig.quad(CameraIntrinsics(cx=w / 2.0, cy=h / 2.0))
    vs_f = VisualSystem(rig, PipelineConfig(orb=cfg, impl=impl))
    vs_u = VisualSystem(rig, PipelineConfig(orb=cfg, impl=impl,
                                            precision="uint8"))
    out_f = vs_f.process_frame(jnp.asarray(imgs.astype(np.float32)))
    out_u = vs_u.process_frame(jnp.asarray(imgs))
    for a, b in zip(jax.tree.leaves(out_f), jax.tree.leaves(out_u)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"impl={impl}")


def test_u8_equals_f32_ref_fixed():
    # odd shapes, 1..8 levels, varying camera counts and thresholds
    for case in [(1, 25, 33, 1, 7, 0), (2, 37, 45, 3, 20, 1),
                 (4, 64, 96, 5, 31, 2), (1, 47, 31, 8, 12, 3)]:
        _check_u8_equals_f32(*case, impl="ref")


def test_u8_equals_f32_pallas_interpret_fixed():
    for case in [(1, 24, 40, 1, 20, 4), (2, 33, 47, 2, 15, 5)]:
        _check_u8_equals_f32(*case, impl="pallas")


def test_u8_frame_bitexact_both_impls():
    for impl in ("ref", "pallas"):
        _check_u8_frame_bitexact(40, 56, 6, impl)


if HAVE_HYPOTHESIS:

    @given(b=st.integers(1, 4), h=st.integers(24, 96),
           w=st.integers(24, 96), n_levels=st.integers(1, 8),
           thr=st.integers(5, 40), seed=st.integers(0, 2 ** 16))
    @settings(**_SETTINGS)
    def test_prop_u8_equals_f32_ref(b, h, w, n_levels, thr, seed):
        _check_u8_equals_f32(b, h, w, n_levels, thr, seed, impl="ref")

    @given(b=st.integers(1, 2), h=st.integers(24, 72),
           w=st.integers(24, 72), n_levels=st.integers(1, 4),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=8, deadline=None)
    def test_prop_u8_equals_f32_pallas_interpret(b, h, w, n_levels,
                                                 seed):
        _check_u8_equals_f32(b, h, w, n_levels, 20, seed, impl="pallas")

    @given(h=st.integers(32, 72), w=st.integers(40, 80),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=6, deadline=None)
    def test_prop_u8_frame_bitexact_both_impls(h, w, seed):
        for impl in ("ref", "pallas"):
            _check_u8_frame_bitexact(h, w, seed, impl)


# ---------------------------------------------------------------------------
# (b, unquantized oracle): the true quantization cost, pinned


def test_u8_vs_unquantized_oracle_bounded():
    """Against the UNQUANTIZED float pipeline (float pyramid levels,
    float Gaussian), the uint8 path's error is the word-length
    quantization itself.  Measured at these seeds: descriptor Hamming
    mean ~14/256 bits, keypoint set diff <= 2 per image (threshold-
    boundary ties).  Pinned with headroom — a regression that breaks
    integer math shows up as hundreds of bits, not tens."""
    means, kdiffs = [], []
    for seed in range(6):
        h, w = 61 + seed, 83 + seed
        imgs = _imgs_u8(seed, 2, h, w)
        cfg_q = _cfg(h, w, 3)
        cfg_u = dataclasses.replace(cfg_q, quantized=False)
        fu = extract_features_batched(jnp.asarray(imgs), cfg_q,
                                      impl="ref", precision="uint8")
        ff = extract_features_batched(
            jnp.asarray(imgs.astype(np.float32)), cfg_u, impl="ref")
        for i in range(2):
            mean, _ = ref.descriptor_hamming_stats(
                fu.desc[i], ff.desc[i], fu.valid[i] & ff.valid[i])
            means.append(mean)
            kdiffs.append(ref.keypoint_set_diff(
                fu.xy[i], fu.valid[i], ff.xy[i], ff.valid[i]))
    assert float(np.mean(means)) <= 24.0, means    # measured ~14.3
    assert max(means) <= 48.0, means
    assert max(kdiffs) <= 6, kdiffs                # measured <= 2


# ---------------------------------------------------------------------------
# (c) int8 wire format round-trips


def _check_wire_descriptors_lossless(k, seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    desc = jnp.asarray(rng.randint(0, 2 ** 32, (k, 8), dtype=np.uint64)
                       .astype(np.uint32))
    wire = compression.encode_descriptors(desc)
    assert wire.dtype == jnp.uint8 and wire.shape == (k, 32)
    back = compression.decode_descriptors(wire)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(desc))


def _check_wire_disparity_bounded(k, scale, seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    disp = jnp.asarray((rng.rand(k) * scale).astype(np.float32))
    depth = DepthSet(disparity=disp, depth=disp * 2.0,
                     xy_right=jnp.stack([disp, disp], -1),
                     valid=jnp.asarray(rng.rand(k) > 0.3))
    back = compression.decode_depth(compression.encode_depth(depth))
    bound = float(jnp.max(jnp.abs(disp))) / 127.0 + 1e-6
    assert ref.max_abs_err(back.disparity, depth.disparity) <= bound
    assert ref.max_abs_err(back.depth, depth.depth) <= 2.0 * bound + 1e-6
    np.testing.assert_array_equal(np.asarray(back.valid),
                                  np.asarray(depth.valid))


def _check_wire_matches_lossless(k, seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    idx = rng.randint(-1, k, k).astype(np.int32)
    dist = np.where(idx < 0, ops.NO_MATCH_DIST,
                    rng.randint(0, 257, k)).astype(np.int32)
    m = MatchSet(right_index=jnp.asarray(idx), distance=jnp.asarray(dist),
                 valid=jnp.asarray(idx >= 0))
    back = compression.decode_matches(
        compression.encode_matches(m),
        no_match_distance=ops.NO_MATCH_DIST)
    for name in m._fields:
        np.testing.assert_array_equal(np.asarray(getattr(back, name)),
                                      np.asarray(getattr(m, name)),
                                      err_msg=name)


def test_wire_roundtrips_fixed():
    for k, seed in [(1, 0), (9, 1), (64, 2)]:
        _check_wire_descriptors_lossless(k, seed)
        _check_wire_disparity_bounded(k, 96.0, seed)
        _check_wire_matches_lossless(k, seed)


if HAVE_HYPOTHESIS:

    @given(k=st.integers(1, 64), seed=st.integers(0, 2 ** 16))
    @settings(**_SETTINGS)
    def test_prop_wire_descriptors_lossless(k, seed):
        _check_wire_descriptors_lossless(k, seed)

    @given(k=st.integers(1, 64), scale=st.floats(0.1, 500.0),
           seed=st.integers(0, 2 ** 16))
    @settings(**_SETTINGS)
    def test_prop_wire_disparity_bounded(k, scale, seed):
        _check_wire_disparity_bounded(k, scale, seed)

    @given(k=st.integers(1, 64), seed=st.integers(0, 2 ** 16))
    @settings(**_SETTINGS)
    def test_prop_wire_matches_lossless(k, seed):
        _check_wire_matches_lossless(k, seed)


def test_wire_stereo_output_roundtrip():
    """Full served-frame uplink: descriptors, match fields and validity
    bit-exact through ``serving.wire_encode``/``wire_decode``; float
    fields within the int8+scale bound; payload smaller than f32."""
    h, w = 48, 64
    imgs = _imgs_u8(3, 4, h, w)
    cfg = ORBConfig(height=h, width=w, max_features=16, n_levels=2,
                    max_disparity=32)
    vs = VisualSystem(RigConfig.quad(CameraIntrinsics(cx=w / 2, cy=h / 2)),
                      PipelineConfig(orb=cfg, precision="uint8"))
    out = vs.process_frame(jnp.asarray(imgs))
    wire = wire_encode(out)
    back = wire_decode(wire)
    np.testing.assert_array_equal(np.asarray(back.features_l.desc),
                                  np.asarray(out.features_l.desc))
    np.testing.assert_array_equal(np.asarray(back.features_r.desc),
                                  np.asarray(out.features_r.desc))
    for name in out.matches._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(back.matches, name)),
            np.asarray(getattr(out.matches, name)), err_msg=name)
    bound = float(jnp.max(jnp.abs(out.depth.disparity))) / 127.0 + 1e-6
    assert ref.max_abs_err(back.depth.disparity,
                           out.depth.disparity) <= bound
    assert (compression.wire_bytes(wire)
            < sum(np.asarray(x).nbytes for x in jax.tree.leaves(out)))


# ---------------------------------------------------------------------------
# config / input validation + launch budget


def test_precision_config_validation():
    with pytest.raises(ValueError, match="precision"):
        PipelineConfig(precision="fp16")
    with pytest.raises(ValueError, match="quantized"):
        PipelineConfig(orb=ORBConfig(quantized=False), precision="uint8")
    with pytest.raises(ValueError, match="quantized=True"):
        # kernels enforce it too, independent of the session layer
        from repro.kernels.frontend_fused import _slab_dtypes
        _slab_dtypes(jnp.zeros((1, 8, 8), jnp.uint8), quantized=False)


def test_dtype_validation_names_precision():
    h, w = 32, 48
    cfg = ORBConfig(height=h, width=w, max_features=8, n_levels=1,
                    max_disparity=16)
    rig = RigConfig.quad(CameraIntrinsics(cx=w / 2.0, cy=h / 2.0))
    vs_u = VisualSystem(rig, PipelineConfig(orb=cfg, precision="uint8"))
    vs_f = VisualSystem(rig, PipelineConfig(orb=cfg))
    f32 = jnp.zeros((4, h, w), jnp.float32)
    u8 = jnp.zeros((4, h, w), jnp.uint8)
    with pytest.raises(TypeError, match="precision='uint8'"):
        vs_u.process_frame(f32)
    with pytest.raises(TypeError, match="precision='f32'"):
        vs_f.process_frame(u8)
    with pytest.raises(TypeError, match="precision='uint8'"):
        vs_u.process_fleet(jnp.zeros((2, 4, h, w), jnp.float32))
    with pytest.raises(TypeError, match="precision='f32'"):
        vs_f.process_fleet(jnp.zeros((2, 4, h, w), jnp.uint8))
    # the happy paths still work after the failed calls
    assert vs_u.process_frame(u8) is not None
    assert vs_f.process_frame(f32) is not None


def test_u8_launch_budget():
    """uint8 frame and fleet frame trace EXACTLY 3 launches — dtype
    switches the kernels' element type, never the launch graph (the
    CI-gated numbers from benchmarks.run's launch_gate/u8_* rows)."""
    h, w = 32, 48
    cfg = ORBConfig(height=h, width=w, max_features=8, n_levels=2,
                    max_disparity=16)
    vs = VisualSystem(RigConfig.quad(CameraIntrinsics(cx=w / 2, cy=h / 2)),
                      PipelineConfig(orb=cfg, precision="uint8"))
    assert vs.traced_launches("process_frame",
                              jnp.zeros((4, h, w), jnp.uint8)) == 3
    assert vs.traced_launches("process_fleet",
                              jnp.zeros((3, 4, h, w), jnp.uint8)) == 3
