"""Behavioural tests of the ORB extraction stages (paper Sec. II-B)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ORBConfig, extract_features
from repro.core import brief, fast, pattern, pyramid
from repro.kernels import ref


def _corner_image(h=96, w=128, pts=((30, 40), (60, 90), (70, 20))):
    """Dark background with bright squares -> strong FAST corners."""
    img = np.full((h, w), 30.0, np.float32)
    for (y, x) in pts:
        img[y:y + 6, x:x + 6] = 220.0
    return jnp.asarray(img)


def test_pyramid_shapes_match_paper():
    cfg = ORBConfig(height=720, width=1280, n_levels=2)
    assert cfg.level_shape(0) == (720, 1280)
    assert cfg.level_shape(1) == (600, 1067)  # paper Sec. III-C


def test_pyramid_level_count_and_range():
    cfg = ORBConfig(height=96, width=128, n_levels=3)
    img = _corner_image()
    levels = pyramid.build_pyramid(img, cfg)
    assert len(levels) == 3
    for lvl, im in enumerate(levels):
        assert im.shape == cfg.level_shape(lvl)
        assert float(im.min()) >= 0.0 and float(im.max()) <= 255.0


def test_fast_detects_square_corners():
    img = _corner_image()
    cfg = ORBConfig(height=96, width=128, max_features=32, border=16)
    xy, score, theta, valid = fast.detect(img, cfg, k=32)
    got = {(int(x), int(y)) for (x, y), v in
           zip(np.asarray(xy), np.asarray(valid)) if v}
    # each stamped square produces corners near its own corners
    for (y0, x0) in ((30, 40), (60, 90)):
        near = [(x, y) for x, y in got
                if abs(x - x0) <= 8 and abs(y - y0) <= 8]
        assert near, f"no corner near square at {(x0, y0)}"


def test_nms_keeps_local_maxima_only():
    score = jnp.zeros((16, 16)).at[5, 5].set(10.0).at[5, 6].set(8.0)
    out = fast.nms3(score)
    assert float(out[5, 5]) == 10.0
    assert float(out[5, 6]) == 0.0


def test_topk_respects_border_and_static_shape():
    score = jnp.ones((64, 64))
    xy, vals, valid = fast.select_topk(score, k=16, border=16)
    assert xy.shape == (16, 2) and valid.shape == (16,)
    xs, ys = np.asarray(xy[:, 0]), np.asarray(xy[:, 1])
    v = np.asarray(valid)
    assert np.all(xs[v] >= 16) and np.all(xs[v] < 48)
    assert np.all(ys[v] >= 16) and np.all(ys[v] < 48)


def test_orientation_points_toward_bright_side():
    """Patch bright on +x side -> centroid to the right -> theta ~ 0;
    bright on +y side -> theta ~ +pi/2 (y down)."""
    img = np.full((64, 64), 10.0, np.float32)
    img[:, 40:] = 200.0  # bright right half
    theta = fast.orientations(jnp.asarray(img),
                              jnp.asarray([[32, 32]], np.int32))
    assert abs(float(theta[0])) < 0.2
    img2 = np.full((64, 64), 10.0, np.float32)
    img2[40:, :] = 200.0  # bright bottom half
    theta2 = fast.orientations(jnp.asarray(img2),
                               jnp.asarray([[32, 32]], np.int32))
    assert abs(float(theta2[0]) - np.pi / 2) < 0.2


def test_pattern_within_patch_after_rotation():
    """Paper Eq. 3: rotated pairs must stay inside the 31x31 patch."""
    for theta in np.linspace(0.0, 2 * np.pi, 17):
        rot = pattern.rotated_pattern(theta)
        assert np.abs(rot).max() <= pattern.PATCH_RADIUS


def test_descriptor_rotation_invariance():
    """The steered descriptor of a rotated image stays close in Hamming
    distance (rBRIEF's purpose, paper Sec. II-B2)."""
    rng = np.random.RandomState(3)
    img = rng.randint(0, 256, (96, 96)).astype(np.float32)
    img_r = np.rot90(img, k=1).copy()  # 90 deg CCW in array coords
    cfg = ORBConfig(height=96, width=96)
    sm = brief.smooth(jnp.asarray(img), cfg, impl="ref")
    sm_r = brief.smooth(jnp.asarray(img_r), cfg, impl="ref")
    c = 48
    # point (x, y) maps to (y, H-1-x) under np.rot90(k=1)
    x0, y0 = 60, 40
    x1, y1 = y0, 96 - 1 - x0
    th0 = fast.orientations(jnp.asarray(img), jnp.asarray([[x0, y0]],
                                                          np.int32))[0]
    th1 = fast.orientations(jnp.asarray(img_r), jnp.asarray([[x1, y1]],
                                                            np.int32))[0]
    d0 = brief.describe(sm, jnp.asarray([[x0, y0]], np.int32),
                        jnp.asarray([th0]))
    d1 = brief.describe(sm_r, jnp.asarray([[x1, y1]], np.int32),
                        jnp.asarray([th1]))
    dist = int(ref.hamming_distance_matrix(d0, d1)[0, 0])
    # unrotated-descriptor baseline distance would be ~128 (random);
    # steering must do much better.
    assert dist < 70, f"rotation invariance broken: hamming={dist}"


def test_extract_features_static_shapes_and_level_coords():
    img = _corner_image()
    cfg = ORBConfig(height=96, width=128, max_features=64, n_levels=2)
    fs = extract_features(img, cfg)
    assert fs.xy.shape == (64, 2)
    assert fs.desc.shape == (64, 8) and fs.desc.dtype == jnp.uint32
    # level-1 coordinates are scaled back to level-0 pixel space
    lvl = np.asarray(fs.level)
    xy = np.asarray(fs.xy)
    v = np.asarray(fs.valid)
    assert np.all(xy[v][:, 0] < 128.0 * 1.01)
    assert int(fs.count()) > 0
    if np.any(v & (lvl == 1)):
        # scaled coords may be fractional
        assert np.any(np.abs(xy[v & (lvl == 1)] % 1.0) > 0)


@pytest.mark.parametrize("k", [16, 33, 100])
def test_feature_budget_split(k):
    cfg = ORBConfig(height=720, width=1280, max_features=k, n_levels=2)
    ks = cfg.features_per_level()
    assert sum(ks) == k and all(x >= 1 for x in ks)
    assert ks[0] > ks[1]  # level 0 has more area -> larger budget
