"""Optimization-backend tests: pose estimation building blocks + VO."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend
from repro.core.types import CameraIntrinsics


def _random_rt(rng, angle=0.1, scale=0.5):
    w = rng.normal(size=3)
    w = angle * w / np.linalg.norm(w)
    theta = np.linalg.norm(w)
    k = np.array([[0, -w[2], w[1]], [w[2], 0, -w[0]], [-w[1], w[0], 0]])
    r = (np.eye(3) + np.sin(theta) / theta * k
         + (1 - np.cos(theta)) / theta**2 * (k @ k))
    t = scale * rng.normal(size=3)
    return r, t


def test_kabsch_recovers_exact_transform():
    rng = np.random.RandomState(0)
    r_true, t_true = _random_rt(rng)
    pts = rng.uniform(-2, 2, (50, 3))
    pts_b = pts @ r_true.T + t_true
    w = np.ones(50)
    r, t = backend.kabsch(jnp.asarray(pts), jnp.asarray(pts_b),
                          jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(r), r_true, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t), t_true, atol=1e-5)


def test_kabsch_weights_ignore_outliers():
    rng = np.random.RandomState(1)
    r_true, t_true = _random_rt(rng)
    pts = rng.uniform(-2, 2, (60, 3))
    pts_b = pts @ r_true.T + t_true
    pts_b[:10] += rng.uniform(5, 9, (10, 3))        # gross outliers
    w = np.ones(60)
    w[:10] = 0.0
    r, t = backend.kabsch(jnp.asarray(pts), jnp.asarray(pts_b),
                          jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(r), r_true, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t), t_true, atol=1e-5)


def test_gauss_newton_reduces_reprojection_error():
    rng = np.random.RandomState(2)
    intr = CameraIntrinsics(fx=300, fy=300, cx=160, cy=120)
    r_true, t_true = _random_rt(rng, angle=0.05, scale=0.2)
    pts = np.stack([rng.uniform(-1, 1, 40), rng.uniform(-1, 1, 40),
                    rng.uniform(3, 8, 40)], axis=1)
    p_cam = pts @ r_true.T + t_true
    xy = np.stack([intr.fx * p_cam[:, 0] / p_cam[:, 2] + intr.cx,
                   intr.fy * p_cam[:, 1] / p_cam[:, 2] + intr.cy], axis=1)
    w = jnp.ones(40)
    # start from a perturbed initialization
    r0, t0 = _random_rt(rng, angle=0.03, scale=0.1)
    r0 = r0 @ r_true
    t0 = t_true + t0

    def err(r, t):
        res = backend.reprojection_residuals(
            jnp.asarray(r), jnp.asarray(t), jnp.asarray(pts),
            jnp.asarray(xy), intr)
        return float(jnp.sqrt(jnp.mean(res ** 2)))

    e0 = err(r0, t0)
    r_f, t_f = backend.gauss_newton_refine(
        jnp.asarray(r0), jnp.asarray(t0), jnp.asarray(pts),
        jnp.asarray(xy), w, intr)
    e1 = err(np.asarray(r_f), np.asarray(t_f))
    assert e1 < 0.02 * e0, (e0, e1)


def test_so3_exp_zero_is_identity_and_differentiable():
    np.testing.assert_allclose(
        np.asarray(backend._so3_exp(jnp.zeros(3))), np.eye(3), atol=1e-6)
    g = jax.jacfwd(backend._so3_exp)(jnp.zeros(3))
    assert np.all(np.isfinite(np.asarray(g)))


def test_trajectory_integration_straight_line():
    # constant forward motion: relative pose maps prev into curr frame,
    # camera moving +z in world => t_rel = -dz
    poses = [backend.PoseEstimate(jnp.eye(3),
                                  jnp.asarray([0.0, 0.0, -0.1]),
                                  jnp.asarray(10))
             for _ in range(5)]
    traj = np.asarray(backend.integrate_trajectory(poses))
    np.testing.assert_allclose(traj[-1], [0, 0, 0.5], atol=1e-6)
