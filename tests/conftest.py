"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and it does so before importing jax)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
