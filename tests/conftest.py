"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and it does so before importing jax)."""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:          # hypothesis is a dev-only dep; tests skip
    pass
else:
    # Deterministic property tests for CI: fixed derivation (no random
    # seed between runs), no wall-clock deadline (Pallas interpret mode
    # and jit compilation make first examples slow, which is not a bug).
    settings.register_profile(
        "repro-ci",
        derandomize=True,
        deadline=None,
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much],
    )
    settings.load_profile("repro-ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
