"""Property tests for the deterministic backoff schedules — the one
piece of "randomness" in the serving layer.  Both the ``Supervisor``
(restart scheduling) and the ``DispatchGuard`` (dispatch retries) use
the same ``RandomState([seed, crc32(key), attempt])`` idiom; these pin
the three properties every consumer relies on:

  replay     same config + seed -> bit-identical schedule, across
             instances (fault episodes replay exactly);
  bound      every delay is positive and <= backoff_max_s * (1+jitter)
             (a restart can never be scheduled unboundedly far out);
  monotone   pre-cap, delays grow with the attempt number whenever the
             worst-case jitter cannot invert the exponential growth
             (factor * (1-j) >= (1+j)) — flapping rigs back OFF.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st      # noqa: E402

from repro.serving.failover import (DispatchGuard,      # noqa: E402
                                    DispatchGuardConfig)
from repro.serving.supervisor import Supervisor, SupervisorConfig  # noqa: E402

# Configs constrained so the monotonicity property is actually implied:
# with jitter j and factor f, attempt n+1 beats attempt n in the worst
# case iff f * (1 - j) >= (1 + j); j <= 0.25 and f >= 1.7 guarantees it
# (1.7 * 0.75 = 1.275 >= 1.25).
_cfgs = st.builds(
    SupervisorConfig,
    backoff_base_s=st.floats(0.01, 2.0),
    backoff_factor=st.floats(1.7, 3.0),
    backoff_max_s=st.floats(2.0, 60.0),
    backoff_jitter=st.floats(0.0, 0.25),
    seed=st.integers(0, 2**31 - 1),
)
_rig_ids = st.one_of(st.integers(0, 1000), st.text(min_size=1, max_size=8))
_attempts = st.integers(1, 12)


@given(cfg=_cfgs, rig=_rig_ids, attempt=_attempts)
def test_backoff_replays_identically(cfg, rig, attempt):
    assert Supervisor(cfg)._backoff(rig, attempt) == \
        Supervisor(cfg)._backoff(rig, attempt)


@given(cfg=_cfgs, rig=_rig_ids, attempt=_attempts)
def test_backoff_is_positive_and_bounded(cfg, rig, attempt):
    d = Supervisor(cfg)._backoff(rig, attempt)
    assert 0.0 < d <= cfg.backoff_max_s * (1.0 + cfg.backoff_jitter)


@given(cfg=_cfgs, rig=_rig_ids)
def test_backoff_monotone_nondecreasing_precap(cfg, rig):
    """Growth holds up to the attempt where the deterministic part hits
    the cap; past that, only the bound (above) is promised."""
    sup = Supervisor(cfg)
    delays, det = [], []
    for attempt in range(1, 10):
        base = cfg.backoff_base_s * cfg.backoff_factor ** (attempt - 1)
        det.append(base)
        delays.append(sup._backoff(rig, attempt))
    for i in range(len(delays) - 1):
        if det[i + 1] >= cfg.backoff_max_s:
            break                        # capped: growth no longer promised
        assert delays[i + 1] >= delays[i], (
            f"backoff shrank pre-cap at attempt {i + 1}: {delays}")


@given(cfg=_cfgs, attempt=_attempts)
def test_backoff_decorrelates_rigs(cfg, attempt):
    """Different rigs draw different jitter (no restart stampede) —
    unless jitter is disabled, in which case schedules coincide by
    construction."""
    a = Supervisor(cfg)._backoff("rig-a", attempt)
    b = Supervisor(cfg)._backoff("rig-b", attempt)
    if cfg.backoff_jitter > 1e-6:       # sub-ulp jitter can round equal
        assert a != b
    else:
        assert abs(a - b) <= cfg.backoff_max_s * 2e-6


@given(
    cfg=st.builds(
        DispatchGuardConfig,
        timeout_s=st.floats(0.1, 60.0),
        backoff_base_s=st.floats(0.01, 2.0),
        backoff_factor=st.floats(1.7, 3.0),
        backoff_max_s=st.floats(2.0, 60.0),
        backoff_jitter=st.floats(0.0, 0.25),
        seed=st.integers(0, 2**31 - 1),
    ),
    key=st.integers(0, 10_000),
    attempt=_attempts,
)
def test_dispatch_guard_backoff_same_properties(cfg, key, attempt):
    """The guard shares the idiom, so it shares the guarantees."""
    d = DispatchGuard(cfg).backoff(key, attempt)
    assert d == DispatchGuard(cfg).backoff(key, attempt)
    assert 0.0 < d <= cfg.backoff_max_s * (1.0 + cfg.backoff_jitter)
