"""Fused sparse descriptor stage (orientation + rBRIEF) vs. oracles.

Three implementations must agree BIT-exactly on theta, the circular-
patch moments and the packed descriptors:

  * the Pallas kernel (interpret mode on CPU),
  * the jnp fallback (``ops.orient_describe_batched(..., impl="ref")``),
  * the per-image ref oracle (``ref.orient_describe``).

The kernel resolves taps with a selection matmul whose SIGN equals the
oracle's gather-compare exactly, so equality is exact, not approximate.
Descriptor differences against the pre-refactor EXACT steering
(``ref.describe_steered``) are bounded by the documented 30-degree
angle-bin quantization and pinned here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ORBConfig, extract_features,
                        extract_features_batched)
from repro.core import brief, fast
from repro.kernels import ops, pattern, ref


def _imgs(rng, b, h, w):
    return jnp.asarray(rng.randint(0, 256, (b, h, w)).astype(np.float32))


def _keypoints(rng, b, k, h, w, border=16):
    return jnp.asarray(np.stack([
        rng.randint(border, w - border, (b, k)),
        rng.randint(border, h - border, (b, k))], axis=-1).astype(np.int32))


def _assert_tri_impl_exact(raw, smoothed, xy):
    """pallas == jnp fallback == per-image oracle, bit for bit."""
    out_pl = ops.orient_describe_batched(raw, smoothed, xy, impl="pallas")
    out_ref = ops.orient_describe_batched(raw, smoothed, xy, impl="ref")
    for a, b, name in zip(out_pl, out_ref, ("theta", "moments", "desc")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"pallas vs fallback {name}")
    for c in range(raw.shape[0]):
        th, mom, desc = ref.orient_describe(raw[c], smoothed[c], xy[c])
        np.testing.assert_array_equal(np.asarray(out_pl[0][c]),
                                      np.asarray(th),
                                      err_msg=f"camera {c} theta")
        np.testing.assert_array_equal(np.asarray(out_pl[1][c]),
                                      np.asarray(mom),
                                      err_msg=f"camera {c} moments")
        np.testing.assert_array_equal(np.asarray(out_pl[2][c]),
                                      np.asarray(desc),
                                      err_msg=f"camera {c} desc")
    return out_pl


@pytest.mark.parametrize("shape,b,k", [
    ((70, 111), 3, 21),      # non-square, K not a KP_BLOCK multiple
    ((96, 128), 4, 8),
    ((37, 53), 2, 5),        # image smaller than one dense tile
])
def test_tri_impl_bitexact(rng, shape, b, k):
    h, w = shape
    raw = _imgs(rng, b, h, w)
    smoothed = ops.fast_blur_nms_batched(raw, 20.0, impl="ref")[0]
    xy = _keypoints(rng, b, k, h, w)
    out = _assert_tri_impl_exact(raw, smoothed, xy)
    assert out[0].shape == (b, k)
    assert out[1].shape == (b, k, 2)
    assert out[2].shape == (b, k, 8) and out[2].dtype == jnp.uint32


def test_paper_level1_shape(rng):
    """600x1067 — the paper's 1280x720 level-1 shape (Sec. III-C), far
    from tile alignment on both axes."""
    raw = _imgs(rng, 1, 600, 1067)
    smoothed = ops.fast_blur_nms_batched(raw, 20.0, impl="ref")[0]
    xy = _keypoints(rng, 1, 16, 600, 1067)
    _assert_tri_impl_exact(raw, smoothed, xy)


def test_border_adjacent_and_out_of_range_keypoints(rng):
    """Keypoints on the image border use edge-padded patches; coords
    outside the image (top-K padding rows carry arbitrary values) are
    clamped identically by kernel and oracle."""
    h, w = 64, 96
    raw = _imgs(rng, 2, h, w)
    smoothed = ops.fast_blur_nms_batched(raw, 20.0, impl="ref")[0]
    pts = np.array([
        [0, 0], [w - 1, h - 1], [0, h - 1], [w - 1, 0],
        [15, 15], [16, 16], [w - 16, h - 16],
        [-5, 10], [w + 40, h + 40], [10, -3],     # out of range -> clamped
    ], dtype=np.int32)
    xy = jnp.asarray(np.broadcast_to(pts, (2, *pts.shape)).copy())
    out = _assert_tri_impl_exact(raw, smoothed, xy)
    assert np.isfinite(np.asarray(out[0])).all()
    assert np.isfinite(np.asarray(out[1])).all()


def test_all_invalid_level(rng):
    """A level with NO corners (blank image): top-K emits valid=False
    rows with degenerate coords; the sparse stage must stay finite and
    agree across impls, and the extractor must mask everything."""
    imgs = jnp.zeros((2, 64, 96), jnp.float32)
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2)
    for impl in ("ref", "pallas"):
        feats = extract_features_batched(imgs, cfg, impl=impl)
        assert int(feats.count()) == 0
        assert np.isfinite(np.asarray(feats.theta)).all()
    f_ref = extract_features_batched(imgs, cfg, impl="ref")
    f_pl = extract_features_batched(imgs, cfg, impl="pallas")
    for f in f_ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(f_ref, f)),
                                      np.asarray(getattr(f_pl, f)),
                                      err_msg=f)


def test_orientation_only_variant_matches_full(rng):
    """smoothed=None selects the orientation-only kernel; its theta and
    moments must equal the full kernel's."""
    raw = _imgs(rng, 2, 70, 90)
    smoothed = ops.fast_blur_nms_batched(raw, 20.0, impl="ref")[0]
    xy = _keypoints(rng, 2, 12, 70, 90)
    for impl in ("ref", "pallas"):
        th_o, mom_o, desc_o = ops.orient_describe_batched(
            raw, None, xy, impl=impl)
        th_f, mom_f, _ = ops.orient_describe_batched(
            raw, smoothed, xy, impl=impl)
        assert desc_o is None
        np.testing.assert_array_equal(np.asarray(th_o), np.asarray(th_f))
        np.testing.assert_array_equal(np.asarray(mom_o), np.asarray(mom_f))


def test_extractor_two_launches_per_frame(rng):
    """Acceptance: extract_features_batched issues exactly 2 launches
    per FRAME (1 dense fused + 1 sparse descriptor) for ALL cameras x
    ALL pyramid levels, via the traced launch counter; the per-level
    reference schedule still costs 2 per level."""
    from repro.core import extract_features_per_level
    imgs = _imgs(rng, 4, 96, 128)
    cfg = ORBConfig(height=96, width=128, max_features=48, n_levels=2)
    with ops.launch_audit() as audit:
        jax.eval_shape(
            lambda im: extract_features_batched(im, cfg, impl="pallas"),
            imgs)
    assert audit.count == 2
    with ops.launch_audit() as audit:
        jax.eval_shape(
            lambda im: extract_features_per_level(im, cfg, impl="pallas"),
            imgs)
    assert audit.count == 2 * cfg.n_levels


def test_detect_theta_pinned_to_batched_path(rng):
    """Satellite fix: fast.detect routes orientation through the same
    dispatch as the batched extractor, so single-image and batched theta
    are bit-identical — across BOTH impls."""
    img = _imgs(rng, 1, 96, 128)[0]
    cfg = ORBConfig(height=96, width=128, max_features=32, n_levels=1)
    k = cfg.features_per_level()[0]
    xy_d, _, theta_d, valid_d = fast.detect(img, cfg, k, impl="pallas")
    feats = extract_features(img, cfg, impl="pallas")
    np.testing.assert_array_equal(np.asarray(xy_d, np.float32),
                                  np.asarray(feats.xy))
    np.testing.assert_array_equal(np.asarray(theta_d),
                                  np.asarray(feats.theta))
    # and ref == pallas on the single-image path itself
    _, _, theta_ref, _ = fast.detect(img, cfg, k, impl="ref")
    np.testing.assert_array_equal(np.asarray(theta_d),
                                  np.asarray(theta_ref))


def test_lut_binning_quantization_pinned(rng):
    """The ONLY descriptor change vs the pre-refactor exact steering is
    the 30-degree angle-bin quantization.  Pin its size: mean Hamming
    distance well under the random-descriptor baseline (~128), and
    near-zero when theta sits on a bin center."""
    dists = []
    for seed in range(3):
        r = np.random.RandomState(seed)
        img = jnp.asarray(r.randint(0, 256, (128, 160)).astype(np.float32))
        cfg = ORBConfig(height=128, width=160)
        sm = brief.smooth(img, cfg, impl="ref")
        xy = jnp.asarray(np.stack([r.randint(16, 144, 64),
                                   r.randint(16, 112, 64)], 1).astype(np.int32))
        theta = fast.orientations(img, xy, impl="ref")
        d_lut = brief.describe(sm, xy, theta)
        d_exact = ref.describe_steered(sm, xy, theta)
        dists.append(np.asarray(
            ref.hamming_distance_matrix(d_lut, d_exact)).diagonal())
    d = np.concatenate(dists)
    # observed: mean ~43, max 98 of 256 (bins are 30 deg -> taps move by
    # up to |r| * 15 deg ~ 3.4 px).  Random descriptors would give ~128.
    assert d.mean() < 56.0, f"quantization too large: mean {d.mean()}"
    assert d.max() <= 128, f"quantization too large: max {d.max()}"

    # At bin centers the LUT row IS the rotated pattern; residual bits
    # come only from f32 (exact path) vs f64 (LUT) trig rounding at
    # half-integer taps.  Observed <= 4 bits.
    img = jnp.asarray(np.random.RandomState(9).randint(
        0, 256, (128, 160)).astype(np.float32))
    sm = brief.smooth(img, ORBConfig(height=128, width=160), impl="ref")
    r = np.random.RandomState(10)
    xy = jnp.asarray(np.stack([r.randint(16, 144, pattern.N_ANGLE_BINS),
                               r.randint(16, 112, pattern.N_ANGLE_BINS)],
                              1).astype(np.int32))
    centers = (np.arange(pattern.N_ANGLE_BINS) * pattern.ANGLE_BIN_STEP
               + np.pi) % (2 * np.pi) - np.pi
    th = jnp.asarray(centers, dtype=jnp.float32)
    dist = np.asarray(ref.hamming_distance_matrix(
        brief.describe(sm, xy, th),
        ref.describe_steered(sm, xy, th))).diagonal()
    assert dist.max() <= 8, f"bin-center mismatch: {dist}"


def test_steer_lut_geometry():
    """Every LUT tap stays inside the 31x31 patch, and row b equals the
    exact rotation at the bin-b center angle (the LUT's definition)."""
    lut = pattern.STEER_LUT
    assert lut.shape == (pattern.N_ANGLE_BINS, pattern.N_PAIRS, 2)
    assert lut.min() >= 0 and lut.max() < 31 * 31
    for b in range(pattern.N_ANGLE_BINS):
        rot = pattern.rotated_pattern(b * pattern.ANGLE_BIN_STEP)
        a_lin = (rot[:, 1] + 15) * 31 + (rot[:, 0] + 15)
        b_lin = (rot[:, 3] + 15) * 31 + (rot[:, 2] + 15)
        np.testing.assert_array_equal(lut[b, :, 0], a_lin)
        np.testing.assert_array_equal(lut[b, :, 1], b_lin)
