"""Oracle tests for the model-stack numerics: flash-chunked attention vs
naive softmax attention, chunked SSD vs the step recurrence, MoE
dispatch vs a per-token loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention, mlp, ssm


def naive_attention(q, k, v, s: attention.AttnSpec, is_local=None):
    """Direct softmax attention with the same masking semantics."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    g = s.group
    qh = q.reshape(b, sq, s.kv_eff, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k).astype(jnp.float32) \
        * s.query_scale
    if s.softcap:
        sc = jnp.tanh(sc / s.softcap) * s.softcap
    mask = attention._mask_block(s, jnp.arange(sq), jnp.arange(skv),
                                 is_local)
    sc = jnp.where(mask[None, None, None], sc, -2e9)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)


@pytest.mark.parametrize("mask,window,softcap", [
    ("causal", None, None), ("causal", 16, None), ("full", None, None),
    ("prefix", None, None), ("causal", None, 30.0)])
@pytest.mark.parametrize("sq", [32, 48])
def test_flash_matches_naive(mask, window, softcap, sq):
    rng = np.random.RandomState(0)
    s = attention.AttnSpec(d_model=32, n_heads=4, n_kv=2, kv_eff=2,
                           head_dim=8, query_scale=8 ** -0.5,
                           softcap=softcap, window=window, mask=mask,
                           prefix_len=7, chunk=16)
    q = jnp.asarray(rng.normal(size=(2, sq, 4, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, sq, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, sq, 2, 8)).astype(np.float32))
    got = attention.flash(q, k, v, s)
    want = naive_attention(q, k, v, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_local_global_flag():
    rng = np.random.RandomState(1)
    s = attention.AttnSpec(d_model=32, n_heads=2, n_kv=2, kv_eff=2,
                           head_dim=8, query_scale=8 ** -0.5,
                           window=8, mask="causal", chunk=16)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    for flag in (True, False):
        got = attention.flash(q, k, v, s, is_local=jnp.asarray(flag))
        want = naive_attention(q, k, v, s, is_local=jnp.asarray(flag))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    # the flag must matter: local != global outputs
    a = attention.flash(q, k, v, s, is_local=jnp.asarray(True))
    b = attention.flash(q, k, v, s, is_local=jnp.asarray(False))
    assert float(jnp.max(jnp.abs(a - b))) > 1e-4


def test_ssd_chunked_matches_recurrence():
    rng = np.random.RandomState(2)
    b, seq, h, p, n = 2, 64, 3, 4, 8
    s = ssm.SSMSpec(d_model=16, d_state=n, head_dim=p, chunk=16, intra_bf16=False)
    xs = jnp.asarray(rng.normal(size=(b, seq, h, p)).astype(np.float32))
    bs = jnp.asarray(rng.normal(size=(b, seq, n)).astype(np.float32))
    cs = jnp.asarray(rng.normal(size=(b, seq, n)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, seq, h)).astype(np.float32))
    la = jnp.asarray(-rng.uniform(0.01, 0.5, (b, seq, h))
                     .astype(np.float32))
    y, h_fin = ssm.ssd_scan(xs, bs, cs, dt, la, s)

    # naive recurrence
    hstate = np.zeros((b, h, n, p), np.float32)
    ys = np.zeros((b, seq, h, p), np.float32)
    xs_, bs_, cs_ = map(np.asarray, (xs, bs, cs))
    dt_, la_ = np.asarray(dt), np.asarray(la)
    for t in range(seq):
        a = np.exp(la_[:, t])                       # (b, h)
        outer = np.einsum("bn,bhp->bhnp", bs_[:, t], xs_[:, t]) \
            * dt_[:, t][:, :, None, None]
        hstate = a[:, :, None, None] * hstate + outer
        ys[:, t] = np.einsum("bn,bhnp->bhp", cs_[:, t], hstate)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_fin), hstate, rtol=2e-4,
                               atol=2e-4)


def test_moe_matches_per_token_loop():
    """With generous capacity (no drops) the static dispatch equals the
    obvious per-token top-k mixture."""
    rng = np.random.RandomState(3)
    s = mlp.MoESpec(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    capacity_factor=8.0)
    schema = mlp.moe_schema(s)
    from repro.models.params import init_params
    params = init_params(schema, jax.random.key(0))
    x = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    y, aux = mlp.moe(params, x, s)

    xt = np.asarray(x).reshape(16, 16)
    idx, gates, _ = mlp.router_probs(params, jnp.asarray(xt), s)
    idx, gates = np.asarray(idx), np.asarray(gates)
    want = np.zeros_like(xt)
    for t in range(16):
        for j in range(s.top_k):
            e = idx[t, j]
            g = np.asarray(jax.nn.silu(
                xt[t] @ np.asarray(params["wi_gate"])[e]))
            u = xt[t] @ np.asarray(params["wi_up"])[e]
            want[t] += gates[t, j] * ((g * u)
                                      @ np.asarray(params["wo"])[e])
    np.testing.assert_allclose(np.asarray(y).reshape(16, 16), want,
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most (token, expert) pairs are dropped
    and the output shrinks toward zero — never corrupts other tokens."""
    rng = np.random.RandomState(4)
    s_full = mlp.MoESpec(d_model=8, d_ff=16, n_experts=2, top_k=1,
                         capacity_factor=8.0)
    s_tight = dataclasses.replace(s_full, capacity_factor=0.01)
    schema = mlp.moe_schema(s_full)
    from repro.models.params import init_params
    params = init_params(schema, jax.random.key(1))
    x = jnp.asarray(rng.normal(size=(1, 32, 8)).astype(np.float32))
    y_full, _ = mlp.moe(params, x, s_full)
    y_tight, _ = mlp.moe(params, x, s_tight)
    # capacity 8 slots: exactly the first tokens routed to each expert
    # are preserved, the rest are zero
    kept = np.any(np.abs(np.asarray(y_tight)[0]) > 0, axis=-1)
    assert kept.sum() <= 2 * s_tight.capacity(32)
    matches = np.isclose(np.asarray(y_tight)[0][kept],
                         np.asarray(y_full)[0][kept], atol=1e-5)
    assert matches.all()
