"""`VisualSystem` session API tests: fleet-vs-loop bit-exactness (ref
AND pallas-interpret, n_rigs in {1, 3}, odd shapes), the 3-launch fleet
budget, jit-cache retrace accounting, the per-frame desync check,
config validation, shard_map'd fleets, heterogeneous per-pair
intrinsics, and the context-var impl / launch-audit isolation."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CameraIntrinsics, DesyncError, ORBConfig,
                        PipelineConfig, RigConfig, VisualSystem)
from repro.distributed import sharding
from repro.kernels import ops


def _imgs(seed, *shape):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 256, shape).astype(np.float32))


def _quad(h=64, w=96, impl=None, **pipe_kw):
    ocfg = ORBConfig(height=h, width=w, max_features=16, n_levels=2,
                     max_disparity=32)
    intr = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0)
    return VisualSystem(RigConfig.quad(intr),
                        PipelineConfig(orb=ocfg, impl=impl, **pipe_kw))


def _assert_tree_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# Fleet batching: bit-exact vs the per-rig loop, 3 launches total.

@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("n_rigs,h,w", [(1, 64, 96), (3, 59, 85)])
def test_fleet_equals_per_rig_loop(impl, n_rigs, h, w):
    """process_fleet folds the rig axis into the kernels' camera/pair
    batch axes; every rig's slice must equal its own process_frame,
    bit for bit, on both impls and odd shapes."""
    vs = _quad(h, w, impl=impl)
    fleet = _imgs(100 + n_rigs, n_rigs, 4, h, w)
    out = vs.process_fleet(fleet)
    assert out.matches.valid.shape[:2] == (n_rigs, 2)
    for r in range(n_rigs):
        want = vs.process_frame(fleet[r])
        got = jax.tree.map(lambda x: x[r], out)
        _assert_tree_equal(got, want, f"rig {r} impl {impl}")


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_run_fleet_equals_stacked_fleet_frames(impl):
    vs = _quad(impl=impl)
    frames = _imgs(7, 3, 2, 4, 64, 96)       # (T=3, n_rigs=2, 4, H, W)
    outs = vs.run_fleet(frames)
    assert outs.matches.valid.shape[:3] == (3, 2, 2)
    for t in range(3):
        want = vs.process_fleet(frames[t])
        got = jax.tree.map(lambda x: x[t], outs)
        _assert_tree_equal(got, want, f"t {t} impl {impl}")


def test_fleet_frame_is_three_launches():
    """Acceptance: an N-rig fleet frame costs exactly 3 traced kernel
    launches (1 dense FE + 1 sparse FE + 1 fused FM) — the same budget
    as a single rig, for any fleet size."""
    vs = _quad()
    for n_rigs in (1, 2, 5):
        fleet = _imgs(5, n_rigs, 4, 64, 96)
        assert vs.traced_launches("process_fleet", fleet) == 3, n_rigs
    assert vs.traced_launches("process_frame", _imgs(6, 4, 64, 96)) == 3


def test_pipelined_fleet_schedule_matches_sequential():
    frames = _imgs(8, 3, 2, 4, 64, 96)
    a = _quad(schedule="sequential").run_fleet(frames)
    b = _quad(schedule="pipelined").run_fleet(frames)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Jit cache: entry points trace once per shape, zero retraces after.

def test_process_frame_retraces_zero_times():
    vs = _quad()
    imgs = _imgs(9, 4, 64, 96)
    for _ in range(3):
        vs.process_frame(imgs)
    assert vs.trace_count("process_frame") == 1
    # a NEW fleet shape traces once; repeats hit the cache
    for n in (2, 2, 2, 3):
        vs.process_fleet(_imgs(10, n, 4, 64, 96))
    assert vs.trace_count("process_fleet") == 2
    # other entry points are cached independently
    vs.extract(imgs)
    vs.extract(imgs)
    assert vs.trace_count("extract") == 1
    assert vs.trace_count("process_frame") == 1


# ---------------------------------------------------------------------------
# Sync policy: hardware asserts zero desync, software reports jitter.

def test_hardware_rig_accepts_trigger_tags_and_rejects_jitter():
    vs = _quad()
    imgs = _imgs(11, 4, 64, 96)
    vs.process_frame(imgs, timestamps=[2.5, 2.5, 2.5, 2.5])
    assert list(vs.desync_log) == [0.0]
    with pytest.raises(DesyncError, match="trigger"):
        vs.process_frame(imgs, timestamps=[2.5, 2.504, 2.5, 2.5])
    assert len(vs.desync_log) == 2 and vs.desync_log[1] > 0.0


def test_software_rig_reports_jitter_without_raising():
    ocfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                     max_disparity=32)
    rig = RigConfig.quad(CameraIntrinsics(cx=48.0, cy=32.0),
                         sync_policy="software")
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))
    imgs = _imgs(12, 4, 64, 96)
    out = vs.process_frame(imgs, timestamps=[1.0, 1.004, 1.0, 1.001])
    assert out.matches.valid.shape[0] == 2
    assert len(vs.desync_log) == 1
    assert 3e-3 < vs.desync_log[0] < 5e-3


def test_hardware_rig_with_tolerance_accepts_small_desync():
    ocfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                     max_disparity=32)
    rig = RigConfig.quad(CameraIntrinsics(cx=48.0, cy=32.0),
                         max_desync=5e-3)
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))
    vs.process_frame(_imgs(13, 4, 64, 96),
                     timestamps=[1.0, 1.004, 1.0, 1.0])
    with pytest.raises(DesyncError):
        vs.process_frame(_imgs(13, 4, 64, 96),
                         timestamps=[1.0, 1.006, 1.0, 1.0])


def test_desync_check_keeps_float64_resolution_at_epoch_scale():
    """Real capture stamps are epoch seconds (~1.75e9), where float32
    spacing is 128 s — the check must stay in float64 or a 0.5 s
    desync would silently read as 0."""
    vs = _quad()
    t0 = 1.7537e9
    with pytest.raises(DesyncError):
        vs.process_frame(_imgs(15, 4, 64, 96),
                         timestamps=[t0, t0 + 0.5, t0, t0])
    assert abs(vs.desync_log[-1] - 0.5) < 1e-6


def test_desync_check_validates_timestamp_count():
    vs = _quad()
    with pytest.raises(ValueError, match="timestamps"):
        vs.process_frame(_imgs(14, 4, 64, 96), timestamps=[1.0, 2.0])


# ---------------------------------------------------------------------------
# Config validation.

def test_rig_config_validation():
    with pytest.raises(ValueError, match="outside"):
        RigConfig(n_cameras=2, pairs=((0, 2),))
    with pytest.raises(ValueError, match="twice"):
        RigConfig(n_cameras=2, pairs=((1, 1),))
    with pytest.raises(ValueError, match="at least one"):
        RigConfig(n_cameras=2, pairs=())
    with pytest.raises(ValueError, match="intrinsics"):
        RigConfig(n_cameras=4, intrinsics=(CameraIntrinsics(),) * 3)
    with pytest.raises(ValueError, match="sync_policy"):
        RigConfig(sync_policy="gps")
    # single intrinsics broadcast to every camera
    rig = RigConfig.quad(CameraIntrinsics(fx=111.0))
    assert len(rig.intrinsics) == 4
    assert rig.homogeneous_intrinsics
    assert rig.sync.n_cameras == 4
    assert rig.left_cams == (0, 2) and rig.right_cams == (1, 3)


def test_pipeline_config_validation():
    with pytest.raises(ValueError, match="schedule"):
        PipelineConfig(schedule="async")
    with pytest.raises(ValueError, match="impl"):
        PipelineConfig(impl="cuda")


# ---------------------------------------------------------------------------
# Heterogeneous per-pair intrinsics: depth scales with the pair's
# fx * baseline.

def test_heterogeneous_intrinsics_scale_depth_per_pair():
    h, w = 64, 96
    ocfg = ORBConfig(height=h, width=w, max_features=16, n_levels=1,
                     max_disparity=32)
    base = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0, baseline=0.1)
    wide = CameraIntrinsics(cx=w / 2.0, cy=h / 2.0, baseline=0.2)
    rig = RigConfig(n_cameras=4, pairs=((0, 1), (2, 3)),
                    intrinsics=(base, base, wide, wide))
    assert not rig.homogeneous_intrinsics
    vs = VisualSystem(rig, PipelineConfig(orb=ocfg))
    # both pairs see the SAME stereo scene (right = left shifted 8 px ->
    # uniform disparity) -> identical disparities; the back pair's
    # doubled baseline must double its depths.
    left = np.full((h, w), 40.0, np.float32)
    rng = np.random.RandomState(21)
    for _ in range(10):
        y, x = rng.randint(18, h - 24), rng.randint(26, w - 24)
        left[y:y + 5, x:x + 5] = rng.uniform(150, 250)
    right = np.roll(left, -8, axis=1)
    right[:, -8:] = 40.0
    pair = jnp.asarray(np.stack([left, right]))
    imgs = jnp.concatenate([pair, pair])
    out = vs.process_frame(imgs)
    v = np.asarray(out.depth.valid)
    assert v[0].sum() >= 3
    np.testing.assert_array_equal(v[0], v[1])
    d0 = np.asarray(out.depth.depth)[0][v[0]]
    d1 = np.asarray(out.depth.depth)[1][v[1]]
    np.testing.assert_allclose(d1, 2.0 * d0, rtol=1e-5)


# ---------------------------------------------------------------------------
# shard_map'd fleet over a mesh (1-device CPU mesh in CI).

def test_sharded_fleet_matches_unsharded():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("rig",))
    want_vs = _quad(impl="ref")
    shard_vs = _quad(impl="ref", rig_shard_axis="rig")
    fleet = _imgs(22, 2, 4, 64, 96)
    want = want_vs.process_fleet(fleet)
    with sharding.use_sharding(mesh, sharding.Rules.make()):
        got = shard_vs.process_fleet(fleet)
        seq = shard_vs.run_fleet(_imgs(23, 2, 2, 4, 64, 96))
    _assert_tree_equal(got, want, "sharded process_fleet")
    assert seq.matches.valid.shape[:3] == (2, 2, 2)
    # outside the mesh context the same session falls back to plain jit
    _assert_tree_equal(shard_vs.process_fleet(fleet), want, "fallback")


# ---------------------------------------------------------------------------
# Context isolation: parallel sessions audit/resolve independently.

def test_launch_audit_threads_do_not_cross_talk():
    cfg = ORBConfig(height=64, width=96, max_features=16, n_levels=2,
                    max_disparity=32)
    imgs = _imgs(24, 4, 64, 96)
    intr = CameraIntrinsics(cx=48.0, cy=32.0)
    counts = {}

    def worker(name, n_repeats):
        vs = VisualSystem(RigConfig.quad(intr), PipelineConfig(orb=cfg))
        with ops.launch_audit() as audit:
            for _ in range(n_repeats):
                jax.eval_shape(lambda im: vs._frame_core(im, "pallas"),
                               imgs)
        counts[name] = audit.count

    threads = [threading.Thread(target=worker, args=("a", 1)),
               threading.Thread(target=worker, args=("b", 3))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert counts == {"a": 3, "b": 9}


def test_session_impl_resolved_at_construction():
    """A session pins its kernel impl when BUILT (None -> the ambient
    context / backend default), so later context flips can't silently
    miss its jit cache."""
    vs = _quad()
    assert vs.impl == "ref"              # CPU backend default, eager
    with ops.use_impl("pallas"):
        vs2 = _quad()
        assert vs.impl == "ref"          # already-pinned session unmoved
    assert vs2.impl == "pallas"


def test_use_impl_scopes_default_per_context():
    assert ops.resolve_impl("ref") == "ref"
    with ops.use_impl("pallas"):
        assert ops.resolve_impl(None) == "pallas"
        with ops.use_impl("ref"):
            assert ops.resolve_impl(None) == "ref"
        assert ops.resolve_impl(None) == "pallas"
        # an explicit per-call impl still wins over the context
        assert ops.resolve_impl("ref") == "ref"
        # a NEW thread starts from the default context, not this one
        seen = {}
        t = threading.Thread(
            target=lambda: seen.setdefault("impl", ops.resolve_impl(None)))
        t.start()
        t.join()
        assert seen["impl"] == "ref"     # CPU default, not "pallas"
    assert ops.resolve_impl(None) == "ref"
