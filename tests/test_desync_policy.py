"""Hypothesis matrix over the desync policy space (ISSUE 6 satellite):
hardware/software sync x (None | raise | drop_frame | degrade) x jitter
above/below ``max_desync``, pinning that

  - the action taken matches the policy table exactly (including the
    legacy ``None`` split: hardware raises, software logs),
  - ``degrade`` output is BIT-EXACT to a healthy frame on the surviving
    cameras (and identical to an explicit ``camera_mask`` call),
  - jitter below tolerance never perturbs the output at all.

Timestamps are epoch-scale (~1.7e9 s) on purpose: the desync math must
run in host float64 (float32 spacing there is 128 s), so any float32
round-trip in the policy path fails these tests immediately."""

import functools

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core import (CameraIntrinsics, DesyncError, ORBConfig,  # noqa: E402
                        PipelineConfig, RigConfig, VisualSystem)

H, W = 32, 48
TOL = 1e-3
BASE_T = 1.7e9          # epoch-scale stamps: float64-only territory
_SETTINGS = dict(max_examples=40, deadline=None)

_IMGS = np.random.RandomState(0).randint(0, 256, (4, H, W)) \
    .astype(np.float32)


@functools.lru_cache(maxsize=16)
def _vs(sync_policy, desync_policy):
    ocfg = ORBConfig(height=H, width=W, max_features=8, n_levels=1,
                     max_disparity=16)
    rig = RigConfig.quad(CameraIntrinsics(cx=W / 2.0, cy=H / 2.0),
                         sync_policy=sync_policy,
                         desync_policy=desync_policy, max_desync=TOL)
    return VisualSystem(rig, PipelineConfig(orb=ocfg))


def _stamps(camera, delta):
    ts = np.full(4, BASE_T, dtype=np.float64)
    ts[camera] += delta
    return ts


def _tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@given(sync=st.sampled_from(["hardware", "software"]),
       policy=st.sampled_from([None, "raise", "drop_frame", "degrade"]),
       above=st.booleans(),
       camera=st.integers(0, 3),
       mag=st.floats(1.1, 100.0))
@settings(**_SETTINGS)
def test_policy_matrix(sync, policy, above, camera, mag):
    vs = _vs(sync, policy)
    delta = mag * TOL if above else TOL / mag
    ts = _stamps(camera, delta)

    decision = vs.desync_decision(ts)
    assert decision.desync == pytest.approx(delta, abs=1e-6)

    if not above:
        # Within tolerance: every policy is a no-op and the output is
        # bit-exact to a timestamp-free call.
        assert decision.action == "ok"
        _tree_equal(vs.process_frame(_IMGS, timestamps=ts),
                    vs.process_frame(_IMGS))
        return

    want = policy if policy is not None else (
        "raise" if sync == "hardware" else "ok")
    assert decision.action == want

    if want == "raise":
        with pytest.raises(DesyncError, match="trigger clock"):
            vs.process_frame(_IMGS, timestamps=ts)
    elif want == "drop_frame":
        assert vs.process_frame(_IMGS, timestamps=ts) is None
    elif want == "ok":          # software legacy: log only
        _tree_equal(vs.process_frame(_IMGS, timestamps=ts),
                    vs.process_frame(_IMGS))
        assert vs.desync_log[-1] == pytest.approx(delta, abs=1e-6)
    else:                       # degrade
        keep = np.ones(4, bool)
        keep[camera] = False
        assert decision.camera_mask.tolist() == keep.tolist()
        out = vs.process_frame(_IMGS, timestamps=ts)
        # identical to an explicit dead-camera mask...
        _tree_equal(out, vs.process_frame(_IMGS, camera_mask=keep))
        # ...the offending pair is fully gated off...
        dead_pair = camera // 2
        assert not np.asarray(out.matches.valid[dead_pair]).any()
        assert not np.asarray(out.depth.valid[dead_pair]).any()
        # ...and the SURVIVING pair is bit-exact to a healthy frame.
        healthy = vs.process_frame(_IMGS)
        live_pair = 1 - dead_pair
        _tree_equal(jax.tree.map(lambda x: x[live_pair], out),
                    jax.tree.map(lambda x: x[live_pair], healthy))


@given(sync=st.sampled_from(["hardware", "software"]),
       mag=st.floats(1.1, 100.0))
@settings(**_SETTINGS)
def test_fleet_degrade_matches_frame_degrade(sync, mag):
    """The per-rig fleet timestamps path resolves to the same mask the
    single-frame path does: rig 1 desynced on camera 3 -> its slice
    equals the degraded process_frame, rig 0 stays bit-exact healthy."""
    vs = _vs(sync, "degrade")
    delta = mag * TOL
    fleet = np.stack([_IMGS, _IMGS])
    ts = np.stack([_stamps(0, 0.0), _stamps(3, delta)])
    out = vs.process_fleet(fleet, timestamps=ts)
    _tree_equal(jax.tree.map(lambda x: x[0], out), vs.process_frame(_IMGS))
    _tree_equal(jax.tree.map(lambda x: x[1], out),
                vs.process_frame(_IMGS, timestamps=_stamps(3, delta)))


@given(policy=st.sampled_from(["raise", "drop_frame", "degrade"]),
       deltas=st.lists(st.floats(0.0, 50.0), min_size=4, max_size=4))
@settings(**_SETTINGS)
def test_decision_never_mutates_state_on_ok(policy, deltas):
    """desync_decision is observation + log only: the jit caches and
    health log length are the only state it may touch."""
    vs = _vs("hardware", policy)
    n_before = len(vs.desync_log)
    decision = vs.desync_decision(np.asarray(deltas) + BASE_T)
    assert len(vs.desync_log) == n_before + 1
    spread = max(deltas) - min(deltas)
    # epoch-scale float64 rounding moves the spread by up to ~4e-7;
    # stay off the policy boundary so the expected action is unambiguous
    assume(abs(spread - TOL) > 1e-5)
    assert decision.desync == pytest.approx(spread, abs=1e-6)
    if spread <= TOL:
        assert decision.action == "ok"
    else:
        assert decision.action == policy
