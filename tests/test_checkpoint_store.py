"""Crash-consistency tests for the checkpoint store and the snapshot
layer's torn-write handling: a crashed save (leftover ``.tmp`` dir) is
invisible, a truncated leaf in the newest snapshot falls back to the
previous step, and ``load_flat`` raises (rather than misreads) on torn
files."""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import (latest_step, list_steps, load_flat,
                              restore_array_tree, save)


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(4, 3).astype(np.float32),
            "b": rng.randn(3).astype(np.float32),
            "stack": [rng.randint(0, 9, (2, 2)) for _ in range(2)]}


def test_save_restore_round_trip(tmp_path):
    tree = _tree()
    save(str(tmp_path), 7, tree)
    got = restore_array_tree(str(tmp_path), 7, tree)
    for a, b in zip(np.asarray(got["w"]), tree["w"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(got["stack"][1], tree["stack"][1])


def test_tmp_dir_from_crashed_save_is_invisible(tmp_path):
    """A crash between tmp-write and rename leaves ``step_N.tmp`` —
    neither ``list_steps`` nor ``latest_step`` may surface it."""
    save(str(tmp_path), 1, _tree(1))
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "index.json").write_text("{}")
    assert list_steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1


def test_step_without_index_is_invisible(tmp_path):
    """A step dir missing its index (manual tampering, partial copy) is
    not a restore candidate."""
    save(str(tmp_path), 1, _tree(1))
    save(str(tmp_path), 2, _tree(2))
    os.remove(tmp_path / "step_00000002" / "index.json")
    assert list_steps(str(tmp_path)) == [1]


def test_load_flat_keys_and_torn_file_raises(tmp_path):
    tree = _tree(3)
    save(str(tmp_path), 5, tree)
    flat = load_flat(str(tmp_path), 5)
    assert set(flat) == {"w", "b", "stack§0", "stack§1"}
    np.testing.assert_array_equal(flat["stack§0"], tree["stack"][0])
    # tear one data file mid-write: load_flat must raise, not misread
    d = tmp_path / "step_00000005"
    with open(d / "index.json") as f:
        fname = json.load(f)["leaves"]["w"]["file"]
    path = d / fname
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(Exception):
        load_flat(str(tmp_path), 5)


def test_snapshot_load_falls_back_past_torn_step(tmp_path):
    """The serving snapshot layer on top: tear the newest step's leaf
    file and ``snapshot.load`` steps back to the previous verifiable
    one instead of crashing (``corrupt_newest`` is the same hook the
    fault injector drives)."""
    from repro.serving import snapshot

    meta = {"version": snapshot.SNAPSHOT_VERSION, "n_leaves": 1,
            "leaf_crcs": None}
    for step, fill in ((1, 11), (2, 22)):
        leaf = np.full((8, 8), fill, np.int32)
        meta["leaf_crcs"] = [snapshot._crc(leaf)]
        arr = np.frombuffer(json.dumps(meta).encode(), np.uint8).copy()
        save(str(tmp_path), step, {"meta": arr, "leaves": [leaf]})

    step, got_meta, leaves = snapshot.load(str(tmp_path))
    assert step == 2 and leaves[0][0, 0] == 22

    assert snapshot.corrupt_newest(str(tmp_path), leaf_index=0,
                                   keep_fraction=0.3) is not None
    # SOME file of step 2 is torn (leaf or manifest) -> fall back to 1
    step, got_meta, leaves = snapshot.load(str(tmp_path))
    assert step == 1 and leaves[0][0, 0] == 11


def test_snapshot_load_detects_bit_flip_via_crc(tmp_path):
    """A snapshot whose files all LOAD but whose contents changed (bit
    rot, partial overwrite landing on valid npy bytes) is caught by the
    per-leaf CRC and skipped."""
    from repro.serving import snapshot

    leaf = np.arange(16, dtype=np.int32).reshape(4, 4)
    meta = {"version": snapshot.SNAPSHOT_VERSION, "n_leaves": 1,
            "leaf_crcs": [snapshot._crc(leaf)]}
    arr = np.frombuffer(json.dumps(meta).encode(), np.uint8).copy()
    save(str(tmp_path), 1, {"meta": arr, "leaves": [leaf]})
    save(str(tmp_path), 2, {"meta": arr, "leaves": [leaf + 1]})  # crc lies

    step, _, leaves = snapshot.load(str(tmp_path))
    assert step == 1                    # step 2 failed verification
    np.testing.assert_array_equal(leaves[0], leaf)
