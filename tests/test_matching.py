"""Stereo matching + SAD rectification behaviour (paper Sec. II-C)."""

import jax.numpy as jnp
import numpy as np

from repro.core import (CameraIntrinsics, ORBConfig, extract_features,
                        process_stereo_frame, sad_rectify, stereo_match)
from repro.data import scenes


def _stereo_pair(disparity=12, h=128, w=192, seed=1):
    """Right image = left shifted by `disparity` px (fronto-parallel)."""
    rng = np.random.RandomState(seed)
    left = np.full((h, w), 40.0, np.float32)
    for _ in range(12):
        y = rng.randint(20, h - 26)
        x = rng.randint(20 + disparity, w - 26)
        left[y:y + 5, x:x + 5] = rng.uniform(150, 250)
    right = np.roll(left, -disparity, axis=1)
    right[:, -disparity:] = 40.0
    return jnp.asarray(left), jnp.asarray(right)


def test_stereo_match_recovers_uniform_disparity():
    disp = 12
    left, right = _stereo_pair(disp)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1,
                    max_disparity=32)
    intr = CameraIntrinsics(fx=140.0, baseline=0.12)
    out = process_stereo_frame(left, right, cfg, intr)
    v = np.asarray(out.depth.valid)
    assert v.sum() >= 5
    d = np.asarray(out.depth.disparity)[v]
    # integer-shift scene: every rectified disparity equals the true shift
    assert np.all(np.abs(d - disp) <= 1.0)
    z = np.asarray(out.depth.depth)[v]
    np.testing.assert_allclose(z, 140.0 * 0.12 / d, rtol=1e-5)


def test_sad_rectification_fixes_coarse_match():
    """Corrupt matched right-x by +-2 px; SAD must slide it back."""
    disp = 10
    left, right = _stereo_pair(disp, seed=3)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1,
                    max_disparity=32, sad_range=4)
    intr = CameraIntrinsics(fx=140.0, baseline=0.12)
    feat_l = extract_features(left, cfg)
    feat_r = extract_features(right, cfg)
    matches = stereo_match(feat_l, feat_r, cfg)
    # corrupt the right feature coordinates before rectification
    rng = np.random.RandomState(0)
    offs = rng.randint(-2, 3, feat_r.xy.shape[0]).astype(np.float32)
    feat_r_bad = feat_r._replace(
        xy=feat_r.xy.at[:, 0].add(jnp.asarray(offs)))
    depth = sad_rectify(left, right, feat_l, feat_r_bad, matches, cfg, intr)
    v = np.asarray(depth.valid)
    assert v.sum() >= 5
    d = np.asarray(depth.disparity)[v]
    # >= 90% of matches slide back to the true shift (edge features near
    # the rolled image border may lock onto the wrap seam)
    frac = np.mean(np.abs(d - disp) <= 1.0)
    assert frac >= 0.9, (frac, d)


def test_matching_on_rendered_scene_has_depth_ground_truth():
    # generous baseline -> fine disparity resolution at 160 px width
    cfg = scenes.SceneConfig(height=120, width=160, n_points=80, seed=2,
                             baseline=0.5)
    frames, poses, intr = scenes.render_sequence(cfg, 1)
    ocfg = ORBConfig(height=120, width=160, max_features=128, n_levels=1,
                     max_disparity=64)
    out = process_stereo_frame(frames[0, 0], frames[0, 1], ocfg, intr)
    v = np.asarray(out.depth.valid)
    assert v.sum() >= 10
    z = np.asarray(out.depth.depth)[v]
    lo, hi = cfg.depth_range
    # >= 80% of estimated depths lie in the landmark depth band (stereo
    # mismatches on repeated texture may fall outside)
    frac = np.mean((z > lo * 0.5) & (z < hi * 2.0))
    assert frac >= 0.8, (frac, np.sort(z))


def test_temporal_match_finds_same_features():
    left, _ = _stereo_pair(8)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1)
    f = extract_features(left, cfg)
    m = stereo_match(f, f, cfg)  # self stereo-match: dx == 0 allowed
    from repro.core import temporal_match
    tm = temporal_match(f, f, cfg)
    v = np.asarray(tm.valid)
    idx = np.asarray(tm.right_index)
    # every valid feature self-matches at distance 0; identically-stamped
    # squares yield identical descriptors, so ties may resolve to a twin —
    # require the matched descriptor to be identical, not the same index.
    assert np.all(np.asarray(tm.distance)[v] == 0)
    desc = np.asarray(f.desc)
    np.testing.assert_array_equal(desc[v], desc[idx[v]])
