"""Stereo matching + SAD rectification behaviour (paper Sec. II-C) on
the ``VisualSystem`` session API, plus brute-force numpy oracle pins
for the matcher ops: the jnp path and the Pallas kernels of
``ops.hamming_match`` / ``ops.sad_search`` are both pinned against the
python-loop references in ``kernels.ref``, and the session's
``temporal_match`` / ``sad_rectify`` get dedicated oracle tests."""

import jax.numpy as jnp
import numpy as np

import jax

from repro.core import (CameraIntrinsics, FeatureSet, ORBConfig,
                        PipelineConfig, RigConfig, VisualSystem,
                        extract_features)
from repro.data import scenes
from repro.kernels import ops, ref
from repro.kernels.hamming_match import BIG


def _system(cfg, intr=None, impl=None):
    intr = intr if intr is not None else CameraIntrinsics()
    return VisualSystem(RigConfig.stereo(intr),
                        PipelineConfig(orb=cfg, impl=impl))


def _stereo_frame(vs, img_l, img_r):
    out = vs.process_frame(jnp.stack([img_l, img_r]))
    return jax.tree.map(lambda x: x[0], out)


def _stereo_pair(disparity=12, h=128, w=192, seed=1):
    """Right image = left shifted by `disparity` px (fronto-parallel)."""
    rng = np.random.RandomState(seed)
    left = np.full((h, w), 40.0, np.float32)
    for _ in range(12):
        y = rng.randint(20, h - 26)
        x = rng.randint(20 + disparity, w - 26)
        left[y:y + 5, x:x + 5] = rng.uniform(150, 250)
    right = np.roll(left, -disparity, axis=1)
    right[:, -disparity:] = 40.0
    return jnp.asarray(left), jnp.asarray(right)


def test_stereo_match_recovers_uniform_disparity():
    disp = 12
    left, right = _stereo_pair(disp)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1,
                    max_disparity=32)
    intr = CameraIntrinsics(fx=140.0, baseline=0.12)
    out = _stereo_frame(_system(cfg, intr), left, right)
    v = np.asarray(out.depth.valid)
    assert v.sum() >= 5
    d = np.asarray(out.depth.disparity)[v]
    # integer-shift scene: every rectified disparity equals the true shift
    assert np.all(np.abs(d - disp) <= 1.0)
    z = np.asarray(out.depth.depth)[v]
    np.testing.assert_allclose(z, 140.0 * 0.12 / d, rtol=1e-5)


def test_sad_rectification_fixes_coarse_match():
    """Corrupt matched right-x by +-2 px; SAD must slide it back."""
    disp = 10
    left, right = _stereo_pair(disp, seed=3)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1,
                    max_disparity=32, sad_range=4)
    intr = CameraIntrinsics(fx=140.0, baseline=0.12)
    vs = _system(cfg, intr)
    feat_l = extract_features(left, cfg)
    feat_r = extract_features(right, cfg)
    matches = vs.stereo_match(feat_l, feat_r)
    # corrupt the right feature coordinates before rectification
    rng = np.random.RandomState(0)
    offs = rng.randint(-2, 3, feat_r.xy.shape[0]).astype(np.float32)
    feat_r_bad = feat_r._replace(
        xy=feat_r.xy.at[:, 0].add(jnp.asarray(offs)))
    depth = vs.sad_rectify(left, right, feat_l, feat_r_bad, matches)
    v = np.asarray(depth.valid)
    assert v.sum() >= 5
    d = np.asarray(depth.disparity)[v]
    # >= 90% of matches slide back to the true shift (edge features near
    # the rolled image border may lock onto the wrap seam)
    frac = np.mean(np.abs(d - disp) <= 1.0)
    assert frac >= 0.9, (frac, d)


def test_matching_on_rendered_scene_has_depth_ground_truth():
    # generous baseline -> fine disparity resolution at 160 px width
    cfg = scenes.SceneConfig(height=120, width=160, n_points=80, seed=2,
                             baseline=0.5)
    frames, poses, intr = scenes.render_sequence(cfg, 1)
    ocfg = ORBConfig(height=120, width=160, max_features=128, n_levels=1,
                     max_disparity=64)
    out = _stereo_frame(_system(ocfg, intr), frames[0, 0], frames[0, 1])
    v = np.asarray(out.depth.valid)
    assert v.sum() >= 10
    z = np.asarray(out.depth.depth)[v]
    lo, hi = cfg.depth_range
    # >= 80% of estimated depths lie in the landmark depth band (stereo
    # mismatches on repeated texture may fall outside)
    frac = np.mean((z > lo * 0.5) & (z < hi * 2.0))
    assert frac >= 0.8, (frac, np.sort(z))


def _random_features(rng, k, h=480, w=640, n_levels=2):
    """Random FeatureSet with some invalid rows — matcher-op fodder."""
    desc = jnp.asarray(rng.randint(0, 2**32, (k, 8), dtype=np.uint64)
                       .astype(np.uint32))
    return FeatureSet(
        xy=jnp.asarray(np.stack([rng.uniform(0, w, k),
                                 rng.uniform(0, h, k)], 1)
                       .astype(np.float32)),
        level=jnp.asarray(rng.randint(0, n_levels, k).astype(np.int32)),
        score=jnp.asarray(rng.uniform(1, 50, k).astype(np.float32)),
        theta=jnp.asarray(rng.uniform(-np.pi, np.pi, k)
                          .astype(np.float32)),
        desc=desc,
        valid=jnp.asarray(rng.uniform(size=k) > 0.2),
    )


def _meta(feat):
    return jnp.stack([feat.xy[:, 0], feat.xy[:, 1],
                      feat.level.astype(jnp.float32),
                      feat.valid.astype(jnp.float32)], axis=-1)


def test_hamming_match_pinned_to_bruteforce():
    """Both impls of ops.hamming_match equal the python-loop reference
    (kernels.ref.hamming_match_bruteforce), sentinels included."""
    assert ref.MATCH_BIG == BIG
    rng = np.random.RandomState(17)
    fl = _random_features(rng, 37)
    fr = _random_features(rng, 29)
    want_d, want_i = ref.hamming_match_bruteforce(
        fl.desc, _meta(fl), fr.desc, _meta(fr),
        row_band=20.0, max_disparity=320.0)
    for impl in ("ref", "pallas"):
        d, i = ops.hamming_match(fl.desc, _meta(fl), fr.desc, _meta(fr),
                                 row_band=20.0, max_disparity=320.0,
                                 impl=impl)
        np.testing.assert_array_equal(np.asarray(d), want_d,
                                      err_msg=f"dist {impl}")
        np.testing.assert_array_equal(np.asarray(i), want_i,
                                      err_msg=f"idx {impl}")
    assert (want_i == -1).any() and (want_i >= 0).any()


def test_sad_search_pinned_to_bruteforce():
    """Both impls of ops.sad_search equal the python-loop reference."""
    rng = np.random.RandomState(18)
    k, p, r = 13, 11, 5
    lp = rng.randint(0, 256, (k, p, p)).astype(np.float32)
    rs = rng.randint(0, 256, (k, p, p + 2 * r)).astype(np.float32)
    want = ref.sad_search_bruteforce(lp, rs)
    for impl in ("ref", "pallas"):
        got = ops.sad_search(jnp.asarray(lp), jnp.asarray(rs), impl=impl)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=impl)


def test_temporal_match_pinned_to_bruteforce():
    """temporal_match is the stereo kernel with a shifted square window:
    rebuild its MatchSet from the brute-force reference with the same
    meta shift and acceptance gates.  fb plants near-duplicates of fa's
    first rows (few-bit descriptor flips, small +-dx drift) so the
    max_hamming gate actually accepts matches."""
    rng = np.random.RandomState(19)
    cfg = ORBConfig(height=480, width=640, max_hamming=80)
    fa = _random_features(rng, 41)
    fb = _random_features(rng, 33)
    n_planted = 16
    drift = rng.uniform(-30.0, 30.0, (n_planted, 2)).astype(np.float32)
    desc_b = np.asarray(fb.desc).copy()
    desc_b[:n_planted] = np.asarray(fa.desc)[:n_planted]
    desc_b[:n_planted, 0] ^= (1 << rng.randint(0, 32, n_planted)).astype(
        np.uint32)
    xy_b = np.asarray(fb.xy).copy()
    xy_b[:n_planted] = np.asarray(fa.xy)[:n_planted] + drift
    fb = fb._replace(
        desc=jnp.asarray(desc_b), xy=jnp.asarray(xy_b),
        level=fb.level.at[:n_planted].set(fa.level[:n_planted]),
        valid=fb.valid.at[:n_planted].set(True))
    radius = 48.0
    meta_a = np.asarray(_meta(fa)).copy()
    meta_a[:, 0] += radius
    want_d, want_i = ref.hamming_match_bruteforce(
        fa.desc, meta_a, fb.desc, _meta(fb),
        row_band=radius, max_disparity=2.0 * radius)
    want_valid = ((want_i >= 0) & (want_d <= cfg.max_hamming)
                  & np.asarray(fa.valid))
    for impl in ("ref", "pallas"):
        tm = _system(cfg, impl=impl).temporal_match(fa, fb,
                                                    search_radius=radius)
        np.testing.assert_array_equal(np.asarray(tm.distance), want_d,
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(tm.valid), want_valid,
                                      err_msg=impl)
        np.testing.assert_array_equal(
            np.asarray(tm.right_index), np.where(want_valid, want_i, 0),
            err_msg=impl)
    # the window is square and two-sided: some accepted matches must sit
    # at negative dx, which the raw stereo window would reject
    dx = (np.asarray(fa.xy)[:, 0]
          - np.asarray(fb.xy)[np.where(want_valid, want_i, 0), 0])
    assert (dx[want_valid] < 0).any() or want_valid.sum() == 0


def test_sad_rectify_pinned_to_bruteforce():
    """sad_rectify == numpy reconstruction: edge-padded patch gathers,
    python-loop SAD sweep, argmin re-location, disparity/depth gates."""
    rng = np.random.RandomState(20)
    h, w = 96, 144
    # wide row band + accept-all Hamming gate so random features yield a
    # healthy mix of matched and unmatched rows
    cfg = ORBConfig(height=h, width=w, sad_window=11, sad_range=5,
                    max_hamming=256, row_band=30)
    intr = CameraIntrinsics(fx=120.0, cx=72.0, cy=48.0, baseline=0.2)
    img_l = rng.randint(0, 256, (h, w)).astype(np.float32)
    img_r = rng.randint(0, 256, (h, w)).astype(np.float32)
    fl = _random_features(rng, 19, h=h, w=w)
    fr = _random_features(rng, 23, h=h, w=w)
    matches = _system(cfg).stereo_match(fl, fr)

    p, r = cfg.sad_window, cfg.sad_range

    def gather(img, xy, ph, pw):
        ry, rx = ph // 2, pw // 2
        padded = np.pad(img, ((ry, ry), (rx, rx)), mode="edge")
        out = np.zeros((xy.shape[0], ph, pw), np.float32)
        for i, (x, y) in enumerate(xy):
            xi = int(np.clip(np.round(x), 0, img.shape[1] - 1))
            yi = int(np.clip(np.round(y), 0, img.shape[0] - 1))
            out[i] = padded[yi:yi + ph, xi:xi + pw]
        return out

    xy_l = np.asarray(fl.xy)
    xy_r = np.asarray(fr.xy)[np.asarray(matches.right_index)]
    table = ref.sad_search_bruteforce(
        gather(img_l, xy_l, p, p), gather(img_r, xy_r, p, p + 2 * r))
    best = table.argmin(axis=1).astype(np.float32) - r
    x_r_rect = xy_r[:, 0] + best
    disparity = xy_l[:, 0] - x_r_rect
    valid = np.asarray(matches.valid) & (disparity > 0.5)
    depth = np.where(valid,
                     intr.fx * intr.baseline / np.maximum(disparity, 0.5),
                     0.0)
    for impl in ("ref", "pallas"):
        got = _system(cfg, intr, impl=impl).sad_rectify(
            jnp.asarray(img_l), jnp.asarray(img_r), fl, fr, matches)
        np.testing.assert_array_equal(np.asarray(got.valid), valid,
                                      err_msg=impl)
        np.testing.assert_array_equal(
            np.asarray(got.disparity), np.where(valid, disparity, 0.0),
            err_msg=impl)
        np.testing.assert_allclose(np.asarray(got.depth), depth,
                                   rtol=1e-6, err_msg=impl)
        np.testing.assert_allclose(
            np.asarray(got.xy_right),
            np.stack([x_r_rect, xy_r[:, 1]], axis=-1), rtol=1e-6,
            err_msg=impl)


def test_temporal_match_finds_same_features():
    left, _ = _stereo_pair(8)
    cfg = ORBConfig(height=128, width=192, max_features=64, n_levels=1)
    vs = _system(cfg)
    f = extract_features(left, cfg)
    m = vs.stereo_match(f, f)  # self stereo-match: dx == 0 allowed
    tm = vs.temporal_match(f, f)
    v = np.asarray(tm.valid)
    idx = np.asarray(tm.right_index)
    # every valid feature self-matches at distance 0; identically-stamped
    # squares yield identical descriptors, so ties may resolve to a twin —
    # require the matched descriptor to be identical, not the same index.
    assert np.all(np.asarray(tm.distance)[v] == 0)
    desc = np.asarray(f.desc)
    np.testing.assert_array_equal(desc[v], desc[idx[v]])
